#!/usr/bin/env python
"""Multi-device smoke: ``devices=N`` must be bit-identical to single-device.

Forces ``N`` virtual host devices (``repro.config.set_host_devices`` must
run before jax initializes, so this script applies it itself) and runs the
scenario engine's sharded dispatch — one jitted executable whose batch
axis is split over a 1-D ``shard_map`` mesh (``scenarios._compile_runner``)
— against the plain single-device runner on the same cells, for all FOUR
grid runners (``run_grid`` / ``run_replicated_grid`` / ``trace_grid`` /
``targeted_grid``), including a deliberately uneven batch that exercises
the chunker's padding path. The samplers are counter-based, so any
divergence is a sharding bug, not noise.

Usage: ``python scripts/smoke_devices.py [N]`` (default 8; the CI
multi-device matrix runs the 2- and 8-virtual-device legs). Exits
non-zero on any mismatch.
"""
from __future__ import annotations

import os
import sys

N = int(sys.argv[1]) if len(sys.argv) > 1 else 8

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import config as CFG  # noqa: E402

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    CFG.set_host_devices(N)
# topology-keyed persistent compile cache (entries are not portable
# across device counts — see repro.config.cache_dir)
os.environ.setdefault("JAX_COMPILATION_CACHE_DIR", CFG.cache_dir(N))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import scenarios as SC  # noqa: E402

CELLS = [dict(n_objects=12, n_chunks=2, k_outer=2, k_inner=8,
              r_inner=20, n_nodes=2000, byz_fraction=0.25,
              churn_per_year=52.0, step_hours=12.0, years=0.05,
              cache_ttl_hours=24.0),
         dict(n_objects=8, n_chunks=3, k_outer=2, k_inner=16,
              r_inner=48, n_nodes=4000, byz_fraction=1 / 3,
              churn_per_year=26.0, step_hours=12.0, years=0.05)]


def _diff(tag: str, a, b) -> int:
    fields = getattr(a, "_fields", None)
    pairs = zip(fields, a, b) if fields else [(tag, a, b)]
    for name, x, y in pairs:
        if not np.array_equal(np.asarray(x), np.asarray(y)):
            print(f"FAIL: {tag}: field {name!r} diverges between "
                  f"single-device and devices={N}")
            return 1
    print(f"  {tag}: bit-identical")
    return 0


def main() -> int:
    avail = jax.local_device_count()
    if avail < N:
        print(f"FAIL: {avail} local device(s), need {N} "
              "(XLA_FLAGS was set too late?)")
        return 1
    rc = 0
    # 2N seeds: the batch splits cleanly across devices AND leaves a
    # second per-device element so the in-shard vmap axis is exercised
    even, odd = range(2 * N), range(2 * N + 1)
    rc |= _diff("run_grid",
                SC.run_grid(CELLS, seeds=even, sampler="arx"),
                SC.run_grid(CELLS, seeds=even, sampler="arx", devices=N))
    # odd seed count -> B % N != 0 -> the chunker's padding path
    rc |= _diff("run_grid[uneven]",
                SC.run_grid(CELLS[:1], seeds=odd, sampler="arx"),
                SC.run_grid(CELLS[:1], seeds=odd, sampler="arx", devices=N))
    rc |= _diff("run_replicated_grid",
                SC.run_replicated_grid(CELLS, seeds=even, sampler="arx"),
                SC.run_replicated_grid(CELLS, seeds=even, sampler="arx",
                                       devices=N))
    tcell = [dict(k_inner=8, r_inner=20, byz_fraction=0.2,
                  churn_per_year=52.0, step_hours=12.0, years=0.05)]
    rc |= _diff("trace_grid",
                SC.trace_grid(tcell, seeds=odd, sampler="arx"),
                SC.trace_grid(tcell, seeds=odd, sampler="arx", devices=N))
    gcell = [dict(n_objects=30, n_chunks=4, k_outer=2, byz_fraction=1 / 3,
                  attack_frac=0.1, n_nodes=1000)]
    rc |= _diff("targeted_grid",
                SC.targeted_grid(gcell, seeds=odd, sampler="arx"),
                SC.targeted_grid(gcell, seeds=odd, sampler="arx",
                                 devices=N))
    if rc:
        return 1
    print(f"devices={N} shard_map dispatch bit-identical to single-device "
          f"across all four grid runners (sampler=arx, incl. uneven batch)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
