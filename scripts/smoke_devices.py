#!/usr/bin/env python
"""Multi-device pmap smoke: ``devices=N`` must be bit-identical to the
single-device path.

Forces ``N`` virtual host devices (``xla_force_host_platform_device_count``
must be set before jax initializes, so this script sets it itself) and runs
the scenario engine's sharded dispatch — ``run_grid(..., devices=N)``
reshapes each chunk to ``[N, B/N]`` and ``pmap``s it — against the plain
single-device runner on the same cells. The samplers are counter-based, so
any divergence is a sharding bug, not noise.

Usage: ``python scripts/smoke_devices.py [N]`` (default 8; CI runs the
8-virtual-device leg). Exits non-zero on any mismatch.
"""
from __future__ import annotations

import os
import sys

N = int(sys.argv[1]) if len(sys.argv) > 1 else 8
flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in flags:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={N}"
        f"{' ' + flags if flags else ''}")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import scenarios as SC  # noqa: E402


def main() -> int:
    avail = jax.local_device_count()
    if avail < N:
        print(f"FAIL: {avail} local device(s), need {N} "
              "(XLA_FLAGS was set too late?)")
        return 1
    cells = [dict(n_objects=12, n_chunks=2, k_outer=2, k_inner=8,
                  r_inner=20, n_nodes=2000, byz_fraction=0.25,
                  churn_per_year=52.0, step_hours=12.0, years=0.05),
             dict(n_objects=8, n_chunks=3, k_outer=2, k_inner=16,
                  r_inner=48, n_nodes=4000, byz_fraction=1 / 3,
                  churn_per_year=26.0, step_hours=12.0, years=0.05)]
    # 2N seeds: the batch must split cleanly across devices AND leave a
    # second per-device element so the in-shard vmap axis is exercised
    a = SC.run_grid(cells, seeds=range(2 * N), sampler="arx")
    b = SC.run_grid(cells, seeds=range(2 * N), sampler="arx", devices=N)
    for name, x, y in zip(a._fields, a, b):
        if not np.array_equal(np.asarray(x), np.asarray(y)):
            print(f"FAIL: field {name!r} diverges between single-device "
                  f"and devices={N}")
            return 1
    print(f"devices={N} pmap path bit-identical to single-device "
          f"({len(cells)} cells x {2 * N} seeds, sampler=arx)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
