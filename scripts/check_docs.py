"""Docs checker: execute fenced ``python`` snippets, verify intra-repo links.

Scans ``README.md`` and ``docs/*.md``:

* every fenced code block tagged ``python`` is executed in a fresh
  subprocess (``PYTHONPATH=src``, per-snippet timeout) — broken examples
  fail the build, so the docs cannot rot silently;
* every markdown link target that is not an external URL or a pure
  anchor must resolve to a file or directory in the repo (relative to the
  linking file, anchors stripped).

Used three ways: ``python scripts/check_docs.py`` (manual; nonzero exit on
any failure), ``python scripts/check_docs.py --links-only`` (the fast CI
docs gate — snippet execution already runs inside the tier-1 suite via
``tests/test_docs.py``, so CI does not pay the jit compiles twice), and
imported by ``tests/test_docs.py``.
"""
from __future__ import annotations

import pathlib
import re
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
SNIPPET_TIMEOUT_S = 180  # per snippet; engine snippets pay a jit compile

_FENCE = re.compile(r"^```(.*)$")
# [text](target) — excluding images; tolerate titles after the target
_LINK = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)[^)]*\)")


def doc_files() -> list[pathlib.Path]:
    return [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))]


def python_snippets(path: pathlib.Path) -> list[tuple[int, str]]:
    """(start line, source) of every fenced ``python`` block in ``path``.

    The language is the first word of the fence info string (so
    `````python copy`` and ````` python`` count); ANY later fence line
    closes the block, and an unterminated trailing python block is still
    returned — malformed fences must fail the gate, not silently skip it.
    """
    out, buf, lang, start = [], [], None, 0
    for i, line in enumerate(path.read_text().splitlines(), 1):
        m = _FENCE.match(line.strip())
        if m and lang is None:
            info = m.group(1).strip()
            lang = info.split()[0].lower() if info else ""
            buf, start = [], i
        elif m and lang is not None:
            if lang == "python":
                out.append((start, "\n".join(buf)))
            lang = None
        elif lang is not None:
            buf.append(line)
    if lang == "python":  # unterminated fence at EOF
        out.append((start, "\n".join(buf)))
    return out


def intra_repo_links(path: pathlib.Path) -> list[str]:
    return [t for t in _LINK.findall(path.read_text())
            if not t.startswith(("http://", "https://", "mailto:", "#"))]


def check_links(path: pathlib.Path) -> list[str]:
    """Broken intra-repo link targets of one markdown file."""
    broken = []
    for target in intra_repo_links(path):
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        base = REPO if rel.startswith("/") else path.parent
        if not (base / rel.lstrip("/")).exists():
            broken.append(target)
    return broken


def run_snippet(src: str, timeout: int = SNIPPET_TIMEOUT_S):
    """Run one snippet; returns (ok, combined output)."""
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(REPO / "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    try:
        proc = subprocess.run(
            [sys.executable, "-c", src], capture_output=True, text=True,
            timeout=timeout, env=env, cwd=REPO)
    except subprocess.TimeoutExpired:
        return False, f"timeout after {timeout}s"
    return proc.returncode == 0, proc.stdout + proc.stderr


def main(links_only: bool = False) -> int:
    failures = 0
    for path in doc_files():
        rel = path.relative_to(REPO)
        broken = check_links(path)
        for target in broken:
            failures += 1
            print(f"[FAIL] {rel}: broken link -> {target}")
        if links_only:
            if not broken:
                print(f"[ok] {rel} links "
                      f"({len(intra_repo_links(path))} checked)")
            continue
        for line, src in python_snippets(path):
            ok, out = run_snippet(src)
            status = "ok" if ok else "FAIL"
            print(f"[{status}] {rel}:{line} python snippet "
                  f"({len(src.splitlines())} lines)")
            if not ok:
                failures += 1
                print(out)
    print(f"{failures} failure(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main(links_only="--links-only" in sys.argv[1:]))
