"""Emit the EXPERIMENTS.md §Roofline markdown table from dry-run JSONs.

    PYTHONPATH=src python scripts/gen_roofline_md.py results/dryrun single
"""
import json
import pathlib
import sys


def main(d: str, mesh: str):
    rows = []
    for p in sorted(pathlib.Path(d).glob(f"*__{mesh}.json")):
        r = json.loads(p.read_text())
        if not r.get("ok"):
            rows.append((r["arch"], r["shape"], "FAIL", 0, 0, 0, 0, 0))
            continue
        rl = r["roofline"]
        rows.append((
            r["arch"], r["shape"], rl["dominant"], rl["compute_s"],
            rl["memory_s"], rl["collective_s"],
            r.get("hlo_model_flops_ratio", 0),
            r.get("state_bytes_per_device", 0) / 2**30,
        ))
    print("| arch | shape | compute_s | memory_s | collective_s | dominant "
          "| useful (6·N·D / HLO) | state GiB/dev |")
    print("|---|---|---|---|---|---|---|---|")
    for a, s, d_, c, m, co, u, g in rows:
        print(f"| {a} | {s} | {c:.3f} | {m:.2f} | {co:.3f} | **{d_}** | "
              f"{u:.3f} | {g:.1f} |")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun",
         sys.argv[2] if len(sys.argv) > 2 else "single")
