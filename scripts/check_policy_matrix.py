#!/usr/bin/env python
"""Policy-matrix drift guard: every registered zoo policy is cross-validated.

``benchmarks/cross_validate.py`` auto-discovers its matched-config matrix
from the policy zoo registry (``policies.zoo_members()``), so *registering*
a policy is what adds its cross-validation row. That coupling drifts in
two ways:

* the benchmark quietly stops auto-discovering — someone reverts
  ``matched_configs`` to a hand-written dict and newly registered policies
  silently fall out of the matrix;
* a policy is waived via ``EXCLUDED_ROWS`` without a recorded reason, or
  a waiver goes stale (names a policy that was since renamed or removed)
  and would shadow a future policy of the same name.

This script re-derives both sides from the *source text*: the
``_register(ZooEntry(name=..., ...))`` literals in
``src/repro/core/policies.py`` (they are kept ast-parseable by
convention — a comment in the registry says so) and the ``EXCLUDED_ROWS``
dict literal plus the ``zoo_members()`` call in
``benchmarks/cross_validate.py``. It exits non-zero on any drift and
deliberately has **no dependencies beyond the stdlib** — the docs CI job
that runs it installs nothing, so it must not import the repo (which
needs jax/numpy).

Usage: ``python scripts/check_policy_matrix.py [--policies PATH]
[--bench PATH]`` (defaults: src/repro/core/policies.py and
benchmarks/cross_validate.py).
"""
from __future__ import annotations

import argparse
import ast
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]


def registered_names(policies_path: pathlib.Path) -> list[str]:
    """Zoo names from the ``_register(ZooEntry(name=...))`` literals."""
    tree = ast.parse(policies_path.read_text())
    names: list[str] = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id == "_register"):
            continue
        for arg in node.args:
            if not (isinstance(arg, ast.Call)
                    and isinstance(arg.func, (ast.Name, ast.Attribute))):
                continue
            for kw in arg.keywords:
                if (kw.arg == "name"
                        and isinstance(kw.value, ast.Constant)
                        and isinstance(kw.value.value, str)):
                    names.append(kw.value.value)
    if not names:
        raise SystemExit(
            f"check_policy_matrix: no _register(ZooEntry(name=...)) "
            f"literals found in {policies_path} — registry moved or no "
            "longer ast-parseable?")
    return names


def parse_bench(bench_path: pathlib.Path) -> tuple[dict[str, str], bool]:
    """``(EXCLUDED_ROWS literal, does the module call zoo_members())``."""
    tree = ast.parse(bench_path.read_text())
    excluded: dict[str, str] | None = None
    for node in ast.walk(tree):
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets = [node.target]
        for t in targets:
            if isinstance(t, ast.Name) and t.id == "EXCLUDED_ROWS":
                try:
                    excluded = ast.literal_eval(node.value)
                except ValueError:
                    raise SystemExit(
                        "check_policy_matrix: EXCLUDED_ROWS is not a "
                        "plain dict literal — keep it ast-parseable")
    discovers = any(
        isinstance(node, ast.Call)
        and ((isinstance(node.func, ast.Attribute)
              and node.func.attr == "zoo_members")
             or (isinstance(node.func, ast.Name)
                 and node.func.id == "zoo_members"))
        for node in ast.walk(tree))
    if excluded is None:
        raise SystemExit(
            f"check_policy_matrix: no EXCLUDED_ROWS dict in {bench_path}")
    return excluded, discovers


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--policies",
                    default=str(ROOT / "src" / "repro" / "core"
                                / "policies.py"))
    ap.add_argument("--bench",
                    default=str(ROOT / "benchmarks" / "cross_validate.py"))
    args = ap.parse_args(argv)

    names = registered_names(pathlib.Path(args.policies))
    excluded, discovers = parse_bench(pathlib.Path(args.bench))

    errors: list[str] = []
    if not discovers:
        errors.append(
            "benchmarks/cross_validate.py no longer calls zoo_members() — "
            "the matrix is not auto-discovered, so registered policies can "
            "silently drop out of cross-validation")
    for n in {x for x in names if names.count(x) > 1}:
        errors.append(f"{n}: registered more than once in the zoo")
    for n, reason in sorted(excluded.items()):
        if n not in names:
            errors.append(
                f"{n}: waived in EXCLUDED_ROWS but not a registered zoo "
                "policy (stale waiver — remove it)")
        if not (isinstance(reason, str) and reason.strip()):
            errors.append(
                f"{n}: EXCLUDED_ROWS waiver has no reason — every "
                "exclusion must record why")

    if errors:
        print("check_policy_matrix: registry/matrix drift:",
              file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1
    rows = [n for n in names if n not in excluded]
    print(f"check_policy_matrix: OK — {len(names)} registered policies: "
          f"{len(rows)} cross-validated + {len(excluded)} waived "
          "with reasons")
    return 0


if __name__ == "__main__":
    sys.exit(main())
