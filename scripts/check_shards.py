#!/usr/bin/env python
"""CI shard drift guard: every tier-1 test file runs in exactly one shard.

The tier-1 suite is split across two CI jobs (see .github/workflows/ci.yml):
the *engine* shard runs the files listed in the ``ENGINE_SHARD`` env var and
the *core* shard runs everything else by passing ``--ignore=`` for each
engine file. That partition drifts in two ways:

* a file lands in the core shard's ignore set without being in
  ``ENGINE_SHARD`` (e.g. someone adds a literal ``--ignore=tests/...`` to
  "temporarily" skip a slow file) — it is then collected by **neither**
  shard and silently stops running in CI;
* a file is in ``ENGINE_SHARD`` but missing from the core ignore set — it
  is collected by **both** shards and double-bills CI minutes.

Plus the cheap staleness cases: ``ENGINE_SHARD`` naming a file that no
longer exists (the engine shard would hard-fail on collection) or naming
one twice.

This script re-derives both sides from the workflow file and the
``tests/test_*.py`` files on disk and exits non-zero on any drift. It
deliberately has **no dependencies beyond the stdlib** (no PyYAML — the
docs CI job that runs it installs nothing), so the workflow is parsed
with a purpose-built reader: the ``ENGINE_SHARD: >-`` folded block and
``--ignore=`` occurrences, with ``--ignore=$var`` loop forms expanding to
the ``ENGINE_SHARD`` set exactly as the shell step does.

Usage: ``python scripts/check_shards.py [--workflow PATH] [--tests DIR]``
(defaults: .github/workflows/ci.yml and tests/).
"""
from __future__ import annotations

import argparse
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]


def parse_engine_shard(text: str) -> list[str]:
    """Extract the ENGINE_SHARD file list (inline or folded-block scalar)."""
    m = re.search(r"^(\s*)ENGINE_SHARD:[ \t]*(.*)$", text, re.MULTILINE)
    if not m:
        raise SystemExit("check_shards: no ENGINE_SHARD key in workflow")
    indent, inline = m.groups()
    if inline and not inline.startswith((">", "|")):
        return inline.split()
    # folded/literal block: consume lines indented deeper than the key
    files: list[str] = []
    for line in text[m.end():].splitlines():
        if line.strip() and not line.startswith(indent + " "):
            break
        files.extend(line.split())
    return files


def parse_core_ignores(text: str, engine: list[str]) -> set[str]:
    """The core shard's effective ignore set.

    Literal ``--ignore=tests/...`` flags are taken as-is; the
    ``--ignore=$t``-inside-``for t in $ENGINE_SHARD`` loop form expands to
    the full ENGINE_SHARD list, mirroring what the shell does.
    """
    ignores: set[str] = set()
    for val in re.findall(r"--ignore=(\S+)", text):
        val = val.strip("\"'")
        if "$" not in val:
            ignores.add(val)
        elif re.search(r"for\s+\w+\s+in\s+\$\{?ENGINE_SHARD", text):
            ignores.update(engine)
        else:
            raise SystemExit(
                f"check_shards: --ignore={val} uses a variable but no "
                "'for ... in $ENGINE_SHARD' loop was found — cannot "
                "derive the core shard's ignore set")
    if not ignores:
        raise SystemExit(
            "check_shards: no --ignore= flags found — the core shard no "
            "longer excludes the engine files?")
    return ignores


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--workflow",
                    default=str(ROOT / ".github" / "workflows" / "ci.yml"))
    ap.add_argument("--tests", default=str(ROOT / "tests"))
    args = ap.parse_args(argv)

    text = pathlib.Path(args.workflow).read_text()
    engine = parse_engine_shard(text)
    ignores = parse_core_ignores(text, engine)
    on_disk = {f"tests/{p.name}"
               for p in pathlib.Path(args.tests).glob("test_*.py")}

    errors: list[str] = []
    for f in {x for x in engine if engine.count(x) > 1}:
        errors.append(f"{f}: listed more than once in ENGINE_SHARD")
    for f in sorted(set(engine) - on_disk):
        errors.append(f"{f}: in ENGINE_SHARD but not on disk "
                      "(stale entry — engine shard fails at collection)")
    for f in sorted((ignores & on_disk) - set(engine)):
        errors.append(f"{f}: ignored by the core shard but absent from "
                      "ENGINE_SHARD — collected by NEITHER shard")
    for f in sorted(set(engine) - ignores):
        errors.append(f"{f}: in ENGINE_SHARD but not ignored by the core "
                      "shard — collected by BOTH shards")

    if errors:
        print("check_shards: shard partition drift:", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1
    core = sorted(on_disk - ignores)
    print(f"check_shards: OK — {len(engine)} engine + {len(core)} core "
          f"= {len(on_disk)} test files, each collected exactly once")
    return 0


if __name__ == "__main__":
    sys.exit(main())
