#!/usr/bin/env bash
# Tier-1 test runner: sets PYTHONPATH and a deterministic single-device JAX
# host platform (multi-device tests fork their own subprocesses with their
# own XLA_FLAGS — see tests/conftest.py). Override the device count with
# XLA_DEVICES=n for local experiments.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export XLA_FLAGS="--xla_force_host_platform_device_count=${XLA_DEVICES:-1}${XLA_FLAGS:+ $XLA_FLAGS}"

# Persistent XLA compilation cache: repeat runs skip the ~9 s engine jit
# compiles (only compiles above jax's 1 s min-compile-time threshold are
# stored). Point JAX_COMPILATION_CACHE_DIR elsewhere to relocate it.
# The directory is keyed by the virtual device count: the cache key does
# NOT cover xla_force_host_platform_device_count, and replaying an entry
# compiled under a different host topology returns corrupted outputs
# (uninitialized buffers — bitten by the 8-device CI leg).
export JAX_COMPILATION_CACHE_DIR="${JAX_COMPILATION_CACHE_DIR:-$HOME/.cache/repro-jax-cache-d${XLA_DEVICES:-1}}"

exec python -m pytest -x -q "$@"
