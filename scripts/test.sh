#!/usr/bin/env bash
# Tier-1 test runner. Environment setup (device count, XLA flags, the
# topology-keyed persistent compilation cache) comes from ONE place —
# `python -m repro.config` (see src/repro/config.py) — shared with
# tests/conftest.py, scripts/smoke_devices.py and benchmarks/common.py.
# Override the virtual device count with XLA_DEVICES=n (default 1; the
# main pytest process stays single-device — multi-device tests fork
# their own subprocesses with their own flags, see tests/conftest.py).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Persistent XLA compilation cache: repeat runs skip the ~9 s engine jit
# compiles. repro.config keys the directory by device count — the cache
# key does NOT cover xla_force_host_platform_device_count, and replaying
# an entry compiled under a different host topology returns corrupted
# outputs (uninitialized buffers — bitten by the 8-device CI leg).
eval "$(python -m repro.config)"

exec python -m pytest -x -q "$@"
