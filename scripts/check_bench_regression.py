#!/usr/bin/env python
"""Gate benchmark throughput against the committed trajectory point.

The repo commits one machine-readable ``BENCH_<name>.json`` per benchmark
(the "trajectory": every PR that touches performance refreshes it). The CI
``bench-regression`` job snapshots the committed files, re-runs the
benchmarks at ``BENCH_SCALE=quick``, and calls this script to compare the
fresh numbers with the snapshot:

    python scripts/check_bench_regression.py \
        --baseline /tmp/bench-baseline --current results/bench

A throughput metric may regress by at most ``--tolerance`` (default 0.30,
the >30% gate; override with ``BENCH_REGRESSION_TOLERANCE`` for noisy
hosts). Only metrics present in BOTH files are compared, so adding new
fields never breaks older baselines. Higher-is-better metrics are the
``*_per_s`` and ``speedup*`` families; ``*_ms``/``*_s`` latencies are
compared in the inverse direction.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys

# headline metrics gated per benchmark: name -> higher_is_better
GATED = {
    "steps_per_s": True,
    "samples_per_s": True,
    "node_ticks_per_s": True,
    "reads_per_s": True,
    "speedup_vs_loop": True,
    "speedup_best": True,
    "engine_s": False,
    "tick_ms_vectorized_hash": False,
    "tick_ms_vectorized_arx": False,
    "eclipse_month_s": False,
}


def _flatten(headline: dict, prefix: str = "") -> dict[str, float]:
    out: dict[str, float] = {}
    for k, v in headline.items():
        if isinstance(v, dict):
            out.update(_flatten(v, f"{prefix}{k}."))
        elif isinstance(v, (int, float)) and not isinstance(v, bool):
            out[f"{prefix}{k}"] = float(v)
    return out


def compare(baseline: dict, current: dict, tolerance: float,
            name: str) -> list[str]:
    if baseline.get("scale") != current.get("scale"):
        return [f"{name}: scale mismatch "
                f"({baseline.get('scale')} vs {current.get('scale')}) — "
                "not comparable"]
    base = _flatten(baseline.get("headline", {}))
    cur = _flatten(current.get("headline", {}))
    failures = []
    for key in sorted(set(base) & set(cur)):
        leaf = key.rsplit(".", 1)[-1]
        if leaf not in GATED:
            continue
        b, c = base[key], cur[key]
        if b <= 0:
            continue
        # lower-is-better metrics invert; a current value of 0 there is an
        # infinite improvement, never a regression
        ratio = c / b if GATED[leaf] else (float("inf") if c == 0
                                           else b / c)
        status = "ok" if ratio >= 1.0 - tolerance else "REGRESSION"
        print(f"  {name}:{key}: baseline={b:g} current={c:g} "
              f"({ratio:.2f}x of baseline) {status}")
        if status != "ok":
            failures.append(f"{name}:{key} at {ratio:.2f}x of baseline "
                            f"(tolerance {1.0 - tolerance:.2f}x)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True,
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--current", default="results/bench",
                    help="directory holding the freshly emitted files")
    ap.add_argument("--tolerance", type=float, default=float(
        os.environ.get("BENCH_REGRESSION_TOLERANCE", "0.30")),
        help="allowed fractional throughput loss (default 0.30)")
    args = ap.parse_args()

    base_dir = pathlib.Path(args.baseline)
    cur_dir = pathlib.Path(args.current)
    baselines = sorted(base_dir.glob("BENCH_*.json"))
    if not baselines:
        print(f"no BENCH_*.json under {base_dir} — nothing to gate")
        return 1
    failures: list[str] = []
    compared = 0
    for bpath in baselines:
        cpath = cur_dir / bpath.name
        if not cpath.exists():
            failures.append(f"{bpath.name}: benchmark emitted no fresh "
                            f"file at {cpath}")
            continue
        compared += 1
        failures += compare(json.loads(bpath.read_text()),
                            json.loads(cpath.read_text()),
                            args.tolerance, bpath.stem)
    if failures:
        print("\nbench regression gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"\nbench regression gate passed ({compared} trajectory "
          f"point(s), tolerance {args.tolerance:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
