"""Durability under attack: Byzantine claimers, churn, a targeted attack,
and decentralized repair keeping objects alive — VAULT vs the replicated
baseline on the SAME network.

    PYTHONPATH=src python examples/durable_store_demo.py
"""
import numpy as np

from repro.core import chunks as C
from repro.core import group as G
from repro.core import repair as R
from repro.core.baseline import ReplicatedStore
from repro.core.network import SimNetwork
from repro.core.vault import VaultClient

rng = np.random.default_rng(0)
net = SimNetwork(seed=0)
N, BYZ = 200, 66
for i in range(N):
    net.add_node(byzantine=i < BYZ, seed=i.to_bytes(4, "little"))
print(f"network: {N} peers, {BYZ} byzantine ({BYZ/N:.0%})")

params = C.CodeParams(k_outer=4, n_chunks=8, k_inner=8, r_inner=24)
client = VaultClient(net, net.alive_nodes()[80])
repl = ReplicatedStore(net, replication=3)

objects = []
for i in range(6):
    data = rng.integers(0, 256, 20_000, np.uint8).tobytes()
    oid, _ = client.store(data, params, cache_ttl=1e9)
    rid, _ = repl.store(client.node, data)
    objects.append((data, oid, rid))
print(f"stored {len(objects)} objects in both systems "
      f"(vault redundancy {params.redundancy:.2f}x vs 3x replication)")


def survey(label):
    v_ok = r_ok = 0
    for data, oid, rid in objects:
        try:
            got, _ = client.query(oid)
            v_ok += int(got == data)
        except Exception:
            pass
        try:
            got, _ = repl.query(client.node, rid)
            r_ok += int(got == data)
        except Exception:
            pass
    print(f"{label}: vault {v_ok}/{len(objects)} alive, "
          f"replicated {r_ok}/{len(objects)} alive")


survey("initial")

# --- churn: 25% of peers leave; both systems repair -------------------
alive = [n for n in net.alive_nodes() if n.nid != client.node.nid]
for node in rng.choice(alive, size=len(alive) // 4, replace=False):
    net.fail_node(node.nid)
for node in list(net.alive_nodes()):
    G.broadcast_claims(net, node)
R.repair_all(net, cache_ttl=1e9)
repl.repair_tick()
survey("after 25% churn + repair")

# --- targeted attack: adversary knows the replicated placement --------
# (vault's chunk->object mapping is opaque; the attacker can only hit
# random groups)
for data, oid, rid in objects[:3]:
    for nid in list(repl.placement.get(rid.ohash, [])):
        if nid in net.nodes and net.nodes[nid].alive:
            net.fail_node(nid)
print("targeted attack: adversary disconnected every replica holder of 3 "
      "replicated objects (9-ish nodes)")
# two maintenance rounds: heartbeats -> membership convergence -> repair
for _ in range(2):
    for node in list(net.alive_nodes()):
        G.broadcast_claims(net, node)
    R.repair_all(net, cache_ttl=1e9)
    repl.repair_tick()
survey("after targeted attack + repair")
print(f"repair traffic so far: {net.repair_traffic_bytes/2**20:.1f} MiB, "
      f"{net.repair_count} fragments regenerated")

# --- paper-scale Monte-Carlo: the batched scenario engine ----------------
# The protocol-level network above runs real coding on 200 peers; the
# batched engine extrapolates the same dynamics to thousands of groups
# under three adversary/churn models in ONE device dispatch (8 seeds each).
from repro.core import scenarios as SC

base = dict(n_objects=100, n_chunks=8, k_outer=4, k_inner=8, r_inner=24,
            n_nodes=2000, byz_fraction=0.33, churn_per_year=26.0,
            step_hours=12.0, years=0.5)
cells = [
    ("iid churn / static byz", dict(base)),
    ("regional bursts", dict(base, churn_policy="regional",
                             burst_prob=0.1, burst_mult=10.0)),
    ("adaptive re-join", dict(base, adv_policy="adaptive",
                              adapt_boost=1.5)),
    ("targeted greedy-kill", dict(base, adv_policy="targeted",
                                  attack_frac=0.2, attack_step=180)),
]
res = SC.run_grid([c for _, c in cells], seeds=range(8), sampler="arx")
lost_m, lost_ci = SC.mean_ci(res.lost_fraction)
traf_m, traf_ci = SC.mean_ci(res.repair_traffic_units)
print("\nbatched engine sweep (100 objects x 6 months, 8 seeds/scenario):")
for i, (name, _) in enumerate(cells):
    print(f"  {name:24s} lost {lost_m[i]:6.1%} ±{lost_ci[i]:.1%}   "
          f"repair traffic {traf_m[i]:8.1f} ±{traf_ci[i]:.1f} obj-units")
