"""Elastic rescale drill: lose hosts mid-run, resume on a smaller mesh.

Runs in a subprocess with 8 placeholder devices:
  1. train on a (4 data, 2 model) mesh, Vault-checkpoint at step 5,
  2. "lose" half the hosts → re-plan to a (2, 2) mesh,
  3. restore from Vault, reshard with the same logical rules, resume —
     and verify the loss trajectory continues from the checkpoint.

    PYTHONPATH=src python examples/elastic_rescale.py
"""
import os
import pathlib
import subprocess
import sys

SRC = pathlib.Path(__file__).resolve().parents[1] / "src"

CODE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.checkpoint import VaultCheckpointer
from repro.core import chunks as C
from repro.core.network import SimNetwork
from repro.data import SyntheticStream
from repro.distributed import sharding as shd
from repro.models import param_specs
from repro.optim import AdamWConfig
from repro.runtime.elastic import plan_mesh, reshard_state, state_shardings
from repro.training import init_train_state, make_train_step

cfg = configs.smoke_config("internlm2-20b")
opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=20)
stream = SyntheticStream(cfg, batch=4, seq=32, seed=0)
step_fn = make_train_step(cfg, opt)

def shardings_for(mesh, state_shapes):
    named = state_shardings(param_specs(cfg), state_shapes["params"], mesh)
    return {"params": named,
            "opt": {"mu": named, "nu": named,
                    "step": NamedSharding(mesh, P())}}

# ---- phase 1: 8 devices as (4 data, 2 model)
mesh1 = jax.make_mesh((4, 2), ("data", "model"))
state = init_train_state(cfg, jax.random.PRNGKey(0))
shapes = jax.eval_shape(lambda: state)
sh1 = shardings_for(mesh1, shapes)
state = reshard_state(jax.tree_util.tree_map(np.asarray, state), sh1)
losses = []
with mesh1, shd.logical_axis_rules(None, mesh1):
    f1 = jax.jit(step_fn, in_shardings=(sh1, None), out_shardings=(sh1, None))
    for t in range(5):
        batch = {k: jnp.asarray(v) for k, v in stream.batch_at(t).items()}
        state, m = f1(state, batch)
        losses.append(float(m["loss"]))
print("phase1 losses:", [round(x, 4) for x in losses])

net = SimNetwork(seed=1)
for i in range(150):
    net.add_node(seed=i.to_bytes(4, "little"))
ck = VaultCheckpointer(net, params=C.CodeParams(k_outer=4, n_chunks=6,
                                                k_inner=8, r_inner=20),
                       object_bytes=1 << 18)
host_state = jax.tree_util.tree_map(np.asarray, state)
ck.save(host_state, step=5)
print("checkpointed to vault at step 5")

# ---- phase 2: half the fleet is gone; kill 40% of vault peers too
rng = np.random.default_rng(2)
for node in rng.choice(net.alive_nodes()[1:], size=60, replace=False):
    net.fail_node(node.nid)
d, mdl = plan_mesh(4, prefer_model=cfg.n_heads)
mesh2 = jax.make_mesh((d, mdl), ("data", "model"))
print(f"re-meshed to ({d},{mdl}) on 4 surviving devices; "
      f"{len(net.alive_nodes())} vault peers alive")
restored = ck.restore(5)
sh2 = shardings_for(mesh2, shapes)
state2 = reshard_state(restored, sh2)
with mesh2, shd.logical_axis_rules(None, mesh2):
    f2 = jax.jit(step_fn, in_shardings=(sh2, None), out_shardings=(sh2, None))
    for t in range(5, 10):
        batch = {k: jnp.asarray(v) for k, v in stream.batch_at(t).items()}
        state2, m = f2(state2, batch)
        losses.append(float(m["loss"]))
print("resumed losses:", [round(x, 4) for x in losses[5:]])
assert losses[5] < losses[0], "resumed run lost progress"
print("ELASTIC RESCALE OK")
"""


def main() -> int:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run([sys.executable, "-c", CODE], env=env,
                         capture_output=True, text=True, timeout=600)
    print(out.stdout)
    if out.returncode != 0:
        print(out.stderr[-3000:])
    return out.returncode


if __name__ == "__main__":
    raise SystemExit(main())
