"""End-to-end driver: train an LM with Vault-backed fault tolerance.

Wraps the production launcher (``repro.launch.train``) with a failure drill:
periodic Vault checkpoints into a 200-peer simulated network with 20%
Byzantine claimers, a mid-run loss of 30% of the peers, restore, and resume.

Defaults are CPU-friendly (~1M params, 60 steps). ``--big`` trains a ~120M
parameter codeqwen-family model for a few hundred steps — the "train ~100M
for a few hundred steps" configuration (hours on this 1-core box; sized for
a real cluster).

    PYTHONPATH=src python examples/train_with_vault_checkpoint.py
    PYTHONPATH=src python examples/train_with_vault_checkpoint.py --big
"""
import sys

sys.argv = [sys.argv[0]] + (
    [
        "--arch", "codeqwen1.5-7b", "--steps", "300", "--batch", "8",
        "--seq", "512", "--ckpt-every", "50", "--kill-at", "120",
        "--kill-fraction", "0.3", "--byz-fraction", "0.2",
        "--vault-nodes", "200", "--log-every", "20", "--full-ish",
    ]
    if "--big" in sys.argv
    else [
        "--arch", "codeqwen1.5-7b", "--steps", "60", "--batch", "8",
        "--seq", "128", "--ckpt-every", "20", "--kill-at", "30",
        "--kill-fraction", "0.3", "--byz-fraction", "0.2",
        "--vault-nodes", "200", "--log-every", "10",
    ]
)

if "--full-ish" in sys.argv:
    # ~120M-param mid-size config: the smoke architecture scaled up
    sys.argv.remove("--full-ish")
    import dataclasses

    from repro import configs
    from repro.models import LayerPattern

    _orig = configs.smoke_config

    def _bigger(arch):
        cfg = _orig(arch)
        return dataclasses.replace(
            cfg, d_model=512, n_heads=8, n_kv_heads=8, d_ff=1536,
            vocab=32_000,
            pattern=(LayerPattern(12, (("gqa", "dense"),)),),
        )

    configs.smoke_config = _bigger

from repro.launch.train import main  # noqa: E402

raise SystemExit(main())
