"""Serve a small model with batched requests: prefill + decode loop.

    PYTHONPATH=src python examples/serve_lm.py [--arch minicpm3-4b]
"""
import sys

if len(sys.argv) == 1:
    sys.argv += ["--arch", "minicpm3-4b", "--batch", "4",
                 "--prompt-len", "48", "--decode-steps", "24"]

from repro.launch.serve import main  # noqa: E402

raise SystemExit(main())
