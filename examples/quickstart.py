"""Quickstart: VAULT in 60 seconds.

1. spin up a simulated decentralized network (1/3 Byzantine),
2. STORE an object (outer rateless code -> opaque chunks -> VRF-selected
   fragment groups), QUERY it back,
3. evaluate the durability theory for the deployment,
4. train a tiny LM whose checkpoints live in the vault.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import VaultCheckpointer
from repro.core import chunks as C
from repro.core import durability as D
from repro.core.network import SimNetwork
from repro.core.vault import VaultClient
from repro import configs
from repro.data import SyntheticStream
from repro.optim import AdamWConfig
from repro.training import init_train_state, make_train_step

# ---------------------------------------------------------------- network
net = SimNetwork(seed=0)
for i in range(150):
    net.add_node(byzantine=i < 50, seed=i.to_bytes(4, "little"))
print(f"network: {net.n_nodes} peers, 50 byzantine (1/3)")

# ------------------------------------------------------------ store/query
params = C.CodeParams(k_outer=8, n_chunks=10, k_inner=16, r_inner=40)
client = VaultClient(net, net.alive_nodes()[60])
data = np.random.default_rng(0).integers(0, 256, 100_000, np.uint8).tobytes()
oid, st = client.store(data, params)
print(f"STORE 100KB: {len(oid.chunk_hashes)} chunks, "
      f"redundancy {params.redundancy:.2f}x, latency {st.latency_s:.2f}s "
      f"(modeled geo-RTT)")
got, qt = client.query(oid)
assert got == data
print(f"QUERY OK: latency {qt.latency_s:.2f}s")

# ------------------------------------------------------------- durability
I = D.initial_state_vector(net.n_nodes, 50, params.r_inner, params.k_inner)
theta = D.transition_matrix(net.n_nodes, 50, params.r_inner, params.k_inner,
                            churn_mu=0.1, evict=1)
p_group = D.absorb_probability(I, theta, 365)[-1]
print(f"durability (CTMC, 1y): group absorb {p_group:.2e}, object bound "
      f"{D.object_loss_bound(p_group, params.n_chunks):.2e}")

# -------------------------------------------- vault-checkpointed training
cfg = configs.smoke_config("codeqwen1.5-7b")
state = init_train_state(cfg, jax.random.PRNGKey(0))
step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=2,
                                                total_steps=20)))
stream = SyntheticStream(cfg, batch=4, seq=32, seed=0)
ck = VaultCheckpointer(net, params=params, object_bytes=1 << 18)
for t in range(10):
    batch = {k: jnp.asarray(v) for k, v in stream.batch_at(t).items()}
    state, m = step(state, batch)
rep = ck.save(jax.tree_util.tree_map(np.asarray, state), step=10)
print(f"trained 10 steps (loss {float(m['loss']):.3f}); checkpoint -> vault "
      f"({rep.n_objects} objects, {rep.bytes/2**20:.1f} MiB)")
restored = ck.restore(10)
print("restore OK — bytes identical:",
      all(np.array_equal(np.asarray(a), np.asarray(b)) for a, b in zip(
          jax.tree_util.tree_leaves(state),
          jax.tree_util.tree_leaves(restored))))
