"""Roofline table from the dry-run sweep (results/dryrun/*.json).

Per (arch × shape × mesh): the three per-device roofline terms in seconds
(compute @197 TFLOP/s bf16, memory @819 GB/s HBM, collective @50 GB/s/link),
the dominant term, MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D
(serve), and the useful-compute ratio MODEL_FLOPS / (HLO_FLOPs × chips).

Run the sweep first:  PYTHONPATH=src python -m repro.launch.dryrun --all
"""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import emit

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[1] / "results"
DRYRUN = RESULTS_DIR / "dryrun"
BASELINE = RESULTS_DIR / "dryrun_baseline"


def load(mesh: str | None = None, tag: str = "") -> list[dict]:
    """Tuned sweep results, overlaid on the paper-faithful baseline for any
    cell the tuned sweep hasn't (re)compiled yet."""
    by_cell: dict[tuple, dict] = {}
    for directory, config in ((BASELINE, "baseline"), (DRYRUN, "tuned")):
        if not directory.exists():
            continue
        for p in sorted(directory.glob("*.json")):
            r = json.loads(p.read_text())
            if not r.get("ok"):
                continue
            if mesh and r["mesh"] != mesh:
                continue
            if (r.get("tag") or "") != tag:
                continue
            r["config"] = config
            by_cell[(r["arch"], r["shape"], r["mesh"])] = r
    return [by_cell[k] for k in sorted(by_cell)]


def rows_from(recs: list[dict]) -> list[dict]:
    rows = []
    for r in recs:
        rl = r.get("roofline")
        if not rl:
            continue
        rows.append({
            "arch": r["arch"],
            "shape": r["shape"],
            "mesh": r["mesh"],
            "compute_s": f"{rl['compute_s']:.4f}",
            "memory_s": f"{rl['memory_s']:.4f}",
            "collective_s": f"{rl['collective_s']:.4f}",
            "dominant": rl["dominant"],
            "bound_s": f"{rl['bound_s']:.4f}",
            "useful_ratio": f"{min(r.get('hlo_model_flops_ratio', 0), 9):.3f}",
            "state_GiB/dev": f"{r.get('state_bytes_per_device', 0)/2**30:.2f}",
            "config": r.get("config", "tuned"),
        })
    return rows


def run():
    # single-pod is the roofline table per the brief; multi-pod proves the
    # pod axis shards (reported separately)
    single = rows_from(load("single"))
    multi = rows_from(load("multi"))
    emit("roofline_single_pod", single)
    emit("roofline_multi_pod", multi)
    if single:
        worst = min(single, key=lambda r: float(r["useful_ratio"]))
        coll = [r for r in single if r["dominant"] == "collective"]
        print(f"  -> worst useful-compute ratio: {worst['arch']} "
              f"{worst['shape']} ({worst['useful_ratio']})")
        if coll:
            print(f"  -> collective-bound cells: "
                  f"{[(r['arch'], r['shape']) for r in coll]}")
    return single + multi


if __name__ == "__main__":
    run()
