"""Lemma 4.1 / 4.2 (App. A): the durability theory evaluated at paper
parameters — CTMC absorbing probabilities, Hoeffding initial bound, and the
targeted-attack birthday bound, cross-checked against Monte-Carlo."""
from __future__ import annotations

from benchmarks.common import SCALE, emit
from repro.core import durability as D
from repro.core import scenarios as SC

SEEDS = tuple(range(8))


def run():
    N, F = 100_000, 33_333
    rows = []
    for (n, k) in ((80, 32), (64, 32), (112, 32)):
        I = D.initial_state_vector(N, F, n, k)
        hoeff = D.hoeffding_initial_bound(n, k)
        theta = D.transition_matrix(N, F, n, k, churn_mu=0.2, evict=1)
        traj = D.absorb_probability(I, theta, 365)
        p_group = traj[-1]
        rows.append({
            "model": "ctmc",
            "config": f"({k},{n})",
            "init_absorb": f"{I[-1]:.3e}",
            "hoeffding": f"{hoeff:.3e}",
            "absorb_1y": f"{p_group:.3e}",
            "object_bound_1y": f"{D.object_loss_bound(p_group, 10):.3e}",
        })
    # Monte-Carlo cross-check of the CTMC: batched engine, mean over seeds.
    # Quick scale simulates half a year — the config column records the
    # horizon so the row is not misread against the 1-year CTMC numbers.
    quick = SCALE == "quick"
    mc_years = 0.5 if quick else 1.0
    mc = SC.run_grid([dict(
        n_objects=200 if quick else 400, byz_fraction=1 / 3,
        churn_per_year=26.0, step_hours=12.0 if quick else 6.0,
        years=mc_years)], seeds=SEEDS, sampler="arx")
    rows.append({
        "model": "monte-carlo", "config": f"(32,80) {mc_years:g}y",
        "init_absorb": "", "hoeffding": "",
        "absorb_1y": f"{float(mc.lost_fraction[0].mean()):.3e}"
                     f"±{float(mc.lost_fraction[0].std()):.1e}",
        "object_bound_1y": "",
    })
    # targeted-attack bound (Lemma 4.2) vs Monte-Carlo attack sim — one
    # batched dispatch over all attack budgets x seeds
    phis = (2000, 10_000, 30_000)
    tg = SC.targeted_grid(
        [dict(n_objects=1000, n_chunks=14, k_outer=8, byz_fraction=1 / 3,
              attack_frac=phi / 100_000, n_nodes=100_000) for phi in phis],
        seeds=SEEDS, chunk_size=12)
    for i, phi_nodes in enumerate(phis):
        phi_groups = D.attacker_groups(phi_nodes, n=80, k=32)
        bound = D.targeted_attack_bound(8, 6, omega=1000,
                                        phi_groups=max(phi_groups, 8), g=1)
        rows.append({
            "model": "targeted", "config": f"phi={phi_nodes}",
            "init_absorb": "", "hoeffding": "",
            "absorb_1y": f"mc={float(tg[i].mean()):.3e}",
            "object_bound_1y": f"bound={bound:.3e}",
        })
    emit("durability_model", rows)
    return rows


if __name__ == "__main__":
    run()
