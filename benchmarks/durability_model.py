"""Lemma 4.1 / 4.2 (App. A): the durability theory evaluated at paper
parameters — CTMC absorbing probabilities, Hoeffding initial bound, and the
targeted-attack birthday bound, cross-checked against Monte-Carlo."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import durability as D
from repro.core import simulation as S


def run():
    N, F = 100_000, 33_333
    rows = []
    for (n, k) in ((80, 32), (64, 32), (112, 32)):
        I = D.initial_state_vector(N, F, n, k)
        hoeff = D.hoeffding_initial_bound(n, k)
        theta = D.transition_matrix(N, F, n, k, churn_mu=0.2, evict=1)
        traj = D.absorb_probability(I, theta, 365)
        p_group = traj[-1]
        rows.append({
            "model": "ctmc",
            "config": f"({k},{n})",
            "init_absorb": f"{I[-1]:.3e}",
            "hoeffding": f"{hoeff:.3e}",
            "absorb_1y": f"{p_group:.3e}",
            "object_bound_1y": f"{D.object_loss_bound(p_group, 10):.3e}",
        })
    # Monte-Carlo cross-check of the CTMC (same dynamics, sampled)
    mc = S.simulate_vault(S.SimParams(
        n_objects=400, byz_fraction=1 / 3, churn_per_year=26.0, seed=8))
    rows.append({
        "model": "monte-carlo", "config": "(32,80)",
        "init_absorb": "", "hoeffding": "",
        "absorb_1y": f"{mc.lost_fraction:.3e}",
        "object_bound_1y": "",
    })
    # targeted-attack bound (Lemma 4.2) vs Monte-Carlo attack sim
    for phi_nodes in (2000, 10_000, 30_000):
        phi_groups = D.attacker_groups(phi_nodes, n=80, k=32)
        bound = D.targeted_attack_bound(8, 6, omega=1000,
                                        phi_groups=max(phi_groups, 8), g=1)
        p = S.SimParams(n_objects=1000, n_chunks=14, k_outer=8,
                        byz_fraction=1 / 3, seed=9)
        mc_loss = S.targeted_attack_vault(p, phi_nodes / 100_000)
        rows.append({
            "model": "targeted", "config": f"phi={phi_nodes}",
            "init_absorb": "", "hoeffding": "",
            "absorb_1y": f"mc={mc_loss:.3e}",
            "object_bound_1y": f"bound={bound:.3e}",
        })
    emit("durability_model", rows)
    return rows


if __name__ == "__main__":
    run()
