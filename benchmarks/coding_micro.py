"""Fig. 10: encode/decode/repair CPU micro-benchmarks across code params.

Measures our GF(256) RLNC (numpy table path and the Pallas kernel in
interpret mode) on real wall-clock — the analogue of the paper's wirehair
measurements. Reports throughput so sizes are comparable across scales."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import SCALE, emit
from repro.core import chunks as C

CONFIGS = ((8, 10, 16, 40), (8, 10, 32, 80), (8, 12, 32, 80),
           (8, 14, 64, 160))


def run():
    obj_bytes = 1_000_000 if SCALE == "quick" else 16_000_000
    rng = np.random.default_rng(0)
    data = rng.integers(0, 256, obj_bytes, np.uint8).tobytes()
    sk = b"\x01" * 32
    rows = []
    for k_outer, n_chunks, k_inner, r_inner in CONFIGS:
        params = C.CodeParams(k_outer=k_outer, n_chunks=n_chunks,
                              k_inner=k_inner, r_inner=r_inner)
        t0 = time.perf_counter()
        oid, chunks = C.outer_encode(data, sk, params)
        frags = {}
        for chash, chunk in zip(oid.chunk_hashes, chunks):
            frags[chash] = dict(enumerate(
                C.inner_encode_many(chunk, chash, k_inner,
                                    list(range(r_inner)))
            ))
        t_enc = time.perf_counter() - t0
        t0 = time.perf_counter()
        recovered = {}
        for chash in oid.chunk_hashes[: k_outer]:
            sub = dict(list(frags[chash].items())[: k_inner + 2])
            recovered[chash] = C.inner_decode(chash, k_inner, sub)
        out = C.outer_decode(oid, recovered)
        t_dec = time.perf_counter() - t0
        assert out == data
        # repair: regenerate ONE fragment from k_inner existing ones
        chash = oid.chunk_hashes[0]
        sub = dict(list(frags[chash].items())[: k_inner + 2])
        t0 = time.perf_counter()
        chunk = C.inner_decode(chash, k_inner, sub)
        _new = C.inner_encode_fragment(chunk, chash, k_inner, r_inner + 99)
        t_rep = time.perf_counter() - t0
        rows.append({
            "config": f"o({n_chunks},{k_outer}) i({k_inner},{r_inner})",
            "encode_s": round(t_enc, 3),
            "decode_s": round(t_dec, 3),
            "repair_s": round(t_rep, 3),
            "enc_MBps": round(obj_bytes / t_enc / 1e6, 1),
            "dec_MBps": round(obj_bytes / t_dec / 1e6, 1),
        })
    emit("fig10_coding_micro", rows)
    # paper: encode/decode stable across params; repair much cheaper
    assert all(r["repair_s"] < r["decode_s"] for r in rows)
    return rows


if __name__ == "__main__":
    run()
