"""Fig. 5: surviving honest fragments of one chunk group over 10 years,
two inner-code configurations — a batched trace_grid dispatch over
configs × 8 seeds (the old version traced a single seed per config).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import SCALE, emit
from repro.core import scenarios as SC

# (K_inner, R): the default and a lower-redundancy variant. With 1/3
# Byzantine claimers a group of R keeps ~2R/3 honest fragments, so R=72
# rides at ~48 — a visibly thinner margin above K_inner=32 (Fig. 5's
# narrative) while remaining recoverable; R≤64 sits within 3σ of the
# threshold and can absorb over a multi-year trace.
CONFIGS = ((32, 80), (32, 72))
SEEDS = tuple(range(8))


def run():
    years = 10.0 if SCALE == "full" else 3.0
    cells = [dict(k_inner=k, r_inner=r, byz_fraction=1 / 3,
                  churn_per_year=26.0, step_hours=6.0, years=years)
             for k, r in CONFIGS]
    traces = SC.trace_grid(cells, seeds=SEEDS, sampler="arx")  # [config, seed, steps]
    rows = []
    for i, (k, r) in enumerate(CONFIGS):
        tr = traces[i]  # [seeds, steps]
        sample = tr[0][:: max(1, tr.shape[1] // 24)]
        rows.append({
            "config": f"({k},{r})",
            "min": int(tr.min()),
            "mean": round(float(tr.mean()), 1),
            "max": int(tr.max()),
            "recoverable": bool(tr.min() >= k),
            "seeds": len(SEEDS),
            "trace_sample": " ".join(str(int(x)) for x in sample),
        })
    emit("fig5_fragment_trace", rows,
         keys=["config", "min", "mean", "max", "recoverable", "seeds",
               "trace_sample"])
    # the default configuration must never dip below K_inner in ANY seed;
    # the thin-margin variant is reported but not asserted (it rides a few
    # sigma above the threshold by design)
    assert rows[0]["recoverable"], "default config lost — Fig.5 violated"
    return rows


if __name__ == "__main__":
    run()
