"""Fig. 5: surviving honest fragments of one chunk group over 10 years,
two inner-code configurations."""
from __future__ import annotations

import numpy as np

from benchmarks.common import SCALE, emit
from repro.core import simulation as S

# (K_inner, R): the default and a lower-redundancy variant. With 1/3
# Byzantine claimers a group of R keeps ~2R/3 honest fragments, so R=72
# rides at ~48 — a visibly thinner margin above K_inner=32 (Fig. 5's
# narrative) while remaining recoverable; R≤64 sits within 3σ of the
# threshold and can absorb over a multi-year trace.
CONFIGS = ((32, 80), (32, 72))


def run():
    years = 10.0 if SCALE == "full" else 3.0
    rows = []
    for k, r in CONFIGS:
        tr = S.fragment_trace(k, r, byz_fraction=1 / 3, churn_per_year=26.0,
                              years=years, seed=5)
        sample = tr[:: max(1, len(tr) // 24)]
        rows.append({
            "config": f"({k},{r})",
            "min": int(tr.min()),
            "mean": round(float(tr.mean()), 1),
            "max": int(tr.max()),
            "recoverable": bool(tr.min() >= k),
            "trace_sample": " ".join(str(int(x)) for x in sample),
        })
    emit("fig5_fragment_trace", rows,
         keys=["config", "min", "mean", "max", "recoverable",
               "trace_sample"])
    assert all(r["recoverable"] for r in rows), "chunk lost — Fig.5 violated"
    return rows


if __name__ == "__main__":
    run()
