"""Figs. 7/8/9: STORE/QUERY/repair latency on the geo-simulated network —
vs coding parameters (Fig 7), vs concurrency (Fig 8), vs system size
(Fig 9). VAULT vs the IPFS-like Kademlia PUT_RECORD baseline.

Latency composition mirrors the paper's deployment: coding time is measured
for real on this box; network time composes sampled inter-region RTTs with
Alg. 1's parallelism (QUERY completes at the K-th order statistic of the
parallel fragment fetches — which is why VAULT beats the replicated
baseline on reads, §6.2)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import SCALE, emit
from repro.core import chunks as C
from repro.core import repair as R
from repro.core.baseline import IPFSLikeStore
from repro.core.network import SimNetwork
from repro.core.vault import VaultClient

OUTER_SWEEP = ((10, 8), (12, 8), (14, 8))
INNER_SWEEP = ((16, 40), (32, 80), (64, 160))


def build(n_nodes: int, seed: int = 0):
    net = SimNetwork(seed=seed)
    for i in range(n_nodes):
        net.add_node(seed=i.to_bytes(4, "little"))
    return net


def one_pair(net, params, obj_bytes, seed=0, cache_ttl=3600.0):
    rng = np.random.default_rng(seed)
    client = VaultClient(net, net.alive_nodes()[
        int(rng.integers(net.n_nodes))])
    data = rng.integers(0, 256, obj_bytes, np.uint8).tobytes()
    oid, st = client.store(data, params, cache_ttl=cache_ttl)
    got, qt = client.query(oid)
    assert got == data
    # repair latency: evict the oldest member of one group, let a survivor
    # repair (the paper's physical-deployment experiment)
    chash = oid.chunk_hashes[0]
    R.evict_oldest(net, chash)
    survivor = next(n for n in net.alive_nodes() if chash in n.groups)
    rstats = R.repair_group(net, survivor, chash, cache_ttl=cache_ttl)
    return st, qt, rstats


def run():
    quick = SCALE == "quick"
    n_nodes = 600 if quick else 2000
    obj_bytes = 64_000 if quick else 1_000_000
    rows = []
    # ---- Fig 7: vary outer then inner code
    net = build(n_nodes)
    for n_chunks, k_outer in OUTER_SWEEP:
        p = C.CodeParams(k_outer=k_outer, n_chunks=n_chunks,
                         k_inner=16, r_inner=40)
        st, qt, rs = one_pair(net, p, obj_bytes, seed=n_chunks)
        rows.append({
            "fig": "7-outer", "config": f"({n_chunks},{k_outer})",
            "store_s": round(st.latency_s, 3),
            "query_s": round(qt.latency_s, 3),
            "repair_s": round(rs.latency_s, 3),
        })
    for k_inner, r_inner in INNER_SWEEP:
        p = C.CodeParams(k_outer=8, n_chunks=10, k_inner=k_inner,
                         r_inner=r_inner)
        st, qt, rs = one_pair(net, p, obj_bytes, seed=k_inner)
        rows.append({
            "fig": "7-inner", "config": f"({k_inner},{r_inner})",
            "store_s": round(st.latency_s, 3),
            "query_s": round(qt.latency_s, 3),
            "repair_s": round(rs.latency_s, 3),
        })
    # ---- baseline (IPFS-like)
    ipfs = IPFSLikeStore(net, replication=3, records_per_object=64)
    rng = np.random.default_rng(0)
    client_node = net.alive_nodes()[int(rng.integers(net.n_nodes))]
    data = rng.integers(0, 256, obj_bytes, np.uint8).tobytes()
    ioid, ist = ipfs.store(client_node, data)
    _, iqt = ipfs.query(client_node, ioid)
    rows.append({
        "fig": "7-baseline", "config": "ipfs-like r=3",
        "store_s": round(ist.latency_s, 3),
        "query_s": round(iqt.latency_s, 3), "repair_s": "",
    })
    # headline: paper reports store 1.4-2.1x baseline, query ~0.92x
    v = next(r for r in rows if r["config"] == "(32,80)")
    print(f"  -> store ratio vault/baseline: "
          f"{v['store_s'] / max(ist.latency_s, 1e-9):.2f}x "
          f"(paper: 1.4-2.1x); query ratio: "
          f"{v['query_s'] / max(iqt.latency_s, 1e-9):.2f}x (paper: 0.92x)")

    # ---- Fig 8: concurrency (latency under N concurrent client pairs)
    for conc in (1, 10, 50, 100) if quick else (1, 10, 100, 300):
        p = C.CodeParams(k_outer=8, n_chunks=10, k_inner=16, r_inner=40)
        lats_s, lats_q = [], []
        for i in range(min(conc, 12)):  # sample clients; ops are parallel
            st, qt, _ = one_pair(net, p, obj_bytes // 4, seed=1000 + i)
            lats_s.append(st.latency_s)
            lats_q.append(qt.latency_s)
        rows.append({
            "fig": "8-concurrency", "config": conc,
            "store_s": round(float(np.mean(lats_s)), 3),
            "query_s": round(float(np.mean(lats_q)), 3),
            "repair_s": "",
        })
    # ---- Fig 9: scalability (vary N)
    for n in (200, 600, 1500) if quick else (1000, 4000, 10_000):
        net_n = build(n, seed=n)
        p = C.CodeParams(k_outer=8, n_chunks=10, k_inner=16, r_inner=40)
        st, qt, rs = one_pair(net_n, p, obj_bytes // 4, seed=n)
        rows.append({
            "fig": "9-scale", "config": n,
            "store_s": round(st.latency_s, 3),
            "query_s": round(qt.latency_s, 3),
            "repair_s": round(rs.latency_s, 3),
        })
    emit("fig789_latency", rows,
         keys=["fig", "config", "store_s", "query_s", "repair_s"])
    return rows


if __name__ == "__main__":
    run()
