"""Shared benchmark plumbing: CSV/console emit + scale flags + env setup."""
from __future__ import annotations

import os
import pathlib

from repro import config as CFG

# One environment-setup path shared with scripts/test.sh and
# tests/conftest.py: XLA_DEVICES / REPRO_PLATFORM / REPRO_X64 /
# REPRO_DEBUG_NANS are applied here, before any benchmark touches a JAX
# backend (benchmarks import this module first).
CFG.apply_env()

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "bench"

# BENCH_SCALE=full reproduces paper-scale sweeps (slow); default is a
# reduced sweep that exercises identical code with smaller Ω/N/years.
SCALE = os.environ.get("BENCH_SCALE", "quick")


def emit(name: str, rows: list[dict], keys: list[str] | None = None):
    RESULTS.mkdir(parents=True, exist_ok=True)
    if not rows:
        print(f"[{name}] no rows")
        return
    if keys is None:  # union of keys, first-row order first
        keys = list(rows[0].keys())
        for r in rows[1:]:
            keys.extend(k for k in r if k not in keys)
    path = RESULTS / f"{name}.csv"
    with open(path, "w") as f:
        f.write(",".join(keys) + "\n")
        for r in rows:
            f.write(",".join(str(r.get(k, "")) for k in keys) + "\n")
    width = {k: max(len(k), *(len(str(r.get(k, ""))) for r in rows))
             for k in keys}
    print(f"\n== {name} -> {path}")
    print("  " + "  ".join(k.ljust(width[k]) for k in keys))
    for r in rows:
        print("  " + "  ".join(str(r.get(k, "")).ljust(width[k])
                               for k in keys))
