"""Fig. 6: lost objects vs Byzantine fraction (top) and vs targeted-attack
fraction (bottom), three code configurations each, vs replicated baseline."""
from __future__ import annotations

from benchmarks.common import SCALE, emit
from repro.core import simulation as S

INNER_CONFIGS = ((32, 64), (32, 80), (32, 112))  # (K_inner, R)
OUTER_CONFIGS = ((10, 8), (12, 8), (14, 8))  # (n_chunks, K_outer)


def run():
    quick = SCALE == "quick"
    n_obj = 200 if quick else 1000
    byz_sweep = (0.0, 0.05, 0.1, 0.2, 0.33, 0.4, 0.45, 0.5)
    atk_sweep = (0.02, 0.05, 0.1, 0.15, 0.2, 0.3)
    rows = []
    for f in byz_sweep:
        row = {"sweep": "byzantine", "x": f}
        for k, r in INNER_CONFIGS:
            res = S.simulate_vault(S.SimParams(
                n_objects=n_obj, byz_fraction=f, churn_per_year=26.0,
                k_inner=k, r_inner=r, seed=3))
            row[f"vault({k},{r})"] = round(res.lost_fraction, 4)
        rb = S.simulate_replicated(S.SimParams(
            n_objects=n_obj, byz_fraction=f, churn_per_year=26.0, seed=3))
        row["replicated"] = round(rb.lost_fraction, 4)
        rows.append(row)
    for phi in atk_sweep:
        row = {"sweep": "targeted", "x": phi}
        for n_chunks, k_outer in OUTER_CONFIGS:
            p = S.SimParams(n_objects=n_obj, n_chunks=n_chunks,
                            k_outer=k_outer, byz_fraction=1 / 3, seed=4)
            row[f"vault({n_chunks},{k_outer})"] = round(
                S.targeted_attack_vault(p, phi), 4)
        row["replicated"] = round(
            S.targeted_attack_replicated(
                S.SimParams(n_objects=n_obj), phi), 4)
        rows.append(row)
    emit("fig6_fault_tolerance", rows)
    # headline checks
    byz33 = next(r for r in rows if r["sweep"] == "byzantine"
                 and r["x"] == 0.33)
    assert byz33["vault(32,80)"] == 0.0, "default must tolerate 33%"
    print("  -> default (32,80) tolerates 33% byzantine: OK; replicated "
          f"lost {byz33['replicated']:.0%} at 33%")
    return rows


if __name__ == "__main__":
    run()
