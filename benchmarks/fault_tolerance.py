"""Fig. 6: lost objects vs Byzantine fraction (top) and vs targeted-attack
fraction (bottom), three code configurations each, vs replicated baseline.

Each panel runs on the batched scenario engine as one dispatch over all
(config × x-value) cells × 8 seeds; reported values are seed means (± CI
columns for the Byzantine panel's lost fractions).
"""
from __future__ import annotations

from benchmarks.common import SCALE, emit
from repro.core import scenarios as SC

INNER_CONFIGS = ((32, 64), (32, 80), (32, 112))  # (K_inner, R)
OUTER_CONFIGS = ((10, 8), (12, 8), (14, 8))  # (n_chunks, K_outer)
SEEDS = tuple(range(8))


def run():
    quick = SCALE == "quick"
    n_obj = 200 if quick else 1000
    step_hours = 12.0 if quick else 6.0
    years = 0.5 if quick else 1.0
    byz_sweep = (0.0, 0.05, 0.1, 0.2, 0.33, 0.4, 0.45, 0.5)
    atk_sweep = (0.02, 0.05, 0.1, 0.15, 0.2, 0.3)
    rows = []

    # --- Byzantine panel: all fracs x inner configs in one dispatch
    cells = [dict(n_objects=n_obj, byz_fraction=f, churn_per_year=26.0,
                  k_inner=k, r_inner=r, step_hours=step_hours, years=years)
             for f in byz_sweep for (k, r) in INNER_CONFIGS]
    res = SC.run_grid(cells, seeds=SEEDS, sampler="arx", chunk_size=64)
    mean, ci = SC.mean_ci(res.lost_fraction)
    repl = SC.run_replicated_grid(
        [dict(n_objects=n_obj, byz_fraction=f, churn_per_year=26.0,
              step_hours=step_hours, years=years) for f in byz_sweep],
        seeds=SEEDS, sampler="arx")
    rmean, _ = SC.mean_ci(repl.lost_fraction)
    for i, f in enumerate(byz_sweep):
        row = {"sweep": "byzantine", "x": f}
        for j, (k, r) in enumerate(INNER_CONFIGS):
            row[f"vault({k},{r})"] = round(mean[i * 3 + j], 4)
            row[f"vault({k},{r})_ci"] = round(ci[i * 3 + j], 4)
        row["replicated"] = round(rmean[i], 4)
        rows.append(row)

    # --- targeted panel: one dispatch over attack fracs x outer configs
    tcells = [dict(n_objects=n_obj, n_chunks=n_chunks, k_outer=k_outer,
                   byz_fraction=1 / 3, attack_frac=phi)
              for phi in atk_sweep for (n_chunks, k_outer) in OUTER_CONFIGS]
    tg = SC.targeted_grid(tcells, seeds=SEEDS, chunk_size=72)
    tmean, _ = SC.mean_ci(tg)
    from repro.core import simulation as S
    for i, phi in enumerate(atk_sweep):
        row = {"sweep": "targeted", "x": phi}
        for j, (n_chunks, k_outer) in enumerate(OUTER_CONFIGS):
            row[f"vault({n_chunks},{k_outer})"] = round(tmean[i * 3 + j], 4)
        row["replicated"] = round(
            S.targeted_attack_replicated(S.SimParams(n_objects=n_obj), phi), 4)
        rows.append(row)

    emit("fig6_fault_tolerance", rows)
    # headline checks
    byz33 = next(r for r in rows if r["sweep"] == "byzantine"
                 and r["x"] == 0.33)
    assert byz33["vault(32,80)"] == 0.0, "default must tolerate 33%"
    print("  -> default (32,80) tolerates 33% byzantine over "
          f"{len(SEEDS)} seeds: OK; replicated lost "
          f"{byz33['replicated']:.0%} at 33%")
    return rows


if __name__ == "__main__":
    run()
