"""Run every benchmark harness (one per paper table/figure + roofline).

    PYTHONPATH=src python -m benchmarks.run            # quick scale
    BENCH_SCALE=full PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import os
import time
import traceback


def main() -> int:
    from benchmarks import (
        coding_micro,
        cross_validate,
        durability_model,
        engine_speed,
        fault_tolerance,
        fig_serving,
        fragment_trace,
        latency,
        protocol_speed,
        repair_traffic,
        roofline,
        selection_micro,
    )

    suites = [
        ("fig4_repair_traffic", repair_traffic.run),
        ("fig5_fragment_trace", fragment_trace.run),
        ("fig6_fault_tolerance", fault_tolerance.run),
        ("fig789_latency", latency.run),
        ("fig10_coding_micro", coding_micro.run),
        ("fig_serving", fig_serving.run),
        ("selection_micro", selection_micro.run),
        ("durability_model", durability_model.run),
        ("engine_speed", engine_speed.run),
        ("protocol_speed", protocol_speed.run),
        ("cross_validation", cross_validate.run),
        ("roofline", roofline.run),
    ]
    skip = {s for s in os.environ.get("BENCH_SKIP", "").split(",") if s}
    failures = 0
    for name, fn in suites:
        if any(s in name for s in skip):
            print(f"[skip] {name} (BENCH_SKIP)")
            continue
        t0 = time.time()
        try:
            fn()
            print(f"[done] {name} ({time.time() - t0:.1f}s)")
        except Exception:
            failures += 1
            print(f"[FAIL] {name}:\n{traceback.format_exc()}")
    print(f"\n{len(suites) - failures}/{len(suites)} benchmark suites OK")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
