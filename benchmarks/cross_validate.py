"""Cross-validation: protocol-level simulator vs batched group-level engine.

Runs matched configurations — auto-discovered from the policy zoo
registry (``policies.zoo_members()``; one source of truth, guarded by
``scripts/check_policy_matrix.py``), same code parameters, same
seeds-per-cell discipline — through BOTH simulation layers:

* the group-level engine (``scenarios.run_grid``, 8 seeds, mean ± 95% CI),
* the protocol-level simulator (``protocol_sim.run_protocol_seeds``: real
  VRF selection proofs, GF(256) coding, persistence claims, decentralized
  repair on a small ``SimNetwork``),

and emits ``results/bench/cross_validation.csv`` recording, per
(config, metric): engine mean ± CI, protocol mean ± CI, the absolute
difference, and two pass flags:

* ``within_engine_ci`` — protocol mean inside the engine's own 95% CI
  (the strict read; ignores protocol sampling noise, so expected to fail
  occasionally for high-variance count metrics even when both layers
  agree);
* ``within_combined_ci`` — |Δ| ≤ √(ci_eng² + ci_proto²), the two-sample
  95% criterion ``tests/test_cross_validation.py`` enforces.

Known, documented deltas (see ``protocol_sim`` module docstring):
regional-burst kills concentrate on whole groups in the engine but
straddle 2–3 ring domains in the protocol, so the engine's group-death
rate is the conservative bound. (The engine cache model's historical
holder-churn blindness — leak #1 of the original table — is FIXED as of
the serving PR: the engine now retires cached copies when holders die,
and ``tests/test_cross_validation.py::test_cache_holder_leak_closed``
proves the old optimistic model over-credits while the fixed one agrees.)

The four ISSUE-10 zoo members add their own known deltas: ``pareto_static``
(the engine's protected-cohort mean-field is a churn *lower* bound, so
protocol repair activity may exceed it — one-sided), ``iid_collude``
(withholding retries are exact in the protocol but a closed-form extra-pull
term in the engine — one-sided on traffic) and ``iid_eclipse_targeted``
(composes both eclipse leaks — one-sided like ``iid_eclipse``);
``diurnal_static`` integrates to the same daily-mean rate in both layers
and rides the normal two-sided gates.

Serving metrics (``read_rate > 0`` in every matched config) compare the
engine's closed-form Zipf request load against the protocol's sampled
end-to-end Get() batches: served traffic, hit rate, and failed-read
counts ride the same combined-CI gate as the repair metrics, with two
documented one-config exceptions (cached served traffic carries a ≈1%
padding-quantization delta; the eclipse config is one-sided because the
engine's whole-group eclipse is the conservative serving bound — see
``tests/test_cross_validation.py``).

    PYTHONPATH=src python -m benchmarks.cross_validate
    BENCH_SCALE=full PYTHONPATH=src python -m benchmarks.cross_validate
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import SCALE, emit
from repro.core import policies as P
from repro.core import protocol_sim as PS
from repro.core import scenarios as SC

ENGINE_SEEDS = tuple(range(8))

# Registered zoo members intentionally NOT cross-validated, as
# ``{name: reason}``. Every entry needs a non-empty reason;
# ``scripts/check_policy_matrix.py`` asserts that each registered policy
# is either auto-discovered below or waived here, and that no waiver is
# stale. Keep this a plain dict literal — the checker ast-parses it.
EXCLUDED_ROWS: dict[str, str] = {}

# quick/full scales, shared with tests/test_cross_validation.py so the
# committed CSV and the enforcing test always validate the same configs
QUICK_KW = dict(steps=30, n_objects=3, n_nodes=200)
QUICK_PROTO_SEEDS = tuple(range(5))
FULL_KW = dict(steps=60, n_objects=6, n_nodes=300)
FULL_PROTO_SEEDS = tuple(range(8))

# scalar fields compared 1:1 between the two layers' result schemas
METRICS = ("repairs", "repair_traffic_units", "cache_hits", "lost_objects",
           "final_honest_mean", "served_traffic_units", "reads_failed")


def matched_configs(steps: int, n_objects: int,
                    n_nodes: int) -> dict[str, PS.ProtocolParams]:
    """The matched-config suite, auto-discovered from the policy zoo.

    One row per ``policies.zoo_members()`` entry (minus ``EXCLUDED_ROWS``
    waivers): each registered :class:`~repro.core.policies.ZooEntry`
    carries its spec, its matched-config knob overrides (``StepFrac``
    values resolve against ``steps`` here) and its gate contract
    (``"two_sided"`` rows ride the blanket combined-CI gates in
    ``tests/test_cross_validation.py``; ``"one_sided"`` rows — eclipse,
    targeted, the composed eclipse+targeted, pareto, collude — are
    documented abstraction leaks with dedicated bound tests). Registering
    a new zoo member therefore *is* adding its cross-validation row;
    ``scripts/check_policy_matrix.py`` enforces that nothing is silently
    dropped.

    ``read_rate`` is on in every config so the serving metrics are
    cross-validated on the full churn/adversary/cache grid;
    ``region_cap`` stays 0 (congestion off) — the closed-form uniform
    load split and the protocol's emergent per-region split are compared
    through the fig_serving benchmark instead."""
    base = dict(n_nodes=n_nodes, n_objects=n_objects, k_outer=2, n_chunks=5,
                k_inner=6, r_inner=14, byz_fraction=0.1, churn_per_year=26.0,
                step_hours=12.0, steps=steps, claim_every=2,
                read_rate=40.0, zipf_alpha=1.1)
    configs = {}
    for entry in P.zoo_members():
        if entry.name in EXCLUDED_ROWS:
            continue
        kw = P.zoo_config_kwargs(entry, steps)
        configs[entry.name] = PS.ProtocolParams(**{**base, **kw})
    return configs


def compare(configs: dict[str, PS.ProtocolParams], proto_seeds,
            sampler: str = "fast") -> list[dict]:
    """Run both layers on ``configs`` and tabulate the comparison rows."""
    names = list(configs)
    cells = [configs[n].to_scenario_kwargs() for n in names]
    eng = SC.run_grid(cells, seeds=ENGINE_SEEDS, sampler=sampler)
    rows = []
    for i, name in enumerate(names):
        proto = PS.run_protocol_seeds(configs[name], seeds=proto_seeds)
        summ = PS.summarize(proto)
        eng_alive = np.asarray(eng.alive_frac_trace[i], np.float64)[
            :, configs[name].steps - 1]
        proto_alive = np.array([r.alive_frac_trace[-1] for r in proto],
                               np.float64)
        eng_hit_rate = (np.asarray(eng.reads_hit[i], np.float64)
                        / np.maximum(np.asarray(eng.reads_issued[i],
                                                np.float64), 1e-9))
        proto_hit_rate = np.array(
            [r.reads_hit / max(r.reads_issued, 1) for r in proto],
            np.float64)
        extra = {
            "alive_frac_final": (
                SC.mean_ci(eng_alive), SC.mean_ci(proto_alive)),
            "hit_rate": (
                SC.mean_ci(eng_hit_rate), SC.mean_ci(proto_hit_rate)),
        }
        for metric in METRICS:
            em, ec = SC.mean_ci(np.asarray(getattr(eng, metric)[i],
                                           np.float64))
            pm, pc = summ[metric]
            rows.append(_row(name, metric, float(em), float(ec), pm, pc))
        for metric, ((em, ec), (pm, pc)) in extra.items():
            rows.append(_row(name, metric, float(em), float(ec),
                             float(pm), float(pc)))
    return rows


def _row(config: str, metric: str, em: float, ec: float, pm: float,
         pc: float) -> dict:
    diff = abs(pm - em)
    return {
        "config": config, "metric": metric,
        "engine_mean": round(em, 4), "engine_ci95": round(ec, 4),
        "protocol_mean": round(pm, 4), "protocol_ci95": round(pc, 4),
        "abs_diff": round(diff, 4),
        "within_engine_ci": diff <= ec,
        "within_combined_ci": diff <= float(np.hypot(ec, pc)),
    }


def run():
    quick = SCALE == "quick"
    configs = matched_configs(**(QUICK_KW if quick else FULL_KW))
    rows = compare(
        configs, proto_seeds=QUICK_PROTO_SEEDS if quick
        else FULL_PROTO_SEEDS)
    emit("cross_validation", rows)
    n_ok = sum(r["within_combined_ci"] for r in rows)
    print(f"cross-validation: {n_ok}/{len(rows)} metrics within the "
          "combined 95% CI")


if __name__ == "__main__":
    run()
