"""Serving tail latency: p50/p99/p999 retrieval hops vs churn & adversary.

The serving workload layer (PR 8) answers Get() requests on both tiers;
this figure sweeps request-serving quality across churn intensity and an
eclipse-adversary axis, on BOTH layers with matched configs:

* **engine** — the closed-form Zipf load inside the jitted scan
  (``scenarios._vault_serve``): expected per-step hit/miss/degraded/failed
  splits and the congestion-stretched hop histogram;
* **protocol** — sampled end-to-end Get() batches per tick
  (``protocol_sim._serve_tick``): cache probe → ring walk → fragment
  pulls → GF(256) decode, hops through the same histogram bins.

Tail latency is read off the retrieval-hop histograms: p50/p99/p999 are
the smallest hop bins covering 50/99/99.9% of completed reads. A shared
``region_cap`` makes repair and serving compete for per-region links, so
the upper percentiles actually move with load. Engine hop histograms are
expected counts — scale-invariant in ``read_rate`` — so the matched
configs use the protocol's modest per-tick rate while a separate
engine-only leg drives ~10⁸ closed-form reads for the throughput
headline.

Emits ``results/bench/fig_serving.csv`` (one row per config × tier, with
the engine/protocol p99 gap) and ``results/bench/BENCH_serving.json`` —
the trajectory point CI's bench-regression job gates (``reads_per_s``,
``engine_s``).

    PYTHONPATH=src python -m benchmarks.fig_serving
    BENCH_SCALE=full PYTHONPATH=src python -m benchmarks.fig_serving
"""
from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import RESULTS, SCALE, emit
from repro.core import protocol_sim as PS
from repro.core import scenarios as SC

ENGINE_SEEDS = tuple(range(8))
QUICK = dict(churns=(26.0, 150.0, 400.0), proto_seeds=tuple(range(3)),
             steps=30, n_nodes=200, n_objects=3)
FULL = dict(churns=(26.0, 80.0, 150.0, 260.0, 400.0),
            proto_seeds=tuple(range(5)), steps=60, n_nodes=300,
            n_objects=6)

#: per-region per-step link budget (object units). Sized just above the
#: engine's uniform split of the serving load (read_rate / N_BW_REGIONS
#: = 8 units/region) so the closed-form tier stays mostly uncongested
#: while the protocol's *emergent* per-region hotspots (ring-walk holder
#: clustering + localized repair pulls) oversubscribe their links — the
#: p99/p999 gap between the tiers is exactly the uniform-split
#: approximation this figure measures.
REGION_CAP = 12.0
READ_RATE = 40.0
#: engine-only throughput leg: closed-form reads per step
BIG_READ_RATE = 2e5

PCTS = (("p50", 0.50), ("p99", 0.99), ("p999", 0.999))


def hist_percentiles(hist) -> dict[str, float]:
    """Smallest hop bin covering each target mass of completed reads."""
    h = np.asarray(hist, np.float64).ravel()
    tot = h.sum()
    if tot <= 0:
        return {name: float("nan") for name, _ in PCTS}
    cum = np.cumsum(h)
    return {name: int(np.searchsorted(cum, q * tot - 1e-9))
            for name, q in PCTS}


def configs(churns, steps, n_nodes, n_objects,
            **_) -> dict[str, PS.ProtocolParams]:
    base = dict(n_nodes=n_nodes, n_objects=n_objects, k_outer=2,
                n_chunks=5, k_inner=6, r_inner=14, byz_fraction=0.1,
                step_hours=12.0, steps=steps, claim_every=2,
                cache_ttl_hours=48.0, read_rate=READ_RATE,
                zipf_alpha=1.1, region_cap=REGION_CAP)
    out = {}
    for churn in churns:
        out[f"churn{churn:g}"] = PS.ProtocolParams(
            **base, churn_per_year=churn)
        out[f"churn{churn:g}_eclipse"] = PS.ProtocolParams(
            **base, churn_per_year=churn, adv_policy="eclipse",
            attack_frac=0.3, attack_step=steps // 4,
            eclipse_steps=steps // 3)
    return out


def _tier_row(name, p, tier, hist, hit_rate, failed_frac, served):
    row = {
        "config": name, "tier": tier, "churn_per_year": p.churn_per_year,
        "adversary": p.adv_policy, "hit_rate": round(hit_rate, 4),
        "failed_frac": round(failed_frac, 4),
        "served_units": round(served, 2),
    }
    row.update(hist_percentiles(hist))
    return row


def _engine_rows(cfgs) -> list[dict]:
    names = list(cfgs)
    cells = [cfgs[n].to_scenario_kwargs() for n in names]
    eng = SC.run_grid(cells, seeds=ENGINE_SEEDS)
    rows = []
    for i, name in enumerate(names):
        issued = np.asarray(eng.reads_issued[i], np.float64)
        hist = np.asarray(eng.serve_hop_hist[i], np.float64).sum(axis=0)
        rows.append(_tier_row(
            name, cfgs[name], "engine", hist,
            float((np.asarray(eng.reads_hit[i], np.float64)
                   / np.maximum(issued, 1e-9)).mean()),
            float((np.asarray(eng.reads_failed[i], np.float64)
                   / np.maximum(issued, 1e-9)).mean()),
            float(np.mean(np.asarray(eng.served_traffic_units[i],
                                     np.float64)))))
    return rows


def _protocol_rows(cfgs, proto_seeds) -> list[dict]:
    rows = []
    for name, p in cfgs.items():
        res = PS.run_protocol_seeds(p, seeds=proto_seeds)
        hist = np.sum([r.serve_hop_hist for r in res], axis=0)
        rows.append(_tier_row(
            name, p, "protocol", hist,
            float(np.mean([r.reads_hit / max(r.reads_issued, 1)
                           for r in res])),
            float(np.mean([r.reads_failed / max(r.reads_issued, 1)
                           for r in res])),
            float(np.mean([r.served_traffic_units for r in res]))))
    return rows


def _throughput(churns, steps, n_nodes, n_objects, **_) -> dict:
    """Engine-only closed-form serving throughput (reads/s, steady state).

    One dispatch over a churn × Zipf-α grid at ``BIG_READ_RATE`` reads
    per step and an 8× horizon — billions of Zipf reads per run even at
    quick scale, and enough wall-clock (~0.5 s steady) that the 30%
    trajectory gate sits well above host timing noise. The first dispatch
    pays jit compile; timed runs are warm (same discipline as
    engine_speed)."""
    cells = [dict(n_objects=n_objects, k_outer=2, n_chunks=5, k_inner=6,
                  r_inner=14, n_nodes=n_nodes, byz_fraction=0.1,
                  churn_per_year=churn, step_hours=12.0, steps=steps * 8,
                  cache_ttl_hours=48.0, read_rate=BIG_READ_RATE,
                  zipf_alpha=alpha)
             for churn in churns
             for alpha in (0.7, 1.1, 1.4, 2.0)]
    t0 = time.time()
    res = SC.run_grid(cells, seeds=ENGINE_SEEDS)
    t_first = time.time() - t0
    ts = []
    for _ in range(3):
        t0 = time.time()
        res = SC.run_grid(cells, seeds=ENGINE_SEEDS)
        ts.append(time.time() - t0)
    t = min(ts)
    issued = float(np.asarray(res.reads_issued, np.float64).sum())
    return {
        "reads": int(issued), "engine_s": round(t, 3),
        "compile_s": round(max(t_first - t, 0.0), 2),
        "reads_per_s": int(issued / t),
    }


def run():
    kw = QUICK if SCALE == "quick" else FULL
    cfgs = configs(**kw)
    rows = _engine_rows(cfgs) + _protocol_rows(cfgs, kw["proto_seeds"])
    by_tier = {(r["config"], r["tier"]): r for r in rows}
    for name in cfgs:
        e, p = by_tier[(name, "engine")], by_tier[(name, "protocol")]
        gap = abs(e["p99"] - p["p99"])
        e["p99_gap"] = p["p99_gap"] = gap
    emit("fig_serving", rows)

    thr = _throughput(**kw)
    worst = max((r for r in rows if r["tier"] == "protocol"),
                key=lambda r: r["p999"])
    point = {
        "bench": "fig_serving", "scale": SCALE,
        "headline": {
            "serving_throughput": thr,
            "tails": {r["config"] + ":" + r["tier"]: {
                n: r[n] for n, _ in PCTS} for r in rows},
        },
        "rows": rows,
    }
    path = RESULTS / "BENCH_serving.json"
    with open(path, "w") as f:
        json.dump(point, f, indent=1)
    print(f"  -> {thr['reads']:,} closed-form reads in {thr['engine_s']}s "
          f"steady ({thr['reads_per_s']:,} reads/s; compile "
          f"{thr['compile_s']}s excluded)")
    print(f"  -> worst protocol tail: {worst['config']} "
          f"p50={worst['p50']} p99={worst['p99']} p999={worst['p999']} "
          f"hops (hit rate {worst['hit_rate']})")
    return rows


if __name__ == "__main__":
    run()
