"""Scenario-engine throughput study: sampler × chunk-size × device-axis.

For each workload (a many-small-cell parameter grid and a medium Fig. 4
cell block — the same two regimes PR 1 measured) this times:

* every sampler in ``repro.core.samplers.SAMPLERS`` (exact / fast / arx),
* the best sampler with chunked dispatch,
* the best sampler sharded over local devices (only when the process has
  more than one — e.g. ``XLA_FLAGS=--xla_force_host_platform_device_count=2``),

reporting **compile time separately from steady-state run time** (the
first dispatch pays jit compile; timed runs are all warm) plus derived
``steps/s`` (batch-element time steps per second) and ``samples/s``
(binomial draws per second: 3 draws × groups per element-step — the
engine's sampler workload), so sampler improvements are directly
comparable across PRs. The per-cell numpy reference loop from
``simulation.py`` is timed once per workload as the baseline.

Emits ``results/bench/engine_speed.csv`` (full table) and
``results/bench/BENCH_engine_speed.json`` — the machine-readable
trajectory point future PRs diff against.

Device-scaling study (``python -m benchmarks.engine_speed --scaling``):
times the grid-cells workload at 1/2/4/8 *virtual host devices*
(``xla_force_host_platform_device_count``), each count in its own
subprocess because the flag is XLA-pre-init only. The sharded leg runs
the engine's ``devices=N`` dispatch — one jitted executable whose batch
axis splits over a ``shard_map`` mesh (``scenarios._compile_runner``).
Emits ``results/bench/engine_scaling.csv`` and
``results/bench/BENCH_scaling.json`` (the ``scaling_8dev`` trajectory
point CI gates via ``scripts/check_bench_regression.py``).

Scaling provenance: virtual host devices only parallelize across
*physical cores* — the XLA CPU backend runs one shard per device thread,
so an M-core host tops out near M× regardless of the device count, and a
1-core host measures ≈1× by construction (the committed point records
``host_cores`` so trajectory diffs stay like-for-like; near-linear
scaling is expected when cores ≥ devices, e.g. on real accelerator pods).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax

from benchmarks.common import RESULTS, SCALE, emit
from repro import config as CFG
from repro.core import scenarios as SC
from repro.core import simulation as S
from repro.core.samplers import SAMPLERS

SEEDS = tuple(range(8))
REPS = 3  # steady-state timing: best of REPS warm dispatches
SCALING_DEVICES = (1, 2, 4, 8)  # virtual-host-device counts, one per run


def _workloads():
    quick = SCALE == "quick"
    years = 0.5 if quick else 1.0
    # many small cells: (byz x R) grid, the scenario-sweep workload
    grid = [dict(n_objects=20 if quick else 50, k_inner=32, r_inner=r,
                 byz_fraction=f, churn_per_year=26.0, n_nodes=20_000,
                 step_hours=12.0, years=years)
            for f in (0.0, 0.1, 0.2, 0.33, 0.4, 0.5)
            for r in (64, 80, 112)]
    # medium cells: a Fig. 4 object-count x TTL block
    fig4 = [dict(n_objects=100 if quick else 400, churn_per_year=26.0,
                 cache_ttl_hours=ttl, n_nodes=20_000, step_hours=12.0,
                 years=years)
            for ttl in (0.0, 24.0, 48.0)]
    return [("grid-18cells", grid), ("fig4-3cells", fig4)]


def _work_units(cells) -> tuple[int, int]:
    """(element-steps, binomial samples) of useful work in one dispatch."""
    steps = samples = 0
    for c in cells:
        sc = SC.make_scenario(**c)
        g = int(sc.n_objects) * int(sc.n_chunks)
        steps += int(sc.steps) * len(SEEDS)
        samples += int(sc.steps) * 3 * g * len(SEEDS)
    return steps, samples


def _time_engine(name, cells, sampler, chunk=None, devices=None):
    kw = dict(seeds=SEEDS, sampler=sampler, chunk_size=chunk,
              devices=devices)
    t0 = time.time()
    res = SC.run_grid(cells, **kw)
    t_first = time.time() - t0
    ts = []
    for _ in range(REPS):
        t0 = time.time()
        res = SC.run_grid(cells, **kw)
        ts.append(time.time() - t0)
    t = min(ts)
    steps, samples = _work_units(cells)
    lost_m, _ = SC.mean_ci(res.lost_fraction)
    return {
        "regime": name, "sampler": sampler,
        "chunk": chunk or "", "devices": devices or 1,
        "cells": len(cells), "seeds": len(SEEDS),
        "engine_s": round(t, 3),
        "compile_s": round(max(t_first - t, 0.0), 2),
        "steps_per_s": int(steps / t),
        "samples_per_s": int(samples / t),
        "mean_lost": round(float(lost_m.mean()), 4),
    }


def _time_python_loop(cells) -> float:
    t0 = time.time()
    for c in cells:
        for s in SEEDS:
            S.simulate_vault(S.SimParams(seed=s, **{
                k: v for k, v in c.items()
                if k in ("n_objects", "n_chunks", "k_outer", "k_inner",
                         "r_inner", "n_nodes", "byz_fraction",
                         "churn_per_year", "cache_ttl_hours", "step_hours",
                         "years")}))
    return time.time() - t0


def run():
    n_dev = jax.local_device_count()
    rows = []
    for name, cells in _workloads():
        t_loop = _time_python_loop(cells)
        variants = [dict(sampler=s) for s in SAMPLERS]
        variants.append(dict(sampler="arx", chunk=48))
        if n_dev > 1:
            variants.append(dict(sampler="arx", devices=n_dev))
        for v in variants:
            row = _time_engine(name, cells, **v)
            row["python_loop_s"] = round(t_loop, 2)
            row["speedup_vs_loop"] = round(t_loop / row["engine_s"], 1)
            rows.append(row)
    emit("engine_speed", rows)

    best = {}
    for name, _ in _workloads():
        cand = [r for r in rows if r["regime"] == name]
        best[name] = max(cand, key=lambda r: r["steps_per_s"])
    point = {
        "bench": "engine_speed", "scale": SCALE, "devices": n_dev,
        "headline": {k: {kk: v[kk] for kk in
                         ("sampler", "chunk", "engine_s", "compile_s",
                          "steps_per_s", "samples_per_s", "python_loop_s",
                          "speedup_vs_loop")}
                     for k, v in best.items()},
        "rows": rows,
    }
    path = RESULTS / "BENCH_engine_speed.json"
    with open(path, "w") as f:
        json.dump(point, f, indent=1)
    hb = best["grid-18cells"]
    print(f"  -> best {hb['sampler']}: {hb['engine_s']}s steady "
          f"({hb['steps_per_s']:,} steps/s, {hb['samples_per_s']:,} "
          f"samples/s; compile {hb['compile_s']}s excluded); "
          f"python loop {hb['python_loop_s']}s -> {hb['speedup_vs_loop']}x")
    return rows


# --- device-scaling study -------------------------------------------------

_CHILD = """\
import json, sys, time
ndev, reps, seeds = int(sys.argv[1]), int(sys.argv[2]), int(sys.argv[3])
import jax   # topology comes from XLA_FLAGS (repro.config.subprocess_env)
assert jax.local_device_count() >= ndev, (jax.local_device_count(), ndev)
from repro.core import scenarios as SC
cells = json.loads(sys.stdin.read())
kw = dict(seeds=range(seeds), sampler="arx",
          devices=ndev if ndev > 1 else None)
t0 = time.time()
res = SC.run_grid(cells, **kw)
t_first = time.time() - t0
ts = []
for _ in range(reps):
    t0 = time.time()
    res = SC.run_grid(cells, **kw)
    ts.append(time.time() - t0)
print("RESULT " + json.dumps({
    "t": min(ts), "t_first": t_first,
    "mean_lost": float(res.lost_fraction.mean())}))
"""


def _time_scaling_leg(cells, ndev: int) -> dict:
    """Time the grid workload at ``ndev`` virtual host devices.

    One subprocess per count: ``xla_force_host_platform_device_count``
    only takes effect before XLA initializes, so the parent process
    (whatever its own topology) cannot measure other counts in-process.
    """
    env = CFG.subprocess_env(ndev)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = os.path.join(root, "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD, str(ndev), str(REPS),
         str(len(SEEDS))],
        input=json.dumps(cells), env=env, capture_output=True, text=True)
    if proc.returncode != 0:
        raise RuntimeError(
            f"scaling leg devices={ndev} failed:\n{proc.stderr}")
    line = next(l for l in proc.stdout.splitlines()
                if l.startswith("RESULT "))
    out = json.loads(line[len("RESULT "):])
    steps, samples = _work_units(cells)
    t = out["t"]
    return {
        "regime": "grid-18cells", "devices": ndev,
        "engine_s": round(t, 3),
        "compile_s": round(max(out["t_first"] - t, 0.0), 2),
        "steps_per_s": int(steps / t),
        "samples_per_s": int(samples / t),
        "mean_lost": round(out["mean_lost"], 4),
    }


def run_scaling():
    name, cells = _workloads()[0]  # grid-cells: 18 cells x 8 seeds = 144
    rows = []
    for ndev in SCALING_DEVICES:
        row = _time_scaling_leg(cells, ndev)
        rows.append(row)
        print(f"  devices={ndev}: {row['engine_s']}s steady "
              f"({row['steps_per_s']:,} steps/s)")
    base = rows[0]["engine_s"]
    for row in rows:
        row["speedup_vs_1dev"] = round(base / row["engine_s"], 2)
    emit("engine_scaling", rows)

    at8 = next(r for r in rows if r["devices"] == 8)
    point = {
        "bench": "scaling", "scale": SCALE,
        "host_cores": os.cpu_count(),
        "note": ("virtual host devices scale with physical cores; "
                 "speedup_vs_1dev ~= min(devices, host_cores) and is "
                 "deliberately NOT a gated metric"),
        "headline": {"scaling_8dev": {
            k: at8[k] for k in ("devices", "engine_s", "compile_s",
                                "steps_per_s", "samples_per_s",
                                "speedup_vs_1dev")}},
        "rows": rows,
    }
    path = RESULTS / "BENCH_scaling.json"
    with open(path, "w") as f:
        json.dump(point, f, indent=1)
    print(f"  -> 8-device leg: {at8['engine_s']}s "
          f"({at8['steps_per_s']:,} steps/s, "
          f"{at8['speedup_vs_1dev']}x vs 1 device on "
          f"{os.cpu_count()}-core host) -> {path}")
    return rows


if __name__ == "__main__":
    if "--scaling" in sys.argv[1:]:
        run_scaling()
    else:
        run()
