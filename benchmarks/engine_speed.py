"""Batched scenario engine vs per-cell Python loop: wall-clock for a
Fig. 4/6-style sweep (cells × seeds) through (a) one batched ``run_grid``
dispatch and (b) the numpy reference looped one ``(params, seed)`` point at
a time.

Two regimes are timed: a parameter-grid sweep over many small cells (the
scenario-exploration workload the engine exists for — Python loop overhead
dominates the reference) and a medium-sized Fig. 4 cell block. Compile time
is reported separately; on accelerators the dispatch gap widens further.
"""
from __future__ import annotations

import time

from benchmarks.common import SCALE, emit
from repro.core import scenarios as SC
from repro.core import simulation as S

SEEDS = tuple(range(8))


def _time_pair(name: str, cells: list[dict]) -> dict:
    t0 = time.time()
    res = SC.run_grid(cells, seeds=SEEDS, sampler="fast")
    t_compile = time.time() - t0
    t0 = time.time()
    res = SC.run_grid(cells, seeds=SEEDS, sampler="fast")
    t_engine = time.time() - t0

    t0 = time.time()
    for c in cells:
        for s in SEEDS:
            S.simulate_vault(S.SimParams(seed=s, **{
                k: v for k, v in c.items()
                if k in ("n_objects", "n_chunks", "k_outer", "k_inner",
                         "r_inner", "n_nodes", "byz_fraction",
                         "churn_per_year", "cache_ttl_hours", "step_hours",
                         "years")}))
    t_loop = time.time() - t0
    lost_m, _ = SC.mean_ci(res.lost_fraction)
    return {
        "regime": name, "cells": len(cells), "seeds": len(SEEDS),
        "engine_s": round(t_engine, 2),
        "engine_compile_s": round(t_compile - t_engine, 2),
        "python_loop_s": round(t_loop, 2),
        "speedup": round(t_loop / max(t_engine, 1e-9), 2),
        "mean_lost": round(float(lost_m.mean()), 4),
    }


def run():
    quick = SCALE == "quick"
    years = 0.5 if quick else 1.0
    # many small cells: (byz x R) grid, the scenario-sweep workload
    grid = [dict(n_objects=20 if quick else 50, k_inner=32, r_inner=r,
                 byz_fraction=f, churn_per_year=26.0, n_nodes=20_000,
                 step_hours=12.0, years=years)
            for f in (0.0, 0.1, 0.2, 0.33, 0.4, 0.5)
            for r in (64, 80, 112)]
    # medium cells: a Fig. 4 object-count x TTL block
    fig4 = [dict(n_objects=100 if quick else 400, churn_per_year=26.0,
                 cache_ttl_hours=ttl, n_nodes=20_000, step_hours=12.0,
                 years=years)
            for ttl in (0.0, 24.0, 48.0)]
    rows = [_time_pair("grid-18cells", grid), _time_pair("fig4-3cells", fig4)]
    emit("engine_speed", rows)
    print(f"  -> one dispatch vs python loop: "
          f"{rows[0]['speedup']}x on the {rows[0]['cells']}-cell grid")
    return rows


if __name__ == "__main__":
    run()
