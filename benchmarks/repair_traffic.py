"""Fig. 4: repair traffic vs #objects and churn, with chunk-cache TTLs,
VAULT vs Ceph-like replication. Traffic in object-size units / first year."""
from __future__ import annotations

from benchmarks.common import SCALE, emit
from repro.core import simulation as S

TTLS = (0.0, 12.0, 24.0, 48.0)


def run():
    quick = SCALE == "quick"
    n_objects_sweep = (250, 500, 1000) if quick else (1000, 5000, 10000)
    churn_sweep = (8.0, 26.0, 52.0, 104.0) if quick else (
        8.0, 26.0, 52.0, 104.0, 208.0)
    base_churn = 26.0
    n_nodes = 20_000 if quick else 100_000
    rows = []
    for n_obj in n_objects_sweep:
        row = {"sweep": "objects", "x": n_obj, "churn": base_churn}
        for ttl in TTLS:
            r = S.simulate_vault(S.SimParams(
                n_nodes=n_nodes, n_objects=n_obj, churn_per_year=base_churn,
                cache_ttl_hours=ttl, seed=1))
            row[f"vault_{int(ttl)}h"] = round(r.repair_traffic_units, 1)
        rb = S.simulate_replicated(S.SimParams(
            n_nodes=n_nodes, n_objects=n_obj, churn_per_year=base_churn,
            seed=1))
        row["replicated"] = round(rb.repair_traffic_units, 1)
        rows.append(row)
    for churn in churn_sweep:
        row = {"sweep": "churn", "x": churn, "churn": churn}
        for ttl in TTLS:
            r = S.simulate_vault(S.SimParams(
                n_nodes=n_nodes, n_objects=n_objects_sweep[0],
                churn_per_year=churn, cache_ttl_hours=ttl, seed=2))
            row[f"vault_{int(ttl)}h"] = round(r.repair_traffic_units, 1)
        rb = S.simulate_replicated(S.SimParams(
            n_nodes=n_nodes, n_objects=n_objects_sweep[0],
            churn_per_year=churn, seed=2))
        row["replicated"] = round(rb.repair_traffic_units, 1)
        rows.append(row)
    emit("fig4_repair_traffic", rows)
    # headline claims (paper: ~6x reduction at 48h cache; linear in objects)
    r0 = rows[0][f"vault_0h"]
    r48 = rows[0][f"vault_48h"]
    print(f"  -> cache reduction at 48h: {r0 / max(r48, 1e-9):.1f}x "
          f"(paper reports 6x)")
    return rows


if __name__ == "__main__":
    run()
