"""Fig. 4: repair traffic vs #objects and churn, with chunk-cache TTLs,
VAULT vs Ceph-like replication. Traffic in object-size units / first period.

Runs on the batched scenario engine: each sweep family (all object-count ×
TTL cells, all churn × TTL cells) is ONE device dispatch over cells × 8
seeds, reported as per-cell mean ± 95% CI instead of the old single-seed
point estimates.
"""
from __future__ import annotations

from benchmarks.common import SCALE, emit
from repro.core import scenarios as SC

TTLS = (0.0, 12.0, 24.0, 48.0)
SEEDS = tuple(range(8))


def run():
    quick = SCALE == "quick"
    n_objects_sweep = (100, 200, 400) if quick else (1000, 5000, 10000)
    churn_sweep = (8.0, 26.0, 52.0, 104.0) if quick else (
        8.0, 26.0, 52.0, 104.0, 208.0)
    base_churn = 26.0
    n_nodes = 20_000 if quick else 100_000
    step_hours = 12.0 if quick else 6.0
    years = 0.5 if quick else 1.0
    common = dict(n_nodes=n_nodes, step_hours=step_hours, years=years)

    rows = []
    # --- objects sweep: one batched dispatch over n_obj x TTL x seeds
    cells = [dict(n_objects=n_obj, churn_per_year=base_churn,
                  cache_ttl_hours=ttl, **common)
             for n_obj in n_objects_sweep for ttl in TTLS]
    res = SC.run_grid(cells, seeds=SEEDS, sampler="arx", chunk_size=64)
    mean, ci = SC.mean_ci(res.repair_traffic_units)
    repl = SC.run_replicated_grid(
        [dict(n_objects=n_obj, churn_per_year=base_churn, **common)
         for n_obj in n_objects_sweep], seeds=SEEDS, sampler="arx")
    rmean, rci = SC.mean_ci(repl.repair_traffic_units)
    for i, n_obj in enumerate(n_objects_sweep):
        row = {"sweep": "objects", "x": n_obj, "churn": base_churn}
        for j, ttl in enumerate(TTLS):
            row[f"vault_{int(ttl)}h"] = round(mean[i * len(TTLS) + j], 1)
            row[f"vault_{int(ttl)}h_ci"] = round(ci[i * len(TTLS) + j], 1)
        row["replicated"] = round(rmean[i], 1)
        row["replicated_ci"] = round(rci[i], 1)
        rows.append(row)

    # --- churn sweep: second dispatch (smaller padded group count)
    cells = [dict(n_objects=n_objects_sweep[0], churn_per_year=churn,
                  cache_ttl_hours=ttl, **common)
             for churn in churn_sweep for ttl in TTLS]
    res = SC.run_grid(cells, seeds=SEEDS, sampler="arx", chunk_size=64)
    mean, ci = SC.mean_ci(res.repair_traffic_units)
    repl = SC.run_replicated_grid(
        [dict(n_objects=n_objects_sweep[0], churn_per_year=churn, **common)
         for churn in churn_sweep], seeds=SEEDS, sampler="arx")
    rmean, rci = SC.mean_ci(repl.repair_traffic_units)
    for i, churn in enumerate(churn_sweep):
        row = {"sweep": "churn", "x": churn, "churn": churn}
        for j, ttl in enumerate(TTLS):
            row[f"vault_{int(ttl)}h"] = round(mean[i * len(TTLS) + j], 1)
            row[f"vault_{int(ttl)}h_ci"] = round(ci[i * len(TTLS) + j], 1)
        row["replicated"] = round(rmean[i], 1)
        row["replicated_ci"] = round(rci[i], 1)
        rows.append(row)

    emit("fig4_repair_traffic", rows)
    # headline claims (paper: ~6x reduction at 48h cache; linear in objects)
    r0 = rows[0]["vault_0h"]
    r48 = rows[0]["vault_48h"]
    print(f"  -> cache reduction at 48h: {r0 / max(r48, 1e-9):.1f}x "
          f"(paper reports 6x); {len(SEEDS)} seeds/cell")
    return rows


if __name__ == "__main__":
    run()
