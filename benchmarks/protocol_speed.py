"""Protocol-simulator tick-throughput study: PR 3 scalar path vs the
batched/vectorized engine, at 1K+ nodes.

For a paper-shaped deployment (R=64 groups on 1K nodes, plus 10K- and
100K-node vectorized legs — 6 probe ticks each at quick scale, the full
probe at ``BENCH_SCALE=full``) this times, per engine × VRF backend:

* **setup** — object stores through the VRF placement path (once), and
* **steady-state tick cost** — the median of the per-tick wall times
  recorded by the ``run_protocol`` probe hook, after a warm-up prefix;
  the median is robust to transient host-noise spikes and the setup
  never enters the per-tick measurement at all.

``engine="reference"`` is the preserved PR 3 implementation (scalar
``verify_selection`` per claim × receiver, per-node dict loops, no lookup
caching) — the baseline the ≥10× acceptance criterion is measured
against. ``engine="vectorized"`` is the batched path: one memoized
``verify_selection_batch`` round per (re)ingest, persistent array claim
tables (``repro.core.claims_engine``), table-driven repair pre-checks and
block-drawn churn. ``vrf="arx"`` additionally routes cold verification
batches through the ``kernels/prf_select`` pairs kernel; its steady-state
ticks pay python int packing in Locate() rounds, so the memoized hash
backend usually leads once caches are warm — both are reported.

The second scenario is the PR's protocol-only adversary at paper scale: a
1K-node, one-simulated-month run with an eclipse window cutting 25% of
the ring for a week — a configuration the mean-field engine cannot
express — which must finish inside the CI bench budget.

Emits ``results/bench/protocol_speed.csv`` and the machine-readable
trajectory point ``results/bench/BENCH_protocol_speed.json`` that the CI
``bench-regression`` job diffs against (``scripts/check_bench_regression``).
"""
from __future__ import annotations

import dataclasses
import json
import time

from benchmarks.common import RESULTS, SCALE, emit
from repro.core import protocol_sim as PS

# steady-state tick cost = median of the per-tick wall times (probe hook)
# after a warm-up prefix — identical legs for every engine, and the
# median throws away transient host-noise spikes that a two-leg
# difference would fold straight into the estimate
TICKS = 12
WARMUP_TICKS = 3  # early ticks are cheaper (views not yet churned)

# Honest fixed point for the 10K-node scaling claim: the pre-rework
# vectorized engine (commit 489aba7, before batched Locate() rounds, the
# kernelized GF(256) solve and the dead-node reaper) run naively at
# n_nodes=10_000 / R=64 / vrf="arx" for a full 60-tick simulated month,
# measured back-to-back with the current engine on the same host within
# minutes of each other. Not re-measured in CI — the naive path no longer
# exists in the tree — so it is recorded here as provenance, and the
# speedup_vs_naive field it feeds is informational, not gated.
NAIVE_10K_MONTH_TICK_MS = 1721.5

# Cross-group batching provenance: the per-group tick (commit 66c03bc —
# batched Locate() rounds and the kernelized GF(256) solve, but python
# loops over the 600 groups for claims, repair solves and membership
# timers) vs the one-dispatch-per-phase engine, each run as a full
# 60-tick 10K-node simulated month (n_objects=120, vrf="arx"),
# interleaved back-to-back on the same idle single-core host within
# minutes. Median steady-state tick (diffs after a 2-tick warm-up).
# Recorded here as provenance — the per-group path no longer exists in
# the tree — while CI gates the live scale_10k / scale_100k points
# below. The honest split: the steady-state tick median moves only ~4%
# because ~3/4 of a churned tick is per-repair protocol work (Locate +
# fragment pulls + rateless decode), already batched per repair since
# PR 5-6; the cross-group dispatch instead compresses the solve-heavy
# phases — the same month's full wall clock drops 98.2 s -> 73.9 s
# (1.33x), and the claims/timer phase cost scales with groups, not
# nodes, which is what unlocks the 100K-node probe point.
PER_GROUP_10K_MONTH_TICK_MS = 893.3
BATCHED_10K_MONTH_TICK_MS = 855.0
PER_GROUP_10K_MONTH_WALL_S = 98.2
BATCHED_10K_MONTH_WALL_S = 73.9


def _base_params(n_nodes: int) -> PS.ProtocolParams:
    return PS.ProtocolParams(
        n_nodes=n_nodes, n_objects=max(6, 12 * n_nodes // 1000),
        n_chunks=5, object_bytes=1024, k_outer=2, k_inner=16, r_inner=64,
        byz_fraction=0.1, churn_per_year=4.0, step_hours=12.0,
        claim_every=1, seed=0)


def _clear_shared_caches() -> None:
    """Reset the process-global memo caches between variants.

    Benchmark runs share one seed, hence one object/key population — a
    later variant would otherwise inherit the earlier one's warm ring/
    threshold memos and measure a mix of engines."""
    from repro.core import rateless as rl
    from repro.core import selection as sel

    sel._threshold_for.cache_clear()
    sel._node_point.cache_clear()
    rl._coeff_row.cache_clear()


def _tick_cost(p: PS.ProtocolParams, engine: str,
               ticks: int = TICKS) -> dict:
    _clear_shared_caches()
    marks = []
    t0 = time.time()
    r = PS.run_protocol(dataclasses.replace(p, steps=ticks), engine=engine,
                        probe=lambda t, net: marks.append(time.time()))
    total = time.time() - t0
    diffs = [b - a for a, b in zip(marks, marks[1:])][WARMUP_TICKS - 1:]
    tick_s = sorted(diffs)[len(diffs) // 2]
    return {
        "engine": engine, "vrf": p.vrf, "n_nodes": p.n_nodes,
        "n_groups": r.n_groups,
        "setup_s": round(total - (marks[-1] - marks[0]), 2),
        "tick_ms": round(tick_s * 1e3, 1),
        "ticks_per_s": round(1.0 / tick_s, 3),
        "node_ticks_per_s": int(p.n_nodes / tick_s),
        "alive_frac_final": round(float(r.alive_frac_trace[-1]), 4),
        "repairs": int(r.repairs),
    }


def _eclipse_month(n_nodes: int) -> dict:
    """1K-node, one-simulated-month eclipse run (the protocol-only
    scenario): 25% of the ring cut for 14 ticks (one week at 12h steps)."""
    p = dataclasses.replace(
        _base_params(n_nodes), steps=60, adv_policy="eclipse",
        attack_frac=0.25, attack_step=20, eclipse_steps=14,
        churn_per_year=26.0)
    t0 = time.time()
    r = PS.run_protocol(p, engine="vectorized")
    wall = time.time() - t0
    return {
        "engine": "vectorized", "vrf": p.vrf, "n_nodes": n_nodes,
        "n_groups": r.n_groups, "scenario": "eclipse-1month",
        "wall_s": round(wall, 1),
        "tick_ms": round(wall / p.steps * 1e3, 1),
        "alive_frac_final": round(float(r.alive_frac_trace[-1]), 4),
        "lost_objects": int(r.lost_objects),
        "repairs": int(r.repairs),
    }


def run():
    n = 1000
    rows = []
    variants = [("vectorized", "hash"), ("vectorized", "arx"),
                ("reference", "hash")]
    for engine, vrf in variants:
        p = dataclasses.replace(_base_params(n), vrf=vrf)
        rows.append(_tick_cost(p, engine))
    ecl = _eclipse_month(n)
    rows.append(ecl)
    # 10K-node leg, vectorized only (the point of it): full probe at
    # BENCH_SCALE=full, a 6-tick smoke at quick scale (the CI
    # bench-regression job gates its scale_10k point like the 1K legs)
    p10 = dataclasses.replace(_base_params(10_000), vrf="arx")
    r10 = _tick_cost(p10, "vectorized",
                     ticks=TICKS if SCALE == "full" else 6)
    rows.append(r10)
    # 100K-node probe leg (vectorized/arx only). Methodology: n_objects is
    # pinned to 120 — the same 600-group universe as the 10K leg — so the
    # tick cost isolates *population* scaling (Locate() candidate sets,
    # block-drawn churn, claims-slab row tables all grow with n_nodes
    # while the per-tick group work stays fixed). A handful of probe
    # ticks, same median-after-warm-up estimator as every other leg: the
    # one-dispatch-per-phase tick keeps this inside the CI bench budget
    # (~35 s setup + ~1.5 s/tick on the reference host).
    p100 = dataclasses.replace(_base_params(100_000), n_objects=120,
                               vrf="arx")
    r100 = _tick_cost(p100, "vectorized",
                      ticks=TICKS if SCALE == "full" else 6)
    rows.append(r100)
    emit("protocol_speed", rows)

    ref = next(r for r in rows if r["engine"] == "reference")
    vec = {r["vrf"]: r for r in rows
           if r["engine"] == "vectorized" and "scenario" not in r
           and r["n_nodes"] == n}
    point = {
        "bench": "protocol_speed", "scale": SCALE, "n_nodes": n,
        "headline": {
            "tick_ms_reference": ref["tick_ms"],
            "tick_ms_vectorized_hash": vec["hash"]["tick_ms"],
            "tick_ms_vectorized_arx": vec["arx"]["tick_ms"],
            "node_ticks_per_s": vec["hash"]["node_ticks_per_s"],
            "speedup_hash": round(ref["tick_ms"] / vec["hash"]["tick_ms"],
                                  1),
            "speedup_arx": round(ref["tick_ms"] / vec["arx"]["tick_ms"], 1),
            # the acceptance metric: fastest batched backend vs PR 3 scalar
            # (the two backends trade places with host noise; either one
            # is a fair reading of "the batched path")
            "speedup_best": round(ref["tick_ms"]
                                  / min(vec["hash"]["tick_ms"],
                                        vec["arx"]["tick_ms"]), 1),
            "eclipse_month_s": ecl["wall_s"],
            # 10K-node point; leaf names match the gated 1K metrics so
            # scripts/check_bench_regression.py diffs them automatically
            "scale_10k": {
                "tick_ms_vectorized_arx": r10["tick_ms"],
                "node_ticks_per_s": r10["node_ticks_per_s"],
                "naive_month_tick_ms": NAIVE_10K_MONTH_TICK_MS,
                "speedup_vs_naive": round(
                    NAIVE_10K_MONTH_TICK_MS / r10["tick_ms"], 1),
            },
            # leaf names match the gated 1K metrics, so the regression
            # gate picks the 100K point up automatically
            "scale_100k": {
                "tick_ms_vectorized_arx": r100["tick_ms"],
                "node_ticks_per_s": r100["node_ticks_per_s"],
            },
            # interleaved back-to-back month measurement (see the
            # PER_GROUP_/BATCHED_ constants above for methodology)
            "month_10k": {
                "per_group_tick_ms": PER_GROUP_10K_MONTH_TICK_MS,
                "batched_tick_ms": BATCHED_10K_MONTH_TICK_MS,
                "speedup": round(PER_GROUP_10K_MONTH_TICK_MS
                                 / BATCHED_10K_MONTH_TICK_MS, 2),
                "per_group_wall_s": PER_GROUP_10K_MONTH_WALL_S,
                "batched_wall_s": BATCHED_10K_MONTH_WALL_S,
                "wall_speedup": round(PER_GROUP_10K_MONTH_WALL_S
                                      / BATCHED_10K_MONTH_WALL_S, 2),
            },
        },
        "rows": rows,
    }
    with open(RESULTS / "BENCH_protocol_speed.json", "w") as f:
        json.dump(point, f, indent=1)
    h = point["headline"]
    print(f"  -> tick {h['tick_ms_reference']}ms (PR 3 scalar) vs "
          f"{h['tick_ms_vectorized_hash']}ms (vectorized, hash) / "
          f"{h['tick_ms_vectorized_arx']}ms (arx kernel): "
          f"{h['speedup_hash']}x / {h['speedup_arx']}x at {n} nodes; "
          f"1-month eclipse run {h['eclipse_month_s']}s; "
          f"10K nodes {h['scale_10k']['tick_ms_vectorized_arx']}ms/tick "
          f"({h['scale_10k']['speedup_vs_naive']}x vs pre-rework); "
          f"100K nodes {h['scale_100k']['tick_ms_vectorized_arx']}ms/tick")
    return rows


if __name__ == "__main__":
    run()
