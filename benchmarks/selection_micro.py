"""Selection-throughput micro-benchmark (repair-storm path).

When a node holding µ fragments fails, µ chunk groups re-run Locate():
candidates must evaluate selection PRFs for (node × fragment) pairs in
bulk. Compares the protocol-level path (per-pair keyed hash, what the
simulated peers run) against the batched ARX kernel (`kernels/prf_select`,
interpret mode here; the TPU target layout) — the VPU-friendly form scales
the selection layer past 10⁶ pairs/s even on this 1-core box."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.vrf import KeyPair, VRFRegistry
from repro.kernels import ops


def run():
    rng = np.random.default_rng(0)
    rows = []
    # protocol path: per-pair sha-based VRF (one candidate set)
    reg = VRFRegistry()
    kps = [KeyPair.generate(bytes([i, 7])) for i in range(64)]
    for kp in kps:
        reg.register(kp)
    alphas = [int(x).to_bytes(32, "big") for x in
              rng.integers(0, 2**62, 64)]
    t0 = time.perf_counter()
    n_pairs = 0
    for kp in kps:
        for a in alphas:
            reg.prove(kp.sk, a)
            n_pairs += 1
    t_proto = time.perf_counter() - t0
    rows.append({
        "path": "protocol (keyed hash, per pair)",
        "pairs": n_pairs,
        "wall_s": round(t_proto, 4),
        "pairs_per_s": int(n_pairs / t_proto),
    })
    # batched kernel path
    for n, f in ((64, 64), (512, 1024), (2048, 4096)):
        tags = rng.integers(-(2**31), 2**31 - 1, (n, 2)).astype(np.int32)
        fh = rng.integers(-(2**31), 2**31 - 1, (f, 2)).astype(np.int32)
        ops.prf_select(tags[:8], fh[:128])  # warm the jit cache
        t0 = time.perf_counter()
        ops.prf_select(tags, fh)
        dt = time.perf_counter() - t0
        rows.append({
            "path": f"pallas ARX kernel {n}x{f} (interpret)",
            "pairs": n * f,
            "wall_s": round(dt, 4),
            "pairs_per_s": int(n * f / dt),
        })
    emit("selection_micro", rows)
    return rows


if __name__ == "__main__":
    run()
