"""AdamW + cosine schedule + global-norm clipping (no optax here).

Moments are fp32 regardless of parameter dtype; master weights stay in the
parameter dtype with the update computed in fp32 (bf16 params + fp32 moments
is the memory layout the dry-run reports). Moment tensors inherit the
parameter sharding plus the ZeRO-1 data-axis dimension
(``distributed.sharding.zero1_tree``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads
    ), gn


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, opt_state, cfg: AdamWConfig):
    step = opt_state["step"] + 1
    lr = cosine_schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g32
        v2 = b2 * v + (1 - b2) * g32 * g32
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p2 = p32 - lr * (delta + cfg.weight_decay * p32)
        return p2.astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["mu"])
    flat_v = tdef.flatten_up_to(opt_state["nu"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, {"mu": new_m, "nu": new_v, "step": step}, metrics
