"""Shared model plumbing: config, norms, RoPE, init, logical-axis metadata.

No flax/haiku in this environment — models are pure pytrees (nested dicts of
jnp arrays) plus init/apply functions. Every parameter carries a parallel
*logical axis* annotation (built by ``*_spec`` functions mirroring the init
tree) which ``repro.distributed.sharding`` resolves to mesh ``PartitionSpec``s
with divisibility fallback.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = Any  # nested dict pytree of jnp arrays
Specs = Any  # same structure, leaves = tuple[str | None, ...]


# ------------------------------------------------------------------- config
@dataclasses.dataclass(frozen=True)
class LayerPattern:
    """One scanned segment: ``repeat`` copies of a block of sub-layers.

    Each sub-layer is ``(mixer, ffn)`` where mixer ∈ {"gqa", "mla",
    "mamba", None} and ffn ∈ {"dense", "moe", None}.
    """

    repeat: int
    block: tuple[tuple[str | None, str | None], ...]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    vocab: int = 32_000
    d_model: int = 512
    n_layers: int = 4
    # attention
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # MLA (used when a pattern names "mla")
    q_lora_rank: int = 0  # 0 -> direct q projection
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # dense FFN
    d_ff: int = 2048
    # MoE
    n_experts: int = 0
    n_experts_per_tok: int = 2
    moe_d_ff: int = 0  # routed expert hidden size
    n_shared_experts: int = 0
    shared_d_ff: int = 0  # total shared-expert hidden size
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3
    moe_constrain: str = "be"  # "be": buffer sharded (batch, experts) | "none"
    # combine strategy: "scatter" keeps the combine in expert-major space
    # (scatter-add to token space + all-reduce over the expert shards —
    # B·S·d wire bytes); "gather" is the naive inverse-gather (forces an
    # all-gather of the (B,E,C,d) expert outputs). See EXPERIMENTS.md §Perf.
    moe_combine: str = "scatter"
    # Mamba2 / SSD
    ssm_state: int = 128
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # layer pattern; () -> n_layers x (default_mixer, default_ffn)
    pattern: tuple[LayerPattern, ...] = ()
    default_mixer: str = "gqa"
    default_ffn: str = "dense"
    # embeddings
    embed_inputs: bool = False  # modality stub: consume (B,S,d) embeddings
    extra_embed_len: int = 0  # vlm: prepended patch embeddings
    tie_embeddings: bool = False
    # numerics / memory
    dtype: str = "float32"  # parameter dtype
    compute_dtype: str = "float32"
    attn_chunk: int = 0  # 0 -> unchunked; else online-softmax KV block
    remat: str = "none"  # none | full | dots
    max_cache_len: int = 0  # serve: KV cache capacity
    # analysis: python-loop the layer stacks instead of lax.scan so that
    # compiled.cost_analysis() sees every layer (it counts scan bodies ONCE,
    # ignoring trip count — launch/dryrun.py measures per-layer costs from
    # shallow unrolled variants and reconstructs full-depth totals)
    scan_unroll: bool = False

    # ------------------------------------------------------------ derived
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def patterns(self) -> tuple[LayerPattern, ...]:
        if self.pattern:
            return self.pattern
        return (
            LayerPattern(self.n_layers, ((self.default_mixer, self.default_ffn),)),
        )

    @property
    def total_layers(self) -> int:
        return sum(p.repeat * len(p.block) for p in self.patterns)

    def pdtype(self):
        return jnp.dtype(self.dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)


# ------------------------------------------------------------------- norms
def rmsnorm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def rmsnorm_init(d: int, dtype) -> jax.Array:
    return jnp.ones((d,), dtype)


# -------------------------------------------------------------------- RoPE
def rope_freqs(dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, dim, 2, dtype=np.float64) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, D) with D even; positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------------- init
def dense_init(key, shape, dtype, fan_in: int | None = None):
    fi = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / np.sqrt(max(fi, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


class KeyGen:
    """Deterministic fresh-key dispenser (avoids threading key tuples)."""

    def __init__(self, key: jax.Array):
        self._key = key

    def __call__(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub


# ------------------------------------------------------------ pytree utils
def tree_bytes(tree) -> int:
    return sum(
        x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree)
    )


def tree_count(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def cast_tree(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )
