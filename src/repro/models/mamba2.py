"""Mamba2 (SSD — state-space duality) mixer, arXiv:2405.21060.

TPU-adapted chunked algorithm: the sequence is split into chunks of
``ssm_chunk``; each chunk does an attention-like intra-chunk matmul (MXU
work, (Q,Q) score tile) plus a rank-N inter-chunk state handoff carried by a
``lax.scan``. The per-chunk tile is the only O(Q²) live buffer — memory is
O(L·Q) not O(L²) — which is what makes the 500K-token decode/train shapes
feasible for the SSM/hybrid architectures.

Decode keeps (conv window, SSM state) as the cache: state update is a rank-1
outer-product accumulate per head — O(H·P·N) per token, independent of
context length.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import KeyGen, ModelConfig, dense_init, rmsnorm



def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    conv_dim = d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return d_inner, n_heads, conv_dim


def mamba_init(key, cfg: ModelConfig):
    kg = KeyGen(key)
    d = cfg.d_model
    d_inner, h, conv_dim = _dims(cfg)
    dt = cfg.pdtype()
    proj_out = 2 * d_inner + 2 * cfg.ssm_groups * cfg.ssm_state + h
    p = {
        "in_proj": dense_init(kg(), (d, proj_out), dt),
        "conv_w": dense_init(kg(), (cfg.ssm_conv, conv_dim), dt,
                             fan_in=cfg.ssm_conv),
        "conv_b": jnp.zeros((conv_dim,), dt),
        # A in (-exp) parametrization; init in [1, 16] as in the paper
        "a_log": jnp.log(
            jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)
        ).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.ones((d_inner,), dt),
        "out_proj": dense_init(kg(), (d_inner, d), dt, fan_in=d_inner),
    }
    return p


def mamba_spec(cfg: ModelConfig):
    return {
        "in_proj": ("embed", "mlp"),
        "conv_w": (None, "mlp"),
        "conv_b": ("mlp",),
        "a_log": ("heads",),
        "d_skip": ("heads",),
        "dt_bias": ("heads",),
        "norm": ("mlp",),
        "out_proj": ("mlp", "embed"),
    }


def mamba_cache_init(cfg: ModelConfig, batch: int, dtype):
    d_inner, h, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, h, cfg.ssm_head_dim, cfg.ssm_state),
                         jnp.float32),
    }


def mamba_cache_spec(cfg: ModelConfig):
    return {
        "conv": ("batch", None, "mlp"),
        "ssm": ("batch", "heads", None, None),
    }


def _split_proj(cfg: ModelConfig, zxbcdt):
    d_inner, h, _ = _dims(cfg)
    gn = cfg.ssm_groups * cfg.ssm_state
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner : 2 * d_inner + 2 * gn]
    dt = zxbcdt[..., 2 * d_inner + 2 * gn :]
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv over the sequence axis. xbc: (B,L,C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for j in range(k):  # k is 4 — unrolled taps vectorize cleanly
        out = out + pad[:, j : j + xbc.shape[1], :] * w[j]
    return jax.nn.silu(out + b)


def _expand_groups(t, h):
    """(B,L,G,N) -> (B,L,H,N) by repeating each group's B/C to its heads."""
    g = t.shape[2]
    return jnp.repeat(t, h // g, axis=2)


def _ssd_chunked(cfg: ModelConfig, x, b_mat, c_mat, dt, a, init_state):
    """Chunked SSD. x:(B,L,H,P) b/c:(B,L,H,N) dt:(B,L,H) a:(H,)<0.

    Returns (y (B,L,H,P), final_state (B,H,P,N)).
    """
    bsz, l, h, p = x.shape
    n = b_mat.shape[-1]
    q = min(cfg.ssm_chunk, l)
    pad = (-l) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // q

    def chunk(t):  # (B, L', ...) -> (nc, B, q, ...)
        return t.reshape(bsz, nc, q, *t.shape[2:]).swapaxes(0, 1)

    xs = (chunk(x), chunk(b_mat), chunk(c_mat), chunk(dt))

    def step(state, inp):
        xc, bc, cc, dtc = inp  # (B,q,H,P/N/·)
        da = dtc * a  # (B,q,H), negative
        cs = jnp.cumsum(da, axis=1)
        # NOTE: never clamp the cumulative log-decay — every exponent below
        # is a *difference* of cs values (≤ 0 by construction), so exp() can
        # only underflow to 0, which is exact; clamping cs itself corrupts
        # relative decays within a chunk when |a|·dt is large.
        seg = cs[:, :, None, :] - cs[:, None, :, :]  # (B,q,q,H) i-j
        tri = jnp.tril(jnp.ones((q, q), bool))[None, :, :, None]
        # mask BEFORE exp: upper-triangular seg is positive and would
        # overflow inside the where's untaken branch, poisoning the
        # backward pass with inf·0 = NaN
        decay = jnp.where(tri, jnp.exp(jnp.where(tri, seg, 0.0)), 0.0)
        scores = jnp.einsum("bihn,bjhn->bijh", cc, bc)  # (B,q,q,H)
        m = scores * decay * dtc[:, None, :, :]
        y_intra = jnp.einsum("bijh,bjhp->bihp", m, xc)
        y_inter = jnp.einsum("bihn,bhpn->bihp", cc, state) * jnp.exp(
            cs
        )[..., None]
        tail = cs[:, -1:, :] - cs  # decay from j to chunk end, ≤ 0
        sloc = jnp.einsum(
            "bjhn,bjhp,bjh->bhpn", bc, xc, jnp.exp(tail) * dtc
        )
        state = state * jnp.exp(cs[:, -1, :])[:, :, None, None] + sloc
        return state, y_intra + y_inter

    if cfg.scan_unroll:  # dry-run analysis: expose every chunk to HLO
        state = init_state
        ys_l = []
        for i in range(nc):
            state, yi = step(state, tuple(t[i] for t in xs))
            ys_l.append(yi)
        ys = jnp.stack(ys_l)
    else:
        state, ys = jax.lax.scan(step, init_state, xs)
    y = ys.swapaxes(0, 1).reshape(bsz, nc * q, h, p)[:, :l]
    return y, state


def mamba_forward(p, cfg: ModelConfig, x, positions=None, cache=None,
                  cur_len=None):
    """Full-sequence path (train/prefill). Returns (out, new_cache)."""
    cd = cfg.cdtype()
    bsz, l, _ = x.shape
    d_inner, h, conv_dim = _dims(cfg)
    zxbcdt = x @ p["in_proj"].astype(cd)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    if cache is not None and cur_len is not None:
        # splice the cached conv window ahead of this segment
        win = cache["conv"].astype(cd)
        xbc_ext = jnp.concatenate([win, xbc], axis=1)
        conv_out = _causal_conv(xbc_ext, p["conv_w"].astype(cd),
                                p["conv_b"].astype(cd))[:, win.shape[1]:]
    else:
        conv_out = _causal_conv(xbc, p["conv_w"].astype(cd),
                                p["conv_b"].astype(cd))
    gn = cfg.ssm_groups * cfg.ssm_state
    xs = conv_out[..., :d_inner].reshape(bsz, l, h, cfg.ssm_head_dim)
    b_mat = conv_out[..., d_inner : d_inner + gn].reshape(
        bsz, l, cfg.ssm_groups, cfg.ssm_state
    )
    c_mat = conv_out[..., d_inner + gn :].reshape(
        bsz, l, cfg.ssm_groups, cfg.ssm_state
    )
    b_mat = _expand_groups(b_mat, h)
    c_mat = _expand_groups(c_mat, h)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"]
    )  # (B,L,H)
    a = -jnp.exp(p["a_log"])  # (H,) negative
    state0 = (
        cache["ssm"] if cache is not None
        else jnp.zeros((bsz, h, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
    )
    y, state = _ssd_chunked(
        cfg, xs.astype(jnp.float32), b_mat.astype(jnp.float32),
        c_mat.astype(jnp.float32), dt, a, state0
    )
    y = y + xs.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, l, d_inner).astype(cd)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"].astype(cd))
    out = y @ p["out_proj"].astype(cd)
    if cache is not None:
        k = cfg.ssm_conv - 1
        win = jnp.concatenate([cache["conv"].astype(cd), xbc], axis=1)[:, -k:]
        cache = {"conv": win.astype(cache["conv"].dtype), "ssm": state}
    return out, cache


def mamba_decode(p, cfg: ModelConfig, x, positions, cache, cur_len):
    """Single-token recurrent step. x: (B,1,d)."""
    cd = cfg.cdtype()
    bsz = x.shape[0]
    d_inner, h, conv_dim = _dims(cfg)
    zxbcdt = x[:, 0] @ p["in_proj"].astype(cd)  # (B, ·)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    # conv: window is the last (k-1) inputs
    win = cache["conv"].astype(cd)  # (B, k-1, C)
    full = jnp.concatenate([win, xbc[:, None, :]], axis=1)  # (B,k,C)
    w = p["conv_w"].astype(cd)
    conv = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", full, w) + p["conv_b"].astype(cd)
    )
    gn = cfg.ssm_groups * cfg.ssm_state
    xt = conv[:, :d_inner].reshape(bsz, h, cfg.ssm_head_dim)
    b_t = _expand_groups(
        conv[:, d_inner : d_inner + gn].reshape(
            bsz, 1, cfg.ssm_groups, cfg.ssm_state),
        h,
    )[:, 0]
    c_t = _expand_groups(
        conv[:, d_inner + gn :].reshape(bsz, 1, cfg.ssm_groups, cfg.ssm_state),
        h,
    )[:, 0]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt * a)  # (B,H); ≤ 1, underflow-safe
    state = cache["ssm"] * da[:, :, None, None] + jnp.einsum(
        "bhp,bhn,bh->bhpn", xt.astype(jnp.float32), b_t.astype(jnp.float32),
        dt,
    )
    y = jnp.einsum("bhn,bhpn->bhp", c_t.astype(jnp.float32), state)
    y = y + xt.astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(bsz, d_inner).astype(cd)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"].astype(cd))
    out = (y @ p["out_proj"].astype(cd))[:, None, :]
    new_cache = {
        "conv": full[:, 1:].astype(cache["conv"].dtype),
        "ssm": state,
    }
    return out, new_cache
