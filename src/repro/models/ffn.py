"""Feed-forward blocks: dense SwiGLU and Mixture-of-Experts.

MoE uses sort-based capacity dispatch — the TPU-native pattern (static
shapes, no per-token gathers of weight matrices):

1. router top-k → (token, expert) assignments;
2. stable-sort assignments by expert, compute each one's slot within its
   expert via counts/cumsum;
3. scatter tokens into an (E, C, d) buffer (slots ≥ capacity drop — standard
   token dropping, capacity_factor controls the drop rate);
4. batched expert einsum (E,C,d)×(E,d,f) — shardable over the expert axis
   (expert parallelism) or the hidden axis (tensor parallelism), chosen by
   the sharding rules' divisibility fallback;
5. gather back, weighted-combine over the k assignments.

Aux losses (load-balance + router-z) are returned to the caller and summed
into the training objective.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.common import KeyGen, ModelConfig, dense_init


def swiglu(x, wg, wi, wo):
    h = jax.nn.silu(x @ wg) * (x @ wi)
    return h @ wo


# ------------------------------------------------------------------- dense
def dense_ffn_init(key, cfg: ModelConfig, d_ff: int | None = None):
    kg = KeyGen(key)
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = cfg.pdtype()
    return {
        "wg": dense_init(kg(), (d, f), dt),
        "wi": dense_init(kg(), (d, f), dt),
        "wo": dense_init(kg(), (f, d), dt),
    }


def dense_ffn_spec(cfg: ModelConfig):
    return {"wg": ("embed", "mlp"), "wi": ("embed", "mlp"),
            "wo": ("mlp", "embed")}


def dense_ffn_forward(p, cfg: ModelConfig, x):
    cd = cfg.cdtype()
    return swiglu(x, p["wg"].astype(cd), p["wi"].astype(cd),
                  p["wo"].astype(cd))


# --------------------------------------------------------------------- MoE
def moe_init(key, cfg: ModelConfig):
    kg = KeyGen(key)
    d, e = cfg.d_model, cfg.n_experts
    f = cfg.moe_d_ff or cfg.d_ff
    dt = cfg.pdtype()
    p = {
        "router": dense_init(kg(), (d, e), jnp.float32),  # fp32 routing
        "wg": dense_init(kg(), (e, d, f), dt, fan_in=d),
        "wi": dense_init(kg(), (e, d, f), dt, fan_in=d),
        "wo": dense_init(kg(), (e, f, d), dt, fan_in=f),
    }
    if cfg.n_shared_experts:
        sf = cfg.shared_d_ff or f * cfg.n_shared_experts
        p["shared"] = dense_ffn_init(kg(), cfg, d_ff=sf)
    return p


def moe_spec(cfg: ModelConfig):
    s = {
        "router": ("embed", None),
        "wg": ("experts", "embed", "expert_mlp"),
        "wi": ("experts", "embed", "expert_mlp"),
        "wo": ("experts", "expert_mlp", "embed"),
    }
    if cfg.n_shared_experts:
        s["shared"] = dense_ffn_spec(cfg)
    return s


def moe_capacity(tokens_per_row: int, cfg: ModelConfig) -> int:
    """Per-row expert capacity. Dispatch is per batch row (see
    ``moe_forward``), so capacity scales with S, not B·S — the (B,E,C,d)
    buffer keeps its sharded batch dim and no global sort/scatter exists.

    Capped at S: top-k experts are DISTINCT per token, so one expert can
    receive at most S tokens from a row. For decode (S=1) this makes the
    capacity exactly 1 — the naive max(8,·) floor wasted 8× expert compute
    and buffer traffic on every decode step of a many-expert model
    (EXPERIMENTS.md §Perf, deepseek decode)."""
    s = tokens_per_row
    tk = s * cfg.n_experts_per_tok
    c = math.ceil(tk / cfg.n_experts * cfg.capacity_factor)
    return min(s, max(8, c))


def _dispatch_row(cfg: ModelConfig, xs, topi, topw, cap: int):
    """One batch row: xs (S,d), topi/topw (S,k) -> (buf (E,C,d),
    e_sorted, pos, order, gate, tok_map (E,C), gate_map (E,C))."""
    s, d = xs.shape
    k = cfg.n_experts_per_tok
    e = cfg.n_experts
    sk = s * k
    eids = topi.reshape(sk)
    tok_ix = jnp.repeat(jnp.arange(s), k)
    gate = topw.reshape(sk)
    order = jnp.argsort(eids, stable=True)
    e_sorted = eids[order]
    counts = jnp.zeros((e,), jnp.int32).at[eids].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(sk, dtype=jnp.int32) - starts[e_sorted]
    x_sorted = xs[tok_ix[order]]
    buf = jnp.zeros((e, cap, d), xs.dtype).at[e_sorted, pos].set(x_sorted)
    # inverse maps for the scatter combine: slot -> (token, gate); dropped
    # slots keep token=s (scattered into a scratch row, discarded)
    tok_map = jnp.full((e, cap), s, jnp.int32).at[e_sorted, pos].set(
        tok_ix[order]
    )
    gate_map = jnp.zeros((e, cap), jnp.float32).at[e_sorted, pos].set(
        gate[order]
    )
    return buf, e_sorted, pos, order, gate, tok_map, gate_map


def _combine_row(y_e, e_sorted, pos, order, gate, s: int, k: int):
    """Inverse of _dispatch_row: y_e (E,C,d) -> (S,d)."""
    d = y_e.shape[-1]
    y_sorted = y_e.at[e_sorted, pos].get(mode="fill", fill_value=0)
    y_assign = jnp.zeros((s * k, d), y_e.dtype).at[order].set(y_sorted)
    return (y_assign * gate[:, None].astype(y_e.dtype)).reshape(
        s, k, d
    ).sum(axis=1)


def moe_forward(p, cfg: ModelConfig, x):
    """x: (B,S,d) -> (y, aux_losses dict). Per-row capacity dispatch."""
    cd = cfg.cdtype()
    b, s, d = x.shape
    k = cfg.n_experts_per_tok
    e = cfg.n_experts
    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (B,S,E)
    topw, topi = jax.lax.top_k(probs, k)  # (B,S,k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    # ---- aux losses (fp32 router path, global statistics)
    assign_frac = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(1.0)
    assign_frac = assign_frac / (b * s * k)
    mean_prob = probs.reshape(-1, e).mean(axis=0)
    aux = {
        "load_balance": e * jnp.sum(assign_frac * mean_prob),
        "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
    }
    cap = moe_capacity(s, cfg)
    buf, e_sorted, pos, order, gate, tok_map, gate_map = jax.vmap(
        lambda xs, ti, tw: _dispatch_row(cfg, xs, ti, tw, cap)
    )(x.astype(cd), topi, topw)
    if cfg.moe_constrain == "be":
        buf = constrain(buf, "batch", "experts", None, None)
    # ---- batched expert SwiGLU: (B,E,C,d) x (E,d,f)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["wg"].astype(cd)))
    h = h * jnp.einsum("becd,edf->becf", buf, p["wi"].astype(cd))
    y_e = jnp.einsum("becf,efd->becd", h, p["wo"].astype(cd))
    if cfg.moe_constrain == "be":
        y_e = constrain(y_e, "batch", "experts", None, None)
    if cfg.moe_combine == "scatter":
        # expert-major combine: weight in expert space, scatter-add into
        # token space. With E sharded, each shard contributes a partial
        # (B,S,d) sum and XLA reduces partials with ONE all-reduce of
        # B·S·d — instead of all-gathering the (B,E,C,d) expert outputs
        # (≈ E·C/S·k ≈ capacity_factor·k × larger) for a per-token gather.
        yw = y_e * gate_map[..., None].astype(cd)

        def comb(ye_row, tmap_row):
            return jnp.zeros((s + 1, d), ye_row.dtype).at[
                tmap_row.reshape(-1)
            ].add(ye_row.reshape(-1, d))[:s]

        y = jax.vmap(comb)(yw, tok_map)
    else:  # "gather": the naive inverse-permutation path
        y = jax.vmap(
            lambda ye, es, po, od, ga: _combine_row(ye, es, po, od, ga, s, k)
        )(y_e, e_sorted, pos, order, gate)
    if cfg.n_shared_experts:
        y = y + dense_ffn_forward(p["shared"], cfg, x)
    return y.reshape(b, s, d), aux
