"""LM assembly: embeddings → scanned layer segments → norm → logits.

An architecture is a sequence of ``LayerPattern`` segments; each segment is
``repeat`` copies of a *block* of sub-layers ((mixer, ffn) pairs) whose
parameters are stacked on a leading layer axis and driven by ``lax.scan`` —
one HLO body per segment regardless of depth (61–80-layer configs compile in
seconds instead of minutes, and remat applies per-block).

Three modes share the block code:
* ``train``   — full sequence, no cache;
* ``prefill`` — full sequence, writes a fixed-capacity cache;
* ``decode``  — S=1 against the cache (MLA uses the absorbed path, Mamba the
  recurrent path).

Caches are pytrees mirroring the segment structure with a leading repeat
axis, so the same ``lax.scan`` threads them.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain
from repro.models import attention as A
from repro.models import ffn as F
from repro.models import mamba2 as M
from repro.models.common import (
    KeyGen,
    ModelConfig,
    embed_init,
    rmsnorm,
    rmsnorm_init,
)

MIXERS = {"gqa", "mla", "mamba"}
FFNS = {"dense", "moe"}


# ------------------------------------------------------------------- init
def _sublayer_init(key, cfg: ModelConfig, mixer: str | None, ffn: str | None):
    kg = KeyGen(key)
    p: dict[str, Any] = {}
    if mixer == "gqa":
        p["mixer_norm"] = rmsnorm_init(cfg.d_model, cfg.pdtype())
        p["mixer"] = A.gqa_init(kg(), cfg)
    elif mixer == "mla":
        p["mixer_norm"] = rmsnorm_init(cfg.d_model, cfg.pdtype())
        p["mixer"] = A.mla_init(kg(), cfg)
    elif mixer == "mamba":
        p["mixer_norm"] = rmsnorm_init(cfg.d_model, cfg.pdtype())
        p["mixer"] = M.mamba_init(kg(), cfg)
    if ffn == "dense":
        p["ffn_norm"] = rmsnorm_init(cfg.d_model, cfg.pdtype())
        p["ffn"] = F.dense_ffn_init(kg(), cfg)
    elif ffn == "moe":
        p["ffn_norm"] = rmsnorm_init(cfg.d_model, cfg.pdtype())
        p["ffn"] = F.moe_init(kg(), cfg)
    return p


def _sublayer_spec(cfg: ModelConfig, mixer: str | None, ffn: str | None):
    s: dict[str, Any] = {}
    if mixer in ("gqa", "mla", "mamba"):
        s["mixer_norm"] = (None,)
        s["mixer"] = {
            "gqa": A.gqa_spec, "mla": A.mla_spec, "mamba": M.mamba_spec
        }[mixer](cfg)
    if ffn == "dense":
        s["ffn_norm"] = (None,)
        s["ffn"] = F.dense_ffn_spec(cfg)
    elif ffn == "moe":
        s["ffn_norm"] = (None,)
        s["ffn"] = F.moe_spec(cfg)
    return s


def _block_init(key, cfg: ModelConfig, block):
    kg = KeyGen(key)
    return {
        f"sub{j}": _sublayer_init(kg(), cfg, mixer, ffn)
        for j, (mixer, ffn) in enumerate(block)
    }


def init_params(cfg: ModelConfig, key) -> dict:
    kg = KeyGen(key)
    params: dict[str, Any] = {}
    if not cfg.embed_inputs:
        params["embed"] = embed_init(kg(), (cfg.vocab, cfg.d_model),
                                     cfg.pdtype())
    segs = []
    for pat in cfg.patterns:
        keys = jax.random.split(kg(), pat.repeat)
        segs.append(jax.vmap(
            functools.partial(_block_init, cfg=cfg, block=pat.block)
        )(keys))
    params["segments"] = segs
    params["final_norm"] = rmsnorm_init(cfg.d_model, cfg.pdtype())
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(kg(), (cfg.d_model, cfg.vocab),
                                       cfg.pdtype())
    return params


def param_specs(cfg: ModelConfig) -> dict:
    specs: dict[str, Any] = {}
    if not cfg.embed_inputs:
        specs["embed"] = ("vocab", "embed")
    segs = []
    for pat in cfg.patterns:
        blk = {
            f"sub{j}": _sublayer_spec(cfg, mixer, ffn)
            for j, (mixer, ffn) in enumerate(pat.block)
        }
        # leading stacked-layer axis
        segs.append(jax.tree_util.tree_map(
            lambda t: ("layers",) + t,
            blk,
            is_leaf=lambda t: isinstance(t, tuple),
        ))
    specs["segments"] = segs
    specs["final_norm"] = (None,)
    if not cfg.tie_embeddings:
        specs["unembed"] = ("embed", "vocab")
    return specs


# ------------------------------------------------------------------ cache
def init_cache(cfg: ModelConfig, batch: int, dtype=None) -> list:
    dtype = dtype or cfg.cdtype()
    segs = []
    for pat in cfg.patterns:
        blk = {}
        for j, (mixer, _ffn) in enumerate(pat.block):
            if mixer == "gqa":
                c = A.gqa_cache_init(cfg, batch, dtype)
            elif mixer == "mla":
                c = A.mla_cache_init(cfg, batch, dtype)
            elif mixer == "mamba":
                c = M.mamba_cache_init(cfg, batch, dtype)
            else:
                continue
            blk[f"sub{j}"] = c
        segs.append(jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (pat.repeat,) + x.shape),
            blk,
        ))
    return segs


def cache_specs(cfg: ModelConfig) -> list:
    segs = []
    for pat in cfg.patterns:
        blk = {}
        for j, (mixer, _ffn) in enumerate(pat.block):
            if mixer == "gqa":
                s = A.gqa_cache_spec(cfg)
            elif mixer == "mla":
                s = A.mla_cache_spec(cfg)
            elif mixer == "mamba":
                s = M.mamba_cache_spec(cfg)
            else:
                continue
            blk[f"sub{j}"] = s
        segs.append(jax.tree_util.tree_map(
            lambda t: ("layers",) + t,
            blk,
            is_leaf=lambda t: isinstance(t, tuple),
        ))
    return segs


# ---------------------------------------------------------------- forward
def _mixer_apply(mixer: str, mode: str):
    if mixer == "gqa":
        return A.gqa_forward  # full attend handles decode via cache
    if mixer == "mla":
        return A.mla_forward if mode != "decode" else A.mla_decode
    if mixer == "mamba":
        return M.mamba_forward if mode != "decode" else M.mamba_decode
    raise ValueError(mixer)


def _block_apply(cfg: ModelConfig, block, mode: str):
    """Returns body(x, positions, cur_len, blk_params, blk_cache) ->
    (x, aux_lb, aux_rz, new_cache)."""

    def body(x, positions, cur_len, blk_params, blk_cache):
        lb = jnp.zeros((), jnp.float32)
        rz = jnp.zeros((), jnp.float32)
        new_cache = {}
        for j, (mixer, ffn) in enumerate(block):
            p = blk_params[f"sub{j}"]
            if mixer in MIXERS:
                h = rmsnorm(x, p["mixer_norm"].astype(x.dtype))
                c = blk_cache.get(f"sub{j}") if blk_cache else None
                fn = _mixer_apply(mixer, mode)
                y, c2 = fn(p["mixer"], cfg, h, positions, c, cur_len)
                x = x + y
                if c is not None:
                    new_cache[f"sub{j}"] = c2
            if ffn in FFNS:
                h = rmsnorm(x, p["ffn_norm"].astype(x.dtype))
                if ffn == "dense":
                    y = F.dense_ffn_forward(p["ffn"], cfg, h)
                else:
                    y, aux = F.moe_forward(p["ffn"], cfg, h)
                    lb = lb + aux["load_balance"]
                    rz = rz + aux["router_z"]
                x = x + y
            x = constrain(x, "batch", None, None)
        return x, lb, rz, new_cache

    return body


def forward(
    params: dict, cfg: ModelConfig, batch: dict, mode: str = "train",
    cache: list | None = None, cur_len=None,
):
    """Returns (logits (B,S,V) fp32, aux dict, new_cache)."""
    cd = cfg.cdtype()
    if cfg.embed_inputs:
        x = batch["embeds"].astype(cd)
    else:
        x = params["embed"].astype(cd)[batch["tokens"]]
    if cfg.extra_embed_len and mode != "decode":
        x = jnp.concatenate([batch["patches"].astype(cd), x], axis=1)
    b, s, _ = x.shape
    x = constrain(x, "batch", None, None)
    if mode == "decode":
        positions = jnp.broadcast_to(
            jnp.asarray(cur_len, jnp.int32)[None, None], (b, 1)
        )
    else:
        positions = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None, :], (b, s)
        )
        if cur_len is None and mode == "prefill":
            cur_len = 0
    lb = jnp.zeros((), jnp.float32)
    rz = jnp.zeros((), jnp.float32)
    new_cache: list | None = [] if cache is not None else None
    for si, pat in enumerate(cfg.patterns):
        body = _block_apply(cfg, pat.block, mode)
        seg_p = params["segments"][si]
        seg_c = cache[si] if cache is not None else None

        if cfg.scan_unroll:
            blk_fn = body
            if cfg.remat != "none":
                blk_fn = jax.checkpoint(body, policy=_remat_policy(cfg.remat))
            ncs = []
            for i in range(pat.repeat):
                bp = jax.tree_util.tree_map(lambda a: a[i], seg_p)
                bc = (
                    jax.tree_util.tree_map(lambda a: a[i], seg_c)
                    if seg_c is not None else None
                )
                x, l2, r2, nc = blk_fn(x, positions, cur_len, bp, bc)
                lb, rz = lb + l2, rz + r2
                ncs.append(nc)
            if seg_c is not None:
                new_cache.append(
                    jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ncs)
                )
            continue

        if seg_c is not None:
            def step(carry, xs):
                xx, l1, r1 = carry
                bp, bc = xs
                xx, l2, r2, nc = body(xx, positions, cur_len, bp, bc)
                return (xx, l1 + l2, r1 + r2), nc

            if cfg.remat != "none":
                step = jax.checkpoint(
                    step, policy=_remat_policy(cfg.remat)
                )
            (x, lb, rz), nc = jax.lax.scan(step, (x, lb, rz), (seg_p, seg_c))
            new_cache.append(nc)
        else:
            def step(carry, bp):
                xx, l1, r1 = carry
                xx, l2, r2, _ = body(xx, positions, cur_len, bp, None)
                return (xx, l1 + l2, r1 + r2), None

            if cfg.remat != "none":
                step = jax.checkpoint(
                    step, policy=_remat_policy(cfg.remat)
                )
            (x, lb, rz), _ = jax.lax.scan(step, (x, lb, rz), seg_p)
    x = rmsnorm(x, params["final_norm"].astype(cd))
    if cfg.tie_embeddings:
        unembed = params["embed"].T
    else:
        unembed = params["unembed"]
    logits = (x @ unembed.astype(cd)).astype(jnp.float32)
    # vocab-shard the logits: (B,S,V) fp32 replicated over model would be
    # the largest activation in every train cell (e.g. 34 GiB/device for
    # deepseek train_4k); the CE loss reduces over the sharded V cleanly
    logits = constrain(logits, "batch", None, "vocab")
    aux = {"load_balance": lb, "router_z": rz}
    return logits, aux, new_cache


def _remat_policy(kind: str):
    if kind == "full":
        return jax.checkpoint_policies.nothing_saveable
    if kind == "dots":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    raise ValueError(kind)


# ------------------------------------------------------------------- loss
def lm_loss(cfg: ModelConfig, logits, tokens):
    """Next-token CE. ``tokens``: the text token ids (B,S). Handles the
    vlm case where ``extra_embed_len`` patch positions are prepended."""
    p = cfg.extra_embed_len
    if p:
        preds = logits[:, p - 1 : p - 1 + tokens.shape[1]]
        targets = tokens
    else:
        preds = logits[:, :-1]
        targets = tokens[:, 1:]
    logz = jax.nn.logsumexp(preds, axis=-1)
    ll = jnp.take_along_axis(preds, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - ll)


def train_loss(params, cfg: ModelConfig, batch):
    logits, aux, _ = forward(params, cfg, batch, mode="train")
    tokens = batch.get("labels", batch.get("tokens"))
    loss = lm_loss(cfg, logits, tokens)
    total = (
        loss
        + cfg.router_aux_coef * aux["load_balance"]
        + cfg.router_z_coef * aux["router_z"]
    )
    metrics = {"ce": loss, **aux}
    return total, metrics


# ------------------------------------------------------------ param count
def param_count(cfg: ModelConfig) -> int:
    """Total parameters, computed analytically from the config."""
    shapes = jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.PRNGKey(0)
    )
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(shapes))


def active_param_count(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: top-k of routed experts)."""
    total = param_count(cfg)
    if not cfg.n_experts:
        return total
    f = cfg.moe_d_ff or cfg.d_ff
    per_expert = 3 * cfg.d_model * f
    moe_layers = sum(
        pat.repeat * sum(1 for (_m, fn) in pat.block if fn == "moe")
        for pat in cfg.patterns
    )
    inactive = (
        moe_layers * (cfg.n_experts - cfg.n_experts_per_tok) * per_expert
    )
    return total - inactive
