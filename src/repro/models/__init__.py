from repro.models.common import LayerPattern, ModelConfig  # noqa: F401
from repro.models.model import (  # noqa: F401
    active_param_count,
    cache_specs,
    forward,
    init_cache,
    init_params,
    lm_loss,
    param_count,
    param_specs,
    train_loss,
)
