"""Attention mixers: GQA/MHA and MLA (DeepSeek-style multi-head latent).

Both support three modes through one code path:

* train/forward — full sequence, causal, no cache;
* prefill — full sequence, causal, returns the populated KV cache;
* decode — S=1 with absolute positions against a fixed-capacity cache.

The score/weighted-sum core (``attend``) has an optional *chunked
online-softmax* path (``attn_chunk``) that scans KV blocks with running
(max, denom, acc) — O(S·C) live memory instead of O(S²) — required for the
32K/500K shapes.

MLA decode uses the *weight-absorbed* form: queries are projected into the
compressed KV space (q·W_uk), attention runs against the (kv_lora + rope)
cache directly, and values are re-expanded after the weighted sum — the
cache stays at (kv_lora + rope) per token regardless of head count, which is
the whole point of MLA.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.common import (
    KeyGen,
    ModelConfig,
    apply_rope,
    dense_init,
    rmsnorm,
    rmsnorm_init,
)

NEG_INF = -1e30


# ------------------------------------------------------------------- core
def _attend_full(q, k, v, mask, scale):
    """q:(B,S,N,G,D) k:(B,T,N,D) v:(B,T,N,Dv) mask:(B,S,T) -> (B,S,N,G,Dv)."""
    scores = jnp.einsum("bsngd,btnd->bngst", q, k).astype(jnp.float32) * scale
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bngst,btnd->bsngd", w, v)


def _attend_chunked(q, k, v, q_pos, k_pos, scale, chunk: int,
                    unroll: bool = False):
    """Flash-style double chunking: sequential q blocks (lax.map), online
    softmax over kv blocks (lax.scan). Live memory is one (qc × kc) score
    tile per head — O(S²) never materializes.

    ``unroll=True`` replaces both loops with python loops so that
    ``cost_analysis`` (which counts scan bodies once) sees every tile —
    used only by the dry-run depth-analysis variants.

    q:(B,S,N,G,D) k:(B,T,N,D) v:(B,T,N,Dv) q_pos:(B,S) k_pos:(B,T).
    """
    b, t, n, dv = v.shape
    s, g = q.shape[1], q.shape[3]
    kc = chunk
    qc = min(chunk, s)
    pad_t = (-t) % kc
    if pad_t:
        k = jnp.pad(k, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad_t)), constant_values=2**30)
    pad_s = (-s) % qc
    if pad_s:
        q = jnp.pad(q, ((0, 0), (0, pad_s), (0, 0), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_s)), constant_values=-1)
    nkb = k.shape[1] // kc
    nqb = q.shape[1] // qc
    kb = k.reshape(b, nkb, kc, n, k.shape[-1]).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nkb, kc, n, dv).transpose(1, 0, 2, 3, 4)
    kpb = k_pos.reshape(b, nkb, kc).transpose(1, 0, 2)
    qb = q.reshape(b, nqb, qc, n, g, q.shape[-1]).transpose(1, 0, 2, 3, 4, 5)
    qpb = q_pos.reshape(b, nqb, qc).transpose(1, 0, 2)

    def one_q_block(args):
        qi, qpi = args  # (B,qc,N,G,D), (B,qc)

        def step(carry, blk):
            m, l, acc = carry  # (B,N,G,qc) ×2, (B,qc,N,G,Dv)
            kci, vci, kpi = blk
            sc = jnp.einsum(
                "bsngd,btnd->bngst", qi, kci
            ).astype(jnp.float32) * scale
            msk = kpi[:, None, :] <= qpi[:, :, None]  # (B,qc,kc)
            sc = jnp.where(msk.transpose(0, 1, 2)[:, None, None], sc, NEG_INF)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bngst,btnd->bsngd", p.astype(vci.dtype), vci)
            acc_new = (
                acc * corr.transpose(0, 3, 1, 2)[..., None].astype(acc.dtype)
                + pv
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, n, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, n, g, qc), jnp.float32)
        a0 = jnp.zeros((b, qc, n, g, dv), v.dtype)
        carry = (m0, l0, a0)
        if unroll:
            for i in range(nkb):
                carry, _ = step(carry, (kb[i], vb[i], kpb[i]))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(step, carry, (kb, vb, kpb))
        denom = jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return (acc / denom.astype(acc.dtype)).astype(v.dtype)

    if unroll:
        out = jnp.stack([one_q_block((qb[i], qpb[i])) for i in range(nqb)])
    else:
        out = jax.lax.map(one_q_block, (qb, qpb))  # (nqb,B,qc,N,G,Dv)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, nqb * qc, n, g, dv)
    return out[:, :s]


def attend(q, k, v, q_pos, k_pos, *, scale: float, chunk: int = 0,
           unroll: bool = False):
    """Grouped causal attention.

    q: (B,S,H,D) with H = N·G query heads; k: (B,T,N,D); v: (B,T,N,Dv);
    q_pos: (B,S) absolute positions; k_pos: (T,) or (B,T).
    """
    b, s, h, d = q.shape
    n = k.shape[2]
    g = h // n
    qg = q.reshape(b, s, n, g, d)
    if k_pos.ndim == 1:
        k_pos = jnp.broadcast_to(k_pos[None, :], (b, k_pos.shape[0]))
    if chunk and k.shape[1] > chunk:
        out = _attend_chunked(qg, k, v, q_pos, k_pos, scale, chunk,
                              unroll=unroll)
    else:
        mask = k_pos[:, None, :] <= q_pos[:, :, None]  # causal, absolute
        out = _attend_full(qg, k, v, mask, scale)
    return out.reshape(b, s, h, v.shape[-1])


# -------------------------------------------------------------------- GQA
def gqa_init(key, cfg: ModelConfig):
    kg = KeyGen(key)
    d, h, nkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = cfg.pdtype()
    p = {
        "wq": dense_init(kg(), (d, h, hd), dt, fan_in=d),
        "wk": dense_init(kg(), (d, nkv, hd), dt, fan_in=d),
        "wv": dense_init(kg(), (d, nkv, hd), dt, fan_in=d),
        "wo": dense_init(kg(), (h, hd, d), dt, fan_in=h * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dt)
        p["bk"] = jnp.zeros((nkv, hd), dt)
        p["bv"] = jnp.zeros((nkv, hd), dt)
    return p


def gqa_spec(cfg: ModelConfig):
    s = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.qkv_bias:
        s["bq"] = ("heads", "head_dim")
        s["bk"] = ("kv_heads", "head_dim")
        s["bv"] = ("kv_heads", "head_dim")
    return s


def gqa_cache_init(cfg: ModelConfig, batch: int, dtype):
    t = cfg.max_cache_len
    return {
        "k": jnp.zeros((batch, t, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((batch, t, cfg.n_kv_heads, cfg.hd), dtype),
    }


def gqa_cache_spec(cfg: ModelConfig):
    return {
        "k": ("batch", "cache_len", "kv_heads", "head_dim"),
        "v": ("batch", "cache_len", "kv_heads", "head_dim"),
    }


def gqa_forward(p, cfg: ModelConfig, x, positions, cache=None, cur_len=None):
    """x: (B,S,d). cache: dict or None. cur_len: scalar write offset.

    Returns (out (B,S,d), new_cache_or_None).
    """
    b, s, _ = x.shape
    cd = cfg.cdtype()
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dnk->bsnk", x, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dnk->bsnk", x, p["wv"].astype(cd))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    q = apply_rope(q.swapaxes(1, 2), positions[:, None, :], cfg.rope_theta).swapaxes(1, 2)
    k = apply_rope(k.swapaxes(1, 2), positions[:, None, :], cfg.rope_theta).swapaxes(1, 2)
    # pin head sharding: rope's trig chain can drop the propagated sharding
    # and SPMD then replicates the whole attention (EXPERIMENTS.md §Perf)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    if cache is not None:
        off = cur_len if cur_len is not None else 0
        kc = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, off, 0, 0)
        )
        vc = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, off, 0, 0)
        )
        cache = {"k": kc, "v": vc}
        k_all, v_all = kc.astype(cd), vc.astype(cd)
        k_pos = jnp.arange(kc.shape[1], dtype=positions.dtype)
    else:
        k_all, v_all = k, v
        k_pos = positions if positions.ndim == 1 else positions[0]
    scale = 1.0 / (cfg.hd ** 0.5)
    out = attend(q, k_all, v_all, positions, k_pos, scale=scale,
                 chunk=cfg.attn_chunk, unroll=cfg.scan_unroll)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))
    return y, cache


# -------------------------------------------------------------------- MLA
def mla_init(key, cfg: ModelConfig):
    kg = KeyGen(key)
    d, h = cfg.d_model, cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dt = cfg.pdtype()
    p = {}
    if cfg.q_lora_rank:
        p["w_dq"] = dense_init(kg(), (d, cfg.q_lora_rank), dt)
        p["q_norm"] = rmsnorm_init(cfg.q_lora_rank, dt)
        p["w_uq"] = dense_init(
            kg(), (cfg.q_lora_rank, h, nope + rope_d), dt, fan_in=cfg.q_lora_rank
        )
    else:
        p["w_q"] = dense_init(kg(), (d, h, nope + rope_d), dt, fan_in=d)
    p["w_dkv"] = dense_init(kg(), (d, cfg.kv_lora_rank + rope_d), dt)
    p["kv_norm"] = rmsnorm_init(cfg.kv_lora_rank, dt)
    p["w_uk"] = dense_init(
        kg(), (cfg.kv_lora_rank, h, nope), dt, fan_in=cfg.kv_lora_rank
    )
    p["w_uv"] = dense_init(
        kg(), (cfg.kv_lora_rank, h, vd), dt, fan_in=cfg.kv_lora_rank
    )
    p["wo"] = dense_init(kg(), (h, vd, d), dt, fan_in=h * vd)
    return p


def mla_spec(cfg: ModelConfig):
    s = {
        "w_dkv": ("embed", "lora"),
        "kv_norm": (None,),
        "w_uk": ("lora", "heads", "head_dim"),
        "w_uv": ("lora", "heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.q_lora_rank:
        s["w_dq"] = ("embed", "lora")
        s["q_norm"] = (None,)
        s["w_uq"] = ("lora", "heads", "head_dim")
    else:
        s["w_q"] = ("embed", "heads", "head_dim")
    return s


def mla_cache_init(cfg: ModelConfig, batch: int, dtype):
    t = cfg.max_cache_len
    return {
        "ckv": jnp.zeros((batch, t, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, t, cfg.qk_rope_dim), dtype),
    }


def mla_cache_spec(cfg: ModelConfig):
    return {
        "ckv": ("batch", "cache_len", "lora"),
        "krope": ("batch", "cache_len", "head_dim"),
    }


def _mla_queries(p, cfg: ModelConfig, x, positions, cd):
    if cfg.q_lora_rank:
        cq = rmsnorm(x @ p["w_dq"].astype(cd), p["q_norm"].astype(cd))
        q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"].astype(cd))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["w_q"].astype(cd))
    q_nope = q[..., : cfg.qk_nope_dim]
    q_rope = apply_rope(
        q[..., cfg.qk_nope_dim :].swapaxes(1, 2), positions[:, None, :],
        cfg.rope_theta,
    ).swapaxes(1, 2)
    return q_nope, q_rope


def _mla_compress(p, cfg: ModelConfig, x, positions, cd):
    ckv_full = x @ p["w_dkv"].astype(cd)
    ckv = rmsnorm(ckv_full[..., : cfg.kv_lora_rank], p["kv_norm"].astype(cd))
    krope = apply_rope(
        ckv_full[..., cfg.kv_lora_rank :], positions, cfg.rope_theta
    )  # (B,S,rope) — one shared rope key head (DeepSeek-V2/V3)
    return ckv, krope


def mla_forward(p, cfg: ModelConfig, x, positions, cache=None, cur_len=None):
    """Naive-expand path used for train/prefill. Returns (out, new_cache)."""
    cd = cfg.cdtype()
    q_nope, q_rope = _mla_queries(p, cfg, x, positions, cd)
    ckv, krope = _mla_compress(p, cfg, x, positions, cd)
    if cache is not None:
        off = cur_len if cur_len is not None else 0
        cc = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, off, 0)
        )
        kr = jax.lax.dynamic_update_slice(
            cache["krope"], krope.astype(cache["krope"].dtype), (0, off, 0)
        )
        cache = {"ckv": cc, "krope": kr}
    k_nope = jnp.einsum("btr,rhk->bthk", ckv, p["w_uk"].astype(cd))
    v = jnp.einsum("btr,rhk->bthk", ckv, p["w_uv"].astype(cd))
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope[:, :, None, :], k_nope.shape[:3] + (cfg.qk_rope_dim,))],
        axis=-1,
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    # pin head sharding: the shared-rope broadcast + concat makes the head
    # dim look "produced by broadcast" to SPMD, which then replicates the
    # entire attention (a 1 TiB/step all-gather on deepseek prefill before
    # this constraint — EXPERIMENTS.md §Perf)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "heads", None)
    v = constrain(v, "batch", None, "heads", None)
    scale = 1.0 / ((cfg.qk_nope_dim + cfg.qk_rope_dim) ** 0.5)
    out = attend(q, k, v, positions, positions if positions.ndim == 1 else positions[0],
                 scale=scale, chunk=cfg.attn_chunk, unroll=cfg.scan_unroll)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))
    return y, cache


def mla_decode(p, cfg: ModelConfig, x, positions, cache, cur_len):
    """Weight-absorbed decode: attention in compressed-KV space."""
    cd = cfg.cdtype()
    b, s, _ = x.shape
    q_nope, q_rope = _mla_queries(p, cfg, x, positions, cd)
    ckv, krope = _mla_compress(p, cfg, x, positions, cd)
    cc = jax.lax.dynamic_update_slice(
        cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, cur_len, 0)
    )
    kr = jax.lax.dynamic_update_slice(
        cache["krope"], krope.astype(cache["krope"].dtype), (0, cur_len, 0)
    )
    cache = {"ckv": cc, "krope": kr}
    # absorb W_uk into the query: q_c = q_nope · W_uk  -> (B,S,H,R)
    q_c = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"].astype(cd))
    t = cc.shape[1]
    k_pos = jnp.arange(t, dtype=positions.dtype)
    mask = k_pos[None, None, :] <= positions[:, :, None]  # (B,S,T)
    scale = 1.0 / ((cfg.qk_nope_dim + cfg.qk_rope_dim) ** 0.5)
    sc = (
        jnp.einsum("bshr,btr->bhst", q_c, cc.astype(cd))
        + jnp.einsum("bshk,btk->bhst", q_rope, kr.astype(cd))
    ).astype(jnp.float32) * scale
    sc = jnp.where(mask[:, None, :, :], sc, NEG_INF)
    w = jax.nn.softmax(sc, axis=-1).astype(cd)
    ctx_c = jnp.einsum("bhst,btr->bshr", w, cc.astype(cd))  # compressed ctx
    out = jnp.einsum("bshr,rhk->bshk", ctx_c, p["w_uv"].astype(cd))
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))
    return y, cache
