"""Failure detection + straggler mitigation (pure logic; the launcher wires
it to real heartbeats in a deployment, tests drive it synthetically).

* ``HeartbeatMonitor`` — per-host last-seen tracking with a timeout; the
  same primitive VAULT's chunk groups use for persistence claims, reused at
  the job-control layer for host liveness.
* ``StragglerDetector`` — per-host EWMA of step durations. A host whose
  EWMA exceeds ``threshold ×`` the fleet median is flagged; policy:
  "warn" → log only; "drop" → recommend elastic restart without the host
  (synchronous data-parallel steps are gated by the slowest host, so one
  2× straggler halves fleet goodput — dropping 1/256 hosts costs 0.4%
  throughput and returns ~50%).
"""
from __future__ import annotations

import dataclasses


class HeartbeatMonitor:
    def __init__(self, timeout_s: float):
        self.timeout_s = timeout_s
        self._last: dict[str, float] = {}

    def beat(self, host: str, now: float) -> None:
        self._last[host] = now

    def alive(self, now: float) -> list[str]:
        return [h for h, t in self._last.items()
                if now - t <= self.timeout_s]

    def dead(self, now: float) -> list[str]:
        return [h for h, t in self._last.items() if now - t > self.timeout_s]


@dataclasses.dataclass
class StragglerDecision:
    host: str
    ewma_s: float
    median_ewma_s: float
    action: str  # "ok" | "warn" | "drop"


class StragglerDetector:
    def __init__(self, alpha: float = 0.2, warn_factor: float = 1.5,
                 drop_factor: float = 2.5, min_samples: int = 5):
        self.alpha = alpha
        self.warn_factor = warn_factor
        self.drop_factor = drop_factor
        self.min_samples = min_samples
        self._ewma: dict[str, float] = {}
        self._count: dict[str, int] = {}

    def record(self, host: str, step_s: float) -> None:
        prev = self._ewma.get(host)
        self._ewma[host] = (
            step_s if prev is None
            else self.alpha * step_s + (1 - self.alpha) * prev
        )
        self._count[host] = self._count.get(host, 0) + 1

    def median(self) -> float:
        vals = sorted(self._ewma.values())
        if not vals:
            return 0.0
        n = len(vals)
        return vals[n // 2] if n % 2 else 0.5 * (vals[n // 2 - 1] + vals[n // 2])

    def decisions(self) -> list[StragglerDecision]:
        med = self.median()
        out = []
        for host, e in self._ewma.items():
            if self._count[host] < self.min_samples or med == 0.0:
                action = "ok"
            elif e > self.drop_factor * med:
                action = "drop"
            elif e > self.warn_factor * med:
                action = "warn"
            else:
                action = "ok"
            out.append(StragglerDecision(host, e, med, action))
        return out

    def to_drop(self) -> list[str]:
        return [d.host for d in self.decisions() if d.action == "drop"]
