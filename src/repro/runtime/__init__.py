from repro.runtime.failure import HeartbeatMonitor, StragglerDetector  # noqa: F401
from repro.runtime.elastic import plan_mesh, reshard_state  # noqa: F401
