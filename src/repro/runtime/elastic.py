"""Elastic re-meshing: resume a job on a different device count.

The restart path after host loss (or a straggler drop):

  1. the latest Vault checkpoint is QUERYed (survives the lost hosts by
     construction — that is the paper's guarantee);
  2. ``plan_mesh`` picks a (data, model) factorization of the surviving
     device count;
  3. ``reshard_state`` re-places the host-resident state onto the new mesh
     using the same logical rules — the divisibility fallback makes every
     intermediate mesh compilable (DESIGN.md §6);
  4. the data pipeline resumes from the checkpointed step cursor
     (bit-identical batches — ``data.pipeline`` is a pure function of step).

Global batch is preserved (gradient accumulation increases per-device work
on smaller meshes), so training curves are comparable across re-meshes.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shd


def plan_mesh(n_devices: int, prefer_model: int = 0) -> tuple[int, int]:
    """Largest (data, model) grid with model | prefer_model if given.

    model axis defaults to the largest power-of-two divisor ≤ √n that also
    divides ``prefer_model`` (typically the head count) when provided.
    """
    best = (n_devices, 1)
    m = 1
    while True:
        nxt = m * 2
        if n_devices % nxt != 0:
            break
        if prefer_model and prefer_model % nxt != 0:
            break
        if nxt > n_devices:
            break
        m = nxt
        if m * m >= n_devices:
            break
    return (n_devices // m, m)


def state_shardings(spec_tree, shapes, mesh: Mesh, rules=None):
    resolved = shd.tree_specs(spec_tree, shapes, mesh, rules)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), resolved,
        is_leaf=lambda t: isinstance(t, P),
    )


def reshard_state(state_host, shardings):
    """Place host (numpy) state onto devices per ``shardings``."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(np.asarray(x), s), state_host, shardings
    )
