"""GF(256) and GF(2) arithmetic used by VAULT's rateless codes.

Two multiply implementations are provided:

* table-based (log/exp) — fast on host, used by the pure-jnp/numpy reference
  paths and by the Gaussian-elimination decoder;
* bit-sliced Russian-peasant — 8 rounds of AND/XOR/shift, no gathers, the
  form used inside the Pallas TPU kernels (VPU-friendly).

Field: GF(2^8) with the AES-adjacent primitive polynomial x^8+x^4+x^3+x^2+1
(0x11D), generator 2 — the same field wirehair uses.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

GF_POLY = 0x11D  # x^8 + x^4 + x^3 + x^2 + 1 (primitive)
GF_GEN = 2


def _build_tables() -> tuple[np.ndarray, np.ndarray]:
    exp = np.zeros(512, dtype=np.uint8)  # doubled to avoid mod-255 in mul
    log = np.zeros(256, dtype=np.int32)
    x = 1
    for i in range(255):
        exp[i] = x
        log[x] = i
        x <<= 1
        if x & 0x100:
            x ^= GF_POLY
    exp[255:510] = exp[:255]
    return exp, log


GF_EXP, GF_LOG = _build_tables()


# ---------------------------------------------------------------- table path
def gf_mul_np(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Elementwise GF(256) multiply via log/exp tables (numpy, host)."""
    a = np.asarray(a, dtype=np.uint8)
    b = np.asarray(b, dtype=np.uint8)
    out = GF_EXP[GF_LOG[a] + GF_LOG[b]]
    return np.where((a == 0) | (b == 0), np.uint8(0), out)


def gf_inv_np(a: np.ndarray) -> np.ndarray:
    a = np.asarray(a, dtype=np.uint8)
    if np.any(a == 0):
        raise ZeroDivisionError("gf_inv(0)")
    return GF_EXP[255 - GF_LOG[a]]


def gf_div_np(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return gf_mul_np(a, gf_inv_np(b))


# Sentinel log/exp pair for the fused matmul below: log 0 is pushed to
# 1020, past every reachable true-log sum (max 254 + 254 = 508), and the
# exp table maps the whole sentinel range to 0 — so one gather computes
# exp[log a + log b] with GF(256) zero-propagation built in, no masks.
_LOG_S = GF_LOG.astype(np.int32).copy()
_LOG_S[0] = 1020
_EXP_S = np.zeros(2048, np.uint8)  # max index 1020 + 1020 = 2040
_EXP_S[:510] = GF_EXP[:510]


def gf_matmul_np(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """GF(256) matmul: (m,k) x (k,n) -> (m,n) via one fused table gather
    (host). The (m, k-block, n) product tensor is XOR-reduced over the
    inner axis; k is blocked only to bound the intermediate."""
    a = np.asarray(a, dtype=np.uint8)
    b = np.asarray(b, dtype=np.uint8)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    la = _LOG_S[a]
    lb = _LOG_S[b]
    step = max(1, (1 << 22) // max(1, m * n))
    out = np.zeros((m, n), dtype=np.uint8)
    for j in range(0, k, step):
        prod = _EXP_S[la[:, j:j + step, None] + lb[None, j:j + step, :]]
        out ^= np.bitwise_xor.reduce(prod, axis=1)
    return out


# ------------------------------------------------------------ bit-sliced path
def gf_mul_bitsliced(a, b):
    """Elementwise GF(256) multiply via 8-round Russian peasant (jnp).

    Operates on integer arrays holding byte values in [0,256). Pure
    AND/XOR/shift/select — the exact sequence the Pallas kernel runs on the
    TPU VPU. Inputs may be any integer dtype; computation is int32.
    """
    a = jnp.asarray(a).astype(jnp.int32)
    b = jnp.asarray(b).astype(jnp.int32)
    res = jnp.zeros(jnp.broadcast_shapes(a.shape, b.shape), jnp.int32)
    for _ in range(8):
        res = res ^ jnp.where((b & 1) != 0, a, 0)
        hi = a & 0x80
        a = (a << 1) & 0xFF
        a = jnp.where(hi != 0, a ^ (GF_POLY & 0xFF), a)
        b = b >> 1
    return res


def gf_mul_jnp_tables(a, b):
    """Elementwise GF(256) multiply via tables (jnp gathers; host/ref use)."""
    exp = jnp.asarray(GF_EXP)
    log = jnp.asarray(GF_LOG)
    a = jnp.asarray(a).astype(jnp.int32)
    b = jnp.asarray(b).astype(jnp.int32)
    out = exp[log[a] + log[b]].astype(jnp.int32)
    return jnp.where((a == 0) | (b == 0), 0, out)


# ----------------------------------------------------------------- GF(2) bits
def pack_bits_to_words(data: np.ndarray) -> np.ndarray:
    """Pack a uint8 array (..., L) into int32 words (..., ceil(L/4))."""
    data = np.asarray(data, dtype=np.uint8)
    L = data.shape[-1]
    pad = (-L) % 4
    if pad:
        data = np.concatenate(
            [data, np.zeros(data.shape[:-1] + (pad,), np.uint8)], axis=-1
        )
    return data.reshape(data.shape[:-1] + (-1, 4)).view(np.int32).reshape(
        data.shape[:-1] + (-1,)
    )


def unpack_words_to_bytes(words: np.ndarray, length: int) -> np.ndarray:
    words = np.asarray(words, dtype=np.int32)
    b = words.astype(np.uint32).view(np.uint8).reshape(words.shape[:-1] + (-1,))
    return b[..., :length]


@functools.lru_cache(maxsize=None)
def _identity(k: int) -> np.ndarray:
    return np.eye(k, dtype=np.uint8)
