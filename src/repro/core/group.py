"""Chunk-group maintenance (paper §4.3.3): persistence claims + membership.

Group members periodically broadcast *persistence claims* — (chunk hash,
fragment index, selection proof) — to peers in their local membership view.
Receivers verify the selection proof (Alg. 2) before refreshing the sender's
liveness; unverifiable claims are ignored, so Byzantine nodes cannot inject
themselves into groups they were not selected for.

``MembershipTimer`` re-runs Locate() so views *eventually* converge even when
the client-issued bootstrap membership was missed (§4.3.3).

Two implementations of the per-step claim round live here:

* the **scalar path** — :func:`broadcast_claims` + :func:`prune_dead_members`
  per node, one ``verify_selection`` sha256 round-trip per (claim, receiver)
  pair.  This is the PR 3 reference the protocol golden regression pins.
* the **vectorized path** — ``repro.core.claims_engine.ClaimsEngine``
  runs the same round as array ops over persistent per-group tables with
  ONE batched selection-proof verification per (re)ingest
  (``selection.verify_selection_batch``).  It is *bit-identical* to
  running the scalar loop over the same node order: the sequential view
  updates have a closed form (derivation in ``ClaimsEngine.round``),
  including dict insertion order, prune timing, and effective timestamps,
  so downstream repair iteration order — and hence RNG consumption — is
  unchanged.

Partition/eclipse semantics (``SimNetwork.eclipse``): an eclipsed node is
alive but unreachable — its claims are dropped in both directions and its
own membership timers freeze (a node that observes *total* connectivity
loss must not evict its whole view; it waits out the partition instead, so
it returns with its views intact — the invariant
``tests/test_eclipse.py`` checks). Unaffected nodes keep pruning the
silent segment after the claim timeout, exactly as they would prune
crashed peers.
"""
from __future__ import annotations

import dataclasses

from repro.core import chunks as C
from repro.core import selection as sel
from repro.core.network import Node, SimNetwork


@dataclasses.dataclass(frozen=True)
class PersistenceClaim:
    """Heartbeat a member broadcasts for one stored fragment (§4.3.3)."""

    chash: bytes
    index: int
    proof: sel.SelectionProof  # stored alongside the fragment (paper: cached)
    sender_nid: int


def make_claims(node: Node) -> list[PersistenceClaim]:
    """Build persistence claims for every fragment ``node`` stores.

    Byzantine nodes in the Fig. 6 adversary model *do* send claims for
    fragments they discarded — that is exactly the attack the durability
    analysis covers — so claims are built from group views, not payloads.
    """
    claims = []
    for chash in node.groups:
        for idx, proof in node.claim_proofs_by_chash.get(chash, {}).items():
            claims.append(
                PersistenceClaim(
                    chash=chash, index=idx, proof=proof,
                    sender_nid=node.nid,
                )
            )
    return claims


def receive_claim(net: SimNetwork, receiver: Node, claim: PersistenceClaim) -> bool:
    """Handle one incoming claim: verify proof, refresh sender liveness.

    Returns True iff the claim was accepted (verification passed and the
    receiver tracks that group).
    """
    view = receiver.groups.get(claim.chash)
    if view is None:
        return False
    anchor = C.hash_point(claim.chash)
    ok = sel.verify_selection(
        net.registry, claim.proof, anchor, view.meta.r_target, net.n_nodes
    )
    if not ok:
        return False  # forged or stale proof — ignored (§4.3.3)
    view.members[claim.sender_nid] = net.now
    return True


def broadcast_claims(net: SimNetwork, node: Node) -> int:
    """One heartbeat round for ``node``; returns #claims accepted anywhere.

    Eclipsed senders reach nobody and eclipsed receivers hear nothing —
    the partition drops claims in both directions.
    """
    if net.is_eclipsed(node.nid):
        return 0
    accepted = 0
    for claim in make_claims(node):
        view = node.groups.get(claim.chash)
        if view is None:
            continue
        for peer_nid in list(view.members):
            peer = net.nodes.get(peer_nid)
            if (peer is None or not peer.alive or peer.nid == node.nid
                    or net.is_eclipsed(peer_nid)):
                continue
            if receive_claim(net, peer, claim):
                accepted += 1
    return accepted


def prune_dead_members(net: SimNetwork, node: Node, timeout_s: float) -> None:
    """Expire members whose last claim is older than ``timeout_s``."""
    for view in node.groups.values():
        dead = [
            nid for nid, last in view.members.items()
            if nid != node.nid and net.now - last > timeout_s
        ]
        for nid in dead:
            del view.members[nid]


def membership_timer(net: SimNetwork, node: Node, chash: bytes,
                     batch: bool = False, cache: dict | None = None) -> None:
    """MembershipTimer() of §4.3.3: merge Locate() results into the view.

    ``batch=True`` routes the walk through the resident Locate() state:
    ``net.locate_round`` returns the tick's ``selection.LocateRound`` for
    this anchor (the same instance repair slots use), whose
    ``timer_admit`` lanes hold one boolean verdict per candidate, carried
    across ticks by the round's donor machinery and invalidated per nid
    when a repair stores fresh proofs (``SimNetwork.
    evict_timer_verdicts``). A steady-state timer pass therefore verifies
    nothing and runs no per-candidate Python; newcomers get their stored
    claim proofs verified in one ``verify_selection_batch`` call. A
    candidate is (re)admitted iff *any* of its proofs verifies, so the
    admitted set — and the resulting view state — is identical to the
    scalar walk. Eclipsed nodes cannot run Locate().

    The admitted set is caller-independent — a pure function of the ring,
    the candidates' stored proofs, and the population count, none of which
    change between repairs inside one tick — so repair ticks additionally
    pass ``cache`` (a per-tick ``{chash: admitted nids}`` dict) and every
    view of the same short group merges the one computed set. The repair
    loop evicts a group's entry whenever a repair adds a member (new
    proofs / new view), keeping the cached set exact.
    """
    if net.is_eclipsed(node.nid):
        return
    view = node.groups.get(chash)
    if view is None:
        return
    if cache is not None:
        admit = cache.get(chash)
        if admit is not None:
            now = net.now
            for nid in admit:
                view.members[nid] = now
            return
    anchor = C.hash_point(chash)
    if batch:
        lr = net.locate_round(
            anchor, min(4 * view.meta.r_target, net.n_nodes),
            view.meta.r_target)
        admit = lr.timer_admit(chash)
        now = net.now
        for nid in admit:
            view.members[nid] = now
        if cache is not None:
            cache[chash] = admit
        return
    cands = net.candidates(anchor, min(4 * view.meta.r_target, net.n_nodes))
    for cand in cands:
        peer_view = cand.groups.get(chash)
        if peer_view is None:
            continue
        # peers who can present a verifiable claim are (re)admitted
        for proof in cand.claim_proofs_by_chash.get(chash, {}).values():
            if sel.verify_selection(
                net.registry, proof, anchor, view.meta.r_target, net.n_nodes
            ):
                view.members[cand.nid] = net.now
                break


def alive_members(net: SimNetwork, node: Node, chash: bytes) -> list[int]:
    view = node.groups.get(chash)
    if view is None:
        return []
    # alive_set mirrors `nid in net.nodes and net.nodes[nid].alive`
    # exactly (maintained by add_node/fail_node); one set probe per member
    alive = net.alive_set
    return [nid for nid in view.members if nid in alive]
