"""Chunk-group maintenance (paper §4.3.3): persistence claims + membership.

Group members periodically broadcast *persistence claims* — (chunk hash,
fragment index, selection proof) — to peers in their local membership view.
Receivers verify the selection proof (Alg. 2) before refreshing the sender's
liveness; unverifiable claims are ignored, so Byzantine nodes cannot inject
themselves into groups they were not selected for.

``MembershipTimer`` re-runs Locate() so views *eventually* converge even when
the client-issued bootstrap membership was missed (§4.3.3).
"""
from __future__ import annotations

import dataclasses

from repro.core import chunks as C
from repro.core import selection as sel
from repro.core.network import Node, SimNetwork


@dataclasses.dataclass(frozen=True)
class PersistenceClaim:
    """Heartbeat a member broadcasts for one stored fragment (§4.3.3)."""

    chash: bytes
    index: int
    proof: sel.SelectionProof  # stored alongside the fragment (paper: cached)
    sender_nid: int


def make_claims(node: Node) -> list[PersistenceClaim]:
    """Build persistence claims for every fragment ``node`` stores.

    Byzantine nodes in the Fig. 6 adversary model *do* send claims for
    fragments they discarded — that is exactly the attack the durability
    analysis covers — so claims are built from group views, not payloads.
    """
    claims = []
    for chash, view in node.groups.items():
        for (ch, idx), proof in node.claim_proofs.items():
            if ch == chash:
                claims.append(
                    PersistenceClaim(
                        chash=chash, index=idx, proof=proof,
                        sender_nid=node.nid,
                    )
                )
    return claims


def receive_claim(net: SimNetwork, receiver: Node, claim: PersistenceClaim) -> bool:
    """Handle one incoming claim: verify proof, refresh sender liveness.

    Returns True iff the claim was accepted (verification passed and the
    receiver tracks that group).
    """
    view = receiver.groups.get(claim.chash)
    if view is None:
        return False
    anchor = C.hash_point(claim.chash)
    ok = sel.verify_selection(
        net.registry, claim.proof, anchor, view.meta.r_target, net.n_nodes
    )
    if not ok:
        return False  # forged or stale proof — ignored (§4.3.3)
    view.members[claim.sender_nid] = net.now
    return True


def broadcast_claims(net: SimNetwork, node: Node) -> int:
    """One heartbeat round for ``node``; returns #claims accepted anywhere."""
    accepted = 0
    for claim in make_claims(node):
        view = node.groups.get(claim.chash)
        if view is None:
            continue
        for peer_nid in list(view.members):
            peer = net.nodes.get(peer_nid)
            if peer is None or not peer.alive or peer.nid == node.nid:
                continue
            if receive_claim(net, peer, claim):
                accepted += 1
    return accepted


def prune_dead_members(net: SimNetwork, node: Node, timeout_s: float) -> None:
    """Expire members whose last claim is older than ``timeout_s``."""
    for view in node.groups.values():
        dead = [
            nid for nid, last in view.members.items()
            if nid != node.nid and net.now - last > timeout_s
        ]
        for nid in dead:
            del view.members[nid]


def membership_timer(net: SimNetwork, node: Node, chash: bytes) -> None:
    """MembershipTimer() of §4.3.3: merge Locate() results into the view."""
    view = node.groups.get(chash)
    if view is None:
        return
    anchor = C.hash_point(chash)
    cands = net.candidates(anchor, min(4 * view.meta.r_target, net.n_nodes))
    for cand in cands:
        peer_view = cand.groups.get(chash)
        if peer_view is None:
            continue
        # peers who can present a verifiable claim are (re)admitted
        for (ch, idx), proof in cand.claim_proofs.items():
            if ch != chash:
                continue
            if sel.verify_selection(
                net.registry, proof, anchor, view.meta.r_target, net.n_nodes
            ):
                view.members[cand.nid] = net.now
                break


def alive_members(net: SimNetwork, node: Node, chash: bytes) -> list[int]:
    view = node.groups.get(chash)
    if view is None:
        return []
    return [
        nid for nid in view.members
        if nid in net.nodes and net.nodes[nid].alive
    ]
