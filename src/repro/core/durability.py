"""VAULT durability theory (paper §4.4 + Appendix A).

Implements, with numerics that hold up at the paper's parameter scale:

* the hypergeometric **initial-state** vector ``I`` and its Hoeffding bound
  (A.1.2, eqs. 3–5);
* the CTMC **transition matrix** ``Θ`` over group states (A.1.3, eqs. 8–11)
  with churn (Poisson), a fixed eviction rate ``Υ``, and repair-refill, and
  the absorbing-state probability ``Σ_T (IΘ^T)_{n-k+1}`` (Lemma A.1);
* the per-object bound ``1 - (1 - p_group)^(K+R)`` (Lemma 4.1 / A.2);
* the **targeted-attack** birthday bound (Lemma 4.2 / A.3, eqs. 16–17),
  evaluated in log space because ``C(Φ·g, R+1)`` overflows float64 quickly.

State convention: a group nominally holds ``n`` members; state ``b`` counts
Byzantine/faulty members, transient for ``b ∈ [0, n-k]``, absorbing once
fewer than ``k`` honest members remain. Repair refills the group to ``n``
each step (the protocol's steady-state behaviour), so ``Θ`` composes
churn → eviction → refill exactly as A.1.3 does.
"""
from __future__ import annotations

import math

import numpy as np


# ------------------------------------------------------------ combinatorics
def log_comb(n: float, k: float) -> float:
    """log C(n, k) via lgamma; -inf when the coefficient is zero."""
    if k < 0 or k > n:
        return -math.inf
    return (
        math.lgamma(n + 1) - math.lgamma(k + 1) - math.lgamma(n - k + 1)
    )


def hypergeom_pmf(b: int, N: int, F: int, n: int) -> float:
    """Pr[B = b] drawing n from N with F marked (eq. 6)."""
    lg = log_comb(F, b) + log_comb(N - F, n - b) - log_comb(N, n)
    return 0.0 if lg == -math.inf else math.exp(lg)


def poisson_pmf(c: int, mu: float) -> float:
    if mu <= 0:
        return 1.0 if c == 0 else 0.0
    return math.exp(c * math.log(mu) - mu - math.lgamma(c + 1))


# ---------------------------------------------------------- initial state
def initial_state_vector(N: int, F: int, n: int, k: int) -> np.ndarray:
    """I: Pr[B=0..n-k] + aggregated absorbing mass (eq. 6)."""
    n_trans = n - k + 1
    out = np.zeros(n_trans + 1)
    for b in range(n_trans):
        out[b] = hypergeom_pmf(b, N, F, n)
    out[-1] = max(0.0, 1.0 - out[:-1].sum())
    return out


def hoeffding_initial_bound(n: int, k: int) -> float:
    """Eq. 4: Pr[b > n-k] <= exp(-2 (2n/3 - k)^2 / n), for F = N/3."""
    margin = 2.0 * n / 3.0 - k
    if margin <= 0:
        return 1.0
    return math.exp(-2.0 * margin * margin / n)


# ------------------------------------------------------- transition matrix
def transition_matrix(
    N: int, F: int, n: int, k: int, churn_mu: float, evict: int = 0,
) -> np.ndarray:
    """Θ over states [0..n-k] + absorbing (eqs. 8–13).

    ``churn_mu``: expected honest members lost per group per step (the
    paper's Poisson churn, eq. 7, expressed per-group). ``evict``: the fixed
    eviction count Υ. Each step composes churn → eviction → refill-to-n
    (repair), with refills drawn hypergeometrically from the population.
    """
    n_trans = n - k + 1
    S = n_trans + 1
    theta = np.zeros((S, S))
    for i in range(n_trans):  # current byzantine count
        honest = n - i
        for c in range(0, honest - k + 1):  # honest churned, stays transient
            pc = poisson_pmf(c, churn_mu)
            if pc == 0.0:
                continue
            # after churn: group size n-c, byz i, honest honest-c
            size_ac = n - c
            max_v = min(evict, honest - c - k)  # honest evictable
            if evict > size_ac:
                continue  # cannot evict more than the group holds
            for v in range(0, max_v + 1):
                bz_ev = evict - v
                if bz_ev > i:
                    continue
                if evict == 0:
                    pe = 1.0 if v == 0 else 0.0
                else:
                    pe = math.exp(
                        log_comb(honest - c, v) + log_comb(i, bz_ev)
                        - log_comb(size_ac, evict)
                    )
                if pe == 0.0:
                    continue
                # after eviction: size n-c-evict, byz i-bz_ev
                byz_ae = i - bz_ev
                size_ae = size_ac - evict
                refill = n - size_ae  # c + evict
                pop = N - size_ae
                pop_byz = F - byz_ae
                for a in range(0, refill + 1):  # byzantine added back
                    j = byz_ae + a
                    pa = math.exp(
                        log_comb(pop_byz, a)
                        + log_comb(pop - pop_byz, refill - a)
                        - log_comb(pop, refill)
                    )
                    if pa == 0.0:
                        continue
                    tgt = j if j <= n - k else n_trans  # overfull refill
                    theta[i, tgt] += pc * pe * pa
        # transient -> absorbing absorbs all remaining mass (eq. 13)
        theta[i, n_trans] += max(0.0, 1.0 - theta[i].sum())
    theta[n_trans, n_trans] = 1.0  # absorbing -> absorbing (eq. 12 note)
    return theta


def absorb_probability(
    I: np.ndarray, theta: np.ndarray, t: int
) -> np.ndarray:
    """Cumulative absorbing probability after steps 1..t (Lemma A.1).

    The absorbing state accumulates, so (IΘ^T)_{abs} is already the
    cumulative probability at step T; we return the whole trajectory.
    """
    out = np.zeros(t)
    v = I.copy()
    for step in range(t):
        v = v @ theta
        out[step] = v[-1]
    return out


def object_loss_bound(p_group_absorb: float, n_chunks: int) -> float:
    """Lemma 4.1 / A.2: any of the K+R chunk groups absorbing loses opacity
    margin; bound = 1 - (1-p)^(K+R)."""
    if p_group_absorb >= 1.0:
        return 1.0
    return -math.expm1(n_chunks * math.log1p(-p_group_absorb))


def group_durability_horizon(
    N: int, F: int, n: int, k: int, churn_mu: float, evict: int = 0,
    eps_log2: float = -128.0, max_steps: int = 10_000,
) -> int:
    """Largest t with cumulative absorb probability <= 2^eps_log2."""
    I = initial_state_vector(N, F, n, k)
    theta = transition_matrix(N, F, n, k, churn_mu, evict)
    limit = 2.0 ** eps_log2
    v = I.copy()
    for step in range(1, max_steps + 1):
        v = v @ theta
        if v[-1] > limit:
            return step - 1
    return max_steps


# -------------------------------------------------------- targeted attacks
def targeted_attack_bound(
    K: int, R: int, omega: int, phi_groups: int, g: int = 1,
) -> float:
    """Lemma 4.2 / A.3 (eqs. 16–17): probability an attacker that can absorb
    ``phi_groups`` groups (each node holding ``g`` fragments) kills >= R+1
    chunks of one object among ``omega`` objects of K+R chunks each.

    Evaluated fully in log space: C(Φ·g, R+1) and the product both reach
    1e±hundreds at paper scale.
    """
    total_chunks = omega * (K + R)
    attacked = phi_groups * g
    if attacked < R + 1:
        return 0.0
    # log p_single = sum_i log((K+R-i) / (omega(K+R)-i)), i=1..R
    log_p = 0.0
    for i in range(1, R + 1):
        num = K + R - i
        den = total_chunks - i
        if num <= 0 or den <= 0:
            return 0.0 if num <= 0 else 1.0
        log_p += math.log(num) - math.log(den)
    log_trials = log_comb(attacked, R + 1)
    # P = 1 - (1 - p)^trials;  log(1-p) ~ -p for tiny p
    p = math.exp(log_p) if log_p > -700 else 0.0
    if p == 0.0:
        # exponent * p in logs
        log_exp_p = log_trials + log_p
        if log_exp_p < -40:
            return math.exp(log_exp_p)  # ~ trials * p
        return -math.expm1(-math.exp(log_exp_p))
    log1m = math.log1p(-p)
    x = math.exp(log_trials) * log1m if log_trials < 700 else -math.inf
    return -math.expm1(x) if x > -700 else 1.0


def attacker_groups(phi_nodes: int, n: int, k: int) -> int:
    """A.3: average groups an attacker can absorb with phi node removals —
    each kill needs (n/3 - k + 1) honest removals on average; worst case
    (groups already at exactly k honest) is phi itself."""
    per_group = max(1, int(n / 3) - k + 1)
    return phi_nodes // per_group
