"""Decentralized chunk repair (paper §4.3.4).

When a node's local view of a chunk group drops below the threshold ``R``, it
repairs *independently* — no consensus. For each missing slot it:

1. draws a fresh fragment index from the (infinite) inner-code stream,
2. runs Locate() (Alg. 2) to find a verifiably-selected new member,
3. sends a RepairRequest carrying its membership view,
4. the new member either (a) receives the fragment directly from a peer whose
   *chunk cache* is still warm (that peer encodes the requested index locally
   — one fragment of traffic), or (b) pulls ``K_inner`` fragments from the
   view, inner-decodes, verifies the chunk hash, caches the chunk, and
   encodes its own fragment (``K_inner`` fragments of traffic — the paper's
   minimum repair amplification).

Note on the cache semantics: the paper's prose says the caching node "sends
its chunk copy"; a chunk copy is ``K_inner`` fragments of bytes, which could
not produce Fig. 4's ~``K_inner``× traffic reduction. The only reading
consistent with Fig. 4 (and with the repair-amplification sentence preceding
it) is that a warm peer *constructs the requested fragment from its cached
chunk* and ships one fragment; that is what we implement, and what
``benchmarks/repair_traffic.py`` reproduces. Recorded in DESIGN.md §7.

Over-repair is safe (§4.3.4): concurrent repairs may push the group above
``R``; membership convergence trims nothing — extra fragments only help.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import chunks as C
from repro.core import group as G
from repro.core import selection as sel
from repro.core.network import GroupMeta, GroupView, Node, SimNetwork
from repro.core.rateless import InsufficientFragments


@dataclasses.dataclass
class RepairStats:
    repaired: int = 0
    traffic_bytes: int = 0
    cache_hits: int = 0
    latency_s: float = 0.0  # modeled network latency of the slowest repair
    # nids placed into the group by this call — these nodes gained a view,
    # so tick-level schedulers must re-scan their group lists
    new_nids: list[int] = dataclasses.field(default_factory=list)


def _fresh_index(net: SimNetwork, view) -> int:
    """A random index in the infinite encoding stream (paper: 'randomly
    selected fragment within the encoding stream')."""
    return int(net.rng.integers(1 << 32, C.INDEX_SPACE))


def _locate_new_member(
    net: SimNetwork, chash: bytes, fhash: int, r_target: int,
    exclude: set[int], pick=None, batch: bool = False,
) -> tuple[Node, sel.SelectionProof] | None:
    """Locate() restricted to nodes not already in the group.

    ``pick`` chooses among the verifiably-selected responders: ``None``
    keeps the default (nearest-to-anchor, the paper's Locate()); a callable
    ``pick(responders) -> index`` models response-timing adversaries — the
    adaptive Byzantine strategy answers Locate() rounds faster than honest
    peers, so the repairer's "first verifiable responder" is biased (see
    ``protocol_sim.rush_picker``). Every responder passed to ``pick`` has
    already survived proof verification; the bias can only reorder
    *legitimately selected* candidates, never admit forged ones.

    ``batch=True`` runs the round through the net's resident
    ``selection.LocateRound`` (one vectorized VRF pass over a cached
    candidate-array set) instead of per-candidate scalar calls; the
    responder list — order included — is identical.
    """
    anchor = C.hash_point(chash)
    responders: list[tuple[int, Node, sel.SelectionProof]] = []
    if batch:
        lr = net.locate_round(anchor, min(4 * r_target, net.n_nodes),
                              r_target)
        if pick is None:  # default nearest-selected: winner-only fast path
            return lr.nearest(fhash, exclude)
        responders = lr.responders(fhash, exclude)
    else:
        cands = net.candidates(anchor, min(4 * r_target, net.n_nodes))
        for cand in cands:
            if cand.nid in exclude or not cand.alive:
                continue
            proof, selected = cand.selection_proof(fhash, anchor, r_target)
            if not selected:
                continue
            if not sel.verify_selection(
                net.registry, proof, anchor, r_target, net.n_nodes
            ):
                continue
            responders.append(
                (sel.ring_distance(anchor, cand.nid), cand, proof))
    if not responders:
        return None
    if pick is None:
        best = min(responders, key=lambda t: t[0])
    else:
        best = responders[pick(responders)]
    return best[1], best[2]


class SolvePool:
    """Cross-group repair-decode collector: one padded solve dispatch/tick.

    Inline, each repair only decides what the rest of the tick can observe
    — how many fragments it pulls (which fixes its traffic charge and the
    count of RTT draws, i.e. the RNG stream) and the chunk bytes it
    re-encodes from. Chunks are content-addressed, so the bytes behind a
    ``chash`` are immutable for the whole run: the *first* decode of each
    chunk runs inline (hash-verified in ``chunks.inner_decode``) and is
    memoized here, and every later repair of the same chunk reuses the
    memo, decides its pull count with a rank-only elimination
    (``kernels.gf256_solve.gf256_rank_prefix`` — provably the exact count
    the PR 4 one-more-row retry loop reaches) and defers its payload
    system to :meth:`flush`.

    ``flush`` (end of the repair tick) stacks the deferred systems into
    padded ``kernels.gf256_solve.gf256_solve_batch`` dispatches: every
    system enters at the minimum ``k`` rows, and PR 4's one-fragment
    extension runs as a *masked second round* over just the
    rank-deficient lanes instead of a per-group Python loop. Each decoded
    chunk is verified against its content address — so the memo shortcut
    is continuously re-proven by the real batched math, and any
    divergence between the inline rank decision and the batch solve
    raises instead of corrupting state.

    ``chunks`` persists across ticks (bounded by the deployment's chunk
    population, ~1 KiB each); ``systems`` drains every flush.
    """

    def __init__(self) -> None:
        self.chunks: dict[bytes, bytes] = {}
        # (chash, k, coeffs (n_pull, k), symbols (n_pull, L), n_pull)
        self.systems: list[tuple] = []
        self.flushed = 0

    def enqueue(self, chash: bytes, k: int, coeffs: np.ndarray,
                symbols: np.ndarray, n_pull: int) -> None:
        self.systems.append((chash, k, coeffs, symbols, n_pull))

    def flush(self) -> int:
        """Solve + verify every deferred system; returns how many."""
        if not self.systems:
            return 0
        from repro.kernels.gf256_solve import gf256_solve_batch

        systems, self.systems = self.systems, []
        k = systems[0][1]
        ls = [s[3].shape[1] for s in systems]
        lmax = max(ls)
        pending = list(range(len(systems)))
        tries = [0] * len(systems)
        while pending:
            mmax = k + max(tries[i] for i in pending)
            a = np.zeros((len(pending), mmax, k), np.uint8)
            y = np.zeros((len(pending), mmax, lmax), np.uint8)
            for j, i in enumerate(pending):
                rows = k + tries[i]
                a[j, :rows] = systems[i][2][:rows]
                y[j, :rows, :ls[i]] = systems[i][3][:rows]
            # zero pad rows are never eligible pivots and eliminate to
            # nothing, so the padded batch is per-system bit-identical to
            # solving each at its own prefix length. Backend is pinned to
            # the numpy mirror: the batch geometry (B ~ repairs/tick, m ~
            # k+1) changes every tick, and per-shape XLA compiles of the
            # pallas path cost more than the whole elimination at this
            # size — accelerator sweeps call gf256_solve_batch directly
            # with stable shapes and get the kernel via auto-dispatch.
            x, ok, _ = gf256_solve_batch(a, y, backend="numpy")
            nxt = []
            for j, i in enumerate(pending):
                chash, k_i, coeffs, _, n_pull = systems[i]
                if ok[j]:
                    if k + tries[i] != n_pull:
                        raise RuntimeError(
                            "batched solve prefix disagrees with inline "
                            f"rank decision ({k + tries[i]} != {n_pull})")
                    chunk = C.join_blocks(x[j][:, :ls[i]])
                    if C.chunk_hash(chunk) != chash:
                        raise RuntimeError(
                            "batched repair decode failed content-address "
                            "verification")
                    self.flushed += 1
                elif k + tries[i] >= coeffs.shape[0]:
                    raise RuntimeError(
                        "batched solve exhausted rows the inline rank "
                        "decision declared sufficient")
                else:
                    tries[i] += 1  # masked retry round: one more fragment
                    nxt.append(i)
            pending = nxt
        return self.flushed


def decode_from_available(
    chash: bytes, k_inner: int,
    available: list[tuple[int, bytes, "Node"]],
    pool: SolvePool | None = None,
) -> tuple[bytes, int]:
    """Decode one chunk from an ordered ``(index, payload, holder)`` list.

    The shared decode core of repair pulls and serving reads
    (``protocol_sim._serve_tick``). The pull starts at exactly ``k_inner``
    fragments in list order. About 1 in 255 index combinations is
    rank-deficient over GF(256); since the order is stable, a group that
    hits one would otherwise retry the *same* singular set every tick
    forever — a deterministic repair livelock that, at 1K+ nodes,
    snowballed into a network-wide repair storm (the PR 3 scalar path has
    the same latent bug; it simply never ran at a scale that exposed it).
    On rank deficiency one more fragment is pulled and the decode retried
    — exactly what a real reader does when a decode fails.

    With ``pool`` (the vectorized tick), repeat decodes of a memoized
    chunk compute only the pull count inline (``gf256_rank_prefix``
    reaches the same count as the retry loop — see its docstring for the
    nesting argument) and defer the payload solve to the tick-end batched
    dispatch; the returned ``n_pull`` is identical either way.

    Returns ``(chunk, n_pull)``; raises InsufficientFragments when the
    available rows never reach rank ``k_inner``. No RNG anywhere.
    """
    chunk = pool.chunks.get(chash) if pool is not None else None
    if chunk is None:
        n_pull = k_inner
        while True:
            frags = {idx: payload for idx, payload, _ in available[:n_pull]}
            try:
                chunk = C.inner_decode(chash, k_inner, frags)
                break
            except InsufficientFragments:
                if n_pull >= len(available):
                    raise
                n_pull += 1  # rank-deficient combination: pull one more
        if pool is not None:
            pool.chunks[chash] = chunk
    else:
        from repro.kernels.gf256_solve import gf256_rank_prefix

        code = C.inner_code(chash, k_inner)
        coeffs = code.coeff_matrix([idx for idx, _, _ in available])
        ok, n_pull = gf256_rank_prefix(coeffs)
        if not ok:
            # same condition under which the retry loop exhausts
            # ``available`` and re-raises the decode failure
            raise InsufficientFragments(
                f"rank-deficient pull: rank < {k_inner} over "
                f"{len(available)} fragments")
        symbols = np.stack([np.frombuffer(p, np.uint8)
                            for _, p, _ in available[:n_pull]])
        pool.enqueue(chash, k_inner, coeffs[:n_pull], symbols, n_pull)
    return chunk, n_pull


def _pull_and_decode(
    net: SimNetwork, requester: Node, chash: bytes, meta: GroupMeta,
    members: list[Node], pool: SolvePool | None = None,
) -> tuple[bytes, int, float]:
    """New member pulls >= K_inner fragments, decodes, verifies the chunk.

    Returns (chunk, traffic_bytes, latency_s). Raises InsufficientFragments
    if the view cannot supply enough fragments. The decode itself (minimum
    ``K_inner``-fragment pull, one-more-row rank-deficiency retries, the
    SolvePool memo shortcut) lives in :func:`decode_from_available`;
    traffic, per-region link charges, holders and RTT draws are accounted
    here and are unchanged by the pool path.

    Withholding hook (``policies.ADV_COLLUDE``): every gathered row is
    verified against its creator-recorded tag (``SimNetwork.row_ok``)
    *at pull time* — colluding members' corrupt rows are charged to
    traffic and the holder's region links (the transfer happened) but
    never enter the decode, and they don't claim their index, so a
    colluder can't shadow an honest same-index row. The decode then sees
    exactly the honest row set a serve-nothing Byzantine run yields —
    withholding can add cost, never decode success.
    """
    available: list[tuple[int, bytes, Node]] = []
    seen: set[int] = set()
    corrupt_bytes = 0
    for m in members:
        for idx, payload in m.serve_fragments(chash).items():
            if not net.row_ok(chash, idx, payload):
                corrupt_bytes += len(payload)
                net.region_load[m.region] += len(payload)
                continue
            if idx not in seen:
                seen.add(idx)
                available.append((idx, payload, m))
    if len(available) < meta.k_inner:
        raise InsufficientFragments(
            f"repair: {len(available)}/{meta.k_inner} fragments reachable"
        )
    chunk, n_pull = decode_from_available(chash, meta.k_inner, available,
                                          pool=pool)
    holders = list(dict.fromkeys(m for _, _, m in available[:n_pull]))
    traffic = 0
    for _, payload, m in available[:n_pull]:
        traffic += len(payload)
        net.region_load[m.region] += len(payload)
    # wasted colluder transfers ride the traffic lane; holders (and so
    # the RTT draws) stay the honest fan-out set
    traffic += corrupt_bytes
    rtts = net.rtts(requester, holders) if holders else np.zeros(1)
    return chunk, traffic, float(np.max(rtts))


def repair_group(
    net: SimNetwork, node: Node, chash: bytes, cache_ttl: float = 0.0,
    max_new: int | None = None, pick=None, batch: bool = False,
    timer_cache: dict | None = None, pool: SolvePool | None = None,
) -> RepairStats:
    """One repair pass from ``node``'s local view (§4.3.4).

    Restores the group to ``R`` alive members (or as close as the candidate
    set allows). Returns traffic/latency accounting for the benchmarks.
    ``pick`` forwards to :func:`_locate_new_member` (response-order bias of
    the adaptive adversary; ``None`` = nearest-selected, the default);
    ``batch`` selects the batched VRF path there and in MembershipTimer
    (identical results, one vectorized verification round per call);
    ``pool`` defers repeat chunk decodes to the tick-end batched solve
    (see :class:`SolvePool` — the caller must ``flush()``).

    An eclipsed repairer is cut off from Locate() and every peer — the
    repair no-ops until the partition heals.
    """
    stats = RepairStats()
    if net.is_eclipsed(node.nid):
        return stats
    view = node.groups.get(chash)
    if view is None:
        return stats
    meta = view.meta
    # refresh the view first (MembershipTimer — §4.3.3); the per-tick
    # timer cache shares the verified-candidate set across the group's
    # viewers (see membership_timer) and is evicted below on any repair
    G.membership_timer(net, node, chash, batch=batch, cache=timer_cache)
    alive = G.alive_members(net, node, chash)
    deficit = meta.r_target - len(alive)
    if max_new is not None:
        deficit = min(deficit, max_new)
    if deficit <= 0:
        return stats
    member_nodes = [net.nodes[nid] for nid in alive]  # alive by construction
    exclude = set(alive)
    lat_worst = 0.0
    for _ in range(deficit):
        index = _fresh_index(net, view)
        fhash = C.fragment_hash(chash, index)
        found = _locate_new_member(net, chash, fhash, meta.r_target, exclude,
                                   pick=pick, batch=batch)
        if found is None:
            continue  # candidate set exhausted; next timer tick retries
        new_member, proof = found
        # RepairRequest: sender's view bootstraps the new member (§4.3.4).
        # Peers behind a partition cut are omitted — the repairer cannot
        # vouch for their liveness, and forwarding them fresh would let an
        # unreachable node's apparent liveness cross the cut.
        if net._eclipse is None:
            membership = dict.fromkeys(alive, net.now)
        else:
            membership = {nid: net.now for nid in alive
                          if not net.is_eclipsed(nid)}
        lat = net.rtt(node, new_member)  # the RepairRequest round
        # (a) warm chunk cache anywhere in the view → one-fragment traffic
        # (the scan is skipped while no cache_chunk write has ever landed
        # — cache_ttl=0 runs — where it could only ever yield None)
        warm = None
        if net.chunk_caches:
            warm = next(
                (m for m in member_nodes
                 if m.cached_chunk(chash) is not None),
                None,
            )
        if warm is not None:
            chunk = warm.cached_chunk(chash)
            frag = C.inner_encode_fragment(chunk, chash, meta.k_inner, index)
            net.record_frag_tag(chash, index, frag)
            stats.traffic_bytes += len(frag)
            net.region_load[warm.region] += len(frag)
            stats.cache_hits += 1
            lat += net.rtt(new_member, warm)
        else:
            # (b) pull K_inner fragments, decode, cache, re-encode
            try:
                chunk, traffic, pull_lat = _pull_and_decode(
                    net, new_member, chash, meta, member_nodes, pool=pool
                )
            except InsufficientFragments:
                continue  # incomplete view — MembershipTimer() will retry
            stats.traffic_bytes += traffic
            lat += pull_lat
            new_member.groups.setdefault(chash, GroupView(meta=meta))
            frag = C.inner_encode_fragment(chunk, chash, meta.k_inner, index)
            net.record_frag_tag(chash, index, frag)
        new_member.store_fragment(meta, index, frag, membership, proof)
        if cache_ttl > 0 and warm is None:
            new_member.cache_chunk(chash, chunk, cache_ttl)
        # merge into the repairing node's view too
        view.members[new_member.nid] = net.now
        exclude.add(new_member.nid)
        member_nodes.append(new_member)
        alive.append(new_member.nid)
        stats.new_nids.append(new_member.nid)
        stats.repaired += 1
        lat_worst = max(lat_worst, lat)
    stats.latency_s = lat_worst
    if stats.repaired:
        # the new members hold fresh verifiable proofs — the cached
        # admitted set for this group is stale from here on
        if timer_cache is not None:
            timer_cache.pop(chash, None)
        if batch:
            # the cross-tick timer lanes stay valid for everyone else:
            # ``store_fragment`` touched ONLY the recruited members'
            # proofs, so drop just those verdicts — they re-verify as
            # unjudged rows on the next MembershipTimer pass
            net.evict_timer_verdicts(C.hash_point(chash), meta.r_target,
                                     stats.new_nids)
    net.repair_traffic_bytes += stats.traffic_bytes
    net.repair_count += stats.repaired
    return stats


def evict_oldest(net: SimNetwork, chash: bytes) -> int | None:
    """Force-evict the longest-standing member of a chunk group.

    Mirrors the paper's physical-deployment repair trigger ("a special
    command to force nodes to evict the oldest member that stores the
    chunk"). Returns the evicted node id, or None.
    """
    holders = [
        n for n in net.alive_nodes()
        if any(ch == chash for (ch, _i) in n.fragments)
        or chash in n.groups
    ]
    holders = [n for n in holders if chash in n.groups]
    if not holders:
        return None
    oldest = min(holders, key=lambda n: min(
        (t for t in n.groups[chash].members.values()), default=net.now
    ))
    net.fail_node(oldest.nid)
    return oldest.nid


def repair_all(
    net: SimNetwork, cache_ttl: float = 0.0
) -> RepairStats:
    """Run one repair tick across every node's local views (the steady-state
    background loop)."""
    total = RepairStats()
    for n in list(net.alive_nodes()):
        for chash in list(n.groups):
            s = repair_group(net, n, chash, cache_ttl=cache_ttl)
            total.repaired += s.repaired
            total.traffic_bytes += s.traffic_bytes
            total.cache_hits += s.cache_hits
            total.latency_s = max(total.latency_s, s.latency_s)
    return total
