"""Decentralized chunk repair (paper §4.3.4).

When a node's local view of a chunk group drops below the threshold ``R``, it
repairs *independently* — no consensus. For each missing slot it:

1. draws a fresh fragment index from the (infinite) inner-code stream,
2. runs Locate() (Alg. 2) to find a verifiably-selected new member,
3. sends a RepairRequest carrying its membership view,
4. the new member either (a) receives the fragment directly from a peer whose
   *chunk cache* is still warm (that peer encodes the requested index locally
   — one fragment of traffic), or (b) pulls ``K_inner`` fragments from the
   view, inner-decodes, verifies the chunk hash, caches the chunk, and
   encodes its own fragment (``K_inner`` fragments of traffic — the paper's
   minimum repair amplification).

Note on the cache semantics: the paper's prose says the caching node "sends
its chunk copy"; a chunk copy is ``K_inner`` fragments of bytes, which could
not produce Fig. 4's ~``K_inner``× traffic reduction. The only reading
consistent with Fig. 4 (and with the repair-amplification sentence preceding
it) is that a warm peer *constructs the requested fragment from its cached
chunk* and ships one fragment; that is what we implement, and what
``benchmarks/repair_traffic.py`` reproduces. Recorded in DESIGN.md §7.

Over-repair is safe (§4.3.4): concurrent repairs may push the group above
``R``; membership convergence trims nothing — extra fragments only help.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import chunks as C
from repro.core import group as G
from repro.core import selection as sel
from repro.core.network import GroupMeta, GroupView, Node, SimNetwork
from repro.core.rateless import InsufficientFragments


@dataclasses.dataclass
class RepairStats:
    repaired: int = 0
    traffic_bytes: int = 0
    cache_hits: int = 0
    latency_s: float = 0.0  # modeled network latency of the slowest repair
    # nids placed into the group by this call — these nodes gained a view,
    # so tick-level schedulers must re-scan their group lists
    new_nids: list[int] = dataclasses.field(default_factory=list)


def _fresh_index(net: SimNetwork, view) -> int:
    """A random index in the infinite encoding stream (paper: 'randomly
    selected fragment within the encoding stream')."""
    return int(net.rng.integers(1 << 32, C.INDEX_SPACE))


def _locate_new_member(
    net: SimNetwork, chash: bytes, fhash: int, r_target: int,
    exclude: set[int], pick=None, batch: bool = False,
) -> tuple[Node, sel.SelectionProof] | None:
    """Locate() restricted to nodes not already in the group.

    ``pick`` chooses among the verifiably-selected responders: ``None``
    keeps the default (nearest-to-anchor, the paper's Locate()); a callable
    ``pick(responders) -> index`` models response-timing adversaries — the
    adaptive Byzantine strategy answers Locate() rounds faster than honest
    peers, so the repairer's "first verifiable responder" is biased (see
    ``protocol_sim.rush_picker``). Every responder passed to ``pick`` has
    already survived proof verification; the bias can only reorder
    *legitimately selected* candidates, never admit forged ones.

    ``batch=True`` runs the round through the net's resident
    ``selection.LocateRound`` (one vectorized VRF pass over a cached
    candidate-array set) instead of per-candidate scalar calls; the
    responder list — order included — is identical.
    """
    anchor = C.hash_point(chash)
    responders: list[tuple[int, Node, sel.SelectionProof]] = []
    if batch:
        lr = net.locate_round(anchor, min(4 * r_target, net.n_nodes),
                              r_target)
        if pick is None:  # default nearest-selected: winner-only fast path
            return lr.nearest(fhash, exclude)
        responders = lr.responders(fhash, exclude)
    else:
        cands = net.candidates(anchor, min(4 * r_target, net.n_nodes))
        for cand in cands:
            if cand.nid in exclude or not cand.alive:
                continue
            proof, selected = cand.selection_proof(fhash, anchor, r_target)
            if not selected:
                continue
            if not sel.verify_selection(
                net.registry, proof, anchor, r_target, net.n_nodes
            ):
                continue
            responders.append(
                (sel.ring_distance(anchor, cand.nid), cand, proof))
    if not responders:
        return None
    if pick is None:
        best = min(responders, key=lambda t: t[0])
    else:
        best = responders[pick(responders)]
    return best[1], best[2]


def _pull_and_decode(
    net: SimNetwork, requester: Node, chash: bytes, meta: GroupMeta,
    members: list[Node],
) -> tuple[bytes, int, float]:
    """New member pulls >= K_inner fragments, decodes, verifies the chunk.

    Returns (chunk, traffic_bytes, latency_s). Raises InsufficientFragments
    if the view cannot supply enough fragments.

    The pull starts at exactly ``K_inner`` fragments (the paper's minimum
    repair amplification) in view order. About 1 in 255 index
    combinations is rank-deficient over GF(256); since the view order is
    stable, a group that hits one would otherwise retry the *same*
    singular set every tick forever — a deterministic repair livelock
    that, at 1K+ nodes, snowballed into a network-wide repair storm (the
    PR 3 scalar path has the same latent bug; it simply never ran at a
    scale that exposed it). On rank deficiency the requester pulls
    additional fragments one at a time and retries — exactly what a real
    repairer does when a decode fails — with the extra traffic charged.
    """
    available: list[tuple[int, bytes, Node]] = []
    seen: set[int] = set()
    for m in members:
        for idx, payload in m.serve_fragments(chash).items():
            if idx not in seen:
                seen.add(idx)
                available.append((idx, payload, m))
    if len(available) < meta.k_inner:
        raise InsufficientFragments(
            f"repair: {len(available)}/{meta.k_inner} fragments reachable"
        )
    n_pull = meta.k_inner
    while True:
        frags = {idx: payload for idx, payload, _ in available[:n_pull]}
        try:
            chunk = C.inner_decode(chash, meta.k_inner, frags)
            break
        except InsufficientFragments:
            if n_pull >= len(available):
                raise
            n_pull += 1  # rank-deficient combination: pull one more
    holders = list(dict.fromkeys(m for _, _, m in available[:n_pull]))
    traffic = sum(len(payload) for _, payload, _ in available[:n_pull])
    rtts = net.rtts(requester, holders) if holders else np.zeros(1)
    return chunk, traffic, float(np.max(rtts))


def repair_group(
    net: SimNetwork, node: Node, chash: bytes, cache_ttl: float = 0.0,
    max_new: int | None = None, pick=None, batch: bool = False,
    timer_cache: dict | None = None, timer_prev: dict | None = None,
) -> RepairStats:
    """One repair pass from ``node``'s local view (§4.3.4).

    Restores the group to ``R`` alive members (or as close as the candidate
    set allows). Returns traffic/latency accounting for the benchmarks.
    ``pick`` forwards to :func:`_locate_new_member` (response-order bias of
    the adaptive adversary; ``None`` = nearest-selected, the default);
    ``batch`` selects the batched VRF path there and in MembershipTimer
    (identical results, one vectorized verification round per call).

    An eclipsed repairer is cut off from Locate() and every peer — the
    repair no-ops until the partition heals.
    """
    stats = RepairStats()
    if net.is_eclipsed(node.nid):
        return stats
    view = node.groups.get(chash)
    if view is None:
        return stats
    meta = view.meta
    # refresh the view first (MembershipTimer — §4.3.3); the per-tick
    # timer cache shares the verified-candidate set across the group's
    # viewers (see membership_timer) and is evicted below on any repair
    G.membership_timer(net, node, chash, batch=batch, cache=timer_cache,
                       prev=timer_prev)
    alive = G.alive_members(net, node, chash)
    deficit = meta.r_target - len(alive)
    if max_new is not None:
        deficit = min(deficit, max_new)
    if deficit <= 0:
        return stats
    member_nodes = [net.nodes[nid] for nid in alive if net.nodes[nid].alive]
    exclude = set(alive)
    lat_worst = 0.0
    for _ in range(deficit):
        index = _fresh_index(net, view)
        fhash = C.fragment_hash(chash, index)
        found = _locate_new_member(net, chash, fhash, meta.r_target, exclude,
                                   pick=pick, batch=batch)
        if found is None:
            continue  # candidate set exhausted; next timer tick retries
        new_member, proof = found
        # RepairRequest: sender's view bootstraps the new member (§4.3.4).
        # Peers behind a partition cut are omitted — the repairer cannot
        # vouch for their liveness, and forwarding them fresh would let an
        # unreachable node's apparent liveness cross the cut.
        membership = {nid: net.now for nid in alive
                      if not net.is_eclipsed(nid)}
        lat = net.rtt(node, new_member)  # the RepairRequest round
        # (a) warm chunk cache anywhere in the view → one-fragment traffic
        warm = next(
            (m for m in member_nodes if m.cached_chunk(chash) is not None),
            None,
        )
        if warm is not None:
            chunk = warm.cached_chunk(chash)
            frag = C.inner_encode_fragment(chunk, chash, meta.k_inner, index)
            stats.traffic_bytes += len(frag)
            stats.cache_hits += 1
            lat += net.rtt(new_member, warm)
        else:
            # (b) pull K_inner fragments, decode, cache, re-encode
            try:
                chunk, traffic, pull_lat = _pull_and_decode(
                    net, new_member, chash, meta, member_nodes
                )
            except InsufficientFragments:
                continue  # incomplete view — MembershipTimer() will retry
            stats.traffic_bytes += traffic
            lat += pull_lat
            new_member.groups.setdefault(chash, GroupView(meta=meta))
            frag = C.inner_encode_fragment(chunk, chash, meta.k_inner, index)
        new_member.store_fragment(meta, index, frag, membership, proof)
        if cache_ttl > 0 and warm is None:
            new_member.cache_chunk(chash, chunk, cache_ttl)
        # merge into the repairing node's view too
        view.members[new_member.nid] = net.now
        exclude.add(new_member.nid)
        member_nodes.append(new_member)
        alive.append(new_member.nid)
        stats.new_nids.append(new_member.nid)
        stats.repaired += 1
        lat_worst = max(lat_worst, lat)
    stats.latency_s = lat_worst
    if stats.repaired:
        # the new members hold fresh verifiable proofs — the cached
        # admitted set for this group is stale from here on
        if timer_cache is not None:
            timer_cache.pop(chash, None)
        if timer_prev is not None:
            # the cross-tick verdict donor stays valid for everyone else:
            # ``store_fragment`` touched ONLY the recruited members'
            # proofs, so drop just those verdicts — they re-verify as
            # window newcomers on the next MembershipTimer pass
            ent = timer_prev.get(chash)
            if ent is not None:
                for nid in stats.new_nids:
                    ent[0].discard(nid)
                    ent[1].discard(nid)
    net.repair_traffic_bytes += stats.traffic_bytes
    net.repair_count += stats.repaired
    return stats


def evict_oldest(net: SimNetwork, chash: bytes) -> int | None:
    """Force-evict the longest-standing member of a chunk group.

    Mirrors the paper's physical-deployment repair trigger ("a special
    command to force nodes to evict the oldest member that stores the
    chunk"). Returns the evicted node id, or None.
    """
    holders = [
        n for n in net.alive_nodes()
        if any(ch == chash for (ch, _i) in n.fragments)
        or chash in n.groups
    ]
    holders = [n for n in holders if chash in n.groups]
    if not holders:
        return None
    oldest = min(holders, key=lambda n: min(
        (t for t in n.groups[chash].members.values()), default=net.now
    ))
    net.fail_node(oldest.nid)
    return oldest.nid


def repair_all(
    net: SimNetwork, cache_ttl: float = 0.0
) -> RepairStats:
    """Run one repair tick across every node's local views (the steady-state
    background loop)."""
    total = RepairStats()
    for n in list(net.alive_nodes()):
        for chash in list(n.groups):
            s = repair_group(net, n, chash, cache_ttl=cache_ttl)
            total.repaired += s.repaired
            total.traffic_bytes += s.traffic_bytes
            total.cache_hits += s.cache_hits
            total.latency_s = max(total.latency_s, s.latency_s)
    return total
