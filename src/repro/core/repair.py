"""Decentralized chunk repair (paper §4.3.4).

When a node's local view of a chunk group drops below the threshold ``R``, it
repairs *independently* — no consensus. For each missing slot it:

1. draws a fresh fragment index from the (infinite) inner-code stream,
2. runs Locate() (Alg. 2) to find a verifiably-selected new member,
3. sends a RepairRequest carrying its membership view,
4. the new member either (a) receives the fragment directly from a peer whose
   *chunk cache* is still warm (that peer encodes the requested index locally
   — one fragment of traffic), or (b) pulls ``K_inner`` fragments from the
   view, inner-decodes, verifies the chunk hash, caches the chunk, and
   encodes its own fragment (``K_inner`` fragments of traffic — the paper's
   minimum repair amplification).

Note on the cache semantics: the paper's prose says the caching node "sends
its chunk copy"; a chunk copy is ``K_inner`` fragments of bytes, which could
not produce Fig. 4's ~``K_inner``× traffic reduction. The only reading
consistent with Fig. 4 (and with the repair-amplification sentence preceding
it) is that a warm peer *constructs the requested fragment from its cached
chunk* and ships one fragment; that is what we implement, and what
``benchmarks/repair_traffic.py`` reproduces. Recorded in DESIGN.md §7.

Over-repair is safe (§4.3.4): concurrent repairs may push the group above
``R``; membership convergence trims nothing — extra fragments only help.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import chunks as C
from repro.core import group as G
from repro.core import selection as sel
from repro.core.network import GroupMeta, GroupView, Node, SimNetwork
from repro.core.rateless import InsufficientFragments


@dataclasses.dataclass
class RepairStats:
    repaired: int = 0
    traffic_bytes: int = 0
    cache_hits: int = 0
    latency_s: float = 0.0  # modeled network latency of the slowest repair


def _fresh_index(net: SimNetwork, view) -> int:
    """A random index in the infinite encoding stream (paper: 'randomly
    selected fragment within the encoding stream')."""
    return int(net.rng.integers(1 << 32, C.INDEX_SPACE))


def _locate_new_member(
    net: SimNetwork, chash: bytes, fhash: int, r_target: int,
    exclude: set[int], pick=None,
) -> tuple[Node, sel.SelectionProof] | None:
    """Locate() restricted to nodes not already in the group.

    ``pick`` chooses among the verifiably-selected responders: ``None``
    keeps the default (nearest-to-anchor, the paper's Locate()); a callable
    ``pick(responders) -> index`` models response-timing adversaries — the
    adaptive Byzantine strategy answers Locate() rounds faster than honest
    peers, so the repairer's "first verifiable responder" is biased (see
    ``protocol_sim.rush_picker``). Every responder passed to ``pick`` has
    already survived proof verification; the bias can only reorder
    *legitimately selected* candidates, never admit forged ones.
    """
    anchor = C.hash_point(chash)
    cands = net.candidates(anchor, min(4 * r_target, net.n_nodes))
    responders: list[tuple[int, Node, sel.SelectionProof]] = []
    for cand in cands:
        if cand.nid in exclude or not cand.alive:
            continue
        proof, selected = cand.selection_proof(fhash, anchor, r_target)
        if not selected:
            continue
        if not sel.verify_selection(
            net.registry, proof, anchor, r_target, net.n_nodes
        ):
            continue
        responders.append((sel.ring_distance(anchor, cand.nid), cand, proof))
    if not responders:
        return None
    if pick is None:
        best = min(responders, key=lambda t: t[0])
    else:
        best = responders[pick(responders)]
    return best[1], best[2]


def _pull_and_decode(
    net: SimNetwork, requester: Node, chash: bytes, meta: GroupMeta,
    members: list[Node],
) -> tuple[bytes, int, float]:
    """New member pulls >= K_inner fragments, decodes, verifies the chunk.

    Returns (chunk, traffic_bytes, latency_s). Raises InsufficientFragments
    if the view cannot supply K_inner distinct fragments.
    """
    frags: dict[int, bytes] = {}
    holders: list[Node] = []
    for m in members:
        served = m.serve_fragments(chash)
        took = False
        for idx, payload in served.items():
            if idx not in frags and len(frags) < meta.k_inner:
                frags[idx] = payload
                took = True
        if took:
            holders.append(m)
    if len(frags) < meta.k_inner:
        raise InsufficientFragments(
            f"repair: {len(frags)}/{meta.k_inner} fragments reachable"
        )
    traffic = sum(len(p) for p in frags.values())
    rtts = net.rtts(requester, holders) if holders else np.zeros(1)
    chunk = C.inner_decode(chash, meta.k_inner, frags)
    return chunk, traffic, float(np.max(rtts))


def repair_group(
    net: SimNetwork, node: Node, chash: bytes, cache_ttl: float = 0.0,
    max_new: int | None = None, pick=None,
) -> RepairStats:
    """One repair pass from ``node``'s local view (§4.3.4).

    Restores the group to ``R`` alive members (or as close as the candidate
    set allows). Returns traffic/latency accounting for the benchmarks.
    ``pick`` forwards to :func:`_locate_new_member` (response-order bias of
    the adaptive adversary; ``None`` = nearest-selected, the default).
    """
    stats = RepairStats()
    view = node.groups.get(chash)
    if view is None:
        return stats
    meta = view.meta
    # refresh the view first (MembershipTimer — §4.3.3)
    G.membership_timer(net, node, chash)
    alive = G.alive_members(net, node, chash)
    deficit = meta.r_target - len(alive)
    if max_new is not None:
        deficit = min(deficit, max_new)
    if deficit <= 0:
        return stats
    member_nodes = [net.nodes[nid] for nid in alive if net.nodes[nid].alive]
    exclude = set(alive)
    lat_worst = 0.0
    for _ in range(deficit):
        index = _fresh_index(net, view)
        fhash = C.fragment_hash(chash, index)
        found = _locate_new_member(net, chash, fhash, meta.r_target, exclude,
                                   pick=pick)
        if found is None:
            continue  # candidate set exhausted; next timer tick retries
        new_member, proof = found
        # RepairRequest: sender's view bootstraps the new member (§4.3.4)
        membership = {nid: net.now for nid in alive}
        lat = net.rtt(node, new_member)  # the RepairRequest round
        # (a) warm chunk cache anywhere in the view → one-fragment traffic
        warm = next(
            (m for m in member_nodes if m.cached_chunk(chash) is not None),
            None,
        )
        if warm is not None:
            chunk = warm.cached_chunk(chash)
            frag = C.inner_encode_fragment(chunk, chash, meta.k_inner, index)
            stats.traffic_bytes += len(frag)
            stats.cache_hits += 1
            lat += net.rtt(new_member, warm)
        else:
            # (b) pull K_inner fragments, decode, cache, re-encode
            try:
                chunk, traffic, pull_lat = _pull_and_decode(
                    net, new_member, chash, meta, member_nodes
                )
            except InsufficientFragments:
                continue  # incomplete view — MembershipTimer() will retry
            stats.traffic_bytes += traffic
            lat += pull_lat
            new_member.groups.setdefault(chash, GroupView(meta=meta))
            frag = C.inner_encode_fragment(chunk, chash, meta.k_inner, index)
        new_member.store_fragment(meta, index, frag, membership, proof)
        if cache_ttl > 0 and warm is None:
            new_member.cache_chunk(chash, chunk, cache_ttl)
        # merge into the repairing node's view too
        view.members[new_member.nid] = net.now
        exclude.add(new_member.nid)
        member_nodes.append(new_member)
        alive.append(new_member.nid)
        stats.repaired += 1
        lat_worst = max(lat_worst, lat)
    stats.latency_s = lat_worst
    net.repair_traffic_bytes += stats.traffic_bytes
    net.repair_count += stats.repaired
    return stats


def evict_oldest(net: SimNetwork, chash: bytes) -> int | None:
    """Force-evict the longest-standing member of a chunk group.

    Mirrors the paper's physical-deployment repair trigger ("a special
    command to force nodes to evict the oldest member that stores the
    chunk"). Returns the evicted node id, or None.
    """
    holders = [
        n for n in net.alive_nodes()
        if any(ch == chash for (ch, _i) in n.fragments)
        or chash in n.groups
    ]
    holders = [n for n in holders if chash in n.groups]
    if not holders:
        return None
    oldest = min(holders, key=lambda n: min(
        (t for t in n.groups[chash].members.values()), default=net.now
    ))
    net.fail_node(oldest.nid)
    return oldest.nid


def repair_all(
    net: SimNetwork, cache_ttl: float = 0.0
) -> RepairStats:
    """Run one repair tick across every node's local views (the steady-state
    background loop)."""
    total = RepairStats()
    for n in list(net.alive_nodes()):
        for chash in list(n.groups):
            s = repair_group(net, n, chash, cache_ttl=cache_ttl)
            total.repaired += s.repaired
            total.traffic_bytes += s.traffic_bytes
            total.cache_hits += s.cache_hits
            total.latency_s = max(total.latency_s, s.latency_s)
    return total
