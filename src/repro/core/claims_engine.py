"""Persistent array-table engine for the per-tick persistence-claim round.

``group.broadcast_claims`` + ``prune_dead_members`` — the PR 3 scalar path —
cost one ``verify_selection`` hash round-trip *and* several dict operations
per (claim, receiver) pair, every tick. The closed form of the round (see
:meth:`ClaimsEngine.round`) makes the verifications batchable, but a naive
per-round table build still pays O(members × viewers) dict traffic just to
re-write timestamps that change the same way every round. This engine keeps
the group state resident in arrays *between* rounds and touches Python
dicts only where the round actually changes something:

* **Membership** lives in a persistent presence matrix ``P[viewer, member]``
  per group. A steady round changes no membership at all — insertions
  (re-admissions) and prune deletions are rare events applied to the real
  ``GroupView.members`` dicts one by one, preserving the exact insertion
  order the scalar loop would produce.
* **Timestamps** are virtualized. A claim round refreshes almost every
  (viewer, member) pair to "now", so the engine stores one ``bulk_ts`` per
  view plus a small exception dict for the members that were *not*
  refreshed (dead, eclipsed, or unclaimed). The effective timestamp of a
  member is ``max(dict value, bulk_ts)`` — or ``max(dict value, exception
  entry)`` when tracked — which reproduces the reference prune decisions
  exactly while writing O(exceptions) instead of O(members) per view. Dict
  values written by shared protocol code (MembershipTimer merges, repair
  bootstraps) dominate via the ``max``, so external writes need no hook.
* **Verification** flags (does this viewer hold a verifying claim for this
  group?) are computed once per (re)ingest through
  ``selection.verify_selection_batch`` — one memoized batch VRF pass, a
  single vectorized ``kernels/prf_select`` dispatch on the ARX registry —
  and reused until the group is touched or the population count changes.
* **Cross-group batching**: every per-group table is a numpy *view* into a
  padded engine-level slab (:class:`_Pool` — ``P3`` is ``(n_groups, Vcap,
  Ccap)`` etc., pad presence False, pad row indices −1), so the whole
  round's dense algebra — liveness/eclipse gathers, the claim-delivery
  matrix identity, suspect screening, non-refresh detection, the bulk
  timestamp write, and the repair pre-check counts — runs as ONE dispatch
  over all groups instead of ~``n_groups`` small per-group evaluations.
  The pad invariants make the batch bit-identical to per-group math: a
  pad viewer can never be alive (row −1) and a pad member can never be
  present, so every pad lane is all-False through the whole identity.
  Only the rare event rows drop back to exact-order Python.

Groups mutated outside the round (repairs, timer merges) are marked dirty
via :meth:`touch` and re-ingested from their dicts at the next round; until
then the engine refuses to answer pre-check queries for them, so callers
fall back to the exact dict walk. Bit-compatibility of the whole scheme
against the scalar loop is enforced end-to-end by
``tests/test_protocol_golden.py``.

The claim round is deliberately adversary-agnostic: colluding/withholding
Byzantine nodes (``policies.ADV_COLLUDE``) hold valid selection proofs and
broadcast well-formed claims, so they pass this audit layer
indistinguishably from honest members — by design. Withholding is only
observable (and charged) at fragment pull time, where ``SimNetwork.row_ok``
rejects their corrupt payloads.
"""
from __future__ import annotations

import bisect
from itertools import islice

import numpy as np

from repro.core import chunks as C
from repro.core import selection as sel
from repro.core.network import Node, SimNetwork

_NEG_INF = float("-inf")

_TRIL: dict[int, np.ndarray] = {}


def _tril(n: int) -> np.ndarray:
    """Shared strictly-lower-triangular bool mask (read-only per size)."""
    t = _TRIL.get(n)
    if t is None:
        t = np.tril(np.ones((n, n), bool), k=-1)
        t.setflags(write=False)
        _TRIL[n] = t
    return t


def _cap(n: int) -> int:
    """Slab capacity for a requested length: ~25% headroom, 8-aligned."""
    return max(8, -(-(n + (n >> 2)) // 8) * 8)


class _Pool:
    """Padded per-group slabs, one leading group axis per table.

    ``P3[gi, :vlen[gi], :clen[gi]]`` is group ``gi``'s presence matrix;
    the other tables follow the same prefix convention. Slab space beyond
    a group's prefix keeps the pad invariants — presence/claim False, row
    indices −1 — so batched expressions over the full slabs are exact.
    """

    __slots__ = ("n", "vcap", "ccap", "P3", "claim3", "bulk3", "vrows3",
                 "colrows3", "vlen", "clen", "tracked3")

    def __init__(self, n: int, vcap: int, ccap: int):
        self.n = n
        self.vcap = vcap
        self.ccap = ccap
        self.P3 = np.zeros((n, vcap, ccap), bool)
        self.claim3 = np.zeros((n, vcap), bool)
        self.bulk3 = np.full((n, vcap), _NEG_INF)
        self.vrows3 = np.full((n, vcap), -1, np.int64)
        self.colrows3 = np.full((n, ccap), -1, np.int64)
        self.vlen = np.zeros(n, np.int64)
        self.clen = np.zeros(n, np.int64)
        # tracked3[gi, j, c] set => colnids[c] is already a stale_ts[j]
        # exception of group gi, so the virtual-timestamp walk may skip the
        # triple (it would find the entry present and write nothing).
        # Cleared whenever entries can be popped or rows rebuilt:
        # _apply_events event rows, _clear_slab.
        self.tracked3 = np.zeros((n, vcap, ccap), bool)


class _GState:
    """Resident claim-round state of one chunk group.

    The array attributes (``P``, ``claim_ok``, ``bulk_ts``, ``vrows``,
    ``colrows``) are views into the engine's :class:`_Pool` slabs —
    writes through them land in the batched tensors and vice versa.
    """

    __slots__ = ("chash", "anchor", "r_target", "gi", "vnids", "vrows",
                 "vpos", "views", "colnids", "colpos", "colrows", "P",
                 "claim_ok", "bulk_ts", "stale_ts", "nn", "counts",
                 "rows_v", "mlen", "st_rows")

    def __init__(self, chash: bytes, gi: int):
        self.chash = chash
        self.anchor = C.hash_point(chash)
        self.r_target = 0
        self.gi = gi                   # slab index in the engine pool
        self.vnids: list[int] = []     # viewer nids, ascending (turn order)
        self.vrows: np.ndarray | None = None
        self.vpos: dict[int, int] = {}
        self.views: list = []          # GroupView per viewer
        self.colnids: list[int] = []   # member-universe nids
        self.colpos: dict[int, int] = {}
        self.colrows: np.ndarray | None = None
        self.P: np.ndarray | None = None      # [V, C] presence (view)
        self.claim_ok: np.ndarray | None = None
        self.bulk_ts: np.ndarray | None = None
        self.stale_ts: list[dict[int, float]] = []
        self.st_rows: set[int] = set()  # viewer rows with stale exceptions
        self.nn = -1                   # population count claim_ok was keyed on
        self.counts: np.ndarray | None = None
        self.rows_v = -1               # net.rows_version the row arrays match
        self.mlen: list[int] = []      # len(view.members) at last table sync


class ClaimsEngine:
    """Array-resident claims rounds + repair pre-check counts for one net."""

    def __init__(self, net: SimNetwork):
        self.net = net
        self.groups: dict[bytes, _GState] = {}
        self.dirty: set[bytes] = set()
        self._started = False
        self._pool: _Pool | None = None
        self._by_gi: list[_GState] = []

    # -------------------------------------------------------------- slabs
    def _rebind(self, g: _GState) -> None:
        """Re-derive ``g``'s array views from its pool slab prefix."""
        pool = self._pool
        gi = g.gi
        V, Cn = int(pool.vlen[gi]), int(pool.clen[gi])
        g.P = pool.P3[gi, :V, :Cn]
        g.claim_ok = pool.claim3[gi, :V]
        g.bulk_ts = pool.bulk3[gi, :V]
        g.vrows = pool.vrows3[gi, :V]
        g.colrows = pool.colrows3[gi, :Cn]

    def _ensure_capacity(self, V: int, Cn: int) -> None:
        """Grow the pool slabs (copy + rebind every group) when a group
        outgrows them. Headroom in :func:`_cap` keeps this rare; the copy
        is a few MB of bools at protocol scale."""
        pool = self._pool
        if V <= pool.vcap and Cn <= pool.ccap:
            return
        vcap = pool.vcap if V <= pool.vcap else _cap(V)
        ccap = pool.ccap if Cn <= pool.ccap else _cap(Cn)
        # viewers lead the column order, so round()'s viewer-viewer block
        # slice ``P3[:, :, :vcap]`` needs ccap >= vcap. Column-only growth
        # (_patch light path) can push ccap ahead of vcap; a later
        # viewer-side grow that crosses vcap but not ccap must not leave
        # the column slab narrower than the viewer slab.
        ccap = max(ccap, vcap)
        new = _Pool(pool.n, vcap, ccap)
        new.P3[:, :pool.vcap, :pool.ccap] = pool.P3
        new.claim3[:, :pool.vcap] = pool.claim3
        new.bulk3[:, :pool.vcap] = pool.bulk3
        new.vrows3[:, :pool.vcap] = pool.vrows3
        new.colrows3[:, :pool.ccap] = pool.colrows3
        new.vlen[:] = pool.vlen
        new.clen[:] = pool.clen
        new.tracked3[:, :pool.vcap, :pool.ccap] = pool.tracked3
        self._pool = new
        for g in self.groups.values():
            self._rebind(g)

    def _clear_slab(self, gi: int) -> None:
        """Reset one group's slab to the pad invariants."""
        pool = self._pool
        pool.P3[gi] = False
        pool.claim3[gi] = False
        pool.bulk3[gi] = _NEG_INF
        pool.vrows3[gi] = -1
        pool.colrows3[gi] = -1
        pool.tracked3[gi] = False

    # -------------------------------------------------------------- ingest
    def touch(self, chash: bytes) -> None:
        """Mark a group's dicts as mutated outside the engine (repairs)."""
        if self._started:
            self.dirty.add(chash)

    def _discover(self, nodes: list[Node]) -> None:
        """First round only: full scan for the group universe (object
        stores all happen before the first tick, so no new group hash can
        appear afterwards — later viewer changes ride the dirty path)."""
        seeds: dict[bytes, list[int]] = {}
        for node in nodes:
            for chash in node.groups:
                seeds.setdefault(chash, []).append(node.nid)
        self._pool = _Pool(len(seeds), 8, 8)
        for chash in seeds:
            g = _GState(chash, len(self._by_gi))
            self.groups[chash] = g
            self._by_gi.append(g)
        for chash, nids in seeds.items():
            self._ingest(self.groups[chash], seed=nids)

    def _ingest(self, g: _GState, seed: list[int] | None = None) -> None:
        """(Re)build a group's tables from the live view dicts.

        Keeps the virtual-timestamp state of surviving viewers: an
        exception entry is reconciled with the (possibly newer) dict value
        via ``max`` at read time, so external writes since the last round
        are honored without bookkeeping here.
        """
        net = self.net
        old_bulk = dict(zip(g.vnids, g.bulk_ts)) if g.bulk_ts is not None \
            else {}
        old_stale = dict(zip(g.vnids, g.stale_ts))
        # viewer closure: previous viewers (or the discovery seed), plus
        # any node referenced by a member dict that holds a view — a new
        # repair member always appears in the repairing node's view, so
        # the closure is complete
        frontier = list(g.vnids) + list(seed or ())
        seen = set()
        vn: list[int] = []
        alive = net.alive_set
        while frontier:
            nid = frontier.pop()
            if nid in seen:
                continue
            seen.add(nid)
            # dead viewers never broadcast, receive, prune, or repair
            # again (no resurrection), so they are dropped from the
            # tables outright — without this the viewer matrices grow by
            # every churn replacement ever repaired in, and the O(V²)
            # round cost creeps up tick over tick. Alive-only traversal
            # stays complete: a new member always appears in the (alive)
            # repairing node's view.
            if nid not in alive:
                continue
            node = net.nodes[nid]
            view = node.groups.get(g.chash)
            if view is None:
                continue
            bisect.insort(vn, nid)
            frontier.extend(view.members)
        g.vnids = vn
        g.vpos = {nid: j for j, nid in enumerate(vn)}
        g.views = [net.nodes[nid].groups[g.chash] for nid in vn]
        g.rows_v = net.rows_version
        g.r_target = g.views[0].meta.r_target if g.views else 0
        # member universe: every viewer plus every member nid
        cols: list[int] = list(vn)
        colpos = {nid: c for c, nid in enumerate(cols)}
        for view in g.views:
            for nid in view.members:
                if nid not in colpos:
                    colpos[nid] = len(cols)
                    cols.append(nid)
        g.colnids = cols
        g.colpos = colpos
        row_of = net.row_of
        V, Cn = len(vn), len(cols)
        self._ensure_capacity(V, Cn)
        pool = self._pool
        self._clear_slab(g.gi)
        pool.vlen[g.gi] = V
        pool.clen[g.gi] = Cn
        self._rebind(g)
        g.vrows[...] = np.fromiter((row_of[nid] for nid in vn), np.int64, V)
        g.colrows[...] = np.fromiter((row_of.get(nid, -1) for nid in cols),
                                     np.int64, Cn)
        for j, view in enumerate(g.views):
            row = g.P[j]
            for nid in view.members:
                row[colpos[nid]] = True
        g.bulk_ts[...] = np.fromiter(
            (old_bulk.get(nid, _NEG_INF) for nid in vn), np.float64, V)
        g.stale_ts = [old_stale.get(nid) or {} for nid in vn]
        g.st_rows = {j for j, st in enumerate(g.stale_ts) if st}
        g.counts = None
        g.mlen = [len(v.members) for v in g.views]
        self._verify_claims(g)

    def _patch(self, g: _GState) -> bool:
        """Apply an add-only membership delta to the resident tables.

        Between rounds, shared protocol code only ever *adds* members to
        view dicts (repair placements, MembershipTimer re-admissions) —
        prunes happen inside the round, which keeps the tables in sync
        itself. So a dirty group's per-view growth since the last sync
        point (``mlen``) locates every membership change, and the tables
        are patched in O(changed entries) instead of the full O(V × C)
        dict rebuild of :meth:`_ingest`. At 10K nodes this is the
        difference between the claim round riding repairs for free and
        re-ingests dominating the tick. Returns False when the delta
        cannot be expressed (caller falls back to the full ingest).

        Matches ``_ingest`` observably: sorted viewer (turn) order, the
        same bulk/stale timestamp carry-over, ``-inf`` bulk stamps for
        new viewers (→ a full prune scan on their first turn), and
        ``claim_ok`` recomputed only for the new rows — existing viewers'
        proof sets cannot change outside a (re)ingest, and a population
        shift re-keys every row in :meth:`round` regardless. The one
        divergence — dead viewers are *kept* instead of dropped — is
        behavior-neutral (their send/recv lanes are liveness-masked) and
        bounded by the compaction trigger in :meth:`round`.
        """
        net = self.net
        V = len(g.vnids)
        if V == 0 or g.P is None or len(g.mlen) != V:
            return False
        grown = [j for j in range(V)
                 if len(g.views[j].members) != g.mlen[j]]
        if not grown:
            return True        # timestamp-only touch: tables still exact
        # -- discover new nids and viewer promotions. Closure argument: a
        # new repair member always appears in the repairing viewer's
        # (grown) view, and new viewers' own views only reference nids
        # already known or found by this scan.
        colpos = g.colpos
        vpos = g.vpos
        alive = net.alive_set
        nodes = net.nodes

        def _viewer(nid: int):
            if nid not in alive:
                return None
            node = nodes.get(nid)
            return None if node is None else node.groups.get(g.chash)

        new_cols: list[int] = []       # nids with no column yet
        promote: dict[int, object] = {}  # nid -> view (needs a viewer row)
        seen: set[int] = set()
        stack: list[int] = []
        # add-only delta => the new entries are exactly the dict TAIL of
        # each grown view (insertion order), so the discovery scan walks
        # only len(members) - mlen[j] nids, not the whole view
        n_new = {j: len(g.views[j].members) - g.mlen[j] for j in grown}
        for j in grown:
            for nid in islice(reversed(g.views[j].members), n_new[j]):
                if nid in seen:
                    continue
                seen.add(nid)
                if nid not in colpos:
                    stack.append(nid)
                elif nid not in vpos:
                    # existing member-only column that acquired a view
                    # since the last ingest (repair target drawn from a
                    # stale view): the full rebuild would admit it as a
                    # viewer now, so must we
                    view = _viewer(nid)
                    if view is not None:
                        promote[nid] = view
        while stack:
            nid = stack.pop()
            new_cols.append(nid)
            view = _viewer(nid)
            if view is None:
                continue
            promote[nid] = view
            for m in view.members:
                if m in seen or m in colpos:
                    continue
                seen.add(m)
                stack.append(m)
        grown_nids = [g.vnids[j] for j in grown]
        if not promote:
            # light path: new bits (and maybe new member-only columns) only
            if new_cols:
                C0 = len(g.colnids)
                self._ensure_capacity(V, C0 + len(new_cols))
                pool = self._pool
                row_of = net.row_of
                pool.colrows3[g.gi, C0:C0 + len(new_cols)] = np.fromiter(
                    (row_of.get(nid, -1) for nid in new_cols), np.int64,
                    len(new_cols))
                pool.clen[g.gi] = C0 + len(new_cols)
                for nid in new_cols:
                    colpos[nid] = len(g.colnids)
                    g.colnids.append(nid)
                self._rebind(g)  # widen the P/colrows views
            for j in grown:
                view = g.views[j]
                row = g.P[j]
                # old members' bits are already set — tail only
                for nid in islice(reversed(view.members), n_new[j]):
                    row[colpos[nid]] = True
                g.mlen[j] = len(view.members)
            g.counts = None
            return True
        # -- new viewer rows: rebuild the index arrays around a sorted
        # merge, permuting the old table blocks into place
        vn_new = sorted(set(g.vnids) | set(promote))
        V2 = len(vn_new)
        vpos2 = {nid: j for j, nid in enumerate(vn_new)}
        old_view = dict(zip(g.vnids, g.views))  # keeps reaped viewers' refs
        views2 = [old_view.get(nid) or promote[nid] for nid in vn_new]
        row_of = net.row_of
        tail = ([nid for nid in g.colnids if nid not in vpos2]
                + [nid for nid in new_cols if nid not in vpos2])
        cols2 = vn_new + tail
        colpos2 = {nid: c for c, nid in enumerate(cols2)}
        rmap = np.fromiter((vpos2[nid] for nid in g.vnids), np.int64, V)
        cmap = np.fromiter((colpos2[nid] for nid in g.colnids), np.int64,
                           len(g.colnids))
        P2 = np.zeros((V2, len(cols2)), bool)
        P2[np.ix_(rmap, cmap)] = g.P
        bulk2 = np.full(V2, _NEG_INF)
        bulk2[rmap] = g.bulk_ts
        stale2: list[dict[int, float]] = [{} for _ in range(V2)]
        for j, st in zip(rmap, g.stale_ts):
            stale2[j] = st
        claim2 = np.zeros(V2, bool)
        claim2[rmap] = g.claim_ok
        old_rows = set(int(j) for j in rmap)
        proofs, owners = [], []
        for j2, nid in enumerate(vn_new):
            if j2 in old_rows:
                continue
            node = nodes.get(nid)
            if node is None:
                continue
            for proof in node.claim_proofs_by_chash.get(
                    g.chash, {}).values():
                proofs.append(proof)
                owners.append(j2)
        if proofs:
            okv = sel.verify_selection_batch(
                net.registry, proofs, [g.anchor] * len(proofs), g.r_target,
                net.n_nodes)
            np.logical_or.at(claim2, owners, okv)
        g.vnids = vn_new
        g.vpos = vpos2
        g.views = views2
        g.rows_v = net.rows_version
        g.colnids = cols2
        g.colpos = colpos2
        self._ensure_capacity(V2, len(cols2))
        pool = self._pool
        self._clear_slab(g.gi)
        pool.vlen[g.gi] = V2
        pool.clen[g.gi] = len(cols2)
        self._rebind(g)
        g.P[...] = P2
        g.claim_ok[...] = claim2
        g.bulk_ts[...] = bulk2
        g.vrows[...] = np.fromiter((row_of.get(nid, -1) for nid in vn_new),
                                   np.int64, V2)
        g.colrows[...] = np.fromiter((row_of.get(nid, -1) for nid in cols2),
                                     np.int64, len(cols2))
        g.stale_ts = stale2
        g.st_rows = {j for j, st in enumerate(stale2) if st}
        n_new_nid = {grown_nids[i]: n_new[j] for i, j in enumerate(grown)}
        for nid in set(grown_nids) | set(promote):
            j2 = vpos2[nid]
            row = g.P[j2]
            mem = views2[j2].members
            # promoted rows start all-zero and need the full view; grown
            # rows carried their old bits through the permutation — tail
            tail = n_new_nid.get(nid)
            it = mem if tail is None else islice(reversed(mem), tail)
            for m in it:
                row[colpos2[m]] = True
        g.counts = None
        g.mlen = [len(v.members) for v in views2]
        return True

    def _refresh_rows(self, g: _GState) -> None:
        """Re-derive cached row-index gathers after a row-table compaction.

        ``SimNetwork._compact_rows`` renumbers ``Node.row``, so any stale
        ``vrows``/``colrows`` would index the wrong liveness slots. Reaped
        (dead) nids are no longer in ``row_of`` and map to -1 — callers
        gather through a ``>= 0`` mask, which reproduces exactly the
        "row present but alive_rows False" answer the pre-reaper tables
        gave for dead nodes.
        """
        row_of = self.net.row_of
        g.vrows[...] = np.fromiter((row_of.get(nid, -1) for nid in g.vnids),
                                   np.int64, len(g.vnids))
        g.colrows[...] = np.fromiter(
            (row_of.get(nid, -1) for nid in g.colnids), np.int64,
            len(g.colnids))
        g.rows_v = self.net.rows_version

    def _verify_claims(self, g: _GState) -> None:
        """claim_ok[v]: viewer holds >= 1 verifying claim proof (batched).

        Reaped viewers (dead since the last ingest) contribute no proofs —
        behavior-neutral, since a dead viewer's ``claim_ok`` is always
        masked by the liveness gather before use."""
        net = self.net
        proofs, owners = [], []
        for j, nid in enumerate(g.vnids):
            node = net.nodes.get(nid)
            if node is None:
                continue
            for proof in node.claim_proofs_by_chash.get(
                    g.chash, {}).values():
                proofs.append(proof)
                owners.append(j)
        g.claim_ok[...] = False
        if proofs:
            ok = sel.verify_selection_batch(
                net.registry, proofs, [g.anchor] * len(proofs), g.r_target,
                net.n_nodes)
            np.logical_or.at(g.claim_ok, owners, ok)
        g.nn = net.n_nodes

    # --------------------------------------------------------------- round
    def round(self, nodes: list[Node], timeout_s: float) -> None:
        """One claim round — bit-identical to the scalar loop::

            for node in nodes:                      # ring order
                if eclipsed(node): continue
                broadcast_claims(net, node)
                prune_dead_members(net, node, timeout_s)

        Closed form (``pos`` = turn order, ``M0`` = pre-round views): for a
        receiver R earlier than sender S, S's view may already contain R's
        own refresh, so ``A(S→R) = ok(S→R) ∧ (R ∈ M0(S) ∨ A0(R→S))``;
        for a later receiver ``A(S→R) = ok(S→R) ∧ R ∈ M0(S)`` — one
        boolean matrix identity per group, evaluated for ALL groups in a
        single dispatch over the pool slabs. Membership edits and prune
        decisions are applied to the real dicts in exact turn order;
        timestamps refresh virtually (``bulk_ts`` + exceptions).

        Batching across groups is exact because round turns only ever
        touch the turning group's own state: views are keyed by chash, so
        one group's event application can neither observe nor perturb
        another group's algebra — phase order (all gathers, all events,
        all non-refresh tracking, one bulk write) equals group order.
        """
        net = self.net
        now = net.now
        if not self._started:
            self._started = True
            self._discover(nodes)
        for chash in self.dirty:
            g = self.groups.get(chash)
            if g is not None and not self._patch(g):
                self._ingest(g)
        self.dirty.clear()
        pool = self._pool
        if pool is None or pool.n == 0:
            return
        groups = self._by_gi
        nn = net.n_nodes
        rv = net.rows_version
        for g in groups:
            if not g.vnids:
                continue
            if g.nn != nn:
                self._verify_claims(g)  # population shift re-keys Alg. 2
            if g.rows_v != rv:
                self._refresh_rows(g)
        alive_rows = net.alive_rows
        # --- one batched liveness gather + dead-viewer compaction screen
        vr = pool.vrows3
        valid = vr >= 0
        va3 = valid & alive_rows[np.where(valid, vr, 0)]
        dead = pool.vlen - va3.sum(axis=1)
        need = np.nonzero(dead > np.maximum(8, pool.vlen // 8))[0]
        if need.size:
            # enough viewers died since the last ingest: compact those
            # groups' tables (amortized O(1) per death; keeps V ~ alive)
            for gi in need.tolist():
                self._ingest(groups[gi])
            pool = self._pool  # _ingest may have grown the slabs
            vr = pool.vrows3
            valid = vr >= 0
            va3 = valid & alive_rows[np.where(valid, vr, 0)]
        if net.eclipse is not None:
            recv3 = va3 & ~(valid & net.eclipsed_rows[
                np.where(valid, vr, 0)])
        else:
            recv3 = va3
        # --- the claim-delivery identity, all groups at once
        vcap = pool.vcap
        send3 = pool.claim3 & recv3
        m03 = pool.P3[:, :, :vcap]  # viewer-viewer block (viewers lead)
        okm3 = send3[:, :, None] & recv3[:, None, :]
        d = np.arange(vcap)
        okm3[:, d, d] = False
        a03 = okm3 & m03
        a3 = okm3 & (m03 | (_tril(vcap)[None] & a03.transpose(0, 2, 1)))
        # --- rare membership events -----------------------------------
        # a view needs a prune pass when it tracks a timestamp
        # exception OR its bulk refresh is itself near the timeout
        # (first round; a viewer returning from an eclipse window) —
        # then every member must be checked, like the reference does.
        # Insertion = the SENDER is new to the RECEIVER's view:
        # m0[j, s] is "s ∈ view(j)", so the test for edge (s, r) is
        # ~m0[r, s] — the transpose, not ~m0[s, r].
        ig, isx, irx = np.nonzero(a3 & ~m03.transpose(0, 2, 1))
        ins_by_g: dict[int, tuple[list[int], list[int]]] = {}
        for gi, s, r in zip(ig.tolist(), isx.tolist(), irx.tolist()):
            pair = ins_by_g.get(gi)
            if pair is None:
                pair = ins_by_g[gi] = ([], [])
            pair[0].append(s)
            pair[1].append(r)
        suspect3 = recv3 & (now - pool.bulk3 > timeout_s)
        sus_set = set(np.nonzero(suspect3.any(axis=1))[0].tolist())
        for g in groups:
            V = len(g.vnids)
            if V == 0:
                continue
            gi = g.gi
            pair = ins_by_g.get(gi)
            if pair is None and gi not in sus_set and not g.st_rows:
                continue
            a = a3[gi, :V, :V]
            recv = recv3[gi, :V]
            suspect = suspect3[gi, :V]
            ins_s, ins_r = pair if pair is not None else ((), ())
            ins_set = set(ins_r)
            # A stale-exception turn with no insertions and a fresh bulk
            # stamp is a complete no-op unless some tracked entry would
            # actually fire: either its tracked timestamp already exceeds
            # the timeout (the tracked value lower-bounds the effective
            # one, so a real prune implies this test fires — conservative),
            # or a live sender edge into this view would pop it. Scanning
            # just the tracked entries here lets ``_apply_events`` skip the
            # (numerous) turns that would only walk their dicts and return.
            stale_slow: set[int] = set()
            for j in g.st_rows:
                if not recv[j] or j in ins_set or suspect[j]:
                    continue
                for nid, ts in g.stale_ts[j].items():
                    if now - ts > timeout_s:
                        stale_slow.add(j)
                        break
                    sidx = g.vpos.get(nid)
                    if sidx is not None and sidx != j and a[sidx, j]:
                        stale_slow.add(j)
                        break
            events = sorted(
                ins_set
                | {int(j) for j in np.nonzero(suspect)[0]}
                | stale_slow)
            if events:
                self._apply_events(g, a, ins_s, ins_r, events, suspect,
                                   now, timeout_s)
                g.mlen = [len(v.members) for v in g.views]
        # --- virtual timestamp maintenance (all groups at once) -------
        # P3 reflects the event edits (the per-group tables are views into
        # it), while a3 is the pre-event delivery matrix — exactly the
        # pairing the per-group evaluation used.
        nonrefr3 = pool.P3 & recv3[:, :, None]
        nonrefr3[:, :, :vcap] &= ~a3.transpose(0, 2, 1)
        nonrefr3[:, d, d] = False  # self-entry: never
        # already-tracked triples are no-ops here (the entry exists, the
        # write is skipped, the row is in st_rows), so the Python walk
        # covers only the NEW exceptions of this round
        nonrefr3 &= ~pool.tracked3
        ng, nr, nc = np.nonzero(nonrefr3)
        if ng.size:
            pool.tracked3[ng, nr, nc] = True
            g = None
            last_gi = -1
            for gi, j, c in zip(ng.tolist(), nr.tolist(), nc.tolist()):
                if gi != last_gi:  # nonzero is group-major: cheap run cut
                    g = groups[gi]
                    last_gi = gi
                st = g.stale_ts[j]
                nid = g.colnids[c]
                if nid not in st:
                    last = g.views[j].members[nid]
                    bulk = g.bulk_ts[j]
                    st[nid] = last if last > bulk else bulk
                g.st_rows.add(j)
        pool.bulk3[recv3] = now
        for g in groups:
            g.counts = None

    def _apply_events(self, g: _GState, a, ins_s, ins_r, events, suspect,
                      now: float, timeout_s: float) -> None:
        """Apply insertions and prunes to the real dicts in turn order."""
        tracked3 = self._pool.tracked3
        ins_by_r: dict[int, list[int]] = {}
        for s, r in zip(ins_s, ins_r):
            ins_by_r.setdefault(int(r), []).append(int(s))
        for j in events:
            view = g.views[j]
            mem = view.members
            self_nid = g.vnids[j]
            st = g.stale_ts[j]
            senders = sorted(ins_by_r.get(j, ()))
            if not suspect[j] and not st:
                # pure-insert turn (the common case: a fresh repair
                # member's claim landing in up-to-date views): no tracked
                # exceptions and a fresh bulk stamp mean the prune scan is
                # provably empty, so the turn reduces to the insertions —
                # in the same dict order the full turn would produce
                # (before-turn senders then after-turn senders, both
                # ascending; ``readds`` needs a prune to be non-empty)
                for s in senders:
                    mem[g.vnids[s]] = now
                    g.P[j, s] = True
                g.st_rows.discard(j)
                continue
            # general (prune-capable) turn: each popped stale_ts entry
            # drops exactly its own tracked bit (entries that survive keep
            # theirs — clearing whole rows would make every insertion turn
            # re-walk its exceptions next round). Pure-insert turns pop
            # nothing (st empty by the branch condition => no tracked
            # bits, invariant) and skip all of this.
            trow = tracked3[g.gi, j]
            colpos = g.colpos
            k = bisect.bisect_left(senders, j)
            for s in senders[:k]:       # inserted before j's own turn
                mem[g.vnids[s]] = now
                g.P[j, s] = True
                if st.pop(g.vnids[s], None) is not None:
                    trow[s] = False
            # ---- j's own turn: the prune pass -------------------------
            scan = (mem if suspect[j] else list(st))
            readds: list[int] = []  # pruned members re-added after the turn
            for nid in list(scan):
                if nid == self_nid:
                    continue            # reference never prunes self
                sidx = g.vpos.get(nid)
                edge = sidx is not None and sidx != j and a[sidx, j]
                if edge and sidx < j:
                    # refreshed before the turn: fresh
                    if st.pop(nid, None) is not None:
                        trow[colpos[nid]] = False
                    continue
                if nid not in mem:
                    # vanished externally (re-ingest); may predate the
                    # current column universe, hence the colpos guard
                    if st.pop(nid, None) is not None:
                        cp = colpos.get(nid)
                        if cp is not None:
                            trow[cp] = False
                    continue
                last = mem[nid]
                tracked = st.get(nid)
                eff = last
                if tracked is not None and tracked > eff:
                    eff = tracked
                if tracked is None and g.bulk_ts[j] > eff:
                    eff = g.bulk_ts[j]
                if now - eff > timeout_s:   # the reference prune test
                    del mem[nid]
                    st.pop(nid, None)
                    cp = colpos[nid]
                    g.P[j, cp] = False
                    trow[cp] = False
                    if edge:            # re-added at the sender's turn
                        readds.append(sidx)
                elif edge:
                    # refreshed after the turn
                    if st.pop(nid, None) is not None:
                        trow[colpos[nid]] = False
            # post-turn events land in sender-turn order: fresh inserts
            # and prune-then-readd claims interleave on that one axis
            for s in sorted(senders[k:] + readds):
                mem[g.vnids[s]] = now
                g.P[j, s] = True
                if st.pop(g.vnids[s], None) is not None:
                    trow[s] = False
            if not st:
                g.st_rows.discard(j)

    # ----------------------------------------------------- repair pre-check
    def precheck_count(self, nid: int, chash: bytes) -> int | None:
        """Alive-member count of ``nid``'s view, or None if the engine
        cannot vouch for it (dirty group / unknown view) — callers then
        fall back to the exact dict walk."""
        if chash in self.dirty:
            return None
        g = self.groups.get(chash)
        if g is None:
            return None
        j = g.vpos.get(nid)
        if j is None:
            return None
        if g.counts is None:
            if g.rows_v != self.net.rows_version:
                self._refresh_rows(g)
            alive_cols = np.zeros(len(g.colnids), bool)
            valid = g.colrows >= 0
            alive_cols[valid] = self.net.alive_rows[g.colrows[valid]]
            g.counts = (g.P & alive_cols[None, :]).sum(axis=1)
        return int(g.counts[j])

    def under_r_visits(self, registry: dict,
                       r_inner: int) -> dict[int, dict[bytes, int]]:
        """Alive-member counts of every under-``R`` (viewer, group) pair.

        ONE liveness gather over the pool slabs counts every view of
        every group (liveness is fixed for the whole repair tick, so the
        counts are exact until a view mutates) and returns ``{viewer nid:
        {chash: count}}`` for the pairs strictly below ``r_inner``. The
        computed count rows are also cached on the groups for
        :meth:`precheck_count`."""
        net = self.net
        pool = self._pool
        if pool is None or pool.n == 0:
            return {}
        rv = net.rows_version
        groups = self._by_gi
        for g in groups:
            if g.rows_v != rv:
                self._refresh_rows(g)
        alive_rows = net.alive_rows
        cr = pool.colrows3
        validc = cr >= 0
        ac3 = validc & alive_rows[np.where(validc, cr, 0)]
        counts3 = (pool.P3 & ac3[:, None, :]).sum(axis=2)
        vmask = np.arange(pool.vcap)[None, :] < pool.vlen[:, None]
        ug, uj = np.nonzero(vmask & (counts3 < r_inner))
        visit: dict[int, dict[bytes, int]] = {}
        for gi, j in zip(ug.tolist(), uj.tolist()):
            g = groups[gi]
            if g.chash not in registry:
                continue
            visit.setdefault(g.vnids[j], {})[g.chash] = int(counts3[gi, j])
        for g in groups:
            g.counts = counts3[g.gi, :len(g.vnids)]
        return visit

    def begin_repair_tick(self) -> None:
        """Invalidate cached counts (liveness changed since last tick)."""
        for g in self.groups.values():
            g.counts = None
