"""Vectorized discrete-event simulation of VAULT at paper scale (§6.1).

The protocol-level simulator (``SimNetwork`` + ``repair.py``) executes real
coding and real selection proofs — ideal for correctness, too slow for the
paper's 100K-node × 10K-object × 1-year sweeps. This module simulates the
same dynamics at *group granularity* with numpy array updates, exactly the
abstraction the paper's own discrete-event simulator uses:

* each chunk group is (honest members, byzantine claimers, cache timestamp);
* churn is Poisson per node ⇒ binomial thinning per step;
* repair refills groups to ``R`` when membership (honest + byzantine claims)
  drops below it, drawing new members i.i.d. from the population mix — valid
  because VRF selection is uniform (§3.3);
* a chunk dies when honest fragments < K_inner (decode impossible ⇒
  absorbing, per the CTMC model);
* repair traffic: ``K_inner`` fragments per repaired fragment on cache miss
  (the repairer then caches the chunk), one fragment on cache hit — see
  repair.py docstring for why this is the Fig.4-consistent reading. A
  cached copy is warm only while its TTL holds AND at least one of its
  holder nodes is still alive (holders churn like everyone else).

Traffic is reported in *object-size units* (the paper's unit). The Ceph-like
replicated baseline (§6.1) is simulated under identical churn.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import policies as P

HOURS_PER_YEAR = P.HOURS_PER_YEAR


@dataclasses.dataclass(frozen=True)
class SimParams:
    n_nodes: int = 100_000
    n_objects: int = 1_000
    byz_fraction: float = 0.0
    churn_per_year: float = 4.0  # expected failures per node-year
    k_outer: int = 8
    n_chunks: int = 10
    k_inner: int = 32
    r_inner: int = 80
    cache_ttl_hours: float = 0.0
    step_hours: float = 6.0
    years: float = 1.0
    seed: int = 0
    # churn policy for the reference path: "iid" (default, bit-stable) or
    # "diurnal" (sinusoidally modulated rate, policies.diurnal_p_fail);
    # richer policies live in the batched engine / protocol simulator
    churn_policy: int | str = "iid"
    diurnal_amplitude: float = 0.6

    @property
    def frag_units(self) -> float:
        """Fragment size in object units."""
        return 1.0 / (self.k_outer * self.k_inner)

    @property
    def chunk_units(self) -> float:
        return 1.0 / self.k_outer

    @property
    def redundancy(self) -> float:
        return (self.n_chunks / self.k_outer) * (self.r_inner / self.k_inner)


@dataclasses.dataclass
class SimResult:
    repair_traffic_units: float
    lost_objects: int
    n_objects: int
    repairs: int
    cache_hits: int
    final_honest_mean: float

    @property
    def lost_fraction(self) -> float:
        return self.lost_objects / max(self.n_objects, 1)


def simulate_vault(p: SimParams) -> SimResult:
    """One VAULT run: returns repair traffic + object losses."""
    rng = np.random.default_rng(p.seed)
    n_groups = p.n_objects * p.n_chunks
    # initial placement: R members drawn from the population mix
    byz = rng.binomial(p.r_inner, p.byz_fraction, size=n_groups)
    honest = p.r_inner - byz
    alive = honest >= p.k_inner
    cache_t = np.zeros(n_groups)  # client seeds caches at store time (t=0)
    has_cache = p.cache_ttl_hours > 0.0
    # cached-copy holder counts: the storing client seeds all R members;
    # holders churn like any node, and a copy is warm only while ≥1 holder
    # survives (matches the batched engine's churn-aware cache model)
    cache_h = np.full(n_groups, p.r_inner if has_cache else 0)
    churn_id = P.churn_policy_id(p.churn_policy)
    p_fail_base = P.p_fail_step(p.churn_per_year, p.step_hours, xp=np)
    steps = int(round(p.years * HOURS_PER_YEAR / p.step_hours))
    traffic = 0.0
    repairs = 0
    cache_hits = 0
    now = 0.0
    for t in range(steps):
        now += p.step_hours
        # per-step rate: identical to p_fail_base except under diurnal
        # modulation (value-identical where(), keeping iid runs bit-stable)
        p_fail = float(P.diurnal_p_fail(
            churn_id, p.churn_per_year, p.diurnal_amplitude, t,
            p.step_hours, p_fail_base, xp=np))
        # --- churn: binomial thinning of members (honest & byzantine churn)
        lost_h = rng.binomial(honest, p_fail)
        lost_b = rng.binomial(byz, p_fail)
        honest = honest - lost_h
        byz = byz - lost_b
        if has_cache:
            # cache holders churn too; guarded so the rng stream of
            # cache-free runs is untouched
            cache_h = cache_h - rng.binomial(cache_h, p_fail)
        # --- absorbing check: decode impossible below K_inner honest
        alive &= honest >= p.k_inner
        # --- repair: refill to R where membership dropped (alive groups)
        deficit = np.where(alive, p.r_inner - (honest + byz), 0)
        deficit = np.maximum(deficit, 0)
        new_b = rng.binomial(deficit, p.byz_fraction)
        honest = honest + (deficit - new_b)
        byz = byz + new_b
        repaired = deficit  # fragments regenerated this step
        n_rep = int(repaired.sum())
        if n_rep:
            repairs += n_rep
            if has_cache:
                warm = ((now - cache_t) <= p.cache_ttl_hours) & (cache_h >= 1)
                hit_frags = np.where(warm, repaired, np.maximum(repaired - 1, 0))
                miss_pulls = np.where(~warm & (repaired > 0), 1, 0)
                traffic += float(hit_frags.sum()) * p.frag_units
                traffic += float(miss_pulls.sum()) * p.chunk_units
                cache_hits += int(hit_frags.sum())
                # a cache miss makes the repairer cache the chunk afresh
                cache_t = np.where(miss_pulls > 0, now, cache_t)
                cache_h = np.where(miss_pulls > 0, 1, cache_h)
            else:
                traffic += float(repaired.sum()) * p.k_inner * p.frag_units
    chunks_alive = alive.reshape(p.n_objects, p.n_chunks).sum(axis=1)
    lost = int((chunks_alive < p.k_outer).sum())
    return SimResult(
        repair_traffic_units=traffic,
        lost_objects=lost,
        n_objects=p.n_objects,
        repairs=repairs,
        cache_hits=cache_hits,
        final_honest_mean=float(honest[alive].mean()) if alive.any() else 0.0,
    )


def simulate_replicated(p: SimParams, replication: int = 3) -> SimResult:
    """Ceph-like baseline under identical churn: r random replicas, eager
    repair (one object of traffic per re-replication).

    Byzantine model: replicas are *not verifiable* (no content addressing of
    repair sources in a plain replicated store), so a repair that copies
    from a Byzantine claimer — indistinguishable from an honest holder —
    yields a bad replica. Good-replica count therefore decays contagiously;
    the object is lost when no good replica remains. This is what collapses
    the baseline at small Byzantine fractions in Fig. 6, while VAULT is
    immune: its fragments are content-verified against the chunk hash, so
    Byzantine peers can only *withhold*, never poison.
    """
    rng = np.random.default_rng(p.seed + 1)
    good = replication - rng.binomial(
        replication, p.byz_fraction, size=p.n_objects
    )
    bad = replication - good  # byzantine-claimed or poisoned slots
    alive = good >= 1
    p_fail = P.p_fail_step(p.churn_per_year, p.step_hours, xp=np)
    steps = int(round(p.years * HOURS_PER_YEAR / p.step_hours))
    traffic = 0.0
    repairs = 0
    for _ in range(steps):
        lost_g = rng.binomial(good, p_fail)
        lost_b = rng.binomial(bad, p_fail)
        good = good - lost_g
        bad = bad - lost_b
        alive &= good >= 1  # no good replica left ⇒ object gone
        deficit = np.where(alive, replication - (good + bad), 0)
        deficit = np.maximum(deficit, 0)
        # each repair copies from a uniformly chosen claimed replica and
        # lands on a uniformly chosen node: good iff source good AND new
        # holder honest
        remaining = np.maximum(good + bad, 1)
        src_good_p = np.where(alive, good / remaining, 0.0)
        p_good_new = src_good_p * (1.0 - p.byz_fraction)
        new_good = rng.binomial(deficit, np.clip(p_good_new, 0.0, 1.0))
        good = good + new_good
        bad = bad + (deficit - new_good)
        n_rep = int(deficit.sum())
        repairs += n_rep
        traffic += float(n_rep) * 1.0  # full object copy per repair
    lost = int((~alive).sum())
    return SimResult(
        repair_traffic_units=traffic,
        lost_objects=lost,
        n_objects=p.n_objects,
        repairs=repairs,
        cache_hits=0,
        final_honest_mean=float(good[alive].mean()) if alive.any() else 0.0,
    )


# ------------------------------------------------------------- Fig 5 trace
def fragment_trace(
    k_inner: int, r_inner: int, byz_fraction: float, churn_per_year: float,
    years: float = 10.0, step_hours: float = 6.0,
    repair_interval_hours: float = 24.0, seed: int = 0,
) -> np.ndarray:
    """Honest-fragment count of one chunk group over time (Fig. 5)."""
    rng = np.random.default_rng(seed)
    byz = int(rng.binomial(r_inner, byz_fraction))
    honest = r_inner - byz
    p_fail = P.p_fail_step(churn_per_year, step_hours, xp=np)
    steps = int(round(years * HOURS_PER_YEAR / step_hours))
    out = np.zeros(steps, dtype=np.int64)
    since_repair = 0.0
    for t in range(steps):
        honest -= int(rng.binomial(honest, p_fail))
        byz -= int(rng.binomial(byz, p_fail))
        since_repair += step_hours
        if honest < k_inner:
            out[t:] = honest
            return out  # absorbed (never happens at paper parameters)
        if since_repair >= repair_interval_hours:
            deficit = max(0, r_inner - (honest + byz))
            nb = int(rng.binomial(deficit, byz_fraction))
            honest += deficit - nb
            byz += nb
            since_repair = 0.0
        out[t] = honest
    return out


# --------------------------------------------------- Fig 6 targeted attacks
def targeted_attack_vault(
    p: SimParams, attacked_fraction: float, fragments_per_node: int = 1,
    seed: int = 0,
) -> float:
    """Fraction of objects lost to an adversary disconnecting
    ``attacked_fraction * n_nodes`` nodes (Fig. 6 bottom).

    The adversary sees every group's composition (worst case, A.2) but NOT
    the chunk→object mapping (outer-code opacity): it greedily kills the
    cheapest groups — cost of a kill is (honest − K_inner + 1) removals,
    amortized by ``fragments_per_node`` co-located fragments (A.3 eq. 17) —
    and the kills land on objects *uniformly at random*.
    """
    rng = np.random.default_rng(seed)
    n_groups = p.n_objects * p.n_chunks
    byz = rng.binomial(p.r_inner, p.byz_fraction, size=n_groups)
    honest = p.r_inner - byz
    cost = np.asarray(P.kill_cost(honest, p.k_inner, fragments_per_node,
                                   xp=np), np.float64)
    budget = attacked_fraction * p.n_nodes
    # cheapest groups first; ties broken uniformly at random — the outer
    # code's opacity means equal-cost groups are indistinguishable, so the
    # attacker cannot concentrate kills on one object
    perm = rng.permutation(n_groups)
    order = perm[np.argsort(cost[perm], kind="stable")]
    csum = np.cumsum(cost[order])
    n_killed = int(np.searchsorted(csum, budget, side="right"))
    killed = np.zeros(n_groups, dtype=bool)
    killed[order[:n_killed]] = True
    chunks_alive = (~killed).reshape(p.n_objects, p.n_chunks).sum(axis=1)
    return float((chunks_alive < p.k_outer).mean())


def targeted_attack_replicated(
    p: SimParams, attacked_fraction: float, replication: int = 3,
) -> float:
    """Baseline under targeted attack: placement is public, so the attacker
    erases whole replica sets at a cost of ``replication`` nodes each."""
    budget = attacked_fraction * p.n_nodes
    killed = min(p.n_objects, int(budget // replication))
    return killed / max(p.n_objects, 1)


# ------------------------------------------------ batched-engine compat layer
# The numpy functions above are the reference path; `repro.core.scenarios`
# is the batched JAX engine that runs whole (params x seeds x policy) sweeps
# in one dispatch. These wrappers keep the SimParams/SimResult API for
# callers that want multi-seed estimates of a single parameter point.
def simulate_vault_batched(
    p: SimParams, seeds=range(8), sampler: str = "fast",
) -> SimResult:
    """Multi-seed VAULT run via the batched engine; seed-mean SimResult."""
    from repro.core import scenarios as SC

    r = SC.run_grid([SC.from_simparams(p)], seeds=seeds, sampler=sampler)
    return SimResult(
        repair_traffic_units=float(r.repair_traffic_units[0].mean()),
        lost_objects=int(round(float(r.lost_objects[0].mean()))),
        n_objects=p.n_objects,
        repairs=int(round(float(r.repairs[0].mean()))),
        cache_hits=int(round(float(r.cache_hits[0].mean()))),
        final_honest_mean=float(r.final_honest_mean[0].mean()),
    )


def simulate_replicated_batched(
    p: SimParams, replication: int = 3, seeds=range(8),
    sampler: str = "fast",
) -> SimResult:
    """Multi-seed replicated baseline via the batched engine."""
    from repro.core import scenarios as SC

    r = SC.run_replicated_grid(
        [SC.from_simparams(p, replication=replication)], seeds=seeds,
        sampler=sampler)
    return SimResult(
        repair_traffic_units=float(r.repair_traffic_units[0].mean()),
        lost_objects=int(round(float(r.lost_objects[0].mean()))),
        n_objects=p.n_objects,
        repairs=int(round(float(r.repairs[0].mean()))),
        cache_hits=0,
        final_honest_mean=float(r.final_honest_mean[0].mean()),
    )
