"""Shared churn / adversary / cache policy definitions for both VAULT layers.

The repo simulates VAULT at two levels of abstraction:

* the **group-level statistical engine** (``repro.core.scenarios`` — batched
  JAX, whole parameter sweeps in one dispatch; ``repro.core.simulation`` is
  its numpy reference), and
* the **protocol-level simulator** (``repro.core.protocol_sim`` — real
  ``SimNetwork`` peers, VRF selection proofs, GF(256) coding, decentralized
  repair).

Cross-validating the two (``benchmarks/cross_validate.py``) only means
something if both layers run the *same* scenario policies, so the policy
identifiers and every piece of shared policy arithmetic live here — one
source of truth instead of three copies.

Every numeric helper takes an ``xp=`` array namespace (default
``jax.numpy``) so the same formula serves the traced JAX engine
(``xp=jnp`` — the op sequence is identical to the pre-refactor inlined
code, keeping compiled outputs bit-for-bit stable), the numpy reference
path (``xp=np``), and the scalar protocol simulator (``xp=np`` on python
floats).

Policy catalogue
----------------

Churn (``churn_policy``):

* ``iid`` (:data:`CHURN_IID`) — i.i.d. Poisson churn per node, the paper's
  own model (§6.1, Figs. 4–6).  Per-step failure probability is
  :func:`p_fail_step`.
* ``regional`` (:data:`CHURN_REGIONAL`) — correlated bursts: with
  probability ``burst_prob`` per step one of :data:`N_REGIONS` fault
  domains suffers ``burst_mult``× the base failure rate (rack/AZ outages,
  after *Topology-Aware Cooperative Data Protection*).  The burst is
  applied as a *second* thinning pass with :func:`burst_extra_probability`
  so composing it with the base pass equals one boosted pass exactly.
* ``diurnal`` (:data:`CHURN_DIURNAL`) — time-of-day churn modulation:
  the Poisson rate is scaled by ``1 + amplitude · sin(2π · hour/24)``
  sampled at each step's midpoint (:func:`diurnal_rate_factor`), so the
  rate integrates to the *same yearly total* as ``iid`` over any whole
  number of days (the sin samples over a full period sum to zero
  exactly — pinned by ``tests/test_policy_zoo.py``).  Both layers
  recompute the per-step probability with :func:`diurnal_p_fail`.
* ``pareto`` (:data:`CHURN_PARETO`) — heavy-tailed node session lengths:
  each node lives for an independent Pareto(α, x_m) session with
  ``x_m = mean · (α−1)/α`` so the mean session matches the ``iid``
  churn rate (:func:`pareto_session_from_uniform`).  The protocol layer
  draws real sessions and expires nodes deterministically; the engine
  runs the documented **protected-cohort mean-field**
  (:func:`pareto_p_fail`): every session survives at least ``x_m``, so
  the effective hazard seen by a randomly-inspected step is the
  α-discounted ``(1 − exp(−α·rate·dt))/α`` — strictly below the i.i.d.
  probability (Jensen), which makes the cross-validation gate
  **one-sided** (abstraction leak #5, same pattern as the eclipse
  mean-field below).

Adversary (``adv_policy``):

* ``static`` (:data:`ADV_STATIC`) — fixed Byzantine population fraction;
  repair refills draw Byzantine members at the population share
  (paper Fig. 6 top; the §4.4 CTMC assumes exactly this).
* ``adaptive`` (:data:`ADV_ADAPTIVE`) — BFT-DSN-style repair-path attack:
  Byzantine members never churn voluntarily
  (:func:`byz_churn_probability` → 0) and flood repair refills at
  ``adapt_boost``× their population share
  (:func:`refill_byz_probability`).
* ``targeted`` (:data:`ADV_TARGETED`) — greedy targeted kill at
  ``attack_step`` under the A.3 cost model (:func:`kill_cost`), budget
  ``attack_frac · n_nodes`` (paper Fig. 6 bottom).
* ``eclipse`` (:data:`ADV_ECLIPSE`) — partition adversary: the ring
  segment covering ``attack_frac`` of id space is cut off for
  ``eclipse_steps`` steps starting at ``attack_step``. Eclipsed nodes are
  *alive but unreachable* — they keep their fragments and views, but no
  claims or repairs cross the cut, so their groups churn without repair
  for the whole window. Only the protocol layer can express the cut
  itself; the engine runs the documented **mean-field approximation**
  (:func:`eclipse_groups`, :func:`eclipse_active`): VRF placement is
  ring-local, so a fraction ``attack_frac`` of chunk groups sit inside
  the segment, and those groups get repair (and refills, traffic, cache
  warming) suppressed during the window while i.i.d. churn continues.
  The approximation is *deterministic* where the protocol's eclipsed set
  is binomial across seeds (anchors are hash-uniform), and it charges
  whole groups where the protocol's segment-boundary groups straddle the
  cut — both documented leaks cross-validated by ``tests/test_eclipse.py``.
* ``collude`` (:data:`ADV_COLLUDE`) — BFT-DSN-style collusion /
  withholding: Byzantine nodes *store* their fragments, answer Locate()
  rounds and persistence claims like honest members (they pass audits),
  but serve deterministically **corrupt** payloads at pull time.  The
  protocol layer verifies every gathered row against its creator-recorded
  integrity tag (``chunks.payload_tag`` / ``SimNetwork.frag_tags`` —
  the simulation stand-in for the paper's verifiable-fragment property)
  and discards corrupt rows *after paying their transfer*, which
  exercises the GF(256) rank-deficiency retry path under adversarial
  rather than random deficiency.  The engine charges the analogous
  wasted pulls closed-form (:func:`collusion_extra_pulls`).  Withholding
  never *increases* decode success by construction (corrupt rows are
  discarded pre-decode; honest row sets are unchanged) — pinned on both
  tiers by ``tests/test_policy_zoo.py``.
* ``eclipse_targeted`` (:data:`ADV_ECLIPSE_TARGETED`) — the **composed**
  product ``compose(eclipse(...), targeted_kill(...))``: the greedy kill
  lands at ``attack_step`` *and* the partition window opens at the same
  step, so repair of the surviving groups is suppressed exactly while
  the damage is fresh.  Both attacks share the ``attack_frac`` knob (the
  kill budget and the cut-segment width — one adversary resource pool).
  The engine runs both mean-field pieces simultaneously; the
  cross-validation row is gated one-sided like eclipse (leak #4).

Cache policy is the scalar ``cache_ttl_hours`` knob (0 disables); the
hit/miss traffic semantics are documented in ``repair.py`` and reproduced
identically by both layers.

Combinator API
--------------

``PolicySpec`` (plus the combinators :func:`iid`, :func:`regional`,
:func:`diurnal`, :func:`pareto_sessions`, :func:`static`,
:func:`adaptive`, :func:`targeted_kill`, :func:`eclipse`,
:func:`collude`, and :func:`compose`) is the construction layer above
the int ids: a spec carries at most one churn id, one adversary id, and
a tuple of knob overrides, and **lowers** through :func:`resolve` to the
same static-int/branchless form the jitted scan body consumes.  The
lowering target is deliberately unchanged — per-policy behavior stays a
fixed table of scalars selected by id inside ``xp.where``/family-flag
predicates — so the grid axis can ``vmap`` over arbitrary compositions
without per-policy retraces (every batch element shares one compiled
executable; only the two id leaves and the knob scalars differ).
:func:`compose` is **later-wins per axis** (documented order), except
for adversary pairs registered in the product table
(eclipse × targeted → ``eclipse_targeted``).  The zoo registry
(:func:`zoo_members`) enumerates every named policy configuration with
its cross-validation gate; ``benchmarks/cross_validate.py``
auto-discovers its config matrix from it and
``scripts/check_policy_matrix.py`` guards the mapping.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

HOURS_PER_YEAR = 24 * 365.0

CHURN_IID = 0
CHURN_REGIONAL = 1
CHURN_DIURNAL = 2
CHURN_PARETO = 3
CHURN_POLICIES = {
    "iid": CHURN_IID, "regional": CHURN_REGIONAL, "diurnal": CHURN_DIURNAL,
    "pareto": CHURN_PARETO,
}

ADV_STATIC = 0
ADV_ADAPTIVE = 1
ADV_TARGETED = 2
ADV_ECLIPSE = 3
ADV_COLLUDE = 4
ADV_ECLIPSE_TARGETED = 5
ADVERSARY_POLICIES = {
    "static": ADV_STATIC, "adaptive": ADV_ADAPTIVE, "targeted": ADV_TARGETED,
    "eclipse": ADV_ECLIPSE, "collude": ADV_COLLUDE,
    "eclipse_targeted": ADV_ECLIPSE_TARGETED,
}

# Family membership: a behavior is keyed by *membership* in a family, not
# by equality with a single id, so composed policies (eclipse_targeted)
# light up every component behavior. Single-member families compile to the
# exact same one-equality predicate as the pre-combinator code — that is
# what keeps the lowering bit-identical for all pre-existing policies.
ADV_ADAPTIVE_FAMILY = (ADV_ADAPTIVE,)
ADV_TARGETED_FAMILY = (ADV_TARGETED, ADV_ECLIPSE_TARGETED)
ADV_ECLIPSE_FAMILY = (ADV_ECLIPSE, ADV_ECLIPSE_TARGETED)
ADV_COLLUDE_FAMILY = (ADV_COLLUDE,)
CHURN_REGIONAL_FAMILY = (CHURN_REGIONAL,)
CHURN_DIURNAL_FAMILY = (CHURN_DIURNAL,)
CHURN_PARETO_FAMILY = (CHURN_PARETO,)

N_REGIONS = 16  # regional-burst fault domains (racks/AZs)


def _member_flag(policy, members):
    """OR-chain membership predicate (works traced and on python ints)."""
    flag = policy == members[0]
    for m in members[1:]:
        flag = flag | (policy == m)
    return flag


def adaptive_flag(adv_policy):
    """True iff the adversary plays the adaptive-refill behavior."""
    return _member_flag(adv_policy, ADV_ADAPTIVE_FAMILY)


def targeted_flag(adv_policy):
    """True iff the adversary fires the greedy targeted kill."""
    return _member_flag(adv_policy, ADV_TARGETED_FAMILY)


def eclipse_flag(adv_policy):
    """True iff the adversary opens the eclipse partition window."""
    return _member_flag(adv_policy, ADV_ECLIPSE_FAMILY)


def collude_flag(adv_policy):
    """True iff Byzantine members collude (store + serve corrupt rows)."""
    return _member_flag(adv_policy, ADV_COLLUDE_FAMILY)


def regional_flag(churn_policy):
    """True iff churn runs the regional-burst second thinning."""
    return _member_flag(churn_policy, CHURN_REGIONAL_FAMILY)


def diurnal_flag(churn_policy):
    """True iff churn is diurnally modulated."""
    return _member_flag(churn_policy, CHURN_DIURNAL_FAMILY)


def pareto_flag(churn_policy):
    """True iff churn follows Pareto session lengths."""
    return _member_flag(churn_policy, CHURN_PARETO_FAMILY)


def churn_policy_id(policy) -> int:
    """Resolve a churn policy (name, id, or :class:`PolicySpec`) to its
    int id.  Back-compat shim over :func:`resolve` — spec churn axis
    defaults to ``iid`` when unset."""
    if isinstance(policy, PolicySpec):
        return CHURN_IID if policy.churn is None else int(policy.churn)
    return CHURN_POLICIES[policy] if isinstance(policy, str) else int(policy)


def adv_policy_id(policy) -> int:
    """Resolve an adversary policy (name, id, or :class:`PolicySpec`) to
    its int id.  Back-compat shim over :func:`resolve` — spec adversary
    axis defaults to ``static`` when unset."""
    if isinstance(policy, PolicySpec):
        return ADV_STATIC if policy.adversary is None else int(policy.adversary)
    return (ADVERSARY_POLICIES[policy] if isinstance(policy, str)
            else int(policy))


# ------------------------------------------------------------ churn arithmetic
def p_fail_step(churn_per_year, step_hours, xp=jnp):
    """Per-step per-node failure probability from a Poisson churn rate.

    ``churn_per_year`` is expected failures per node-year, ``step_hours``
    the step width in hours; returns ``1 - exp(-rate · dt)`` in [0, 1).
    """
    return -xp.expm1(-churn_per_year / HOURS_PER_YEAR * step_hours)


def diurnal_rate_factor(t, step_hours, amplitude, xp=jnp):
    """Diurnal churn-rate multiplier for step ``t``.

    ``1 + amplitude · sin(2π · hour/24)`` sampled at the step *midpoint*
    ``(t + 0.5) · step_hours`` (endpoint sampling would alias to the sin
    zeros whenever ``step_hours`` divides 12).  Over any whole number of
    days with an integer number of steps per day the factors average to
    exactly 1 — the modulation integrates to the same yearly rate as
    ``iid``. ``amplitude`` must stay in [0, 1) to keep the rate positive.
    """
    hour = (t + 0.5) * step_hours
    return 1.0 + amplitude * xp.sin(2.0 * xp.pi * hour / 24.0)


def diurnal_p_fail(churn_policy, churn_per_year, diurnal_amplitude, t,
                   step_hours, p_fail_base, xp=jnp):
    """Per-step failure probability with optional diurnal modulation.

    ``diurnal`` policy: :func:`p_fail_step` of the modulated rate for
    this step. Every other policy: ``p_fail_base`` unchanged (the select
    is value-identical, keeping pre-existing policies bit-stable)."""
    factor = diurnal_rate_factor(t, step_hours, diurnal_amplitude, xp=xp)
    return xp.where(diurnal_flag(churn_policy),
                    p_fail_step(churn_per_year * factor, step_hours, xp=xp),
                    p_fail_base)


def pareto_session_mean_hours(churn_per_year, xp=jnp):
    """Mean session length (hours) matching the i.i.d. churn rate."""
    return HOURS_PER_YEAR / xp.maximum(churn_per_year, 1e-9)


def pareto_xm_hours(mean_hours, alpha, xp=jnp):
    """Pareto scale ``x_m`` (minimum session) for a target mean.

    ``mean = x_m · α/(α−1)`` for α > 1, so ``x_m = mean · (α−1)/α``."""
    a = xp.maximum(alpha, 1.0 + 1e-6)
    return mean_hours * (a - 1.0) / a


def pareto_session_from_uniform(u, mean_hours, alpha, xp=jnp):
    """Pareto(α, x_m) session length from one uniform in [0, 1).

    Inverse CDF: ``x_m · (1−u)^(−1/α)``, with ``x_m`` chosen by
    :func:`pareto_xm_hours` so the mean matches ``mean_hours``."""
    a = xp.maximum(alpha, 1.0 + 1e-6)
    xm = pareto_xm_hours(mean_hours, alpha, xp=xp)
    return xm * (1.0 - u) ** (-1.0 / a)


def pareto_p_fail(churn_policy, churn_per_year, pareto_alpha, step_hours,
                  p_fail_base, xp=jnp):
    """Engine mean-field per-step failure probability under Pareto sessions.

    A Pareto(α, x_m) session is *protected* for its first ``x_m`` hours
    (no node can die younger than the scale), so the population a random
    step inspects is a mix of protected and at-risk cohorts.  The
    flux-matched closed form is the α-discounted hazard
    ``(1 − exp(−α·rate·dt))/α`` — equal to the i.i.d. probability at
    α → 1 and *strictly below* it for α > 1 (Jensen).  This
    under-estimates burst clustering of heavy-tailed respawns, so the
    cross-validation row is **one-sided** (abstraction leak #5: the
    engine is the optimistic bound on repair volume, the protocol's real
    session draws sit above it).  Other policies pass ``p_fail_base``
    through bit-identically."""
    a = xp.maximum(pareto_alpha, 1.0 + 1e-6)
    rate_dt = churn_per_year / HOURS_PER_YEAR * step_hours
    return xp.where(pareto_flag(churn_policy),
                    -xp.expm1(-a * rate_dt) / a, p_fail_base)


def burst_from_uniforms(churn_policy, burst_prob, u0, u1, xp=jnp):
    """Regional-burst coin for one step from two uniforms in (0, 1).

    Returns ``(burst, region)``: ``burst`` is True iff the policy is in
    the ``regional`` family and ``u0 < burst_prob``; ``region`` is the
    hit fault domain, ``floor(u1 · N_REGIONS)`` clipped to
    ``[0, N_REGIONS)``.
    """
    regional = regional_flag(churn_policy)
    burst = regional & (u0 < burst_prob)
    region = xp.minimum((u1 * N_REGIONS).astype(xp.int32), N_REGIONS - 1)
    return burst, region


def burst_extra_probability(p_base, burst_mult, xp=jnp):
    """Second-pass thinning probability realizing a ``burst_mult``× boost.

    Thinning survivors of a ``p_base`` pass with this probability equals a
    single ``min(p_base · burst_mult, 0.95)`` pass exactly (binomial
    thinning composition), so the burst costs nothing on non-burst steps.
    """
    boosted = xp.minimum(p_base * burst_mult, 0.95)
    return xp.clip((boosted - p_base)
                   / xp.maximum(1.0 - p_base, 1e-9), 0.0, 1.0)


def group_domain(gidx, n_regions: int = N_REGIONS):
    """Fault domain of group ``gidx`` in the group-level engine.

    The engine's topology-aware worst case: a chunk group's members are
    co-located, so whole groups map to domains (round-robin)."""
    return gidx % n_regions


def ring_domain(nid: int, ring: int, n_regions: int = N_REGIONS) -> int:
    """Fault domain of a node id in the protocol-level simulator.

    Nodes are binned by ring segment, so ring-adjacent nodes — the ones
    VRF placement co-selects into the same chunk groups — share a domain.
    This is the protocol-level realization of :func:`group_domain`'s
    co-location assumption."""
    return int(nid // -(-ring // n_regions))


# -------------------------------------------------------- adversary arithmetic
def byz_churn_probability(adv_policy, p_fail, xp=jnp):
    """Voluntary churn probability of Byzantine members.

    The adaptive adversary's members never leave on their own (they hold
    seats to starve honest refills); every other policy churns Byzantine
    members like honest ones."""
    return xp.where(adaptive_flag(adv_policy), 0.0, p_fail)


def refill_byz_probability(adv_policy, byz_fraction, adapt_boost, xp=jnp):
    """Probability that one repair refill lands on a Byzantine member.

    ``static``/``targeted``: the population share ``byz_fraction`` (VRF
    selection is uniform, §3.3).  ``adaptive``: boosted to
    ``clip(byz_fraction · adapt_boost, 0, 0.95)`` — the adversary races
    Locate() rounds, answering first for every open slot."""
    return xp.where(
        adaptive_flag(adv_policy),
        xp.clip(byz_fraction * adapt_boost, 0.0, 0.95),
        byz_fraction)


def collusion_extra_pulls(adv_policy, byz_count, xp=jnp):
    """Wasted fragment pulls a colluding group charges per decode gather.

    Under ``collude`` every Byzantine member of the group serves one
    corrupt row that is pulled, integrity-checked, and discarded — so a
    repairing group pays ``byz_count`` extra fragment transfers per
    chunk-decode gather. Zero for every other adversary (value-identical
    pass-through, additive-zero in the traffic lane)."""
    return xp.where(collude_flag(adv_policy), byz_count, 0.0)


def ring_segment(attack_frac: float, ring: int) -> tuple[int, int]:
    """The cut ring interval of the eclipse adversary (protocol layer).

    Deterministic ``[0, attack_frac · ring)`` — node ids are hash-uniform,
    so the segment's population share is ``attack_frac`` in expectation and
    the choice of origin carries no information."""
    return (0, int(attack_frac * ring))


def eclipse_active(adv_policy, t, attack_step, eclipse_steps, xp=jnp):
    """True while the eclipse window is open: ``attack_step ≤ t <
    attack_step + eclipse_steps`` under an ``eclipse``-family policy
    (plain eclipse or the composed eclipse+targeted product)."""
    return (eclipse_flag(adv_policy) & (t >= attack_step)
            & (t < attack_step + eclipse_steps))


def eclipse_groups(gidx, attack_frac, n_groups, xp=jnp):
    """Engine mean-field mask of eclipsed groups.

    VRF placement is ring-local, so the protocol's cut segment captures a
    fraction ``attack_frac`` of group anchors; the engine (which has no
    anchors) eclipses the first ``round(attack_frac · n_groups)`` groups —
    the right mean, no across-seed variance (documented approximation)."""
    n_ecl = xp.round(attack_frac * n_groups)
    return gidx < n_ecl


def kill_cost(honest, k_inner, frags_per_node, xp=jnp):
    """Per-group kill cost of the targeted adversary (A.3 eq. 17).

    Disconnecting a group needs ``honest − K_inner + 1`` honest removals,
    amortized by ``frags_per_node`` co-located fragments per node. Units:
    nodes (the attack budget is ``attack_frac · n_nodes``)."""
    cost = xp.maximum(honest - k_inner + 1.0, 0.0)
    return cost / xp.maximum(frags_per_node, 1.0)


# ---------------------------------------------------------- serving arithmetic
# The request-serving workload layer (ROADMAP item 3). Both tiers serve
# Zipf-popular whole-object Get() requests each step and classify every
# request into exactly one of four disjoint buckets (priority order):
#
#   failed    — fewer than K_outer chunks readable: the read cannot
#               complete (includes groups behind an eclipse cut);
#   degraded  — completes, but at least one chunk group is dead or
#               eclipsed, so the client fans wider and pays an extra hop;
#   hit       — completes entirely from warm cached chunk copies;
#   miss      — completes via fragment pulls + GF(256) decode.
#
# Latency is measured in *hops* (request→holder round trips), not sampled
# RTTs, so both tiers produce the same deterministic quantity:
# cache hit = anchor walk + cached-chunk pull (2), miss adds the
# fragment-gather round (3), degraded adds one more fan-out round (4).
# Per-region bandwidth caps stretch hops multiplicatively (congestion),
# which is how repair and serving compete for the same links.

#: Hop cost of a cache-hit read: candidate walk + whole-chunk pull.
SERVE_HOPS_HIT = 2.0
#: Hop cost of a decode-path read: walk + parallel fragment gather + decode.
SERVE_HOPS_MISS = 3.0
#: Extra hop a degraded read pays to fan out past dead/eclipsed groups.
SERVE_HOPS_DEGRADED_EXTRA = 1.0
#: Bins of the retrieval-hop histogram; effective hops clip to the last bin.
SERVE_HIST_BINS = 16
#: Bandwidth fault domains — one per ``network.REGIONS`` entry.
N_BW_REGIONS = 5


def zipf_weights(obj_idx, zipf_alpha, n_objects, xp=jnp):
    """Zipf(α) popularity weights over objects, normalized to sum 1.

    ``obj_idx`` ranks objects by popularity (0 = hottest, weight
    ``(i+1)^-α``); indices ≥ ``n_objects`` (grid padding) get weight 0 and
    the rest renormalize over the active objects only.  ``zipf_alpha = 0``
    degenerates to uniform popularity.
    """
    rank = xp.asarray(obj_idx, dtype=xp.float32) + 1.0
    w = rank ** -xp.asarray(zipf_alpha, dtype=xp.float32)
    w = xp.where(obj_idx < n_objects, w, 0.0)
    return w / xp.maximum(w.sum(), 1e-30)


def congestion_factor(load_units, region_cap, xp=jnp):
    """Latency stretch of a bandwidth region carrying ``load_units``.

    ``region_cap`` is the per-region per-step capacity in object units
    (0 or negative disables the model).  Under the cap the factor is 1;
    above it, hops stretch linearly with the overload ratio — the M/D/1
    heavy-traffic asymptote both tiers share.
    """
    cap = xp.asarray(region_cap, dtype=xp.float32)
    ratio = load_units / xp.maximum(cap, 1e-30)
    return xp.where(cap > 0.0, xp.maximum(ratio, 1.0), 1.0)


def effective_hops(hops, factor, xp=jnp):
    """Histogram bin of a read with base ``hops`` under congestion
    ``factor``: ``round(hops · factor)`` clipped to the last bin."""
    e = xp.round(hops * factor)
    return xp.clip(e, 0.0, SERVE_HIST_BINS - 1.0)


# ------------------------------------------------------------- combinator API
#: Knob keys a PolicySpec may carry — exactly the policy-parameter kwargs
#: of ``scenarios.make_scenario`` / ``protocol_sim.ProtocolParams``.
POLICY_KNOBS = ("burst_prob", "burst_mult", "adapt_boost", "attack_frac",
                "attack_step", "eclipse_steps", "diurnal_amplitude",
                "pareto_alpha")


@dataclass(frozen=True)
class PolicySpec:
    """One composable policy: at most one churn id, one adversary id, and
    a tuple of ``(knob, value)`` overrides (hashable, so specs can key
    caches and sit in grid cells).  Build specs with the combinators
    below and :func:`compose`; lower them with :func:`resolve`."""

    name: str
    churn: int | None = None
    adversary: int | None = None
    knobs: tuple = ()

    def knob_dict(self) -> dict:
        return dict(self.knobs)


def _spec(name, churn=None, adversary=None, **knobs) -> PolicySpec:
    kn = tuple((k, v) for k, v in knobs.items() if v is not None)
    for k, _ in kn:
        if k not in POLICY_KNOBS:
            raise TypeError(f"unknown policy knob {k!r}")
    return PolicySpec(name=name, churn=churn, adversary=adversary, knobs=kn)


def iid() -> PolicySpec:
    """i.i.d. Poisson churn (the paper's §6.1 model)."""
    return _spec("iid", churn=CHURN_IID)


def regional(burst_prob=None, burst_mult=None) -> PolicySpec:
    """Correlated regional-burst churn; ``None`` knobs keep defaults."""
    return _spec("regional", churn=CHURN_REGIONAL,
                 burst_prob=burst_prob, burst_mult=burst_mult)


def diurnal(amplitude=None) -> PolicySpec:
    """Diurnally modulated churn rate (see :func:`diurnal_rate_factor`)."""
    return _spec("diurnal", churn=CHURN_DIURNAL, diurnal_amplitude=amplitude)


def pareto_sessions(alpha=None) -> PolicySpec:
    """Heavy-tailed Pareto(α) session lengths (see :func:`pareto_p_fail`)."""
    return _spec("pareto", churn=CHURN_PARETO, pareto_alpha=alpha)


def static() -> PolicySpec:
    """Static Byzantine population fraction (Fig. 6 top)."""
    return _spec("static", adversary=ADV_STATIC)


def adaptive(boost=None) -> PolicySpec:
    """Adaptive repair-path adversary; ``boost`` = refill bias."""
    return _spec("adaptive", adversary=ADV_ADAPTIVE, adapt_boost=boost)


def targeted_kill(budget=None, attack_step=None) -> PolicySpec:
    """Greedy targeted kill; ``budget`` = ``attack_frac`` of n_nodes."""
    return _spec("targeted", adversary=ADV_TARGETED,
                 attack_frac=budget, attack_step=attack_step)


def eclipse(frac=None, window=None, attack_step=None) -> PolicySpec:
    """Ring-partition adversary; ``frac`` = ``attack_frac`` segment
    width, ``window`` = ``eclipse_steps``."""
    return _spec("eclipse", adversary=ADV_ECLIPSE, attack_frac=frac,
                 eclipse_steps=window, attack_step=attack_step)


def collude() -> PolicySpec:
    """Collusion/withholding adversary (BFT-DSN): Byzantine nodes pass
    audits but serve corrupt fragments at pull time."""
    return _spec("collude", adversary=ADV_COLLUDE)


#: Adversary product table for :func:`compose`: pairs that combine into a
#: genuinely composed behavior instead of later-wins. Symmetric by
#: construction (frozenset keys); absorbing (product ∘ component = product).
_ADV_PRODUCTS = {
    frozenset((ADV_ECLIPSE, ADV_TARGETED)): ADV_ECLIPSE_TARGETED,
    frozenset((ADV_ECLIPSE_TARGETED, ADV_TARGETED)): ADV_ECLIPSE_TARGETED,
    frozenset((ADV_ECLIPSE_TARGETED, ADV_ECLIPSE)): ADV_ECLIPSE_TARGETED,
}


def compose(*specs: PolicySpec) -> PolicySpec:
    """Fold specs left-to-right into one spec.

    Composition order is documented and deterministic: per axis (churn,
    adversary) the **later spec wins**, *except* adversary pairs listed
    in the product table (``eclipse × targeted → eclipse_targeted``,
    which is symmetric and absorbing).  Knobs merge later-wins per key.
    ``compose(x)`` is the identity, so composing a single combinator with
    nothing lowers exactly like the combinator itself."""
    if not specs:
        raise TypeError("compose() needs at least one PolicySpec")
    acc = specs[0]
    for s in specs[1:]:
        if not isinstance(s, PolicySpec):
            raise TypeError(f"compose() takes PolicySpec, got {type(s)}")
        churn = acc.churn if s.churn is None else s.churn
        if acc.adversary is None or s.adversary is None:
            adv = acc.adversary if s.adversary is None else s.adversary
        else:
            adv = _ADV_PRODUCTS.get(frozenset((acc.adversary, s.adversary)),
                                    s.adversary)
        knobs = dict(acc.knobs)
        knobs.update(s.knobs)
        acc = PolicySpec(name=f"{acc.name}+{s.name}", churn=churn,
                         adversary=adv, knobs=tuple(knobs.items()))
    return acc


class LoweredPolicy(tuple):
    """Static lowering of a spec: ``(churn id, adversary id, knob tuple)``.

    The ids are the exact ints the branchless scan body selects on;
    ``knobs`` are scalar overrides for the scenario/protocol kwargs of
    the same names. A plain tuple subclass so it stays hashable."""

    __slots__ = ()

    def __new__(cls, churn: int, adversary: int, knobs: tuple = ()):
        return super().__new__(cls, (int(churn), int(adversary),
                                     tuple(knobs)))

    @property
    def churn(self) -> int:
        return self[0]

    @property
    def adversary(self) -> int:
        return self[1]

    @property
    def knobs(self) -> tuple:
        return self[2]

    def knob_dict(self) -> dict:
        return dict(self[2])


def resolve(policy) -> LoweredPolicy:
    """THE resolver: lower a policy to its static-int/branchless form.

    Accepts a :class:`PolicySpec` (from the combinators or
    :func:`compose`), a registered zoo name (:func:`zoo_members`), a
    plain churn or adversary policy name (``"iid"``, ``"eclipse"``, …),
    or ``None`` (the iid/static baseline).  Unset axes default to
    ``iid``/``static``.  Plain ints are rejected — an int does not say
    *which* axis it belongs to; use the per-axis shims
    :func:`churn_policy_id` / :func:`adv_policy_id` for those."""
    if policy is None:
        return LoweredPolicy(CHURN_IID, ADV_STATIC)
    if isinstance(policy, LoweredPolicy):
        return policy
    if isinstance(policy, str):
        if policy in _ZOO:
            policy = _ZOO[policy].spec
        elif policy in CHURN_POLICIES:
            policy = _spec(policy, churn=CHURN_POLICIES[policy])
        elif policy in ADVERSARY_POLICIES:
            policy = _spec(policy, adversary=ADVERSARY_POLICIES[policy])
        else:
            raise KeyError(f"unknown policy name {policy!r}")
    if not isinstance(policy, PolicySpec):
        raise TypeError(
            f"cannot resolve {policy!r}: pass a PolicySpec or name "
            "(plain ints are axis-ambiguous; use churn_policy_id / "
            "adv_policy_id)")
    return LoweredPolicy(
        CHURN_IID if policy.churn is None else policy.churn,
        ADV_STATIC if policy.adversary is None else policy.adversary,
        policy.knobs)


# ----------------------------------------------------------------- policy zoo
@dataclass(frozen=True)
class StepFrac:
    """A step count expressed as an exact fraction of the horizon
    (``steps · num // den`` — integer arithmetic, so ``StepFrac(1, 3)``
    of 30 steps is exactly 10, where a float ``1/3`` would truncate)."""

    num: int
    den: int

    def resolve(self, steps: int) -> int:
        return int(steps) * self.num // self.den


@dataclass(frozen=True)
class ZooEntry:
    """One registered zoo member: a named policy configuration with its
    cross-validation contract.

    ``overrides`` are extra matched-config kwargs (``StepFrac`` values
    resolve against the horizon at build time); ``gate`` is how
    ``tests/test_cross_validation.py`` holds the row — ``"two_sided"``
    rows ride the blanket combined-CI gates, ``"one_sided"`` rows get
    dedicated bound tests (documented abstraction leaks); ``note`` says
    why."""

    name: str
    spec: PolicySpec
    overrides: tuple = ()
    gate: str = "two_sided"
    note: str = ""


_ZOO: dict[str, ZooEntry] = {}


def _register(entry: ZooEntry) -> ZooEntry:
    if entry.name in _ZOO:
        raise ValueError(f"duplicate zoo entry {entry.name!r}")
    if entry.gate not in ("two_sided", "one_sided"):
        raise ValueError(f"unknown gate {entry.gate!r}")
    _ZOO[entry.name] = entry
    return entry


def zoo_members() -> tuple[ZooEntry, ...]:
    """Every registered zoo entry, in registration order. The
    auto-discovery source for ``benchmarks/cross_validate.py`` (guarded
    by ``scripts/check_policy_matrix.py``)."""
    return tuple(_ZOO.values())


def zoo_entry(name: str) -> ZooEntry:
    return _ZOO[name]


def zoo_config_kwargs(entry: ZooEntry, steps: int) -> dict:
    """Matched-config kwargs of a zoo entry at horizon ``steps``:
    ``policy=`` spec plus the entry's overrides with ``StepFrac`` values
    resolved."""
    kw = {"policy": entry.spec}
    for k, v in entry.overrides:
        kw[k] = v.resolve(steps) if isinstance(v, StepFrac) else v
    return kw


# The zoo. Legacy entries reproduce the exact pre-combinator matched
# configs of benchmarks/cross_validate.py; new entries are the four
# ISSUE-10 zoo members. NOTE: scripts/check_policy_matrix.py ast-parses
# these _register(ZooEntry(name="...")) calls — keep them literal.
_register(ZooEntry(
    name="iid_static",
    spec=compose(iid(), static())))
_register(ZooEntry(
    name="regional_static",
    spec=compose(regional(burst_prob=0.15, burst_mult=8.0), static())))
_register(ZooEntry(
    name="iid_adaptive",
    spec=compose(iid(), adaptive(boost=2.0))))
_register(ZooEntry(
    name="iid_static_cache",
    spec=compose(iid(), static()),
    overrides=(("cache_ttl_hours", 48.0),)))
_register(ZooEntry(
    name="iid_targeted",
    spec=compose(iid(), targeted_kill(budget=0.25)),
    overrides=(("attack_step", StepFrac(1, 2)),),
    gate="one_sided",
    note="engine kill is the conservative bound (dedicated gates)"))
_register(ZooEntry(
    name="iid_eclipse",
    spec=compose(iid(), eclipse(frac=0.3)),
    overrides=(("churn_per_year", 80.0),
               ("attack_step", StepFrac(1, 4)),
               ("eclipse_steps", StepFrac(1, 3))),
    gate="one_sided",
    note="whole-group mean-field eclipse: engine is the conservative "
         "bound (abstraction leak #4)"))
_register(ZooEntry(
    name="diurnal_static",
    spec=compose(diurnal(amplitude=0.6), static()),
    note="same yearly rate as iid by construction; rides the blanket "
         "combined-CI gates"))
_register(ZooEntry(
    name="pareto_static",
    spec=compose(pareto_sessions(alpha=1.5), static()),
    gate="one_sided",
    note="protected-cohort mean-field: engine under-counts heavy-tailed "
         "respawn clustering (abstraction leak #5)"))
_register(ZooEntry(
    name="iid_collude",
    spec=compose(iid(), collude()),
    gate="one_sided",
    note="withholding only adds discarded-row traffic; decode metrics "
         "match static, traffic gated one-sided"))
_register(ZooEntry(
    name="iid_eclipse_targeted",
    spec=compose(iid(), eclipse(frac=0.25), targeted_kill(budget=0.25)),
    overrides=(("churn_per_year", 80.0),
               ("attack_step", StepFrac(1, 4)),
               ("eclipse_steps", StepFrac(1, 3))),
    gate="one_sided",
    note="composed product INVERTS the eclipse leak: the kill exploits "
         "the partition, so the protocol loses MORE than the engine's "
         "independent mean-field product — gated one-sided the other "
         "way (see tests/test_cross_validation.py)"))
