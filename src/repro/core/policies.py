"""Shared churn / adversary / cache policy definitions for both VAULT layers.

The repo simulates VAULT at two levels of abstraction:

* the **group-level statistical engine** (``repro.core.scenarios`` — batched
  JAX, whole parameter sweeps in one dispatch; ``repro.core.simulation`` is
  its numpy reference), and
* the **protocol-level simulator** (``repro.core.protocol_sim`` — real
  ``SimNetwork`` peers, VRF selection proofs, GF(256) coding, decentralized
  repair).

Cross-validating the two (``benchmarks/cross_validate.py``) only means
something if both layers run the *same* scenario policies, so the policy
identifiers and every piece of shared policy arithmetic live here — one
source of truth instead of three copies.

Every numeric helper takes an ``xp=`` array namespace (default
``jax.numpy``) so the same formula serves the traced JAX engine
(``xp=jnp`` — the op sequence is identical to the pre-refactor inlined
code, keeping compiled outputs bit-for-bit stable), the numpy reference
path (``xp=np``), and the scalar protocol simulator (``xp=np`` on python
floats).

Policy catalogue
----------------

Churn (``churn_policy``):

* ``iid`` (:data:`CHURN_IID`) — i.i.d. Poisson churn per node, the paper's
  own model (§6.1, Figs. 4–6).  Per-step failure probability is
  :func:`p_fail_step`.
* ``regional`` (:data:`CHURN_REGIONAL`) — correlated bursts: with
  probability ``burst_prob`` per step one of :data:`N_REGIONS` fault
  domains suffers ``burst_mult``× the base failure rate (rack/AZ outages,
  after *Topology-Aware Cooperative Data Protection*).  The burst is
  applied as a *second* thinning pass with :func:`burst_extra_probability`
  so composing it with the base pass equals one boosted pass exactly.

Adversary (``adv_policy``):

* ``static`` (:data:`ADV_STATIC`) — fixed Byzantine population fraction;
  repair refills draw Byzantine members at the population share
  (paper Fig. 6 top; the §4.4 CTMC assumes exactly this).
* ``adaptive`` (:data:`ADV_ADAPTIVE`) — BFT-DSN-style repair-path attack:
  Byzantine members never churn voluntarily
  (:func:`byz_churn_probability` → 0) and flood repair refills at
  ``adapt_boost``× their population share
  (:func:`refill_byz_probability`).
* ``targeted`` (:data:`ADV_TARGETED`) — greedy targeted kill at
  ``attack_step`` under the A.3 cost model (:func:`kill_cost`), budget
  ``attack_frac · n_nodes`` (paper Fig. 6 bottom).
* ``eclipse`` (:data:`ADV_ECLIPSE`) — partition adversary: the ring
  segment covering ``attack_frac`` of id space is cut off for
  ``eclipse_steps`` steps starting at ``attack_step``. Eclipsed nodes are
  *alive but unreachable* — they keep their fragments and views, but no
  claims or repairs cross the cut, so their groups churn without repair
  for the whole window. Only the protocol layer can express the cut
  itself; the engine runs the documented **mean-field approximation**
  (:func:`eclipse_groups`, :func:`eclipse_active`): VRF placement is
  ring-local, so a fraction ``attack_frac`` of chunk groups sit inside
  the segment, and those groups get repair (and refills, traffic, cache
  warming) suppressed during the window while i.i.d. churn continues.
  The approximation is *deterministic* where the protocol's eclipsed set
  is binomial across seeds (anchors are hash-uniform), and it charges
  whole groups where the protocol's segment-boundary groups straddle the
  cut — both documented leaks cross-validated by ``tests/test_eclipse.py``.

Cache policy is the scalar ``cache_ttl_hours`` knob (0 disables); the
hit/miss traffic semantics are documented in ``repair.py`` and reproduced
identically by both layers.
"""
from __future__ import annotations

import jax.numpy as jnp

HOURS_PER_YEAR = 24 * 365.0

CHURN_IID = 0
CHURN_REGIONAL = 1
CHURN_POLICIES = {"iid": CHURN_IID, "regional": CHURN_REGIONAL}

ADV_STATIC = 0
ADV_ADAPTIVE = 1
ADV_TARGETED = 2
ADV_ECLIPSE = 3
ADVERSARY_POLICIES = {
    "static": ADV_STATIC, "adaptive": ADV_ADAPTIVE, "targeted": ADV_TARGETED,
    "eclipse": ADV_ECLIPSE,
}

N_REGIONS = 16  # regional-burst fault domains (racks/AZs)


def churn_policy_id(policy: int | str) -> int:
    """Resolve a churn policy name (or pass through an id) to its int id."""
    return CHURN_POLICIES[policy] if isinstance(policy, str) else int(policy)


def adv_policy_id(policy: int | str) -> int:
    """Resolve an adversary policy name (or id) to its int id."""
    return (ADVERSARY_POLICIES[policy] if isinstance(policy, str)
            else int(policy))


# ------------------------------------------------------------ churn arithmetic
def p_fail_step(churn_per_year, step_hours, xp=jnp):
    """Per-step per-node failure probability from a Poisson churn rate.

    ``churn_per_year`` is expected failures per node-year, ``step_hours``
    the step width in hours; returns ``1 - exp(-rate · dt)`` in [0, 1).
    """
    return -xp.expm1(-churn_per_year / HOURS_PER_YEAR * step_hours)


def burst_from_uniforms(churn_policy, burst_prob, u0, u1, xp=jnp):
    """Regional-burst coin for one step from two uniforms in (0, 1).

    Returns ``(burst, region)``: ``burst`` is True iff the policy is
    ``regional`` and ``u0 < burst_prob``; ``region`` is the hit fault
    domain, ``floor(u1 · N_REGIONS)`` clipped to ``[0, N_REGIONS)``.
    """
    regional = churn_policy == CHURN_REGIONAL
    burst = regional & (u0 < burst_prob)
    region = xp.minimum((u1 * N_REGIONS).astype(xp.int32), N_REGIONS - 1)
    return burst, region


def burst_extra_probability(p_base, burst_mult, xp=jnp):
    """Second-pass thinning probability realizing a ``burst_mult``× boost.

    Thinning survivors of a ``p_base`` pass with this probability equals a
    single ``min(p_base · burst_mult, 0.95)`` pass exactly (binomial
    thinning composition), so the burst costs nothing on non-burst steps.
    """
    boosted = xp.minimum(p_base * burst_mult, 0.95)
    return xp.clip((boosted - p_base)
                   / xp.maximum(1.0 - p_base, 1e-9), 0.0, 1.0)


def group_domain(gidx, n_regions: int = N_REGIONS):
    """Fault domain of group ``gidx`` in the group-level engine.

    The engine's topology-aware worst case: a chunk group's members are
    co-located, so whole groups map to domains (round-robin)."""
    return gidx % n_regions


def ring_domain(nid: int, ring: int, n_regions: int = N_REGIONS) -> int:
    """Fault domain of a node id in the protocol-level simulator.

    Nodes are binned by ring segment, so ring-adjacent nodes — the ones
    VRF placement co-selects into the same chunk groups — share a domain.
    This is the protocol-level realization of :func:`group_domain`'s
    co-location assumption."""
    return int(nid // -(-ring // n_regions))


# -------------------------------------------------------- adversary arithmetic
def byz_churn_probability(adv_policy, p_fail, xp=jnp):
    """Voluntary churn probability of Byzantine members.

    The adaptive adversary's members never leave on their own (they hold
    seats to starve honest refills); every other policy churns Byzantine
    members like honest ones."""
    return xp.where(adv_policy == ADV_ADAPTIVE, 0.0, p_fail)


def refill_byz_probability(adv_policy, byz_fraction, adapt_boost, xp=jnp):
    """Probability that one repair refill lands on a Byzantine member.

    ``static``/``targeted``: the population share ``byz_fraction`` (VRF
    selection is uniform, §3.3).  ``adaptive``: boosted to
    ``clip(byz_fraction · adapt_boost, 0, 0.95)`` — the adversary races
    Locate() rounds, answering first for every open slot."""
    return xp.where(
        adv_policy == ADV_ADAPTIVE,
        xp.clip(byz_fraction * adapt_boost, 0.0, 0.95),
        byz_fraction)


def ring_segment(attack_frac: float, ring: int) -> tuple[int, int]:
    """The cut ring interval of the eclipse adversary (protocol layer).

    Deterministic ``[0, attack_frac · ring)`` — node ids are hash-uniform,
    so the segment's population share is ``attack_frac`` in expectation and
    the choice of origin carries no information."""
    return (0, int(attack_frac * ring))


def eclipse_active(adv_policy, t, attack_step, eclipse_steps, xp=jnp):
    """True while the eclipse window is open: ``attack_step ≤ t <
    attack_step + eclipse_steps`` under the ``eclipse`` policy."""
    return ((adv_policy == ADV_ECLIPSE) & (t >= attack_step)
            & (t < attack_step + eclipse_steps))


def eclipse_groups(gidx, attack_frac, n_groups, xp=jnp):
    """Engine mean-field mask of eclipsed groups.

    VRF placement is ring-local, so the protocol's cut segment captures a
    fraction ``attack_frac`` of group anchors; the engine (which has no
    anchors) eclipses the first ``round(attack_frac · n_groups)`` groups —
    the right mean, no across-seed variance (documented approximation)."""
    n_ecl = xp.round(attack_frac * n_groups)
    return gidx < n_ecl


def kill_cost(honest, k_inner, frags_per_node, xp=jnp):
    """Per-group kill cost of the targeted adversary (A.3 eq. 17).

    Disconnecting a group needs ``honest − K_inner + 1`` honest removals,
    amortized by ``frags_per_node`` co-located fragments per node. Units:
    nodes (the attack budget is ``attack_frac · n_nodes``)."""
    cost = xp.maximum(honest - k_inner + 1.0, 0.0)
    return cost / xp.maximum(frags_per_node, 1.0)


# ---------------------------------------------------------- serving arithmetic
# The request-serving workload layer (ROADMAP item 3). Both tiers serve
# Zipf-popular whole-object Get() requests each step and classify every
# request into exactly one of four disjoint buckets (priority order):
#
#   failed    — fewer than K_outer chunks readable: the read cannot
#               complete (includes groups behind an eclipse cut);
#   degraded  — completes, but at least one chunk group is dead or
#               eclipsed, so the client fans wider and pays an extra hop;
#   hit       — completes entirely from warm cached chunk copies;
#   miss      — completes via fragment pulls + GF(256) decode.
#
# Latency is measured in *hops* (request→holder round trips), not sampled
# RTTs, so both tiers produce the same deterministic quantity:
# cache hit = anchor walk + cached-chunk pull (2), miss adds the
# fragment-gather round (3), degraded adds one more fan-out round (4).
# Per-region bandwidth caps stretch hops multiplicatively (congestion),
# which is how repair and serving compete for the same links.

#: Hop cost of a cache-hit read: candidate walk + whole-chunk pull.
SERVE_HOPS_HIT = 2.0
#: Hop cost of a decode-path read: walk + parallel fragment gather + decode.
SERVE_HOPS_MISS = 3.0
#: Extra hop a degraded read pays to fan out past dead/eclipsed groups.
SERVE_HOPS_DEGRADED_EXTRA = 1.0
#: Bins of the retrieval-hop histogram; effective hops clip to the last bin.
SERVE_HIST_BINS = 16
#: Bandwidth fault domains — one per ``network.REGIONS`` entry.
N_BW_REGIONS = 5


def zipf_weights(obj_idx, zipf_alpha, n_objects, xp=jnp):
    """Zipf(α) popularity weights over objects, normalized to sum 1.

    ``obj_idx`` ranks objects by popularity (0 = hottest, weight
    ``(i+1)^-α``); indices ≥ ``n_objects`` (grid padding) get weight 0 and
    the rest renormalize over the active objects only.  ``zipf_alpha = 0``
    degenerates to uniform popularity.
    """
    rank = xp.asarray(obj_idx, dtype=xp.float32) + 1.0
    w = rank ** -xp.asarray(zipf_alpha, dtype=xp.float32)
    w = xp.where(obj_idx < n_objects, w, 0.0)
    return w / xp.maximum(w.sum(), 1e-30)


def congestion_factor(load_units, region_cap, xp=jnp):
    """Latency stretch of a bandwidth region carrying ``load_units``.

    ``region_cap`` is the per-region per-step capacity in object units
    (0 or negative disables the model).  Under the cap the factor is 1;
    above it, hops stretch linearly with the overload ratio — the M/D/1
    heavy-traffic asymptote both tiers share.
    """
    cap = xp.asarray(region_cap, dtype=xp.float32)
    ratio = load_units / xp.maximum(cap, 1e-30)
    return xp.where(cap > 0.0, xp.maximum(ratio, 1.0), 1.0)


def effective_hops(hops, factor, xp=jnp):
    """Histogram bin of a read with base ``hops`` under congestion
    ``factor``: ``round(hops · factor)`` clipped to the last bin."""
    e = xp.round(hops * factor)
    return xp.clip(e, 0.0, SERVE_HIST_BINS - 1.0)
