"""End-to-end protocol-level VAULT simulator, cross-validated against the
batched group-level engine (``repro.core.scenarios``).

The group-level engine abstracts a chunk group to counters ``(honest, byz,
cache_t, alive)``. This module runs the *protocol* instead, on a small
``SimNetwork``: real keypairs and VRF selection proofs place fragments
(``vrf.py`` / ``selection.py`` via ``VaultClient.store``), GF(256) rateless
coding produces real fragment payloads (``chunks.py`` / ``gf.py``), nodes
churn and are replaced, Byzantine nodes follow the Fig. 6 model (answer
claims / accept stores / serve nothing), persistence claims and membership
timers converge group views (``group.py``), and decentralized repair
reconstructs fragments from surviving ones (``repair.py``). Both layers
consume the same policy definitions from ``repro.core.policies``, and
:func:`run_protocol` reports results in the engine's trace schema
(:class:`ProtocolResult` mirrors ``scenarios.ScenarioResult`` field by
field), so ``benchmarks/cross_validate.py`` and
``tests/test_cross_validation.py`` can assert that protocol-level loss and
repair statistics fall inside the engine's multi-seed confidence intervals.

Correspondence to the engine's abstraction, and the known deltas
----------------------------------------------------------------

* **Step order** matches the engine scan body: churn (+ regional-burst
  second thinning) → targeted attack (at ``attack_step``) → repair →
  record. ``alive_frac_trace[t]`` is the post-repair fraction of decodable
  groups, exactly the engine's per-step trace.
* **Churn** — every node fails i.i.d. with ``policies.p_fail_step`` per
  step; failed nodes are replaced by *fresh* keypairs (new ring position),
  Byzantine with the population probability, so the population stays at
  ``n_nodes`` with a stationary Byzantine share, the engine's implicit
  infinite-population assumption.
* **Regional bursts** — nodes are binned into ``policies.N_REGIONS`` fault
  domains *by ring segment* (``policies.ring_domain``), so ring-adjacent
  nodes — the ones VRF placement co-selects into the same groups — share a
  domain. This realizes the engine's co-located-group assumption
  (``policies.group_domain``); a group whose anchor sits mid-segment still
  straddles 2–3 domains, so protocol-level burst kills are slightly less
  group-concentrated than the engine's (the engine is the conservative
  bound).
* **Adaptive adversary** — Byzantine nodes never churn voluntarily
  (``policies.byz_churn_probability``) and *rush* repair Locate() rounds:
  :func:`rush_picker` makes the repairer accept the first verifiable
  responder, with Byzantine responders ``adapt_boost``× as fast. Realized
  refill-Byzantine probability is ``βf / (βf + (1 − f))`` for population
  share ``f`` and boost ``β`` — the engine's ``βf``
  (``policies.refill_byz_probability``) to first order in ``f``.
* **Diurnal churn** — the per-step failure probability is recomputed every
  step from the sinusoidally modulated rate (``policies.diurnal_p_fail``,
  midpoint-sampled); both layers integrate the same factor, so daily-mean
  rates match exactly and the cross-validation gate stays two-sided.
* **Pareto sessions** — under ``CHURN_PARETO`` the failure coin is replaced
  by deterministic session expiry: every arrival draws a Pareto(α) lifetime
  from a dedicated RNG stream (mean matched to ``churn_per_year``) and
  departs when it ends. The engine's mean-field form
  (``policies.pareto_p_fail``) keeps the protected-cohort *lower bound* on
  churn, so protocol loss/traffic can only exceed it — a one-sided gate
  (documented abstraction leak, like eclipse).
* **Collusion / withholding** — ``ADV_COLLUDE`` Byzantine nodes *do* store
  fragments and pass Locate()/claims audits, but serve deterministically
  corrupt payloads at pull time; pullers verify rows against
  creator-recorded tags (``SimNetwork.frag_tags``), pay the wasted
  transfer, and retry on honest holders — the GF(256) decode never sees a
  corrupt row. Everything except repair-traffic accounting is
  bit-identical to the matched static run (pinned by a differential test).
* **Eclipse + targeted** — ``ADV_ECLIPSE_TARGETED`` (the composed zoo
  member) runs the partition window *and* the greedy kill at
  ``attack_step``, sharing the ``attack_frac`` budget knob.
* **Repair accounting** — a repaired fragment costs ``K_inner`` fragment
  transfers on a cold pull and one on a warm chunk-cache hit (repair.py
  docstring); ``repair_traffic_units`` converts bytes to object-size units
  with the group's true fragment length, so it is directly comparable to
  the engine's ``deficit · K_inner / (K_outer · K_inner)`` bookkeeping.
* **Serving** — with ``read_rate > 0`` every tick additionally serves a
  sampled batch of Zipf-popular client Get() requests end to end (cache
  probe → Locate() walk → fragment pulls → GF(256) decode through the
  same ``repair.decode_from_available`` core), classified
  hit/miss/degraded/failed exactly like the engine's closed-form serving
  model and charged against per-region link budgets that repair traffic
  shares (see :func:`_serve_tick`). Serving draws only from a dedicated
  RNG stream, so every pre-serving trace is bit-identical.
* **Group death is emergent, not flagged**: a group is alive iff its
  honest alive members hold ``≥ K_inner`` distinct fragment indices
  (decode possible). With caches disabled death is absorbing exactly like
  the engine's ``alive`` latch; a warm chunk cache *can* resurrect a group
  the engine would consider dead — a real protocol behavior the
  group-level abstraction gives away (cross-validation configs with
  nonzero TTL keep loss ≈ 0 so the delta never binds).

What this buys: every number the batched engine produces for a sweep cell
is backed by a run of the real selection/coding/repair code on matched
configurations — the correctness anchor ROADMAP.md called for.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import NamedTuple

import numpy as np

from repro.core import chunks as C
from repro.core import claims_engine as CE
from repro.core import group as G
from repro.core import policies as P
from repro.core import repair as R
from repro.core.network import REGIONS, Node, SimNetwork
from repro.core.rateless import InsufficientFragments
from repro.core.vault import VaultClient, gather_available
from repro.core.vrf import RING

# dedicated RNG stream tag for the serving layer (seeded as
# ``(p.seed, _SERVE_STREAM)``): serving never draws from ``rng`` or
# ``net.rng``, so a ``read_rate=0`` run is bit-identical to one predating
# the serving layer (pinned by tests/test_protocol_golden.py)
_SERVE_STREAM = 0x5E17
# dedicated stream for Pareto session-length draws (``CHURN_PARETO``):
# session lifetimes never touch ``rng``, so every non-pareto run is
# bit-identical to one predating session churn
_SESSION_STREAM = 0x5E55


@dataclasses.dataclass(frozen=True)
class ProtocolParams:
    """One protocol-level run. Knob names and meanings match
    ``scenarios.make_scenario`` (and ``policies``) so a matched engine cell
    is one :func:`to_scenario_kwargs` call away.

    Units: ``churn_per_year`` in failures per node-year, ``step_hours`` /
    ``cache_ttl_hours`` in hours, ``object_bytes`` in bytes,
    ``attack_frac`` as a fraction of ``n_nodes``.

    ``policy=`` is the preferred way to pick the churn/adversary point:
    any ``policies.PolicySpec`` (combinators / ``compose``), registered
    zoo name, or plain policy name resolves through ``policies.resolve``
    and its knobs are applied over the matching fields below.

    .. deprecated:: PR 10
        ``churn_policy=`` / ``adv_policy=`` (and passing policy knobs
        while relying on the defaults of the other axis) remain supported
        shims with unchanged behavior; when ``policy=`` is given it wins
        over both id fields and over any knob field its spec carries.
    """

    n_nodes: int = 120
    n_objects: int = 4
    object_bytes: int = 2000
    k_outer: int = 2
    n_chunks: int = 5
    k_inner: int = 6
    r_inner: int = 14
    byz_fraction: float = 0.0
    churn_per_year: float = 26.0
    cache_ttl_hours: float = 0.0
    step_hours: float = 12.0
    steps: int = 40
    churn_policy: int | str = "iid"
    adv_policy: int | str = "static"
    burst_prob: float = 0.05
    burst_mult: float = 20.0
    adapt_boost: float = 2.0
    attack_frac: float = 0.0
    attack_step: int = 0
    eclipse_steps: int = 0  # partition window length (eclipse policy)
    diurnal_amplitude: float = 0.6  # rate modulation depth (diurnal churn)
    pareto_alpha: float = 1.5  # session-length tail index (pareto churn)
    read_rate: float = 0.0  # client Get() requests per step (serving layer)
    zipf_alpha: float = 1.1  # object-popularity skew (policies.zipf_weights)
    region_cap: float = 0.0  # per-region link budget, object units/step; 0=∞
    claim_every: int = 1  # persistence-claim broadcast period (steps)
    vrf: str = "hash"  # selection-proof registry backend (vrf.make_registry)
    seed: int = 0
    policy: object = None  # PolicySpec / zoo name / policy name (resolver)

    def __post_init__(self):
        # Lower ``policy=`` onto the legacy id/knob fields exactly once.
        # Idempotent by construction (``resolve`` is deterministic), so
        # ``dataclasses.replace`` — which re-runs this — is safe.
        if self.policy is None:
            return
        low = P.resolve(self.policy)
        object.__setattr__(self, "churn_policy", low.churn)
        object.__setattr__(self, "adv_policy", low.adversary)
        kn = low.knob_dict()
        for k in P.POLICY_KNOBS:
            if k in kn:
                object.__setattr__(self, k, kn.pop(k))
        if kn:  # a spec knob with no matching field is a bug, not a no-op
            raise TypeError(f"unknown policy knobs: {sorted(kn)}")

    @property
    def code_params(self) -> C.CodeParams:
        return C.CodeParams(k_outer=self.k_outer, n_chunks=self.n_chunks,
                            k_inner=self.k_inner, r_inner=self.r_inner)

    def to_scenario_kwargs(self, **overrides) -> dict:
        """The matched group-level engine cell (``make_scenario`` kwargs)."""
        kw = dict(
            n_objects=self.n_objects, n_chunks=self.n_chunks,
            k_outer=self.k_outer, k_inner=self.k_inner,
            r_inner=self.r_inner, n_nodes=self.n_nodes,
            byz_fraction=self.byz_fraction,
            churn_per_year=self.churn_per_year,
            cache_ttl_hours=self.cache_ttl_hours,
            step_hours=self.step_hours, steps=self.steps,
            churn_policy=self.churn_policy, adv_policy=self.adv_policy,
            burst_prob=self.burst_prob, burst_mult=self.burst_mult,
            adapt_boost=self.adapt_boost, attack_frac=self.attack_frac,
            attack_step=self.attack_step, eclipse_steps=self.eclipse_steps,
            diurnal_amplitude=self.diurnal_amplitude,
            pareto_alpha=self.pareto_alpha,
            read_rate=self.read_rate, zipf_alpha=self.zipf_alpha,
            region_cap=self.region_cap,
        )
        kw.update(overrides)
        return kw


class ProtocolResult(NamedTuple):
    """Engine-schema result of one protocol run.

    The first nine fields mirror ``scenarios.ScenarioResult`` name by name
    (scalars here, ``[cells, seeds]`` arrays there); the trailing fields
    are protocol-level extras the group abstraction cannot produce.
    """

    repair_traffic_units: float  # object-size units, see repair.py
    repairs: int                 # fragments regenerated
    cache_hits: int              # warm-cache single-fragment repairs
    lost_objects: int            # objects with < K_outer decodable chunks
    lost_fraction: float
    final_honest_mean: float     # mean honest fragments over live groups
    honest_min: float            # min honest seen in any live group
    members_max: float           # max honest+byz claimers seen in any group
    alive_frac_trace: np.ndarray  # [steps] post-repair live-group fraction
    # ---- protocol-level extras -------------------------------------------
    honest_trace: np.ndarray     # [steps, n_groups] honest fragment counts
    byz_trace: np.ndarray        # [steps, n_groups] Byzantine claimers
    loss_events: tuple           # ((step, object_index), ...) first losses
    n_groups: int
    repair_attempts: int         # repair calls that regenerated ≥1 fragment
    # ---- serving layer (mirrors the engine's serving fields) -------------
    reads_issued: int            # Get() requests sampled over the run
    reads_hit: int               # served entirely from warm chunk caches
    reads_miss: int              # served via fragment pulls + decode
    reads_degraded: int          # served with < n_chunks readable chunks
    reads_failed: int            # < k_outer readable chunks: unreadable
    served_traffic_units: float  # object-size units shipped to clients
    serve_hop_hist: np.ndarray   # [policies.SERVE_HIST_BINS] reads per
    #                              congestion-stretched hop count
    serve_trace: np.ndarray      # [steps, 5] per-tick (issued, hit, miss,
    #                              degraded, failed) — golden-pinned


def rush_picker(net: SimNetwork, boost: float):
    """Adaptive-adversary response bias for ``repair._locate_new_member``.

    Models Byzantine repair-flooding: every verifiably-selected responder
    races to answer the RepairRequest and Byzantine responders are
    ``boost``× as fast, so the repairer's first verifiable answer is
    Byzantine with probability ``β·n_b / (β·n_b + n_h)``. Draws from
    ``net.rng`` (deterministic per seed)."""
    def pick(responders) -> int:
        w = np.array([boost if n.byzantine else 1.0
                      for _, n, _ in responders], np.float64)
        return int(net.rng.choice(len(responders), p=w / w.sum()))
    return pick


def _spawn(net: SimNetwork, rng, byz_p: float, counter: list[int],
           colluding: bool = False, session=None) -> Node:
    """Add one node with a deterministic keypair seed and Byzantine coin.

    ``colluding=True`` flags Byzantine arrivals as withholding colluders
    (``policies.ADV_COLLUDE``). ``session``, when given, is the Pareto
    session context ``(session_rng, mean_hours, alpha, adaptive)``
    (``CHURN_PARETO``): the node's lifetime is drawn from the dedicated
    session stream — never from ``rng``, so non-pareto runs are
    bit-identical — except adaptive Byzantine nodes, which never churn
    voluntarily (``policies.byz_churn_probability``) and keep an
    infinite session."""
    counter[0] += 1
    node = net.add_node(
        byzantine=bool(rng.random() < byz_p),
        seed=counter[0].to_bytes(8, "little"))
    if colluding and node.byzantine:
        node.colluding = True
    if session is not None:
        srng, mean_h, alpha, adaptive = session
        if not (node.byzantine and adaptive):
            node.session_end = net.now + float(P.pareto_session_from_uniform(
                srng.random(), mean_h, alpha, xp=np))
    return node


def _census(net: SimNetwork, registry: dict, k_inner: int):
    """Ground-truth group composition in one pass over the network.

    Returns ``(honest, byz, alive)`` arrays over the group index order of
    ``registry`` (chash → group index): ``honest[g]`` counts distinct
    fragment indices held by alive honest nodes, ``byz[g]`` alive Byzantine
    claimers, ``alive[g]`` decodability (``honest ≥ K_inner``)."""
    n = len(registry)
    frag_sets: list[set] = [set() for _ in range(n)]
    byz = np.zeros(n, np.int64)
    for node in net.nodes.values():
        if not node.alive:
            continue
        if node.byzantine:
            for chash in node.groups:
                g = registry.get(chash)
                if g is not None:
                    byz[g] += 1
        else:
            for (chash, idx) in node.fragments:
                g = registry.get(chash)
                if g is not None:
                    frag_sets[g].add(idx)
    honest = np.array([len(s) for s in frag_sets], np.int64)
    return honest, byz, honest >= k_inner


def _burst_coin(net: SimNetwork, rng, p: ProtocolParams, p_fail: float):
    """The shared head of both churn implementations: draw the burst coin
    and precompute the second-thinning probabilities."""
    churn_id = P.churn_policy_id(p.churn_policy)
    u = rng.random(2)
    burst, region = P.burst_from_uniforms(
        churn_id, p.burst_prob, u[0], np.float64(u[1]), xp=np)
    p_extra = float(P.burst_extra_probability(
        np.float64(p_fail), p.burst_mult, xp=np))
    p_extra_b = float(P.byz_churn_probability(
        P.adv_policy_id(p.adv_policy), p_extra, xp=np))
    return burst, region, p_extra, p_extra_b


def _respawn(net: SimNetwork, rng, p: ProtocolParams, failed: list[int],
             counter: list[int], session=None) -> int:
    """Replace ``failed`` nodes with fresh arrivals (population constant)."""
    colluding = P.adv_policy_id(p.adv_policy) in P.ADV_COLLUDE_FAMILY
    for nid in failed:
        net.fail_node(nid)
        _spawn(net, rng, p.byz_fraction, counter, colluding=colluding,
               session=session)
    return len(failed)


def _churn_scalar_body(net: SimNetwork, rng, client_nid: int, p_fail: float,
                       p_fail_b: float, burst, region, p_extra: float,
                       p_extra_b: float) -> list[int]:
    """The PR 3 per-node thinning loop: one ``rng.random()`` per eligible
    node, with the burst's second thinning draw interleaved per node.
    Shared verbatim by the reference engine (every step) and the
    vectorized engine (burst steps, whose interleaved draws a block draw
    cannot reproduce). Returns the failed nids in ring order."""
    failed = []
    for node in net.alive_nodes():
        if node.nid == client_nid:
            continue  # one immortal observer drives queries/repairs
        pf = p_fail_b if node.byzantine else p_fail
        dead = rng.random() < pf
        if not dead and burst and P.ring_domain(node.nid, RING) == region:
            # second thinning pass — composes to the boosted rate exactly
            # (policies.burst_extra_probability), as in the engine
            dead = rng.random() < (p_extra_b if node.byzantine else p_extra)
        if dead:
            failed.append(node.nid)
    return failed


def _churn_step(net: SimNetwork, rng, p: ProtocolParams, client_nid: int,
                p_fail: float, p_fail_b: float, counter: list[int]) -> int:
    """One churn half-step: i.i.d. thinning (+ regional burst), replace
    failures with fresh arrivals. Returns the number of failures."""
    burst, region, p_extra, p_extra_b = _burst_coin(net, rng, p, p_fail)
    failed = _churn_scalar_body(net, rng, client_nid, p_fail, p_fail_b,
                                burst, region, p_extra, p_extra_b)
    return _respawn(net, rng, p, failed, counter)


def _churn_step_vec(net: SimNetwork, rng, p: ProtocolParams, client_nid: int,
                    p_fail: float, p_fail_b: float,
                    counter: list[int]) -> int:
    """Vectorized churn: one block uniform draw + array thinning masks.

    numpy's block ``rng.random(m)`` consumes the bit stream exactly like
    ``m`` scalar draws, so on non-burst steps (every i.i.d. step, and the
    ``1 − burst_prob`` share of regional steps) the failure set is
    bit-identical to :func:`_churn_step`. Burst steps fall through to the
    shared scalar body to preserve the interleaved stream.
    """
    burst, region, p_extra, p_extra_b = _burst_coin(net, rng, p, p_fail)
    if burst:
        failed = _churn_scalar_body(net, rng, client_nid, p_fail, p_fail_b,
                                    burst, region, p_extra, p_extra_b)
        return _respawn(net, rng, p, failed, counter)
    elig = [n for n in net.alive_nodes() if n.nid != client_nid]
    us = rng.random(len(elig))
    pf = np.where(np.fromiter((n.byzantine for n in elig), bool, len(elig)),
                  p_fail_b, p_fail)
    dead = us < pf
    failed = [n.nid for n, d in zip(elig, dead) if d]
    return _respawn(net, rng, p, failed, counter)


def _churn_step_pareto(net: SimNetwork, rng, p: ProtocolParams,
                       client_nid: int, counter: list[int],
                       session) -> int:
    """Session-expiry churn (``CHURN_PARETO``): a node departs when its
    Pareto-drawn session ends — deterministic given the session stream,
    no per-step failure coin — and its replacement draws a fresh session.
    The ring walk is the sorted-nid order, so the failure list is
    deterministic; respawn Byzantine coins still come from ``rng``."""
    failed = [n.nid for n in net.alive_nodes()
              if n.nid != client_nid and n.session_end <= net.now]
    return _respawn(net, rng, p, failed, counter, session=session)


def _targeted_attack(net: SimNetwork, rng, p: ProtocolParams,
                     registry: dict, k_inner: int) -> int:
    """Greedy targeted kill (A.3 cost model via ``policies.kill_cost``).

    The adversary sees group compositions (worst case, A.2) but not the
    chunk→object mapping: it disconnects the honest members of the
    cheapest groups first, stopping at the first unaffordable group
    (budget ``attack_frac · n_nodes`` node removals). Returns the number
    of nodes disconnected."""
    by_group: dict[int, set[int]] = {g: set() for g in registry.values()}
    for node in net.nodes.values():
        if node.alive and not node.byzantine:
            for (chash, _idx) in node.fragments:
                g = registry.get(chash)
                if g is not None:
                    by_group[g].add(node.nid)
    honest = np.array([len(by_group[g]) for g in sorted(by_group)],
                      np.float64)
    cost = np.asarray(P.kill_cost(honest, float(k_inner), 1.0, xp=np))
    # cheapest groups first, random tiebreak (outer-code opacity: the
    # attacker cannot tell equal-cost groups apart) — same ordering rule
    # as the engine's _targeted_kill / the numpy targeted_attack_vault
    order = rng.permutation(len(cost))
    order = order[np.argsort(cost[order], kind="stable")]
    budget = p.attack_frac * p.n_nodes
    killed: set[int] = set()
    for g in order:
        if cost[g] <= 0:
            continue
        # already-killed co-located nodes count as free (emergent
        # frags_per_node amortization)
        victims = [nid for nid in sorted(by_group[int(g)])
                   if nid not in killed]
        price = len(victims) - k_inner + 1
        if price <= 0:
            continue
        if price > budget:
            break  # cheapest-first cumulative budget exhausted
        rng.shuffle(victims)
        killed.update(victims[:price])
        budget -= price
    for nid in killed:
        if nid in net.nodes and net.nodes[nid].alive:
            net.fail_node(nid)
    return len(killed)


def _repair_tick(net: SimNetwork, p: ProtocolParams, registry: dict,
                 frag_len: dict, pick, batch: bool = False,
                 claims: "CE.ClaimsEngine | None" = None,
                 pool: "R.SolvePool | None" = None,
                 ) -> tuple[float, int, int, int]:
    """One decentralized repair tick: every alive node checks each of its
    group views and repairs the ones short of ``R`` (repair.py §4.3.4).

    Over-repair within a tick is prevented the protocol's own way: the
    first member to repair restores the group, and later members' stale
    views converge via MembershipTimer before they would add anyone.
    Returns ``(traffic_units, repairs, cache_hits, attempts)``; bytes are
    converted to object-size units with each group's true fragment length.

    The vectorized engine passes ``batch=True`` (batched VRF rounds inside
    ``repair_group``) and the :class:`~repro.core.claims_engine.
    ClaimsEngine`, whose resident tables turn the ``≥ R`` pre-check into
    an O(1) count lookup. Any group a ``repair_group`` call (or the
    inlined timer merge) may have mutated is marked dirty on the engine
    and falls back to the exact dict walk until the next claim round
    re-ingests it — so the pre-check outcome is identical to the scalar
    path's, call for call.
    """
    frag_units = 1.0 / (p.k_outer * p.k_inner)
    ttl = p.cache_ttl_hours
    traffic_units, repairs, hits, attempts = 0.0, 0, 0, 0
    if claims is not None:
        claims.begin_repair_tick()  # liveness changed since the last tick
    timer_cache: dict | None = {} if batch else None
    # Liveness is fixed for the whole tick (churn and the attack have
    # already run; repairs only ever add members), so every view's alive
    # count is non-decreasing from here on. One vectorized pass over the
    # resident tables therefore finds every (viewer, group) pair that can
    # possibly be under R this tick — visiting a >= R view is a pure
    # no-op in the loop body below, so skipping those pairs is exact.
    # Only usable when the tables cover every view (claim round just
    # synced, nothing dirty); nodes that GAIN views mid-tick (fresh
    # repair members, reported via ``RepairStats.new_nids``) fall back to
    # the full walk of their group lists.
    visit: dict[int, dict[bytes, int]] | None = None
    if claims is not None and claims._started and not claims.dirty:
        visit = claims.under_r_visits(registry, p.r_inner)
    tick_new: set[int] = set()
    # Iteration order is the ring's sorted-nid order over the tick-start
    # alive snapshot (repairs only add views, never nodes, so the ring is
    # static for the whole tick). With a live visit table only its listed
    # viewers — plus mid-tick recruits — can do any work, so the walk
    # visits just those nids, heap-merged in sorted order: a recruit with
    # a nid beyond the current position is pushed and reached exactly
    # where the full ring walk would have reached it; one at or before
    # the current position would not be revisited by the full walk either.
    nodes_d = net.nodes
    if visit is None:
        queue = [n.nid for n in net.alive_nodes()]
        queue.reverse()  # pop() from the tail yields ascending nids
        pop_next = queue.pop
        enqueue = None
    else:
        heap = sorted(visit)  # ascending => already a valid heap
        queued = set(heap)
        pop_next = lambda: heapq.heappop(heap)  # noqa: E731
        queue = heap

        def enqueue(nids: list[int], cur: int) -> None:
            for nn in nids:
                if nn > cur and nn not in queued:
                    queued.add(nn)
                    heapq.heappush(heap, nn)

    while queue:
        nid = pop_next()
        node = nodes_d.get(nid)
        if node is None or node.byzantine:
            continue  # Fig. 6 adversary stores nothing and repairs nothing
        # The precomputed table count stays EXACT for every (viewer, group)
        # pair on the visit list until that viewer's own view mutates —
        # and mid-tick the only mutation paths are the viewer's own visit
        # (below) and being recruited by someone else's repair, which
        # lands the viewer in ``tick_new`` and onto the exact-walk path.
        # So visit-listed pairs skip both the table lookup and the dict
        # walk: their tick-start count IS the current count.
        fast_counts: dict | None = None
        if visit is None or nid in tick_new:
            group_iter = list(node.groups)
        else:
            want = visit.get(nid)
            if not want:
                continue
            group_iter = [ch for ch in node.groups if ch in want]
            fast_counts = want
        for chash in group_iter:
            if chash not in registry:
                continue
            if fast_counts is not None:
                n_alive = fast_counts[chash]
            else:
                n_alive = (claims.precheck_count(node.nid, chash)
                           if claims is not None else None)
                if n_alive is None:
                    n_alive = len(G.alive_members(net, node, chash))
            if n_alive >= p.r_inner:
                continue  # cheap pre-check; repair_group re-verifies
            if batch and not net.is_eclipsed(node.nid):
                # inline the call's no-op fast path: in steady state almost
                # every under-R view is restored by MembershipTimer alone
                # (an earlier member already repaired the group), and such
                # a repair_group call's ONLY effect is the timer merge.
                # Apply the cached admit set directly and skip the call
                # when the merged view is back at R — bit-identical state
                # (same writes, same order, no RNG anywhere on this path).
                admit = timer_cache.get(chash)
                if admit is not None:
                    mem = node.groups[chash].members
                    for anid in admit:
                        mem[anid] = net.now
                    if claims is not None:
                        claims.touch(chash)  # merge outdated the tables
                    # every admitted candidate is ring-resident => alive
                    # this tick, so |admit| >= R already proves the merged
                    # view holds R alive members — skip the dict walk
                    if len(admit) >= p.r_inner:
                        continue
                    alive_set = net.alive_set
                    if sum(1 for mnid in mem if mnid in alive_set) \
                            >= p.r_inner:
                        continue
            s = R.repair_group(net, node, chash, cache_ttl=ttl, pick=pick,
                               batch=batch, timer_cache=timer_cache,
                               pool=pool)
            if claims is not None:
                # MembershipTimer inside repair_group may have changed the
                # view even when nothing was repaired — stop trusting the
                # table for this group until the next re-ingest
                claims.touch(chash)
            if s.repaired:
                attempts += 1
                tick_new.update(s.new_nids)
                if enqueue is not None:
                    enqueue(s.new_nids, nid)
            repairs += s.repaired
            hits += s.cache_hits
            traffic_units += s.traffic_bytes / frag_len[chash] * frag_units
    if pool is not None:
        # drain the tick's deferred decode systems: one padded batched
        # GF(256) dispatch (plus masked retry rounds for the rare
        # rank-deficient lanes) re-proves every inline rank decision
        pool.flush()
    return traffic_units, repairs, hits, attempts


def _serve_tick(net: SimNetwork, p: ProtocolParams, serve_rng, oids,
                zipf_w: np.ndarray, frag_len0: int,
                pool: "R.SolvePool | None" = None):
    """One serving tick: sample ``round(read_rate)`` Zipf-popular Get()
    requests and serve each end to end — cache probe → DHT candidate walk
    (Locate()) → fragment pulls → GF(256) decode via the shared
    ``repair.decode_from_available`` core — classifying every request
    hit / miss / degraded / failed exactly like the engine's closed-form
    serving model (``scenarios._vault_serve``).

    The read path within one tick is deterministic: candidate walks,
    cache probes and decode pull counts are pure functions of network
    state, with no RNG anywhere (``net.rng`` is never touched). Requests
    for the same object are therefore evaluated **once** and weighted by
    their sampled multiplicity — millions of issued reads cost at most
    ``n_objects`` end-to-end evaluations per tick. The only randomness is
    the dedicated ``serve_rng`` (object popularity sampling), so serving
    never perturbs the churn/claims/repair stream.

    Accounting mirrors the engine: a failed read ships nothing; a served
    read ships its ``k_outer`` chosen chunks (cached chunks whole, missed
    chunks as the decode's ``n_pull`` fragments — ~1 object unit either
    way). Base hop counts come from ``policies`` (hit 2, miss 3, degraded
    +1); the congestion pass then stretches each read by the worst
    oversubscription among the regions it touched, where per-region load
    is this tick's repair bytes (``net.region_load``, charged by
    repair.py) plus the serving bytes charged here — repair and serving
    compete for the same links. ``region_cap <= 0`` disables the stretch.

    Returns ``(counts, served_units, hist)`` with ``counts`` the int64
    5-vector ``(issued, hit, miss, degraded, failed)`` and ``hist`` the
    ``SERVE_HIST_BINS`` effective-hop histogram of completed reads.
    """
    m = int(round(p.read_rate))
    counts = np.zeros(5, np.int64)
    hist = np.zeros(P.SERVE_HIST_BINS, np.int64)
    served_units = 0.0
    if m <= 0:
        return counts, served_units, hist
    frag_units = 1.0 / (p.k_outer * p.k_inner)
    counts[0] = m
    mult = np.bincount(serve_rng.choice(len(oids), size=m, p=zipf_w),
                       minlength=len(oids))
    serve_bytes = np.zeros(len(REGIONS))
    pending = []  # (count, base hops, touched regions) for the stretch pass
    for o in np.nonzero(mult)[0]:
        cnt = int(mult[o])
        ok_chunks = []  # (hops, units, cached, {region: bytes})
        for chash in oids[int(o)].chunk_hashes:
            # cache probe first: any reachable candidate with a warm chunk
            # copy serves the whole chunk (the scan is skipped while no
            # cache_chunk write has ever landed, as in repair_group)
            warm = None
            if net.chunk_caches:
                cands = net.candidates(C.hash_point(chash),
                                       min(4 * p.r_inner, net.n_nodes))
                warm = next((c for c in cands
                             if c.cached_chunk(chash) is not None), None)
            if warm is not None:
                nbytes = len(warm.cached_chunk(chash))
                ok_chunks.append((P.SERVE_HOPS_HIT,
                                  nbytes / frag_len0 * frag_units, True,
                                  {warm.region: nbytes}))
                continue
            # corrupt rows (colluding holders) are filtered by the gather
            # and NOT charged on the serve path: the engine's closed-form
            # serving model has no withholding term, so keeping the read
            # path cost-free under collusion keeps both layers' serving
            # metrics matched — the withholding cost lands in repair
            # traffic on both layers instead
            rows, _holders, _corrupt = gather_available(net, chash,
                                                        p.r_inner)
            if len(rows) < p.k_inner:
                continue  # chunk unreadable this tick
            try:
                _chunk, n_pull = R.decode_from_available(
                    chash, p.k_inner, rows, pool=pool)
            except InsufficientFragments:
                continue  # reachable rows never reach rank k_inner
            rbytes: dict[int, int] = {}
            nbytes = 0
            for _, payload, holder in rows[:n_pull]:
                nbytes += len(payload)
                rbytes[holder.region] = (rbytes.get(holder.region, 0)
                                         + len(payload))
            ok_chunks.append((P.SERVE_HOPS_MISS,
                              nbytes / frag_len0 * frag_units, False,
                              rbytes))
        if len(ok_chunks) < p.k_outer:
            counts[4] += cnt  # failed: object unreadable, nothing shipped
            continue
        degraded = len(ok_chunks) < p.n_chunks
        # the client takes the cheapest k_outer chunks — cached ones first
        # (stable sort: chunk order breaks ties deterministically)
        ok_chunks.sort(key=lambda c: c[0])
        chosen = ok_chunks[:p.k_outer]
        if degraded:
            counts[3] += cnt
        elif all(c[2] for c in chosen):
            counts[1] += cnt
        else:
            counts[2] += cnt
        hops = max(c[0] for c in chosen) + (
            P.SERVE_HOPS_DEGRADED_EXTRA if degraded else 0.0)
        served_units += cnt * sum(c[1] for c in chosen)
        touched: set[int] = set()
        for c in chosen:
            for reg, b in c[3].items():
                serve_bytes[reg] += cnt * b
                touched.add(reg)
        pending.append((cnt, hops, touched))
    # congestion pass: this tick's repair bytes (net.region_load) and the
    # serving bytes above share the links; each completed read is
    # stretched by the worst factor among the regions it touched
    region_units = (net.region_load + serve_bytes) * frag_units / frag_len0
    factor = np.asarray(P.congestion_factor(region_units, p.region_cap,
                                            xp=np), np.float64)
    for cnt, hops, touched in pending:
        f = max((float(factor[reg]) for reg in touched), default=1.0)
        hist[int(P.effective_hops(hops, f, xp=np))] += cnt
    return counts, float(served_units), hist


def run_protocol(p: ProtocolParams, engine: str = "vectorized",
                 probe=None) -> ProtocolResult:
    """Run one seeded protocol-level simulation end to end.

    Builds the network, stores ``n_objects`` real objects through the VRF
    placement path, then advances ``steps`` scan-equivalent steps (churn →
    attack → eclipse window → claims → repair → record). Deterministic:
    identical ``p`` (including ``seed``) produces an identical
    :class:`ProtocolResult` (validated by ``tests/test_protocol_sim.py``).

    ``engine`` picks the tick implementation:

    * ``"vectorized"`` (default) — block-drawn churn, the closed-form
      array claims round (``group.claims_phase``), table-driven repair
      pre-checks, and batched VRF verification (one memoized
      ``verify_selection_batch`` pass per tick; a single vectorized
      ``kernels/prf_select`` dispatch on the ``vrf="arx"`` backend).
    * ``"reference"`` — the preserved PR 3 scalar path: per-claim
      ``verify_selection`` sha256 loops and per-node dict updates.

    Both engines consume the identical RNG stream and produce bit-identical
    results (``tests/test_protocol_golden.py`` pins them to a golden
    capture of the PR 3 commit); ``benchmarks/protocol_speed.py`` measures
    the throughput gap. ``probe(t, net)``, if given, is called after each
    step's census — a read-only hook for invariant tests.
    """
    if engine not in ("vectorized", "reference"):
        raise ValueError(f"unknown engine {engine!r}")
    vec = engine == "vectorized"
    rng = np.random.default_rng(p.seed)
    net = SimNetwork(seed=p.seed, vrf=p.vrf, cache_lookups=vec)
    churn_id = P.churn_policy_id(p.churn_policy)
    adv_id = P.adv_policy_id(p.adv_policy)
    colluding = adv_id in P.ADV_COLLUDE_FAMILY
    session = None
    if churn_id == P.CHURN_PARETO:
        # mean session matches the i.i.d. churn rate; lifetimes draw from
        # a dedicated stream so every other policy is bit-unaffected
        session = (np.random.default_rng((p.seed, _SESSION_STREAM)),
                   float(P.pareto_session_mean_hours(p.churn_per_year,
                                                     xp=np)),
                   p.pareto_alpha, adv_id == P.ADV_ADAPTIVE)
    counter = [0]
    for _ in range(p.n_nodes):
        _spawn(net, rng, p.byz_fraction, counter, colluding=colluding,
               session=session)
    client_node = next(n for n in net.alive_nodes() if not n.byzantine)
    client = VaultClient(net, client_node, batch=vec)

    code = p.code_params
    registry: dict[bytes, int] = {}   # chash -> flat group index
    frag_len: dict[bytes, int] = {}
    oids = []
    for _ in range(p.n_objects):
        data = rng.integers(0, 256, p.object_bytes, np.uint8).tobytes()
        oid, _st = client.store(data, code, cache_ttl=p.cache_ttl_hours)
        oids.append(oid)
        for chash in oid.chunk_hashes:
            registry[chash] = len(registry)
    for node in net.nodes.values():
        for (chash, _i), frag in node.fragments.items():
            frag_len.setdefault(chash, len(frag))

    pick = (rush_picker(net, p.adapt_boost)
            if adv_id == P.ADV_ADAPTIVE else None)
    # bootstrap: top groups up to R (client stores may undershoot when the
    # candidate set thins out); uncounted, like the engine's exact-R init
    # pool: cross-tick decode-chunk memo + per-tick deferred solve batch
    # (vectorized engine only — see repair.SolvePool)
    pool = R.SolvePool() if vec else None
    _repair_tick(net, p, registry, frag_len, pick, batch=vec, pool=pool)

    p_fail_base = float(P.p_fail_step(p.churn_per_year, p.step_hours, xp=np))

    serve_on = p.read_rate > 0 and p.n_objects > 0
    serve_rng = zipf_w = None
    frag_len0 = next(iter(frag_len.values())) if frag_len else 1
    if serve_on:
        serve_rng = np.random.default_rng((p.seed, _SERVE_STREAM))
        zw = np.asarray(P.zipf_weights(np.arange(p.n_objects), p.zipf_alpha,
                                       p.n_objects, xp=np), np.float64)
        zipf_w = zw / zw.sum()
    serve_trace = np.zeros((p.steps, 5), np.int64)
    serve_hist = np.zeros(P.SERVE_HIST_BINS, np.int64)
    served_units = 0.0

    n_groups = len(registry)  # object-major: group g belongs to object
    honest_tr = np.zeros((p.steps, n_groups), np.int64)  # g // n_chunks
    byz_tr = np.zeros((p.steps, n_groups), np.int64)
    alive_frac = np.zeros(p.steps)
    lost_seen: set[int] = set()
    loss_events: list[tuple[int, int]] = []
    traffic_units, repairs, cache_hits, attempts = 0.0, 0, 0, 0
    honest_min, members_max = np.inf, 0.0

    segment = P.ring_segment(p.attack_frac, RING)
    claim_timeout = 3.0 * p.step_hours * max(p.claim_every, 1)
    claims = CE.ClaimsEngine(net) if vec else None
    for t in range(p.steps):
        net.now += p.step_hours
        net.region_load[:] = 0.0  # per-tick link budgets (repair + serving)
        if adv_id in P.ADV_ECLIPSE_FAMILY:
            in_window = p.attack_step <= t < p.attack_step + p.eclipse_steps
            net.eclipse = segment if in_window else None
        # per-step failure probability: identical to p_fail_base except
        # under diurnal modulation (the where() is value-identical for
        # every other policy, so pre-existing goldens are bit-stable)
        p_fail = float(P.diurnal_p_fail(
            churn_id, p.churn_per_year, p.diurnal_amplitude, t,
            p.step_hours, p_fail_base, xp=np))
        p_fail_b = float(P.byz_churn_probability(adv_id, p_fail, xp=np))
        if churn_id == P.CHURN_PARETO:
            _churn_step_pareto(net, rng, p, client_node.nid, counter,
                               session)
        else:
            churn = _churn_step_vec if vec else _churn_step
            churn(net, rng, p, client_node.nid, p_fail, p_fail_b, counter)
        if adv_id in P.ADV_TARGETED_FAMILY and t == p.attack_step:
            _targeted_attack(net, rng, p, registry, p.k_inner)
        if p.claim_every and t % p.claim_every == 0:
            nodes = list(net.alive_nodes())
            if vec:
                claims.round(nodes, claim_timeout)
            else:
                for node in nodes:
                    if net.is_eclipsed(node.nid):
                        continue  # partitioned: no claims, timers frozen
                    G.broadcast_claims(net, node)
                    G.prune_dead_members(net, node, claim_timeout)
        tu, rp, ch, at = _repair_tick(
            net, p, registry, frag_len, pick, batch=vec, claims=claims,
            pool=pool)
        traffic_units += tu
        repairs += rp
        cache_hits += ch
        attempts += at
        if serve_on:
            cnts, su, hadd = _serve_tick(net, p, serve_rng, oids, zipf_w,
                                         frag_len0, pool=pool)
            serve_trace[t] = cnts
            serve_hist += hadd
            served_units += su
            if pool is not None:
                pool.flush()  # serving's own deferred decode systems
        honest, byz, alive = _census(net, registry, p.k_inner)
        honest_tr[t] = honest
        byz_tr[t] = byz
        alive_frac[t] = alive.mean() if n_groups else 0.0
        if n_groups:
            if alive.any():
                honest_min = min(honest_min, int(honest[alive].min()))
            members_max = max(members_max, float((honest + byz).max()))
        chunks_alive = alive.reshape(p.n_objects, p.n_chunks).sum(axis=1)
        for o in np.nonzero(chunks_alive < p.k_outer)[0]:
            if int(o) not in lost_seen:
                lost_seen.add(int(o))
                loss_events.append((t, int(o)))
        if probe is not None:
            probe(t, net)
    net.eclipse = None  # window cannot outlive the run

    if p.steps == 0:  # nothing simulated: census the freshly-stored state
        honest, byz, alive = _census(net, registry, p.k_inner)
        chunks_alive = alive.reshape(p.n_objects, p.n_chunks).sum(axis=1)
    lost = int((chunks_alive < p.k_outer).sum())
    return ProtocolResult(
        repair_traffic_units=float(traffic_units),
        repairs=int(repairs),
        cache_hits=int(cache_hits),
        lost_objects=lost,
        lost_fraction=lost / max(p.n_objects, 1),
        final_honest_mean=(float(honest[alive].mean()) if alive.any()
                           else 0.0),
        honest_min=float(honest_min if np.isfinite(honest_min) else 0.0),
        members_max=float(members_max),
        alive_frac_trace=alive_frac,
        honest_trace=honest_tr,
        byz_trace=byz_tr,
        loss_events=tuple(loss_events),
        n_groups=n_groups,
        repair_attempts=int(attempts),
        reads_issued=int(serve_trace[:, 0].sum()),
        reads_hit=int(serve_trace[:, 1].sum()),
        reads_miss=int(serve_trace[:, 2].sum()),
        reads_degraded=int(serve_trace[:, 3].sum()),
        reads_failed=int(serve_trace[:, 4].sum()),
        served_traffic_units=float(served_units),
        serve_hop_hist=serve_hist,
        serve_trace=serve_trace,
    )


def run_protocol_seeds(p: ProtocolParams, seeds=range(4)) -> list:
    """Replicate :func:`run_protocol` over seeds (protocol-side analogue of
    the engine's seed axis). Returns one :class:`ProtocolResult` per seed."""
    return [run_protocol(dataclasses.replace(p, seed=int(s)))
            for s in seeds]


def summarize(results: list) -> dict:
    """Seed-mean summary of the engine-comparable fields.

    Returns ``{field: (mean, ci95_halfwidth)}`` for the scalar fields shared
    with ``scenarios.ScenarioResult``, computed by the engine's own
    ``scenarios.mean_ci`` so both layers report one CI convention."""
    from repro.core.scenarios import mean_ci

    out = {}
    for field in ("repair_traffic_units", "repairs", "cache_hits",
                  "lost_objects", "lost_fraction", "final_honest_mean",
                  "honest_min", "members_max", "reads_issued", "reads_hit",
                  "reads_miss", "reads_degraded", "reads_failed",
                  "served_traffic_units"):
        m, ci = mean_ci(np.array([getattr(r, field) for r in results],
                                 np.float64))
        out[field] = (float(m), float(ci))
    return out
