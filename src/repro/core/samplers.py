"""Pluggable high-rate binomial samplers for the batched scenario engine.

The scenario engine's hot loop draws three binomials per group per step
(honest churn, Byzantine churn, repair refill).  ``jax.random.binomial``'s
rejection sampler runs at ~6 M samples/s on CPU and dominated PR 1's sweep
cost, so this module makes the sampler a first-class, swappable component.
Each entry in :data:`SAMPLERS` is a :class:`Sampler` bundle — key
derivation *and* draw functions — so the engine can run an entire
time-step's randomness either through the reference ``threefry`` path or
through a counter-based ARX pipeline with no per-step key hashing at all.

Samplers
--------

``exact``
    ``jax.random.binomial`` (rejection sampling) with threefry keys.  The
    reference: statistically exact for every ``(n, p)``, and the slowest.

``fast``
    Threefry uniforms feeding :func:`binom_from_uniform` — a truncated
    inverse-CDF for small means and a rounded-Gaussian tail above
    :data:`GAUSS_CUT`.  PR 1's hybrid sampler, re-based onto the
    division-free CDF recurrence below (the old per-lane divisions made the
    recurrence ~10x slower than its flop count).

``arx``
    The high-rate path.  Uniforms come from the ARX (add-rotate-xor) keyed
    PRF in ``kernels/prf_select.py`` — ChaCha-style quarter-rounds over
    ``(key0, key1, lane, salt)`` counters — instead of threefry
    (~4x cheaper per uniform on CPU), per-step/stream keys are derived with
    two integer multiplies instead of a threefry hash, and draws run
    through the same :func:`binom_from_uniform` core.  ``ARX_ROUNDS = 4``
    full quarter-round groups pass a 256-bin uniformity chi-square over
    2 M lanes (chi2 ~ 235, dof 255) with |lag-1 autocorrelation| < 1e-3;
    the per-seed base key is still a threefry hash (one-time, outside the
    scan) so consecutive integer seeds stay decorrelated.

Error budget (validated in ``tests/test_samplers.py``)
------------------------------------------------------

* Small-mean branch (``n*p <= GAUSS_CUT``): exact inverse-CDF up to the
  truncation tail ``P(X > INV_CDF_TERMS)`` — at the cutover mean 3.0 that
  tail is ~2e-5, below Monte-Carlo noise at any seed count the engine
  runs.  Chi-square against the exact PMF passes at the 1e-3 level across
  the churn regimes the engine actually hits (``n*p <~ 2``).
* Gaussian branch (``n*p > GAUSS_CUT``): rounded Gaussian via a
  logistic-probit ``z`` (one log instead of erfinv).  Mean and variance
  are exact to ~1 % relative; the sup-CDF error is <= ~3 % (the logistic
  probit's classical deviation) — immaterial for repair-burst sizes, and
  identical to PR 1's hybrid budget.
* ``(1-p)^n`` is computed by integer square-and-multiply
  (:func:`pow_int`), exact to float32 rounding for ``n <= 255`` — the full
  engine domain, enforced by ``make_scenario`` (``r_inner, replication <
  256``); no ``exp``/``log1p`` in the small-mean branch at all.

All draws are float32 in/out (counts are integer-valued floats, matching
the engine's state dtype); keys/lanes are int32 — nothing in this module
touches float64 or int64, so it runs identically with or without x64.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.prf_select import arx_mix

# ----------------------------------------------------------------- constants
INV_CDF_TERMS = 12    # truncated inverse-CDF terms; exact for means <= cut
GAUSS_CUT = 3.0       # switch to rounded Gaussian above this mean
ARX_ROUNDS = 4        # quarter-round groups per uniform (8 = PRF strength)

_GOLD = np.int32(-1640531527)    # 0x9E3779B9: golden-ratio increment
_MULT1 = np.int32(-1640531535)   # odd multipliers: bijective in Z_2^32,
_MULT2 = np.int32(747796405)     # so distinct (t, stream) never collide
_SALT0 = np.int32(1013904223)


class Sampler(NamedTuple):
    """One pluggable randomness pipeline for the engine.

    ``base``     int32 seed scalar -> per-element key carrier (one-time,
                 outside the scan — may hash).
    ``fold``     (carrier, t) -> per-step key (inside the scan — cheap).
    ``streams``  (step key, n) -> n independent stream keys in ONE fused
                 derivation (one ``split`` for threefry, integer adds for
                 arx) — the engine pulls all of a step's churn/attack/repair
                 keys from a single call.
    ``uniform``  (key, shape) -> float32 uniforms in (0, 1).
    ``binom``    (key, n, p) -> float32 binomial draws, broadcast(n, p).
    """

    name: str
    base: Callable[[Any], Any]
    fold: Callable[[Any, Any], Any]
    streams: Callable[[Any, int], list]
    uniform: Callable[[Any, tuple], jnp.ndarray]
    binom: Callable[[Any, jnp.ndarray, jnp.ndarray], jnp.ndarray]


# ------------------------------------------------------------- shared pieces
def pow_int(base: jnp.ndarray, e: jnp.ndarray, bits: int = 8) -> jnp.ndarray:
    """``base ** e`` for integer-valued float ``e`` in ``[0, 2**bits)`` by
    square-and-multiply — ~3.7x cheaper than ``exp(e * log(base))`` on CPU
    and division/transcendental free.

    The exponent is read modulo ``2**bits``: callers MUST keep ``e`` below
    the cap (the engine enforces ``r_inner, replication < 256`` in
    ``make_scenario``, so every in-engine count fits ``bits=8``).  A wrong
    result here is silent — guard the domain at the boundary, not here.
    """
    e = e.astype(jnp.int32)
    acc = jnp.ones_like(base)
    for _ in range(bits):
        acc = jnp.where((e & 1) != 0, acc * base, acc)
        base = base * base
        e = e >> 1
    return acc


def fast_logit(u: jnp.ndarray) -> jnp.ndarray:
    """``log(u/(1-u)) * 0.5513`` via float32 exponent extraction + a cubic
    ``log2(1+f)`` polynomial — no transcendental calls.  Max abs error vs
    the log-based logistic probit is < 5e-3 in z units, far below the
    ~3 % CDF budget of the Gaussian branch itself."""
    def _log2(x):
        b = jax.lax.bitcast_convert_type(x, jnp.int32)
        e = ((b >> 23) & 0xFF).astype(jnp.float32) - 127.0
        f = jax.lax.bitcast_convert_type(
            (b & 0x7FFFFF) | 0x3F800000, jnp.float32) - 1.0
        poly = f * (1.44269504 + f * (-0.7213475
                                      + f * (0.4423885 - f * 0.1524863)))
        return e + poly

    return (_log2(u) - _log2(1.0 - u)) * np.float32(0.6931472 * 0.5513)


def _logit(u: jnp.ndarray) -> jnp.ndarray:
    return jnp.log(u / (1.0 - u)) * np.float32(0.5513)


def binom_from_uniform(u: jnp.ndarray, n: jnp.ndarray, p: jnp.ndarray,
                       logit=_logit) -> jnp.ndarray:
    """Regime-aware binomial draw from one uniform per lane.

    Small means (``n*p <= GAUSS_CUT``): count CDF terms below ``u`` with the
    division-free recurrence ``pmf_{j+1} = pmf_j * (n-j) * (r/(j+1))`` where
    ``r = p/(1-p)`` is the only division and ``1/(j+1)`` folds into a
    compile-time constant.  Large means: rounded Gaussian with a
    logistic-probit ``z`` (see module docstring for the error budget).

    Keep ``p`` a *scalar* (or per-batch-element scalar under ``vmap``)
    whenever the model allows: every ``p``-derived quantity then stays off
    the lane axis and XLA's CPU backend vectorizes the CDF recurrence ~2x
    better than with a per-lane ``p`` vector.  The engine is structured
    around this — i.i.d. churn, refill and init probabilities are scalars
    per element; regional bursts become a second scalar-``p`` thinning.
    """
    n = jnp.maximum(n, 0.0)
    p = jnp.clip(p, 0.0, 1.0 - 1e-7)
    q = 1.0 - p
    m = n * p
    r = p / q
    pmf = pow_int(q, n)
    cdf = pmf
    cnt = (u > cdf).astype(jnp.float32)
    for j in range(INV_CDF_TERMS - 1):
        pmf = pmf * (n - j) * (r * np.float32(1.0 / (j + 1.0)))
        cdf = cdf + pmf
        cnt = cnt + (u > cdf)
    small = jnp.minimum(cnt, n)
    s = jnp.sqrt(jnp.maximum(m * q, 1e-12))
    big = jnp.clip(jnp.round(m + s * logit(u)), 0.0, n)
    return jnp.where(m <= GAUSS_CUT, small, big)


# ----------------------------------------------------------- threefry family
def _tf_base(seed):
    return jax.random.PRNGKey(jnp.asarray(seed, jnp.uint32))


def _tf_fold(base, t):
    return jax.random.fold_in(base, t)


def _tf_streams(key, n: int):
    return list(jax.random.split(key, n))


def _tf_uniform(key, shape):
    return jax.random.uniform(key, shape, minval=np.float32(2.0 ** -24),
                              maxval=np.float32(1.0 - 2.0 ** -24))


def binom_exact(key, n, p):
    """Exact binomial sample; safe for n == 0 and p in {0, 1}."""
    return jax.random.binomial(key, jnp.maximum(n, 0.0),
                               jnp.clip(p, 0.0, 1.0))


def binom_hybrid(key, n, p):
    """Threefry uniforms + the shared inverse-CDF/Gaussian core."""
    u = _tf_uniform(key, jnp.broadcast_shapes(jnp.shape(n), jnp.shape(p)))
    return binom_from_uniform(u, n, p)


# ---------------------------------------------------------------- ARX family
def _arx_base(seed):
    """One-time threefry hash of the seed -> (k0, k1, salt) int32 carrier.

    Hashing here (outside the scan) keeps consecutive integer seeds
    decorrelated without paying threefry inside the hot loop.
    """
    kd = jax.random.key_data(_tf_base(seed))
    k = jax.lax.bitcast_convert_type(kd, jnp.int32)
    return (k[0], k[1], jnp.int32(_SALT0))


def _arx_fold(base, t):
    k0, k1, salt = base
    t = jnp.asarray(t, jnp.int32)
    return (k0 + t * _GOLD, k1 ^ (t * _MULT1), salt)


def _i32(x: int) -> np.int32:
    """Python int -> wrapped int32 (numpy would warn on overflow)."""
    x &= 0xFFFFFFFF
    return np.int32(x - (1 << 32) if x >= (1 << 31) else x)


def _arx_streams(key, n: int):
    k0, k1, salt = key
    return [(k0, k1, salt + _i32(i * int(_MULT2))) for i in range(n)]


def _arx_uniform(key, shape):
    k0, k1, salt = key
    if len(shape) == 0:
        lanes = jnp.int32(0)
    else:
        lanes = jax.lax.broadcasted_iota(jnp.int32, shape, len(shape) - 1)
    if len(shape) > 1:  # decorrelate leading axes without extra key material
        rows = jax.lax.broadcasted_iota(jnp.int32, shape, 0)
        lanes = lanes + (rows + 1) * _SALT0
    bits = arx_mix(k0, k1, lanes, salt, rounds=ARX_ROUNDS)
    return ((bits & 0x7FFFFF).astype(jnp.float32) + 0.5) * np.float32(2.0 ** -23)


def binom_arx(key, n, p):
    """ARX-counter uniforms + the shared inverse-CDF/Gaussian core (with
    the polynomial logit — the Gaussian branch costs ~the same as the
    small-mean branch)."""
    u = _arx_uniform(key, jnp.broadcast_shapes(jnp.shape(n), jnp.shape(p)))
    return binom_from_uniform(u, n, p, logit=fast_logit)


# ------------------------------------------------------------------ registry
#: Pluggable sampler bundles, keyed by the grid runners' ``sampler=`` knob:
#: ``"exact"`` (reference rejection sampling), ``"fast"`` (threefry +
#: inverse-CDF/Gaussian hybrid), ``"arx"`` (counter-based ARX uniforms,
#: highest rate). Error budgets: module docstring + tests/test_samplers.py;
#: measured throughput: docs/engine_guide.md.
SAMPLERS: dict[str, Sampler] = {
    "exact": Sampler("exact", _tf_base, _tf_fold, _tf_streams, _tf_uniform,
                     binom_exact),
    "fast": Sampler("fast", _tf_base, _tf_fold, _tf_streams, _tf_uniform,
                    binom_hybrid),
    "arx": Sampler("arx", _arx_base, _arx_fold, _arx_streams, _arx_uniform,
                   binom_arx),
}
