"""VAULT randomized peer selection (Algorithm 2).

``Distance`` measures ring distance in units of the expected node spacing
``D = 2^hashlen / N`` (paper Alg. 2 line 19). A candidate at distance ``d``
is selected for a fragment iff its VRF output satisfies

    r < 2^hashlen * exp(-(d - 1) / R)

i.e. the selection probability decays exponentially in ring distance and the
expected number of selected candidates is ``sum_d exp(-(d-1)/R) ~= R``, which
is what §4.3.2 states ("the expected number of selected nodes is approximated
R"). Note the paper's literal constant ``R * 2^(hashlen - d)`` yields an
expected ``log2(R)+2`` selections — too few to ever fill a group of R members
— so we keep the paper's structure (VRF threshold, exponential decay, public
verifiability) with the decay rate normalized by R; see DESIGN.md §7.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import numpy as np

from repro.core.vrf import HASHLEN, RING, VRFRegistry, node_id


def ring_distance(a: int, b: int) -> int:
    d = (a - b) % RING
    return min(d, RING - d)


def distance_metric(point: int, nid: int, n_nodes: int) -> float:
    """Paper's Distance(): ring distance in expected-node-spacing units."""
    spacing = RING / max(n_nodes, 1)
    return ring_distance(point, nid) / spacing + 1.0


def selection_threshold(d: float, r_target: int) -> int:
    """Hash-space threshold for selection at distance metric ``d``.

    Decay rate 2/R (not 1/R): ``Distance`` is two-sided ring distance, so
    every spacing-distance occurs twice (one candidate on each side of the
    anchor) — Σ_d 2·exp(-2(d-1)/R) ≈ R keeps the expected selected count at
    R, per §4.3.2.
    """
    p = math.exp(-2.0 * (d - 1.0) / max(r_target, 1))
    # exact for p=1; float precision ~2^-53 relative otherwise (fine: the
    # threshold itself is public and recomputed identically by verifiers).
    return RING if p >= 1.0 else int(p * RING)


@dataclasses.dataclass(frozen=True)
class SelectionProof:
    pk: bytes
    r: int
    proof: bytes
    fragment_hash: int  # VRF input point (hash of chash || fragment index)


def make_selection_proof(
    registry: VRFRegistry, sk: bytes, pk: bytes, fragment_hash: int,
    anchor: int, r_target: int, n_nodes: int,
) -> tuple[SelectionProof, bool]:
    """SelectionProof() of Alg. 2: returns (proof, selected?)."""
    alpha = fragment_hash.to_bytes(HASHLEN // 8, "big")
    r, proof = registry.prove(sk, alpha)
    d = distance_metric(anchor, node_id(pk), n_nodes)
    selected = r < selection_threshold(d, r_target)
    return SelectionProof(pk=pk, r=r, proof=proof, fragment_hash=fragment_hash), selected


def verify_selection(
    registry: VRFRegistry, sp: SelectionProof, anchor: int,
    r_target: int, n_nodes: int,
) -> bool:
    """VerifySelection() of Alg. 2 — publicly recomputable."""
    alpha = sp.fragment_hash.to_bytes(HASHLEN // 8, "big")
    if not registry.verify(sp.pk, alpha, sp.r, sp.proof):
        return False
    d = distance_metric(anchor, node_id(sp.pk), n_nodes)
    return sp.r < selection_threshold(d, r_target)


# --------------------------------------------------------------- batch paths
# node_id is a pure sha256 of pk; the batch verifier caches ring points so
# re-verified claims cost zero hashing. The scalar verify_selection above is
# deliberately left uncached — it IS the PR 3 reference path the protocol
# benchmarks use as their baseline.
_node_point = functools.lru_cache(maxsize=None)(node_id)


@functools.lru_cache(maxsize=1 << 20)
def _threshold_for(anchor: int, pk: bytes, r_target: int,
                   n_nodes: int) -> int:
    """Memoized ``selection_threshold(distance(anchor, pk))``.

    The (anchor, candidate) pairs of a deployment recur every Locate() /
    store / verification round, and the threshold arithmetic (256-bit ring
    distance, float division, exp) is the per-candidate cost that is left
    once VRF evaluation is batched. Pure function — exact same integers
    as the scalar path computes inline.
    """
    return selection_threshold(
        distance_metric(anchor, _node_point(pk), n_nodes), r_target)


def make_selection_proofs_batch(
    registry: VRFRegistry, keys: list[tuple[bytes, bytes]], fragment_hash: int,
    anchor: int, r_target: int, n_nodes: int,
) -> tuple[list[SelectionProof | None], np.ndarray]:
    """Batched SelectionProof() over candidate keypairs ``[(sk, pk), ...]``
    for ONE fragment hash (the Locate() round shape).

    Element-for-element equal to :func:`make_selection_proof`:
    ``proofs[i]`` is the same proof object and ``selected[i]`` the same
    coin the scalar call would produce for ``keys[i]`` — except that for
    unselected candidates ``proofs[i]`` is ``None`` (their proof objects
    are never used by any caller: an unselected candidate does not
    respond). The VRF work goes through ``registry.prove_batch`` — pure
    array arithmetic for the ARX registry — while the threshold side is
    exact integer math behind the :func:`_threshold_for` memo.
    """
    alpha = fragment_hash.to_bytes(HASHLEN // 8, "big")
    rs, prfs = registry.prove_batch([sk for sk, _ in keys],
                                    [alpha] * len(keys))
    proofs: list[SelectionProof | None] = []
    selected = np.empty(len(keys), bool)
    for i, (_, pk) in enumerate(keys):
        sel_i = rs[i] < _threshold_for(anchor, pk, r_target, n_nodes)
        selected[i] = sel_i
        proofs.append(SelectionProof(pk=pk, r=rs[i], proof=prfs[i],
                                     fragment_hash=fragment_hash)
                      if sel_i else None)
    return proofs, selected


def verified_responders(
    registry: VRFRegistry, candidates: list, fragment_hash: int,
    anchor: int, r_target: int, n_nodes: int,
) -> list[tuple[int, object, SelectionProof]]:
    """One batched Locate()/store selection round over node candidates.

    Proves every candidate for ``fragment_hash`` in one
    :func:`make_selection_proofs_batch` pass, verifies the selected ones
    in one :func:`verify_selection_batch` pass, and returns the verified
    responders as ``(ring_distance(anchor, node), node, proof)`` in
    candidate order — the shape both ``vault.VaultClient`` store rounds
    and ``repair._locate_new_member`` consume (``min()`` over the result
    reproduces the scalar paths' first-nearest tie-break exactly).
    Candidates need ``.kp``/``.nid`` (``network.Node``).
    """
    if not candidates:
        return []
    proofs, selected = make_selection_proofs_batch(
        registry, [(c.kp.sk, c.kp.pk) for c in candidates], fragment_hash,
        anchor, r_target, n_nodes)
    idx = [i for i in range(len(candidates)) if selected[i]]
    ok = verify_selection_batch(
        registry, [proofs[i] for i in idx], [anchor] * len(idx), r_target,
        n_nodes)
    return [(ring_distance(anchor, candidates[i].nid), candidates[i],
             proofs[i]) for i, good in zip(idx, ok) if good]


def verify_selection_batch(
    registry: VRFRegistry, sps: list[SelectionProof], anchors: list[int],
    r_target: int, n_nodes: int,
) -> np.ndarray:
    """Batched VerifySelection() — element-for-element equal to the scalar
    :func:`verify_selection` (pinned by ``tests/test_vrf_selection.py``).

    Verdicts are memoized in ``registry.selection_cache`` keyed on the full
    proof tuple (pk, input, r, proof, anchor, population), so persistence
    claims re-broadcast every heartbeat verify once ever (until ``n_nodes``
    shifts, which re-keys the distance metric). Cache misses go through
    ``registry.verify_batch`` in one call — for :class:`~repro.core.vrf.
    ArxVRFRegistry` that is a single vectorized ``prf_select_pairs``
    evaluation per tick. The distance/threshold side runs per element in
    exact Python ints (the 256-bit ring does not fit machine words); it is
    a few arithmetic ops against the VRF's hashing, and only on misses.
    """
    n = len(sps)
    out = np.zeros(n, bool)
    cache = registry.selection_cache
    keys = []
    miss = []
    for i, (sp, anchor) in enumerate(zip(sps, anchors)):
        k = (sp.pk, sp.fragment_hash, sp.r, sp.proof, anchor, r_target,
             n_nodes)
        keys.append(k)
        v = cache.get(k)
        if v is None:
            miss.append(i)
        else:
            out[i] = v
    if miss:
        vrf_ok = registry.verify_batch(
            [sps[i].pk for i in miss],
            [sps[i].fragment_hash.to_bytes(HASHLEN // 8, "big")
             for i in miss],
            [sps[i].r for i in miss],
            [sps[i].proof for i in miss])
        for j, i in enumerate(miss):
            ok = bool(vrf_ok[j])
            if ok:
                sp = sps[i]
                ok = sp.r < _threshold_for(anchors[i], sp.pk, r_target,
                                           n_nodes)
            cache[keys[i]] = ok
            out[i] = ok
    return out
