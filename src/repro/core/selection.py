"""VAULT randomized peer selection (Algorithm 2).

``Distance`` measures ring distance in units of the expected node spacing
``D = 2^hashlen / N`` (paper Alg. 2 line 19). A candidate at distance ``d``
is selected for a fragment iff its VRF output satisfies

    r < 2^hashlen * exp(-(d - 1) / R)

i.e. the selection probability decays exponentially in ring distance and the
expected number of selected candidates is ``sum_d exp(-(d-1)/R) ~= R``, which
is what §4.3.2 states ("the expected number of selected nodes is approximated
R"). Note the paper's literal constant ``R * 2^(hashlen - d)`` yields an
expected ``log2(R)+2`` selections — too few to ever fill a group of R members
— so we keep the paper's structure (VRF threshold, exponential decay, public
verifiability) with the decay rate normalized by R; see DESIGN.md §7.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import numpy as np

from repro.core.vrf import (ARX_SHIFT, HASHLEN, RING, ArxVRFRegistry,
                            VRFRegistry, node_id)


def ring_distance(a: int, b: int) -> int:
    d = (a - b) % RING
    return min(d, RING - d)


def distance_metric(point: int, nid: int, n_nodes: int) -> float:
    """Paper's Distance(): ring distance in expected-node-spacing units."""
    spacing = RING / max(n_nodes, 1)
    return ring_distance(point, nid) / spacing + 1.0


def selection_threshold(d: float, r_target: int) -> int:
    """Hash-space threshold for selection at distance metric ``d``.

    Decay rate 2/R (not 1/R): ``Distance`` is two-sided ring distance, so
    every spacing-distance occurs twice (one candidate on each side of the
    anchor) — Σ_d 2·exp(-2(d-1)/R) ≈ R keeps the expected selected count at
    R, per §4.3.2.
    """
    p = math.exp(-2.0 * (d - 1.0) / max(r_target, 1))
    # exact for p=1; float precision ~2^-53 relative otherwise (fine: the
    # threshold itself is public and recomputed identically by verifiers).
    return RING if p >= 1.0 else int(p * RING)


@dataclasses.dataclass(frozen=True)
class SelectionProof:
    pk: bytes
    r: int
    proof: bytes
    fragment_hash: int  # VRF input point (hash of chash || fragment index)


def make_selection_proof(
    registry: VRFRegistry, sk: bytes, pk: bytes, fragment_hash: int,
    anchor: int, r_target: int, n_nodes: int,
) -> tuple[SelectionProof, bool]:
    """SelectionProof() of Alg. 2: returns (proof, selected?)."""
    alpha = fragment_hash.to_bytes(HASHLEN // 8, "big")
    r, proof = registry.prove(sk, alpha)
    d = distance_metric(anchor, node_id(pk), n_nodes)
    selected = r < selection_threshold(d, r_target)
    return SelectionProof(pk=pk, r=r, proof=proof, fragment_hash=fragment_hash), selected


def verify_selection(
    registry: VRFRegistry, sp: SelectionProof, anchor: int,
    r_target: int, n_nodes: int,
) -> bool:
    """VerifySelection() of Alg. 2 — publicly recomputable."""
    alpha = sp.fragment_hash.to_bytes(HASHLEN // 8, "big")
    if not registry.verify(sp.pk, alpha, sp.r, sp.proof):
        return False
    d = distance_metric(anchor, node_id(sp.pk), n_nodes)
    return sp.r < selection_threshold(d, r_target)


# --------------------------------------------------------------- batch paths
# node_id is a pure sha256 of pk; the batch verifier caches ring points so
# re-verified claims cost zero hashing. The scalar verify_selection above is
# deliberately left uncached — it IS the PR 3 reference path the protocol
# benchmarks use as their baseline. Bounded (LRU) so a churn-heavy month
# does not accumulate one entry per keypair ever generated.
_node_point = functools.lru_cache(maxsize=1 << 20)(node_id)


@functools.lru_cache(maxsize=1 << 20)
def _threshold_for(anchor: int, pk: bytes, r_target: int,
                   n_nodes: int) -> int:
    """Memoized ``selection_threshold(distance(anchor, pk))``.

    The (anchor, candidate) pairs of a deployment recur every Locate() /
    store / verification round, and the threshold arithmetic (256-bit ring
    distance, float division, exp) is the per-candidate cost that is left
    once VRF evaluation is batched. Pure function — exact same integers
    as the scalar path computes inline.
    """
    return selection_threshold(
        distance_metric(anchor, _node_point(pk), n_nodes), r_target)


def make_selection_proofs_batch(
    registry: VRFRegistry, keys: list[tuple[bytes, bytes]], fragment_hash: int,
    anchor: int, r_target: int, n_nodes: int,
) -> tuple[list[SelectionProof | None], np.ndarray]:
    """Batched SelectionProof() over candidate keypairs ``[(sk, pk), ...]``
    for ONE fragment hash (the Locate() round shape).

    Element-for-element equal to :func:`make_selection_proof`:
    ``proofs[i]`` is the same proof object and ``selected[i]`` the same
    coin the scalar call would produce for ``keys[i]`` — except that for
    unselected candidates ``proofs[i]`` is ``None`` (their proof objects
    are never used by any caller: an unselected candidate does not
    respond). The VRF work goes through ``registry.prove_batch`` — pure
    array arithmetic for the ARX registry — while the threshold side is
    exact integer math behind the :func:`_threshold_for` memo.
    """
    alpha = fragment_hash.to_bytes(HASHLEN // 8, "big")
    rs, prfs = registry.prove_batch([sk for sk, _ in keys],
                                    [alpha] * len(keys))
    proofs: list[SelectionProof | None] = []
    selected = np.empty(len(keys), bool)
    for i, (_, pk) in enumerate(keys):
        sel_i = rs[i] < _threshold_for(anchor, pk, r_target, n_nodes)
        selected[i] = sel_i
        proofs.append(SelectionProof(pk=pk, r=rs[i], proof=prfs[i],
                                     fragment_hash=fragment_hash)
                      if sel_i else None)
    return proofs, selected


def verified_responders(
    registry: VRFRegistry, candidates: list, fragment_hash: int,
    anchor: int, r_target: int, n_nodes: int,
) -> list[tuple[int, object, SelectionProof]]:
    """One batched Locate()/store selection round over node candidates.

    Proves every candidate for ``fragment_hash`` in one
    :func:`make_selection_proofs_batch` pass, verifies the selected ones
    in one :func:`verify_selection_batch` pass, and returns the verified
    responders as ``(ring_distance(anchor, node), node, proof)`` in
    candidate order — the shape both ``vault.VaultClient`` store rounds
    and ``repair._locate_new_member`` consume (``min()`` over the result
    reproduces the scalar paths' first-nearest tie-break exactly).
    Candidates need ``.kp``/``.nid`` (``network.Node``).
    """
    if not candidates:
        return []
    proofs, selected = make_selection_proofs_batch(
        registry, [(c.kp.sk, c.kp.pk) for c in candidates], fragment_hash,
        anchor, r_target, n_nodes)
    idx = [i for i in range(len(candidates)) if selected[i]]
    ok = verify_selection_batch(
        registry, [proofs[i] for i in idx], [anchor] * len(idx), r_target,
        n_nodes)
    return [(ring_distance(anchor, candidates[i].nid), candidates[i],
             proofs[i]) for i, good in zip(idx, ok) if good]


class LocateRound:
    """Resident selection state for one (anchor, candidate set, r_target,
    population) cell, reused across every Locate() slot of a tick.

    :func:`verified_responders` re-derives per-candidate constants — ring
    distance, selection threshold, VRF tag lanes — on every call, then
    verifies proofs the candidates just made themselves. Both costs are
    per-slot invariant: a store round runs up to ``6R`` slots against the
    same candidate list, and a 10K-node repair tick runs ~1K slots against
    per-anchor lists that only change at churn/partition edges
    (``SimNetwork.locate_round`` caches instances on exactly that state).
    This class hoists the invariants into arrays built once:

    * ``dists``/``thresholds`` — exact integer ring distances and Alg. 2
      thresholds per candidate (the ``_threshold_for`` memo feeds them).
    * ARX registries additionally get a ``(P, 2)`` uint32 tag-lane array;
      a slot is then ONE vectorized PRF evaluation plus a uint64 threshold
      compare — no per-candidate Python until the ~R selected survivors.
      The compare is exact: ``r == r32 << 224 < t  iff  r32 < ceil(t /
      2^224)``, and the ceiling fits uint64.
    * Verification is elided, not approximated: every candidate is an
      alive registered node proving over its *own* key, so
      ``verify_selection`` recomputes byte-identical values and returns
      the selection coin — the scalar path's verify can only ever confirm
      (``test_locate_round.py`` pins the responder lists either way). The
      memoized verdict is still written, so later re-verifications of
      stored proofs (claims) hit the cache exactly as before.

    ``responders(fhash, exclude)`` returns the same ``(ring_distance,
    node, proof)`` list, in the same candidate order, as
    ``verified_responders(registry, [c for c in candidates if c.nid not
    in exclude and c.alive], ...)``.
    """

    def __init__(self, registry: VRFRegistry, candidates: list, anchor: int,
                 r_target: int, n_nodes: int,
                 prev: "LocateRound | None" = None):
        self.registry = registry
        self.candidates = list(candidates)
        self.anchor = anchor
        self.r_target = r_target
        self.n_nodes = n_nodes
        cands = self.candidates
        n = len(cands)
        arx = isinstance(registry, ArxVRFRegistry)
        # The per-candidate constants are pure functions of (anchor, nid/pk,
        # r_target, n_nodes). Under steady churn the same anchor recurs
        # every tick with a near-identical candidate window, so instead of
        # re-deriving 256-bit ring distances and thresholds per candidate,
        # copy the rows of the invalidated previous round (matched by nid)
        # and compute only the handful of newcomers. Exact reuse — the
        # values are deterministic in the matched key.
        self._nid_idx: dict | None = None
        # MembershipTimer lanes (see ``timer_admit``): None until a timer
        # pass runs; carried across generations through the same nid-match
        # below, so judged candidates keep their admit verdicts for as
        # long as the donor chain lives.
        self._timer_known: np.ndarray | None = None
        self._timer_admit: np.ndarray | None = None
        self._timer_chash: bytes | None = None
        if (prev is not None and prev.anchor == anchor
                and prev.r_target == r_target and prev.n_nodes == n_nodes
                and prev.registry is registry):
            pidx = prev._nid_idx
            if pidx is None:
                pidx = {c.nid: i for i, c in enumerate(prev.candidates)}
            if arx and prev._words is not None:
                # arx fast lane: responders()/nearest() on this backend
                # read only ``dists`` + ``_words``/``_thr_hi`` — skip the
                # python thresholds list and the secret-key gather, both
                # dead weight here (donor chains stay on this lane too)
                dists = [0] * n
                nid_idx = {}
                src = np.full(n, -1, np.int64)
                miss = []
                for i, c in enumerate(cands):
                    nid = c.nid
                    nid_idx[nid] = i
                    j = pidx.get(nid, -1)
                    if j >= 0:
                        src[i] = j
                        dists[i] = prev.dists[j]
                    else:
                        miss.append(i)
                self._nid_idx = nid_idx
                hit = src >= 0
                words = np.empty((n, 2), np.uint32)
                thr_hi = np.empty(n, np.uint64)
                words[hit] = prev._words[src[hit]]
                thr_hi[hit] = prev._thr_hi[src[hit]]
                if miss:
                    words[miss] = registry.sk_lanes(
                        [cands[i].kp.sk for i in miss])
                    for i in miss:
                        c = cands[i]
                        dists[i] = ring_distance(anchor, c.nid)
                        t = _threshold_for(anchor, c.kp.pk, r_target,
                                           n_nodes)
                        thr_hi[i] = (t + (1 << ARX_SHIFT) - 1) >> ARX_SHIFT
                self.dists = dists
                self.thresholds = None
                self._sks = None
                self._words = words
                self._thr_hi = thr_hi
                self._carry_timer(prev, src, hit)
                return
            dists: list = [0] * n
            thresholds: list = [0] * n
            nid_idx: dict = {}
            src = np.full(n, -1, np.int64)
            miss: list[int] = []
            for i, c in enumerate(cands):
                nid = c.nid
                nid_idx[nid] = i
                j = pidx.get(nid, -1)
                if j >= 0:
                    src[i] = j
                    dists[i] = prev.dists[j]
                    thresholds[i] = prev.thresholds[j]
                else:
                    miss.append(i)
            self._nid_idx = nid_idx
            for i in miss:
                c = cands[i]
                dists[i] = ring_distance(anchor, c.nid)
                thresholds[i] = _threshold_for(anchor, c.kp.pk, r_target,
                                               n_nodes)
            self.dists = dists
            self.thresholds = thresholds
            self._sks = [c.kp.sk for c in cands]
            self._carry_timer(prev, src, src >= 0)
            if arx and prev._words is not None:
                hit = src >= 0
                words = np.empty((n, 2), np.uint32)
                thr_hi = np.empty(n, np.uint64)
                words[hit] = prev._words[src[hit]]
                thr_hi[hit] = prev._thr_hi[src[hit]]
                if miss:
                    words[miss] = registry.sk_lanes(
                        [self._sks[i] for i in miss])
                    for i in miss:
                        t = thresholds[i]
                        thr_hi[i] = (t + (1 << ARX_SHIFT) - 1) >> ARX_SHIFT
                self._words = words
                self._thr_hi = thr_hi
                return
            if not arx:
                self._words = None
                return
        self.dists = [ring_distance(anchor, c.nid) for c in cands]
        self.thresholds = [_threshold_for(anchor, c.kp.pk, r_target, n_nodes)
                           for c in cands]
        self._sks = [c.kp.sk for c in cands]
        if arx:
            self._words = registry.sk_lanes(self._sks)
            self._thr_hi = np.fromiter(
                ((t + (1 << ARX_SHIFT) - 1) >> ARX_SHIFT
                 for t in self.thresholds), np.uint64, len(self.thresholds))
        else:
            self._words = None

    def _carry_timer(self, prev: "LocateRound", src: np.ndarray,
                     hit: np.ndarray) -> None:
        """Copy the donor's MembershipTimer verdicts for nid-matched rows.

        Verdicts are pure in (stored proofs, anchor, r_target, n_nodes) —
        all matched by the donor condition, and proofs only change through
        repairs, which evict via :meth:`evict_timer` — so the copy is
        exact. Unmatched rows stay unjudged and get verified on the next
        ``timer_admit`` pass (deterministic, so re-judging a candidate
        that dropped out of the window and returned is also exact)."""
        if prev._timer_known is None:
            return
        n = len(self.candidates)
        tk = np.zeros(n, bool)
        ta = np.zeros(n, bool)
        tk[hit] = prev._timer_known[src[hit]]
        ta[hit] = prev._timer_admit[src[hit]]
        self._timer_known = tk
        self._timer_admit = ta
        self._timer_chash = prev._timer_chash

    def timer_admit(self, chash: bytes) -> list[int]:
        """MembershipTimer admit set for ``chash``, in candidate order.

        Array-resident replacement for the per-candidate timer walk:
        judged candidates are a boolean lane pair (``known``/``admit``)
        carried across ticks by the donor machinery, so a steady-state
        pass verifies nothing and costs one ``nonzero``. Unjudged
        candidates (window newcomers, or rows invalidated by
        :meth:`evict_timer` after a repair) get their stored claim proofs
        verified in one ``verify_selection_batch`` call; a candidate with
        no view for ``chash`` is judged not-admitted, exactly like the
        per-candidate walk it replaces."""
        n = len(self.candidates)
        if self._timer_known is None or self._timer_chash != chash:
            self._timer_known = np.zeros(n, bool)
            self._timer_admit = np.zeros(n, bool)
            self._timer_chash = chash
        known = self._timer_known
        admit = self._timer_admit
        fresh = np.nonzero(~known)[0]
        if fresh.size:
            proofs: list = []
            owners: list[int] = []
            for i in fresh:
                c = self.candidates[int(i)]
                if c.groups.get(chash) is None:
                    continue
                for proof in c.claim_proofs_by_chash.get(chash, {}).values():
                    proofs.append(proof)
                    owners.append(int(i))
            if proofs:
                ok = verify_selection_batch(
                    self.registry, proofs, [self.anchor] * len(proofs),
                    self.r_target, self.n_nodes)
                np.logical_or.at(admit, owners, ok)
            known[fresh] = True
        return [self.candidates[int(i)].nid for i in np.nonzero(admit)[0]]

    def evict_timer(self, nids) -> None:
        """Invalidate the timer verdicts of ``nids`` (membership changed:
        a repair stored fresh proofs, so they must be re-judged)."""
        if self._timer_known is None:
            return
        idx = self._nid_idx
        if idx is None:
            idx = self._nid_idx = {c.nid: i
                                   for i, c in enumerate(self.candidates)}
        for nid in nids:
            i = idx.get(nid)
            if i is not None:
                self._timer_known[i] = False
                self._timer_admit[i] = False

    def compact(self, alive_set: set) -> None:
        """Reaper sweep: drop candidate rows of reaped nids.

        Donor reuse is nid-matched, so removing rows never changes what a
        successor round copies — it only unpins the dead ``Node`` objects
        (fragments included) this round would otherwise keep alive
        forever in the cumulative donor map."""
        cands = self.candidates
        keep = [i for i, c in enumerate(cands) if c.nid in alive_set]
        if len(keep) == len(cands):
            return
        self.candidates = [cands[i] for i in keep]
        self.dists = [self.dists[i] for i in keep]
        if self.thresholds is not None:
            self.thresholds = [self.thresholds[i] for i in keep]
        if self._sks is not None:
            self._sks = [self._sks[i] for i in keep]
        sel_rows = np.asarray(keep, np.int64)
        if self._words is not None:
            self._words = self._words[sel_rows]
            self._thr_hi = self._thr_hi[sel_rows]
        if self._timer_known is not None:
            self._timer_known = self._timer_known[sel_rows]
            self._timer_admit = self._timer_admit[sel_rows]
        self._nid_idx = None

    def responders(self, fragment_hash: int, exclude=()) -> list:
        """One Locate() slot: ``[(ring_distance, node, proof), ...]`` over
        the resident candidates, excluding ``exclude`` nids — identical to
        the :func:`verified_responders` result for the filtered list."""
        cands = self.candidates
        cache = self.registry.selection_cache
        out = []
        if self._words is not None:
            alpha = fragment_hash.to_bytes(HASHLEN // 8, "big")
            r32 = self.registry.eval_value_lanes(self._words, alpha)
            hits = np.nonzero(r32.astype(np.uint64) < self._thr_hi)[0]
            keep = [int(i) for i in hits
                    if cands[int(i)].alive and cands[int(i)].nid not in
                    exclude]
            if not keep:
                return out
            # proof lanes only for the admitted few (~R of the P rows)
            p32 = self.registry.eval_proof_lanes(self._words[keep], alpha)
            for j, i in enumerate(keep):
                c = cands[i]
                sp = SelectionProof(
                    pk=c.kp.pk, r=int(r32[i]) << ARX_SHIFT,
                    proof=int(p32[j]).to_bytes(4, "little"),
                    fragment_hash=fragment_hash)
                self._admit(cache, sp)
                out.append((self.dists[i], c, sp))
            return out
        alpha = fragment_hash.to_bytes(HASHLEN // 8, "big")
        rs, prfs = self.registry.prove_batch(self._sks,
                                             [alpha] * len(self._sks))
        for i, c in enumerate(cands):
            if rs[i] >= self.thresholds[i]:
                continue
            if c.nid in exclude or not c.alive:
                continue
            sp = SelectionProof(pk=c.kp.pk, r=rs[i], proof=prfs[i],
                                fragment_hash=fragment_hash)
            self._admit(cache, sp)
            out.append((self.dists[i], c, sp))
        return out

    def nearest(self, fragment_hash: int, exclude=()):
        """The default Locate() pick — ``min(responders(...), key=dist)``
        with the same first-minimum tie-break — returning ``(node,
        proof)`` or None, but materializing only the winner's proof
        object (the only one any default-pick caller ever uses)."""
        cands = self.candidates
        best_i = -1
        best_d = None
        if self._words is not None:
            alpha = fragment_hash.to_bytes(HASHLEN // 8, "big")
            r32 = self.registry.eval_value_lanes(self._words, alpha)
            for i in np.nonzero(r32.astype(np.uint64) < self._thr_hi)[0]:
                i = int(i)
                c = cands[i]
                if c.nid in exclude or not c.alive:
                    continue
                d = self.dists[i]
                if best_d is None or d < best_d:
                    best_d, best_i = d, i
            if best_i < 0:
                return None
            # proof lane for the single winner only
            p32w = self.registry.eval_proof_lanes(
                self._words[best_i:best_i + 1], alpha)
            sp = SelectionProof(
                pk=cands[best_i].kp.pk, r=int(r32[best_i]) << ARX_SHIFT,
                proof=int(p32w[0]).to_bytes(4, "little"),
                fragment_hash=fragment_hash)
        else:
            alpha = fragment_hash.to_bytes(HASHLEN // 8, "big")
            rs, prfs = self.registry.prove_batch(self._sks,
                                                 [alpha] * len(self._sks))
            for i, c in enumerate(cands):
                if rs[i] >= self.thresholds[i]:
                    continue
                if c.nid in exclude or not c.alive:
                    continue
                d = self.dists[i]
                if best_d is None or d < best_d:
                    best_d, best_i = d, i
            if best_i < 0:
                return None
            sp = SelectionProof(pk=cands[best_i].kp.pk, r=rs[best_i],
                                proof=prfs[best_i],
                                fragment_hash=fragment_hash)
        self._admit(self.registry.selection_cache, sp)
        return cands[best_i], sp

    def _admit(self, cache: dict, sp: SelectionProof) -> None:
        """Write the (provably True) verification verdict the scalar path
        would have memoized for this responder's proof."""
        sub = cache.get(sp.pk)
        if sub is None:
            sub = cache[sp.pk] = {}
        sub[(sp.fragment_hash, sp.r, sp.proof, self.anchor, self.r_target,
             self.n_nodes)] = True


def verify_selection_batch(
    registry: VRFRegistry, sps: list[SelectionProof], anchors: list[int],
    r_target: int, n_nodes: int,
) -> np.ndarray:
    """Batched VerifySelection() — element-for-element equal to the scalar
    :func:`verify_selection` (pinned by ``tests/test_vrf_selection.py``).

    Verdicts are memoized in ``registry.selection_cache``, two-level —
    ``pk -> {(input, r, proof, anchor, r_target, population): verdict}`` —
    so persistence claims re-broadcast every heartbeat verify once ever
    (until ``n_nodes`` shifts, which re-keys the distance metric), and the
    dead-node reaper evicts a failed node's history in O(1) (``VRFRegistry.
    evict``). Cache misses go through
    ``registry.verify_batch`` in one call — for :class:`~repro.core.vrf.
    ArxVRFRegistry` that is a single vectorized ``prf_select_pairs``
    evaluation per tick. The distance/threshold side runs per element in
    exact Python ints (the 256-bit ring does not fit machine words); it is
    a few arithmetic ops against the VRF's hashing, and only on misses.
    """
    n = len(sps)
    out = np.zeros(n, bool)
    cache = registry.selection_cache
    keys = []
    subcaches = []
    miss = []
    for i, (sp, anchor) in enumerate(zip(sps, anchors)):
        k = (sp.fragment_hash, sp.r, sp.proof, anchor, r_target, n_nodes)
        keys.append(k)
        sub = cache.get(sp.pk)
        if sub is None:
            sub = cache[sp.pk] = {}
        subcaches.append(sub)
        v = sub.get(k)
        if v is None:
            miss.append(i)
        else:
            out[i] = v
    if miss:
        vrf_ok = registry.verify_batch(
            [sps[i].pk for i in miss],
            [sps[i].fragment_hash.to_bytes(HASHLEN // 8, "big")
             for i in miss],
            [sps[i].r for i in miss],
            [sps[i].proof for i in miss])
        for j, i in enumerate(miss):
            ok = bool(vrf_ok[j])
            if ok:
                sp = sps[i]
                ok = sp.r < _threshold_for(anchors[i], sp.pk, r_target,
                                           n_nodes)
            subcaches[i][keys[i]] = ok
            out[i] = ok
    return out
