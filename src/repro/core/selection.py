"""VAULT randomized peer selection (Algorithm 2).

``Distance`` measures ring distance in units of the expected node spacing
``D = 2^hashlen / N`` (paper Alg. 2 line 19). A candidate at distance ``d``
is selected for a fragment iff its VRF output satisfies

    r < 2^hashlen * exp(-(d - 1) / R)

i.e. the selection probability decays exponentially in ring distance and the
expected number of selected candidates is ``sum_d exp(-(d-1)/R) ~= R``, which
is what §4.3.2 states ("the expected number of selected nodes is approximated
R"). Note the paper's literal constant ``R * 2^(hashlen - d)`` yields an
expected ``log2(R)+2`` selections — too few to ever fill a group of R members
— so we keep the paper's structure (VRF threshold, exponential decay, public
verifiability) with the decay rate normalized by R; see DESIGN.md §7.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.vrf import HASHLEN, RING, VRFRegistry, node_id


def ring_distance(a: int, b: int) -> int:
    d = (a - b) % RING
    return min(d, RING - d)


def distance_metric(point: int, nid: int, n_nodes: int) -> float:
    """Paper's Distance(): ring distance in expected-node-spacing units."""
    spacing = RING / max(n_nodes, 1)
    return ring_distance(point, nid) / spacing + 1.0


def selection_threshold(d: float, r_target: int) -> int:
    """Hash-space threshold for selection at distance metric ``d``.

    Decay rate 2/R (not 1/R): ``Distance`` is two-sided ring distance, so
    every spacing-distance occurs twice (one candidate on each side of the
    anchor) — Σ_d 2·exp(-2(d-1)/R) ≈ R keeps the expected selected count at
    R, per §4.3.2.
    """
    p = math.exp(-2.0 * (d - 1.0) / max(r_target, 1))
    # exact for p=1; float precision ~2^-53 relative otherwise (fine: the
    # threshold itself is public and recomputed identically by verifiers).
    return RING if p >= 1.0 else int(p * RING)


@dataclasses.dataclass(frozen=True)
class SelectionProof:
    pk: bytes
    r: int
    proof: bytes
    fragment_hash: int  # VRF input point (hash of chash || fragment index)


def make_selection_proof(
    registry: VRFRegistry, sk: bytes, pk: bytes, fragment_hash: int,
    anchor: int, r_target: int, n_nodes: int,
) -> tuple[SelectionProof, bool]:
    """SelectionProof() of Alg. 2: returns (proof, selected?)."""
    alpha = fragment_hash.to_bytes(HASHLEN // 8, "big")
    r, proof = registry.prove(sk, alpha)
    d = distance_metric(anchor, node_id(pk), n_nodes)
    selected = r < selection_threshold(d, r_target)
    return SelectionProof(pk=pk, r=r, proof=proof, fragment_hash=fragment_hash), selected


def verify_selection(
    registry: VRFRegistry, sp: SelectionProof, anchor: int,
    r_target: int, n_nodes: int,
) -> bool:
    """VerifySelection() of Alg. 2 — publicly recomputable."""
    alpha = sp.fragment_hash.to_bytes(HASHLEN // 8, "big")
    if not registry.verify(sp.pk, alpha, sp.r, sp.proof):
        return False
    d = distance_metric(anchor, node_id(sp.pk), n_nodes)
    return sp.r < selection_threshold(d, r_target)
