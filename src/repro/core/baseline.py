"""Comparison systems the paper evaluates against.

* ``ReplicatedStore`` — the "Ceph-like" simulation baseline (§6.1): each
  object replicated on 3 randomly selected peers, repair immediately after a
  replica fails (one object of traffic per repair). Used by the Fig. 4/6
  benchmarks.
* ``IPFSLikeStore`` — the physical-deployment baseline (§6.2): the object is
  split into ``K_inner * K_outer`` records; each record is PUT on the
  ``replication``-closest peers on the DHT ring (Kademlia PUT_RECORD
  semantics). Used by the Fig. 7–9 latency/scalability benchmarks.

Both run on the same ``SimNetwork`` (same latency model, same failure
injection) so comparisons isolate the protocol difference.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.core.network import Node, SimNetwork
from repro.core.vault import OpStats


@dataclasses.dataclass(frozen=True)
class ReplicaID:
    ohash: bytes
    length: int


class ReplicatedStore:
    """Ceph-like: r=3 replication on random peers, eager repair."""

    def __init__(self, net: SimNetwork, replication: int = 3):
        self.net = net
        self.replication = replication
        # ohash -> list of holder nids (alive or not; repair prunes)
        self.placement: dict[bytes, list[int]] = {}
        self.objects: dict[bytes, int] = {}  # ohash -> length

    def store(self, client: Node, data: bytes) -> tuple[ReplicaID, OpStats]:
        ohash = hashlib.sha256(b"repl" + data).digest()
        alive = self.net.alive_nodes()
        idx = self.net.rng.choice(len(alive), size=self.replication,
                                  replace=False)
        holders = [alive[int(i)] for i in idx]
        for h in holders:
            if not h.byzantine:
                h.fragments[(ohash, 0)] = data
        self.placement[ohash] = [h.nid for h in holders]
        self.objects[ohash] = len(data)
        # replicas pushed in parallel; latency = slowest push
        lat = float(np.max(self.net.rtts(client, holders)))
        return ReplicaID(ohash, len(data)), OpStats(
            latency_s=lat, coding_s=0.0,
            bytes_sent=len(data) * self.replication,
        )

    def query(self, client: Node, rid: ReplicaID) -> tuple[bytes, OpStats]:
        holders = [
            self.net.nodes[nid] for nid in self.placement.get(rid.ohash, [])
            if nid in self.net.nodes and self.net.nodes[nid].alive
        ]
        for h in sorted(holders, key=lambda h: self.net.rtt(client, h)):
            data = h.fragments.get((rid.ohash, 0))
            if data is not None:
                # query goes to the *closest* replica (one RTT)
                return data, OpStats(
                    latency_s=self.net.rtt(client, h), coding_s=0.0,
                    bytes_sent=0,
                )
        raise KeyError("all replicas lost")

    def repair_tick(self) -> int:
        """Eager repair: replace dead holders immediately. Returns bytes."""
        traffic = 0
        for ohash, nids in self.placement.items():
            alive = [n for n in nids
                     if n in self.net.nodes and self.net.nodes[n].alive]
            dead = len(nids) - len(alive)
            if dead == 0:
                continue
            srcs = [
                self.net.nodes[n] for n in alive
                if (ohash, 0) in self.net.nodes[n].fragments
            ]
            if not srcs:
                self.placement[ohash] = alive
                continue  # object permanently lost
            data = srcs[0].fragments[(ohash, 0)]
            pool = [n for n in self.net.alive_nodes() if n.nid not in alive]
            self.net.rng.shuffle(pool)
            for new in pool[:dead]:
                if not new.byzantine:
                    new.fragments[(ohash, 0)] = data
                alive.append(new.nid)
                traffic += len(data)
            self.placement[ohash] = alive
        self.net.repair_traffic_bytes += traffic
        return traffic

    def lost_objects(self) -> int:
        lost = 0
        for ohash, nids in self.placement.items():
            ok = any(
                n in self.net.nodes
                and self.net.nodes[n].alive
                and (ohash, 0) in self.net.nodes[n].fragments
                for n in nids
            )
            lost += 0 if ok else 1
        return lost


@dataclasses.dataclass(frozen=True)
class IPFSObjectID:
    ohash: bytes
    length: int
    record_hashes: tuple[bytes, ...]


class IPFSLikeStore:
    """IPFS-like: object split into records, each PUT to the ring-closest
    peers (replication factor 3 → redundancy comparable to VAULT's 3.125)."""

    def __init__(self, net: SimNetwork, replication: int = 3,
                 records_per_object: int = 256):
        self.net = net
        self.replication = replication
        self.records_per_object = records_per_object

    def _record_hash(self, ohash: bytes, i: int) -> bytes:
        return hashlib.sha256(ohash + i.to_bytes(4, "big")).digest()

    def store(self, client: Node, data: bytes) -> tuple[IPFSObjectID, OpStats]:
        ohash = hashlib.sha256(b"ipfs" + data).digest()
        n_rec = self.records_per_object
        rec_len = -(-len(data) // n_rec)
        rhashes = []
        worst = 0.0
        sent = 0
        for i in range(n_rec):
            rec = data[i * rec_len : (i + 1) * rec_len]
            rh = self._record_hash(ohash, i)
            rhashes.append(rh)
            point = int.from_bytes(rh, "big")
            holders = self.net.candidates(point, self.replication)
            for h in holders:
                if not h.byzantine:
                    h.fragments[(rh, 0)] = rec
                sent += len(rec)
            if holders:
                # records PUT in parallel; each PUT completes at its slowest
                # replica (DHT PUT_RECORD waits for the replication set)
                worst = max(worst, float(np.max(self.net.rtts(client, holders))))
        return IPFSObjectID(ohash, len(data), tuple(rhashes)), OpStats(
            latency_s=worst, coding_s=0.0, bytes_sent=sent,
        )

    def query(self, client: Node, oid: IPFSObjectID) -> tuple[bytes, OpStats]:
        parts = []
        worst = 0.0
        for rh in oid.record_hashes:
            point = int.from_bytes(rh, "big")
            holders = [
                h for h in self.net.candidates(point, self.replication * 2)
                if (rh, 0) in h.fragments
            ]
            if not holders:
                raise KeyError("record lost")
            # fastest replica wins for each record; records in parallel
            worst = max(worst, float(np.min(self.net.rtts(client, holders))))
            parts.append(holders[0].fragments[(rh, 0)])
        data = b"".join(parts)[: oid.length]
        return data, OpStats(latency_s=worst, coding_s=0.0, bytes_sent=0)
