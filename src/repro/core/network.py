"""Simulated decentralized peer network for VAULT.

Replaces the paper's actix-web HTTP transport with in-process peer objects
and a latency-accounting model (per-link RTTs sampled from a 5-region geo
matrix matching the paper's EC2 zones). Protocol logic — selection proofs,
fragment stores, persistence claims, membership, repair — is executed for
real; only the wire is simulated. DHT lookup is modeled as best-effort
nearest-on-ring (the paper itself evaluates with "a simulated DHT routing
system that provides node discovery in constant time", §6.2).
"""
from __future__ import annotations

import bisect
import dataclasses

import numpy as np

from repro.core import rateless
from repro.core import selection as sel
from repro.core.chunks import corrupt_payload as C_corrupt
from repro.core.chunks import payload_tag as C_payload_tag
from repro.core.vrf import RING, KeyPair, make_registry, node_id

# --- geo latency model (one-way ms between the paper's 5 AWS regions) -----
REGIONS = ("us-west", "ap-southeast", "eu-central", "sa-east", "af-south")
_RTT_MS = np.array(  # symmetric round-trip times, ms
    [
        [2, 170, 150, 180, 290],
        [170, 2, 160, 330, 260],
        [150, 160, 2, 210, 155],
        [180, 330, 210, 2, 340],
        [290, 260, 155, 340, 2],
    ],
    dtype=np.float64,
)


@dataclasses.dataclass
class LatencyModel:
    jitter: float = 0.15  # lognormal-ish multiplicative jitter
    per_request_ms: float = 1.5  # serialization + handler overhead

    def rtt_ms(self, rng: np.random.Generator, ra: int, rb: int) -> float:
        base = _RTT_MS[ra, rb]
        return (base + self.per_request_ms) * float(
            rng.lognormal(mean=0.0, sigma=self.jitter)
        )


@dataclasses.dataclass
class GroupMeta:
    chash: bytes
    k_inner: int
    r_target: int
    frag_len: int


@dataclasses.dataclass
class GroupView:
    """A node's local view of one chunk group (§4.3.3)."""

    meta: GroupMeta
    members: dict[int, float] = dataclasses.field(default_factory=dict)
    # node id -> last-seen time (persistence claims)
    chunk_cache: bytes | None = None
    cache_expiry: float = -1.0


class Node:
    """One VAULT peer. Byzantine nodes follow the protocol but store nothing
    (the paper's Fig. 6 adversary) — they answer claims, accept stores, and
    return nothing on fragment reads.

    ``colluding`` Byzantine nodes (the BFT-DSN withholding adversary,
    ``policies.ADV_COLLUDE``) go further: they *do* store fragments and
    answer Locate()/claims indistinguishably from honest members — they
    pass every audit — but serve deterministically corrupt payloads at
    pull time (``chunks.corrupt_payload``). Pullers verify rows against
    the creator-recorded tags (``SimNetwork.frag_tags``) and discard
    them after paying the transfer. Set by the protocol loop at spawn;
    ``session_end`` likewise (Pareto session churn, ``CHURN_PARETO``)."""

    def __init__(
        self, net: "SimNetwork", kp: KeyPair, region: int, byzantine: bool
    ) -> None:
        self.net = net
        self.kp = kp
        self.nid = node_id(kp.pk)
        self.region = region
        self.byzantine = byzantine
        self.colluding = False
        self.session_end = float("inf")  # hours; finite only under pareto
        self.alive = True
        self.row = -1  # dense index into the network's alive table
        self.fragments: dict[tuple[bytes, int], bytes] = {}
        # per-chunk mirror of ``fragments`` (same payloads, same relative
        # insertion order) so serve_fragments is one lookup instead of a
        # scan over every fragment the node holds; maintained by
        # store_fragment (fragments are never individually deleted — a
        # node's whole state dies with it in the reaper)
        self.fragments_by_chash: dict[bytes, dict[int, bytes]] = {}
        self.groups: dict[bytes, GroupView] = {}
        # selection proofs stored alongside fragments (§4.3.3: avoids
        # regenerating VRF proofs every heartbeat interval), plus a
        # per-chunk index so claim construction / MembershipTimer checks
        # read only the group's own proofs instead of scanning every
        # fragment the node holds
        self.claim_proofs: dict[tuple[bytes, int], object] = {}
        self.claim_proofs_by_chash: dict[bytes, dict[int, object]] = {}

    # -- selection (Alg. 2) -------------------------------------------------
    def selection_proof(self, fragment_hash: int, anchor: int, r_target: int):
        return sel.make_selection_proof(
            self.net.registry, self.kp.sk, self.kp.pk, fragment_hash,
            anchor, r_target, self.net.n_nodes,
        )

    # -- storage RPC handlers ------------------------------------------------
    def store_fragment(
        self, meta: GroupMeta, index: int, payload: bytes,
        membership: dict[int, float], proof: object | None = None,
    ) -> bool:
        view = self.groups.setdefault(meta.chash, GroupView(meta=meta))
        view.members.update(membership)
        view.members[self.nid] = self.net.now
        if proof is not None:
            self.claim_proofs[(meta.chash, index)] = proof
            self.claim_proofs_by_chash.setdefault(meta.chash, {})[index] = \
                proof
        if not self.byzantine or self.colluding:
            self.fragments[(meta.chash, index)] = payload
            self.fragments_by_chash.setdefault(meta.chash, {})[index] = \
                payload
        return True

    def serve_fragments(self, chash: bytes) -> dict[int, bytes]:
        net = self.net
        if (not self.alive
                or (net._eclipse is not None and net.is_eclipsed(self.nid))):
            return {}
        if self.byzantine:
            if not self.colluding:
                return {}
            # withholding: right indices, corrupted bytes — the puller
            # pays the transfer, then the tag check discards the row
            frags = self.fragments_by_chash.get(chash)
            return ({i: C_corrupt(p) for i, p in frags.items()}
                    if frags else {})
        frags = self.fragments_by_chash.get(chash)
        return dict(frags) if frags else {}

    def cache_chunk(self, chash: bytes, chunk: bytes, ttl: float) -> None:
        view = self.groups.get(chash)
        if view is not None and not self.byzantine:
            view.chunk_cache = chunk
            view.cache_expiry = self.net.now + ttl
            self.net.chunk_caches += 1

    def cached_chunk(self, chash: bytes) -> bytes | None:
        view = self.groups.get(chash)
        if view is None or view.chunk_cache is None or self.byzantine:
            return None
        net = self.net
        if net.now >= view.cache_expiry:
            return None
        if net._eclipse is not None and net.is_eclipsed(self.nid):
            return None
        return view.chunk_cache


class SimNetwork:
    """In-process peer network.

    ``vrf=`` picks the selection-proof registry backend (see
    ``repro.core.vrf.make_registry``): ``"hash"`` is the PR 3 keyed-sha256
    construction (bit-stable, the default), ``"arx"`` the batched
    ``kernels/prf_select`` construction used for 1K+-node protocol runs.

    ``eclipse`` models a partition/eclipse adversary: when set to a ring
    interval ``(lo, hi)``, every node whose id falls inside it is *alive
    but unreachable* — DHT lookups skip it, it serves no fragments or
    cached chunks, and the protocol layer drops its claims and freezes its
    timers (see ``protocol_sim``). Set/cleared by the simulation loop.
    """

    def __init__(self, seed: int = 0, latency: LatencyModel | None = None,
                 vrf: str = "hash", cache_lookups: bool = False):
        self.registry = make_registry(vrf)
        self.rng = np.random.default_rng(seed)
        self.latency = latency or LatencyModel()
        self.nodes: dict[int, Node] = {}
        self._ring: list[int] = []  # sorted alive node ids
        self.now = 0.0  # seconds
        self.repair_traffic_bytes = 0
        self.repair_count = 0
        # per-tick byte load on each geo region's links: repair pulls,
        # warm-cache fragment ships and serving reads all charge the
        # holder's region here, so the two traffic classes compete for the
        # same links. Reset by the simulation loop at the start of every
        # tick; read by the serving layer's congestion model
        # (``protocol_sim._serve_tick``). Pure accounting — no RNG.
        self.region_load = np.zeros(len(REGIONS), np.float64)
        # count of cache_chunk writes ever made: while zero (cache_ttl=0
        # runs — the default), repair's warm-holder scan is provably a
        # no-op and is skipped wholesale
        self.chunk_caches = 0
        self._eclipse: tuple[int, int] | None = None  # cut ring segment
        # dense per-node tables for the vectorized tick path: row i of
        # alive_rows is nodes' liveness in creation order (Node.row);
        # eclipsed_rows mirrors is_eclipsed() per row so the batched
        # claims round can mask unreachable receivers with one gather
        # instead of a python scan (recomputed only when the cut moves)
        self._rows: list[Node] = []
        self.alive_rows = np.zeros(0, dtype=bool)
        self.eclipsed_rows = np.zeros(0, dtype=bool)
        # DHT-lookup memo: candidates() is a pure function of the ring and
        # the eclipse cut, both of which change only at churn/window edges,
        # while a repair tick re-runs the same ~R-wide lookups for every
        # member of every short group. Invalidates on any membership or
        # partition change (_ring_version). Off by default so the scalar
        # reference path stays the unmodified PR 3 implementation the
        # protocol_speed benchmark baselines against; the vectorized
        # engine turns it on (results are identical either way — the
        # lookup is deterministic).
        self.cache_lookups = cache_lookups
        self._ring_version = 0
        self._cand_state: tuple = (-1, None)
        self._cand_cache: dict[tuple[int, int], list[Node]] = {}
        # batched-Locate state: selection.LocateRound instances keyed by
        # (anchor, count, r_target), valid while the ring + eclipse cut are
        # unchanged (same invalidation condition as the candidate memo)
        self._locate_state: tuple = (-1, None)
        self._locate_cache: dict[tuple, "sel.LocateRound"] = {}
        self._locate_prev: dict[tuple, "sel.LocateRound"] = {}
        self.row_of: dict[int, int] = {}    # nid -> dense row
        self.alive_set: set[int] = set()    # alive nids (mirror of .alive)
        # creator-recorded fragment integrity tags (chash, index) ->
        # chunks.payload_tag of the honest bytes. Written by whoever
        # *encodes* a fragment (the storing client, a repairer); checked
        # by row_ok() at every pull so colluding holders can't slip
        # corrupt rows into a decode. Pure accounting — no RNG.
        self.frag_tags: dict[tuple[bytes, int], int] = {}
        # dead-node reaper bookkeeping: fail_node drops the node's dict
        # state immediately; the dense row tables are compacted lazily once
        # dead rows outnumber max(64, alive) — amortized O(1) per death.
        # rows_version stamps each compaction so row-index holders
        # (claims_engine) can refresh their cached gathers.
        self.rows_version = 0
        self._dead_rows = 0

    # -- membership ----------------------------------------------------------
    @property
    def n_nodes(self) -> int:
        return len(self._ring)

    @property
    def eclipse(self) -> tuple[int, int] | None:
        return self._eclipse

    @eclipse.setter
    def eclipse(self, segment: tuple[int, int] | None) -> None:
        if segment == self._eclipse:
            return
        self._eclipse = segment
        self._recompute_eclipsed_rows()

    def _recompute_eclipsed_rows(self) -> None:
        ecl = np.zeros(self.alive_rows.shape[0], dtype=bool)
        if self._eclipse is not None:
            for i, node in enumerate(self._rows):
                if node is not None and self.is_eclipsed(node.nid):
                    ecl[i] = True
        self.eclipsed_rows = ecl

    # -- fragment integrity ---------------------------------------------------
    def record_frag_tag(self, chash: bytes, index: int,
                        payload: bytes) -> None:
        """Record the creator-side integrity tag of an honestly encoded
        fragment (see ``frag_tags``)."""
        self.frag_tags[(chash, index)] = C_payload_tag(payload)

    def row_ok(self, chash: bytes, index: int, payload: bytes) -> bool:
        """Verify a pulled fragment row against its creator-recorded tag.

        Rows with no recorded tag are trusted (pre-tag stores, e.g. test
        scaffolding that bypasses the client path); a recorded tag must
        match exactly — colluders' corrupt rows fail here and are
        discarded *after* their transfer was paid."""
        tag = self.frag_tags.get((chash, index))
        return tag is None or tag == C_payload_tag(payload)

    def add_node(self, byzantine: bool = False, seed: bytes | None = None) -> Node:
        kp = KeyPair.generate(seed)
        region = int(self.rng.integers(len(REGIONS)))
        node = Node(self, kp, region, byzantine)
        self.registry.register(kp)
        self.nodes[node.nid] = node
        bisect.insort(self._ring, node.nid)
        node.row = len(self._rows)
        self._rows.append(node)
        if node.row >= self.alive_rows.shape[0]:  # amortized growth
            grown = np.zeros(max(64, 2 * self.alive_rows.shape[0]), bool)
            grown[:self.alive_rows.shape[0]] = self.alive_rows
            self.alive_rows = grown
            grown_e = np.zeros(self.alive_rows.shape[0], bool)
            grown_e[:self.eclipsed_rows.shape[0]] = self.eclipsed_rows
            self.eclipsed_rows = grown_e
        self.alive_rows[node.row] = True
        if self._eclipse is not None:
            self.eclipsed_rows[node.row] = self.is_eclipsed(node.nid)
        self.row_of[node.nid] = node.row
        self.alive_set.add(node.nid)
        self._ring_version += 1
        return node

    def fail_node(self, nid: int) -> None:
        node = self.nodes[nid]
        node.alive = False
        self.alive_rows[node.row] = False
        self.alive_set.discard(nid)
        self._ring_version += 1
        i = bisect.bisect_left(self._ring, nid)
        if i < len(self._ring) and self._ring[i] == nid:
            self._ring.pop(i)
        # --- dead-node reaper -------------------------------------------
        # A failed node never rejoins (churn replaces it with a fresh
        # keypair), and every live read path is guarded (`nid in
        # net.nodes` / `.get` / alive filters), so its per-node dict state
        # — fragments, claim proofs, group views, keypair, memoized
        # selection verdicts — is unreachable garbage from here on.
        # Dropping it immediately keeps a churn-heavy simulated month at
        # bounded memory instead of accruing every keypair ever spawned.
        del self.nodes[nid]
        del self.row_of[nid]
        self._rows[node.row] = None
        self._dead_rows += 1
        self.registry.evict(node.kp)
        # the coefficient rows of the fragments this node held are dead
        # with it — same hook as the VRF registry eviction above (the memo
        # is a pure cache, so a row shared with a surviving duplicate
        # index is simply recomputed on next use)
        for chash, idx in node.fragments:
            rateless.evict_coeff_row(chash, idx)
        if self._dead_rows > max(64, len(self._ring)):
            self._compact_rows()

    def _compact_rows(self) -> None:
        """Rebuild the dense row tables over the surviving nodes.

        Reassigns ``Node.row`` / ``row_of`` and shrinks ``alive_rows`` to
        the live population (with the same amortized headroom growth as
        ``add_node``). Bumps ``rows_version``: any cached row-index arrays
        (``claims_engine`` gathers) are stale and must be re-derived from
        ``row_of``.
        """
        rows = [n for n in self._rows if n is not None]
        self._rows = rows
        self.row_of = {}
        self.alive_rows = np.zeros(max(64, 2 * len(rows)), dtype=bool)
        for i, node in enumerate(rows):
            node.row = i
            self.row_of[node.nid] = i
        self.alive_rows[:len(rows)] = True
        self._dead_rows = 0
        self.rows_version += 1
        self._recompute_eclipsed_rows()
        # sweep the cumulative Locate() donor state: per-candidate rows of
        # reaped nids can never donate again (donor reuse is nid-matched),
        # but they pin the dead Node objects — fragments included — so
        # the donor map would otherwise grow with every node ever seen.
        # Amortized with the row compaction itself.
        for cache in (self._locate_cache, self._locate_prev):
            for lr in cache.values():
                lr.compact(self.alive_set)

    def alive_nodes(self) -> list[Node]:
        return [self.nodes[n] for n in self._ring]

    # -- partition / eclipse -------------------------------------------------
    def is_eclipsed(self, nid: int) -> bool:
        """True iff ``nid`` sits inside the cut ring segment (unreachable)."""
        e = self._eclipse
        if e is None:
            return False
        lo, hi = e
        p = nid % RING
        return lo <= p < hi if lo <= hi else (p >= lo or p < hi)

    # -- DHT-style lookup ----------------------------------------------------
    def candidates(self, point: int, count: int) -> list[Node]:
        """Best-effort nearest-on-ring lookup (the paper's DHT-Lookup).

        Eclipsed nodes are unreachable at the routing layer, so the walk
        passes over them (exactly as it passes over failed nodes, which
        are not in the ring at all).
        """
        if not self._ring:
            return []
        key = None
        if self.cache_lookups:
            state = (self._ring_version, self.eclipse)
            if state != self._cand_state:
                self._cand_state = state
                self._cand_cache.clear()
            key = (point, count)
            hit = self._cand_cache.get(key)
            if hit is not None:
                return hit
        count = min(count, len(self._ring))
        i = bisect.bisect_left(self._ring, point % RING)
        # Walk outwards on the ring from the insertion point: ``lo`` moves
        # counter-clockwise from slot i-1, ``hi`` clockwise from slot i.
        # Together they sweep disjoint slots until they meet — ``remaining``
        # counts the unvisited slots between them, and when it reaches 1
        # both pointers reference the same final slot (lo ≡ hi mod n), so
        # the walk terminates without ever revisiting a node. Every
        # reachable (non-eclipsed) node is therefore visited exactly once,
        # and the result needs no dedup: a short return means the ring
        # genuinely has fewer than ``count`` reachable nodes.
        out: list[int] = []
        lo, hi = i - 1, i
        n = len(self._ring)
        remaining = n
        ring = self._ring
        ecl = self.eclipse
        half = RING >> 1
        # only the advanced pointer needs a fresh distance each step —
        # carry the other side's value (ring_distance inlined: this loop
        # dominates every Locate()/MembershipTimer walk at 10K nodes)
        d = (point - ring[lo % n]) % RING
        dlo = d if d <= half else RING - d
        d = (point - ring[hi % n]) % RING
        dhi = d if d <= half else RING - d
        while len(out) < count and remaining:
            if dlo <= dhi:
                nxt, lo = ring[lo % n], lo - 1
                d = (point - ring[lo % n]) % RING
                dlo = d if d <= half else RING - d
            else:
                nxt, hi = ring[hi % n], hi + 1
                d = (point - ring[hi % n]) % RING
                dhi = d if d <= half else RING - d
            remaining -= 1
            if ecl is None or not self.is_eclipsed(nxt):
                out.append(nxt)
        found = [self.nodes[n_] for n_ in out]
        if key is not None:
            self._cand_cache[key] = found
        return found

    def locate_round(self, anchor: int, count: int,
                     r_target: int) -> "sel.LocateRound":
        """Resident batched-Locate state for one anchor (see
        ``selection.LocateRound``). Instances persist across slots and
        ticks; the cache drops whenever membership or the partition cut
        changes (which also re-keys ``n_nodes``-dependent thresholds)."""
        state = (self._ring_version, self.eclipse)
        if state != self._locate_state:
            self._locate_state = state
            # fold the stale generation into the donor map: LocateRound
            # copies per-candidate rows (distances, thresholds, VRF tag
            # lanes) for nids that survived the membership change. The
            # map is cumulative across generations — an anchor visited at
            # tick t and next needed at tick t+3 still finds its donor
            # (per-nid reuse stays exact however stale the donor: the
            # copied rows are pure functions of (anchor, nid, r_target,
            # n_nodes), all matched). Bounded by one entry per anchor.
            self._locate_prev.update(self._locate_cache)
            self._locate_cache = {}
        key = (anchor, count, r_target)
        lr = self._locate_cache.get(key)
        if lr is None:
            lr = sel.LocateRound(self.registry, self.candidates(anchor, count),
                                 anchor, r_target, self.n_nodes,
                                 prev=self._locate_prev.get(key))
            self._locate_cache[key] = lr
        return lr

    def evict_timer_verdicts(self, anchor: int, r_target: int,
                             nids: list[int]) -> None:
        """Invalidate cached MembershipTimer admit verdicts for ``nids``.

        Called after a repair round changes a group's membership: the new
        members' proofs must be (re)judged on the next timer pass. Both
        the live generation and the cumulative donor map are patched —
        either could seed the next tick's ``LocateRound``."""
        key = (anchor, min(4 * r_target, self.n_nodes), r_target)
        for cache in (self._locate_cache, self._locate_prev):
            lr = cache.get(key)
            if lr is not None:
                lr.evict_timer(nids)

    # -- latency accounting ----------------------------------------------------
    def rtt(self, a: Node, b: Node) -> float:
        """One sampled round-trip in seconds."""
        return self.latency.rtt_ms(self.rng, a.region, b.region) / 1e3

    def rtts(self, src: Node, dsts: list[Node]) -> np.ndarray:
        return np.array([self.rtt(src, d) for d in dsts])
