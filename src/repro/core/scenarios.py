"""Batched JAX scenario engine for paper-scale durability sweeps.

``simulation.py`` is the numpy *reference* implementation: one
``(params, seed)`` point per call, a Python loop per time step. This module
is the production path: the full group state ``(honest, byz, cache_t,
alive)`` lives in batched arrays, every time step advances inside one jitted
``lax.scan``, and ``vmap`` runs a whole ``(parameter-grid × seeds ×
policies)`` sweep — e.g. all cells of Fig. 4 or Fig. 6 — as a single device
dispatch. Group counts, code parameters, churn rates, TTLs, and policy
selectors are all *traced* scalars, so heterogeneous cells (different
``n_objects``, ``n_chunks``, ``(K, R)``) share one compiled executable via
padding masks; only the padded maxima are compile-time constants.

Scenario diversity is a first-class axis. Each policy is a pure function
composed into the scan body and selected per batch element:

Churn policies (``churn_policy``):

* ``CHURN_IID`` — i.i.d. Poisson churn per node ⇒ binomial thinning per
  group per step. The paper's own model (§6.1, Figs. 4–6).
* ``CHURN_REGIONAL`` — correlated regional bursts: with probability
  ``burst_prob`` per step one of ``N_REGIONS`` regions suffers
  ``burst_mult``× the base failure rate, modeling rack/AZ outages as in
  *Topology-Aware Cooperative Data Protection* (PAPERS.md) — failures the
  i.i.d. model provably understates.

Adversary policies (``adv_policy``):

* ``ADV_STATIC`` — a fixed Byzantine population fraction joins repairs
  (paper Fig. 6 top; §4.4's CTMC assumes exactly this).
* ``ADV_ADAPTIVE`` — adaptive re-join: Byzantine members never churn
  voluntarily and flood repair refills at ``adapt_boost``× their population
  share, the BFT-DSN-style adversary (PAPERS.md) that targets the repair
  path itself.
* ``ADV_TARGETED`` — greedy targeted kill at step ``attack_step`` reusing
  ``targeted_attack_vault``'s cost model (A.3 eq. 17): cheapest groups
  first, cost ``(honest − K_inner + 1)/fragments_per_node``, budget
  ``attack_frac · n_nodes`` (paper Fig. 6 bottom, here time-resolved).

Cache policy is the ``cache_ttl_hours`` knob (0 disables), identical to the
reference semantics (repair.py docstring / Fig. 4).

Public API:

* ``make_scenario(**kw)`` / ``from_simparams(p)`` — build one scenario cell;
* ``run_grid(cells, seeds)`` — ONE dispatch over cells × seeds, returns a
  ``ScenarioResult`` of ``[n_cells, n_seeds]`` arrays;
* ``run_replicated_grid(cells, seeds)`` — Ceph-like baseline, same churn;
* ``trace_grid(cells, seeds)`` — Fig. 5 per-step honest-fragment traces;
* ``targeted_grid(cells, seeds)`` — Fig. 6-bottom static attack sweep;
* ``mean_ci(x)`` — per-cell mean and 95% CI over the seed axis.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

HOURS_PER_YEAR = 24 * 365.0

CHURN_IID = 0
CHURN_REGIONAL = 1
CHURN_POLICIES = {"iid": CHURN_IID, "regional": CHURN_REGIONAL}

ADV_STATIC = 0
ADV_ADAPTIVE = 1
ADV_TARGETED = 2
ADVERSARY_POLICIES = {
    "static": ADV_STATIC, "adaptive": ADV_ADAPTIVE, "targeted": ADV_TARGETED,
}

N_REGIONS = 16  # regional-burst fault domains (racks/AZs)


class Scenario(NamedTuple):
    """One sweep cell. Every leaf is a scalar (stacked to [B] when batched);
    all of them are traced, so cells with different values share one
    compiled executable."""

    n_objects: np.int32
    n_chunks: np.int32
    k_outer: np.float32
    k_inner: np.float32
    r_inner: np.float32
    n_nodes: np.float32
    byz_fraction: np.float32
    churn_per_year: np.float32
    cache_ttl_hours: np.float32
    step_hours: np.float32
    steps: np.int32
    churn_policy: np.int32
    adv_policy: np.int32
    burst_prob: np.float32
    burst_mult: np.float32
    adapt_boost: np.float32
    attack_frac: np.float32
    attack_step: np.int32
    frags_per_node: np.float32
    replication: np.float32
    seed: np.int32


class ScenarioResult(NamedTuple):
    repair_traffic_units: jnp.ndarray
    repairs: jnp.ndarray
    cache_hits: jnp.ndarray
    lost_objects: jnp.ndarray
    lost_fraction: jnp.ndarray
    final_honest_mean: jnp.ndarray
    honest_min: jnp.ndarray        # min honest seen in any live group
    members_max: jnp.ndarray       # max honest+byz seen in any group
    alive_frac_trace: jnp.ndarray  # [max_steps] fraction of groups alive


def make_scenario(
    n_objects: int = 1000, n_chunks: int = 10, k_outer: int = 8,
    k_inner: int = 32, r_inner: int = 80, n_nodes: int = 100_000,
    byz_fraction: float = 0.0, churn_per_year: float = 4.0,
    cache_ttl_hours: float = 0.0, step_hours: float = 6.0,
    years: float = 1.0, steps: int | None = None,
    churn_policy: int | str = CHURN_IID, adv_policy: int | str = ADV_STATIC,
    burst_prob: float = 0.05, burst_mult: float = 20.0,
    adapt_boost: float = 2.0, attack_frac: float = 0.0, attack_step: int = 0,
    frags_per_node: int = 1, replication: int = 3, seed: int = 0,
) -> Scenario:
    if isinstance(churn_policy, str):
        churn_policy = CHURN_POLICIES[churn_policy]
    if isinstance(adv_policy, str):
        adv_policy = ADVERSARY_POLICIES[adv_policy]
    if steps is None:
        steps = int(round(years * HOURS_PER_YEAR / step_hours))
    return Scenario(
        n_objects=np.int32(n_objects), n_chunks=np.int32(n_chunks),
        k_outer=np.float32(k_outer), k_inner=np.float32(k_inner),
        r_inner=np.float32(r_inner), n_nodes=np.float32(n_nodes),
        byz_fraction=np.float32(byz_fraction),
        churn_per_year=np.float32(churn_per_year),
        cache_ttl_hours=np.float32(cache_ttl_hours),
        step_hours=np.float32(step_hours), steps=np.int32(steps),
        churn_policy=np.int32(churn_policy), adv_policy=np.int32(adv_policy),
        burst_prob=np.float32(burst_prob), burst_mult=np.float32(burst_mult),
        adapt_boost=np.float32(adapt_boost),
        attack_frac=np.float32(attack_frac),
        attack_step=np.int32(attack_step),
        frags_per_node=np.float32(frags_per_node),
        replication=np.float32(replication), seed=np.int32(seed),
    )


def from_simparams(p, **overrides) -> Scenario:
    """Build a scenario cell from a ``simulation.SimParams``."""
    kw = dict(
        n_objects=p.n_objects, n_chunks=p.n_chunks, k_outer=p.k_outer,
        k_inner=p.k_inner, r_inner=p.r_inner, n_nodes=p.n_nodes,
        byz_fraction=p.byz_fraction, churn_per_year=p.churn_per_year,
        cache_ttl_hours=p.cache_ttl_hours, step_hours=p.step_hours,
        years=p.years, seed=p.seed,
    )
    kw.update(overrides)
    return make_scenario(**kw)


# --------------------------------------------------------------- primitives
def _binom(key, n, p):
    """Exact binomial sample; safe for n == 0 and p ∈ {0, 1}."""
    return jax.random.binomial(key, jnp.maximum(n, 0.0),
                               jnp.clip(p, 0.0, 1.0))


_FAST_J = 12          # inverse-CDF terms; exact for means up to _FAST_CUT
_FAST_CUT = 3.0       # truncation tail P(X > 12 | m = 3) ~ 2e-5


def _binom_fast(key, n, p):
    """Fast binomial: exact truncated inverse-CDF for small means, Gaussian
    approximation above ``_FAST_CUT`` (where ``σ ≥ 2.3`` and the rounding
    bias is negligible).

    ``jax.random.binomial``'s rejection sampler runs at ~6M samples/s on
    CPU — it dominates sweep cost. The churn/repair regime of these
    simulations has ``n·p ≲ 2``, where the unrolled CDF recurrence
    ``pmf_{j+1} = pmf_j (n-j)/(j+1) · p/(1-p)`` is exact (up to the ~2e-5
    truncation tail at the cutover mean) and several times faster. Selected
    by the static ``sampler="fast"`` argument of the grid runners;
    ``"exact"`` keeps the reference sampler.
    """
    n = jnp.maximum(n, 0.0)
    p = jnp.clip(p, 0.0, 1.0)
    m = n * p
    # small-mean branch: X = #{j : u > cdf_j}, capped by J and n
    u = jax.random.uniform(key, jnp.shape(m), minval=1e-7, maxval=1.0 - 1e-7)
    r = p / jnp.maximum(1.0 - p, 1e-12)
    pmf = jnp.exp(n * jnp.log1p(-jnp.minimum(p, 1.0 - 1e-7)))
    cdf = pmf
    cnt = (u > cdf).astype(jnp.float32)
    for j in range(_FAST_J - 1):
        pmf = pmf * ((n - j) / (j + 1.0)) * r
        cdf = cdf + jnp.maximum(pmf, 0.0)
        cnt = cnt + (u > cdf)
    small = jnp.minimum(cnt, n)
    # large-mean branch: clipped rounded Gaussian, with a logistic-probit
    # z from the same uniform (one log instead of erfinv — the branch is
    # already an approximation, ~2% CDF error is immaterial and it halves
    # the sampler's transcendental budget)
    s = jnp.sqrt(jnp.maximum(m * (1.0 - p), 1e-12))
    z = jnp.log(u / (1.0 - u)) * 0.5513
    big = jnp.clip(jnp.round(m + s * z), 0.0, n)
    return jnp.where(m <= _FAST_CUT, small, big)


SAMPLERS = {"exact": _binom, "fast": _binom_fast}


def _p_fail_step(sc: Scenario) -> jnp.ndarray:
    """Per-step per-node failure probability from the Poisson churn rate."""
    return -jnp.expm1(-sc.churn_per_year / HOURS_PER_YEAR * sc.step_hours)


def _churn_prob(sc: Scenario, key, gidx) -> jnp.ndarray:
    """Per-group failure probability [G] under the selected churn policy.

    Policy selection is a ``where`` blend rather than ``lax.switch``: under
    ``vmap`` a batched-index switch is dramatically slower than computing
    both (cheap) branches, and the blend keeps the sampler fusable.
    """
    base = _p_fail_step(sc)
    kb, kr = jax.random.split(key)
    regional = sc.churn_policy == CHURN_REGIONAL
    burst = regional & (jax.random.uniform(kb) < sc.burst_prob)
    region = jax.random.randint(kr, (), 0, N_REGIONS)
    hit = (gidx % N_REGIONS) == region
    boosted = jnp.minimum(base * sc.burst_mult, 0.95)
    return jnp.where(burst & hit, boosted, jnp.full(gidx.shape, base))


def _targeted_kill(sc: Scenario, key, honest, alive):
    """Greedy cheapest-groups-first kill mask (A.3 cost model)."""
    cost = jnp.maximum(honest - sc.k_inner + 1.0, 0.0)
    cost = cost / jnp.maximum(sc.frags_per_node, 1.0)
    cost = jnp.where(alive, cost, jnp.inf)
    # random tiebreak: equal-cost groups are indistinguishable behind the
    # outer code's opacity (same argument as targeted_attack_vault)
    tie = jax.random.uniform(key, cost.shape) * 1e-3
    order = jnp.argsort(cost + tie)
    csum = jnp.cumsum(cost[order])
    budget = sc.attack_frac * sc.n_nodes
    kill_sorted = csum <= budget
    return jnp.zeros_like(kill_sorted).at[order].set(kill_sorted)


# ------------------------------------------------------------- vault engine
class _Static(NamedTuple):
    max_groups: int
    max_objects: int
    max_steps: int


def _vault_init(st: _Static, sampler: str, sc: Scenario):
    """Per-element initial state (vmapped over the batch)."""
    G = st.max_groups
    gidx = jnp.arange(G, dtype=jnp.int32)
    active = gidx < sc.n_objects * sc.n_chunks
    base = jax.random.PRNGKey(jnp.asarray(sc.seed, jnp.uint32))
    k_init, _ = jax.random.split(base)
    byz0 = SAMPLERS[sampler](k_init, jnp.where(active, sc.r_inner, 0.0),
                             jnp.full((G,), sc.byz_fraction))
    honest0 = jnp.where(active, sc.r_inner - byz0, 0.0)
    alive0 = active & (honest0 >= sc.k_inner)
    cache0 = jnp.zeros(G)  # client seeds caches at store time (t=0)
    return (honest0, byz0, alive0, cache0, 0.0, 0.0, 0.0, jnp.inf, 0.0)


def _vault_churn(st: _Static, sampler: str, sc: Scenario, state, t):
    """Per-element churn half-step: thin members, return repair keys."""
    sample = SAMPLERS[sampler]
    gidx = jnp.arange(st.max_groups, dtype=jnp.int32)
    base = jax.random.PRNGKey(jnp.asarray(sc.seed, jnp.uint32))
    kt = jax.random.fold_in(base, t + 1)
    kc, kb, kr, kp, ka = jax.random.split(kt, 5)
    honest, byz = state[0], state[1]
    p_fail = _churn_prob(sc, kp, gidx)
    # adaptive adversary: byzantine members never leave voluntarily
    adaptive = sc.adv_policy == ADV_ADAPTIVE
    p_fail_b = jnp.where(adaptive, 0.0, p_fail)
    h = honest - sample(kc, honest, p_fail)
    b = byz - sample(kb, byz, p_fail_b)
    return h, b, kr, ka


def _vault_attack(sc: Scenario, h, alive, ka):
    """Per-element targeted greedy kill (only traced inside the cond)."""
    attack = sc.adv_policy == ADV_TARGETED
    kill = _targeted_kill(sc, ka, h, alive)
    return jnp.where(attack & kill, jnp.minimum(h, sc.k_inner - 1.0), h)


def _vault_repair(st: _Static, sampler: str, sc: Scenario, state, h, b, kr, t):
    """Per-element repair + traffic half-step."""
    sample = SAMPLERS[sampler]
    gidx = jnp.arange(st.max_groups, dtype=jnp.int32)
    active = gidx < sc.n_objects * sc.n_chunks
    _, _, alive, cache_t, traffic, repairs, hits, hmin, mmax = state
    now = (t + 1.0) * sc.step_hours
    frag_units = 1.0 / (sc.k_outer * sc.k_inner)
    chunk_units = 1.0 / sc.k_outer
    # adaptive adversary floods refills at adapt_boost x population share
    refill_p = jnp.where(
        sc.adv_policy == ADV_ADAPTIVE,
        jnp.clip(sc.byz_fraction * sc.adapt_boost, 0.0, 0.95),
        sc.byz_fraction)

    a = alive & (h >= sc.k_inner)  # decode impossible => absorbing
    deficit = jnp.maximum(jnp.where(a, sc.r_inner - (h + b), 0.0), 0.0)
    new_b = sample(kr, deficit, jnp.full_like(deficit, refill_p))
    h = h + (deficit - new_b)
    b = b + new_b

    has_cache = sc.cache_ttl_hours > 0.0
    warm = (now - cache_t) <= sc.cache_ttl_hours
    hit_frags = jnp.where(warm, deficit, jnp.maximum(deficit - 1.0, 0.0))
    miss_pulls = jnp.where(~warm & (deficit > 0), 1.0, 0.0)
    t_cached = hit_frags.sum() * frag_units + miss_pulls.sum() * chunk_units
    t_plain = deficit.sum() * sc.k_inner * frag_units
    new_cache = jnp.where(has_cache & (miss_pulls > 0), now, cache_t)

    new_state = (
        h, b, a, new_cache,
        traffic + jnp.where(has_cache, t_cached, t_plain),
        repairs + deficit.sum(),
        hits + jnp.where(has_cache, hit_frags.sum(), 0.0),
        jnp.minimum(hmin, jnp.where(a, h, jnp.inf).min()),
        jnp.maximum(mmax, jnp.where(active, h + b, 0.0).max()),
    )
    alive_frac = a.sum() / jnp.maximum(sc.n_objects * sc.n_chunks, 1)
    return new_state, alive_frac


def _vault_finalize(st: _Static, sc: Scenario, state) -> ScenarioResult:
    gidx = jnp.arange(st.max_groups, dtype=jnp.int32)
    honest, _, alive, _, traffic, repairs, hits, hmin, mmax = state
    obj_id = jnp.minimum(gidx // jnp.maximum(sc.n_chunks, 1),
                         st.max_objects - 1)
    chunks_alive = jax.ops.segment_sum(
        alive.astype(jnp.float32), obj_id, num_segments=st.max_objects)
    obj_active = jnp.arange(st.max_objects) < sc.n_objects
    lost = (obj_active & (chunks_alive < sc.k_outer)).sum()
    n_alive = alive.sum()
    fhm = jnp.where(n_alive > 0,
                    (honest * alive).sum() / jnp.maximum(n_alive, 1.0), 0.0)
    return ScenarioResult(
        repair_traffic_units=traffic, repairs=repairs, cache_hits=hits,
        lost_objects=lost.astype(jnp.int32),
        lost_fraction=lost / jnp.maximum(sc.n_objects, 1),
        final_honest_mean=fhm,
        honest_min=jnp.where(jnp.isfinite(hmin), hmin, 0.0),
        members_max=mmax, alive_frac_trace=jnp.zeros(()),  # filled by caller
    )


def _where_on(on, new, old):
    """Select per batch element, broadcasting [B] over state leaves."""
    mask = on.reshape(on.shape + (1,) * (new.ndim - on.ndim))
    return jnp.where(mask, new, old)


@functools.lru_cache(maxsize=None)
def _vault_batch(st: _Static, sampler: str):
    """Compile the batched engine: one lax.scan over time whose body is
    vmapped over the batch. (scan-of-vmap, not vmap-of-scan, so the
    targeted-attack sort can sit behind a real lax.cond and only execute
    on actual attack steps instead of being select-ed every step.)
    """
    churn = jax.vmap(functools.partial(_vault_churn, st, sampler),
                     in_axes=(0, 0, None))
    attack = jax.vmap(_vault_attack)
    repair = jax.vmap(functools.partial(_vault_repair, st, sampler),
                      in_axes=(0, 0, 0, 0, 0, None))

    def run(scb: Scenario):
        init = jax.vmap(functools.partial(_vault_init, st, sampler))(scb)

        def body(state, t):
            h, b, kr, ka = churn(scb, state, t)
            hit_now = (scb.adv_policy == ADV_TARGETED) & (t == scb.attack_step)
            h = jax.lax.cond(
                hit_now.any(),
                lambda args: jnp.where(hit_now[:, None],
                                       attack(scb, *args), args[0]),
                lambda args: args[0], (h, state[2], ka))
            new_state, alive_frac = repair(scb, state, h, b, kr, t)
            on = t < scb.steps
            state = tuple(_where_on(on, n, o)
                          for n, o in zip(new_state, state))
            return state, jnp.where(on, alive_frac, state[2].sum(-1)
                                    / jnp.maximum(scb.n_objects
                                                  * scb.n_chunks, 1))

        state, alive_tr = jax.lax.scan(body, init, jnp.arange(st.max_steps))
        res = jax.vmap(functools.partial(_vault_finalize, st))(scb, state)
        return res._replace(alive_frac_trace=alive_tr.T)

    return jax.jit(run)


def _stack(cells: list[Scenario]) -> Scenario:
    return jax.tree_util.tree_map(lambda *xs: np.stack(xs), *cells)


def _product(cells, seeds) -> list[Scenario]:
    out = []
    for cell in cells:
        if isinstance(cell, dict):
            cell = make_scenario(**cell)
        for s in seeds:
            out.append(cell._replace(seed=np.int32(s)))
    return out


def _reshape(res, n_cells: int, n_seeds: int):
    return type(res)(*(np.asarray(x).reshape(n_cells, n_seeds, *x.shape[1:])
                       for x in res))


def run_grid(cells, seeds=range(8), sampler: str = "exact") -> ScenarioResult:
    """Run cells × seeds vault scenarios in ONE batched dispatch.

    ``cells``: scenarios or kwargs-dicts for :func:`make_scenario`.
    ``sampler``: ``"exact"`` (reference-faithful binomial) or ``"fast"``
    (hybrid inverse-CDF/Gaussian sampler for big sweeps). Returns a
    :class:`ScenarioResult` whose leaves have shape ``[n_cells, n_seeds]``
    (the trace leaf ``[n_cells, n_seeds, max_steps]``).
    """
    seeds = list(seeds)
    flat = _product(cells, seeds)
    st = _Static(
        max_groups=max(int(s.n_objects * s.n_chunks) for s in flat),
        max_objects=max(int(s.n_objects) for s in flat),
        max_steps=max(int(s.steps) for s in flat),
    )
    res = _vault_batch(st, sampler)(_stack(flat))
    return _reshape(res, len(flat) // len(seeds), len(seeds))


# ------------------------------------------------------ replicated baseline
def _repl_single(st: _Static, sampler: str, sc: Scenario) -> ScenarioResult:
    sample = SAMPLERS[sampler]
    O = st.max_objects
    oidx = jnp.arange(O, dtype=jnp.int32)
    active = oidx < sc.n_objects
    base = jax.random.PRNGKey(jnp.asarray(sc.seed + 1, jnp.uint32))
    k_init, _ = jax.random.split(base)
    bad0 = sample(k_init, jnp.where(active, sc.replication, 0.0),
                  jnp.full((O,), sc.byz_fraction))
    good0 = jnp.where(active, sc.replication - bad0, 0.0)
    alive0 = active & (good0 >= 1.0)

    def step(carry, t):
        good, bad, alive, traffic, repairs = carry
        on = t < sc.steps
        kt = jax.random.fold_in(base, t + 1)
        kg, kb, kr, kp = jax.random.split(kt, 4)
        p_fail = _churn_prob(sc, kp, oidx)
        g = good - sample(kg, good, p_fail)
        b = bad - sample(kb, bad, p_fail)
        a = alive & (g >= 1.0)  # no good replica left => object gone
        deficit = jnp.maximum(jnp.where(a, sc.replication - (g + b), 0.0), 0.0)
        # repair copies an unverifiable replica: good iff source good AND
        # the new holder is honest (contagious decay, Fig. 6)
        remaining = jnp.maximum(g + b, 1.0)
        p_good = jnp.where(a, g / remaining, 0.0) * (1.0 - sc.byz_fraction)
        new_good = sample(kr, deficit, jnp.clip(p_good, 0.0, 1.0))
        g = g + new_good
        b = b + (deficit - new_good)
        pick = lambda new, old: jnp.where(on, new, old)
        carry = (pick(g, good), pick(b, bad), jnp.where(on, a, alive),
                 pick(traffic + deficit.sum(), traffic),
                 pick(repairs + deficit.sum(), repairs))
        alive_frac = carry[2].sum() / jnp.maximum(sc.n_objects, 1)
        return carry, alive_frac

    init = (good0, bad0, alive0, 0.0, 0.0)
    (good, bad, alive, traffic, repairs), alive_tr = jax.lax.scan(
        step, init, jnp.arange(st.max_steps))
    lost = (active & ~alive).sum()
    n_alive = alive.sum()
    fhm = jnp.where(n_alive > 0,
                    (good * alive).sum() / jnp.maximum(n_alive, 1.0), 0.0)
    alive_min = jnp.where(alive, good, jnp.inf).min()
    return ScenarioResult(
        repair_traffic_units=traffic, repairs=repairs,
        cache_hits=jnp.zeros(()), lost_objects=lost.astype(jnp.int32),
        lost_fraction=lost / jnp.maximum(sc.n_objects, 1),
        final_honest_mean=fhm,
        honest_min=jnp.where(jnp.isfinite(alive_min), alive_min, 0.0),
        members_max=(good + bad).max(), alive_frac_trace=alive_tr,
    )


@functools.lru_cache(maxsize=None)
def _repl_batch(st: _Static, sampler: str):
    return jax.jit(jax.vmap(functools.partial(_repl_single, st, sampler)))


def run_replicated_grid(cells, seeds=range(8),
                        sampler: str = "exact") -> ScenarioResult:
    """Ceph-like replicated baseline, same grid semantics as run_grid."""
    seeds = list(seeds)
    flat = _product(cells, seeds)
    st = _Static(max_groups=1,
                 max_objects=max(int(s.n_objects) for s in flat),
                 max_steps=max(int(s.steps) for s in flat))
    res = _repl_batch(st, sampler)(_stack(flat))
    return _reshape(res, len(flat) // len(seeds), len(seeds))


# --------------------------------------------------------- Fig 5 trace grid
def _trace_single(max_steps: int, repair_interval_hours, sc: Scenario):
    base = jax.random.PRNGKey(jnp.asarray(sc.seed, jnp.uint32))
    k_init, _ = jax.random.split(base)
    byz0 = _binom(k_init, sc.r_inner, sc.byz_fraction)
    honest0 = sc.r_inner - byz0
    p_fail = _p_fail_step(sc)

    def step(carry, t):
        honest, byz, since, absorbed = carry
        kt = jax.random.fold_in(base, t + 1)
        kh, kb, kr = jax.random.split(kt, 3)
        h = honest - _binom(kh, honest, p_fail)
        b = byz - _binom(kb, byz, p_fail)
        absorbed_n = absorbed | (h < sc.k_inner)
        since_n = since + sc.step_hours
        do_rep = ~absorbed_n & (since_n >= repair_interval_hours)
        deficit = jnp.maximum(sc.r_inner - (h + b), 0.0)
        nb = _binom(kr, deficit, sc.byz_fraction)
        h = jnp.where(do_rep, h + deficit - nb, h)
        b = jnp.where(do_rep, b + nb, b)
        since_n = jnp.where(do_rep, 0.0, since_n)
        # absorbed groups freeze (numpy reference stops simulating them);
        # so do cells whose own horizon (sc.steps) has passed in a padded
        # heterogeneous batch
        frozen = absorbed | (t >= sc.steps)
        pick = lambda new, old: jnp.where(frozen, old, new)
        carry = (pick(h, honest), pick(b, byz), pick(since_n, since),
                 jnp.where(t >= sc.steps, absorbed, absorbed_n))
        return carry, carry[0]

    init = (honest0, byz0, 0.0, jnp.zeros((), bool))
    _, trace = jax.lax.scan(step, init, jnp.arange(max_steps))
    return trace


@functools.lru_cache(maxsize=None)
def _trace_batch(max_steps: int):
    def run(interval, sc):
        return _trace_single(max_steps, interval, sc)
    return jax.jit(jax.vmap(run, in_axes=(0, 0)))


def trace_grid(cells, seeds=range(8),
               repair_interval_hours: float = 24.0) -> np.ndarray:
    """Honest-fragment traces of single chunk groups (Fig. 5), batched over
    cells × seeds. Returns ``[n_cells, n_seeds, max_steps]`` int64; cells
    with a shorter horizon than the padded maximum hold their last value
    for the remaining steps."""
    seeds = list(seeds)
    flat = _product(cells, seeds)
    max_steps = max(int(s.steps) for s in flat)
    interval = np.full(len(flat), repair_interval_hours, np.float32)
    out = _trace_batch(max_steps)(interval, _stack(flat))
    return np.asarray(out, np.int64).reshape(
        len(flat) // len(seeds), len(seeds), max_steps)


# --------------------------------------------------- Fig 6 targeted attacks
def _targeted_single(st: _Static, sc: Scenario):
    G = st.max_groups
    gidx = jnp.arange(G, dtype=jnp.int32)
    active = gidx < sc.n_objects * sc.n_chunks
    base = jax.random.PRNGKey(jnp.asarray(sc.seed, jnp.uint32))
    k_init, ka = jax.random.split(base)
    byz = _binom(k_init, jnp.where(active, sc.r_inner, 0.0),
                 jnp.full((G,), sc.byz_fraction))
    honest = jnp.where(active, sc.r_inner - byz, 0.0)
    kill = _targeted_kill(sc, ka, honest, active)
    obj_id = jnp.minimum(gidx // jnp.maximum(sc.n_chunks, 1),
                         st.max_objects - 1)
    chunks_alive = jax.ops.segment_sum(
        (active & ~kill).astype(jnp.float32), obj_id,
        num_segments=st.max_objects)
    obj_active = jnp.arange(st.max_objects) < sc.n_objects
    lost = (obj_active & (chunks_alive < sc.k_outer)).sum()
    return lost / jnp.maximum(sc.n_objects, 1)


@functools.lru_cache(maxsize=None)
def _targeted_batch(st: _Static):
    return jax.jit(jax.vmap(functools.partial(_targeted_single, st)))


def targeted_grid(cells, seeds=range(8)) -> np.ndarray:
    """Lost-object fraction under the greedy targeted attack (Fig. 6
    bottom), batched over cells × seeds: ``[n_cells, n_seeds]`` float."""
    seeds = list(seeds)
    flat = _product(cells, seeds)
    st = _Static(
        max_groups=max(int(s.n_objects * s.n_chunks) for s in flat),
        max_objects=max(int(s.n_objects) for s in flat), max_steps=1)
    out = _targeted_batch(st)(_stack(flat))
    return np.asarray(out).reshape(len(flat) // len(seeds), len(seeds))


# ------------------------------------------------------------- summarizing
def mean_ci(x: np.ndarray, axis: int = -1) -> tuple[np.ndarray, np.ndarray]:
    """Mean and 95% normal-approx confidence half-width over ``axis``
    (the seed axis of a grid result)."""
    x = np.asarray(x, np.float64)
    n = x.shape[axis]
    mean = x.mean(axis=axis)
    ci = 1.96 * x.std(axis=axis, ddof=1) / np.sqrt(n) if n > 1 else (
        np.zeros_like(mean))
    return mean, ci
