"""Batched JAX scenario engine for paper-scale durability sweeps.

``simulation.py`` is the numpy *reference* implementation: one
``(params, seed)`` point per call, a Python loop per time step. This module
is the production path: the full group state ``(honest, byz, cache_t,
alive)`` lives in batched arrays, every time step advances inside one jitted
``lax.scan``, and ``vmap`` runs a whole ``(parameter-grid × seeds ×
policies)`` sweep — e.g. all cells of Fig. 4 or Fig. 6 — as a single device
dispatch. Group counts, code parameters, churn rates, TTLs, and policy
selectors are all *traced* scalars, so heterogeneous cells (different
``n_objects``, ``n_chunks``, ``(K, R)``) share one compiled executable via
padding masks; only the padded maxima are compile-time constants.

Scenario diversity is a first-class axis. Each policy is a pure function
composed into the scan body and selected per batch element. The policy
*definitions* — ids, per-step probabilities, burst/refill/kill arithmetic —
live in ``repro.core.policies`` (shared verbatim with the protocol-level
simulator ``repro.core.protocol_sim``, which is cross-validated against
this engine); see that module's docstring for the full catalogue:

* churn: ``"iid"`` (paper §6.1), ``"regional"`` correlated bursts,
  ``"diurnal"`` time-of-day rate modulation, and ``"pareto"``
  heavy-tailed session lengths (protected-cohort mean-field here;
  real session draws in the protocol layer);
* adversary: ``"static"`` (Fig. 6), ``"adaptive"`` re-join (BFT-DSN
  style), ``"targeted"`` greedy kill (A.3 cost model, time-resolved),
  ``"eclipse"`` ring partition (mean-field), ``"collude"``
  withholding (wasted-pull traffic, closed-form), and the composed
  ``"eclipse_targeted"`` product;
* cache: the ``cache_ttl_hours`` knob (0 disables), identical to the
  reference semantics (repair.py docstring / Fig. 4), with churn-aware
  holder retirement (a copy goes cold when all its holders die);
* serving: ``read_rate`` Zipf-popular Get() requests per step, classified
  hit/miss/degraded/failed closed-form per object with a retrieval-hop
  histogram and per-region bandwidth contention against repair
  (``region_cap`` — policies.py "serving arithmetic").

Public API:

* ``make_scenario(**kw)`` / ``from_simparams(p)`` — build one scenario cell;
* ``run_grid(cells, seeds)`` — chunked batched dispatch over cells × seeds,
  returns a ``ScenarioResult`` of ``[n_cells, n_seeds]`` arrays;
* ``run_replicated_grid(cells, seeds)`` — Ceph-like baseline, same churn;
* ``trace_grid(cells, seeds)`` — Fig. 5 per-step honest-fragment traces;
* ``targeted_grid(cells, seeds)`` — Fig. 6-bottom static attack sweep;
* ``mean_ci(x)`` — per-cell mean and 95% CI over the seed axis.

Performance knobs
-----------------

The grid runners expose three throughput knobs (benchmarked by
``benchmarks/engine_speed.py``; numbers below are the 2-core CPU host the
repo is tuned on):

* ``sampler=`` — ``"exact"`` (reference ``jax.random.binomial``),
  ``"fast"`` (threefry uniforms + inverse-CDF/Gaussian hybrid, ~3×), or
  ``"arx"`` (counter-based ARX uniforms reusing the ``kernels/prf_select``
  PRF, no per-step key hashing, ~4× over ``fast``). See
  ``repro/core/samplers.py`` for the validated error budgets. Benchmarks
  default to ``"arx"``; the API default stays ``"exact"`` so ad-hoc calls
  are reference-faithful.
* ``chunk_size=`` — split the flat ``cells × seeds`` batch into fixed-size
  chunks dispatched sequentially through ONE compiled executable (the jit
  cache is keyed on the padded maxima + chunk shape). Keeps device memory
  bounded on paper-scale sweeps and stops
  recompiles from dominating when many same-shaped sweeps run in one
  process. ``None`` = single dispatch (PR 1 behavior). Chunking is
  bit-for-bit neutral: every element's randomness derives only from its
  own ``(scenario, seed)``.
* ``devices=`` — shard each chunk's batch axis over this many local JAX
  devices (e.g. multiple CPU host devices via
  ``--xla_force_host_platform_device_count`` / ``repro.config``, or real
  accelerators). The SAME traced function compiles either way: plain
  ``jit`` at 1 device, ``jit`` of a ``shard_map`` over a 1-D ``"batch"``
  mesh above it — one sharded executable from laptop to pod, no
  per-shape ``pmap`` re-tracing. ``None``/``1`` = no device axis.
  ``chunk_size`` is rounded up to a multiple of ``devices`` and uneven
  batches are padded inside the chunker (replicas of the last element,
  sliced off afterwards) — bit-for-bit identical results either way.

The scan body itself is tuned for CPU: per-cell constants (failure
probabilities, refill rates, key material, active masks, unit costs) are
hoisted out of the scan, each step derives all of its churn/attack/repair
stream keys from one fused ``Sampler.streams`` call, state stays float32
end-to-end, and the scan is unrolled (``unroll=2``) to amortize loop
overhead.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec

from repro.core import policies as P
from repro.core.samplers import SAMPLERS, Sampler
# version-compat shard_map (jax<0.6 experimental location, check_rep vs
# check_vma kwarg) — one shim, shared with the distributed substrate
from repro.distributed.compression import shard_map

# Policy ids re-exported from the shared definitions (repro.core.policies)
# so existing `scenarios.CHURN_*` / `scenarios.ADV_*` callers keep working.
HOURS_PER_YEAR = P.HOURS_PER_YEAR
CHURN_IID = P.CHURN_IID
CHURN_REGIONAL = P.CHURN_REGIONAL
CHURN_POLICIES = P.CHURN_POLICIES
CHURN_DIURNAL = P.CHURN_DIURNAL
CHURN_PARETO = P.CHURN_PARETO
ADV_STATIC = P.ADV_STATIC
ADV_ADAPTIVE = P.ADV_ADAPTIVE
ADV_TARGETED = P.ADV_TARGETED
ADV_ECLIPSE = P.ADV_ECLIPSE
ADV_COLLUDE = P.ADV_COLLUDE
ADV_ECLIPSE_TARGETED = P.ADV_ECLIPSE_TARGETED
ADVERSARY_POLICIES = P.ADVERSARY_POLICIES
N_REGIONS = P.N_REGIONS

_UNROLL = 2  # scan unroll factor (see "Performance knobs")


def _default_unroll(sampler: str) -> int:
    # unrolling doubles the traced body: worth ~2x runtime for the compact
    # fast/arx pipelines, but the exact rejection sampler's graph is huge
    # and compile-bound — keep it rolled
    return 1 if sampler == "exact" else _UNROLL


class Scenario(NamedTuple):
    """One sweep cell. Every leaf is a scalar (stacked to [B] when batched);
    all of them are traced, so cells with different values share one
    compiled executable."""

    n_objects: np.int32
    n_chunks: np.int32
    k_outer: np.float32
    k_inner: np.float32
    r_inner: np.float32
    n_nodes: np.float32
    byz_fraction: np.float32
    churn_per_year: np.float32
    cache_ttl_hours: np.float32
    step_hours: np.float32
    steps: np.int32
    churn_policy: np.int32
    adv_policy: np.int32
    burst_prob: np.float32
    burst_mult: np.float32
    adapt_boost: np.float32
    attack_frac: np.float32
    attack_step: np.int32
    eclipse_steps: np.int32
    frags_per_node: np.float32
    replication: np.float32
    read_rate: np.float32
    zipf_alpha: np.float32
    region_cap: np.float32
    cache_churn: np.int32
    seed: np.int32
    diurnal_amplitude: np.float32
    pareto_alpha: np.float32


class ScenarioResult(NamedTuple):
    """Grid-runner output; every leaf is ``[n_cells, n_seeds]`` (the trace
    leaf ``[n_cells, n_seeds, max_steps]``). ``protocol_sim.ProtocolResult``
    mirrors these fields one-to-one for cross-validation."""

    repair_traffic_units: jnp.ndarray  # object-size units (paper's unit)
    repairs: jnp.ndarray               # fragments regenerated
    cache_hits: jnp.ndarray            # warm-cache single-fragment repairs
    lost_objects: jnp.ndarray          # objects with < K_outer live chunks
    lost_fraction: jnp.ndarray         # lost_objects / n_objects
    final_honest_mean: jnp.ndarray     # mean honest frags over live groups
    honest_min: jnp.ndarray        # min honest seen in any live group
    members_max: jnp.ndarray       # max honest+byz seen in any group
    alive_frac_trace: jnp.ndarray  # [..., max_steps] live-group fraction
    # (per step; the grid runners prepend the [n_cells, n_seeds] axes)
    # --- serving workload (all zero when read_rate == 0) ---
    reads_issued: jnp.ndarray      # Get() requests issued over the run
    reads_hit: jnp.ndarray         # completed entirely from warm caches
    reads_miss: jnp.ndarray        # completed via fragment pulls + decode
    reads_degraded: jnp.ndarray    # completed past dead/eclipsed groups
    reads_failed: jnp.ndarray      # < K_outer chunks readable
    served_traffic_units: jnp.ndarray  # object units served to clients
    serve_hop_hist: jnp.ndarray    # [..., SERVE_HIST_BINS] hop histogram


def make_scenario(
    n_objects: int = 1000, n_chunks: int = 10, k_outer: int = 8,
    k_inner: int = 32, r_inner: int = 80, n_nodes: int = 100_000,
    byz_fraction: float = 0.0, churn_per_year: float = 4.0,
    cache_ttl_hours: float = 0.0, step_hours: float = 6.0,
    years: float = 1.0, steps: int | None = None,
    policy=None,
    churn_policy: int | str = CHURN_IID, adv_policy: int | str = ADV_STATIC,
    burst_prob: float = 0.05, burst_mult: float = 20.0,
    adapt_boost: float = 2.0, attack_frac: float = 0.0, attack_step: int = 0,
    eclipse_steps: int = 0, diurnal_amplitude: float = 0.6,
    pareto_alpha: float = 1.5, frags_per_node: int = 1, replication: int = 3,
    read_rate: float = 0.0, zipf_alpha: float = 1.1,
    region_cap: float = 0.0, cache_churn: bool = True,
    seed: int = 0,
) -> Scenario:
    """Build one sweep cell (all leaves traced — heterogeneous cells share
    one compiled executable).

    Deployment: ``n_objects`` stored objects of ``n_chunks`` chunks each
    (any ``k_outer`` recover an object), chunk groups of ``r_inner``
    members (any ``k_inner`` decode a chunk), on ``n_nodes`` peers of
    which ``byz_fraction`` follow the Fig. 6 Byzantine model.

    Dynamics: ``churn_per_year`` expected failures per node-year, advanced
    in ``step_hours``-wide steps for ``years`` (or an explicit ``steps``
    count, which wins); ``cache_ttl_hours`` enables the chunk cache
    (0 = off).

    Policies (shared definitions: ``repro.core.policies``): prefer the
    single ``policy=`` argument — a :class:`policies.PolicySpec` built
    from the combinators (``P.compose(P.eclipse(0.3), P.targeted_kill
    (0.25))``), a registered zoo name (``"iid_eclipse_targeted"``), or a
    plain policy name. It lowers through :func:`policies.resolve` to the
    same static ids + knob scalars, so compositions share the compiled
    executable with everything else. When given, ``policy`` sets
    ``churn_policy``/``adv_policy`` and the knob kwargs it carries
    (explicit knob kwargs it does *not* carry keep their values).

    .. deprecated:: PR 10
       The per-axis kwargs below remain supported and delegate through
       the same resolver (no behavior change), but new call sites should
       pass ``policy=``.

    ``churn_policy`` ``"iid"``/``"regional"``/``"diurnal"``/``"pareto"``
    (ids accepted) with ``burst_prob`` per-step burst probability,
    ``burst_mult`` rate multiplier, ``diurnal_amplitude`` rate-modulation
    depth, ``pareto_alpha`` session-tail index; ``adv_policy``
    ``"static"``/``"adaptive"``/``"targeted"``/``"eclipse"``/
    ``"collude"``/``"eclipse_targeted"`` with ``adapt_boost`` refill
    bias, ``attack_frac`` of ``n_nodes`` as kill budget at step
    ``attack_step`` (for the ``eclipse`` family: also the cut ring
    fraction, window ``[attack_step, attack_step + eclipse_steps)`` —
    the mean-field approximation of the protocol-level partition; the
    composed ``eclipse_targeted`` spends the same ``attack_frac`` on
    both), and ``frags_per_node`` cost amortization (A.3).
    ``replication`` sizes the Ceph-like baseline of
    :func:`run_replicated_grid`. ``seed`` is normally overridden by the
    grid runners' ``seeds`` axis.

    Serving workload (ROADMAP item 3; 0 = off): ``read_rate`` Get()
    requests per step over Zipf(``zipf_alpha``) object popularity, served
    closed-form inside the scan body; ``region_cap`` per-bandwidth-region
    per-step capacity in object units (serving and repair compete for it,
    stretching retrieval hops — :func:`policies.congestion_factor`).
    ``cache_churn=False`` restores the pre-serving optimistic cache model
    (cached copies survive their full TTL even when every holder has
    churned out) — kept only so the regression suite can demonstrate the
    over-credit; real sweeps should never disable it.

    Domain guard: ``r_inner, replication < 256`` (fast-sampler
    ``pow_int`` domain).
    """
    if policy is not None:
        low = P.resolve(policy)
        churn_policy, adv_policy = low.churn, low.adversary
        kn = low.knob_dict()
        burst_prob = kn.pop("burst_prob", burst_prob)
        burst_mult = kn.pop("burst_mult", burst_mult)
        adapt_boost = kn.pop("adapt_boost", adapt_boost)
        attack_frac = kn.pop("attack_frac", attack_frac)
        attack_step = kn.pop("attack_step", attack_step)
        eclipse_steps = kn.pop("eclipse_steps", eclipse_steps)
        diurnal_amplitude = kn.pop("diurnal_amplitude", diurnal_amplitude)
        pareto_alpha = kn.pop("pareto_alpha", pareto_alpha)
        if kn:  # a spec knob with no matching kwarg is a bug, not a no-op
            raise TypeError(f"unknown policy knobs: {sorted(kn)}")
    churn_policy = P.churn_policy_id(churn_policy)
    adv_policy = P.adv_policy_id(adv_policy)
    if r_inner >= 256 or replication >= 256:
        # the fast samplers compute (1-p)^n by 8-bit square-and-multiply
        # (samplers.pow_int) — beyond n=255 they would be silently wrong
        raise ValueError(
            f"r_inner={r_inner} / replication={replication} exceed the "
            "sampler domain (< 256); see repro/core/samplers.pow_int")
    if steps is None:
        steps = int(round(years * HOURS_PER_YEAR / step_hours))
    return Scenario(
        n_objects=np.int32(n_objects), n_chunks=np.int32(n_chunks),
        k_outer=np.float32(k_outer), k_inner=np.float32(k_inner),
        r_inner=np.float32(r_inner), n_nodes=np.float32(n_nodes),
        byz_fraction=np.float32(byz_fraction),
        churn_per_year=np.float32(churn_per_year),
        cache_ttl_hours=np.float32(cache_ttl_hours),
        step_hours=np.float32(step_hours), steps=np.int32(steps),
        churn_policy=np.int32(churn_policy), adv_policy=np.int32(adv_policy),
        burst_prob=np.float32(burst_prob), burst_mult=np.float32(burst_mult),
        adapt_boost=np.float32(adapt_boost),
        attack_frac=np.float32(attack_frac),
        attack_step=np.int32(attack_step),
        eclipse_steps=np.int32(eclipse_steps),
        frags_per_node=np.float32(frags_per_node),
        replication=np.float32(replication),
        read_rate=np.float32(read_rate), zipf_alpha=np.float32(zipf_alpha),
        region_cap=np.float32(region_cap),
        cache_churn=np.int32(bool(cache_churn)), seed=np.int32(seed),
        diurnal_amplitude=np.float32(diurnal_amplitude),
        pareto_alpha=np.float32(pareto_alpha),
    )


def from_simparams(p, **overrides) -> Scenario:
    """Build a scenario cell from a ``simulation.SimParams``."""
    kw = dict(
        n_objects=p.n_objects, n_chunks=p.n_chunks, k_outer=p.k_outer,
        k_inner=p.k_inner, r_inner=p.r_inner, n_nodes=p.n_nodes,
        byz_fraction=p.byz_fraction, churn_per_year=p.churn_per_year,
        cache_ttl_hours=p.cache_ttl_hours, step_hours=p.step_hours,
        years=p.years, seed=p.seed, churn_policy=p.churn_policy,
        diurnal_amplitude=p.diurnal_amplitude,
    )
    kw.update(overrides)
    return make_scenario(**kw)


# --------------------------------------------------------------- primitives
def _burst_draw(smp: Sampler, sc: Scenario, key):
    """Regional-burst coin for one step: (burst?, hit region index).

    Two scalar uniforms per element; the actual boosted thinning runs as a
    *second* binomial pass behind a ``lax.cond`` (see ``_burst_thin``), so
    i.i.d.-only batches never pay for it and the base churn draw keeps a
    scalar ``p`` (see ``samplers.binom_from_uniform``).
    """
    u = smp.uniform(key, (2,))
    return P.burst_from_uniforms(sc.churn_policy, sc.burst_prob, u[0], u[1])


def _targeted_kill(smp: Sampler, sc: Scenario, key, honest, alive):
    """Greedy cheapest-groups-first kill mask (A.3 cost model)."""
    cost = P.kill_cost(honest, sc.k_inner, sc.frags_per_node)
    cost = jnp.where(alive, cost, jnp.inf)
    # random tiebreak: equal-cost groups are indistinguishable behind the
    # outer code's opacity (same argument as targeted_attack_vault)
    tie = smp.uniform(key, cost.shape) * 1e-3
    order = jnp.argsort(cost + tie)
    csum = jnp.cumsum(cost[order])
    budget = sc.attack_frac * sc.n_nodes
    kill_sorted = csum <= budget
    return jnp.zeros_like(kill_sorted).at[order].set(kill_sorted)


# ------------------------------------------------------------- vault engine
class _Static(NamedTuple):
    max_groups: int
    max_objects: int
    max_steps: int


class _Inv(NamedTuple):
    """Per-element scan invariants, hoisted out of the step body."""

    base: Any              # sampler key carrier
    active: jnp.ndarray    # [G] bool: group is real, not padding
    p_fail: jnp.ndarray    # i.i.d. per-step failure probability
    refill_p: jnp.ndarray  # byzantine refill probability during repair
    frag_units: jnp.ndarray
    chunk_units: jnp.ndarray
    n_groups: jnp.ndarray  # float active-group count (alive-frac denom)


def _vault_init(st: _Static, smp: Sampler, sc: Scenario):
    """Per-element invariants + initial state (vmapped over the batch)."""
    G = st.max_groups
    gidx = jnp.arange(G, dtype=jnp.int32)
    active = gidx < sc.n_objects * sc.n_chunks
    base = smp.base(sc.seed)
    inv = _Inv(
        base=base,
        active=active,
        # pareto churn swaps in the protected-cohort mean-field hazard
        # (policies.pareto_p_fail, abstraction leak #5); every other
        # policy gets the plain i.i.d. probability value-identically
        p_fail=P.pareto_p_fail(
            sc.churn_policy, sc.churn_per_year, sc.pareto_alpha,
            sc.step_hours, P.p_fail_step(sc.churn_per_year, sc.step_hours)),
        refill_p=P.refill_byz_probability(
            sc.adv_policy, sc.byz_fraction, sc.adapt_boost),
        frag_units=1.0 / (sc.k_outer * sc.k_inner),
        chunk_units=1.0 / sc.k_outer,
        n_groups=jnp.maximum(sc.n_objects * sc.n_chunks, 1).astype(
            jnp.float32),
    )
    (k_init,) = smp.streams(smp.fold(base, 0), 1)
    byz0 = smp.binom(k_init, jnp.where(active, sc.r_inner, 0.0),
                     sc.byz_fraction)
    honest0 = jnp.where(active, sc.r_inner - byz0, 0.0)
    alive0 = active & (honest0 >= sc.k_inner)
    cache0 = jnp.zeros(G)  # client seeds caches at store time (t=0)
    # cached-copy holder count: the storing client seeds every group member
    # (vault._store_chunk caches at all r_inner holders when the TTL is on)
    cache_h0 = jnp.where(active & (sc.cache_ttl_hours > 0.0),
                         sc.r_inner, 0.0)
    zero = jnp.zeros(())
    state = (honest0, byz0, alive0, cache0, cache_h0,
             0.0, 0.0, 0.0, jnp.inf, 0.0,
             # serving accumulators: issued/hit/miss/degraded/failed reads,
             # served object units, retrieval-hop histogram
             zero, zero, zero, zero, zero, zero,
             jnp.zeros(P.SERVE_HIST_BINS))
    return inv, state


def _vault_churn(st: _Static, smp: Sampler, sc: Scenario, inv: _Inv,
                 state, t):
    """Per-element churn half-step: thin members with the *scalar* i.i.d.
    probability, return burst coordinates + repair/attack/burst keys."""
    kt = smp.fold(inv.base, t + 1)
    kc, kb, kp, kr, ka, kxh, kxb = smp.streams(kt, 7)
    honest, byz = state[0], state[1]
    # diurnal churn recomputes this step's probability from the modulated
    # rate; every other policy passes inv.p_fail through value-identically
    p_fail = P.diurnal_p_fail(sc.churn_policy, sc.churn_per_year,
                              sc.diurnal_amplitude, t, sc.step_hours,
                              inv.p_fail)
    # adaptive adversary: byzantine members never leave voluntarily
    p_fail_b = P.byz_churn_probability(sc.adv_policy, p_fail)
    h = honest - smp.binom(kc, honest, p_fail)
    b = byz - smp.binom(kb, byz, p_fail_b)
    burst, region = _burst_draw(smp, sc, kp)
    return h, b, burst, region, (kxh, kxb), kr, ka


def _burst_thin(st: _Static, smp: Sampler, sc: Scenario, inv: _Inv,
                h, b, burst, region, kx):
    """Per-element regional-burst second thinning (traced inside a cond:
    only executed on steps where some element actually bursts)."""
    gidx = jnp.arange(st.max_groups, dtype=jnp.int32)
    p_extra = P.burst_extra_probability(inv.p_fail, sc.burst_mult)
    hit = burst & (P.group_domain(gidx) == region)
    dh = smp.binom(kx[0], h, p_extra)
    db = smp.binom(kx[1], b,
                   P.byz_churn_probability(sc.adv_policy, p_extra))
    return h - jnp.where(hit, dh, 0.0), b - jnp.where(hit, db, 0.0)


def _vault_attack(smp: Sampler, sc: Scenario, h, alive, ka):
    """Per-element targeted greedy kill (only traced inside the cond).
    Family predicate: fires for ``targeted`` and the composed
    ``eclipse_targeted`` product alike."""
    attack = P.targeted_flag(sc.adv_policy)
    kill = _targeted_kill(smp, sc, ka, h, alive)
    return jnp.where(attack & kill, jnp.minimum(h, sc.k_inner - 1.0), h)


def _vault_repair(st: _Static, smp: Sampler, with_cache: bool, sc: Scenario,
                  inv: _Inv, state, h, b, kr, t):
    """Per-element repair + traffic half-step.

    Compiled twice — ``with_cache`` True (per-element TTL blend, holder
    churn on the cached copies) and False (all TTLs zero: no warm/miss
    bookkeeping at all) — and selected by a batch-level ``lax.cond``, so
    cache-free sweeps skip the extra [G]-wide selects and reductions
    entirely.

    Returns the repair part of the state plus the post-repair warm-cache
    mask and this step's repair traffic, both consumed by the serving
    stage (:func:`_vault_serve`).
    """
    (_, _, alive, cache_t, cache_h,
     traffic, repairs, hits, hmin, mmax) = state[:10]
    now = (t + 1.0) * sc.step_hours

    a = alive & (h >= sc.k_inner)  # decode impossible => absorbing
    deficit = jnp.maximum(jnp.where(a, sc.r_inner - (h + b), 0.0), 0.0)
    # eclipse mean-field (policies.ADV_ECLIPSE): groups inside the cut ring
    # segment get no repair — no refills, traffic, or cache warming — while
    # the partition window is open; churn keeps thinning them meanwhile.
    # One select per step; identity (all-False mask) for other policies.
    gidx_e = jnp.arange(st.max_groups, dtype=jnp.int32)
    ecl = (P.eclipse_active(sc.adv_policy, t, sc.attack_step,
                            sc.eclipse_steps)
           & P.eclipse_groups(gidx_e, sc.attack_frac, inv.n_groups))
    deficit = jnp.where(ecl, 0.0, deficit)
    # collusion withholding (policies.ADV_COLLUDE): every byzantine member
    # of a repairing group serves one corrupt row per decode gather that
    # is pulled, integrity-checked, and discarded — wasted transfers hit
    # the traffic lane only (b here is the pre-refill byzantine count the
    # gather actually sees). Charged as a separate additive term (exactly
    # zero for other policies) so the pre-existing traffic expressions
    # keep their fp summation order bit-identically.
    wasted_pulls = jnp.where(deficit > 0.0,
                             P.collusion_extra_pulls(sc.adv_policy, b), 0.0)
    new_b = smp.binom(kr, deficit, inv.refill_p)
    h = h + (deficit - new_b)
    b = b + new_b

    t_plain = (deficit.sum() * sc.k_inner * inv.frag_units
               + wasted_pulls.sum() * inv.frag_units)
    if with_cache:
        has_cache = sc.cache_ttl_hours > 0.0
        # churn-aware cache: holders of cached copies die like any other
        # member, so a copy is warm only while ≥1 holder survives AND its
        # TTL holds. cache_churn=0 freezes the holder count (the old
        # optimistic model, kept for the leak-regression test only).
        # Key material: a second fold at a disjoint counter (t+1+2^20), so
        # the seven original per-step streams stay bit-identical; the arx
        # fold is collision-free here for any horizon below 2^20 steps.
        (kcd,) = smp.streams(smp.fold(inv.base, t + 1 + (1 << 20)), 1)
        dead_h = smp.binom(kcd, cache_h, inv.p_fail)
        cache_h = jnp.where(sc.cache_churn > 0,
                            jnp.maximum(cache_h - dead_h, 0.0), cache_h)
        warm = (((now - cache_t) <= sc.cache_ttl_hours)
                & (cache_h >= 1.0))
        hit_frags = jnp.where(warm, deficit, jnp.maximum(deficit - 1.0, 0.0))
        miss_pulls = jnp.where(~warm & (deficit > 0), 1.0, 0.0)
        # colluder waste only on the miss path (warm repairs pull the
        # cached chunk from an honest holder — no group gather)
        t_cached = (hit_frags.sum() * inv.frag_units
                    + miss_pulls.sum() * inv.chunk_units
                    + (miss_pulls * wasted_pulls).sum() * inv.frag_units)
        refresh = has_cache & (miss_pulls > 0)
        new_cache = jnp.where(refresh, now, cache_t)
        # a miss-path repairer re-caches the decoded chunk: one new holder
        new_cache_h = jnp.where(refresh, 1.0, cache_h)
        traffic_add = jnp.where(has_cache, t_cached, t_plain)
        hits_add = jnp.where(has_cache, hit_frags.sum(), 0.0)
        warm_out = has_cache & (warm | refresh)
    else:
        new_cache = cache_t
        new_cache_h = cache_h
        traffic_add = t_plain
        hits_add = 0.0
        warm_out = jnp.zeros_like(a)

    new_state = (
        h, b, a, new_cache, new_cache_h,
        traffic + traffic_add,
        repairs + deficit.sum(),
        hits + hits_add,
        jnp.minimum(hmin, jnp.where(a, h, jnp.inf).min()),
        jnp.maximum(mmax, jnp.where(inv.active, h + b, 0.0).max()),
    )
    alive_frac = a.sum() / inv.n_groups
    return new_state, warm_out, traffic_add, alive_frac


def _vault_serve(st: _Static, sc: Scenario, inv: _Inv, rep_state, warm,
                 traffic_add, srv, t):
    """Per-element closed-form serving half-step (traced inside a cond:
    only executed when some batch element has ``read_rate > 0``).

    ``read_rate`` Get() requests are spread over objects by Zipf(α)
    popularity and classified per object from this step's group state
    (disjoint buckets, priority failed > degraded > hit > miss — the same
    rule the protocol-level ``_serve_tick`` applies per sampled request).
    Completed reads retrieve ``K_outer`` chunks = 1 object unit. Retrieval
    hops land in a histogram after congestion stretch: this step's repair
    + serving units spread over ``N_BW_REGIONS`` bandwidth domains against
    ``region_cap`` (:func:`policies.congestion_factor`).
    """
    issued, r_hit, r_miss, r_degr, r_fail, served, hist = srv
    a = rep_state[2]
    gidx = jnp.arange(st.max_groups, dtype=jnp.int32)
    ecl = (P.eclipse_active(sc.adv_policy, t, sc.attack_step,
                            sc.eclipse_steps)
           & P.eclipse_groups(gidx, sc.attack_frac, inv.n_groups))
    readable = a & ~ecl        # eclipsed groups hold data but can't serve
    warm_r = readable & warm

    obj_id = jnp.minimum(gidx // jnp.maximum(sc.n_chunks, 1),
                         st.max_objects - 1)
    n_read = jax.ops.segment_sum(readable.astype(jnp.float32), obj_id,
                                 num_segments=st.max_objects)
    n_warm = jax.ops.segment_sum(warm_r.astype(jnp.float32), obj_id,
                                 num_segments=st.max_objects)
    oidx = jnp.arange(st.max_objects, dtype=jnp.int32)
    obj_active = oidx < sc.n_objects
    load = sc.read_rate * P.zipf_weights(oidx, sc.zipf_alpha, sc.n_objects)

    failed_o = obj_active & (n_read < sc.k_outer)
    degr_o = obj_active & ~failed_o & (n_read < sc.n_chunks)
    hit_o = (obj_active & ~failed_o & ~degr_o
             & (n_warm >= sc.k_outer))  # all K_outer pulls can be cache pulls
    miss_o = obj_active & ~failed_o & ~degr_o & ~hit_o

    n_fail = (load * failed_o).sum()
    n_degr = (load * degr_o).sum()
    n_hit = (load * hit_o).sum()
    n_miss = (load * miss_o).sum()
    served_add = n_hit + n_miss + n_degr  # completed reads × 1 object unit

    # serving and repair compete for the same per-region links
    per_region = (traffic_add + served_add) / P.N_BW_REGIONS
    factor = P.congestion_factor(per_region, sc.region_cap)
    for count, hops in ((n_hit, P.SERVE_HOPS_HIT),
                        (n_miss, P.SERVE_HOPS_MISS),
                        (n_degr, P.SERVE_HOPS_MISS
                         + P.SERVE_HOPS_DEGRADED_EXTRA)):
        hbin = P.effective_hops(hops, factor).astype(jnp.int32)
        hist = hist.at[hbin].add(count)

    # weights sum to 1 over active objects, so the four buckets conserve
    # sc.read_rate exactly (tests/test_serving_properties.py pins this)
    return (issued + sc.read_rate, r_hit + n_hit, r_miss + n_miss,
            r_degr + n_degr, r_fail + n_fail, served + served_add, hist)


def _vault_finalize(st: _Static, sc: Scenario, state) -> ScenarioResult:
    gidx = jnp.arange(st.max_groups, dtype=jnp.int32)
    (honest, _, alive, _, _, traffic, repairs, hits, hmin, mmax,
     issued, r_hit, r_miss, r_degr, r_fail, served, hist) = state
    obj_id = jnp.minimum(gidx // jnp.maximum(sc.n_chunks, 1),
                         st.max_objects - 1)
    chunks_alive = jax.ops.segment_sum(
        alive.astype(jnp.float32), obj_id, num_segments=st.max_objects)
    obj_active = jnp.arange(st.max_objects) < sc.n_objects
    lost = (obj_active & (chunks_alive < sc.k_outer)).sum()
    n_alive = alive.sum()
    fhm = jnp.where(n_alive > 0,
                    (honest * alive).sum() / jnp.maximum(n_alive, 1.0), 0.0)
    return ScenarioResult(
        repair_traffic_units=traffic, repairs=repairs, cache_hits=hits,
        lost_objects=lost.astype(jnp.int32),
        lost_fraction=lost / jnp.maximum(sc.n_objects, 1),
        final_honest_mean=fhm,
        honest_min=jnp.where(jnp.isfinite(hmin), hmin, 0.0),
        members_max=mmax, alive_frac_trace=jnp.zeros(()),  # filled by caller
        reads_issued=issued, reads_hit=r_hit, reads_miss=r_miss,
        reads_degraded=r_degr, reads_failed=r_fail,
        served_traffic_units=served, serve_hop_hist=hist,
    )


def _where_on(on, new, old):
    """Select per batch element, broadcasting [B] over state leaves."""
    mask = on.reshape(on.shape + (1,) * (new.ndim - on.ndim))
    return jnp.where(mask, new, old)


_BATCH_AXIS = "batch"  # the 1-D mesh axis the grid batch shards over


def _ndev(devices: int | None) -> int:
    """Validate and normalize the ``devices=`` knob (before any mesh or
    compiled runner is built, so the error is actionable)."""
    ndev = int(devices or 1)
    if ndev > 1:
        avail = jax.local_device_count()
        if ndev > avail:
            raise ValueError(
                f"devices={ndev} but only {avail} local JAX device(s); "
                "set --xla_force_host_platform_device_count or lower it")
    return ndev


def _compile_runner(run, devices: int = 1):
    """Compile a batched ``run`` into one executable for any topology.

    This is the single sharded-runner helper behind all four grid
    factories (``_vault_batch`` / ``_repl_batch`` / ``_trace_batch`` /
    ``_targeted_batch``). ``devices <= 1`` is a plain ``jit``. Otherwise
    the SAME traced ``run`` is wrapped in ``shard_map`` over a 1-D
    ``Mesh`` of the first ``devices`` local devices: every input leaf's
    leading batch axis splits across the mesh (``PartitionSpec``
    prefixes broadcast over the pytree), outputs concatenate back along
    it. No per-shape ``pmap`` re-trace, no host-side
    ``[devices, B/devices]`` reshape.

    Bit-exactness: the per-element math never crosses batch lanes (no
    collectives anywhere in the scan body), so shards compute exactly
    what the single-device executable computes. The only semantic
    difference is that batch-global ``.any()`` cond predicates become
    per-shard — and every such cond selects between branches that are
    arithmetically identical by construction (the conds exist purely to
    skip work; see ``_vault_repair``'s docstring). Locked down by
    ``scripts/smoke_devices.py`` and the subprocess tests in
    ``tests/test_scenarios.py`` / ``tests/test_samplers.py``.

    Inputs are deliberately NOT donated (``donate_argnums``). Donation +
    the persistent compilation cache mis-executes on replay: a freshly
    compiled CPU executable refuses the aliasing ("Some donated buffers
    were not usable" — int32 scenario leaves can't alias float outputs)
    and runs correctly, but the *deserialized* cache entry honors the
    requested input→output aliases, so the donated input buffer is freed
    while live outputs still point into it and the next executable to
    allocate scribbles over the results. Reproduced deterministically:
    warm-cache process running two runners corrupts the first runner's
    outputs (random fields each run); identical process without donation
    is bit-exact. Donation only ever bought flat memory on chunked
    sweeps — never correctness or measured speed on CPU — so it loses to
    the cache. ``tests/test_scenarios.py::
    test_warm_cache_two_runners_bitexact`` locks the regression down.
    """
    if devices <= 1:
        return jax.jit(run)
    mesh = Mesh(np.asarray(jax.devices()[:devices]), (_BATCH_AXIS,))
    sharded = shard_map(run, mesh=mesh,
                        in_specs=(PartitionSpec(_BATCH_AXIS),),
                        out_specs=PartitionSpec(_BATCH_AXIS),
                        check_vma=False)
    return jax.jit(sharded)


@functools.lru_cache(maxsize=None)
def _vault_batch(st: _Static, sampler: str, unroll: int = _UNROLL,
                 devices: int = 1):
    """Compile the batched engine: one lax.scan over time whose body is
    vmapped over the batch. (scan-of-vmap, not vmap-of-scan, so the
    targeted-attack sort can sit behind a real lax.cond and only execute
    on actual attack steps instead of being select-ed every step.)

    The cache key is ``(padded maxima, sampler, unroll, devices)``; jit's
    own executable cache then keys on the batch shape, so fixed-size
    chunked dispatch reuses one compiled executable for every chunk.
    ``devices > 1`` shards the batch axis over a 1-D mesh — see
    :func:`_compile_runner`.
    """
    smp = SAMPLERS[sampler]
    churn = jax.vmap(functools.partial(_vault_churn, st, smp),
                     in_axes=(0, 0, 0, None))
    burst_thin = jax.vmap(functools.partial(_burst_thin, st, smp))
    attack = jax.vmap(functools.partial(_vault_attack, smp))
    repair_cache = jax.vmap(functools.partial(_vault_repair, st, smp, True),
                            in_axes=(0, 0, 0, 0, 0, 0, None))
    repair_plain = jax.vmap(functools.partial(_vault_repair, st, smp, False),
                            in_axes=(0, 0, 0, 0, 0, 0, None))
    serve = jax.vmap(functools.partial(_vault_serve, st),
                     in_axes=(0, 0, 0, 0, 0, 0, None))

    def run(scb: Scenario):
        inv, init = jax.vmap(functools.partial(_vault_init, st, smp))(scb)
        cache_any = (scb.cache_ttl_hours > 0.0).any()
        serve_any = (scb.read_rate > 0.0).any()

        def body(state, t):
            h, b, burst, region, kx, kr, ka = churn(scb, inv, state, t)
            h, b = jax.lax.cond(
                burst.any(),
                lambda args: burst_thin(scb, inv, *args),
                lambda args: (args[0], args[1]),
                (h, b, burst, region, kx))
            hit_now = P.targeted_flag(scb.adv_policy) & (t == scb.attack_step)
            h = jax.lax.cond(
                hit_now.any(),
                lambda args: jnp.where(hit_now[:, None],
                                       attack(scb, *args), args[0]),
                lambda args: args[0], (h, state[2], ka))
            rep_state, warm, traffic_add, alive_frac = jax.lax.cond(
                cache_any,
                lambda args: repair_cache(*args),
                lambda args: repair_plain(*args),
                (scb, inv, state, h, b, kr, t))
            srv = jax.lax.cond(
                serve_any,
                lambda args: serve(*args),
                lambda args: args[5],
                (scb, inv, rep_state, warm, traffic_add, state[10:], t))
            on = t < scb.steps
            state = tuple(_where_on(on, n, o)
                          for n, o in zip(rep_state + srv, state))
            return state, jnp.where(on, alive_frac,
                                    state[2].sum(-1) / inv.n_groups)

        state, alive_tr = jax.lax.scan(body, init, jnp.arange(st.max_steps),
                                       unroll=unroll)
        res = jax.vmap(functools.partial(_vault_finalize, st))(scb, state)
        return res._replace(alive_frac_trace=alive_tr.T)

    return _compile_runner(run, devices)


def _stack(cells: list[Scenario]) -> Scenario:
    return jax.tree_util.tree_map(lambda *xs: np.stack(xs), *cells)


def _product(cells, seeds) -> list[Scenario]:
    out = []
    for cell in cells:
        if isinstance(cell, dict):
            cell = make_scenario(**cell)
        for s in seeds:
            out.append(cell._replace(seed=np.int32(s)))
    return out


def _reshape(res, n_cells: int, n_seeds: int):
    return type(res)(*(np.asarray(x).reshape(n_cells, n_seeds, *x.shape[1:])
                       for x in res))


def _dispatch(runner, batch):
    """Invoke a compiled runner (single indirection point for all four
    grid runners — kept so chunked and single dispatch share one call
    site). Donation was removed here (see :func:`_compile_runner`), so
    no warning filtering is needed anymore; pytest.ini still escalates
    any donation warning to an error to keep it that way."""
    return runner(batch)


def _run_chunked(flat: list[Scenario], runner, chunk_size: int | None,
                 devices: int | None = None):
    """Dispatch ``flat`` elements through ``runner`` in fixed-size chunks.

    ``chunk_size=None`` keeps the single-dispatch fast path. Otherwise the
    element list is padded (with replicas of the last element, sliced off
    afterwards) to a multiple of ``chunk_size`` and dispatched chunk by
    chunk — every chunk has identical shapes, so jit compiles exactly once.
    ``runner`` is already topology-bound (see :func:`_compile_runner`);
    with ``devices > 1`` the chunk size is rounded up to a multiple of the
    device count so ``shard_map`` can split the batch axis evenly — uneven
    batches are handled entirely by the same padding path. Chunking and
    sharding are bit-for-bit neutral: element randomness depends only on
    the element itself, never on its batch position.
    """
    B = len(flat)
    ndev = int(devices or 1)
    if ndev > 1:
        chunk_size = min(chunk_size or B, B)
        chunk_size = -(-chunk_size // ndev) * ndev
    elif not chunk_size or chunk_size >= B:
        return _dispatch(runner, _stack(flat))
    pad = (-B) % chunk_size
    padded = list(flat) + [flat[-1]] * pad
    outs = []
    for i in range(0, len(padded), chunk_size):
        out = _dispatch(runner, _stack(padded[i:i + chunk_size]))
        outs.append(jax.tree_util.tree_map(np.asarray, out))
    cat = jax.tree_util.tree_map(
        lambda *xs: np.concatenate(xs, axis=0), *outs)
    return jax.tree_util.tree_map(lambda x: x[:B], cat)


def run_grid(cells, seeds=range(8), sampler: str = "exact",
             chunk_size: int | None = None, devices: int | None = None,
             unroll: int | None = None) -> ScenarioResult:
    """Run cells × seeds vault scenarios as chunked batched dispatches.

    ``cells``: scenarios or kwargs-dicts for :func:`make_scenario`.
    ``sampler`` / ``chunk_size`` / ``devices``: see "Performance knobs" in
    the module docstring. Returns a :class:`ScenarioResult` whose leaves
    have shape ``[n_cells, n_seeds]`` (the trace leaf
    ``[n_cells, n_seeds, max_steps]``).
    """
    seeds = list(seeds)
    unroll = _default_unroll(sampler) if unroll is None else unroll
    ndev = _ndev(devices)
    flat = _product(cells, seeds)
    st = _Static(
        max_groups=max(int(s.n_objects * s.n_chunks) for s in flat),
        max_objects=max(int(s.n_objects) for s in flat),
        max_steps=max(int(s.steps) for s in flat),
    )
    res = _run_chunked(flat, _vault_batch(st, sampler, unroll, ndev),
                       chunk_size, ndev)
    return _reshape(res, len(flat) // len(seeds), len(seeds))


# ------------------------------------------------------ replicated baseline
def _repl_init(st: _Static, smp: Sampler, sc: Scenario):
    O = st.max_objects
    oidx = jnp.arange(O, dtype=jnp.int32)
    active = oidx < sc.n_objects
    base = smp.base(sc.seed + 1)
    (k_init,) = smp.streams(smp.fold(base, 0), 1)
    bad0 = smp.binom(k_init, jnp.where(active, sc.replication, 0.0),
                     sc.byz_fraction)
    good0 = jnp.where(active, sc.replication - bad0, 0.0)
    alive0 = active & (good0 >= 1.0)
    inv = (base, active, P.p_fail_step(sc.churn_per_year, sc.step_hours))
    return inv, (good0, bad0, alive0, 0.0, 0.0)


def _repl_churn(st: _Static, smp: Sampler, sc: Scenario, inv, carry, t):
    base, _, p_fail = inv
    good, bad = carry[0], carry[1]
    kt = smp.fold(base, t + 1)
    kg, kb, kp, kr, kxg, kxb = smp.streams(kt, 6)
    g = good - smp.binom(kg, good, p_fail)
    b = bad - smp.binom(kb, bad, p_fail)
    burst, region = _burst_draw(smp, sc, kp)
    return g, b, burst, region, (kxg, kxb), kr


def _repl_burst_thin(st: _Static, smp: Sampler, sc: Scenario, inv,
                     g, b, burst, region, kx):
    oidx = jnp.arange(st.max_objects, dtype=jnp.int32)
    p_extra = P.burst_extra_probability(inv[2], sc.burst_mult)
    hit = burst & (P.group_domain(oidx) == region)
    dg = smp.binom(kx[0], g, p_extra)
    db = smp.binom(kx[1], b, p_extra)
    return g - jnp.where(hit, dg, 0.0), b - jnp.where(hit, db, 0.0)


def _repl_repair(st: _Static, smp: Sampler, sc: Scenario, inv, carry,
                 g, b, kr, t):
    _, _, alive, traffic, repairs = carry
    on = t < sc.steps
    a = alive & (g >= 1.0)  # no good replica left => object gone
    deficit = jnp.maximum(jnp.where(a, sc.replication - (g + b), 0.0), 0.0)
    # repair copies an unverifiable replica: good iff source good AND
    # the new holder is honest (contagious decay, Fig. 6); the source mix
    # is per-object, so this is the one genuinely per-lane ``p`` draw
    remaining = jnp.maximum(g + b, 1.0)
    p_good = jnp.where(a, g / remaining, 0.0) * (1.0 - sc.byz_fraction)
    new_good = smp.binom(kr, deficit, jnp.clip(p_good, 0.0, 1.0))
    g = g + new_good
    b = b + (deficit - new_good)
    pick = lambda new, old: jnp.where(on, new, old)
    carry = (pick(g, carry[0]), pick(b, carry[1]), jnp.where(on, a, alive),
             pick(traffic + deficit.sum(), traffic),
             pick(repairs + deficit.sum(), repairs))
    alive_frac = carry[2].sum() / jnp.maximum(sc.n_objects, 1)
    return carry, alive_frac


def _repl_finalize(st: _Static, sc: Scenario, inv, carry) -> ScenarioResult:
    good, bad, alive, traffic, repairs = carry
    active = inv[1]
    lost = (active & ~alive).sum()
    n_alive = alive.sum()
    fhm = jnp.where(n_alive > 0,
                    (good * alive).sum() / jnp.maximum(n_alive, 1.0), 0.0)
    alive_min = jnp.where(alive, good, jnp.inf).min()
    zero = jnp.zeros(())
    return ScenarioResult(
        repair_traffic_units=traffic, repairs=repairs,
        cache_hits=zero, lost_objects=lost.astype(jnp.int32),
        lost_fraction=lost / jnp.maximum(sc.n_objects, 1),
        final_honest_mean=fhm,
        honest_min=jnp.where(jnp.isfinite(alive_min), alive_min, 0.0),
        members_max=(good + bad).max(), alive_frac_trace=zero,
        # the replicated baseline has no serving layer
        reads_issued=zero, reads_hit=zero, reads_miss=zero,
        reads_degraded=zero, reads_failed=zero, served_traffic_units=zero,
        serve_hop_hist=jnp.zeros(P.SERVE_HIST_BINS),
    )


@functools.lru_cache(maxsize=None)
def _repl_batch(st: _Static, sampler: str, unroll: int = _UNROLL,
                devices: int = 1):
    """Scan-of-vmap replicated baseline (same scaffolding as the vault
    engine, so the regional-burst thinning sits behind a real cond)."""
    smp = SAMPLERS[sampler]
    churn = jax.vmap(functools.partial(_repl_churn, st, smp),
                     in_axes=(0, 0, 0, None))
    burst_thin = jax.vmap(functools.partial(_repl_burst_thin, st, smp))
    repair = jax.vmap(functools.partial(_repl_repair, st, smp),
                      in_axes=(0, 0, 0, 0, 0, 0, None))

    def run(scb: Scenario):
        inv, init = jax.vmap(functools.partial(_repl_init, st, smp))(scb)

        def body(carry, t):
            g, b, burst, region, kx, kr = churn(scb, inv, carry, t)
            g, b = jax.lax.cond(
                burst.any(),
                lambda args: burst_thin(scb, inv, *args),
                lambda args: (args[0], args[1]),
                (g, b, burst, region, kx))
            return repair(scb, inv, carry, g, b, kr, t)

        carry, alive_tr = jax.lax.scan(body, init, jnp.arange(st.max_steps),
                                       unroll=unroll)
        res = jax.vmap(functools.partial(_repl_finalize, st))(scb, inv, carry)
        return res._replace(alive_frac_trace=alive_tr.T)

    return _compile_runner(run, devices)


def run_replicated_grid(cells, seeds=range(8), sampler: str = "exact",
                        chunk_size: int | None = None,
                        devices: int | None = None) -> ScenarioResult:
    """Ceph-like replicated baseline, same grid semantics as run_grid."""
    seeds = list(seeds)
    ndev = _ndev(devices)
    flat = _product(cells, seeds)
    st = _Static(max_groups=1,
                 max_objects=max(int(s.n_objects) for s in flat),
                 max_steps=max(int(s.steps) for s in flat))
    unroll = _default_unroll(sampler)
    res = _run_chunked(flat, _repl_batch(st, sampler, unroll, ndev),
                       chunk_size, ndev)
    return _reshape(res, len(flat) // len(seeds), len(seeds))


# --------------------------------------------------------- Fig 5 trace grid
def _trace_single(max_steps: int, smp: Sampler, repair_interval_hours,
                  sc: Scenario):
    base = smp.base(sc.seed)
    p_fail = P.p_fail_step(sc.churn_per_year, sc.step_hours)
    (k_init,) = smp.streams(smp.fold(base, 0), 1)
    byz0 = smp.binom(k_init, sc.r_inner, sc.byz_fraction)
    honest0 = sc.r_inner - byz0

    def step(carry, t):
        honest, byz, since, absorbed = carry
        kt = smp.fold(base, t + 1)
        kh, kb, kr = smp.streams(kt, 3)
        h = honest - smp.binom(kh, honest, p_fail)
        b = byz - smp.binom(kb, byz, p_fail)
        absorbed_n = absorbed | (h < sc.k_inner)
        since_n = since + sc.step_hours
        do_rep = ~absorbed_n & (since_n >= repair_interval_hours)
        deficit = jnp.maximum(sc.r_inner - (h + b), 0.0)
        nb = smp.binom(kr, deficit, sc.byz_fraction)
        h = jnp.where(do_rep, h + deficit - nb, h)
        b = jnp.where(do_rep, b + nb, b)
        since_n = jnp.where(do_rep, 0.0, since_n)
        # absorbed groups freeze (numpy reference stops simulating them);
        # so do cells whose own horizon (sc.steps) has passed in a padded
        # heterogeneous batch
        frozen = absorbed | (t >= sc.steps)
        pick = lambda new, old: jnp.where(frozen, old, new)
        carry = (pick(h, honest), pick(b, byz), pick(since_n, since),
                 jnp.where(t >= sc.steps, absorbed, absorbed_n))
        return carry, carry[0]

    init = (honest0, byz0, 0.0, jnp.zeros((), bool))
    _, trace = jax.lax.scan(step, init, jnp.arange(max_steps),
                            unroll=_default_unroll(smp.name))
    return trace


@functools.lru_cache(maxsize=None)
def _trace_batch(max_steps: int, sampler: str, devices: int = 1):
    smp = SAMPLERS[sampler]
    vrun = jax.vmap(functools.partial(_trace_single, max_steps, smp),
                    in_axes=(0, 0))

    def run(batch):
        return vrun(batch[0], batch[1])

    return _compile_runner(run, devices)


def trace_grid(cells, seeds=range(8), repair_interval_hours: float = 24.0,
               sampler: str = "exact", chunk_size: int | None = None,
               devices: int | None = None) -> np.ndarray:
    """Honest-fragment traces of single chunk groups (Fig. 5), batched over
    cells × seeds. Returns ``[n_cells, n_seeds, max_steps]`` int64; cells
    with a shorter horizon than the padded maximum hold their last value
    for the remaining steps."""
    seeds = list(seeds)
    ndev = _ndev(devices)
    flat = _product(cells, seeds)
    max_steps = max(int(s.steps) for s in flat)
    runner = _trace_batch(max_steps, sampler, ndev)
    # _run_chunked stacks element lists as pytrees; pair each scenario with
    # its repair interval so the same chunking path applies.
    interval = np.float32(repair_interval_hours)
    paired = [(interval, s) for s in flat]
    out = _run_chunked(paired, runner, chunk_size, ndev)
    return np.asarray(out, np.int64).reshape(
        len(flat) // len(seeds), len(seeds), max_steps)


# --------------------------------------------------- Fig 6 targeted attacks
def _targeted_single(st: _Static, smp: Sampler, sc: Scenario):
    G = st.max_groups
    gidx = jnp.arange(G, dtype=jnp.int32)
    active = gidx < sc.n_objects * sc.n_chunks
    base = smp.base(sc.seed)
    k_init, ka = smp.streams(smp.fold(base, 0), 2)
    byz = smp.binom(k_init, jnp.where(active, sc.r_inner, 0.0),
                    sc.byz_fraction)
    honest = jnp.where(active, sc.r_inner - byz, 0.0)
    kill = _targeted_kill(smp, sc, ka, honest, active)
    obj_id = jnp.minimum(gidx // jnp.maximum(sc.n_chunks, 1),
                         st.max_objects - 1)
    chunks_alive = jax.ops.segment_sum(
        (active & ~kill).astype(jnp.float32), obj_id,
        num_segments=st.max_objects)
    obj_active = jnp.arange(st.max_objects) < sc.n_objects
    lost = (obj_active & (chunks_alive < sc.k_outer)).sum()
    return lost / jnp.maximum(sc.n_objects, 1)


@functools.lru_cache(maxsize=None)
def _targeted_batch(st: _Static, sampler: str, devices: int = 1):
    run = jax.vmap(functools.partial(_targeted_single, st,
                                     SAMPLERS[sampler]))
    return _compile_runner(run, devices)


def targeted_grid(cells, seeds=range(8), sampler: str = "exact",
                  chunk_size: int | None = None,
                  devices: int | None = None) -> np.ndarray:
    """Lost-object fraction under the greedy targeted attack (Fig. 6
    bottom), batched over cells × seeds: ``[n_cells, n_seeds]`` float."""
    seeds = list(seeds)
    ndev = _ndev(devices)
    flat = _product(cells, seeds)
    st = _Static(
        max_groups=max(int(s.n_objects * s.n_chunks) for s in flat),
        max_objects=max(int(s.n_objects) for s in flat), max_steps=1)
    out = _run_chunked(flat, _targeted_batch(st, sampler, ndev),
                       chunk_size, ndev)
    return np.asarray(out).reshape(len(flat) // len(seeds), len(seeds))


# ------------------------------------------------------------- summarizing
def mean_ci(x: np.ndarray, axis: int = -1) -> tuple[np.ndarray, np.ndarray]:
    """Mean and 95% normal-approx confidence half-width over ``axis``
    (the seed axis of a grid result)."""
    x = np.asarray(x, np.float64)
    n = x.shape[axis]
    mean = x.mean(axis=axis)
    ci = 1.96 * x.std(axis=axis, ddof=1) / np.sqrt(n) if n > 1 else (
        np.zeros_like(mean))
    return mean, ci
