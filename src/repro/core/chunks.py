"""Object <-> chunk <-> fragment coding pipeline (paper §4.2, Fig. 1).

Outer code: RLNC seeded by the *object hash* (public function), with the
chunk indices drawn privately from the owner's secret key — the opacity
property: fragments/chunks are indistinguishable across objects, so targeted
attacks degrade to random attacks (§3.2).

Inner code: RLNC seeded by the *chunk hash* (publicly known), so any node can
generate or verify fragment ``i`` of a chunk — consensus-free repair (§3.2).
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.core.rateless import RLNC, prf_u64

LEN_HEADER = 8
INDEX_SPACE = 1 << 62  # chunk/fragment stream index space


def obj_hash(data: bytes) -> bytes:
    return hashlib.sha256(b"vault-obj" + data).digest()


def chunk_hash(payload: bytes) -> bytes:
    return hashlib.sha256(b"vault-chunk" + payload).digest()


def hash_point(h: bytes) -> int:
    return int.from_bytes(h, "big")


def fragment_hash(chash: bytes, index: int) -> int:
    return hash_point(
        hashlib.sha256(b"vault-frag" + chash + index.to_bytes(8, "big")).digest()
    )


def payload_tag(payload: bytes) -> int:
    """Integrity tag of a fragment payload.

    ``fragment_hash`` above binds only ``(chash, index)`` — it places a
    fragment on the ring but says nothing about its *bytes*.  The inner
    code is deterministic (``inner_encode_fragment``), so a fragment's
    honest payload is a pure function of its chunk and the creator can
    record this tag at encode time (``SimNetwork.frag_tags``) for pullers
    to verify rows against — the simulation stand-in for the paper's
    verifiable-fragment property, at hash cost instead of algebraic
    checks.  sha256-prefix, so any corruption flips it."""
    return int.from_bytes(
        hashlib.sha256(b"vault-frag-tag" + payload).digest()[:8], "big")


def corrupt_payload(payload: bytes) -> bytes:
    """Deterministically corrupted copy of a fragment payload — what a
    colluding/withholding node (``policies.ADV_COLLUDE``) serves at pull
    time: right length, right index, wrong bytes (first byte flipped), so
    it survives every shape check and dies only at tag verification."""
    if not payload:
        return b"\xa5"
    return bytes((payload[0] ^ 0xA5,)) + payload[1:]


def split_blocks(data: bytes, k: int) -> np.ndarray:
    """Split ``data`` into k equal blocks (8-byte length header + padding)."""
    payload = len(data).to_bytes(LEN_HEADER, "big") + data
    block_len = -(-len(payload) // k)
    payload += b"\x00" * (k * block_len - len(payload))
    return np.frombuffer(payload, np.uint8).reshape(k, block_len).copy()


def join_blocks(blocks: np.ndarray) -> bytes:
    raw = np.asarray(blocks, np.uint8).tobytes()
    n = int.from_bytes(raw[:LEN_HEADER], "big")
    return raw[LEN_HEADER : LEN_HEADER + n]


def derive_chunk_indices(sk: bytes, ohash: bytes, n_chunks: int) -> list[int]:
    """Private, deterministic chunk indices (paper: sk + object hash)."""
    key = hashlib.sha256(b"vault-outer-idx" + sk + ohash).digest()
    seen: list[int] = []
    i = 0
    while len(seen) < n_chunks:
        idx = prf_u64(key, i) % INDEX_SPACE
        if idx not in seen:
            seen.append(idx)
        i += 1
    return seen


@dataclasses.dataclass(frozen=True)
class CodeParams:
    """Coding configuration (paper defaults: §6)."""

    k_outer: int = 8
    n_chunks: int = 10
    k_inner: int = 32
    r_inner: int = 80  # threshold group size R

    @property
    def redundancy(self) -> float:
        return (self.n_chunks / self.k_outer) * (self.r_inner / self.k_inner)


@dataclasses.dataclass(frozen=True)
class ObjectID:
    """Returned by STORE; private to the owner (content addressing)."""

    ohash: bytes
    length: int
    chunk_indices: tuple[int, ...]
    chunk_hashes: tuple[bytes, ...]
    params: CodeParams


def outer_encode(
    data: bytes, sk: bytes, params: CodeParams, backend: str = "numpy"
) -> tuple[ObjectID, list[bytes]]:
    """OuterEncode of Alg. 1: object -> n privately-selected chunks."""
    ohash = obj_hash(data)
    blocks = split_blocks(data, params.k_outer)
    code = RLNC(k=params.k_outer, seed=ohash)
    indices = derive_chunk_indices(sk, ohash, params.n_chunks)
    payloads = code.encode(blocks, indices, backend=backend)
    chunks = [payloads[i].tobytes() for i in range(params.n_chunks)]
    oid = ObjectID(
        ohash=ohash,
        length=len(data),
        chunk_indices=tuple(indices),
        chunk_hashes=tuple(chunk_hash(c) for c in chunks),
        params=params,
    )
    return oid, chunks


def outer_decode(oid: ObjectID, recovered: dict[bytes, bytes]) -> bytes:
    """OuterDecode: any K_outer recovered chunks -> object (verified)."""
    code = RLNC(k=oid.params.k_outer, seed=oid.ohash)
    idx, syms = [], []
    for i, ch in zip(oid.chunk_indices, oid.chunk_hashes):
        if ch in recovered:
            idx.append(i)
            syms.append(np.frombuffer(recovered[ch], np.uint8))
        if len(idx) >= oid.params.k_outer:
            break
    if len(idx) < oid.params.k_outer:
        from repro.core.rateless import InsufficientFragments

        raise InsufficientFragments(
            f"need {oid.params.k_outer} chunks, recovered {len(idx)}"
        )
    blocks = code.decode(idx, np.stack(syms))
    data = join_blocks(blocks)[: oid.length]
    if obj_hash(data) != oid.ohash:
        raise ValueError("decoded object failed content-address verification")
    return data


def inner_code(chash: bytes, k_inner: int) -> RLNC:
    return RLNC(k=k_inner, seed=chash)


def inner_encode_fragment(
    chunk: bytes, chash: bytes, k_inner: int, index: int, backend: str = "numpy"
) -> bytes:
    blocks = split_blocks(chunk, k_inner)
    code = inner_code(chash, k_inner)
    return code.encode(blocks, [index], backend=backend)[0].tobytes()


def inner_encode_many(
    chunk: bytes, chash: bytes, k_inner: int, indices, backend: str = "numpy"
) -> list[bytes]:
    blocks = split_blocks(chunk, k_inner)
    code = inner_code(chash, k_inner)
    payloads = code.encode(blocks, indices, backend=backend)
    return [payloads[i].tobytes() for i in range(len(indices))]


def inner_decode(
    chash: bytes, k_inner: int, fragments: dict[int, bytes]
) -> bytes:
    code = inner_code(chash, k_inner)
    items = list(fragments.items())
    idx = [i for i, _ in items]
    syms = np.stack([np.frombuffer(f, np.uint8) for _, f in items])
    blocks = code.decode(idx, syms)
    chunk = join_blocks(blocks)
    if chunk_hash(chunk) != chash:
        raise ValueError("decoded chunk failed content-address verification")
    return chunk
