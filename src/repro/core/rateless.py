"""Rateless erasure codes for VAULT.

Two codes are provided behind one interface:

* ``RLNC`` — random linear network code over GF(256). Every stream index
  ``i`` deterministically maps (via a keyed PRF) to a dense coefficient row
  over the ``k`` source blocks. Any ``k`` symbols whose coefficient matrix is
  full-rank decode; dense random rows over GF(256) are full-rank with
  probability ``>= prod_{j=1..k}(1-256^-j) ~= 0.996``, so the expected
  overhead matches the paper's wirehair figure (``k + ~0.02k`` worst case,
  usually ``k``). This is the default inner/outer code.
* ``LTCode`` — Luby-transform code over GF(2) with a robust-soliton degree
  distribution, XOR encode (bit-packed words), peeling decoder with a
  GF(2) Gaussian-elimination fallback.

Encoding hot path is delegated to ``repro.kernels.ops`` (Pallas on TPU,
interpret-mode on CPU) when ``backend="kernel"``; the numpy table path is the
reference.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib

import numpy as np

from repro.core import gf


class InsufficientFragments(Exception):
    """Raised when the provided symbols cannot reconstruct the source."""


# --------------------------------------------------------------------- PRF
def prf_bytes(key: bytes, index: int, n: int) -> bytes:
    """Deterministic pseudo-random bytes for stream index ``index``."""
    out = b""
    counter = 0
    while len(out) < n:
        h = hashlib.blake2b(
            index.to_bytes(8, "little") + counter.to_bytes(4, "little"),
            key=key[:64],
            digest_size=64,
        )
        out += h.digest()
        counter += 1
    return out[:n]


def prf_u64(key: bytes, index: int) -> int:
    return int.from_bytes(prf_bytes(key, index, 8), "little")


class _CoeffMemo:
    """Memoized read-only coefficient rows — pure in ``(seed, k, index)``.

    Repair decodes re-derive the same rows every tick (same chunk seeds,
    overlapping fragment indices); one blake2b stream per distinct row is
    enough for the whole run. Returned arrays are marked non-writable —
    ``coeff_matrix``'s ``np.stack`` copies, ``coeff_row`` copies
    explicitly.

    Unlike a plain ``lru_cache`` this memo is *explicitly evictable*: the
    dead-node reaper (``SimNetwork.fail_node``) drops the rows of every
    fragment a reaped node held, so the memo tracks the live fragment
    population (plus client-held outer rows) instead of every stream index
    a churn-heavy month ever touched. Eviction is always safe — the memo
    is a pure cache and a dropped row is simply recomputed on next use.
    ``_MAX`` is a crash-barrier only (FIFO), never hit when eviction is
    wired.
    """

    _MAX = 1 << 18

    def __init__(self) -> None:
        # keyed (seed, index) -> (k, row): one k per stream in practice,
        # and collapsing k into the value keeps eviction O(1) per fragment
        self._rows: dict[tuple[bytes, int], tuple[int, np.ndarray]] = {}
        self._hits = 0
        self._misses = 0

    def __call__(self, seed: bytes, k: int, index: int) -> np.ndarray:
        key = (seed, index)
        hit = self._rows.get(key)
        if hit is not None and hit[0] == k:
            self._hits += 1
            return hit[1]
        self._misses += 1
        row = np.frombuffer(prf_bytes(seed, index, k), np.uint8).copy()
        if not row.any():  # all-zero row is useless; bump deterministically
            row[index % k] = 1
        row.setflags(write=False)
        if len(self._rows) >= self._MAX:
            self._rows.pop(next(iter(self._rows)))
        self._rows[key] = (k, row)
        return row

    def evict(self, seed: bytes, index: int) -> None:
        """Drop the cached row for ``(seed, index)``, if any."""
        self._rows.pop((seed, index), None)

    def cache_clear(self) -> None:
        self._rows.clear()
        self._hits = self._misses = 0

    def cache_info(self):
        return functools._CacheInfo(self._hits, self._misses, self._MAX,
                                    len(self._rows))


_coeff_row = _CoeffMemo()


def evict_coeff_row(seed: bytes, index: int) -> None:
    """Reaper hook: forget the memoized coefficient row of one fragment."""
    _coeff_row.evict(seed, index)


# -------------------------------------------------------------------- RLNC
@dataclasses.dataclass(frozen=True)
class RLNC:
    """Random linear fountain code over GF(256).

    ``k``: number of source blocks. ``seed``: public or private key material
    that defines the (infinite) coefficient stream.
    """

    k: int
    seed: bytes

    def coeff_row(self, index: int) -> np.ndarray:
        """Dense GF(256) coefficient row for stream symbol ``index``."""
        return _coeff_row(self.seed, self.k, index).copy()

    def coeff_matrix(self, indices: list[int] | np.ndarray) -> np.ndarray:
        seed, k = self.seed, self.k
        return np.stack([_coeff_row(seed, k, int(i)) for i in indices],
                        axis=0)

    # encode ---------------------------------------------------------------
    def encode(
        self,
        blocks: np.ndarray,
        indices: list[int] | np.ndarray,
        backend: str = "numpy",
    ) -> np.ndarray:
        """Encode ``blocks`` (k, L) uint8 into symbols at ``indices`` (m, L)."""
        blocks = np.asarray(blocks, dtype=np.uint8)
        assert blocks.ndim == 2 and blocks.shape[0] == self.k, blocks.shape
        coeffs = self.coeff_matrix(indices)
        if backend == "kernel":
            from repro.kernels import ops

            return np.asarray(ops.gf256_encode(coeffs, blocks))
        return gf.gf_matmul_np(coeffs, blocks)

    # decode ---------------------------------------------------------------
    def decode(
        self, indices: list[int] | np.ndarray, symbols: np.ndarray
    ) -> np.ndarray:
        """Recover the (k, L) source blocks from >=k symbols."""
        symbols = np.asarray(symbols, dtype=np.uint8)
        coeffs = self.coeff_matrix(indices)
        return gf256_gaussian_solve(coeffs, symbols, self.k)


def gf256_gaussian_solve(
    coeffs: np.ndarray, symbols: np.ndarray, k: int
) -> np.ndarray:
    """Solve ``coeffs @ X = symbols`` over GF(256); returns X (k, L).

    ``coeffs``: (m, k) with m >= k. Raises InsufficientFragments if the
    matrix is rank-deficient.

    Delegates to the ``kernels/gf256_solve`` single-system entry (the
    batched dispatcher routes B=1 through the same augmented-matrix
    path; benchmark-scale batches take the Pallas kernel). Bit-identical
    to
    :func:`gf256_gaussian_solve_ref` — the retained scalar reference —
    including the exact ``InsufficientFragments`` message on
    rank-deficient input (``tests/test_gf256_solve.py`` pins both).
    """
    a = np.asarray(coeffs, dtype=np.uint8)
    y = np.asarray(symbols, dtype=np.uint8)
    m = a.shape[0]
    if m < k:
        raise InsufficientFragments(f"need >= {k} symbols, got {m}")
    assert a.shape[1] == k, (a.shape, k)
    from repro.kernels.gf256_solve import gf256_solve_one

    x, ok, fail_col = gf256_solve_one(a, y)
    if not ok:
        raise InsufficientFragments(
            f"rank-deficient at column {fail_col}")
    return x


def gf256_gaussian_solve_ref(
    coeffs: np.ndarray, symbols: np.ndarray, k: int
) -> np.ndarray:
    """Scalar reference solver (the pre-kernel implementation), kept as
    the bit-pin oracle for ``kernels/gf256_solve``."""
    a = np.asarray(coeffs, dtype=np.uint8).copy()
    y = np.asarray(symbols, dtype=np.uint8).copy()
    m = a.shape[0]
    if m < k:
        raise InsufficientFragments(f"need >= {k} symbols, got {m}")
    row = 0
    for col in range(k):
        piv = None
        for r in range(row, m):
            if a[r, col]:
                piv = r
                break
        if piv is None:
            raise InsufficientFragments(f"rank-deficient at column {col}")
        if piv != row:
            a[[row, piv]] = a[[piv, row]]
            y[[row, piv]] = y[[piv, row]]
        inv = gf.gf_inv_np(a[row, col])
        a[row] = gf.gf_mul_np(a[row], inv)
        y[row] = gf.gf_mul_np(y[row], inv)
        mask = a[:, col].copy()
        mask[row] = 0
        nz = np.nonzero(mask)[0]
        if nz.size:
            a[nz] ^= gf.gf_mul_np(mask[nz, None], a[row][None, :])
            y[nz] ^= gf.gf_mul_np(mask[nz, None], y[row][None, :])
        row += 1
    return y[:k]


# ------------------------------------------------------------------ LT code
def robust_soliton(k: int, c: float = 0.1, delta: float = 0.05) -> np.ndarray:
    """Robust soliton degree distribution (probabilities over degree 1..k)."""
    s = c * np.log(k / delta) * np.sqrt(k)
    rho = np.zeros(k + 1)
    rho[1] = 1.0 / k
    d = np.arange(2, k + 1)
    rho[2:] = 1.0 / (d * (d - 1))
    tau = np.zeros(k + 1)
    pivot = max(1, min(k, int(round(k / s))))
    dd = np.arange(1, pivot)
    tau[1:pivot] = s / (k * dd)
    tau[pivot] = s * np.log(s / delta) / k
    mu = rho + tau
    mu = mu[1:]
    return mu / mu.sum()


@dataclasses.dataclass(frozen=True)
class LTCode:
    """LT fountain code over GF(2) with robust-soliton degrees."""

    k: int
    seed: bytes
    c: float = 0.1
    delta: float = 0.05

    def __post_init__(self):
        object.__setattr__(self, "_dist", robust_soliton(self.k, self.c, self.delta))
        object.__setattr__(self, "_cdf", np.cumsum(self._dist))

    def neighbors(self, index: int) -> np.ndarray:
        """Source-block indices XORed into stream symbol ``index``."""
        u = prf_u64(self.seed, index * 2 + 1) / 2**64
        degree = int(np.searchsorted(self._cdf, u) + 1)
        degree = min(degree, self.k)
        # choose `degree` distinct blocks via PRF-seeded permutation
        rng = np.random.Generator(
            np.random.Philox(key=prf_u64(self.seed, index * 2))
        )
        return np.sort(rng.choice(self.k, size=degree, replace=False))

    def mask_matrix(self, indices) -> np.ndarray:
        m = np.zeros((len(indices), self.k), dtype=np.uint8)
        for r, i in enumerate(indices):
            m[r, self.neighbors(int(i))] = 1
        return m

    def encode(
        self, blocks: np.ndarray, indices, backend: str = "numpy"
    ) -> np.ndarray:
        blocks = np.asarray(blocks, dtype=np.uint8)
        assert blocks.shape[0] == self.k
        masks = self.mask_matrix(indices)
        if backend == "kernel":
            from repro.kernels import ops

            words = gf.pack_bits_to_words(blocks)
            out = np.asarray(ops.gf2_encode(masks, words))
            return gf.unpack_words_to_bytes(out, blocks.shape[1])
        out = np.zeros((len(indices), blocks.shape[1]), dtype=np.uint8)
        for r in range(len(indices)):
            nz = np.nonzero(masks[r])[0]
            for j in nz:
                out[r] ^= blocks[j]
        return out

    def decode(self, indices, symbols: np.ndarray) -> np.ndarray:
        """Peeling decoder; falls back to GF(2) Gaussian elimination."""
        orig_symbols = np.asarray(symbols, dtype=np.uint8)
        symbols = orig_symbols.copy()
        masks = self.mask_matrix(indices).astype(bool)
        k, L = self.k, symbols.shape[1]
        out = np.zeros((k, L), dtype=np.uint8)
        known = np.zeros(k, dtype=bool)
        progress = True
        while progress:
            progress = False
            deg = masks.sum(axis=1)
            for r in np.nonzero(deg == 1)[0]:
                js = np.nonzero(masks[r])[0]
                if js.size != 1:
                    continue  # this row was peeled earlier in the sweep
                j = int(js[0])
                if not known[j]:
                    out[j] = symbols[r]
                    known[j] = True
                    progress = True
                # peel block j out of every symbol that references it
                refs = np.nonzero(masks[:, j])[0]
                symbols[refs] ^= out[j][None, :]
                masks[refs, j] = False
        if known.all():
            return out
        # peeling stalled: solve the original full system exactly
        return self.decode_gaussian(indices, orig_symbols)

    def decode_gaussian(self, indices, symbols: np.ndarray) -> np.ndarray:
        masks = self.mask_matrix(indices)
        return gf2_gaussian_solve(masks, np.asarray(symbols, np.uint8), self.k)


def gf2_gaussian_solve(masks: np.ndarray, symbols: np.ndarray, k: int) -> np.ndarray:
    """Solve XOR system masks @ X = symbols over GF(2)."""
    a = np.asarray(masks, dtype=np.uint8).copy()
    y = np.asarray(symbols, dtype=np.uint8).copy()
    m = a.shape[0]
    if m < k:
        raise InsufficientFragments(f"need >= {k} symbols, got {m}")
    row = 0
    for col in range(k):
        piv = None
        for r in range(row, m):
            if a[r, col]:
                piv = r
                break
        if piv is None:
            raise InsufficientFragments(f"GF(2) rank-deficient at column {col}")
        if piv != row:
            a[[row, piv]] = a[[piv, row]]
            y[[row, piv]] = y[[piv, row]]
        mask = a[:, col].copy()
        mask[row] = 0
        nz = np.nonzero(mask)[0]
        if nz.size:
            a[nz] ^= a[row][None, :]
            y[nz] ^= y[row][None, :]
        row += 1
    return y[:k]
