"""VAULT client protocol (Algorithm 1): STORE and QUERY.

Latency accounting: coding time is measured for real (wall clock on this
box); network time composes sampled per-link RTTs with the parallelism
structure of Alg. 1 (all chunk/fragment operations run in parallel; a store
round is one selection RTT plus one store RTT; a query takes the K-th order
statistic of the parallel fragment fetches — which is why QUERY beats the
replicated baseline in the paper, Fig. 7).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import chunks as C
from repro.core import selection as sel
from repro.core.network import GroupMeta, Node, SimNetwork
from repro.core.rateless import InsufficientFragments

MAX_ROUNDS_FACTOR = 6  # fragment-index rounds per required member


def gather_available(
    net: SimNetwork, chash: bytes, r_inner: int,
) -> tuple[list[tuple[int, bytes, Node]],
           list[Node], list[tuple[int, bytes, Node]]]:
    """DHT walk + parallel fragment gather for one chunk. RNG-free.

    Walks the same candidate window as Alg. 1 QUERY and returns
    ``(rows, holders, corrupt)``: ``rows`` is the distinct *verified*
    fragment payloads in discovery order as ``(index, payload, holder)``
    — the first (nearest) holder of each index wins — shaped for
    ``repair.decode_from_available``; ``holders`` is every candidate that
    served anything, in walk order (the QUERY path's RTT fan-out set);
    ``corrupt`` is the rows that failed ``SimNetwork.row_ok`` tag
    verification (colluding holders, ``policies.ADV_COLLUDE``) — already
    transferred, so callers charge their bytes, but never decoded.
    Corrupt rows do NOT claim their index (a colluder can't shadow an
    honest holder of the same fragment further down the walk), and
    corrupt-only candidates do NOT join ``holders`` — the RTT fan-out
    set, and with it every downstream RNG draw, is exactly the set a
    serve-nothing Byzantine run yields, which is what makes the
    collude-vs-static differential test exact.
    Shared by the client QUERY path and the serving layer
    (``protocol_sim._serve_tick``).
    """
    cands = net.candidates(C.hash_point(chash), min(4 * r_inner, net.n_nodes))
    rows: list[tuple[int, bytes, Node]] = []
    holders: list[Node] = []
    corrupt: list[tuple[int, bytes, Node]] = []
    seen: set[int] = set()
    for cand in cands:
        served = cand.serve_fragments(chash)
        if not served:
            continue
        # a candidate joins the fan-out set iff it served ≥1 *verified*
        # row (duplicate indices included — the pre-tag behavior for
        # honest holders, who always verify)
        contributed = False
        for idx, payload in served.items():
            if not net.row_ok(chash, idx, payload):
                # every corrupt transfer is charged (parallel pulls pay
                # all holders), even when an honest row has the index
                corrupt.append((idx, payload, cand))
                continue
            contributed = True
            if idx not in seen:
                seen.add(idx)
                rows.append((idx, payload, cand))
        if contributed:
            holders.append(cand)
    return rows, holders, corrupt


@dataclasses.dataclass
class OpStats:
    latency_s: float
    coding_s: float
    bytes_sent: int


class VaultClient:
    """A participating node issuing client operations (paper §4.3.1).

    ``batch=True`` runs each STORE selection round through the net's
    resident ``selection.LocateRound`` — one vectorized proof round per
    fragment index over candidate arrays built once per ring state,
    instead of a scalar prove/verify per candidate. The placement
    (and every byte of network state) is identical: the round picks the
    same nearest verified-selected candidate with the same first-minimum
    tie-break, and no RNG is involved.
    """

    def __init__(self, net: SimNetwork, node: Node, backend: str = "numpy",
                 batch: bool = False):
        self.net = net
        self.node = node
        self.backend = backend
        self.batch = batch

    # ------------------------------------------------------------------ STORE
    def store(
        self, data: bytes, params: C.CodeParams | None = None,
        cache_ttl: float = 0.0,
    ) -> tuple[C.ObjectID, OpStats]:
        params = params or C.CodeParams()
        t0 = time.perf_counter()
        oid, chunk_payloads = C.outer_encode(
            data, self.node.kp.sk, params, backend=self.backend
        )
        coding = time.perf_counter() - t0
        lat_chunks = []
        sent = 0
        for chash, payload in zip(oid.chunk_hashes, chunk_payloads):
            lat, nbytes, cs = self._store_chunk(chash, payload, params, cache_ttl)
            lat_chunks.append(lat)
            sent += nbytes
            coding += cs
        # chunks are stored in parallel (Alg. 1): latency = slowest chunk
        stats = OpStats(
            latency_s=coding + (max(lat_chunks) if lat_chunks else 0.0),
            coding_s=coding,
            bytes_sent=sent,
        )
        return oid, stats

    def _store_chunk(
        self, chash: bytes, payload: bytes, params: C.CodeParams,
        cache_ttl: float,
    ) -> tuple[float, int, float]:
        anchor = C.hash_point(chash)
        t0 = time.perf_counter()
        blocks = C.split_blocks(payload, params.k_inner)
        code = C.inner_code(chash, params.k_inner)
        coding = time.perf_counter() - t0
        frag_len = blocks.shape[1] + 0  # symbols have block length
        meta = GroupMeta(
            chash=chash, k_inner=params.k_inner, r_target=params.r_inner,
            frag_len=frag_len,
        )
        members: dict[int, float] = {}
        stored: list[tuple[Node, int, bytes]] = []
        round_lat: list[float] = []
        sent = 0
        max_rounds = params.r_inner * MAX_ROUNDS_FACTOR
        cand_count = min(4 * params.r_inner, self.net.n_nodes)
        cands = self.net.candidates(anchor, cand_count)
        for i in range(max_rounds):
            if len(members) >= params.r_inner:
                break
            fhash = C.fragment_hash(chash, i)
            # ask candidates for selection proofs (one parallel RPC round)
            picked: Node | None = None
            best_d = None
            picked_proof = None
            if self.batch:
                found = self.net.locate_round(
                    anchor, cand_count, params.r_inner).nearest(
                        fhash, members)
                if found is not None:
                    picked, picked_proof = found
            else:
                for cand in cands:
                    if cand.nid in members or not cand.alive:
                        continue
                    proof, selected = cand.selection_proof(
                        fhash, anchor, params.r_inner
                    )
                    if not selected:
                        continue
                    if not sel.verify_selection(
                        self.net.registry, proof, anchor, params.r_inner,
                        self.net.n_nodes,
                    ):
                        continue  # forged / stale proof — never admitted
                    d = sel.ring_distance(anchor, cand.nid)
                    if best_d is None or d < best_d:
                        picked, best_d, picked_proof = cand, d, proof
            if picked is None:
                continue
            t0 = time.perf_counter()
            frag = code.encode(blocks, [i], backend=self.backend)[0].tobytes()
            coding += time.perf_counter() - t0
            members[picked.nid] = self.net.now
            # the encoder knows the honest bytes: record the integrity tag
            # pullers verify rows against (collusion/withholding defense)
            self.net.record_frag_tag(chash, i, frag)
            picked.store_fragment(meta, i, frag, dict(members), picked_proof)
            stored.append((picked, i, frag))
            sent += len(frag)
            # selection round + store round, fragments in parallel:
            round_lat.append(
                float(np.max(self.net.rtts(self.node, cands[: 8]))) +
                self.net.rtt(self.node, picked)
            )
        if len(members) < params.k_inner:
            raise InsufficientFragments(
                f"could only place {len(members)} fragments"
            )
        # forward final membership to every member (bootstraps group views)
        for node_, _, _ in stored:
            view = node_.groups[chash]
            view.members.update(members)
            if cache_ttl > 0:
                node_.cache_chunk(chash, payload, cache_ttl)
        lat = max(round_lat) if round_lat else 0.0
        return lat, sent, coding

    # ------------------------------------------------------------------ QUERY
    def query(self, oid: C.ObjectID) -> tuple[bytes, OpStats]:
        t_net: list[float] = []
        coding = 0.0
        recovered: dict[bytes, bytes] = {}
        # chunk retrievals run in parallel; we need the fastest K_outer
        per_chunk: list[tuple[float, bytes, bytes]] = []
        for chash in oid.chunk_hashes:
            try:
                chunk, lat, cs = self._retrieve_chunk(chash, oid.params)
            except (InsufficientFragments, ValueError):
                # unreachable fragments OR content-verification failure
                # (corrupted/forged fragments): skip — any K_outer of the
                # n_chunks chunks reconstruct the object
                continue
            coding += cs
            per_chunk.append((lat, chash, chunk))
        if len(per_chunk) < oid.params.k_outer:
            raise InsufficientFragments(
                f"only {len(per_chunk)}/{oid.params.k_outer} chunks recoverable"
            )
        per_chunk.sort(key=lambda t: t[0])
        for lat, chash, chunk in per_chunk[: oid.params.k_outer]:
            recovered[chash] = chunk
            t_net.append(lat)
        t0 = time.perf_counter()
        data = C.outer_decode(oid, recovered)
        coding += time.perf_counter() - t0
        return data, OpStats(
            latency_s=max(t_net) + coding, coding_s=coding,
            bytes_sent=0,
        )

    def _retrieve_chunk(
        self, chash: bytes, params: C.CodeParams
    ) -> tuple[bytes, float, float]:
        anchor = C.hash_point(chash)
        cands = self.net.candidates(anchor, min(4 * params.r_inner, self.net.n_nodes))
        lookup_rtt = float(np.max(self.net.rtts(self.node, cands[:8]))) if cands else 0.0
        rows, holders, _corrupt = gather_available(
            self.net, chash, params.r_inner)
        frags = {idx: payload for idx, payload, _ in rows}
        if len(frags) < params.k_inner:
            raise InsufficientFragments(
                f"{len(frags)}/{params.k_inner} fragments reachable"
            )
        # parallel fetches: chunk ready at the K-th fastest response
        rtts = np.sort(self.net.rtts(self.node, holders))
        kth = rtts[min(params.k_inner, len(rtts)) - 1]
        t0 = time.perf_counter()
        chunk = C.inner_decode(chash, params.k_inner, frags)
        coding = time.perf_counter() - t0
        return chunk, lookup_rtt + float(kth), coding
