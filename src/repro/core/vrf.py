"""Verifiable random function (VRF) interface for VAULT peer selection.

The paper uses an ed25519-curve VRF (RFC 9381 style). Elliptic-curve crypto
is not the paper's contribution and has no TPU analogue, so we implement the
VRF *interface* — per-key deterministic, uniformly distributed outputs, a
proof object, and public verification that never touches ``sk`` — with a
keyed-hash construction plus a registry that plays the role of the public
verification equation. A production deployment swaps ``HashVRF`` for a real
ed25519-VRF behind the same three functions (DESIGN.md §4).

Security property preserved for every protocol/test in this repo: an
adversary who does not hold ``sk`` can neither predict ``r`` for a new input
nor forge a ``(r, proof)`` pair that verifies under an honest ``pk``.

Two registry backends share that contract (``make_registry``):

* :class:`VRFRegistry` — the PR 3 keyed-sha256 construction, the default.
  Scalar ``prove``/``verify`` are byte-identical to PR 3 (the protocol
  golden regression depends on it); ``verify_batch`` is a scalar loop, so
  batching gains come from the selection-layer memo cache alone.
* :class:`ArxVRFRegistry` — the same interface on the ``kernels/prf_select``
  ARX permutation: per-key tag *words* are derived once (sha256, at
  registration), after which ``prove_batch``/``verify_batch`` are pure
  int32 array arithmetic — vectorized numpy for small batches, one
  ``prf_select_pairs`` kernel dispatch for per-tick batches. Outputs are
  32-bit values scaled to the full ring (uniformity at 2^-32 granularity —
  ample for selection simulation; the two backends are statistically
  equivalent but not byte-compatible).
"""
from __future__ import annotations

import dataclasses
import hashlib
import hmac
import os

import numpy as np

HASHLEN = 256  # bits of VRF output / ring identifier space
RING = 1 << HASHLEN

ARX_OUT_BITS = 32                      # ArxVRF raw output width
ARX_SHIFT = HASHLEN - ARX_OUT_BITS     # scale factor to ring units
_ARX_PROOF_C0 = 0x9E3779B9             # proof-lane tag tweak (golden ratio)
_ARX_PROOF_C1 = 0x85EBCA6B


def _h(*parts: bytes) -> bytes:
    h = hashlib.sha256()
    for p in parts:
        h.update(len(p).to_bytes(4, "little"))
        h.update(p)
    return h.digest()


@dataclasses.dataclass(frozen=True)
class KeyPair:
    sk: bytes
    pk: bytes

    @staticmethod
    def generate(seed: bytes | None = None) -> "KeyPair":
        sk = _h(b"vault-sk", seed) if seed is not None else os.urandom(32)
        return KeyPair(sk=sk, pk=_h(b"vault-pk", sk))


def _tag(sk: bytes) -> bytes:
    return _h(b"vault-vrf-tag", sk)


class VRFRegistry:
    """Stand-in for the public-key verification equation.

    Maps pk -> verification tag at key registration. Verification reads only
    pk-indexed state; ``sk`` never leaves the prover. One registry per
    simulated network (it models "public keys are known by all nodes").
    """

    def __init__(self) -> None:
        self._tags: dict[bytes, bytes] = {}
        # memo for the *selection* layer (selection.verify_selection_batch):
        # full VerifySelection verdicts, two-level — pk -> {rest of the
        # proof tuple -> verdict} — so a claim re-verified every heartbeat
        # costs one dict hit instead of fresh hashing, and :meth:`evict`
        # drops a dead node's entire verdict history in O(1). Lives here
        # because its lifetime is the registry's ("public keys are known by
        # all nodes" — one per simulated net).
        self.selection_cache: dict[bytes, dict[tuple, bool]] = {}

    def register(self, kp: KeyPair) -> None:
        self._tags[kp.pk] = _tag(kp.sk)

    def evict(self, kp: KeyPair) -> None:
        """Forget a failed node's key material and memoized verdicts.

        Called by ``SimNetwork.fail_node`` (the dead-node reaper): a failed
        node never proves again, and every verification path in the
        protocol verifies proofs *owned by currently-alive nodes* (claims,
        MembershipTimer re-admissions, Locate() responses are all
        self-made), so dropping the tag and the verdict memo is
        behavior-neutral — it only bounds registry memory under churn.
        """
        self._tags.pop(kp.pk, None)
        self.selection_cache.pop(kp.pk, None)

    def prove(self, sk: bytes, alpha: bytes) -> tuple[int, bytes]:
        """VRF_sk(alpha) -> (r, proof). r uniform in [0, 2^HASHLEN)."""
        t = _tag(sk)
        r = int.from_bytes(_h(b"vrf-out", t, alpha), "big")
        proof = _h(b"vrf-proof", t, alpha)
        return r, proof

    def verify(self, pk: bytes, alpha: bytes, r: int, proof: bytes) -> bool:
        t = self._tags.get(pk)
        if t is None:
            return False
        r_ok = int.from_bytes(_h(b"vrf-out", t, alpha), "big") == r
        p_ok = hmac.compare_digest(_h(b"vrf-proof", t, alpha), proof)
        return r_ok and p_ok

    # -- batch interface (element-wise equal to the scalar calls) ----------
    def prove_batch(self, sks: list[bytes], alphas: list[bytes]):
        """[VRF_sk(alpha)] for each (sk, alpha) pair -> (rs, proofs)."""
        out = [self.prove(sk, a) for sk, a in zip(sks, alphas)]
        return [r for r, _ in out], [p for _, p in out]

    def verify_batch(self, pks, alphas, rs, proofs) -> np.ndarray:
        """Element-wise :meth:`verify` over equal-length sequences.

        The keyed-sha256 construction has no array form, so this is the
        scalar loop; ``ArxVRFRegistry`` overrides it with one vectorized
        PRF evaluation. Both satisfy ``verify_batch(...)[i] ==
        verify(pks[i], alphas[i], rs[i], proofs[i])`` exactly
        (``tests/test_vrf_selection.py``).
        """
        return np.fromiter(
            (self.verify(pk, a, r, pr)
             for pk, a, r, pr in zip(pks, alphas, rs, proofs)),
            dtype=bool, count=len(pks))


def _arx_words(tag: bytes) -> tuple[int, int]:
    """Two unsigned 32-bit lanes from a 32-byte verification tag."""
    return (int.from_bytes(tag[0:4], "little"),
            int.from_bytes(tag[4:8], "little"))


def _alpha_words(alpha: bytes) -> tuple[int, int]:
    """Two unsigned 32-bit lanes from the low bits of a VRF input."""
    return (int.from_bytes(alpha[-8:-4], "little"),
            int.from_bytes(alpha[-4:], "little"))


class ArxVRFRegistry(VRFRegistry):
    """VRF interface on the ``kernels/prf_select`` ARX permutation.

    Key derivation stays sha256 (one-time, at :meth:`register`); per-input
    evaluation is ``arx_mix`` on int32 lanes, so proving and verifying
    batch into pure array arithmetic and, for per-tick batches, one
    ``prf_select_pairs`` kernel dispatch. The 32-bit output is scaled by
    ``2^ARX_SHIFT`` onto the hash ring; the proof is the 4-byte output of
    a second, tag-tweaked ARX lane. Statistically interchangeable with the
    sha256 registry — *not* byte-compatible (placements differ), which is
    why the protocol golden regression runs on the default hash backend.
    """

    def __init__(self) -> None:
        super().__init__()
        self._words: dict[bytes, tuple[int, int]] = {}   # pk -> tag lanes
        self._sk_words: dict[bytes, tuple[int, int]] = {}

    def register(self, kp: KeyPair) -> None:
        super().register(kp)
        w = _arx_words(self._tags[kp.pk])
        self._words[kp.pk] = w
        self._sk_words[kp.sk] = w

    def evict(self, kp: KeyPair) -> None:
        super().evict(kp)
        self._words.pop(kp.pk, None)
        self._sk_words.pop(kp.sk, None)

    @staticmethod
    def _eval(t0: int, t1: int, f0: int, f1: int) -> tuple[int, bytes]:
        from repro.kernels.prf_select import arx_mix_words

        r32 = arx_mix_words(t0, t1, f0, f1)
        p32 = arx_mix_words(t0 ^ _ARX_PROOF_C0, t1 ^ _ARX_PROOF_C1, f0, f1)
        return r32 << ARX_SHIFT, p32.to_bytes(4, "little")

    def prove(self, sk: bytes, alpha: bytes) -> tuple[int, bytes]:
        w = self._sk_words.get(sk)
        if w is None:  # unregistered prover (tests): derive on the fly
            w = _arx_words(_tag(sk))
        return self._eval(*w, *_alpha_words(alpha))

    def verify(self, pk: bytes, alpha: bytes, r: int, proof: bytes) -> bool:
        w = self._words.get(pk)
        if w is None:
            return False
        r_want, p_want = self._eval(*w, *_alpha_words(alpha))
        return r_want == r and hmac.compare_digest(p_want, proof)

    # -- vectorized batch paths -------------------------------------------
    def sk_lanes(self, sks: list[bytes]) -> np.ndarray:
        """(P, 2) uint32 tag lanes for a list of secret keys — the resident
        array form a ``selection.LocateRound`` keeps across Locate() slots
        (derive once per candidate set, evaluate per fragment hash)."""
        out = np.empty((len(sks), 2), np.uint32)
        for i, sk in enumerate(sks):
            w = self._sk_words.get(sk)
            out[i] = w if w is not None else _arx_words(_tag(sk))
        return out

    def eval_lanes(self, words: np.ndarray, alpha: bytes):
        """Evaluate every tag-lane row of ``words`` (P, 2) against ONE VRF
        input — the Locate() round shape. Returns (r32, proof32) uint32
        arrays; ``r32[i] << ARX_SHIFT`` and ``proof32[i].to_bytes(4,
        "little")`` are exactly the scalar :meth:`prove` outputs for the
        i-th key."""
        fwords = np.broadcast_to(
            np.array(_alpha_words(alpha), np.uint32), words.shape)
        return self._eval_batch(words, fwords)

    def eval_value_lanes(self, words: np.ndarray, alpha: bytes):
        """Value lanes only — half the PRF work of :meth:`eval_lanes`.

        Selection decisions need every candidate's r32, but proofs are
        materialized for winners only (``LocateRound.nearest``) or the
        selected few (``responders``) — callers fetch those separately
        via :meth:`eval_proof_lanes`. Lane rows are independent, so the
        split is bit-identical to the fused evaluation."""
        from repro.kernels.prf_select import prf_select_pairs

        fwords = np.ascontiguousarray(np.broadcast_to(
            np.array(_alpha_words(alpha), np.uint32), words.shape))
        out = prf_select_pairs(words.view(np.int32), fwords.view(np.int32))
        return np.asarray(out).view(np.uint32)

    def eval_proof_lanes(self, words: np.ndarray, alpha: bytes):
        """Proof lanes for the given tag-lane rows (see eval_value_lanes)."""
        from repro.kernels.prf_select import prf_select_pairs

        tweak = np.array([_ARX_PROOF_C0, _ARX_PROOF_C1], np.uint32)
        fwords = np.ascontiguousarray(np.broadcast_to(
            np.array(_alpha_words(alpha), np.uint32), words.shape))
        out = prf_select_pairs((words ^ tweak).view(np.int32),
                               fwords.view(np.int32))
        return np.asarray(out).view(np.uint32)

    def _eval_batch(self, words: np.ndarray, fwords: np.ndarray):
        """(P,2) uint32 tag lanes × (P,2) uint32 input lanes ->
        (r32, proof32) uint32 arrays, via one fused PRF evaluation over the
        doubled pair list (output lane then proof lane)."""
        from repro.kernels.prf_select import prf_select_pairs

        tweak = np.array([_ARX_PROOF_C0, _ARX_PROOF_C1], np.uint32)
        tags2 = np.concatenate([words, words ^ tweak], axis=0)
        f2 = np.concatenate([fwords, fwords], axis=0)
        out = prf_select_pairs(tags2.view(np.int32), f2.view(np.int32))
        out = np.asarray(out).view(np.uint32)
        n = words.shape[0]
        return out[:n], out[n:]

    def prove_batch(self, sks: list[bytes], alphas: list[bytes]):
        n = len(sks)
        words = np.empty((n, 2), np.uint32)
        fwords = np.empty((n, 2), np.uint32)
        for i, (sk, a) in enumerate(zip(sks, alphas)):
            w = self._sk_words.get(sk)
            words[i] = w if w is not None else _arx_words(_tag(sk))
            fwords[i] = _alpha_words(a)
        r32, p32 = self._eval_batch(words, fwords)
        rs = [r << ARX_SHIFT for r in r32.tolist()]
        proofs = [p.to_bytes(4, "little") for p in p32.tolist()]
        return rs, proofs

    def verify_batch(self, pks, alphas, rs, proofs) -> np.ndarray:
        n = len(pks)
        words = np.zeros((n, 2), np.uint32)
        fwords = np.empty((n, 2), np.uint32)
        known = np.ones(n, bool)
        for i, (pk, a) in enumerate(zip(pks, alphas)):
            w = self._words.get(pk)
            if w is None:
                known[i] = False
            else:
                words[i] = w
            fwords[i] = _alpha_words(a)
        r32, p32 = self._eval_batch(words, fwords)
        r32l, p32l = r32.tolist(), p32.tolist()
        ok = np.fromiter(
            ((r32l[i] << ARX_SHIFT) == rs[i]
             and p32l[i].to_bytes(4, "little") == proofs[i]
             for i in range(n)), dtype=bool, count=n)
        return ok & known


VRF_BACKENDS = {"hash": VRFRegistry, "arx": ArxVRFRegistry}


def make_registry(backend: str = "hash") -> VRFRegistry:
    """Registry factory: ``"hash"`` (PR 3 keyed-sha256, bit-stable) or
    ``"arx"`` (``kernels/prf_select`` ARX lanes, batch-vectorizable)."""
    try:
        return VRF_BACKENDS[backend]()
    except KeyError:
        raise ValueError(
            f"unknown VRF backend {backend!r}; pick from "
            f"{sorted(VRF_BACKENDS)}") from None


def node_id(pk: bytes) -> int:
    """SHA256(pk) as a point on the hash ring (§4.3: random node IDs)."""
    return int.from_bytes(_h(b"vault-node-id", pk), "big")
