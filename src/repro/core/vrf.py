"""Verifiable random function (VRF) interface for VAULT peer selection.

The paper uses an ed25519-curve VRF (RFC 9381 style). Elliptic-curve crypto
is not the paper's contribution and has no TPU analogue, so we implement the
VRF *interface* — per-key deterministic, uniformly distributed outputs, a
proof object, and public verification that never touches ``sk`` — with a
keyed-hash construction plus a registry that plays the role of the public
verification equation. A production deployment swaps ``HashVRF`` for a real
ed25519-VRF behind the same three functions (DESIGN.md §4).

Security property preserved for every protocol/test in this repo: an
adversary who does not hold ``sk`` can neither predict ``r`` for a new input
nor forge a ``(r, proof)`` pair that verifies under an honest ``pk``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import hmac
import os

HASHLEN = 256  # bits of VRF output / ring identifier space
RING = 1 << HASHLEN


def _h(*parts: bytes) -> bytes:
    h = hashlib.sha256()
    for p in parts:
        h.update(len(p).to_bytes(4, "little"))
        h.update(p)
    return h.digest()


@dataclasses.dataclass(frozen=True)
class KeyPair:
    sk: bytes
    pk: bytes

    @staticmethod
    def generate(seed: bytes | None = None) -> "KeyPair":
        sk = _h(b"vault-sk", seed) if seed is not None else os.urandom(32)
        return KeyPair(sk=sk, pk=_h(b"vault-pk", sk))


def _tag(sk: bytes) -> bytes:
    return _h(b"vault-vrf-tag", sk)


class VRFRegistry:
    """Stand-in for the public-key verification equation.

    Maps pk -> verification tag at key registration. Verification reads only
    pk-indexed state; ``sk`` never leaves the prover. One registry per
    simulated network (it models "public keys are known by all nodes").
    """

    def __init__(self) -> None:
        self._tags: dict[bytes, bytes] = {}

    def register(self, kp: KeyPair) -> None:
        self._tags[kp.pk] = _tag(kp.sk)

    def prove(self, sk: bytes, alpha: bytes) -> tuple[int, bytes]:
        """VRF_sk(alpha) -> (r, proof). r uniform in [0, 2^HASHLEN)."""
        t = _tag(sk)
        r = int.from_bytes(_h(b"vrf-out", t, alpha), "big")
        proof = _h(b"vrf-proof", t, alpha)
        return r, proof

    def verify(self, pk: bytes, alpha: bytes, r: int, proof: bytes) -> bool:
        t = self._tags.get(pk)
        if t is None:
            return False
        r_ok = int.from_bytes(_h(b"vrf-out", t, alpha), "big") == r
        p_ok = hmac.compare_digest(_h(b"vrf-proof", t, alpha), proof)
        return r_ok and p_ok


def node_id(pk: bytes) -> int:
    """SHA256(pk) as a point on the hash ring (§4.3: random node IDs)."""
    return int.from_bytes(_h(b"vault-node-id", pk), "big")
