"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches jax device state — smoke tests see 1 device; only
``dryrun.py`` sets ``xla_force_host_platform_device_count`` before jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) ("data","model") single pod; (2,16,16) with a leading "pod"
    axis for the 2-pod (512-chip) deployment. The pod axis is pure data
    parallelism: model axes never cross the inter-pod (DCI) boundary."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return jax.make_mesh(shape, axes)


def mesh_devices(mesh) -> int:
    import numpy as np

    return int(np.prod(mesh.devices.shape))
