"""Serving driver: batched prefill + decode loop (smoke-scale on CPU).

    PYTHONPATH=src python -m repro.launch.serve --arch minicpm3-4b \
        --batch 4 --prompt-len 64 --decode-steps 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import init_cache
from repro.training import make_decode_step, make_prefill_step


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm3-4b", choices=configs.ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.smoke_config(args.arch)
    need = args.prompt_len + args.decode_steps + cfg.extra_embed_len
    if cfg.max_cache_len < need:
        import dataclasses
        cfg = dataclasses.replace(cfg, max_cache_len=need)
    key = jax.random.PRNGKey(args.seed)
    from repro.models import init_params
    params = init_params(cfg, key)
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

    rng = np.random.default_rng(args.seed)
    b = args.batch
    if cfg.embed_inputs:
        batch = {"embeds": jnp.asarray(
            rng.standard_normal((b, args.prompt_len, cfg.d_model))
            .astype(np.float32) * 0.02)}
    else:
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (b, args.prompt_len)), jnp.int32)}
    if cfg.extra_embed_len:
        batch["patches"] = jnp.asarray(
            rng.standard_normal((b, cfg.extra_embed_len, cfg.d_model))
            .astype(np.float32) * 0.02)

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0
    cur = args.prompt_len + cfg.extra_embed_len
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

    t0 = time.perf_counter()
    outs = []
    for i in range(args.decode_steps):
        step_batch = (
            {"embeds": jnp.zeros((b, 1, cfg.d_model), cfg.cdtype())}
            if cfg.embed_inputs else {"tokens": tok[:, None]}
        )
        logits, cache = decode(params, cache, step_batch, jnp.int32(cur + i))
        tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        outs.append(np.asarray(tok))
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0
    toks = b * args.decode_steps
    print(f"arch={cfg.name} batch={b} prompt={args.prompt_len}")
    print(f"prefill: {t_prefill:.3f}s "
          f"({b*args.prompt_len/t_prefill:.0f} tok/s)")
    print(f"decode:  {t_decode:.3f}s ({toks/t_decode:.0f} tok/s, "
          f"{t_decode/args.decode_steps*1e3:.1f} ms/step)")
    print("sample tokens:", np.stack(outs)[:8, 0].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
