"""Extract roofline terms from a compiled (SPMD-partitioned) module.

* FLOPs / bytes-accessed: ``compiled.cost_analysis()`` (per-device program).
* Collective bytes: not in cost_analysis — parsed from the post-partitioning
  HLO text (``compiled.as_text()``): we sum operand sizes of every
  all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
  op (shapes in partitioned HLO are already per-device), and also keep a
  wire-bytes model per op kind (all-reduce moves ~2× its operand bytes on a
  ring; a gather's wire bytes are its output).

Hardware constants: TPU v5e-class chip — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (brief-specified).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# result then opcode:  %x = bf16[1,2]{...} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([0-9,]*)\][^\s]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_kind: dict  # sum of result (per-device) shape bytes
    wire_bytes_by_kind: dict  # ring wire model

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_wire_bytes(self) -> int:
        return sum(self.wire_bytes_by_kind.values())

    def to_json(self):
        return {
            "counts": self.counts,
            "bytes_by_kind": self.bytes_by_kind,
            "wire_bytes_by_kind": self.wire_bytes_by_kind,
            "total_bytes": self.total_bytes,
            "total_wire_bytes": self.total_wire_bytes,
        }


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts = {k: 0 for k in COLLECTIVES}
    nbytes = {k: 0 for k in COLLECTIVES}
    wire = {k: 0 for k in COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        tuple_body, dtype, dims, kind, suffix = m.groups()
        if suffix == "-done":
            continue  # async pair: the -start op already carried the shape
        counts[kind] += 1
        if tuple_body is not None:
            size = sum(
                _shape_bytes(d, s) for d, s in _SHAPE_RE.findall(tuple_body)
            )
        else:
            size = _shape_bytes(dtype, dims)
        nbytes[kind] += size
        # ring wire model per device
        if kind == "all-reduce":
            wire[kind] += 2 * size
        else:
            wire[kind] += size
    return CollectiveStats(counts=counts, bytes_by_kind=nbytes,
                           wire_bytes_by_kind=wire)


def roofline_terms(
    flops: float, bytes_accessed: float, collective_bytes: float,
) -> dict:
    """Three per-device roofline terms, in seconds."""
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_accessed / HBM_BW
    t_collective = collective_bytes / LINK_BW
    terms = {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_collective,
    }
    dominant = max(terms, key=terms.get)
    terms["dominant"] = dominant.replace("_s", "")
    terms["bound_s"] = terms[dominant]
    return terms
