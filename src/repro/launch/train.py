"""Training driver: data pipeline → jitted train step → Vault checkpoints.

Runs for real on this box (CPU, smoke-scale by default; ``--full`` selects
the published config — only sensible on a real cluster). Demonstrates the
paper's technique end-to-end: periodic Vault checkpoints into a simulated
peer network, an optional mid-run failure drill (``--kill-fraction``) that
fails peers *and* Byzantine-corrupts others, restore, and bit-exact resume
via the step-cursor data pipeline.

    PYTHONPATH=src python -m repro.launch.train --arch codeqwen1.5-7b \
        --steps 50 --batch 8 --seq 128 --ckpt-every 20 --kill-at 30 \
        --kill-fraction 0.2
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import VaultCheckpointer
from repro.core import chunks as C
from repro.core.network import SimNetwork
from repro.data import SyntheticStream
from repro.optim import AdamWConfig
from repro.runtime import StragglerDetector
from repro.training import init_train_state, make_train_step


def build_network(n_nodes: int, byz_fraction: float, seed: int = 0):
    net = SimNetwork(seed=seed)
    n_byz = int(n_nodes * byz_fraction)
    for i in range(n_nodes):
        net.add_node(byzantine=i < n_byz, seed=i.to_bytes(4, "little"))
    return net

def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b", choices=configs.ARCHS)
    ap.add_argument("--full", action="store_true",
                    help="published config instead of the smoke config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--vault-nodes", type=int, default=200)
    ap.add_argument("--byz-fraction", type=float, default=0.0)
    ap.add_argument("--kill-at", type=int, default=0,
                    help="step at which to run the failure drill")
    ap.add_argument("--kill-fraction", type=float, default=0.0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = (configs.full_config(args.arch)
           if args.full else configs.smoke_config(args.arch))
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=max(args.steps, 2),
                          warmup_steps=max(args.steps // 10, 1))
    stream = SyntheticStream(cfg, batch=args.batch, seq=args.seq,
                             seed=args.seed)
    key = jax.random.PRNGKey(args.seed)
    state = init_train_state(cfg, key)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(state["params"]))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M "
          f"steps={args.steps} batch={args.batch}x{args.seq}")
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, accum=args.accum),
                      donate_argnums=(0,))

    ckpt = None
    if args.ckpt_every:
        net = build_network(args.vault_nodes, args.byz_fraction, args.seed)
        ckpt = VaultCheckpointer(net, object_bytes=1 << 20)
        print(f"vault: {args.vault_nodes} peers "
              f"({args.byz_fraction:.0%} byzantine), "
              f"code ({ckpt.params.k_inner},{ckpt.params.r_inner}) inner / "
              f"({ckpt.params.k_outer},{ckpt.params.n_chunks}) outer")

    straggler = StragglerDetector()
    losses = []
    step = 0
    drilled = False
    while step < args.steps:
        t0 = time.perf_counter()
        batch = {k: jnp.asarray(v) for k, v in stream.batch_at(step).items()}
        state, metrics = step_fn(state, batch)
        dt = time.perf_counter() - t0
        straggler.record("host0", dt)
        losses.append(float(metrics["loss"]))
        step += 1
        if step % args.log_every == 0 or step == args.steps:
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt:.2f}s/step")
        if ckpt and step % args.ckpt_every == 0:
            host_state = jax.tree_util.tree_map(np.asarray, state)
            host_state["data_step"] = np.asarray(step)
            rep = ckpt.save(host_state, step)
            print(f"  [vault] saved step {step}: {rep.n_objects} objects, "
                  f"{rep.bytes/2**20:.1f} MiB, "
                  f"store latency {rep.store_latency_s:.2f}s (modeled)")
        if (ckpt and args.kill_at and step == args.kill_at
                and args.kill_fraction > 0 and not drilled):
            drilled = True
            net = ckpt.net
            alive = net.alive_nodes()
            kill = int(len(alive) * args.kill_fraction)
            rng = np.random.default_rng(args.seed)
            for node in rng.choice(alive, size=kill, replace=False):
                net.fail_node(node.nid)
            print(f"  [drill] killed {kill}/{len(alive)} peers; "
                  f"restoring latest checkpoint...")
            latest = ckpt.latest_step()
            restored = ckpt.restore(latest)
            data_step = int(restored.pop("data_step"))
            state = jax.tree_util.tree_map(jnp.asarray, restored)
            step = data_step
            print(f"  [drill] resumed from step {step} — "
                  f"restore OK with {kill} dead peers")
    first, last = losses[0], losses[-1]
    print(f"done: loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    for d in straggler.decisions():
        if d.action != "ok":
            print(f"straggler: {d}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
