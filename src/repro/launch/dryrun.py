import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count at first init.
# This module is the ONLY place the 512 placeholder devices exist — smoke
# tests and benches import through other entry points and see 1 device.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this:
  1. builds the full config (exact published shapes, bf16),
  2. resolves parameter/optimizer/cache/batch shardings from the logical
     rules (ZeRO-1 on optimizer moments),
  3. ``jax.jit(step).lower(ShapeDtypeStructs).compile()`` on the production
     mesh — (16,16) "data","model" single-pod and (2,16,16) "pod","data",
     "model" multi-pod — and records memory_analysis of the deployable
     scanned program,
  4. reconstructs exact per-device FLOPs / bytes / collective-bytes:
     ``cost_analysis`` counts a ``lax.scan`` body ONCE (trip count ignored),
     so we compile shallow *unrolled* depth variants (all-segments-depth-1,
     then each segment at depth 2), solve the linear system for per-layer
     costs, and extrapolate to full depth. Glue (embed/unembed/loss/
     optimizer-of-glue) comes out of the same solve.

Failures here (sharding mismatch, OOM at compile, unsupported collective)
are bugs in the system. Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --all [--skip-existing]
    PYTHONPATH=src python -m repro.launch.dryrun --arch codeqwen1.5-7b \
        --shape train_4k --mesh single
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.distributed import sharding as shd
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh, mesh_devices
from repro.models import (
    active_param_count,
    cache_specs,
    init_cache,
    init_params,
    param_count,
    param_specs,
)
from repro.models.common import LayerPattern
from repro.optim import AdamWConfig
from repro.training import init_train_state, make_decode_step, make_train_step
from repro.training.steps import make_prefill_step

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

# Tuned per-arch rule sets (§Perf): archs whose head counts don't divide the
# 16-way model axis run DP-heavy (batch over both axes, ZeRO/FSDP weight
# gathers) — uniform rules would replicate their attention compute 16×.
ARCH_RULES = {
    "musicgen-medium": "dp",
    "minicpm3-4b": "dp",
    "llava-next-34b": "dp",
}

BATCH_SPECS = {
    "tokens": ("batch", "length"),
    "labels": ("batch", "length"),
    "embeds": ("batch", "length", None),
    "patches": ("batch", None, None),
}


def _batch_shardings(specs: dict, mesh, rules):
    return {
        k: NamedSharding(
            mesh, shd.resolve_spec(BATCH_SPECS[k], v.shape, mesh, rules)
        )
        for k, v in specs.items()
    }


def _per_device_bytes(shardings, shapes) -> int:
    total = 0
    for sh, sd in zip(
        jax.tree_util.tree_leaves(shardings), jax.tree_util.tree_leaves(shapes)
    ):
        shard = sh.shard_shape(sd.shape)
        total += int(np.prod(shard)) * sd.dtype.itemsize
    return total


def _named(tree_of_specs, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_of_specs,
        is_leaf=lambda t: isinstance(t, P),
    )


def _build(cfg, shape: str, mesh, rules):
    """Build (jitted_step, lower_args, info) for one cell config."""
    sd = configs.SHAPES[shape]
    in_specs = configs.input_specs(cfg, shape)
    batch_sh = _batch_shardings(in_specs, mesh, rules)
    p_specs = param_specs(cfg)
    info: dict = {}
    if sd.kind == "train":
        state_shapes = jax.eval_shape(
            lambda k: init_train_state(cfg, k), jax.random.PRNGKey(0)
        )
        resolved = shd.tree_specs(p_specs, state_shapes["params"], mesh, rules)
        z1 = shd.zero1_tree(resolved, state_shapes["params"], mesh)
        state_sh = {
            "params": _named(resolved, mesh),
            "opt": {
                "mu": _named(z1, mesh),
                "nu": _named(z1, mesh),
                "step": NamedSharding(mesh, P()),
            },
        }
        jitted = jax.jit(
            make_train_step(cfg, AdamWConfig()),
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        )
        info["state_bytes_per_device"] = _per_device_bytes(
            state_sh, state_shapes
        )
        return jitted, (state_shapes, in_specs), info
    params_shapes = jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.PRNGKey(0)
    )
    resolved = shd.tree_specs(p_specs, params_shapes, mesh, rules)
    params_sh = _named(resolved, mesh)
    info["state_bytes_per_device"] = _per_device_bytes(
        params_sh, params_shapes
    )
    cache_shapes = jax.eval_shape(
        lambda: init_cache(cfg, sd.batch, cfg.cdtype())
    )
    cache_sh = shd.tree_shardings(cache_specs(cfg), cache_shapes, mesh, rules)
    info["cache_bytes_per_device"] = _per_device_bytes(
        cache_sh, cache_shapes
    )
    if sd.kind == "prefill":
        jitted = jax.jit(
            make_prefill_step(cfg),
            in_shardings=(params_sh, batch_sh),
            out_shardings=(None, cache_sh),
        )
        return jitted, (params_shapes, in_specs), info
    jitted = jax.jit(
        make_decode_step(cfg),
        in_shardings=(params_sh, cache_sh, batch_sh, NamedSharding(mesh, P())),
        out_shardings=(None, cache_sh),
        donate_argnums=(1,),
    )
    cur = jax.ShapeDtypeStruct((), jnp.int32)
    return jitted, (params_shapes, cache_shapes, in_specs, cur), info


def _compile(cfg, shape, mesh, rules):
    jitted, args, info = _build(cfg, shape, mesh, rules)
    with mesh, shd.logical_axis_rules(rules, mesh):
        t0 = time.time()
        lowered = jitted.lower(*args)
        info["lower_s"] = round(time.time() - t0, 2)
        t0 = time.time()
        compiled = lowered.compile()
        info["compile_s"] = round(time.time() - t0, 2)
    return compiled, info


def _metrics(compiled) -> dict:
    """Flat linear metrics of one compiled program."""
    out: dict = {}
    try:
        c = compiled.cost_analysis()
        if isinstance(c, (list, tuple)):
            c = c[0]
        out["flops"] = float(c.get("flops", 0.0))
        out["bytes_accessed"] = float(c.get("bytes accessed", 0.0))
    except Exception:
        out["flops"] = 0.0
        out["bytes_accessed"] = 0.0
    coll = H.parse_collectives(compiled.as_text())
    for k in H.COLLECTIVES:
        out[f"coll_count:{k}"] = float(coll.counts[k])
        out[f"coll_bytes:{k}"] = float(coll.bytes_by_kind[k])
        out[f"coll_wire:{k}"] = float(coll.wire_bytes_by_kind[k])
    out["coll_bytes_total"] = float(coll.total_bytes)
    out["coll_wire_total"] = float(coll.total_wire_bytes)
    return out


def _memory(compiled) -> dict:
    try:
        m = compiled.memory_analysis()
        if m is None:
            return {}
        return {
            k: int(getattr(m, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(m, k)
        }
    except Exception as e:  # pragma: no cover
        return {"error": repr(e)}


def _with_repeats(cfg, repeats: list[int]):
    """Shallow unrolled analysis variant. Inner tile loops are unrolled too
    (scan_unroll), with larger tiles to bound HLO size — attention/SSD
    flops are tile-size invariant and elementwise bytes nearly so."""
    pats = tuple(
        LayerPattern(r, p.block) for r, p in zip(repeats, cfg.patterns)
    )
    upd = {"pattern": pats, "scan_unroll": True}
    if cfg.attn_chunk:
        upd["attn_chunk"] = max(cfg.attn_chunk, 4096)
    upd["ssm_chunk"] = max(cfg.ssm_chunk, 1024)
    return dataclasses.replace(cfg, **upd)


def analyze_depth(cfg, shape, mesh, rules) -> dict:
    """Reconstruct full-depth per-device metrics from shallow unrolled
    variants: total(metric) = glue + Σ_seg repeat_seg · body_seg."""
    n_seg = len(cfg.patterns)
    base = [1] * n_seg
    f0, info0 = _compile(_with_repeats(cfg, base), shape, mesh, rules)
    m0 = _metrics(f0)
    bodies = []
    for i in range(n_seg):
        reps = list(base)
        reps[i] = 2
        fi, _ = _compile(_with_repeats(cfg, reps), shape, mesh, rules)
        mi = _metrics(fi)
        bodies.append({k: max(mi[k] - m0[k], 0.0) for k in m0})
    glue = {
        k: max(m0[k] - sum(b[k] for b in bodies), 0.0) for k in m0
    }
    total = {
        k: glue[k]
        + sum(cfg.patterns[i].repeat * bodies[i][k] for i in range(n_seg))
        for k in m0
    }
    return {
        "total": total,
        "glue": glue,
        "bodies": bodies,
        "analysis_compile_s": info0["compile_s"],
    }


def run_cell(
    arch: str, shape: str, mesh_kind: str, rules=None, write: bool = True,
    tag: str = "", cfg_override=None, analyze: bool = True,
    compile_full: bool = True,
) -> dict:
    t_start = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh_devices(mesh)
    cfg = cfg_override or configs.full_config(arch, shape)
    sd = configs.SHAPES[shape]
    rules = rules or shd.DEFAULT_RULES
    rec: dict = {
        "arch": arch, "shape": shape, "mesh": mesh_kind,
        "devices": n_dev, "kind": sd.kind, "tag": tag,
        "params_total": param_count(cfg),
        "params_active": active_param_count(cfg),
    }
    tokens = sd.batch * sd.seq
    if sd.kind == "train":
        rec["model_flops_global"] = 6.0 * rec["params_active"] * tokens
    elif sd.kind == "prefill":
        rec["model_flops_global"] = 2.0 * rec["params_active"] * tokens
    else:
        rec["model_flops_global"] = 2.0 * rec["params_active"] * sd.batch
    try:
        if compile_full:
            compiled, info = _compile(cfg, shape, mesh, rules)
            rec.update(info)
            rec["memory_analysis"] = _memory(compiled)
            rec["scanned_metrics"] = _metrics(compiled)
            del compiled
        if analyze:
            depth = analyze_depth(cfg, shape, mesh, rules)
            rec["per_layer"] = {
                "glue": depth["glue"], "bodies": depth["bodies"],
            }
            tot = depth["total"]
            rec["flops_per_device"] = tot["flops"]
            rec["bytes_accessed_per_device"] = tot["bytes_accessed"]
            rec["collective_bytes_per_device"] = tot["coll_bytes_total"]
            rec["collective_wire_bytes_per_device"] = tot["coll_wire_total"]
            rec["collective_detail"] = {
                k: tot[f"coll_bytes:{k}"] for k in H.COLLECTIVES
            }
            rec["collective_counts"] = {
                k: tot[f"coll_count:{k}"] for k in H.COLLECTIVES
            }
            rec["roofline"] = H.roofline_terms(
                tot["flops"], tot["bytes_accessed"], tot["coll_wire_total"]
            )
            rec["hlo_model_flops_ratio"] = rec["model_flops_global"] / max(
                tot["flops"] * n_dev, 1.0
            )
        rec["ok"] = True
    except Exception as e:
        rec["ok"] = False
        rec["error"] = repr(e)
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["wall_s"] = round(time.time() - t_start, 2)
    if write:
        RESULTS.mkdir(parents=True, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        path = RESULTS / f"{arch}__{shape}__{mesh_kind}{suffix}.json"
        path.write_text(json.dumps(rec, indent=2, default=str))
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCHS)
    ap.add_argument("--shape", choices=tuple(configs.SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-analysis", action="store_true")
    ap.add_argument("--no-full-compile", action="store_true",
                    help="skip the scanned full-depth compile (fast "
                         "iteration on the analysis metrics)")
    ap.add_argument("--rules", choices=tuple(shd.RULE_SETS), default=None,
                    help="override the tuned per-arch rule selection")
    ap.add_argument("--tag", default="",
                    help="suffix for the result JSON (perf experiments)")
    args = ap.parse_args()
    cells = configs.cells() if args.all else [(args.arch, args.shape)]
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    failures = 0
    for arch, shape in cells:
        for mesh_kind in meshes:
            path = RESULTS / f"{arch}__{shape}__{mesh_kind}.json"
            if args.skip_existing and path.exists():
                if json.loads(path.read_text()).get("ok"):
                    print(f"[skip] {arch} {shape} {mesh_kind}", flush=True)
                    continue
            rule_name = args.rules or ARCH_RULES.get(arch, "default")
            rec = run_cell(arch, shape, mesh_kind,
                           rules=shd.RULE_SETS[rule_name],
                           tag=args.tag,
                           analyze=not args.no_analysis,
                           compile_full=not args.no_full_compile)
            if rec["ok"]:
                r = rec.get("roofline", {})
                print(
                    f"[ok]   {arch:22s} {shape:12s} {mesh_kind:6s} "
                    f"compile={rec.get('compile_s', 0):7.1f}s "
                    f"flops/dev={rec.get('flops_per_device', 0):.3e} "
                    f"dom={r.get('dominant', '?'):10s} "
                    f"bound={r.get('bound_s', 0):.4f}s wall={rec['wall_s']}s",
                    flush=True,
                )
            else:
                failures += 1
                print(f"[FAIL] {arch} {shape} {mesh_kind}: {rec['error']}",
                      flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
