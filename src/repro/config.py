"""One place for computation-environment knobs (the bayespec mold).

Benchmarks, tests, CI jobs, and ad-hoc scripts all need the same four
decisions made *before* JAX initializes its backends: float precision,
platform, virtual host-device count, and NaN debugging. Historically each
entry point re-derived them (``scripts/test.sh`` in bash,
``tests/conftest.py`` for subprocesses, ``scripts/smoke_devices.py`` by
hand); this module is the single source of truth they all consume.

Environment knobs (all optional):

* ``XLA_DEVICES`` — virtual host device count
  (``--xla_force_host_platform_device_count``). The scenario engine's
  ``devices=`` axis shards over these; see ``scenarios._compile_runner``.
* ``REPRO_PLATFORM`` — ``cpu`` / ``gpu`` / ``tpu``
  (``jax_platform_name``; GPU also gets the XLA perf-flag recipe).
* ``REPRO_X64`` — truthy enables float64 (``jax_enable_x64``).
* ``REPRO_DEBUG_NANS`` — truthy enables ``jax_debug_nans``.
* ``JAX_COMPILATION_CACHE_DIR`` — persistent compile cache. Entries are
  NOT portable across host topologies (the cache key does not cover the
  device-count flag, and replaying a foreign-topology entry returns
  corrupted executables — see :func:`cache_dir`), so the directory is
  always keyed by the device count.

Import discipline: this module never imports ``jax`` at the top level, so
the pre-init knobs (:func:`set_host_devices`, :func:`cache_dir`,
:func:`subprocess_env`) are safe to call before the first ``import jax``
— and ``python -m repro.config`` (the shell exporter ``scripts/test.sh``
evals) never pays for a JAX import at all.
"""
from __future__ import annotations

import os
import re
import sys

# Matches the device-count flag (with its value) inside an XLA_FLAGS string.
_DEVICE_FLAG_RE = re.compile(
    r"--xla_force_host_platform_device_count=\d+\s*")
# A cache dir already keyed by device count ("...-d8") — see cache_base().
_CACHE_KEY_RE = re.compile(r"-d\d+$")

DEFAULT_CACHE_BASE = os.path.join(
    os.path.expanduser("~"), ".cache", "repro-jax-cache")

# The XLA perf-flag recipe for GPU runs (bayespec's set_platform; see
# https://jax.readthedocs.io/en/latest/gpu_performance_tips.html).
GPU_XLA_FLAGS = (
    "--xla_gpu_triton_gemm_any=True "
    "--xla_gpu_enable_latency_hiding_scheduler=true "
    "--xla_gpu_enable_highest_priority_async_stream=true"
)


# ------------------------------------------------------- pre-init (env) ---
def device_flags(devices: int, base: str | None = None) -> str:
    """XLA_FLAGS string forcing ``devices`` virtual host devices.

    Any device-count flag already present in ``base`` is replaced; every
    other flag is preserved. Pure string function — usable for building
    subprocess environments without touching this process.
    """
    rest = _DEVICE_FLAG_RE.sub("", base or "").strip()
    flag = f"--xla_force_host_platform_device_count={int(devices)}"
    return f"{flag} {rest}".strip()


def set_host_devices(devices: int) -> None:
    """Force ``devices`` virtual host devices in THIS process.

    Only takes effect before JAX initializes its backends (the flag is
    read at backend setup, not at ``import jax``). Unlike bayespec's
    ``set_cpu_cores`` this deliberately does not clamp to the physical
    core count: oversubscribed virtual devices are exactly how CI
    exercises the sharded dispatch path on small runners.
    """
    os.environ["XLA_FLAGS"] = device_flags(
        devices, os.environ.get("XLA_FLAGS"))


def cache_base(env: dict | None = None) -> str:
    """Un-keyed base path of the persistent compilation cache.

    Resolution order: ``REPRO_JAX_CACHE_BASE``, then
    ``JAX_COMPILATION_CACHE_DIR`` with any existing ``-d<N>`` topology
    suffix stripped (so consumers can re-key an already-keyed dir), then
    :data:`DEFAULT_CACHE_BASE`.
    """
    env = os.environ if env is None else env
    base = env.get("REPRO_JAX_CACHE_BASE")
    if base:
        return base
    cur = env.get("JAX_COMPILATION_CACHE_DIR")
    if cur:
        return _CACHE_KEY_RE.sub("", cur)
    return DEFAULT_CACHE_BASE


def cache_dir(devices: int, env: dict | None = None) -> str:
    """Compilation-cache directory keyed by host topology.

    The cache key does NOT cover ``xla_force_host_platform_device_count``;
    replaying an entry compiled under a different topology returns
    corrupted executables (uninitialized output buffers — bitten by the
    8-device CI leg), so every device count gets its own directory.
    """
    return f"{cache_base(env)}-d{int(devices)}"


def subprocess_env(devices: int, env: dict | None = None) -> dict:
    """Environment for a child process pinned to ``devices`` host devices.

    Sets the device-count flag (pre-init, so the child sees it) and a
    topology-keyed compilation-cache dir. Used by ``tests/conftest.py``
    and the device-scaling study in ``benchmarks/engine_speed.py``.
    """
    env = dict(os.environ if env is None else env)
    env["XLA_FLAGS"] = device_flags(devices, env.get("XLA_FLAGS"))
    env["JAX_COMPILATION_CACHE_DIR"] = cache_dir(devices, env)
    return env


# ----------------------------------------------------- jax.config knobs ---
def enable_x64(use_x64: bool = True) -> None:
    """Default JAX arrays to float64 (else float32)."""
    import jax

    jax.config.update("jax_enable_x64", bool(use_x64))


def set_platform(platform: str = "cpu") -> None:
    """Pin the JAX platform (``cpu`` / ``gpu`` / ``tpu``).

    Only takes effect at the beginning of the program; ``gpu`` also
    applies :data:`GPU_XLA_FLAGS` (preserving any flags already set).
    """
    import jax

    jax.config.update("jax_platform_name", platform)
    if platform == "gpu":
        prev = os.environ.get("XLA_FLAGS", "")
        extra = " ".join(f for f in GPU_XLA_FLAGS.split() if f not in prev)
        if extra:
            os.environ["XLA_FLAGS"] = f"{prev} {extra}".strip()


def set_debug_nan(flag: bool = True) -> None:
    """Raise on the first NaN any jitted computation produces."""
    import jax

    jax.config.update("jax_debug_nans", bool(flag))


def _truthy(val: str | None) -> bool:
    return (val or "").strip().lower() not in ("", "0", "false", "no")


def apply_env(env: dict | None = None) -> dict:
    """Apply every knob present in the environment; return what was set.

    The one-call setup path shared by ``benchmarks/common.py`` (import
    time) and ad-hoc scripts. Must run before JAX initializes backends
    for the device count / platform to stick.
    """
    env = os.environ if env is None else env
    applied: dict = {}
    devices = env.get("XLA_DEVICES")
    if devices:
        set_host_devices(int(devices))
        os.environ.setdefault(
            "JAX_COMPILATION_CACHE_DIR", cache_dir(int(devices), env))
        applied["devices"] = int(devices)
    if env.get("REPRO_PLATFORM"):
        set_platform(env["REPRO_PLATFORM"])
        applied["platform"] = env["REPRO_PLATFORM"]
    if _truthy(env.get("REPRO_X64")):
        enable_x64(True)
        applied["x64"] = True
    if _truthy(env.get("REPRO_DEBUG_NANS")):
        set_debug_nan(True)
        applied["debug_nans"] = True
    return applied


# -------------------------------------------------------- shell exporter --
def shell_exports(env: dict | None = None) -> list[str]:
    """``export KEY="VAL"`` lines for shell consumers (scripts/test.sh).

    Derives XLA_FLAGS (device count from ``XLA_DEVICES``, default 1) and
    a topology-keyed JAX_COMPILATION_CACHE_DIR from the same rules the
    Python consumers use, so bash and Python can never drift.
    """
    env = os.environ if env is None else env
    devices = int(env.get("XLA_DEVICES") or 1)
    return [
        f'export XLA_FLAGS="{device_flags(devices, env.get("XLA_FLAGS"))}"',
        f'export JAX_COMPILATION_CACHE_DIR="{cache_dir(devices, env)}"',
    ]


def main(argv: list[str] | None = None) -> int:
    """CLI: print shell export lines (``eval "$(python -m repro.config)"``)."""
    for line in shell_exports():
        print(line)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
