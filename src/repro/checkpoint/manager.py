"""VAULT-backed distributed checkpointing — the paper's technique as the
framework's durability layer (DESIGN.md §2).

A train-state pytree is flattened to leaves, leaves are packed into
fixed-budget byte *objects*, and each object is STOREd through the VAULT
client protocol (outer code → opaque chunks → VRF-selected fragment groups).
Restore QUERYs any ``K_outer`` chunks per object / ``K_inner`` fragments per
chunk — so the checkpoint survives Byzantine peers (≤1/3), targeted attacks
on ≤ the Lemma-4.2 budget, and arbitrary node churn between save and
restore, with ~3.1× redundancy instead of 3× full replication at far weaker
guarantees.

Three interchangeable backends (same interface, same manifest):
* ``VaultCheckpointer``      — the paper's protocol (this work);
* ``ReplicatedCheckpointer`` — Ceph-like r=3 baseline (paper §6.1);
* ``LocalCheckpointer``      — plain files (centralized; the thing a
  decentralized deployment cannot rely on — kept for dev loops and as the
  restart-speed reference).

In a real multi-host deployment every host checkpoints its own shard
(objects are per-host; the manifest is tiny and itself Vault-stored); here
the in-process simulated network plays the peer set, which exercises the
identical protocol path.
"""
from __future__ import annotations

import dataclasses
import io
import json
import pathlib
import pickle
import time
from typing import Any

import jax
import numpy as np

from repro.core import chunks as C
from repro.core.baseline import ReplicatedStore
from repro.core.network import SimNetwork
from repro.core.vault import VaultClient

DEFAULT_OBJECT_BYTES = 4 << 20  # pack leaves into ~4 MiB objects


# ----------------------------------------------------------- (de)serialize
def flatten_state(state) -> tuple[list[tuple[str, np.ndarray]], Any]:
    """Pytree -> [(path, ndarray)] + treedef (host copies, any sharding)."""
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(state)
    flat, treedef = leaves_with_paths
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out.append((key, np.asarray(leaf)))
    return out, treedef


def unflatten_state(treedef, arrays: list[np.ndarray]):
    return jax.tree_util.tree_unflatten(treedef, arrays)


def pack_objects(
    leaves: list[tuple[str, np.ndarray]], object_bytes: int,
) -> tuple[list[bytes], list[dict]]:
    """Pack leaves into byte objects of ~object_bytes; large leaves span
    multiple objects. Returns (objects, manifest_entries)."""
    objects: list[bytes] = []
    entries: list[dict] = []
    buf = io.BytesIO()

    def flush():
        if buf.tell():
            objects.append(buf.getvalue())
            buf.seek(0)
            buf.truncate()

    for key, arr in leaves:
        raw = arr.tobytes()
        spans = []
        off = 0
        while off < len(raw) or (len(raw) == 0 and not spans):
            room = object_bytes - buf.tell()
            if room <= 0:
                flush()
                room = object_bytes
            take = min(room, len(raw) - off)
            spans.append((len(objects), buf.tell(), take))
            buf.write(raw[off : off + take])
            off += take
            if off >= len(raw):
                break
        entries.append({
            "key": key,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "spans": spans,  # (object_index, offset, length)
        })
    flush()
    return objects, entries


def unpack_objects(objects: list[bytes], entries: list[dict]):
    arrays = []
    for e in entries:
        raw = b"".join(
            objects[oi][off : off + ln] for oi, off, ln in e["spans"]
            if ln > 0  # zero-size leaves carry a placeholder span
        )
        arrays.append(
            np.frombuffer(raw, dtype=np.dtype(e["dtype"])).reshape(e["shape"])
        )
    return arrays


# ------------------------------------------------------------- checkpointer
@dataclasses.dataclass
class SaveReport:
    step: int
    n_objects: int
    bytes: int
    wall_s: float
    store_latency_s: float  # modeled network latency (parallel stores)


class VaultCheckpointer:
    def __init__(
        self, net: SimNetwork, client_node=None,
        params: C.CodeParams | None = None,
        object_bytes: int = DEFAULT_OBJECT_BYTES, cache_ttl: float = 0.0,
        backend: str = "numpy",
    ):
        self.net = net
        self.client = VaultClient(
            net, client_node or net.alive_nodes()[0], backend=backend
        )
        self.params = params or C.CodeParams()
        self.object_bytes = object_bytes
        self.cache_ttl = cache_ttl
        self.manifests: dict[int, dict] = {}

    def save(self, state, step: int) -> SaveReport:
        t0 = time.perf_counter()
        leaves, treedef = flatten_state(state)
        objects, entries = pack_objects(leaves, self.object_bytes)
        oids = []
        worst = 0.0
        total = 0
        for obj in objects:
            oid, stats = self.client.store(
                obj, self.params, cache_ttl=self.cache_ttl
            )
            oids.append(oid)
            worst = max(worst, stats.latency_s)  # objects stored in parallel
            total += len(obj)
        self.manifests[step] = {
            "entries": entries,
            "oids": oids,
            "treedef": treedef,
            "step": step,
        }
        return SaveReport(
            step=step, n_objects=len(objects), bytes=total,
            wall_s=time.perf_counter() - t0, store_latency_s=worst,
        )

    def restore(self, step: int):
        man = self.manifests[step]
        objects = []
        for oid in man["oids"]:
            data, _stats = self.client.query(oid)
            objects.append(data)
        arrays = unpack_objects(objects, man["entries"])
        return unflatten_state(man["treedef"], arrays)

    def latest_step(self) -> int | None:
        return max(self.manifests) if self.manifests else None


class ReplicatedCheckpointer:
    """Ceph-like r=3 baseline over the same network/failure model."""

    def __init__(self, net: SimNetwork, client_node=None,
                 replication: int = 3,
                 object_bytes: int = DEFAULT_OBJECT_BYTES):
        self.store = ReplicatedStore(net, replication)
        self.client_node = client_node or net.alive_nodes()[0]
        self.object_bytes = object_bytes
        self.manifests: dict[int, dict] = {}

    def save(self, state, step: int) -> SaveReport:
        t0 = time.perf_counter()
        leaves, treedef = flatten_state(state)
        objects, entries = pack_objects(leaves, self.object_bytes)
        rids = []
        worst = 0.0
        total = 0
        for obj in objects:
            rid, stats = self.store.store(self.client_node, obj)
            rids.append(rid)
            worst = max(worst, stats.latency_s)
            total += len(obj)
        self.manifests[step] = {
            "entries": entries, "rids": rids, "treedef": treedef,
        }
        return SaveReport(step, len(objects), total,
                          time.perf_counter() - t0, worst)

    def restore(self, step: int):
        man = self.manifests[step]
        objects = [
            self.store.query(self.client_node, rid)[0] for rid in man["rids"]
        ]
        arrays = unpack_objects(objects, man["entries"])
        return unflatten_state(man["treedef"], arrays)


class LocalCheckpointer:
    """Centralized file checkpoints (dev loops / restart-speed reference)."""

    def __init__(self, directory: str | pathlib.Path):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)

    def save(self, state, step: int) -> SaveReport:
        t0 = time.perf_counter()
        leaves, treedef = flatten_state(state)
        objects, entries = pack_objects(leaves, DEFAULT_OBJECT_BYTES)
        path = self.dir / f"step_{step:08d}.ckpt"
        with open(path, "wb") as f:
            pickle.dump({"objects": objects, "entries": entries,
                         "treedef": treedef}, f)
        total = sum(len(o) for o in objects)
        return SaveReport(step, len(objects), total,
                          time.perf_counter() - t0, 0.0)

    def restore(self, step: int):
        path = self.dir / f"step_{step:08d}.ckpt"
        with open(path, "rb") as f:
            man = pickle.load(f)
        arrays = unpack_objects(man["objects"], man["entries"])
        return unflatten_state(man["treedef"], arrays)

    def latest_step(self) -> int | None:
        steps = sorted(
            int(p.stem.split("_")[1]) for p in self.dir.glob("step_*.ckpt")
        )
        return steps[-1] if steps else None
