from repro.checkpoint.manager import (  # noqa: F401
    LocalCheckpointer,
    ReplicatedCheckpointer,
    VaultCheckpointer,
    flatten_state,
    pack_objects,
    unflatten_state,
    unpack_objects,
)
