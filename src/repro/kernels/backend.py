"""Backend detection shared by the Pallas kernels and their wrappers.

Pallas kernels take an ``interpret`` flag: ``True`` runs the kernel body
through the interpreter (so it executes — and is validated — on CPU),
``False`` compiles it for the accelerator.  Every kernel entry point
defaults the flag to ``None`` and resolves it here, so real TPU runs get
compiled kernels without each call site having to thread the choice.
"""
from __future__ import annotations

import jax


def default_interpret() -> bool:
    """Interpret-mode default: only a real TPU backend compiles kernels."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    return default_interpret() if interpret is None else bool(interpret)
