"""Pallas TPU kernel: GF(2) (XOR) LT-code encode over bit-packed words.

Computes ``out[r, w] = XOR_{k : mask[r,k]=1} words[k, w]`` where ``words``
packs 4 payload bytes per int32 lane. This is the LT-code variant of the
fragment-generation hot spot: pure XOR/select VPU work, 4 bytes per lane
(4x the effective bandwidth of the GF(256) kernel's byte-per-lane layout).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret

DEFAULT_TILE_R = 8
DEFAULT_TILE_W = 512


def _xor_kernel(m_ref, d_ref, o_ref, *, k_dim: int):
    m = m_ref[...]  # (TR, K) int32 in {0,1}
    d = d_ref[...]  # (K, TW) int32

    def body(k, acc):
        sel = jax.lax.dynamic_slice(m, (0, k), (m.shape[0], 1))  # (TR, 1)
        row = jax.lax.dynamic_slice(d, (k, 0), (1, d.shape[1]))  # (1, TW)
        return acc ^ jnp.where(sel != 0, row, 0)

    acc = jnp.zeros((m.shape[0], d.shape[1]), jnp.int32)
    o_ref[...] = jax.lax.fori_loop(0, k_dim, body, acc)


@functools.partial(jax.jit, static_argnames=("tile_r", "tile_w", "interpret"))
def gf2_encode_kernel(
    masks: jax.Array,
    words: jax.Array,
    tile_r: int = DEFAULT_TILE_R,
    tile_w: int = DEFAULT_TILE_W,
    interpret: bool | None = None,
) -> jax.Array:
    """masks (R, K) int32, words (K, W) int32 -> (R, W) int32."""
    r, k = masks.shape
    k2, w = words.shape
    assert k == k2
    assert r % tile_r == 0 and w % tile_w == 0, (r, w, tile_r, tile_w)
    interpret = resolve_interpret(interpret)
    grid = (r // tile_r, w // tile_w)
    return pl.pallas_call(
        functools.partial(_xor_kernel, k_dim=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_r, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tile_w), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tile_r, tile_w), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, w), jnp.int32),
        interpret=interpret,
    )(masks, words)
