"""Pallas TPU kernel: GF(256) rateless-code encode (coeff-matrix x blocks).

Computes ``out[r, l] = XOR_k gfmul(coeffs[r, k], data[k, l])`` — the inner
loop of VAULT fragment generation (the hot spot the paper covers with
wirehair on CPU, Fig. 10).

TPU adaptation: the field multiply is bit-sliced (8 rounds of
AND/XOR/shift/select), so the kernel is pure VPU element-wise work with no
gathers. Tiling: the coefficient tile (TR, K) stays resident in VMEM across
the payload dimension; payload tiles are lane-aligned multiples of 128.
Operands are carried as int32 byte values (one byte per lane) — a production
variant would bit-pack 4 bytes/lane; see kernels/EXAMPLE.md discussion in
DESIGN.md §4.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret

from repro.core.gf import GF_POLY

DEFAULT_TILE_R = 8
DEFAULT_TILE_L = 512


def _gfmul_tile(a, b):
    """Bit-sliced GF(256) multiply; a: (TR, 1) int32, b: (1, TL) int32."""
    res = jnp.zeros((a.shape[0], b.shape[1]), jnp.int32)
    for _ in range(8):
        res = res ^ jnp.where((b & 1) != 0, a, 0)
        hi = a & 0x80
        a = (a << 1) & 0xFF
        a = jnp.where(hi != 0, a ^ (GF_POLY & 0xFF), a)
        b = b >> 1
    return res


def _encode_kernel(c_ref, d_ref, o_ref, *, k_dim: int):
    c = c_ref[...]  # (TR, K) int32
    d = d_ref[...]  # (K, TL) int32

    def body(k, acc):
        a = jax.lax.dynamic_slice(c, (0, k), (c.shape[0], 1))  # (TR, 1)
        b = jax.lax.dynamic_slice(d, (k, 0), (1, d.shape[1]))  # (1, TL)
        return acc ^ _gfmul_tile(a, b)

    acc = jnp.zeros((c.shape[0], d.shape[1]), jnp.int32)
    o_ref[...] = jax.lax.fori_loop(0, k_dim, body, acc)


@functools.partial(jax.jit, static_argnames=("tile_r", "tile_l", "interpret"))
def gf256_encode_kernel(
    coeffs: jax.Array,
    data: jax.Array,
    tile_r: int = DEFAULT_TILE_R,
    tile_l: int = DEFAULT_TILE_L,
    interpret: bool | None = None,
) -> jax.Array:
    """coeffs (R, K) int32, data (K, L) int32 -> (R, L) int32.

    R must be a multiple of tile_r and L of tile_l (ops.py pads).
    """
    r, k = coeffs.shape
    k2, l = data.shape
    assert k == k2, (coeffs.shape, data.shape)
    assert r % tile_r == 0 and l % tile_l == 0, (r, l, tile_r, tile_l)
    interpret = resolve_interpret(interpret)
    grid = (r // tile_r, l // tile_l)
    return pl.pallas_call(
        functools.partial(_encode_kernel, k_dim=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_r, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tile_l), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tile_r, tile_l), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, l), jnp.int32),
        interpret=interpret,
    )(coeffs, data)
