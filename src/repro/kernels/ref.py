"""Pure-jnp oracles for the encode kernels (shape/dtype-identical)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.gf import gf_mul_jnp_tables


def gf256_encode_ref(coeffs: jax.Array, data: jax.Array) -> jax.Array:
    """coeffs (R, K) int32, data (K, L) int32 -> (R, L) int32."""
    coeffs = jnp.asarray(coeffs, jnp.int32)
    data = jnp.asarray(data, jnp.int32)
    k = coeffs.shape[1]

    def body(j, acc):
        a = jax.lax.dynamic_slice_in_dim(coeffs, j, 1, axis=1)  # (R, 1)
        b = jax.lax.dynamic_slice_in_dim(data, j, 1, axis=0)  # (1, L)
        return acc ^ gf_mul_jnp_tables(a, b)

    acc = jnp.zeros((coeffs.shape[0], data.shape[1]), jnp.int32)
    return jax.lax.fori_loop(0, k, body, acc)


def prf_select_ref(tags: jax.Array, fhashes: jax.Array) -> jax.Array:
    """tags (N,2) int32, fhashes (F,2) int32 -> (N,F) int32 (ARX PRF)."""
    from repro.kernels.prf_select import arx_mix

    tags = jnp.asarray(tags, jnp.int32)
    fhashes = jnp.asarray(fhashes, jnp.int32)
    a = tags[:, 0:1]
    b = tags[:, 1:2]
    c = fhashes[:, 0:1].T
    d = fhashes[:, 1:2].T
    return arx_mix(a, b, c, d)


def gf2_encode_ref(masks: jax.Array, words: jax.Array) -> jax.Array:
    """masks (R, K) int32, words (K, W) int32 -> (R, W) int32."""
    masks = jnp.asarray(masks, jnp.int32)
    words = jnp.asarray(words, jnp.int32)
    k = masks.shape[1]

    def body(j, acc):
        sel = jax.lax.dynamic_slice_in_dim(masks, j, 1, axis=1)  # (R, 1)
        row = jax.lax.dynamic_slice_in_dim(words, j, 1, axis=0)  # (1, W)
        return acc ^ jnp.where(sel != 0, row, 0)

    acc = jnp.zeros((masks.shape[0], words.shape[1]), jnp.int32)
    return jax.lax.fori_loop(0, k, body, acc)
