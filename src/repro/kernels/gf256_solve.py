"""Pallas TPU kernel: batched GF(256) Gaussian solve (rateless decode).

Solves ``coeffs[b] @ X[b] = symbols[b]`` over GF(2^8) for a batch of
independent systems — the decode side of the RLNC rateless code
(``rateless.gf256_gaussian_solve``), which sits on the repair hot path:
every chunk repair that cannot be served from a warm cache pulls >= k
fragments and solves one such system.

The scalar reference solver maintains ``row == col`` throughout (each
column either finds a pivot at-or-below the diagonal and advances, or the
whole solve fails), so the batched form can run a fixed ``k``-step
Gauss-Jordan schedule: per column, pivot search is a masked first-nonzero
reduction over the trailing rows, the row swap is a pair of masked-select
rewrites (no gathers — TPU VPU friendly), the pivot inverse is the
addition-chain ``a^254 = a^2·a^4·a^8·a^16·a^32·a^64·a^128`` on the
bit-sliced multiplier, and elimination clears the column in *all* other
rows. Rank-deficient systems do not raise mid-kernel: each batch element
carries a sticky ``ok`` flag plus the first failing column, and the caller
(``rateless``) re-raises ``InsufficientFragments`` with the exact message
the scalar path produces.

Dispatch: :func:`gf256_solve_batch` mirrors the kernel in vectorized numpy
(bit-identical to the scalar reference on full-rank systems — pinned by
``tests/test_gf256_solve.py``) and routes to the Pallas kernel only above
a work threshold; in-simulator solves are single small systems and stay on
the numpy mirror, while benchmark/test batches exercise the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.gf import GF_EXP, GF_LOG, GF_POLY, gf_mul_np
from repro.kernels.backend import resolve_interpret

# below this many total symbol bytes (B*m*L) the numpy mirror wins: the
# in-sim decode is one (m ~ k+epsilon, L ~ fragment) system at a time,
# far under the threshold, so the simulator never pays a jax dispatch.
SOLVE_KERNEL_MIN = 1 << 16


# ------------------------------------------------------------ numpy mirror
# Sentinel log/exp pair for the single-system solver: _LOG2[0] is pushed to
# 1020, past every reachable true-log sum (max 254 + 254 + 255 = 763), and
# _EXP2 maps the whole sentinel range to 0 — so one fused gather computes
# exp[log f + log row - log pv] with GF(256) zero-propagation built in: no
# mod-255, no zero masks. exp2[i] = exp[i % 255] on the live range.
_LOG2 = GF_LOG.astype(np.int32).copy()
_LOG2[0] = 1020
_EXP2 = np.zeros(2560, np.uint8)
_EXP2[:765] = GF_EXP[np.arange(765) % 255]


def _solve1(
    a: np.ndarray, y: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Single-system fast path (``B == 1``) on one augmented matrix.

    Runs the exact pivot/elimination schedule of the batched loop below —
    identical over GF(256), which is exact integer algebra — but drops the
    batch axis and the per-step batch bookkeeping. The in-simulator repair
    decode solves one (m ~ k, L ~ fragment) system per repaired fragment,
    so this path's per-step numpy overhead is what the repair tick
    actually pays. Early-exits on the first rank-deficient column (the
    solution rows are garbage whenever ``ok`` is False either way).
    """
    m, k = a.shape
    aug = np.concatenate([a, y], axis=1)   # one array, half the op count
    exp2, log2 = _EXP2, _LOG2
    piv_log = np.empty(k, np.int32)
    for col in range(k):
        pv = aug[col, col]
        if pv == 0:
            nz = aug[col:, col] != 0
            if not nz.any():
                return (aug[:k, k:], np.zeros(1, bool),
                        np.full(1, col, np.int32))
            piv = col + int(np.argmax(nz))
            aug[[col, piv]] = aug[[piv, col]]
            pv = aug[col, col]
        row = aug[col]
        lpv = int(log2[pv])
        piv_log[col] = lpv
        # unnormalized Jordan step: subtract (f_i / pv) * row from every
        # other row — prod = exp2[log f + log row - log pv] in one fused
        # gather (sentinel logs zero-propagate). Leaving the pivot row
        # unnormalized keeps the pass this short; the diagonal is fixed
        # up once at the end (exact field algebra — identical solution).
        prod = exp2[log2[aug[:, col]][:, None] + (log2[row] + (255 - lpv))]
        prod[col] = 0
        aug ^= prod
    # rows hold pv_i * x_i — one vectorized normalize settles the output
    sol = aug[:k, k:]
    return (exp2[log2[sol] + (255 - piv_log)[:, None]],
            np.ones(1, bool), np.full(1, -1, np.int32))


def gf256_rank_prefix(coeffs: np.ndarray) -> tuple[bool, int]:
    """Minimum row prefix of ``coeffs`` (m, k) with full column rank.

    Returns ``(ok, n_pull)``. ``ok`` is False iff the *whole* row set is
    rank-deficient (``n_pull`` is then ``m``). Otherwise ``n_pull`` is the
    smallest prefix length whose rows solve — exactly the fragment count
    the incremental one-more-row retry loop in ``repair._pull_and_decode``
    reaches, at rank-only cost (no payload columns):

    The greedy at-or-below-diagonal pivot rule means appending rows below
    a prefix never changes the pivots chosen *within* that prefix (pivot
    search scans top-down, and eliminating a lower row never feeds back
    into upper rows), so the per-prefix retry runs nest and one
    row-echelon pass over the full matrix decides them all: the minimal
    solving prefix is ``1 + max(original row index of any pivot)``, and
    no prefix solves iff the full matrix is rank-deficient. Pivot choice
    matches ``_solve1``/``gf256_gaussian_solve_ref`` exactly (first
    nonzero at/below the diagonal).
    """
    a_full = np.asarray(coeffs, np.uint8)
    m, k = a_full.shape
    if m < k:
        return False, m
    exp2, log2 = _EXP2, _LOG2
    # Fast path: eliminate the k x k prefix alone. Pivot search scans
    # top-down, so as long as every column finds a pivot inside the first
    # k rows the full-matrix pass would choose the identical pivots (rows
    # below k are reachable only once the prefix runs out of nonzeros in
    # a column) — and then every pivot's original row index is < k, so
    # ``deep`` is decided by the prefix too. Rows k..m-1 receive the same
    # eliminations in the full pass but never feed back into the prefix,
    # so skipping them changes nothing. ~1/255 of random draws miss a
    # prefix pivot and fall through to the full pass below.
    a = a_full[:k].copy()
    orig = np.arange(k)
    deep = 0
    prefix_ok = True
    for col in range(k):
        nz = a[col:, col] != 0
        if not nz.any():
            prefix_ok = False
            break
        piv = col + int(np.argmax(nz))
        if piv != col:
            a[[col, piv]] = a[[piv, col]]
            orig[[col, piv]] = orig[[piv, col]]
        if orig[col] >= deep:
            deep = int(orig[col]) + 1
        if col + 1 < k:
            below = a[col + 1:]
            row = a[col]
            below ^= exp2[log2[below[:, col]][:, None]
                          + (log2[row] + (255 - int(log2[row[col]])))]
    if prefix_ok:
        return True, deep
    a = a_full.copy()
    orig = np.arange(m)
    deep = 0
    for col in range(k):
        nz = a[col:, col] != 0
        if not nz.any():
            return False, m
        piv = col + int(np.argmax(nz))
        if piv != col:
            a[[col, piv]] = a[[piv, col]]
            orig[[col, piv]] = orig[[piv, col]]
        if orig[col] >= deep:
            deep = int(orig[col]) + 1
        if col + 1 < m:
            below = a[col + 1:]
            # row-echelon only: rank and pivot order never depend on the
            # rows above the diagonal, so skip the Jordan half. Fused
            # sentinel-log gather as in _solve1 (zero factors propagate).
            row = a[col]
            below ^= exp2[log2[below[:, col]][:, None]
                          + (log2[row] + (255 - int(log2[row[col]])))]
    return True, deep


def gf256_solve_one(
    coeffs: np.ndarray, symbols: np.ndarray
) -> tuple[np.ndarray, bool, int]:
    """Single-system entry: ``(x, ok, fail_col)`` with scalar flags.

    The repair tick calls this once per repaired fragment; skipping the
    batch packaging (leading-axis reshape, batch flag arrays) keeps the
    per-call overhead at the numpy floor. Identical math to
    :func:`gf256_solve_np` with ``B == 1``.
    """
    x, ok, fail_col = _solve1(np.asarray(coeffs, np.uint8),
                              np.asarray(symbols, np.uint8))
    return x, bool(ok[0]), int(fail_col[0])


def gf256_solve_np(
    coeffs: np.ndarray, symbols: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Batched Gauss-Jordan over GF(256), vectorized across the batch.

    ``coeffs``: (B, m, k) uint8, ``symbols``: (B, m, L) uint8, m >= k.
    Returns ``(x, ok, fail_col)``: ``x`` (B, k, L) solutions (garbage rows
    where ``ok`` is False), ``ok`` (B,) bool full-rank flags, ``fail_col``
    (B,) int32 first rank-deficient column (-1 where ok). Element-for-
    element identical to the scalar ``rateless.gf256_gaussian_solve_ref``
    on every full-rank system, and flags exactly the column at which the
    scalar solver raises otherwise.
    """
    a = np.asarray(coeffs, np.uint8)
    y = np.asarray(symbols, np.uint8)
    B, m, k = a.shape
    assert y.shape[0] == B and y.shape[1] == m, (a.shape, y.shape)
    if B == 1:
        x, ok, fail_col = _solve1(a[0], y[0])
        return x[None], ok, fail_col
    a = a.copy()
    y = y.copy()
    ok = np.ones(B, bool)
    fail_col = np.full(B, -1, np.int32)
    bidx = np.arange(B)
    for col in range(k):
        nz = a[:, col:, col] != 0          # (B, m-col) pivot candidates
        has = nz.any(axis=1)
        fail_col[ok & ~has] = col
        ok &= has
        piv = col + np.argmax(nz, axis=1)  # first nonzero at/below diag
        piv = np.where(has, piv, col)      # failed lanes: no-op swap
        # vectorized row swap col <-> piv (identity when piv == col)
        tmp = a[bidx, piv].copy()
        a[bidx, piv] = a[bidx, col]
        a[bidx, col] = tmp
        tmp = y[bidx, piv].copy()
        y[bidx, piv] = y[bidx, col]
        y[bidx, col] = tmp
        pv = a[:, col, col]
        inv = GF_EXP[255 - GF_LOG[np.where(pv == 0, 1, pv)]]  # (B,)
        a[:, col] = gf_mul_np(a[:, col], inv[:, None])
        y[:, col] = gf_mul_np(y[:, col], inv[:, None])
        f = a[:, :, col].copy()            # (B, m) elimination factors
        f[:, col] = 0
        a ^= gf_mul_np(f[:, :, None], a[:, col:col + 1, :])
        y ^= gf_mul_np(f[:, :, None], y[:, col:col + 1, :])
    return y[:, :k], ok, fail_col


# ------------------------------------------------------------ pallas kernel
def _gfmul(a, b):
    """Bit-sliced GF(256) multiply (8-round Russian peasant), broadcasting
    int32 byte-value arrays — same VPU sequence as ``gf256_encode``."""
    res = jnp.zeros(jnp.broadcast_shapes(a.shape, b.shape), jnp.int32)
    for _ in range(8):
        res = res ^ jnp.where((b & 1) != 0, a, 0)
        hi = a & 0x80
        a = (a << 1) & 0xFF
        a = jnp.where(hi != 0, a ^ (GF_POLY & 0xFF), a)
        b = b >> 1
    return res


def _gfinv(a):
    """a^254 == a^-1 in GF(2^8), via the squaring addition chain
    2+4+8+16+32+64+128 = 254 (7 squarings + 6 multiplies, no tables)."""
    x2 = _gfmul(a, a)
    x4 = _gfmul(x2, x2)
    x8 = _gfmul(x4, x4)
    x16 = _gfmul(x8, x8)
    x32 = _gfmul(x16, x16)
    x64 = _gfmul(x32, x32)
    x128 = _gfmul(x64, x64)
    out = _gfmul(x2, x4)
    for t in (x8, x16, x32, x64, x128):
        out = _gfmul(out, t)
    return out


def _solve_kernel(a_ref, y_ref, x_ref, st_ref, *, k: int):
    a = a_ref[0]                     # (mp, kp) int32
    y = y_ref[0]                     # (mp, Lp) int32
    mp = a.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (mp, 1), 0)

    def body(col, carry):
        a, y, ok, fail = carry
        colv = jax.lax.dynamic_slice(a, (0, col), (mp, 1))
        elig = (rows >= col) & (colv != 0)
        has = jnp.any(elig)
        fail = jnp.where(ok & ~has, col, fail)
        ok = ok & has
        piv = jnp.where(has, jnp.min(jnp.where(elig, rows, mp)), col)
        # swap rows col <-> piv via masked reductions (no TPU gathers);
        # identity when piv == col
        is_piv = rows == piv
        is_col = rows == col
        piv_a = jnp.sum(jnp.where(is_piv, a, 0), 0, keepdims=True)
        piv_y = jnp.sum(jnp.where(is_piv, y, 0), 0, keepdims=True)
        col_a = jnp.sum(jnp.where(is_col, a, 0), 0, keepdims=True)
        col_y = jnp.sum(jnp.where(is_col, y, 0), 0, keepdims=True)
        a = jnp.where(is_piv, col_a, jnp.where(is_col, piv_a, a))
        y = jnp.where(is_piv, col_y, jnp.where(is_col, piv_y, y))
        # normalize the pivot row (failed lanes continue on garbage; the
        # sticky ok flag gates the result)
        inv = _gfinv(jax.lax.dynamic_slice(piv_a, (0, col), (1, 1)))
        norm_a = _gfmul(piv_a, inv)
        norm_y = _gfmul(piv_y, inv)
        a = jnp.where(is_col, norm_a, a)
        y = jnp.where(is_col, norm_y, y)
        # eliminate the column everywhere else (Gauss-Jordan)
        f = jnp.where(is_col, 0,
                      jax.lax.dynamic_slice(a, (0, col), (mp, 1)))
        a = a ^ _gfmul(f, norm_a)
        y = y ^ _gfmul(f, norm_y)
        return a, y, ok, fail

    a, y, ok, fail = jax.lax.fori_loop(
        0, k, body, (a, y, jnp.bool_(True), jnp.int32(-1)))
    x_ref[...] = y[:x_ref.shape[1]][None]
    st_ref[...] = jnp.full((1, st_ref.shape[1]),
                           jnp.where(ok, jnp.int32(-1), fail), jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def gf256_solve_kernel(
    a: jax.Array, y: jax.Array, k: int, interpret: bool | None = None
) -> tuple[jax.Array, jax.Array]:
    """a (B, mp, kp) int32, y (B, mp, Lp) int32 -> (x (B, kp8, Lp), status
    (B, 128)) with ``status[b, 0] == -1`` iff full rank, else the first
    rank-deficient column. Grid = batch; each program reduces one system
    entirely in VMEM (the systems are k ~ tens wide — far under tile
    budgets). Padding contract (``gf256_solve_batch`` arranges it): pad
    rows/columns are zero, so they are never eligible pivots and pass
    through elimination unchanged.
    """
    B, mp, kp = a.shape
    _, _, lp = y.shape
    kp8 = max(8, -(-k // 8) * 8)
    interpret = resolve_interpret(interpret)
    return pl.pallas_call(
        functools.partial(_solve_kernel, k=k),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, mp, kp), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, mp, lp), lambda b: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, kp8, lp), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 128), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, kp8, lp), jnp.int32),
            jax.ShapeDtypeStruct((B, 128), jnp.int32),
        ],
        interpret=interpret,
    )(a, y)


# ----------------------------------------------------------------- dispatch
def gf256_solve_batch(
    coeffs: np.ndarray, symbols: np.ndarray, backend: str | None = None,
    interpret: bool | None = None,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Batched GF(256) solve with backend dispatch.

    ``coeffs`` (B, m, k), ``symbols`` (B, m, L) uint8 -> ``(x, ok,
    fail_col)`` as in :func:`gf256_solve_np`. ``backend``: ``"numpy"``,
    ``"kernel"``, or None = auto (kernel only above
    ``SOLVE_KERNEL_MIN`` total symbol bytes — single in-sim decodes stay
    on the numpy mirror). Both backends produce identical outputs
    (``tests/test_gf256_solve.py``).
    """
    coeffs = np.asarray(coeffs, np.uint8)
    symbols = np.asarray(symbols, np.uint8)
    B, m, k = coeffs.shape
    L = symbols.shape[2]
    if backend is None:
        backend = "kernel" if B * m * L >= SOLVE_KERNEL_MIN else "numpy"
    if backend == "numpy":
        return gf256_solve_np(coeffs, symbols)
    if backend != "kernel":
        raise ValueError(f"unknown backend {backend!r}")
    mp = -(-m // 8) * 8
    kp = -(-k // 128) * 128
    lp = -(-L // 128) * 128
    a = np.zeros((B, mp, kp), np.int32)
    a[:, :m, :k] = coeffs
    y = np.zeros((B, mp, lp), np.int32)
    y[:, :m, :L] = symbols
    x, st = gf256_solve_kernel(jnp.asarray(a), jnp.asarray(y), k=k,
                               interpret=interpret)
    fail_col = np.asarray(st)[:, 0].astype(np.int32)
    ok = fail_col < 0
    return (np.asarray(x)[:, :k, :L].astype(np.uint8), ok, fail_col)
