"""Jitted public wrappers around the Pallas encode kernels.

Handles dtype conversion (uint8 <-> int32 lanes), tile padding, and
interpret-mode selection (interpret=True off-TPU so the kernel body runs —
and is validated — on CPU).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.backend import default_interpret as _interpret
from repro.kernels.gf256_encode import gf256_encode_kernel
from repro.kernels.gf2_encode import gf2_encode_kernel


def _pad_to(x: np.ndarray, axis: int, multiple: int) -> np.ndarray:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return np.pad(x, widths)


def _pick_tile(size: int, preferred: int, align: int) -> int:
    if size >= preferred:
        return preferred
    return max(align, ((size + align - 1) // align) * align)


def gf256_encode(coeffs, blocks, tile_r: int = 8, tile_l: int = 512):
    """coeffs (R, K) uint8, blocks (K, L) uint8 -> fragments (R, L) uint8."""
    coeffs = np.asarray(coeffs, np.uint8)
    blocks = np.asarray(blocks, np.uint8)
    r, l = coeffs.shape[0], blocks.shape[1]
    tl = _pick_tile(l, tile_l, 128)
    tr = min(tile_r, max(1, r))
    c = _pad_to(coeffs.astype(np.int32), 0, tr)
    d = _pad_to(blocks.astype(np.int32), 1, tl)
    out = gf256_encode_kernel(
        jnp.asarray(c), jnp.asarray(d), tile_r=tr, tile_l=tl,
        interpret=_interpret(),
    )
    return np.asarray(out)[:r, :l].astype(np.uint8)


def prf_select(tags, fhashes, tile_n: int = 8, tile_f: int = 128):
    """tags (N,2) int32, fhashes (F,2) int32 -> (N,F) int32 PRF matrix."""
    from repro.kernels.prf_select import prf_select_kernel

    tags = np.asarray(tags, np.int32)
    fhashes = np.asarray(fhashes, np.int32)
    n, f = tags.shape[0], fhashes.shape[0]
    tn = min(tile_n, max(1, n))
    tf = _pick_tile(f, tile_f, 128)
    t = _pad_to(tags, 0, tn)
    h = _pad_to(fhashes, 0, tf)
    out = prf_select_kernel(jnp.asarray(t), jnp.asarray(h), tile_n=tn,
                            tile_f=tf, interpret=_interpret())
    return np.asarray(out)[:n, :f]


def selection_mask(tags, fhashes, distances, r_target: int):
    """Batch Alg.2 selection: uniform u from the PRF, select iff
    u < exp(-2(d-1)/R) (same rule as core/selection.py).

    distances: (N,) or (N,F) ring-distance metric values (>= 1).
    """
    r = prf_select(tags, fhashes)
    # top 24 bits -> uniform in [0,1)
    u = (np.right_shift(r.view(np.uint32), 8)).astype(np.float64) / 2**24
    d = np.asarray(distances, np.float64)
    if d.ndim == 1:
        d = d[:, None]
    p = np.exp(-2.0 * (d - 1.0) / max(r_target, 1))
    return u < p


def gf2_encode(masks, words, tile_r: int = 8, tile_w: int = 512):
    """masks (R, K) uint8/int, words (K, W) int32 -> (R, W) int32."""
    masks = np.asarray(masks)
    words = np.asarray(words, np.int32)
    r, w = masks.shape[0], words.shape[1]
    tw = _pick_tile(w, tile_w, 128)
    tr = min(tile_r, max(1, r))
    m = _pad_to(masks.astype(np.int32), 0, tr)
    d = _pad_to(words, 1, tw)
    out = gf2_encode_kernel(
        jnp.asarray(m), jnp.asarray(d), tile_r=tr, tile_w=tw,
        interpret=_interpret(),
    )
    return np.asarray(out)[:r, :w]
