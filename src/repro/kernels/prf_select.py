"""Pallas TPU kernel: batched PRF evaluation for randomized peer selection.

When a node with many fragments fails, every affected chunk group re-runs
Locate() — a repair storm evaluates selection hashes for (candidate node ×
fragment) pairs in bulk. This kernel computes an ARX (add-rotate-xor,
ChaCha-quarter-round-style) keyed PRF over a (nodes × fragments) grid:

    out[n, f] = ARX8(tag0[n], tag1[n], fh0[f], fh1[f])

Pure int32 add/xor/rotate on the VPU — no gathers, no multiplies — with the
node-tag tile resident across the fragment dimension. This is the *batch*
variant of the VRF interface used by the vectorized simulator and the
selection-throughput studies; the protocol-level registry keeps its own
keyed-hash construction (DESIGN.md §4) — the two are independent PRFs with
the same contract (deterministic per key, uniform, unforgeable without the
tag), not byte-compatible.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret

DEFAULT_TILE_N = 8
DEFAULT_TILE_F = 128
ROUNDS = 8


def _rotl(x, k: int):
    return (x << k) | jax.lax.shift_right_logical(x, 32 - k)


def arx_mix(a, b, c, d, rounds: int = ROUNDS):
    """ChaCha-style quarter-rounds over broadcastable int32 lanes.

    ``rounds=ROUNDS`` (8) is the PRF-strength default used by the selection
    kernel; ``core/samplers.py`` reuses the same permutation at 4 rounds as
    a counter-based uniform generator (quality validated by chi-square in
    ``tests/test_samplers.py``).
    """
    for _ in range(rounds):
        a = a + b
        d = _rotl(d ^ a, 16)
        c = c + d
        b = _rotl(b ^ c, 12)
        a = a + b
        d = _rotl(d ^ a, 8)
        c = c + d
        b = _rotl(b ^ c, 7)
    return a ^ _rotl(b, 13) ^ _rotl(c, 7) ^ d


_MASK32 = 0xFFFFFFFF


def arx_mix_words(a: int, b: int, c: int, d: int, rounds: int = ROUNDS) -> int:
    """Host-scalar mirror of :func:`arx_mix` on unsigned 32-bit ints.

    Bit-identical to the kernel lanes (pinned by
    ``tests/test_prf_kernel.py``); used by ``core/vrf.ArxVRFRegistry`` for
    one-off proofs where a kernel dispatch would cost more than it saves.
    """
    def rotl(x: int, k: int) -> int:
        return ((x << k) | (x >> (32 - k))) & _MASK32

    for _ in range(rounds):
        a = (a + b) & _MASK32
        d = rotl(d ^ a, 16)
        c = (c + d) & _MASK32
        b = rotl(b ^ c, 12)
        a = (a + b) & _MASK32
        d = rotl(d ^ a, 8)
        c = (c + d) & _MASK32
        b = rotl(b ^ c, 7)
    return a ^ rotl(b, 13) ^ rotl(c, 7) ^ d


def arx_mix_np(a, b, c, d, rounds: int = ROUNDS):
    """Vectorized numpy mirror of :func:`arx_mix` (uint32 arrays in/out).

    Integer-array overflow wraps silently in numpy, so this is exact
    modular arithmetic — the same bits as the kernel — without tracing.
    Used for small pair batches below the kernel dispatch threshold.
    """
    import numpy as np

    a, b, c, d = (np.asarray(x, np.uint32) for x in (a, b, c, d))

    def rotl(x, k):
        return (x << np.uint32(k)) | (x >> np.uint32(32 - k))

    for _ in range(rounds):
        a = a + b
        d = rotl(d ^ a, 16)
        c = c + d
        b = rotl(b ^ c, 12)
        a = a + b
        d = rotl(d ^ a, 8)
        c = c + d
        b = rotl(b ^ c, 7)
    return a ^ rotl(b, 13) ^ rotl(c, 7) ^ d


def _prf_kernel(t_ref, f_ref, o_ref):
    tags = t_ref[...]  # (TN, 2) int32
    fh = f_ref[...]  # (TF, 2) int32
    a = tags[:, 0:1]  # (TN, 1)
    b = tags[:, 1:2]
    c = fh[:, 0:1].T  # (1, TF)
    d = fh[:, 1:2].T
    o_ref[...] = arx_mix(a, b, c, d)


@functools.partial(jax.jit, static_argnames=("tile_n", "tile_f", "interpret"))
def prf_select_kernel(
    tags: jax.Array, fhashes: jax.Array,
    tile_n: int = DEFAULT_TILE_N, tile_f: int = DEFAULT_TILE_F,
    interpret: bool | None = None,
) -> jax.Array:
    """tags (N,2) int32, fhashes (F,2) int32 -> (N,F) int32 PRF values.

    ``interpret=None`` resolves via backend detection (compiled on TPU,
    interpreted elsewhere).
    """
    interpret = resolve_interpret(interpret)
    n = tags.shape[0]
    f = fhashes.shape[0]
    assert n % tile_n == 0 and f % tile_f == 0, (n, f, tile_n, tile_f)
    grid = (n // tile_n, f // tile_f)
    return pl.pallas_call(
        _prf_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_f, 2), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, tile_f), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, f), jnp.int32),
        interpret=interpret,
    )(tags, fhashes)


# ------------------------------------------------------------- pairs variant
PAIRS_SUBLANES = 8     # VPU tile rows (int32 min sublane count)
PAIRS_LANES = 128      # VPU tile columns
# below this many pairs the jit dispatch overhead (~0.5 ms on the CPU
# interpreter) dwarfs the work — vectorized numpy wins; measured on the
# 2-core host via benchmarks/protocol_speed.py
PAIRS_KERNEL_MIN = 2048


def _prf_pairs_kernel(t0_ref, t1_ref, f0_ref, f1_ref, o_ref):
    o_ref[...] = arx_mix(t0_ref[...], t1_ref[...], f0_ref[...], f1_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def _prf_pairs_call(t0, t1, f0, f1, interpret: bool):
    rows = t0.shape[0]
    spec = pl.BlockSpec((PAIRS_SUBLANES, PAIRS_LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _prf_pairs_kernel,
        grid=(rows // PAIRS_SUBLANES,),
        in_specs=[spec] * 4,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, PAIRS_LANES), jnp.int32),
        interpret=interpret,
    )(t0, t1, f0, f1)


def prf_select_pairs(tags, fhashes, interpret: bool | None = None):
    """tags (P,2) int32, fhashes (P,2) int32 -> (P,) int32 PRF values.

    The *pairwise* companion to :func:`prf_select_kernel`: where that
    kernel fills a (nodes × fragments) grid — the Locate()/repair-storm
    shape — this one evaluates P independent (node tag, fragment hash)
    pairs, the shape of batched selection-proof verification (one claim =
    one pair). Pairs are padded to full (8, 128) VPU tiles and evaluated
    as four elementwise int32 planes; batches under ``PAIRS_KERNEL_MIN``
    skip the dispatch and use the bit-identical numpy mirror
    :func:`arx_mix_np` (equivalence pinned by ``tests/test_prf_kernel.py``).
    """
    import numpy as np

    tags = np.asarray(tags, np.int32)
    fhashes = np.asarray(fhashes, np.int32)
    p = tags.shape[0]
    assert tags.shape == (p, 2) and fhashes.shape == (p, 2), (
        tags.shape, fhashes.shape)
    if p == 0:
        return np.zeros(0, np.int32)
    if p < PAIRS_KERNEL_MIN:
        out = arx_mix_np(tags[:, 0].view(np.uint32), tags[:, 1].view(np.uint32),
                         fhashes[:, 0].view(np.uint32),
                         fhashes[:, 1].view(np.uint32))
        return out.view(np.int32)
    tile = PAIRS_SUBLANES * PAIRS_LANES
    pad = (-p) % tile
    planes = []
    for col in (tags[:, 0], tags[:, 1], fhashes[:, 0], fhashes[:, 1]):
        full = np.concatenate([col, np.zeros(pad, np.int32)])
        planes.append(full.reshape(-1, PAIRS_LANES))
    out = _prf_pairs_call(*planes, interpret=resolve_interpret(interpret))
    return np.asarray(out).reshape(-1)[:p]
