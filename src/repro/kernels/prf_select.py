"""Pallas TPU kernel: batched PRF evaluation for randomized peer selection.

When a node with many fragments fails, every affected chunk group re-runs
Locate() — a repair storm evaluates selection hashes for (candidate node ×
fragment) pairs in bulk. This kernel computes an ARX (add-rotate-xor,
ChaCha-quarter-round-style) keyed PRF over a (nodes × fragments) grid:

    out[n, f] = ARX8(tag0[n], tag1[n], fh0[f], fh1[f])

Pure int32 add/xor/rotate on the VPU — no gathers, no multiplies — with the
node-tag tile resident across the fragment dimension. This is the *batch*
variant of the VRF interface used by the vectorized simulator and the
selection-throughput studies; the protocol-level registry keeps its own
keyed-hash construction (DESIGN.md §4) — the two are independent PRFs with
the same contract (deterministic per key, uniform, unforgeable without the
tag), not byte-compatible.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret

DEFAULT_TILE_N = 8
DEFAULT_TILE_F = 128
ROUNDS = 8


def _rotl(x, k: int):
    return (x << k) | jax.lax.shift_right_logical(x, 32 - k)


def arx_mix(a, b, c, d, rounds: int = ROUNDS):
    """ChaCha-style quarter-rounds over broadcastable int32 lanes.

    ``rounds=ROUNDS`` (8) is the PRF-strength default used by the selection
    kernel; ``core/samplers.py`` reuses the same permutation at 4 rounds as
    a counter-based uniform generator (quality validated by chi-square in
    ``tests/test_samplers.py``).
    """
    for _ in range(rounds):
        a = a + b
        d = _rotl(d ^ a, 16)
        c = c + d
        b = _rotl(b ^ c, 12)
        a = a + b
        d = _rotl(d ^ a, 8)
        c = c + d
        b = _rotl(b ^ c, 7)
    return a ^ _rotl(b, 13) ^ _rotl(c, 7) ^ d


def _prf_kernel(t_ref, f_ref, o_ref):
    tags = t_ref[...]  # (TN, 2) int32
    fh = f_ref[...]  # (TF, 2) int32
    a = tags[:, 0:1]  # (TN, 1)
    b = tags[:, 1:2]
    c = fh[:, 0:1].T  # (1, TF)
    d = fh[:, 1:2].T
    o_ref[...] = arx_mix(a, b, c, d)


@functools.partial(jax.jit, static_argnames=("tile_n", "tile_f", "interpret"))
def prf_select_kernel(
    tags: jax.Array, fhashes: jax.Array,
    tile_n: int = DEFAULT_TILE_N, tile_f: int = DEFAULT_TILE_F,
    interpret: bool | None = None,
) -> jax.Array:
    """tags (N,2) int32, fhashes (F,2) int32 -> (N,F) int32 PRF values.

    ``interpret=None`` resolves via backend detection (compiled on TPU,
    interpreted elsewhere).
    """
    interpret = resolve_interpret(interpret)
    n = tags.shape[0]
    f = fhashes.shape[0]
    assert n % tile_n == 0 and f % tile_f == 0, (n, f, tile_n, tile_f)
    grid = (n // tile_n, f // tile_f)
    return pl.pallas_call(
        _prf_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_f, 2), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, tile_f), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, f), jnp.int32),
        interpret=interpret,
    )(tags, fhashes)
