"""Deterministic synthetic data pipeline with a checkpointable cursor.

Batches are a pure function of (seed, step, shard), so:

* restart/elastic-rescale resumes bit-identically from the saved ``step``
  (the cursor is part of the Vault-protected train state);
* each data-parallel shard generates only its slice — no host ever
  materializes the global batch (the 1000-node posture);
* no filesystem dependency (this box has no corpus); swapping in a real
  tokenized corpus only changes ``_tokens_for``.

The synthetic text is a mixture of Zipf-distributed unigrams and a repeated
Markov-ish phrase structure — enough signal for loss curves to be meaningful
(a model can learn it, loss decreases) while remaining fully reproducible.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.models import ModelConfig


@dataclasses.dataclass
class SyntheticStream:
    cfg: ModelConfig
    batch: int
    seq: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0

    def __post_init__(self):
        assert self.batch % self.n_shards == 0
        v = self.cfg.vocab
        rng = np.random.default_rng(self.seed)
        # Zipfian unigram table + a phrase table for learnable structure
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._phrases = rng.integers(0, v, size=(64, 16))

    def _tokens_for(self, step: int, shard: int) -> np.ndarray:
        b = self.batch // self.n_shards
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 4_096 + shard
        )
        toks = rng.choice(
            self.cfg.vocab, size=(b, self.seq), p=self._probs
        ).astype(np.int32)
        # overwrite random spans with phrases (predictable structure)
        n_spans = max(1, self.seq // 32)
        for i in range(b):
            for _ in range(n_spans):
                ph = self._phrases[rng.integers(64)]
                start = int(rng.integers(0, max(1, self.seq - 16)))
                toks[i, start : start + 16] = ph[: self.seq - start]
        return toks

    def batch_at(self, step: int) -> dict:
        """The (local shard of the) batch for one step."""
        toks = self._tokens_for(step, self.shard)
        out: dict = {}
        if self.cfg.embed_inputs:
            rng = np.random.default_rng(self.seed * 7 + step)
            b = self.batch // self.n_shards
            out["embeds"] = rng.standard_normal(
                (b, self.seq, self.cfg.d_model)
            ).astype(np.float32) * 0.02
            out["labels"] = toks
        else:
            out["tokens"] = toks
        if self.cfg.extra_embed_len:
            rng = np.random.default_rng(self.seed * 13 + step)
            b = self.batch // self.n_shards
            out["patches"] = rng.standard_normal(
                (b, self.cfg.extra_embed_len, self.cfg.d_model)
            ).astype(np.float32) * 0.02
        return out
