from repro.data.pipeline import SyntheticStream  # noqa: F401
