"""Gradient compression for cross-replica reduction (int8 + error feedback).

The data-parallel gradient all-reduce is the collective that crosses pods
(DCI) at 1000-node scale, so its wire bytes are the lever. We compress by
quantizing each shard's gradient to int8 with a per-tensor fp32 scale, then
``all_gather``-ing the quantized tensors and reducing locally in fp32:

    wire bytes/device ≈ (N-1)/N · B     (int8 gather)
    vs. ring all-reduce bf16 ≈ 2 · (N-1)/N · 2B

≈ 4× fewer bytes on the wire. Error feedback (the residual between the true
and quantized gradient is carried into the next step) restores convergence —
``tests/test_compression.py`` checks both the bytes model and convergence on
a quadratic.

Exposed as (a) primitives usable inside ``shard_map`` and (b)
``make_dp_train_step`` — a pure data-parallel training step used by the
multi-replica integration tests and the elastic-training example.
"""
from __future__ import annotations

import functools
import inspect

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:
    from jax import shard_map as _shard_map
except ImportError:  # jax < 0.6 keeps shard_map in experimental
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = inspect.signature(_shard_map).parameters


def shard_map(*args, **kwargs):
    """shard_map with kwarg compat: jax >= 0.6 renamed check_rep->check_vma."""
    if "check_vma" in kwargs and "check_vma" not in _SHARD_MAP_PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    return _shard_map(*args, **kwargs)


def quantize_int8(x):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def int8_all_reduce_mean(x, axis_name: str):
    """Inside shard_map: mean over ``axis_name`` with int8 wire format."""
    q, scale = quantize_int8(x.astype(jnp.float32))
    qg = jax.lax.all_gather(q, axis_name)  # (N, ...) int8 on the wire
    sg = jax.lax.all_gather(scale, axis_name)  # (N,) fp32 (negligible)
    shape = (-1,) + (1,) * x.ndim
    full = qg.astype(jnp.float32) * sg.reshape(shape)
    return full.mean(axis=0)


def tree_int8_all_reduce_mean(grads, axis_name: str, error):
    """Error-feedback compressed mean-reduce over a gradient pytree.

    ``error`` carries each tensor's quantization residual; returns
    (reduced_grads, new_error).
    """

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = quantize_int8(g32)
        new_e = g32 - dequantize_int8(q, scale)
        qg = jax.lax.all_gather(q, axis_name)
        sg = jax.lax.all_gather(scale, axis_name)
        shape = (-1,) + (1,) * g.ndim
        red = (qg.astype(jnp.float32) * sg.reshape(shape)).mean(axis=0)
        return red, new_e

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        tdef.unflatten([o[0] for o in out]),
        tdef.unflatten([o[1] for o in out]),
    )


def error_init(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def make_dp_train_step(cfg, opt_cfg, mesh: Mesh, axis: str = "data",
                       compress: bool = True):
    """Pure data-parallel train step under shard_map (params replicated,
    batch sharded over ``axis``), with optional int8+EF gradient reduce."""
    from repro.models import train_loss
    from repro.optim import adamw_update

    def dp_step(state, batch):
        def inner(params, opt, err, local_batch):
            (loss, _m), grads = jax.value_and_grad(
                train_loss, has_aux=True
            )(params, cfg, local_batch)
            if compress:
                grads, err = tree_int8_all_reduce_mean(grads, axis, err)
            else:
                grads = jax.lax.pmean(grads, axis)
            loss = jax.lax.pmean(loss, axis)
            new_p, new_opt, _om = adamw_update(params, grads, opt, opt_cfg)
            return new_p, new_opt, err, loss

        batch_specs = jax.tree_util.tree_map(
            lambda _: P(axis), batch
        )
        fn = shard_map(
            inner,
            mesh=mesh,
            in_specs=(P(), P(), P(), batch_specs),
            out_specs=(P(), P(), P(), P()),
            check_vma=False,
        )
        new_p, new_opt, err, loss = fn(
            state["params"], state["opt"], state["error"], batch
        )
        return {"params": new_p, "opt": new_opt, "error": err}, loss

    return dp_step
