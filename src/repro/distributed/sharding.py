"""Logical-axis sharding with divisibility fallback.

Every parameter/activation/cache tensor in ``repro.models`` carries a tuple
of *logical* axis names. This module resolves those names against a concrete
mesh via priority rules:

    RULES:  logical name -> tuple of mesh-axis candidates, tried in order.
            A candidate may itself be a tuple (joint sharding, e.g. batch
            over ("pod", "data")).

A candidate is accepted only if (a) all its mesh axes exist, (b) their size
product divides the tensor dim, and (c) none of them is already used by
another dim of the same tensor. Otherwise the next candidate (ultimately
``None`` = replicate) is tried. This is what keeps every (arch × mesh) cell
compilable without per-arch hand-tuning: 8 KV heads on a 16-way model axis
fall back to replicated KV while Q stays sharded; 60 experts fall back to
tensor-parallel expert FFNs; and so on (DESIGN.md §6).

``constrain`` applies ``with_sharding_constraint`` inside model code using
the ambient mesh + rules (no-op outside a mesh/rules context, so smoke tests
on one device run the same code).
"""
from __future__ import annotations

import contextlib
import threading

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Candidate = tuple[str, ...] | str | None

DEFAULT_RULES: dict[str, tuple[Candidate, ...]] = {
    "batch": (("pod", "data"), "data", None),
    "vocab": ("model", None),
    "embed": (None,),
    "heads": ("model", None),
    "kv_heads": ("model", None),
    "head_dim": (None,),
    "mlp": ("model", None),
    "experts": ("model", None),
    "expert_mlp": ("model", None),
    "lora": ("model", None),
    "layers": (None,),
    # KV-cache length: prefer whatever axes the tensor has not used yet —
    # decode_32k gets T/model (batch took data); long_500k's batch=1 falls
    # back to replicated so T takes (data, model) jointly (500K × d fits)
    "cache_len": (("pod", "data", "model"), ("data", "model"), "model", None),
    "length": (None,),
}

# Sequence-parallel variant: long-context caches shard their length dim over
# the data axis (each data shard owns a slice of the KV timeline). Used by
# the decode_32k / long_500k serve cells and as a §Perf lever.
SEQUENCE_RULES = dict(
    DEFAULT_RULES,
    cache_len=(("pod", "data"), "data", None),
    batch=(None,),
)

# DP-heavy variant (§Perf lever): batch shards over BOTH mesh axes, params
# keep their model shardings (ZeRO/FSDP-style weight gathers). The right
# config for architectures whose head counts don't divide the model axis
# (musicgen 24H, minicpm3 40H): uniform rules would replicate their
# attention compute 16× across the model axis; here every FLOP is
# data-parallel and the wire cost is one weight gather per layer.
DP_RULES = dict(
    DEFAULT_RULES,
    batch=(("pod", "data", "model"), ("data", "model"), ("pod", "data"),
           "data", None),
)

RULE_SETS = {
    "default": DEFAULT_RULES,
    "sequence": SEQUENCE_RULES,
    "dp": DP_RULES,
}


class _Ctx(threading.local):
    def __init__(self):
        self.rules: dict[str, tuple[Candidate, ...]] | None = None
        self.mesh: Mesh | None = None


_CTX = _Ctx()


@contextlib.contextmanager
def logical_axis_rules(rules: dict[str, tuple[Candidate, ...]] | None = None,
                       mesh: Mesh | None = None):
    prev = (_CTX.rules, _CTX.mesh)
    _CTX.rules = DEFAULT_RULES if rules is None else rules
    _CTX.mesh = mesh
    try:
        yield
    finally:
        _CTX.rules, _CTX.mesh = prev


def _ambient_mesh() -> Mesh | None:
    if _CTX.mesh is not None:
        return _CTX.mesh
    try:
        mesh = jax.interpreters.pxla.thread_resources.env.physical_mesh
        if mesh and not mesh.empty:
            return mesh
    except Exception:
        pass
    return None


def _axis_sizes(mesh) -> dict[str, int]:
    """Axis name -> size; works for Mesh and AbstractMesh."""
    sizes = getattr(mesh, "axis_sizes", None)
    if sizes is not None:
        return dict(zip(mesh.axis_names, sizes))
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def abstract_mesh(axis_sizes: tuple[int, ...], axis_names: tuple[str, ...]):
    """Version-compat AbstractMesh constructor.

    jax >= 0.5 takes ``AbstractMesh(axis_sizes, axis_names)``; jax 0.4.x
    takes a single ``shape_tuple`` of ``(name, size)`` pairs. Accepts the
    new-style arguments and translates when running on the old signature.
    """
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def resolve_spec(
    names: tuple[str | None, ...], shape: tuple[int, ...], mesh: Mesh,
    rules: dict[str, tuple[Candidate, ...]] | None = None,
) -> P:
    """Resolve logical names for one tensor into a PartitionSpec."""
    rules = rules or DEFAULT_RULES
    sizes = _axis_sizes(mesh)
    used: set[str] = set()
    out: list = []
    for dim, name in zip(shape, names):
        if name is None or name not in rules:
            out.append(None)
            continue
        chosen = None
        for cand in rules[name]:
            if cand is None:
                break
            axes = (cand,) if isinstance(cand, str) else tuple(cand)
            if not all(a in sizes for a in axes):
                continue
            prod = int(np.prod([sizes[a] for a in axes]))
            if dim % prod != 0:
                continue
            if any(a in used for a in axes):
                continue
            chosen = axes if len(axes) > 1 else axes[0]
            used.update(axes)
            break
        out.append(chosen)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_specs(spec_tree, shape_tree, mesh: Mesh, rules=None):
    """Resolve a whole tree of logical-name tuples to PartitionSpecs."""
    is_names = lambda t: isinstance(t, tuple) and all(
        isinstance(x, str) or x is None for x in t
    )
    return jax.tree_util.tree_map(
        lambda names, leaf: resolve_spec(names, leaf.shape, mesh, rules),
        spec_tree,
        shape_tree,
        is_leaf=is_names,
    )


def tree_shardings(spec_tree, shape_tree, mesh: Mesh, rules=None):
    specs = tree_specs(spec_tree, shape_tree, mesh, rules)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda t: isinstance(t, P),
    )


def constrain(x, *names):
    """with_sharding_constraint via the ambient mesh+rules; no-op outside."""
    rules = _CTX.rules
    mesh = _ambient_mesh()
    if rules is None or mesh is None:
        return x
    spec = resolve_spec(tuple(names), x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ------------------------------------------------------------------ ZeRO-1
def zero1_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Additionally shard one replicated dim over the data(+pod) axes.

    Optimizer moments are element-wise state: any consistent placement
    works, so we cut their footprint by the data-parallel degree (ZeRO-1).
    XLA inserts the reduce-scatter/all-gather pair around the update.
    """
    sizes = _axis_sizes(mesh)
    cands = [a for a in ("pod", "data") if a in sizes]
    if not cands:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for e in entries:
        if e is None:
            continue
        used.update((e,) if isinstance(e, str) else e)
    avail = [a for a in cands if a not in used]
    if not avail:
        return spec
    prod = int(np.prod([sizes[a] for a in avail]))
    best, best_dim = None, 0
    for i, (e, dim) in enumerate(zip(entries, shape)):
        if e is None and dim % prod == 0 and dim > best_dim:
            best, best_dim = i, dim
    if best is None:
        # try single-axis fallback
        for a in avail:
            for i, (e, dim) in enumerate(zip(entries, shape)):
                if e is None and dim % sizes[a] == 0 and dim > best_dim:
                    best, best_dim = i, dim
            if best is not None:
                entries[best] = a
                return P(*entries)
        return spec
    entries[best] = tuple(avail) if len(avail) > 1 else avail[0]
    return P(*entries)


def zero1_tree(specs_tree, shape_tree, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s, leaf: zero1_spec(s, leaf.shape, mesh),
        specs_tree,
        shape_tree,
        is_leaf=lambda t: isinstance(t, P),
    )
