# Distribution substrate: logical-axis sharding rules (with divisibility
# fallback), ZeRO-1 optimizer-state sharding, int8 gradient compression.
