"""minicpm3-4b [dense] — HF openbmb/MiniCPM3-4B. Dense transformer with MLA.

62L, d_model 2560, 40 heads, MLA (q_lora 768, kv_lora 256, nope 64, rope 32,
v 64), d_ff 6400, vocab 73448.
"""
from repro.models import LayerPattern, ModelConfig

ARCH = "minicpm3-4b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        vocab=73_448,
        d_model=2_560,
        n_heads=40,
        n_kv_heads=40,
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_dim=64,
        qk_rope_dim=32,
        v_head_dim=64,
        d_ff=6_400,
        pattern=(LayerPattern(62, (("mla", "dense"),)),),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke",
        vocab=512,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        q_lora_rank=32,
        kv_lora_rank=32,
        qk_nope_dim=16,
        qk_rope_dim=8,
        v_head_dim=16,
        d_ff=160,
        pattern=(LayerPattern(3, (("mla", "dense"),)),),
        max_cache_len=64,
    )
