"""Architecture registry: ``--arch <id>`` resolution + shape table."""
from __future__ import annotations

import importlib

from repro.configs.common import (  # noqa: F401
    LONG_CONTEXT_ARCHS,
    SHAPES,
    ShapeDef,
    apply_shape,
    input_specs,
)
from repro.models import ModelConfig

_MODULES = {
    "deepseek-v3-671b": "deepseek_v3_671b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "minicpm3-4b": "minicpm3_4b",
    "qwen1.5-110b": "qwen1_5_110b",
    "internlm2-20b": "internlm2_20b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "mamba2-2.7b": "mamba2_2_7b",
    "llava-next-34b": "llava_next_34b",
    "musicgen-medium": "musicgen_medium",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
}

ARCHS = tuple(_MODULES)


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def full_config(arch: str, shape: str | None = None) -> ModelConfig:
    cfg = _module(arch).full()
    return apply_shape(cfg, shape) if shape else cfg


def smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke()


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; long_500k only for SSM/hybrid."""
    out = []
    for arch in ARCHS:
        for shape in SHAPES:
            skipped = (
                shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS
            )
            if skipped and not include_skipped:
                continue
            out.append((arch, shape) if not include_skipped
                       else (arch, shape, skipped))
    return out
