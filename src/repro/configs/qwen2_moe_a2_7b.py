"""qwen2-moe-a2.7b [moe] — HF Qwen/Qwen1.5-MoE-A2.7B.

24L, d_model 2048, 16 heads (MHA, kv=16), QKV bias, 60 routed experts top-4
(expert d_ff 1408) + 4 shared experts (total shared d_ff 5632), vocab 151936.
"""
from repro.models import LayerPattern, ModelConfig

ARCH = "qwen2-moe-a2.7b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        vocab=151_936,
        d_model=2_048,
        n_heads=16,
        n_kv_heads=16,
        qkv_bias=True,
        d_ff=1_408,
        n_experts=60,
        n_experts_per_tok=4,
        moe_d_ff=1_408,
        n_shared_experts=4,
        shared_d_ff=5_632,
        pattern=(LayerPattern(24, (("gqa", "moe"),)),),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke",
        vocab=512,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        qkv_bias=True,
        d_ff=96,
        n_experts=6,
        n_experts_per_tok=2,
        moe_d_ff=48,
        n_shared_experts=2,
        shared_d_ff=96,
        pattern=(LayerPattern(2, (("gqa", "moe"),)),),
        max_cache_len=64,
    )
