"""codeqwen1.5-7b [dense] — HF Qwen/CodeQwen1.5-7B (qwen1.5 arch).

32L, d_model 4096, 32 heads (MHA kv=32), QKV bias, d_ff 13440, vocab 92416.
"""
from repro.models import LayerPattern, ModelConfig

ARCH = "codeqwen1.5-7b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        vocab=92_416,
        d_model=4_096,
        n_heads=32,
        n_kv_heads=32,
        qkv_bias=True,
        d_ff=13_440,
        pattern=(LayerPattern(32, (("gqa", "dense"),)),),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke",
        vocab=512,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        qkv_bias=True,
        d_ff=192,
        pattern=(LayerPattern(3, (("gqa", "dense"),)),),
        max_cache_len=64,
    )
