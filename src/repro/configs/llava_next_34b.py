"""llava-next-34b [vlm] — LLaVA-NeXT backbone (34B-class LM).

60L, d_model 7168, 56 heads, GQA kv=8, d_ff 20480, vocab 64000. The anyres
vision frontend is a STUB per the brief: ``input_specs()`` provides 576
precomputed patch embeddings (one 24×24 CLIP tile) prepended to the text
sequence; the loss covers text positions only.
"""
from repro.models import LayerPattern, ModelConfig

ARCH = "llava-next-34b"
N_PATCHES = 576


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        vocab=64_000,
        d_model=7_168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=20_480,
        extra_embed_len=N_PATCHES,
        pattern=(LayerPattern(60, (("gqa", "dense"),)),),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke",
        vocab=512,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=192,
        extra_embed_len=8,
        pattern=(LayerPattern(3, (("gqa", "dense"),)),),
        max_cache_len=96,
    )
