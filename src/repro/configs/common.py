"""Shared config tooling: shape table, per-shape adaptation, input specs."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeDef:
    name: str
    kind: str  # train | prefill | decode
    seq: int
    batch: int


SHAPES: dict[str, ShapeDef] = {
    "train_4k": ShapeDef("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeDef("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeDef("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeDef("long_500k", "decode", 524_288, 1),
}

# long_500k needs sub-quadratic attention: run only for SSM/hybrid archs
# (DESIGN.md §5); pure full-attention archs skip it.
LONG_CONTEXT_ARCHS = {"mamba2-2.7b", "jamba-1.5-large-398b"}


def apply_shape(cfg: ModelConfig, shape: str) -> ModelConfig:
    """Specialize a full config for one dry-run cell."""
    sd = SHAPES[shape]
    upd: dict = {
        "dtype": "bfloat16",
        "compute_dtype": "bfloat16",
    }
    if sd.kind == "train":
        upd["remat"] = "dots"
        upd["attn_chunk"] = 1024  # flash-style tiles; O(S²) never lives
    elif sd.kind == "prefill":
        upd["attn_chunk"] = 1024
        upd["max_cache_len"] = sd.seq + cfg.extra_embed_len
    else:  # decode
        upd["attn_chunk"] = 0
        upd["max_cache_len"] = sd.seq + cfg.extra_embed_len
    return dataclasses.replace(cfg, **upd)


def input_specs(cfg: ModelConfig, shape: str) -> dict:
    """ShapeDtypeStruct stand-ins for every step input (no allocation)."""
    sd = SHAPES[shape]
    b = sd.batch
    i32 = jnp.int32
    cd = cfg.cdtype()
    if sd.kind in ("train", "prefill"):
        s = sd.seq
        specs: dict = {}
        if cfg.embed_inputs:
            specs["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), cd)
            specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        if cfg.extra_embed_len:
            specs["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.extra_embed_len, cfg.d_model), cd
            )
        return specs
    # decode: one new token against a populated cache
    if cfg.embed_inputs:
        return {"embeds": jax.ShapeDtypeStruct((b, 1, cfg.d_model), cd)}
    return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
