"""deepseek-v3-671b [moe] — arXiv:2412.19437 / HF deepseek-ai/DeepSeek-V3.

61L, d_model 7168, 128 heads, MLA (q_lora 1536, kv_lora 512, nope 128,
rope 64, v 128), first 3 layers dense (d_ff 18432), 58 MoE layers with
256 routed experts (top-8, expert d_ff 2048 — the brief's "d_ff=2048") + 1
shared expert, vocab 129280. MTP is simplified to standard next-token CE
(DESIGN.md §5).
"""
from repro.models import LayerPattern, ModelConfig

ARCH = "deepseek-v3-671b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        vocab=129_280,
        d_model=7_168,
        n_heads=128,
        n_kv_heads=128,
        q_lora_rank=1_536,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        d_ff=18_432,
        n_experts=256,
        n_experts_per_tok=8,
        moe_d_ff=2_048,
        n_shared_experts=1,
        shared_d_ff=2_048,
        pattern=(
            LayerPattern(3, (("mla", "dense"),)),
            LayerPattern(58, (("mla", "moe"),)),
        ),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke",
        vocab=512,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        q_lora_rank=32,
        kv_lora_rank=32,
        qk_nope_dim=16,
        qk_rope_dim=8,
        v_head_dim=16,
        d_ff=192,
        n_experts=8,
        n_experts_per_tok=2,
        moe_d_ff=32,
        n_shared_experts=1,
        shared_d_ff=32,
        pattern=(
            LayerPattern(1, (("mla", "dense"),)),
            LayerPattern(2, (("mla", "moe"),)),
        ),
        max_cache_len=64,
    )
