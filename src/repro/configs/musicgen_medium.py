"""musicgen-medium [audio] — arXiv:2306.05284. Decoder-only over EnCodec.

48L, d_model 1536, 24 heads (MHA), d_ff 6144, vocab 2048. The EnCodec
frontend is a STUB per the brief: ``input_specs()`` provides precomputed
frame embeddings (B,S,d) plus integer labels for the CE loss; the model's
single head predicts one codebook stream (the 4-codebook delay pattern is a
frontend concern, DESIGN.md §5).
"""
from repro.models import LayerPattern, ModelConfig

ARCH = "musicgen-medium"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        vocab=2_048,
        d_model=1_536,
        n_heads=24,
        n_kv_heads=24,
        d_ff=6_144,
        embed_inputs=True,
        pattern=(LayerPattern(48, (("gqa", "dense"),)),),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke",
        vocab=128,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=192,
        embed_inputs=True,
        pattern=(LayerPattern(3, (("gqa", "dense"),)),),
        max_cache_len=64,
    )
