"""qwen1.5-110b [dense] — Qwen1.5 architecture (QKV bias) at 110B.

80L, d_model 8192, 64 heads, GQA kv=8, d_ff 49152, vocab 152064.
"""
from repro.models import LayerPattern, ModelConfig

ARCH = "qwen1.5-110b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        vocab=152_064,
        d_model=8_192,
        n_heads=64,
        n_kv_heads=8,
        qkv_bias=True,
        d_ff=49_152,
        pattern=(LayerPattern(80, (("gqa", "dense"),)),),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke",
        vocab=512,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        qkv_bias=True,
        d_ff=256,
        pattern=(LayerPattern(3, (("gqa", "dense"),)),),
        max_cache_len=64,
    )
