"""jamba-1.5-large-398b [hybrid] — arXiv:2403.19887 / Jamba-1.5.

72L, d_model 8192, 64 heads GQA kv=8, Mamba:attention 7:1 interleave
(attention at offset 4 of each 8-layer period, as in the released config),
MoE every other layer (16 experts top-2, expert d_ff 24576). The SSM mixer
is our Mamba2/SSD block (state 128) — Jamba ships Mamba-1; the SSD form is
the TPU-native equivalent (DESIGN.md §5). Runs long_500k (hybrid ⇒
sub-quadratic decode cost dominated by the SSM layers).
"""
from repro.models import LayerPattern, ModelConfig

ARCH = "jamba-1.5-large-398b"

# one 8-layer period: mamba ×4 / attention at idx 4 / mamba ×3;
# MoE at odd offsets (period 2, offset 1)
_PERIOD = (
    ("mamba", "dense"),
    ("mamba", "moe"),
    ("mamba", "dense"),
    ("mamba", "moe"),
    ("gqa", "dense"),
    ("mamba", "moe"),
    ("mamba", "dense"),
    ("mamba", "moe"),
)


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        vocab=65_536,
        d_model=8_192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=24_576,
        n_experts=16,
        n_experts_per_tok=2,
        moe_d_ff=24_576,
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_groups=8,
        ssm_conv=4,
        ssm_chunk=256,
        pattern=(LayerPattern(9, _PERIOD),),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke",
        vocab=512,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        n_experts=4,
        n_experts_per_tok=2,
        moe_d_ff=128,
        ssm_state=16,
        ssm_head_dim=16,
        ssm_expand=2,
        ssm_groups=2,
        ssm_chunk=8,
        pattern=(LayerPattern(1, _PERIOD),),
        max_cache_len=64,
    )
