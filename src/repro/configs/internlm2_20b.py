"""internlm2-20b [dense] — arXiv:2403.17297. GQA dense transformer.

48L, d_model 6144, 48 heads, GQA kv=8, d_ff 16384, vocab 92544.
"""
from repro.models import LayerPattern, ModelConfig

ARCH = "internlm2-20b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        vocab=92_544,
        d_model=6_144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16_384,
        pattern=(LayerPattern(48, (("gqa", "dense"),)),),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke",
        vocab=512,
        d_model=96,
        n_heads=6,
        n_kv_heads=2,
        d_ff=256,
        pattern=(LayerPattern(3, (("gqa", "dense"),)),),
        max_cache_len=64,
    )
