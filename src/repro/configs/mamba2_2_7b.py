"""mamba2-2.7b [ssm] — arXiv:2405.21060 (SSD). Attention-free.

64L, d_model 2560, ssm_state 128, head_dim 64 (expand 2 → 80 heads),
vocab 50280, tied embeddings. Runs the long_500k cell (sub-quadratic).
"""
from repro.models import LayerPattern, ModelConfig

ARCH = "mamba2-2.7b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        vocab=50_280,
        d_model=2_560,
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_groups=1,
        ssm_conv=4,
        ssm_chunk=256,
        tie_embeddings=True,
        pattern=(LayerPattern(64, (("mamba", None),)),),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke",
        vocab=512,
        d_model=64,
        ssm_state=16,
        ssm_head_dim=16,
        ssm_expand=2,
        ssm_groups=1,
        ssm_chunk=8,
        tie_embeddings=True,
        pattern=(LayerPattern(3, (("mamba", None),)),),
        max_cache_len=64,
    )
