"""Train / prefill / decode step builders.

``make_train_step`` returns a pure ``(state, batch) -> (state, metrics)``
suitable for ``jax.jit`` with explicit shardings (the dry-run path) or plain
jit on one device (smoke tests). Optional gradient accumulation scans
microbatches with a summed-grad carry — the standard memory lever when the
per-device batch does not fit.

Serve steps follow vLLM-ish structure: ``prefill`` consumes the prompt and
returns (last-token logits, populated cache); ``decode`` advances one token.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, forward, init_cache, init_params, train_loss
from repro.optim import AdamWConfig, adamw_init, adamw_update

TrainState = dict  # {"params": ..., "opt": {"mu","nu","step"}}


def init_train_state(cfg: ModelConfig, key) -> TrainState:
    params = init_params(cfg, key)
    return {"params": params, "opt": adamw_init(params)}


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    accum: int = 1):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            train_loss, has_aux=True
        )(params, cfg, batch)
        return loss, metrics, grads

    def train_step(state: TrainState, batch: dict[str, Any]):
        params = state["params"]
        if accum <= 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(accum, b // accum, *x.shape[1:])

            micro = jax.tree_util.tree_map(split, batch)

            def body(acc, mb):
                loss, metrics, grads = grads_of(params, mb)
                acc_g, acc_l = acc
                acc_g = jax.tree_util.tree_map(jnp.add, acc_g, grads)
                return (acc_g, acc_l + loss), metrics

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss), metrics = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), micro
            )
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
            loss = loss / accum
            metrics = jax.tree_util.tree_map(lambda m: m.mean(), metrics)
        new_params, new_opt, om = adamw_update(
            params, grads, state["opt"], opt_cfg
        )
        metrics = {"loss": loss, **metrics, **om}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill(params, batch):
        cache = init_cache(cfg, _batch_size(cfg, batch))
        logits, _aux, cache = forward(
            params, cfg, batch, mode="prefill", cache=cache, cur_len=0
        )
        return logits[:, -1:], cache

    return prefill


def make_decode_step(cfg: ModelConfig):
    def decode(params, cache, batch, cur_len):
        logits, _aux, cache = forward(
            params, cfg, batch, mode="decode", cache=cache, cur_len=cur_len
        )
        return logits, cache

    return decode


def _batch_size(cfg: ModelConfig, batch) -> int:
    key = "embeds" if cfg.embed_inputs else "tokens"
    return batch[key].shape[0]
