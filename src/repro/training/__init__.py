from repro.training.steps import (  # noqa: F401
    TrainState,
    init_train_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
