"""Unit tests for repro.config — the one environment-setup path.

These cover the pure string/env plumbing (no jax import, no subprocess):
device-flag rewriting, topology-keyed cache dirs and their re-keying,
subprocess environments and the shell-export CLI contract that
``scripts/test.sh`` evals.
"""
from __future__ import annotations

import subprocess
import sys

from repro import config as CFG


def test_device_flags_append_and_replace():
    assert CFG.device_flags(4, "") == \
        "--xla_force_host_platform_device_count=4"
    # replaces an existing count instead of stacking a second flag
    out = CFG.device_flags(8, "--xla_force_host_platform_device_count=2")
    assert out.count("xla_force_host_platform_device_count") == 1
    assert "=8" in out
    # unrelated flags survive
    out = CFG.device_flags(
        2, "--xla_cpu_foo=1 --xla_force_host_platform_device_count=4")
    assert "--xla_cpu_foo=1" in out and "=2" in out


def test_cache_dir_keyed_by_topology():
    env = {"REPRO_JAX_CACHE_BASE": "/tmp/cc"}
    assert CFG.cache_dir(1, env) == "/tmp/cc-d1"
    assert CFG.cache_dir(8, env) == "/tmp/cc-d8"


def test_cache_base_strips_existing_topology_suffix():
    # re-keying an already-keyed dir must not stack suffixes
    env = {"JAX_COMPILATION_CACHE_DIR": "/tmp/cc-d8"}
    assert CFG.cache_base(env) == "/tmp/cc"
    assert CFG.cache_dir(2, env) == "/tmp/cc-d2"


def test_subprocess_env_sets_flags_and_cache():
    env = CFG.subprocess_env(4, {"PATH": "/bin",
                                 "REPRO_JAX_CACHE_BASE": "/tmp/cc"})
    assert "--xla_force_host_platform_device_count=4" in env["XLA_FLAGS"]
    assert env["JAX_COMPILATION_CACHE_DIR"] == "/tmp/cc-d4"
    assert env["PATH"] == "/bin"


def test_shell_exports_cli_round_trip():
    # scripts/test.sh does: eval "$(python -m repro.config)"
    out = subprocess.run(
        [sys.executable, "-m", "repro.config"],
        capture_output=True, text=True,
        env={"PATH": "/usr/bin:/bin", "XLA_DEVICES": "2",
             "REPRO_JAX_CACHE_BASE": "/tmp/cc",
             "PYTHONPATH": CFG.__file__.rsplit("/repro/", 1)[0]})
    assert out.returncode == 0, out.stderr
    lines = out.stdout.splitlines()
    assert any(l.startswith("export XLA_FLAGS=") and "=2" in l
               for l in lines)
    assert 'export JAX_COMPILATION_CACHE_DIR="/tmp/cc-d2"' in lines
