"""Mamba2/SSD: chunked algorithm vs naive recurrence; decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig
from repro.models import mamba2 as M


def naive_ssd(x, b_mat, c_mat, dt, a):
    """Token-by-token linear recurrence oracle (fp64)."""
    bsz, l, h, p = x.shape
    n = b_mat.shape[-1]
    state = np.zeros((bsz, h, p, n))
    ys = np.zeros((bsz, l, h, p))
    for t in range(l):
        da = np.exp(dt[:, t] * a)  # (B,H)
        state = state * da[:, :, None, None] + np.einsum(
            "bhp,bhn,bh->bhpn", x[:, t], b_mat[:, t], dt[:, t]
        )
        ys[:, t] = np.einsum("bhn,bhpn->bhp", c_mat[:, t], state)
    return ys, state


def test_chunked_ssd_matches_naive():
    rng = np.random.default_rng(0)
    bsz, l, h, p, n = 2, 37, 3, 4, 5
    cfg = ModelConfig(ssm_chunk=8)
    x = rng.standard_normal((bsz, l, h, p))
    bm = rng.standard_normal((bsz, l, h, n))
    cm = rng.standard_normal((bsz, l, h, n))
    dt = np.abs(rng.standard_normal((bsz, l, h))) * 0.5
    a = -np.abs(rng.standard_normal(h)) * 0.5
    y_ref, s_ref = naive_ssd(x, bm, cm, dt, a)
    y, s = M._ssd_chunked(
        cfg, jnp.asarray(x, jnp.float32), jnp.asarray(bm, jnp.float32),
        jnp.asarray(cm, jnp.float32), jnp.asarray(dt, jnp.float32),
        jnp.asarray(a, jnp.float32),
        jnp.zeros((bsz, h, p, n), jnp.float32),
    )
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), s_ref, rtol=2e-4, atol=2e-4)


def test_chunked_with_initial_state():
    rng = np.random.default_rng(1)
    bsz, l, h, p, n = 1, 16, 2, 3, 4
    cfg = ModelConfig(ssm_chunk=4)
    x = rng.standard_normal((bsz, l, h, p))
    bm = rng.standard_normal((bsz, l, h, n))
    cm = rng.standard_normal((bsz, l, h, n))
    dt = np.abs(rng.standard_normal((bsz, l, h))) * 0.3
    a = -np.abs(rng.standard_normal(h)) * 0.3
    # run first half then second half with carried state == full run
    args = lambda t0, t1, st: (
        cfg, jnp.asarray(x[:, t0:t1], jnp.float32),
        jnp.asarray(bm[:, t0:t1], jnp.float32),
        jnp.asarray(cm[:, t0:t1], jnp.float32),
        jnp.asarray(dt[:, t0:t1], jnp.float32),
        jnp.asarray(a, jnp.float32), st,
    )
    z = jnp.zeros((bsz, h, p, n), jnp.float32)
    y_full, s_full = M._ssd_chunked(*args(0, l, z))
    y1, s1 = M._ssd_chunked(*args(0, 8, z))
    y2, s2 = M._ssd_chunked(*args(8, l, s1))
    np.testing.assert_allclose(np.asarray(y_full[:, 8:]), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


def test_mamba_decode_matches_forward():
    cfg = ModelConfig(
        d_model=32, ssm_state=8, ssm_head_dim=8, ssm_expand=2,
        ssm_groups=2, ssm_chunk=4, max_cache_len=32,
    )
    p = M.mamba_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((2, 9, 32)), jnp.float32) * 0.3
    y_full, _ = M.mamba_forward(p, cfg, x)
    # prefill 8 tokens with cache, then decode token 9
    cache = M.mamba_cache_init(cfg, 2, jnp.float32)
    y_pre, cache = M.mamba_forward(p, cfg, x[:, :8], cache=cache, cur_len=0)
    np.testing.assert_allclose(np.asarray(y_pre), np.asarray(y_full[:, :8]),
                               rtol=2e-3, atol=2e-3)
    pos = jnp.full((2, 1), 8, jnp.int32)
    y_dec, cache = M.mamba_decode(p, cfg, x[:, 8:9], pos, cache, 8)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full[:, 8:9]),
                               rtol=2e-3, atol=2e-3)
