"""Property tests for the policy combinator API (ISSUE 10 tentpole).

The contract under test, in order of importance:

* **Identity** — lowering a combinator spec through ``policy=`` produces a
  *bit-identical* engine cell / protocol config to the legacy
  ``churn_policy=``/``adv_policy=`` kwargs it replaces, for every
  pre-combinator policy (this is what keeps the golden suites green).
* **Round-trip** — every registered zoo spec survives
  ``resolve(resolve(spec))`` unchanged, and every zoo/plain name resolves.
* **Composition algebra** — later-wins per axis, knob merge later-wins per
  key, and the adversary product table (eclipse × targeted →
  eclipse_targeted, symmetric and absorbing) behave as documented.
* **Rejection** — axis-ambiguous ints and unknown names/knobs raise.
"""
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from repro.core import policies as P  # noqa: E402
from repro.core import protocol_sim as PS  # noqa: E402
from repro.core import scenarios as SC  # noqa: E402

BASE = dict(n_objects=2, n_chunks=3, k_outer=2, k_inner=4, r_inner=8,
            n_nodes=100, byz_fraction=0.1, churn_per_year=30.0,
            step_hours=12.0, steps=6)


def _cells_equal(a, b) -> bool:
    """Bit-wise equality of two Scenario NamedTuples (all leaves)."""
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(a, b))


# ------------------------------------------------------------------ identity
LEGACY = [
    # (spec, legacy make_scenario kwargs)
    (P.compose(P.iid(), P.static()), {}),
    (P.regional(burst_prob=0.2, burst_mult=10.0),
     dict(churn_policy="regional", burst_prob=0.2, burst_mult=10.0)),
    (P.adaptive(boost=3.0),
     dict(adv_policy="adaptive", adapt_boost=3.0)),
    (P.targeted_kill(budget=0.25, attack_step=3),
     dict(adv_policy="targeted", attack_frac=0.25, attack_step=3)),
    (P.eclipse(frac=0.3, window=2, attack_step=1),
     dict(adv_policy="eclipse", attack_frac=0.3, eclipse_steps=2,
          attack_step=1)),
    (P.compose(P.regional(burst_prob=0.2), P.adaptive()),
     dict(churn_policy="regional", adv_policy="adaptive", burst_prob=0.2)),
]


@pytest.mark.parametrize("spec,kwargs", LEGACY,
                         ids=[s.name for s, _ in LEGACY])
def test_spec_lowering_is_bit_identical_to_kwargs(spec, kwargs):
    """policy= and the legacy kwargs build the same Scenario, leaf for
    leaf — the combinator layer is pure construction-time sugar."""
    via_spec = SC.make_scenario(**BASE, policy=spec)
    via_kwargs = SC.make_scenario(**BASE, **kwargs)
    assert _cells_equal(via_spec, via_kwargs)


def test_string_and_none_shims_resolve_through_registry():
    """Pre-existing call sites pass names (or nothing): all of them must
    keep resolving, now through the one registry."""
    for name in ("iid", "regional"):
        assert P.resolve(name).churn == P.CHURN_POLICIES[name]
    for name in ("static", "adaptive", "targeted", "eclipse"):
        assert P.resolve(name).adversary == P.ADVERSARY_POLICIES[name]
    low = P.resolve(None)
    assert (low.churn, low.adversary) == (P.CHURN_IID, P.ADV_STATIC)
    # per-axis int shims are unchanged
    assert P.churn_policy_id(P.CHURN_REGIONAL) == P.CHURN_REGIONAL
    assert P.adv_policy_id("eclipse") == P.ADV_ECLIPSE


def test_protocol_params_policy_lowering_matches_kwargs():
    """ProtocolParams(policy=) lowers onto the same fields the legacy
    kwargs set; to_scenario_kwargs therefore builds the same cell."""
    small = dict(n_nodes=60, n_objects=2, steps=4)
    via_spec = PS.ProtocolParams(
        **small, policy=P.eclipse(frac=0.3, window=2, attack_step=1))
    via_kwargs = PS.ProtocolParams(
        **small, adv_policy="eclipse", attack_frac=0.3, eclipse_steps=2,
        attack_step=1)
    ks, kk = via_spec.to_scenario_kwargs(), via_kwargs.to_scenario_kwargs()
    kk["churn_policy"] = P.churn_policy_id(kk["churn_policy"])
    kk["adv_policy"] = P.adv_policy_id(kk["adv_policy"])
    assert ks == kk


# ----------------------------------------------------------------- round-trip
def test_every_registered_spec_round_trips():
    for entry in P.zoo_members():
        low = P.resolve(entry.spec)
        assert isinstance(low, P.LoweredPolicy)
        # LoweredPolicy passthrough: resolving a lowering is the identity
        assert P.resolve(low) is low
        # name resolution agrees with spec resolution
        assert P.resolve(entry.name) == low
        # lowered ids are registered, knob keys are valid
        assert low.churn in P.CHURN_POLICIES.values()
        assert low.adversary in P.ADVERSARY_POLICIES.values()
        assert set(low.knob_dict()) <= set(P.POLICY_KNOBS)


def test_lowered_policy_is_hashable_and_stable():
    a = P.resolve(P.compose(P.eclipse(frac=0.3), P.targeted_kill(0.2)))
    b = P.resolve(P.compose(P.eclipse(frac=0.3), P.targeted_kill(0.2)))
    assert a == b and hash(a) == hash(b)
    assert len({a, b}) == 1


# ---------------------------------------------------------------- composition
def test_compose_later_wins_per_axis():
    s = P.compose(P.iid(), P.regional(burst_prob=0.3))
    assert P.resolve(s).churn == P.CHURN_REGIONAL
    s = P.compose(P.adaptive(), P.static())
    assert P.resolve(s).adversary == P.ADV_STATIC
    # unset axes pass through untouched
    s = P.compose(P.diurnal(0.5), P.collude())
    low = P.resolve(s)
    assert (low.churn, low.adversary) == (P.CHURN_DIURNAL, P.ADV_COLLUDE)


def test_compose_knobs_merge_later_wins():
    s = P.compose(P.eclipse(frac=0.1, window=5),
                  P.targeted_kill(budget=0.4))
    kn = P.resolve(s).knob_dict()
    assert kn["attack_frac"] == 0.4  # shared budget knob: later wins
    assert kn["eclipse_steps"] == 5  # untouched by the later spec


def test_compose_product_table_symmetric_and_absorbing():
    et = P.ADV_ECLIPSE_TARGETED
    assert P.resolve(P.compose(P.eclipse(), P.targeted_kill())).adversary \
        == et
    assert P.resolve(P.compose(P.targeted_kill(), P.eclipse())).adversary \
        == et
    # absorbing: composing the product with either component stays product
    prod = P.compose(P.eclipse(), P.targeted_kill())
    assert P.resolve(P.compose(prod, P.eclipse())).adversary == et
    assert P.resolve(P.compose(prod, P.targeted_kill())).adversary == et
    # non-product adversary pairs still later-win
    assert P.resolve(P.compose(P.eclipse(), P.collude())).adversary \
        == P.ADV_COLLUDE


def test_compose_single_is_identity():
    s = P.regional(burst_prob=0.2)
    assert P.resolve(P.compose(s)) == P.resolve(s)


# ------------------------------------------------------------------ rejection
def test_plain_ints_are_rejected_as_axis_ambiguous():
    with pytest.raises(TypeError):
        P.resolve(P.ADV_TARGETED)
    with pytest.raises(KeyError):
        P.resolve("no_such_policy")
    with pytest.raises(TypeError):
        P._spec("bad", not_a_knob=1.0)


def test_unknown_spec_knob_raises_at_config_time():
    bad = P.PolicySpec(name="bad", churn=P.CHURN_IID,
                       knobs=(("not_a_knob", 1.0),))
    with pytest.raises(TypeError):
        SC.make_scenario(**BASE, policy=bad)
    with pytest.raises(TypeError):
        PS.ProtocolParams(n_nodes=60, policy=bad)


# ------------------------------------------------------------------- zoo shape
def test_zoo_registry_shape_and_guards():
    entries = P.zoo_members()
    names = [e.name for e in entries]
    assert len(names) == len(set(names))
    # the four ISSUE-10 members are registered, on top of the legacy six
    for required in ("diurnal_static", "pareto_static", "iid_collude",
                     "iid_eclipse_targeted"):
        assert required in names
    assert len(names) >= 10
    for e in entries:
        assert e.gate in ("two_sided", "one_sided")
    with pytest.raises(ValueError):
        P._register(P.ZooEntry(name="iid_static", spec=P.iid()))
    with pytest.raises(ValueError):
        P._register(P.ZooEntry(name="x_bad_gate", spec=P.iid(),
                               gate="sideways"))
    assert "x_bad_gate" not in [e.name for e in P.zoo_members()]


def test_stepfrac_resolves_with_integer_arithmetic():
    assert P.StepFrac(1, 3).resolve(30) == 10
    assert P.StepFrac(1, 2).resolve(31) == 15  # floor, like steps // 2
    kw = P.zoo_config_kwargs(P.zoo_entry("iid_eclipse"), 30)
    assert kw["attack_step"] == 7 and kw["eclipse_steps"] == 10
    assert kw["policy"] is P.zoo_entry("iid_eclipse").spec


def test_replace_keeps_protocol_policy_lowering_idempotent():
    import dataclasses

    p = PS.ProtocolParams(
        n_nodes=60, policy=P.compose(P.eclipse(frac=0.3, window=4,
                                               attack_step=3),
                                     P.targeted_kill(budget=0.25)))
    q = dataclasses.replace(p, seed=7)  # re-runs __post_init__
    assert q.adv_policy == p.adv_policy == P.ADV_ECLIPSE_TARGETED
    assert (q.attack_frac, q.eclipse_steps, q.attack_step) == \
        (p.attack_frac, p.eclipse_steps, p.attack_step)
