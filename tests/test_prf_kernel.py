"""prf_select Pallas kernel: tiling vs oracle, PRF statistics, selection."""
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.prf_select import prf_select_kernel

import jax.numpy as jnp


@pytest.mark.parametrize("n,f", [(1, 1), (8, 128), (13, 200), (40, 1000)])
def test_kernel_matches_ref(n, f):
    rng = np.random.default_rng(n * 100 + f)
    tags = rng.integers(-(2**31), 2**31 - 1, (n, 2)).astype(np.int32)
    fh = rng.integers(-(2**31), 2**31 - 1, (f, 2)).astype(np.int32)
    out = ops.prf_select(tags, fh)
    expect = np.asarray(ref.prf_select_ref(tags, fh))
    assert out.shape == (n, f) and out.dtype == np.int32
    assert np.array_equal(out, expect)


def test_kernel_tile_choices_agree():
    rng = np.random.default_rng(7)
    tags = rng.integers(-(2**31), 2**31 - 1, (16, 2)).astype(np.int32)
    fh = rng.integers(-(2**31), 2**31 - 1, (256, 2)).astype(np.int32)
    a = np.asarray(prf_select_kernel(jnp.asarray(tags), jnp.asarray(fh),
                                     tile_n=4, tile_f=128, interpret=True))
    b = np.asarray(prf_select_kernel(jnp.asarray(tags), jnp.asarray(fh),
                                     tile_n=16, tile_f=256, interpret=True))
    assert np.array_equal(a, b)


def test_prf_deterministic_and_key_sensitive():
    rng = np.random.default_rng(0)
    tags = rng.integers(-(2**31), 2**31 - 1, (4, 2)).astype(np.int32)
    fh = rng.integers(-(2**31), 2**31 - 1, (6, 2)).astype(np.int32)
    a = ops.prf_select(tags, fh)
    b = ops.prf_select(tags, fh)
    assert np.array_equal(a, b)
    tags2 = tags.copy()
    tags2[0, 0] ^= 1  # single-bit key change flips ~half the outputs
    c = ops.prf_select(tags2, fh)
    flips = np.unpackbits(
        (a[0] ^ c[0]).view(np.uint8)
    ).mean()
    assert 0.35 < flips < 0.65
    assert np.array_equal(a[1:], c[1:])  # other keys unaffected


def test_prf_uniformity():
    rng = np.random.default_rng(1)
    tags = rng.integers(-(2**31), 2**31 - 1, (32, 2)).astype(np.int32)
    fh = rng.integers(-(2**31), 2**31 - 1, (512, 2)).astype(np.int32)
    r = ops.prf_select(tags, fh)
    u = np.right_shift(r.view(np.uint32), 8).astype(np.float64) / 2**24
    assert abs(u.mean() - 0.5) < 0.01
    assert abs(u.std() - (1 / 12) ** 0.5) < 0.01
    # byte-level chi-square (loose)
    counts = np.bincount(r.view(np.uint8).reshape(-1), minlength=256)
    expect = counts.sum() / 256
    chi2 = ((counts - expect) ** 2 / expect).sum()
    assert chi2 < 256 * 1.6


def test_selection_mask_expected_count():
    """E[selected per fragment] ~ R, matching core/selection.py semantics."""
    rng = np.random.default_rng(2)
    n_nodes, r_target = 600, 40
    tags = rng.integers(-(2**31), 2**31 - 1, (n_nodes, 2)).astype(np.int32)
    fh = rng.integers(-(2**31), 2**31 - 1, (50, 2)).astype(np.int32)
    # two-sided ring distances in node-spacing units: 1,1,2,2,3,3,...
    d = np.repeat(np.arange(1, n_nodes // 2 + 1), 2)[:n_nodes].astype(float)
    mask = ops.selection_mask(tags, fh, d, r_target)
    per_frag = mask.sum(axis=0)
    assert 0.7 * r_target < per_frag.mean() < 1.3 * r_target


# ------------------------------------------------------------- pairs variant
from repro.kernels.prf_select import (PAIRS_KERNEL_MIN, arx_mix,
                                      arx_mix_np, arx_mix_words,
                                      prf_select_pairs)


@pytest.mark.parametrize("p", [0, 1, 7, 300, PAIRS_KERNEL_MIN,
                               PAIRS_KERNEL_MIN + 1, 5000])
def test_pairs_matches_numpy_mirror(p):
    """Kernel path, numpy path, and padding edges agree bit-for-bit."""
    rng = np.random.default_rng(p)
    tags = rng.integers(-(2**31), 2**31 - 1, (p, 2)).astype(np.int32)
    fh = rng.integers(-(2**31), 2**31 - 1, (p, 2)).astype(np.int32)
    got = prf_select_pairs(tags, fh)
    want = arx_mix_np(
        tags[:, 0].view(np.uint32), tags[:, 1].view(np.uint32),
        fh[:, 0].view(np.uint32), fh[:, 1].view(np.uint32)).view(np.int32)
    assert got.shape == (p,)
    np.testing.assert_array_equal(got, want)


def test_pairs_matches_scalar_words_and_jnp():
    """All four implementations of the ARX permutation are bit-identical:
    host scalar ints, vectorized numpy, traced jnp, and the pairs kernel."""
    rng = np.random.default_rng(0)
    p = 4096  # above PAIRS_KERNEL_MIN: exercises the pallas path
    tags = rng.integers(0, 2**32, (p, 2), np.uint64).astype(np.uint32)
    fh = rng.integers(0, 2**32, (p, 2), np.uint64).astype(np.uint32)
    k = prf_select_pairs(tags.view(np.int32), fh.view(np.int32))
    k = k.view(np.uint32)
    npv = arx_mix_np(tags[:, 0], tags[:, 1], fh[:, 0], fh[:, 1])
    np.testing.assert_array_equal(k, npv)
    j = np.asarray(arx_mix(
        jnp.asarray(tags[:, 0].view(np.int32)),
        jnp.asarray(tags[:, 1].view(np.int32)),
        jnp.asarray(fh[:, 0].view(np.int32)),
        jnp.asarray(fh[:, 1].view(np.int32)))).view(np.uint32)
    np.testing.assert_array_equal(j, npv)
    for i in (0, 17, p - 1):
        assert arx_mix_words(int(tags[i, 0]), int(tags[i, 1]),
                             int(fh[i, 0]), int(fh[i, 1])) == int(npv[i])


def test_pairs_agrees_with_grid_kernel_diagonal():
    """pairs(tags, fh)[i] equals the (i, i) entry of the N×F grid kernel —
    the two entry points compute one PRF."""
    rng = np.random.default_rng(3)
    n = 8
    tags = rng.integers(-(2**31), 2**31 - 1, (n, 2)).astype(np.int32)
    fh = rng.integers(-(2**31), 2**31 - 1, (128, 2)).astype(np.int32)
    grid = np.asarray(prf_select_kernel(tags, fh, tile_n=8, tile_f=128))
    pairs = prf_select_pairs(tags, fh[:n])
    np.testing.assert_array_equal(pairs, np.diagonal(grid)[:n])
