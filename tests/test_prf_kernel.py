"""prf_select Pallas kernel: tiling vs oracle, PRF statistics, selection."""
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.prf_select import prf_select_kernel

import jax.numpy as jnp


@pytest.mark.parametrize("n,f", [(1, 1), (8, 128), (13, 200), (40, 1000)])
def test_kernel_matches_ref(n, f):
    rng = np.random.default_rng(n * 100 + f)
    tags = rng.integers(-(2**31), 2**31 - 1, (n, 2)).astype(np.int32)
    fh = rng.integers(-(2**31), 2**31 - 1, (f, 2)).astype(np.int32)
    out = ops.prf_select(tags, fh)
    expect = np.asarray(ref.prf_select_ref(tags, fh))
    assert out.shape == (n, f) and out.dtype == np.int32
    assert np.array_equal(out, expect)


def test_kernel_tile_choices_agree():
    rng = np.random.default_rng(7)
    tags = rng.integers(-(2**31), 2**31 - 1, (16, 2)).astype(np.int32)
    fh = rng.integers(-(2**31), 2**31 - 1, (256, 2)).astype(np.int32)
    a = np.asarray(prf_select_kernel(jnp.asarray(tags), jnp.asarray(fh),
                                     tile_n=4, tile_f=128, interpret=True))
    b = np.asarray(prf_select_kernel(jnp.asarray(tags), jnp.asarray(fh),
                                     tile_n=16, tile_f=256, interpret=True))
    assert np.array_equal(a, b)


def test_prf_deterministic_and_key_sensitive():
    rng = np.random.default_rng(0)
    tags = rng.integers(-(2**31), 2**31 - 1, (4, 2)).astype(np.int32)
    fh = rng.integers(-(2**31), 2**31 - 1, (6, 2)).astype(np.int32)
    a = ops.prf_select(tags, fh)
    b = ops.prf_select(tags, fh)
    assert np.array_equal(a, b)
    tags2 = tags.copy()
    tags2[0, 0] ^= 1  # single-bit key change flips ~half the outputs
    c = ops.prf_select(tags2, fh)
    flips = np.unpackbits(
        (a[0] ^ c[0]).view(np.uint8)
    ).mean()
    assert 0.35 < flips < 0.65
    assert np.array_equal(a[1:], c[1:])  # other keys unaffected


def test_prf_uniformity():
    rng = np.random.default_rng(1)
    tags = rng.integers(-(2**31), 2**31 - 1, (32, 2)).astype(np.int32)
    fh = rng.integers(-(2**31), 2**31 - 1, (512, 2)).astype(np.int32)
    r = ops.prf_select(tags, fh)
    u = np.right_shift(r.view(np.uint32), 8).astype(np.float64) / 2**24
    assert abs(u.mean() - 0.5) < 0.01
    assert abs(u.std() - (1 / 12) ** 0.5) < 0.01
    # byte-level chi-square (loose)
    counts = np.bincount(r.view(np.uint8).reshape(-1), minlength=256)
    expect = counts.sum() / 256
    chi2 = ((counts - expect) ** 2 / expect).sum()
    assert chi2 < 256 * 1.6


def test_selection_mask_expected_count():
    """E[selected per fragment] ~ R, matching core/selection.py semantics."""
    rng = np.random.default_rng(2)
    n_nodes, r_target = 600, 40
    tags = rng.integers(-(2**31), 2**31 - 1, (n_nodes, 2)).astype(np.int32)
    fh = rng.integers(-(2**31), 2**31 - 1, (50, 2)).astype(np.int32)
    # two-sided ring distances in node-spacing units: 1,1,2,2,3,3,...
    d = np.repeat(np.arange(1, n_nodes // 2 + 1), 2)[:n_nodes].astype(float)
    mask = ops.selection_mask(tags, fh, d, r_target)
    per_frag = mask.sum(axis=0)
    assert 0.7 * r_target < per_frag.mean() < 1.3 * r_target
