"""Per-member invariants for the ISSUE-10 policy zoo, on both tiers.

One test family per new zoo member, checking the property that *defines*
the member rather than replaying goldens:

* **diurnal** — the rate factor averages to exactly 1 over whole days
  (the modulation integrates to the same yearly rate as ``iid``), and
  ``amplitude=0`` is bit-identical to ``iid`` through the engine.
* **pareto** — the engine's protected-cohort hazard sits strictly below
  the i.i.d. hazard for α > 1 (Jensen) and equals it as α → 1; the
  inverse-CDF session draw reproduces the target mean and respects the
  x_m floor; the protocol's session-based churn runs and actually
  diverges from the i.i.d. coin.
* **collude** — withholding never increases decode success: on BOTH
  tiers a collude run is identical to its matched static run in every
  durability and serving field, and strictly more expensive in repair
  traffic only.
* **eclipse+targeted** — with a zero-length window the composed product
  collapses bit-wise onto plain ``targeted`` on both tiers (the family
  lowering adds no behavior of its own).
"""
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from repro.core import policies as P  # noqa: E402
from repro.core import protocol_sim as PS  # noqa: E402
from repro.core import scenarios as SC  # noqa: E402

# one static shape for every engine cell in this file (jit cache reuse)
ENGINE_BASE = dict(n_objects=2, n_chunks=3, k_outer=2, k_inner=4,
                   r_inner=8, n_nodes=120, byz_fraction=0.2,
                   churn_per_year=40.0, step_hours=12.0, steps=8,
                   read_rate=20.0, zipf_alpha=1.1)
ENGINE_SEEDS = (0, 1, 2)

PROTO_BASE = dict(n_nodes=60, n_objects=2, n_chunks=3, k_outer=2,
                  k_inner=3, r_inner=6, byz_fraction=0.2,
                  churn_per_year=40.0, step_hours=12.0, steps=6,
                  claim_every=2, read_rate=20.0, seed=3)


def _grid(*cells):
    return SC.run_grid([dict(ENGINE_BASE, **c) for c in cells],
                       seeds=ENGINE_SEEDS, sampler="fast")


def _cell_equal(res, i, j, skip=()):
    """Bit-wise equality of two cells of one ScenarioResult."""
    for field, leaf in zip(res._fields, res):
        if field in skip:
            continue
        a, b = np.asarray(leaf[i]), np.asarray(leaf[j])
        assert np.array_equal(a, b), field
    return True


# -------------------------------------------------------------- diurnal
def test_diurnal_factor_integrates_to_unit_mean():
    """Whole days of midpoint-sampled factors average to exactly 1 — the
    modulated rate matches the iid yearly rate by construction."""
    for step_hours, n_days in ((6.0, 2), (12.0, 3), (8.0, 1)):
        steps = int(n_days * 24 / step_hours)
        t = np.arange(steps, dtype=np.float64)
        f = P.diurnal_rate_factor(t, step_hours, 0.6, xp=np)
        assert abs(float(f.mean()) - 1.0) < 1e-9, (step_hours, n_days)
        assert float(f.max()) > 1.0 and float(f.min()) < 1.0


def test_diurnal_p_fail_passthrough_and_zero_amplitude():
    base = float(P.p_fail_step(40.0, 12.0, xp=np))
    # non-diurnal policies: pass-through is value-identical
    for cp in (P.CHURN_IID, P.CHURN_REGIONAL, P.CHURN_PARETO):
        got = float(P.diurnal_p_fail(cp, 40.0, 0.6, 3, 12.0, base, xp=np))
        assert got == base, cp
    # diurnal with amplitude 0: the modulated rate IS the base rate
    got = float(P.diurnal_p_fail(P.CHURN_DIURNAL, 40.0, 0.0, 3, 12.0,
                                 base, xp=np))
    assert got == base
    # endpoint sampling would alias to zero here; the midpoint must not
    hot = float(P.diurnal_p_fail(P.CHURN_DIURNAL, 40.0, 0.6, 0, 12.0,
                                 base, xp=np))
    assert hot != base


def test_diurnal_amplitude_zero_is_iid_bitwise_engine():
    res = _grid(dict(churn_policy="iid"),
                dict(churn_policy="diurnal", diurnal_amplitude=0.0))
    _cell_equal(res, 0, 1)


# --------------------------------------------------------------- pareto
def test_pareto_hazard_below_iid_jensen():
    base = float(P.p_fail_step(40.0, 12.0, xp=np))
    for alpha in (1.2, 1.5, 3.0):
        pp = float(P.pareto_p_fail(P.CHURN_PARETO, 40.0, alpha, 12.0,
                                   base, xp=np))
        assert pp < base, alpha
    # α → 1 recovers the i.i.d. hazard; other policies pass through
    near = float(P.pareto_p_fail(P.CHURN_PARETO, 40.0, 1.0 + 1e-6, 12.0,
                                 base, xp=np))
    assert abs(near - base) < 1e-6
    assert float(P.pareto_p_fail(P.CHURN_IID, 40.0, 1.5, 12.0, base,
                                 xp=np)) == base


def test_pareto_session_draw_mean_and_floor():
    mean_h = float(P.pareto_session_mean_hours(26.0, xp=np))
    u = (np.arange(200_000, dtype=np.float64) + 0.5) / 200_000
    draws = P.pareto_session_from_uniform(u, mean_h, 1.5, xp=np)
    # inverse-CDF quadrature reproduces the target mean (heavy tail:
    # midpoint truncation keeps this a couple of percent low)
    assert abs(float(draws.mean()) - mean_h) / mean_h < 0.05
    # the x_m protected floor: no session shorter than the scale
    xm = float(P.pareto_xm_hours(mean_h, 1.5, xp=np))
    assert float(draws.min()) >= xm - 1e-9


def test_pareto_protocol_sessions_diverge_from_iid():
    # crank the rate so x_m (the no-death session floor: mean·(α−1)/α,
    # ≈ 6 steps at the base rate) fits inside this short run
    fast = {**PROTO_BASE, "churn_per_year": 400.0}
    iid = PS.run_protocol(PS.ProtocolParams(**fast, policy="iid"))
    par = PS.run_protocol(
        PS.ProtocolParams(**fast, policy=P.pareto_sessions(1.5)))
    assert par.repairs > 0  # sessions expire, churn really happens
    assert np.all(par.alive_frac_trace >= 0.0)
    # the deterministic session clock is a different churn process from
    # the per-step coin — the runs must not coincide
    assert not np.array_equal(iid.honest_trace, par.honest_trace)


# -------------------------------------------------------------- collude
_DURABILITY = ("repairs", "cache_hits", "lost_objects", "lost_fraction",
               "final_honest_mean", "honest_min", "members_max")
_SERVING = ("reads_issued", "reads_hit", "reads_miss", "reads_degraded",
            "reads_failed", "served_traffic_units")


def test_collude_engine_traffic_only_differential():
    res = _grid(dict(adv_policy="static"), dict(adv_policy="collude"))
    # everything except the traffic bill is bit-identical
    _cell_equal(res, 0, 1, skip=("repair_traffic_units",))
    st = np.asarray(res.repair_traffic_units[0], np.float64)
    co = np.asarray(res.repair_traffic_units[1], np.float64)
    assert np.all(co >= st)
    assert np.any(co > st)  # wasted colluder pulls really get charged


def test_collude_protocol_traffic_only_differential():
    st = PS.run_protocol(PS.ProtocolParams(**PROTO_BASE, policy="static"))
    co = PS.run_protocol(
        PS.ProtocolParams(**PROTO_BASE, policy=P.collude()))
    # withholding never increases decode success: every durability and
    # serving field matches the static run exactly (corrupt rows never
    # reach a decode, corrupt-only candidates never join the fan-out)
    for field in _DURABILITY + _SERVING:
        assert getattr(co, field) == getattr(st, field), field
    assert np.array_equal(co.honest_trace, st.honest_trace)
    assert np.array_equal(co.byz_trace, st.byz_trace)
    assert np.array_equal(co.alive_frac_trace, st.alive_frac_trace)
    assert co.loss_events == st.loss_events
    # ... and the integrity-checked-and-discarded pulls cost extra
    assert co.repair_traffic_units > st.repair_traffic_units


# ---------------------------------------------------- eclipse + targeted
_ET_KW = dict(attack_frac=0.25, attack_step=3)


def test_eclipse_targeted_zero_window_is_targeted_engine():
    res = _grid(dict(adv_policy="targeted", **_ET_KW),
                dict(policy=P.compose(P.eclipse(frac=0.25, window=0,
                                                attack_step=3),
                                      P.targeted_kill(budget=0.25,
                                                      attack_step=3))))
    # the product id only adds the window; window 0 must collapse onto
    # plain targeted bit-for-bit (family-flag lowering, no retrace)
    _cell_equal(res, 0, 1)


def test_eclipse_targeted_zero_window_is_targeted_protocol():
    tg = PS.run_protocol(PS.ProtocolParams(
        **PROTO_BASE, adv_policy="targeted", **_ET_KW))
    pp = PS.ProtocolParams(
        **PROTO_BASE, policy=P.compose(
            P.eclipse(frac=0.25, window=0, attack_step=3),
            P.targeted_kill(budget=0.25, attack_step=3)))
    # distinct lowered id (the product), identical behavior at window 0
    assert P.adv_policy_id(pp.adv_policy) == P.ADV_ECLIPSE_TARGETED
    et = PS.run_protocol(pp)
    for field in _DURABILITY + _SERVING + ("repair_traffic_units",):
        assert getattr(et, field) == getattr(tg, field), field
    assert np.array_equal(et.honest_trace, tg.honest_trace)
    assert np.array_equal(et.byz_trace, tg.byz_trace)


def test_eclipse_targeted_window_hurts():
    """Opening the window on top of the kill can only cost durability:
    eclipsed groups can neither repair nor serve through the cut."""
    res = _grid(
        dict(policy=P.compose(P.eclipse(frac=0.3, window=0, attack_step=3),
                              P.targeted_kill(budget=0.25, attack_step=3))),
        dict(policy=P.compose(P.eclipse(frac=0.3, window=4, attack_step=3),
                              P.targeted_kill(budget=0.25, attack_step=3))))
    closed = np.asarray(res.lost_objects[0], np.float64).mean()
    open_ = np.asarray(res.lost_objects[1], np.float64).mean()
    assert open_ >= closed
