"""Boundedness tests for the dead-node reaper.

Before the reaper, ``SimNetwork`` and the VRF registries kept every node
ever spawned: ``fail_node`` only flipped liveness bits, so a churn-heavy
simulated month accrued one keypair, tag entry, selection-verdict cache,
fragment dict and group-view dict per replacement — unbounded growth in
the number of *deaths*, not the population. ``fail_node`` now deletes the
per-node dict state, evicts the key material from the registry, and lazily
compacts the dense row tables; these tests pin the resulting invariants
under sustained churn, on both engines and both VRF backends.
"""
import dataclasses

import pytest

from repro.core import rateless as rl
from repro.core.protocol_sim import ProtocolParams, run_protocol
from repro.core.vrf import ArxVRFRegistry

# ~8 expected failures per step across the population: enough deaths over
# 12 steps to overrun any "plus a small constant" slack were state leaking.
_CHURN = ProtocolParams(
    n_nodes=60, n_objects=2, object_bytes=900, k_outer=2, n_chunks=4,
    k_inner=4, r_inner=10, churn_per_year=360.0, step_hours=24.0,
    steps=12, claim_every=1, seed=5)


def _assert_bounded(t: int, net) -> None:
    n = net.n_nodes
    reg = net.registry
    # node dict state is exactly the alive population
    assert len(net.nodes) == n
    assert len(net.row_of) == n
    assert set(net.nodes) == set(net._ring)
    # dense row tables: dead rows are bounded by the lazy-compaction
    # threshold (max(64, alive)), never by the cumulative death count
    assert len(net._rows) <= 2 * n + 65
    assert net._dead_rows <= max(64, n)
    # registry state is keyed per alive node (+1: the client keypair)
    assert len(reg._tags) <= n + 1
    assert len(reg.selection_cache) <= n + 1
    if isinstance(reg, ArxVRFRegistry):
        assert len(reg._words) <= n + 1
        assert len(reg._sk_words) <= n + 1
    # coeff-row memo: one row per (chunk, fragment index) with an alive
    # holder (fail_node evicts the dead holder's rows — same hook as the
    # VRF registry eviction) plus one outer-code row per (object, chunk),
    # which are population-independent. Never grows with cumulative
    # deaths.
    live_frags = sum(len(node.fragments) for node in net.nodes.values())
    outer_rows = _CHURN.n_objects * _CHURN.n_chunks
    assert rl._coeff_row.cache_info().currsize <= live_frags + outer_rows
    # cumulative Locate() donor state: dead candidate rows survive only
    # until the next row-table compaction, so per round they are bounded
    # by the compaction trigger (deaths since the last sweep), never by
    # the cumulative death count
    dead_cap = max(64, n) + 1
    for cache in (net._locate_cache, net._locate_prev):
        for lr in cache.values():
            assert sum(1 for c in lr.candidates if not c.alive) <= dead_cap


@pytest.mark.parametrize("engine", ["reference", "vectorized"])
@pytest.mark.parametrize("vrf", ["hash", "arx"])
def test_state_bounded_under_churn(engine, vrf):
    p = dataclasses.replace(_CHURN, vrf=vrf)
    rl._coeff_row.cache_clear()  # module-global memo: isolate this run
    ever: set[int] = set()

    def probe(t, net):
        ever.update(net.nodes)
        _assert_bounded(t, net)

    run_protocol(p, engine=engine, probe=probe)
    # the bounds above are only meaningful if churn actually cycled a
    # large multiple of the population through the network
    assert len(ever) > 2 * p.n_nodes


def test_compaction_renumbers_consistently():
    """Force row-table compactions and check row_of / Node.row / alive_rows
    stay mutually consistent (the invariant claims_engine gathers rely on,
    via rows_version)."""
    from repro.core.network import SimNetwork

    net = SimNetwork(seed=3)
    for i in range(40):
        net.add_node(seed=i.to_bytes(4, "little"))
    versions = {net.rows_version}
    for round_ in range(6):
        doomed = list(net._ring)[::3]
        for nid in doomed:
            net.fail_node(nid)
        for i in range(len(doomed)):
            net.add_node(seed=(1000 + 100 * round_ + i).to_bytes(4, "little"))
        versions.add(net.rows_version)
        for nid, row in net.row_of.items():
            node = net.nodes[nid]
            assert node.row == row
            assert net._rows[row] is node
            assert net.alive_rows[row]
        assert net._dead_rows == sum(r is None for r in net._rows)
    assert len(versions) > 1  # at least one compaction actually happened
