"""Durability theory (App. A): CTMC, Hoeffding, targeted-attack bound."""
import math

import numpy as np

from repro.core import durability as D


def test_initial_state_vector_normalized():
    I = D.initial_state_vector(100_000, 33_333, 80, 32)
    assert abs(I.sum() - 1.0) < 1e-9
    assert np.all(I >= 0)
    # absorbing mass at t=0 is tiny at paper parameters
    assert I[-1] < 1e-5


def test_hoeffding_bounds_exact_tail():
    """Eq. 4 upper-bounds the exact hypergeometric tail (eq. 3)."""
    N, n, k = 100_000, 80, 32
    F = N // 3
    I = D.initial_state_vector(N, F, n, k)
    exact_tail = I[-1]
    bound = D.hoeffding_initial_bound(n, k)
    assert exact_tail <= bound
    assert bound < 1e-3


def test_transition_matrix_stochastic():
    theta = D.transition_matrix(10_000, 3_333, 40, 16, churn_mu=0.4,
                                evict=0)
    rows = theta.sum(axis=1)
    assert np.allclose(rows, 1.0, atol=1e-9)
    assert np.all(theta >= -1e-15)
    # absorbing state is absorbing
    assert theta[-1, -1] == 1.0
    assert np.all(theta[-1, :-1] == 0.0)


def test_absorption_monotone_and_converges():
    N, F, n, k = 10_000, 3_333, 40, 16
    I = D.initial_state_vector(N, F, n, k)
    theta = D.transition_matrix(N, F, n, k, churn_mu=0.6)
    traj = D.absorb_probability(I, theta, 1200)
    assert np.all(np.diff(traj) >= -1e-12)  # cumulative
    assert traj[-1] <= 1.0 + 1e-9  # fp64 accumulation
    # As T->inf the probability converges to 1 (paper §4.4.1): without
    # eviction, Byzantine members ratchet upward until absorption
    assert traj[-1] > 0.99
    assert traj[20] < traj[-1]  # early probability is strictly smaller


def test_eviction_slows_absorption():
    """The eviction parameter Υ flushes accumulated Byzantine members —
    absorption probability at fixed t must drop."""
    N, F, n, k = 10_000, 3_333, 40, 16
    I = D.initial_state_vector(N, F, n, k)
    t0 = D.transition_matrix(N, F, n, k, churn_mu=0.5, evict=0)
    t2 = D.transition_matrix(N, F, n, k, churn_mu=0.5, evict=2)
    a0 = D.absorb_probability(I, t0, 600)[-1]
    a2 = D.absorb_probability(I, t2, 600)[-1]
    assert a2 < a0


def test_object_loss_bound():
    p = 1e-6
    b = D.object_loss_bound(p, 10)
    assert abs(b - (1 - (1 - p) ** 10)) < 1e-12
    assert D.object_loss_bound(1.0, 10) == 1.0


def test_group_durability_horizon_positive():
    t = D.group_durability_horizon(
        100_000, 33_333, 80, 32, churn_mu=0.05, eps_log2=-20.0,
        max_steps=50,
    )
    assert t >= 1


def test_targeted_attack_bound_monotonicity():
    K, R, omega = 8, 6, 1_000
    # more compromised groups -> higher success probability
    probs = [D.targeted_attack_bound(K, R, omega, phi) for phi in
             (10, 50, 200, 1000)]
    assert all(b >= a - 1e-18 for a, b in zip(probs, probs[1:]))
    # more objects (same attack budget) -> lower probability
    p_small = D.targeted_attack_bound(K, R, 100, 50)
    p_large = D.targeted_attack_bound(K, R, 10_000, 50)
    assert p_large < p_small
    # below R+1 kills nothing can be assembled
    assert D.targeted_attack_bound(K, R, omega, phi_groups=R // 2) == 0.0
    # multiple fragments per node amplify the attacker (eq. 17)
    assert (D.targeted_attack_bound(K, R, omega, 50, g=4)
            >= D.targeted_attack_bound(K, R, omega, 50, g=1))


def test_targeted_attack_bound_in_unit_interval():
    for phi in (7, 100, 10_000):
        p = D.targeted_attack_bound(8, 6, 500, phi, g=2)
        assert 0.0 <= p <= 1.0
        assert math.isfinite(p)


def test_attacker_groups():
    # avg kill cost = n/3 - k + 1 honest removals (A.3)
    per_group = 80 // 3 - 32 + 1  # = -5 -> clamped to >= 1? n/3 < k here
    assert D.attacker_groups(phi_nodes=220, n=80, k=32) == 220 // max(
        1, per_group
    )
    # a configuration where n/3 > k
    assert D.attacker_groups(phi_nodes=100, n=120, k=30) == 100 // (40 - 30 + 1)
