"""Docs stay true: intra-repo links resolve, fenced python snippets run.

Link checks are instant and always on. Snippet execution costs a jit
compile per engine snippet, so each snippet is its own parametrized test
case (clear attribution on failure, and the suite stays `-x`-friendly).
"""
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "scripts"))

import check_docs  # noqa: E402


def _cases():
    for path in check_docs.doc_files():
        rel = str(path.relative_to(REPO))
        for line, src in check_docs.python_snippets(path):
            yield pytest.param(src, id=f"{rel}:{line}")


def test_docs_exist():
    assert (REPO / "README.md").exists()
    assert (REPO / "docs" / "ARCHITECTURE.md").exists()
    assert (REPO / "docs" / "engine_guide.md").exists()


@pytest.mark.parametrize("path", check_docs.doc_files(),
                         ids=lambda p: p.name)
def test_intra_repo_links_resolve(path):
    assert check_docs.check_links(path) == []


def test_docs_have_snippets():
    assert len(list(_cases())) >= 3  # quickstarts + engine guide


@pytest.mark.parametrize("src", _cases())
def test_python_snippets_run(src):
    ok, out = check_docs.run_snippet(src)
    assert ok, out
