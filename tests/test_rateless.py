"""Rateless codes: roundtrip properties, overhead ε, failure modes."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # CI installs hypothesis; local runs may lack it
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.rateless import InsufficientFragments, LTCode, RLNC


@given(
    k=st.integers(2, 24),
    length=st.integers(1, 90),
    seed=st.integers(0, 2**32 - 1),
    offset=st.integers(0, 1000),
)
@settings(max_examples=25, deadline=None)
def test_rlnc_roundtrip_any_k_symbols(k, length, seed, offset):
    rng = np.random.default_rng(seed)
    blocks = rng.integers(0, 256, (k, length), dtype=np.uint8)
    code = RLNC(k=k, seed=seed.to_bytes(8, "little"))
    idx = list(range(offset, offset + k + 2))
    syms = code.encode(blocks, idx)
    # decode from an arbitrary k+2 subset (dense rows: full rank whp)
    dec = code.decode(idx, syms)
    assert np.array_equal(dec, blocks)


def test_rlnc_overhead_epsilon():
    """Dense GF(256) rows: P[k symbols decode] ≈ prod(1-256^-j) ≈ 0.996 —
    the paper quotes wirehair's k+0.02 expected overhead; dense RLNC is
    strictly better. Measure decode success with exactly k symbols."""
    rng = np.random.default_rng(7)
    k = 32
    ok = 0
    trials = 60
    blocks = rng.integers(0, 256, (k, 16), dtype=np.uint8)
    for t in range(trials):
        code = RLNC(k=k, seed=t.to_bytes(8, "little"))
        idx = rng.choice(10_000, size=k, replace=False).tolist()
        syms = code.encode(blocks, idx)
        try:
            dec = code.decode(idx, syms)
            ok += int(np.array_equal(dec, blocks))
        except InsufficientFragments:
            pass
    assert ok / trials > 0.95  # expected ~0.996


def test_rlnc_insufficient_raises():
    code = RLNC(k=8, seed=b"x")
    blocks = np.zeros((8, 4), np.uint8)
    syms = code.encode(blocks, list(range(5)))
    with pytest.raises(InsufficientFragments):
        code.decode(list(range(5)), syms)


def test_rlnc_kernel_backend_matches():
    rng = np.random.default_rng(3)
    k = 16
    blocks = rng.integers(0, 256, (k, 200), dtype=np.uint8)
    code = RLNC(k=k, seed=b"kern")
    idx = list(range(40))
    a = code.encode(blocks, idx, backend="numpy")
    b = code.encode(blocks, idx, backend="kernel")
    assert np.array_equal(a, b)


@given(
    k=st.integers(4, 20),
    length=st.integers(1, 60),
    seed=st.integers(0, 2**31),
)
@settings(max_examples=15, deadline=None)
def test_lt_roundtrip(k, length, seed):
    rng = np.random.default_rng(seed)
    blocks = rng.integers(0, 256, (k, length), dtype=np.uint8)
    code = LTCode(k=k, seed=seed.to_bytes(8, "little"))
    n = 2 * k + 8  # LT needs overhead; peeling + gaussian fallback
    idx = list(range(n))
    syms = code.encode(blocks, idx)
    dec = code.decode(idx, syms)
    assert np.array_equal(dec, blocks)


def test_lt_kernel_backend_matches():
    rng = np.random.default_rng(5)
    blocks = rng.integers(0, 256, (12, 100), dtype=np.uint8)
    code = LTCode(k=12, seed=b"lt")
    idx = list(range(30))
    a = code.encode(blocks, idx, backend="numpy")
    b = code.encode(blocks, idx, backend="kernel")
    assert np.array_equal(a, b)


def test_stream_determinism():
    code = RLNC(k=8, seed=b"det")
    r1 = code.coeff_row(12345)
    r2 = code.coeff_row(12345)
    assert np.array_equal(r1, r2)
    assert not np.array_equal(code.coeff_row(1), code.coeff_row(2))
