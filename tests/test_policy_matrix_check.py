"""``scripts/check_policy_matrix.py`` really fails on a doctored registry.

Stdlib-only guard for the guard: the checker must pass on the repo as
committed, and must exit non-zero (with a pointed message) when

* the benchmark stops calling ``zoo_members()`` (auto-discovery reverted),
* an ``EXCLUDED_ROWS`` waiver has an empty reason,
* a waiver names a policy that is not registered (stale), or
* the registry literals stop being ast-discoverable.

Mirrors ``tests/test_ci_shards.py`` for ``check_shards.py``.
"""
import pathlib
import re
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "scripts"))

import check_policy_matrix as CPM  # noqa: E402

POLICIES = ROOT / "src" / "repro" / "core" / "policies.py"
BENCH = ROOT / "benchmarks" / "cross_validate.py"


def test_repo_as_committed_passes(capsys):
    assert CPM.main([]) == 0
    out = capsys.readouterr().out
    assert "OK" in out and "registered policies" in out


def test_registered_names_found_in_real_registry():
    names = CPM.registered_names(POLICIES)
    for required in ("iid_static", "diurnal_static", "pareto_static",
                     "iid_collude", "iid_eclipse_targeted"):
        assert required in names
    assert len(names) >= 10


def _doctored(tmp_path, src: pathlib.Path, pattern: str, repl: str,
              count_required: int = 1) -> str:
    text = src.read_text()
    doctored, n = re.subn(pattern, repl, text)
    assert n >= count_required, f"doctoring pattern missed: {pattern}"
    out = tmp_path / src.name
    out.write_text(doctored)
    return str(out)


def test_fails_when_auto_discovery_reverted(tmp_path, capsys):
    bench = _doctored(tmp_path, BENCH, r"zoo_members", "hand_written_rows")
    assert CPM.main(["--bench", bench]) == 1
    assert "auto-discovered" in capsys.readouterr().err


def test_fails_on_unexplained_waiver(tmp_path, capsys):
    bench = _doctored(
        tmp_path, BENCH,
        r"EXCLUDED_ROWS: dict\[str, str\] = \{\}",
        'EXCLUDED_ROWS: dict[str, str] = {"iid_collude": ""}')
    assert CPM.main(["--bench", bench]) == 1
    assert "no reason" in capsys.readouterr().err


def test_fails_on_stale_waiver(tmp_path, capsys):
    bench = _doctored(
        tmp_path, BENCH,
        r"EXCLUDED_ROWS: dict\[str, str\] = \{\}",
        'EXCLUDED_ROWS: dict[str, str] = '
        '{"renamed_long_ago": "was too slow"}')
    assert CPM.main(["--bench", bench]) == 1
    assert "stale waiver" in capsys.readouterr().err


def test_fails_when_registry_not_parseable(tmp_path):
    policies = _doctored(tmp_path, POLICIES, r"_register\(",
                         "_register_dynamically(")
    with pytest.raises(SystemExit, match="no _register"):
        CPM.registered_names(pathlib.Path(policies))


def test_fails_on_duplicate_registration(tmp_path, capsys):
    # append a second iid_static literal: ast sees the name twice
    out = tmp_path / "policies.py"
    out.write_text(POLICIES.read_text()
                   + '\n_register(ZooEntry(\n    name="iid_static",\n'
                   '    spec=compose(iid(), static())))\n')
    assert CPM.main(["--policies", str(out)]) == 1
    assert "more than once" in capsys.readouterr().err


def test_waived_policy_is_accepted_with_reason(tmp_path, capsys):
    bench = _doctored(
        tmp_path, BENCH,
        r"EXCLUDED_ROWS: dict\[str, str\] = \{\}",
        'EXCLUDED_ROWS: dict[str, str] = '
        '{"iid_collude": "example: waived for a documented reason"}')
    assert CPM.main(["--bench", bench]) == 0
    assert "1 waived" in capsys.readouterr().out
