"""Launcher drivers run end-to-end (subprocess smoke)."""
import os
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]


def run_module(mod: str, *args: str, timeout=420) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run(
        [sys.executable, "-m", mod, *args], capture_output=True, text=True,
        env=env, timeout=timeout, cwd=REPO,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    return out.stdout


def test_train_driver_with_drill():
    out = run_module(
        "repro.launch.train", "--arch", "internlm2-20b", "--steps", "12",
        "--batch", "2", "--seq", "32", "--ckpt-every", "5", "--kill-at", "7",
        "--kill-fraction", "0.25", "--vault-nodes", "120",
        "--log-every", "4",
    )
    assert "restore OK" in out
    assert "improved" in out.splitlines()[-1]


def test_serve_driver():
    out = run_module(
        "repro.launch.serve", "--arch", "qwen1.5-110b", "--batch", "2",
        "--prompt-len", "12", "--decode-steps", "4",
    )
    assert "decode:" in out and "tok/s" in out


def test_dryrun_single_cell_fast():
    """One real dry-run cell on the 512-device mesh, analysis skipped
    (the full sweep is results/dryrun; this guards the entry point)."""
    out = run_module(
        "repro.launch.dryrun", "--arch", "mamba2-2.7b", "--shape",
        "decode_32k", "--mesh", "single", "--no-analysis", "--tag", "smoke",
        timeout=420,
    )
    assert "[ok]" in out
