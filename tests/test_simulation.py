"""Paper-scale statistical simulations (§6.1): reproduce Fig 4/5/6 claims."""
import numpy as np

from repro.core import simulation as S


def test_vault_tolerates_one_third_byzantine():
    p = S.SimParams(n_objects=150, byz_fraction=1 / 3, churn_per_year=26.0,
                    seed=11)
    r = S.simulate_vault(p)
    assert r.lost_objects == 0


def test_replicated_baseline_collapses_at_small_byzantine():
    p = S.SimParams(n_objects=150, byz_fraction=0.05, churn_per_year=26.0,
                    seed=12)
    r = S.simulate_replicated(p)
    assert r.lost_fraction > 0.5  # paper: all objects lost below 5%


def test_vault_loses_past_tolerance():
    p = S.SimParams(n_objects=100, byz_fraction=0.5, churn_per_year=26.0,
                    seed=13)
    r = S.simulate_vault(p)
    assert r.lost_fraction > 0.3


def test_cache_reduces_repair_traffic():
    base = dict(n_objects=150, churn_per_year=26.0, seed=14)
    r0 = S.simulate_vault(S.SimParams(cache_ttl_hours=0.0, **base))
    r48 = S.simulate_vault(S.SimParams(cache_ttl_hours=48.0, **base))
    assert r48.repair_traffic_units < r0.repair_traffic_units / 4
    assert r48.cache_hits > 0


def test_traffic_scales_linearly_with_objects():
    a = S.simulate_vault(S.SimParams(n_objects=100, seed=15,
                                     churn_per_year=26.0))
    b = S.simulate_vault(S.SimParams(n_objects=300, seed=15,
                                     churn_per_year=26.0))
    ratio = b.repair_traffic_units / a.repair_traffic_units
    assert 2.0 < ratio < 4.5  # ~3x


def test_fragment_trace_stays_recoverable():
    tr = S.fragment_trace(32, 80, byz_fraction=1 / 3, churn_per_year=26.0,
                          years=5.0, seed=16)
    assert tr.min() >= 32  # Fig. 5: never below K_inner
    # higher redundancy keeps a wider margin
    tr2 = S.fragment_trace(32, 48, byz_fraction=1 / 3, churn_per_year=26.0,
                           years=5.0, seed=16)
    assert tr.mean() > tr2.mean()


def test_targeted_attack_outer_code_ordering():
    """Fig. 6 bottom: more outer redundancy tolerates more attacked nodes."""
    losses = {}
    for n_chunks in (10, 12, 14):
        p = S.SimParams(n_objects=300, n_chunks=n_chunks, byz_fraction=1 / 3,
                        seed=17)
        losses[n_chunks] = S.targeted_attack_vault(p, attacked_fraction=0.2)
    assert losses[14] <= losses[12] <= losses[10]
    p14 = S.SimParams(n_objects=300, n_chunks=14, byz_fraction=1 / 3, seed=17)
    assert S.targeted_attack_vault(p14, 0.1) < 0.01  # no loss ≤ 10-20%


def test_targeted_attack_baseline_dies_immediately():
    p = S.SimParams(n_objects=500)
    assert S.targeted_attack_replicated(p, 0.02) >= 1.0  # <2% kills all
