"""Gradient compression (int8+EF) and elastic re-meshing."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.compression import dequantize_int8, quantize_int8
from repro.runtime import StragglerDetector, plan_mesh
from repro.runtime.failure import HeartbeatMonitor


def test_quantize_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((256,)) * 3.0, jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_int8_allreduce_matches_mean(subproc):
    out = subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.compression import shard_map
from jax.sharding import PartitionSpec as P
from repro.distributed.compression import int8_all_reduce_mean
mesh = jax.make_mesh((4,), ("data",))
x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 64)),
                jnp.float32)
f = shard_map(lambda v: int8_all_reduce_mean(v[0], "data"),
              mesh=mesh, in_specs=P("data"), out_specs=P(),
              check_vma=False)
got = np.asarray(f(x))
want = np.asarray(x.mean(0))
rel = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
assert rel < 0.02, rel
print("OK", rel)
""",
        devices=4,
    )
    assert "OK" in out


def test_error_feedback_convergence(subproc):
    """SGD on a quadratic with int8+EF gradient reduce converges to the
    same optimum as exact reduction (error feedback removes the bias)."""
    out = subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.compression import shard_map
from jax.sharding import PartitionSpec as P
from repro.distributed.compression import tree_int8_all_reduce_mean
mesh = jax.make_mesh((4,), ("data",))
rng = np.random.default_rng(1)
target = jnp.asarray(rng.standard_normal((4, 32)), jnp.float32)

def run(compress):
    w = jnp.zeros((32,))
    err = {"w": jnp.zeros((32,))}
    def one(w, err, tgt):
        g = {"w": w - tgt.mean(0)}  # local grad per shard uses local target
        def inner(tgt_loc, w, e):
            gl = {"w": w - tgt_loc[0]}
            if compress:
                red, e2 = tree_int8_all_reduce_mean(gl, "data", {"w": e})
                return red["w"], e2["w"]
            return jax.lax.pmean(gl["w"], "data"), e
        f = shard_map(inner, mesh=mesh,
                      in_specs=(P("data"), P(), P()), out_specs=(P(), P()),
                      check_vma=False)
        gr, e2 = f(tgt, w, err["w"])
        return w - 0.3 * gr, {"w": e2}
    for _ in range(60):
        w, err = one(w, err, target)
    return np.asarray(w)

w_exact = run(False)
w_comp = run(True)
opt = np.asarray(target.mean(0))
assert np.abs(w_exact - opt).max() < 1e-3
assert np.abs(w_comp - opt).max() < 2e-2, np.abs(w_comp - opt).max()
print("OK")
""",
        devices=4,
    )
    assert "OK" in out


def test_plan_mesh():
    assert plan_mesh(256) in ((16, 16), (32, 8))
    d, m = plan_mesh(240)  # non-power-of-two device counts still factor
    assert d * m == 240
    d, m = plan_mesh(64, prefer_model=24)  # model must divide head count
    assert d * m == 64 and 24 % m == 0


def test_elastic_reshard_roundtrip(subproc):
    out = subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.distributed import sharding as shd
from repro.models import param_specs
from repro.runtime.elastic import reshard_state, state_shardings
from repro.training import init_train_state

cfg = configs.smoke_config("codeqwen1.5-7b")
state = init_train_state(cfg, jax.random.PRNGKey(0))
host = jax.tree_util.tree_map(np.asarray, state["params"])
# "restore onto the smaller surviving mesh"
mesh2 = jax.make_mesh((2,), ("model",))
sh = state_shardings(param_specs(cfg), jax.eval_shape(lambda: state["params"]),
                     mesh2)
placed = reshard_state(host, sh)
for a, b in zip(jax.tree_util.tree_leaves(placed),
                jax.tree_util.tree_leaves(host)):
    np.testing.assert_array_equal(np.asarray(a), b)
print("OK")
""",
        devices=4,
    )
    assert "OK" in out


def test_heartbeat_monitor():
    hb = HeartbeatMonitor(timeout_s=5.0)
    hb.beat("a", 0.0)
    hb.beat("b", 0.0)
    hb.beat("a", 4.0)
    assert set(hb.alive(8.0)) == {"a"}
    assert set(hb.dead(8.0)) == {"b"}


def test_straggler_detector():
    d = StragglerDetector(min_samples=3)
    for t in range(6):
        for h in ("h0", "h1", "h2", "h3"):
            d.record(h, 1.0 if h != "h3" else 3.2)
    actions = {x.host: x.action for x in d.decisions()}
    assert actions["h3"] == "drop"
    assert actions["h0"] == "ok"
    assert d.to_drop() == ["h3"]
