"""Regression tests for ``SimNetwork.candidates()`` — the DHT ring walk.

The seed implementation walked the ring with ``lo``/``hi`` pointers that
could both visit the same node after wrap-around; the trailing
``dict.fromkeys(out)[:count]`` dedup then returned *fewer* than ``count``
nodes even though enough reachable nodes existed, so Locate()/repair could
falsely conclude no eligible node exists in small or heavily-partitioned
networks. The walk now terminates when the pointers meet (each ring slot
is visited exactly once), so a short result always means the ring really
has fewer than ``count`` reachable nodes.
"""
import random

from repro.core import chunks as C
from repro.core import repair as R
from repro.core.network import SimNetwork
from repro.core.vrf import RING


def _net(n: int, seed: int = 0) -> SimNetwork:
    net = SimNetwork(seed=seed)
    for i in range(n):
        net.add_node(seed=(seed * 1000 + i).to_bytes(8, "little"))
    return net


def test_full_ring_walk_returns_every_node():
    """count == n_alive must return the whole ring — no under-fill, no
    duplicates — from any start point, including exact node ids."""
    rnd = random.Random(7)
    for n in (1, 2, 3, 5, 8, 40):
        net = _net(n, seed=n)
        points = [rnd.randrange(RING) for _ in range(50)]
        points += list(net._ring)                      # exact hits
        points += [(nid + 1) % RING for nid in net._ring]  # just past
        for p in points:
            got = net.candidates(p, n)
            nids = [nd.nid for nd in got]
            assert len(nids) == n, (n, p, len(nids))
            assert len(set(nids)) == n  # every node exactly once
            assert set(nids) == set(net._ring)


def test_count_near_n_alive_never_underfills():
    rnd = random.Random(11)
    for n in (3, 7, 29):
        net = _net(n, seed=100 + n)
        for count in (n - 1, n, n + 5):
            for _ in range(40):
                got = net.candidates(rnd.randrange(RING), count)
                assert len(got) == min(count, n)
                assert len({nd.nid for nd in got}) == len(got)


def test_eclipse_cut_returns_exactly_the_reachable_set():
    """Under a partition cut the walk must return every *reachable* node
    when count >= their number — a heavily-partitioned network must not
    look empty to Locate()."""
    rnd = random.Random(13)
    for n in (4, 9, 33):
        net = _net(n, seed=200 + n)
        # cut one third of the ring (wrapping variant exercised via offset)
        for lo_off in (0, RING // 2, RING - RING // 6):
            lo = lo_off
            hi = (lo + RING // 3) % RING
            net.eclipse = (lo, hi)
            reachable = {nid for nid in net._ring
                         if not net.is_eclipsed(nid)}
            for _ in range(25):
                got = net.candidates(rnd.randrange(RING), n)
                nids = [nd.nid for nd in got]
                assert len(nids) == len(reachable), (n, lo_off)
                assert set(nids) == reachable
                # and a near-exact count still fills from the survivors
                k = max(1, len(reachable) - 1)
                assert len(net.candidates(rnd.randrange(RING), k)) == k
        net.eclipse = None


def test_locate_finds_last_eligible_node_under_partition():
    """End-to-end regression: with every node but one excluded (and a cut
    hiding a third of the ring), Locate() must still find the survivor
    rather than concluding the candidate set is exhausted."""
    net = _net(24, seed=42)
    chash = C.chunk_hash(b"ring-lookup-regression")
    anchor = C.hash_point(chash)
    r_target = 4 * len(net._ring)
    net.eclipse = (anchor % RING, (anchor + RING // 3) % RING)
    reachable = [nid for nid in net._ring if not net.is_eclipsed(nid)]
    assert len(reachable) >= 2
    for batch in (False, True):
        for keep in (reachable[0], reachable[-1]):
            exclude = set(net._ring) - {keep}
            # pick a stream index whose VRF coin selects the survivor —
            # then a miss can only mean the ring walk never reached them
            node = net.nodes[keep]
            fhash = next(
                C.fragment_hash(chash, i) for i in range(64)
                if node.selection_proof(C.fragment_hash(chash, i), anchor,
                                        r_target)[1])
            found = R._locate_new_member(net, chash, fhash, r_target,
                                         exclude=exclude, batch=batch)
            assert found is not None, (batch, keep)
            assert found[0].nid == keep
    net.eclipse = None


def test_walk_matches_bruteforce_distance_order_prefix():
    """The walk returns nodes in non-decreasing ring distance from the
    query point (the nearest-on-ring lookup contract Locate() relies on)."""
    from repro.core import selection as sel

    rnd = random.Random(3)
    net = _net(17, seed=17)
    for _ in range(100):
        p = rnd.randrange(RING)
        got = [nd.nid for nd in net.candidates(p, 17)]
        dists = [sel.ring_distance(p, nid) for nid in got]
        assert dists == sorted(dists)
        brute = sorted(net._ring, key=lambda nid: sel.ring_distance(p, nid))
        assert set(got) == set(brute)
