"""Golden regression for the protocol serving tick (PR 8).

``tests/data/golden_serving.json`` pins the per-tick serving trace
(issued / hit / miss / degraded / failed counts), the congestion-stretched
hop histogram, and the served-traffic total of ``protocol_sim._serve_tick``
for a set of small configs covering the axes that exercise every branch of
the read path: warm-cache hits, cold fragment-pull misses, degraded reads
under heavy churn, failed reads behind an eclipse window, and a
bandwidth-capped config where the congestion pass actually stretches hops.

Every config runs through BOTH engines of ``run_protocol`` —
``engine="reference"`` (scalar claims/repair path, inline decode retry
loop) and ``engine="vectorized"`` (batched tick path, SolvePool memo +
rank-prefix decode shortcut) — and each field must match the golden values
exactly. The serving layer is deterministic given its dedicated RNG stream
(``protocol_sim._SERVE_STREAM``), so any change to the walk order, the
cache-probe rule, decode pull counts, classification priority, or the
congestion arithmetic fails here bit-wise, not statistically.

Captured by running this module as a script::

    PYTHONPATH=src python -m tests.test_serving_golden --regen

(from a commit whose reference engine is known-good).
"""
from __future__ import annotations

import json
import pathlib
import sys

import numpy as np
import pytest

from repro.core import protocol_sim as PS

GOLDEN = pathlib.Path(__file__).parent / "data" / "golden_serving.json"

_BASE = dict(n_nodes=80, n_objects=3, object_bytes=1200, k_outer=2,
             n_chunks=3, k_inner=5, r_inner=10, byz_fraction=0.15,
             churn_per_year=40.0, step_hours=24.0, steps=8, claim_every=2,
             read_rate=200.0, zipf_alpha=1.1)

CONFIGS: dict[str, PS.ProtocolParams] = {
    # cold caches: every served read is a fragment-pull miss
    "cold_miss": PS.ProtocolParams(**_BASE, seed=0),
    # warm caches: the store pre-warms every group, hits dominate
    "warm_cache": PS.ProtocolParams(**_BASE, cache_ttl_hours=96.0, seed=1),
    # heavy churn: chunks drop below n_chunks readable → degraded reads
    "heavy_churn_degraded": PS.ProtocolParams(
        **{**_BASE, "churn_per_year": 400.0, "steps": 10}, seed=2),
    # eclipse window mid-run: eclipsed holders serve nothing, reads fail
    "eclipse_window": PS.ProtocolParams(
        **_BASE, adv_policy="eclipse", attack_frac=0.5, attack_step=2,
        eclipse_steps=3, seed=3),
    # tight per-region link budget: repair + serving oversubscribe the
    # links and the congestion pass stretches hop counts into upper bins
    "bandwidth_capped": PS.ProtocolParams(
        **_BASE, cache_ttl_hours=96.0, region_cap=5.0, seed=4),
}

_SCALARS = ("reads_issued", "reads_hit", "reads_miss", "reads_degraded",
            "reads_failed", "served_traffic_units")


def _digest(r: PS.ProtocolResult) -> dict:
    return {
        **{f: getattr(r, f) for f in _SCALARS},
        "serve_trace": np.asarray(r.serve_trace).tolist(),
        "serve_hop_hist": np.asarray(r.serve_hop_hist).tolist(),
    }


def _capture(run_kwargs: dict | None = None) -> dict:
    kw = run_kwargs or {}
    return {name: _digest(PS.run_protocol(p, **kw))
            for name, p in CONFIGS.items()}


def _assert_matches(got: dict, want: dict, label: str) -> None:
    for name, ref in want.items():
        cur = got[name]
        for field, val in ref.items():
            if isinstance(val, float):
                assert cur[field] == pytest.approx(val, rel=0, abs=0), (
                    f"{label}: {name}.{field}")
            else:
                assert cur[field] == val, f"{label}: {name}.{field}"


@pytest.fixture(scope="module")
def golden() -> dict:
    assert GOLDEN.exists(), (
        f"{GOLDEN} missing — regenerate with "
        "`PYTHONPATH=src python -m tests.test_serving_golden --regen` "
        "from a known-good commit")
    return json.loads(GOLDEN.read_text())


def test_reference_serving_matches_golden(golden):
    """The scalar read path (inline decode retries) reproduces the pin."""
    _assert_matches(_capture({"engine": "reference"}), golden, "reference")


def test_vectorized_serving_matches_golden(golden):
    """The SolvePool/rank-prefix read path is bit-identical to the pin."""
    _assert_matches(_capture({"engine": "vectorized"}), golden, "vectorized")


def test_golden_covers_every_bucket(golden):
    """The config set genuinely exercises all four outcome classes and the
    congestion stretch (a config whose histogram mass sits above the base
    miss+degraded bin)."""
    tot = {f: sum(c[f] for c in golden.values()) for f in _SCALARS[:5]}
    for f in ("reads_hit", "reads_miss", "reads_degraded", "reads_failed"):
        assert tot[f] > 0, f"golden configs never produce a {f} read"
    base_top = int(PS.P.SERVE_HOPS_MISS + PS.P.SERVE_HOPS_DEGRADED_EXTRA)
    capped = np.array(golden["bandwidth_capped"]["serve_hop_hist"])
    assert capped[base_top + 1:].sum() > 0, (
        "bandwidth_capped config never stretched a read past the base hops")


def test_serving_rng_isolated_from_protocol_stream(golden):
    """read_rate=0 must reproduce the pre-serving protocol stream exactly:
    the serving layer draws only from its dedicated stream. Pinned against
    the PR 3-era golden via test_protocol_golden; here we check the
    complementary direction — turning serving ON does not move any
    repair/churn statistic."""
    import dataclasses
    p = CONFIGS["cold_miss"]
    on = PS.run_protocol(p)
    off = PS.run_protocol(dataclasses.replace(p, read_rate=0.0))
    np.testing.assert_array_equal(on.honest_trace, off.honest_trace)
    np.testing.assert_array_equal(on.byz_trace, off.byz_trace)
    assert on.repair_traffic_units == off.repair_traffic_units
    assert on.repairs == off.repairs
    assert off.reads_issued == 0 and off.serve_hop_hist.sum() == 0


if __name__ == "__main__":
    if "--regen" in sys.argv:
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        data = _capture({"engine": "reference"})
        GOLDEN.write_text(json.dumps(data, indent=1))
        print(f"wrote {GOLDEN}")
    else:
        print(__doc__)
