"""Property-based invariants of the serving workload layer (PR 8).

Runs under real ``hypothesis`` when installed (CI property shard) and
under the seeded deterministic stand-in otherwise — see
``tests/_hypothesis_compat.py``, which defers to the real library itself.

The properties pin the engine's closed-form serving model
(``scenarios._vault_serve``) over randomly drawn knob settings:

* **request conservation** — hit + miss + degraded + failed counts
  partition the issued load exactly, and the hop histogram holds exactly
  the completed (non-failed) reads;
* **traffic monotonicity** — served traffic never decreases in the
  request rate (same seed, same scenario otherwise);
* **cache hit rate** — bounded by 1, and nonincreasing in the cache-holder
  death rate (the churn-aware cache model: dead holders stop serving —
  the over-credit leak this PR closes).
"""
import numpy as np

from _hypothesis_compat import given, settings, strategies as st

from repro.core import scenarios as SC

# one shared static geometry (6 objects x 3 chunks, 20 steps) so every
# run_grid call in this module reuses one compiled executable; all drawn
# knobs are traced scalars
GEO = dict(n_objects=6, n_chunks=3, k_outer=2, k_inner=6, r_inner=14,
           n_nodes=500, byz_fraction=0.1, step_hours=12.0, steps=20)
SEEDS = range(4)

read_rates = st.floats(min_value=0.0, max_value=2e6)
alphas = st.floats(min_value=0.0, max_value=2.0)
churns = st.floats(min_value=1.0, max_value=500.0)
ttls = st.floats(min_value=0.0, max_value=240.0)


def _run(cells):
    return SC.run_grid(cells, seeds=SEEDS)


@settings(max_examples=8, deadline=None, derandomize=True)
@given(rate=read_rates, alpha=alphas, churn=churns, ttl=ttls)
def test_request_conservation(rate, alpha, churn, ttl):
    """hits + misses + degraded + failed == issued, in every cell/seed,
    and the hop histogram holds exactly the completed reads."""
    r = _run([dict(GEO, read_rate=rate, zipf_alpha=alpha,
                   churn_per_year=churn, cache_ttl_hours=ttl)])
    issued = np.asarray(r.reads_issued, np.float64)
    buckets = sum(np.asarray(getattr(r, f), np.float64) for f in
                  ("reads_hit", "reads_miss", "reads_degraded",
                   "reads_failed"))
    np.testing.assert_allclose(buckets, issued, rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(issued, rate * GEO["steps"], rtol=1e-5,
                               atol=1e-3)
    completed = issued - np.asarray(r.reads_failed, np.float64)
    hist_mass = np.asarray(r.serve_hop_hist, np.float64).sum(axis=-1)
    np.testing.assert_allclose(hist_mass, completed, rtol=1e-5, atol=1e-3)


@settings(max_examples=8, deadline=None, derandomize=True)
@given(rate=st.floats(min_value=0.0, max_value=1e6), alpha=alphas,
       churn=churns, ttl=ttls, factor=st.floats(min_value=1.0, max_value=8.0))
def test_traffic_monotone_in_request_rate(rate, alpha, churn, ttl, factor):
    """Scaling the request rate up never reduces served traffic (per seed:
    identical RNG streams, closed-form load scaling)."""
    lo, hi = _run([
        dict(GEO, read_rate=rate, zipf_alpha=alpha, churn_per_year=churn,
             cache_ttl_hours=ttl),
        dict(GEO, read_rate=rate * factor, zipf_alpha=alpha,
             churn_per_year=churn, cache_ttl_hours=ttl),
    ]).served_traffic_units
    assert np.all(np.asarray(hi) >= np.asarray(lo) - 1e-6), (lo, hi)


@settings(max_examples=8, deadline=None, derandomize=True)
@given(rate=st.floats(min_value=1.0, max_value=1e6), alpha=alphas,
       churn=churns, ttl=ttls)
def test_hit_rate_is_a_probability(rate, alpha, churn, ttl):
    """0 <= reads_hit / reads_issued <= 1 in every cell and seed."""
    res = _run([dict(GEO, read_rate=rate, zipf_alpha=alpha,
                     cache_ttl_hours=ttl, churn_per_year=churn)])
    rates = (np.asarray(res.reads_hit, np.float64)
             / np.maximum(np.asarray(res.reads_issued, np.float64), 1e-9))
    assert np.all(rates <= 1.0 + 1e-9)
    assert np.all(rates >= -1e-9)


@settings(max_examples=8, deadline=None, derandomize=True)
@given(rate=st.floats(min_value=1.0, max_value=1e6), alpha=alphas)
def test_hit_rate_nonincreasing_in_holder_death_rate(rate, alpha):
    """Killing cache holders faster can only lower the hit rate.

    The TTL is held past the run horizon so warmth is *exactly* holder
    survival — with a short TTL churn also re-warms caches through repair
    misses (``cache_t = now`` on refresh), which legitimately makes hit
    rate non-monotone in churn as a whole; the churn-aware holder model
    this PR introduces is the death side, isolated here. (The old
    optimistic model kept hit rate flat in churn: see the leak-closure
    regression in test_cross_validation.py.) Seed-mean over
    well-separated churn rates."""
    horizon = GEO["steps"] * GEO["step_hours"]
    res = _run([dict(GEO, read_rate=rate, zipf_alpha=alpha,
                     cache_ttl_hours=horizon + 24.0, churn_per_year=c)
                for c in (5.0, 80.0, 400.0)])
    hit = np.asarray(res.reads_hit, np.float64)
    issued = np.asarray(res.reads_issued, np.float64)
    mean = (hit / np.maximum(issued, 1e-9)).mean(axis=1)  # [3 churn cells]
    assert mean[1] <= mean[0] + 1e-6, mean
    assert mean[2] <= mean[1] + 1e-6, mean


def test_protocol_conservation_single_run():
    """The protocol tick obeys the same conservation law (single seeded
    run — the statistical engine/protocol agreement lives in
    test_cross_validation.py; bit-level pins in test_serving_golden.py)."""
    from repro.core import protocol_sim as PS

    p = PS.ProtocolParams(n_nodes=60, n_objects=2, object_bytes=800,
                          k_outer=2, n_chunks=3, k_inner=5, r_inner=10,
                          churn_per_year=60.0, steps=6, read_rate=100.0,
                          cache_ttl_hours=48.0, seed=0)
    r = PS.run_protocol(p)
    assert (r.reads_hit + r.reads_miss + r.reads_degraded + r.reads_failed
            == r.reads_issued == 600)
    assert r.serve_hop_hist.sum() == r.reads_issued - r.reads_failed
    assert np.all(r.serve_trace[:, 0]
                  == r.serve_trace[:, 1:].sum(axis=1))
