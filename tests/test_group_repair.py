"""Chunk groups (§4.3.3) and decentralized repair (§4.3.4)."""
import numpy as np

from repro.core import chunks as C
from repro.core import group as G
from repro.core import repair as R
from repro.core.network import SimNetwork
from repro.core.vault import VaultClient

PARAMS = C.CodeParams(k_outer=4, n_chunks=5, k_inner=8, r_inner=16)


def setup_store(n=120, byz=0, seed=0, cache_ttl=0.0):
    net = SimNetwork(seed=seed)
    for i in range(n):
        net.add_node(byzantine=i < byz, seed=i.to_bytes(4, "little"))
    client = VaultClient(net, net.alive_nodes()[0])
    data = np.random.default_rng(seed).integers(
        0, 256, 4000, np.uint8).tobytes()
    oid, _ = client.store(data, PARAMS, cache_ttl=cache_ttl)
    return net, client, oid, data


def group_sizes(net, chash):
    holders = [
        n for n in net.alive_nodes()
        if any(ch == chash for (ch, _i) in n.fragments)
    ]
    return len(holders)


def test_persistence_claims_accepted_and_forged_rejected():
    net, client, oid, _ = setup_store()
    chash = oid.chunk_hashes[0]
    holder = next(
        n for n in net.alive_nodes()
        if any(ch == chash for (ch, _i) in n.fragments)
    )
    accepted = G.broadcast_claims(net, holder)
    assert accepted > 0
    # forge: replay holder's proof from a non-selected node
    claims = G.make_claims(holder)
    outsider = next(
        n for n in net.alive_nodes() if chash not in n.groups
    )
    fake = G.PersistenceClaim(
        chash=claims[0].chash, index=claims[0].index,
        proof=claims[0].proof, sender_nid=outsider.nid,
    )
    # receiver verifies the proof's pk — it admits the PROOF owner, not the
    # forwarding node; verification of a tampered proof object fails
    import dataclasses
    bad_proof = dataclasses.replace(claims[0].proof, r=claims[0].proof.r ^ 1)
    bad = dataclasses.replace(fake, proof=bad_proof)
    view_holder = next(
        n for n in net.alive_nodes()
        if chash in n.groups and n.nid != holder.nid
    )
    assert not G.receive_claim(net, view_holder, bad)


def test_repair_restores_group_size():
    net, client, oid, data = setup_store(seed=2)
    chash = oid.chunk_hashes[0]
    before = group_sizes(net, chash)
    assert before >= PARAMS.k_inner
    # fail a third of the holders
    holders = [
        n for n in net.alive_nodes()
        if any(ch == chash for (ch, _i) in n.fragments)
    ]
    for h in holders[: len(holders) // 3]:
        net.fail_node(h.nid)
    dropped = group_sizes(net, chash)
    assert dropped < before
    # any surviving member repairs from its local view
    survivor = next(
        n for n in net.alive_nodes() if chash in n.groups
    )
    stats = R.repair_group(net, survivor, chash)
    assert stats.repaired > 0
    after = group_sizes(net, chash)
    assert after >= min(before, PARAMS.r_inner) - 1
    got, _ = client.query(oid)
    assert got == data


def test_chunk_cache_reduces_repair_traffic():
    net1, _, oid1, _ = setup_store(seed=3, cache_ttl=0.0)
    net2, _, oid2, _ = setup_store(seed=3, cache_ttl=1e9)
    for net, oid in ((net1, oid1), (net2, oid2)):
        chash = oid.chunk_hashes[0]
        holders = [
            n for n in net.alive_nodes()
            if any(ch == chash for (ch, _i) in n.fragments)
        ]
        for h in holders[:4]:
            net.fail_node(h.nid)
        survivor = next(n for n in net.alive_nodes() if chash in n.groups)
        R.repair_group(net, survivor, chash, cache_ttl=3600.0)
    # warm caches turn K_inner-fragment pulls into single-fragment sends;
    # net1's first repair still pays one full pull (then caches), so the
    # observed gap is < K_inner but must be substantial
    assert net2.repair_traffic_bytes < net1.repair_traffic_bytes / 2


def test_evict_oldest_and_over_repair_safety():
    net, client, oid, data = setup_store(seed=4)
    chash = oid.chunk_hashes[1]
    evicted = R.evict_oldest(net, chash)
    assert evicted is not None
    # two members repair concurrently from stale views: over-repair is safe
    members = [n for n in net.alive_nodes() if chash in n.groups][:2]
    for m in members:
        R.repair_group(net, m, chash)
    got, _ = client.query(oid)
    assert got == data


def test_membership_timer_converges():
    net, client, oid, _ = setup_store(seed=5)
    chash = oid.chunk_hashes[0]
    holders = [n for n in net.alive_nodes() if chash in n.groups]
    # wipe one member's view; timer should rediscover peers via Locate()
    victim = holders[0]
    victim.groups[chash].members = {victim.nid: net.now}
    G.membership_timer(net, victim, chash)
    assert len(victim.groups[chash].members) > 1
