"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + property tests.

Kernels run in interpret mode on CPU (the kernel BODY executes, validating
the BlockSpec tiling and the bit-sliced field arithmetic)."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # CI installs hypothesis; local runs may lack it
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import gf
from repro.kernels import ops, ref
from repro.kernels.gf2_encode import gf2_encode_kernel
from repro.kernels.gf256_encode import gf256_encode_kernel


@pytest.mark.parametrize("r", [1, 3, 8, 17])
@pytest.mark.parametrize("k", [1, 2, 32, 63])
@pytest.mark.parametrize("l", [1, 100, 128, 1000])
def test_gf256_shape_sweep(r, k, l):
    rng = np.random.default_rng(r * 1000 + k * 10 + l)
    coeffs = rng.integers(0, 256, (r, k), dtype=np.uint8)
    blocks = rng.integers(0, 256, (k, l), dtype=np.uint8)
    out = ops.gf256_encode(coeffs, blocks)
    expect = gf.gf_matmul_np(coeffs, blocks)
    assert out.shape == (r, l) and out.dtype == np.uint8
    assert np.array_equal(out, expect)


@pytest.mark.parametrize("r,k,w", [(2, 5, 7), (8, 16, 128), (5, 33, 300)])
def test_gf2_shape_sweep(r, k, w):
    rng = np.random.default_rng(r + k + w)
    masks = rng.integers(0, 2, (r, k), dtype=np.uint8)
    words = rng.integers(-(2**31), 2**31 - 1, (k, w), dtype=np.int64).astype(
        np.int32
    )
    out = ops.gf2_encode(masks, words)
    expect = np.zeros((r, w), np.int32)
    for i in range(r):
        for j in range(k):
            if masks[i, j]:
                expect[i] ^= words[j]
    assert np.array_equal(out, expect)


def test_kernels_match_ref_oracles_tile_aligned():
    """Direct kernel-vs-ref comparison at the kernel's native layout."""
    rng = np.random.default_rng(0)
    coeffs = rng.integers(0, 256, (8, 32), dtype=np.int64).astype(np.int32)
    data = rng.integers(0, 256, (32, 512), dtype=np.int64).astype(np.int32)
    k_out = gf256_encode_kernel(jnp.asarray(coeffs), jnp.asarray(data),
                                tile_r=8, tile_l=128, interpret=True)
    r_out = ref.gf256_encode_ref(coeffs, data)
    assert np.array_equal(np.asarray(k_out), np.asarray(r_out))

    masks = rng.integers(0, 2, (8, 16), dtype=np.int64).astype(np.int32)
    words = rng.integers(-(2**31), 2**31 - 1, (16, 256),
                         dtype=np.int64).astype(np.int32)
    k2 = gf2_encode_kernel(jnp.asarray(masks), jnp.asarray(words),
                           tile_r=8, tile_w=128, interpret=True)
    r2 = ref.gf2_encode_ref(masks, words)
    assert np.array_equal(np.asarray(k2), np.asarray(r2))


@given(
    r=st.integers(1, 12), k=st.integers(1, 40), l=st.integers(1, 300),
    seed=st.integers(0, 2**31),
)
@settings(max_examples=20, deadline=None)
def test_gf256_property(r, k, l, seed):
    rng = np.random.default_rng(seed)
    coeffs = rng.integers(0, 256, (r, k), dtype=np.uint8)
    blocks = rng.integers(0, 256, (k, l), dtype=np.uint8)
    assert np.array_equal(
        ops.gf256_encode(coeffs, blocks), gf.gf_matmul_np(coeffs, blocks)
    )


def test_gf256_tile_choices_agree():
    rng = np.random.default_rng(9)
    coeffs = rng.integers(0, 256, (16, 24), dtype=np.uint8)
    blocks = rng.integers(0, 256, (24, 700), dtype=np.uint8)
    a = ops.gf256_encode(coeffs, blocks, tile_r=4, tile_l=128)
    b = ops.gf256_encode(coeffs, blocks, tile_r=16, tile_l=512)
    assert np.array_equal(a, b)
