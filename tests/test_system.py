"""End-to-end system behaviour: the full VAULT lifecycle on one network —
store → heartbeats → churn → decentralized repair → query — plus the
training-framework integration (vault-checkpointed training with failures).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import VaultCheckpointer
from repro.core import chunks as C
from repro.core import group as G
from repro.core import repair as R
from repro.core.network import SimNetwork
from repro.core.vault import VaultClient
from repro.data import SyntheticStream
from repro.optim import AdamWConfig
from repro.training import init_train_state, make_train_step

PARAMS = C.CodeParams(k_outer=4, n_chunks=6, k_inner=8, r_inner=16)


def test_full_lifecycle_store_churn_repair_query():
    net = SimNetwork(seed=42)
    for i in range(140):
        net.add_node(byzantine=i < 20, seed=i.to_bytes(4, "little"))
    client = VaultClient(net, net.alive_nodes()[30])
    data = np.random.default_rng(0).integers(0, 256, 30_000,
                                             np.uint8).tobytes()
    oid, _ = client.store(data, PARAMS, cache_ttl=1e9)

    rng = np.random.default_rng(1)
    for round_ in range(3):
        # churn: fail ~10% of alive nodes
        alive = [n for n in net.alive_nodes() if n.nid != client.node.nid]
        for node in rng.choice(alive, size=len(alive) // 10, replace=False):
            net.fail_node(node.nid)
        # heartbeats + membership convergence + repair
        for node in list(net.alive_nodes()):
            G.broadcast_claims(net, node)
        R.repair_all(net, cache_ttl=1e9)
        got, _ = client.query(oid)
        assert got == data, f"lost after churn round {round_}"
    assert net.repair_count > 0


def test_training_with_vault_checkpoint_resume_bitexact():
    """Kill peers mid-training, restore, and verify the resumed run
    reproduces the uninterrupted run exactly (pure-function pipeline)."""
    cfg = configs.smoke_config("mamba2-2.7b")
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    stream = SyntheticStream(cfg, batch=2, seq=16, seed=3)
    step_fn = jax.jit(make_train_step(cfg, opt))

    def run(n, state):
        hist = []
        for t in range(n):
            batch = {k: jnp.asarray(v) for k, v in stream.batch_at(t).items()}
            state, m = step_fn(state, batch)
            hist.append(float(m["loss"]))
        return state, hist

    s0 = init_train_state(cfg, jax.random.PRNGKey(0))
    ref_state, ref_hist = run(6, jax.tree_util.tree_map(jnp.copy, s0))

    # interrupted run: 3 steps -> vault save -> kill 30% peers -> restore
    net = SimNetwork(seed=9)
    for i in range(120):
        net.add_node(seed=i.to_bytes(4, "little"))
    ck = VaultCheckpointer(net, params=PARAMS, object_bytes=1 << 18)
    state, _ = run(3, jax.tree_util.tree_map(jnp.copy, s0))
    ck.save(jax.tree_util.tree_map(np.asarray, state), step=3)
    rng = np.random.default_rng(2)
    alive = net.alive_nodes()[1:]
    for node in rng.choice(alive, size=36, replace=False):
        net.fail_node(node.nid)
    restored = jax.tree_util.tree_map(jnp.asarray, ck.restore(3))
    # resume steps 3..6 must match the uninterrupted run bit-for-bit
    resumed_state = restored
    hist2 = []
    for t in range(3, 6):
        batch = {k: jnp.asarray(v) for k, v in stream.batch_at(t).items()}
        resumed_state, m = step_fn(resumed_state, batch)
        hist2.append(float(m["loss"]))
    np.testing.assert_allclose(hist2, ref_hist[3:], rtol=0, atol=0)
    for a, b in zip(jax.tree_util.tree_leaves(resumed_state["params"]),
                    jax.tree_util.tree_leaves(ref_state["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
