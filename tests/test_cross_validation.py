"""Cross-validation: protocol-level simulator vs batched group-level engine.

Runs the matched-config suite from ``benchmarks/cross_validate.py``
(minus the stretch ``iid_targeted`` config — its engine abstraction gap is
documented there) through BOTH layers and enforces the acceptance
criteria:

* object-loss counts: protocol mean inside the engine's 8-seed 95% CI
  (strict);
* repair counts / traffic / honest-member statistics: the two-sample 95%
  criterion ``|Δ| ≤ √(ci_eng² + ci_proto²)`` — the engine CI alone ignores
  protocol sampling noise (few seeds, emergent fragment co-location), so
  demanding the protocol mean inside it would reject agreeing layers;
* the cached config's known deltas keep their documented *direction*: the
  engine's per-group cache timestamp ignores cache-holder churn, so the
  protocol must show ≥ engine traffic and ≤ engine hit counts;
* the eclipse config is CI-gated on every metric except ``lost_objects``,
  where the engine's clean-bisection approximation is a documented
  one-sided bound: protocol losses must not exceed the engine's upper
  band (see ``test_eclipse_loss_one_sided_bound``).

Everything is seeded (engine cells and protocol replicas), so this test is
deterministic — it either always passes or always fails for a given code
state.
"""
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.cross_validate import (  # noqa: E402
    QUICK_KW, QUICK_PROTO_SEEDS, compare, matched_configs)


@pytest.fixture(scope="module")
def rows():
    configs = matched_configs(**QUICK_KW)
    configs.pop("iid_targeted")
    return compare(configs, proto_seeds=QUICK_PROTO_SEEDS)


def _get(rows, config, metric):
    return next(r for r in rows
                if r["config"] == config and r["metric"] == metric)


def _configs(rows):
    return sorted({r["config"] for r in rows})


def test_covers_required_policy_axes(rows):
    names = _configs(rows)
    assert len(names) >= 3
    assert any("regional" in n for n in names)  # iid + regional churn
    assert any("adaptive" in n for n in names)  # static + adaptive adversary
    assert any("static" in n for n in names)
    assert any("eclipse" in n for n in names)   # partition window


def test_loss_within_engine_ci(rows):
    for name in _configs(rows):
        if "eclipse" in name:
            continue  # one-sided bound, tested below — documented leak
        r = _get(rows, name, "lost_objects")
        assert r["within_engine_ci"], r


def test_eclipse_loss_one_sided_bound(rows):
    """The engine models an eclipse as a clean bisection: eclipsed groups
    lose ALL repair capacity for the window. At protocol level, groups
    whose members straddle the cut keep partial repair, so the engine's
    loss count is the conservative (pessimistic) bound — the protocol may
    lose strictly fewer objects, never more. Gate exactly that direction
    (documented as an abstraction leak in docs/ARCHITECTURE.md)."""
    name = next(n for n in _configs(rows) if "eclipse" in n)
    r = _get(rows, name, "lost_objects")
    assert (r["protocol_mean"]
            <= r["engine_mean"] + r["engine_ci95"]), r


def test_repairs_within_combined_ci(rows):
    for name in _configs(rows):
        r = _get(rows, name, "repairs")
        assert r["within_combined_ci"], r


def test_traffic_and_honest_members_match(rows):
    for name in _configs(rows):
        if "cache" in name:
            continue  # cached traffic: documented delta, tested below
        r = _get(rows, name, "repair_traffic_units")
        assert r["within_combined_ci"], r
    for name in _configs(rows):
        r = _get(rows, name, "final_honest_mean")
        assert r["within_combined_ci"], r


def test_alive_fraction_matches(rows):
    # regional bursts straddle ring domains at protocol level, so group
    # deaths are slightly rarer than the engine's co-located worst case:
    # allow a small absolute slack on top of the combined CI
    for name in _configs(rows):
        r = _get(rows, name, "alive_frac_final")
        combined = float(np.hypot(r["engine_ci95"], r["protocol_ci95"]))
        assert r["abs_diff"] <= combined + 0.05, r


def test_cache_config_documented_deltas(rows):
    name = next(n for n in _configs(rows) if "cache" in n)
    traffic = _get(rows, name, "repair_traffic_units")
    hits = _get(rows, name, "cache_hits")
    plain = _get(rows, "iid_static", "repair_traffic_units")
    # engine's per-group cache ignores holder churn => engine is optimistic
    assert traffic["protocol_mean"] >= traffic["engine_mean"]
    # ...but caching still has to cut protocol traffic well below cold pulls
    assert traffic["protocol_mean"] < 0.75 * plain["protocol_mean"]
    # holder churn can only lose warm hits, never add them
    assert hits["protocol_mean"] <= hits["engine_mean"] + hits["engine_ci95"]
    combined = float(np.hypot(hits["engine_ci95"], hits["protocol_ci95"]))
    assert hits["abs_diff"] <= 2.0 * combined, hits
