"""Cross-validation: protocol-level simulator vs batched group-level engine.

Runs the matched-config suite from ``benchmarks/cross_validate.py``
(minus the stretch ``iid_targeted`` config — its engine abstraction gap is
documented there) through BOTH layers and enforces the acceptance
criteria:

* object-loss counts: protocol mean inside the engine's 8-seed 95% CI
  (strict);
* repair counts / traffic / honest-member statistics: the two-sample 95%
  criterion ``|Δ| ≤ √(ci_eng² + ci_proto²)`` — the engine CI alone ignores
  protocol sampling noise (few seeds, emergent fragment co-location), so
  demanding the protocol mean inside it would reject agreeing layers;
* serving metrics (``served_traffic_units``, ``reads_failed``,
  ``hit_rate``): combined-CI gated like the repair metrics, with two
  documented exceptions — the cached config's served traffic carries the
  padding-quantization delta (the protocol ships actual cached-chunk
  bytes, ≈1% under the engine's idealized 1 unit/read), and the eclipse
  config is one-sided (the engine's whole-group eclipse predicts failed
  reads the protocol's k-of-n decoding survives, so the engine is the
  conservative bound);
* the cached config's repair-path metrics: ``cache_hits`` is now inside
  the combined CI (the holder-churn leak — #1 of the original table — is
  closed by the churn-aware cache model), and
  ``test_cache_holder_leak_closed`` proves the closure is real: the old
  optimistic model (``cache_churn=False``) under-counts repair traffic
  beyond the combined CI on a leak-amplifying config while the fixed
  model agrees;
* the eclipse config is CI-gated on every metric except ``lost_objects``,
  where the engine's clean-bisection approximation is a documented
  one-sided bound: protocol losses must not exceed the engine's upper
  band (see ``test_eclipse_loss_one_sided_bound``);
* the ISSUE-10 zoo rows: ``diurnal_static`` rides the blanket two-sided
  gates (same daily-mean rate in both layers); ``pareto_static``,
  ``iid_collude`` and ``iid_eclipse_targeted`` are registered
  ``gate="one_sided"`` and get dedicated bound tests at the bottom —
  including the *inverted* direction of the composed eclipse+targeted
  leak, where the protocol is strictly worse than the engine.

The matrix itself is auto-discovered from ``policies.zoo_members()``
(``test_matrix_auto_discovers_zoo``); which rows land in which gate tier
is driven by each entry's registered ``gate`` field, so registering a new
zoo member automatically enrolls it here.

Everything is seeded (engine cells and protocol replicas), so this test is
deterministic — it either always passes or always fails for a given code
state.
"""
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.cross_validate import (  # noqa: E402
    ENGINE_SEEDS, EXCLUDED_ROWS, QUICK_KW, QUICK_PROTO_SEEDS, compare,
    matched_configs)
from repro.core import policies as P  # noqa: E402


@pytest.fixture(scope="module")
def rows():
    configs = matched_configs(**QUICK_KW)
    # Entries registered gate="one_sided" are documented abstraction
    # leaks with dedicated bound tests below — except iid_eclipse, whose
    # per-metric exceptions are historically woven into the blanket tests
    # (leak #4), and iid_targeted, which keeps its original exclusion
    # (stretch config — engine abstraction gap documented in the
    # benchmark docstring).
    for entry in P.zoo_members():
        if entry.gate == "one_sided" and entry.name != "iid_eclipse":
            configs.pop(entry.name, None)
    return compare(configs, proto_seeds=QUICK_PROTO_SEEDS)


@pytest.fixture(scope="module")
def one_sided_rows():
    """The three ISSUE-10 one-sided zoo rows, plus iid_static as the
    differential baseline for the collude invariants (same engine grid,
    same protocol seeds, one compare() pass)."""
    configs = matched_configs(**QUICK_KW)
    keep = ("iid_static", "pareto_static", "iid_collude",
            "iid_eclipse_targeted")
    return compare({k: configs[k] for k in keep},
                   proto_seeds=QUICK_PROTO_SEEDS)


def test_matrix_auto_discovers_zoo():
    """Every registered zoo member is a matrix row (or an explicit
    waiver), and the four ISSUE-10 members are present by name."""
    configs = matched_configs(**QUICK_KW)
    for entry in P.zoo_members():
        assert entry.name in configs or entry.name in EXCLUDED_ROWS, \
            entry.name
    for name in ("diurnal_static", "pareto_static", "iid_collude",
                 "iid_eclipse_targeted"):
        assert name in configs
    for name, reason in EXCLUDED_ROWS.items():
        assert reason.strip(), f"waiver for {name!r} needs a reason"


def _get(rows, config, metric):
    return next(r for r in rows
                if r["config"] == config and r["metric"] == metric)


def _configs(rows):
    return sorted({r["config"] for r in rows})


def test_covers_required_policy_axes(rows):
    names = _configs(rows)
    assert len(names) >= 3
    assert any("regional" in n for n in names)  # iid + regional churn
    assert any("adaptive" in n for n in names)  # static + adaptive adversary
    assert any("static" in n for n in names)
    assert any("eclipse" in n for n in names)   # partition window
    assert any("diurnal" in n for n in names)   # modulated-rate churn


def test_loss_within_engine_ci(rows):
    for name in _configs(rows):
        if "eclipse" in name:
            continue  # one-sided bound, tested below — documented leak
        r = _get(rows, name, "lost_objects")
        assert r["within_engine_ci"], r


def test_eclipse_loss_one_sided_bound(rows):
    """The engine models an eclipse as a clean bisection: eclipsed groups
    lose ALL repair capacity for the window. At protocol level, groups
    whose members straddle the cut keep partial repair, so the engine's
    loss count is the conservative (pessimistic) bound — the protocol may
    lose strictly fewer objects, never more. Gate exactly that direction
    (documented as an abstraction leak in docs/ARCHITECTURE.md)."""
    name = next(n for n in _configs(rows) if "eclipse" in n)
    r = _get(rows, name, "lost_objects")
    assert (r["protocol_mean"]
            <= r["engine_mean"] + r["engine_ci95"]), r


def test_repairs_within_combined_ci(rows):
    for name in _configs(rows):
        r = _get(rows, name, "repairs")
        assert r["within_combined_ci"], r


def test_traffic_and_honest_members_match(rows):
    for name in _configs(rows):
        if "cache" in name:
            continue  # cached traffic: documented delta, tested below
        r = _get(rows, name, "repair_traffic_units")
        assert r["within_combined_ci"], r
    for name in _configs(rows):
        r = _get(rows, name, "final_honest_mean")
        assert r["within_combined_ci"], r


def test_alive_fraction_matches(rows):
    # regional bursts straddle ring domains at protocol level, so group
    # deaths are slightly rarer than the engine's co-located worst case:
    # allow a small absolute slack on top of the combined CI
    for name in _configs(rows):
        r = _get(rows, name, "alive_frac_final")
        combined = float(np.hypot(r["engine_ci95"], r["protocol_ci95"]))
        assert r["abs_diff"] <= combined + 0.05, r


def test_cache_config_repair_metrics(rows):
    name = next(n for n in _configs(rows) if "cache" in n)
    traffic = _get(rows, name, "repair_traffic_units")
    hits = _get(rows, name, "cache_hits")
    plain = _get(rows, "iid_static", "repair_traffic_units")
    # warm hits agree within the combined CI now that the engine retires
    # cached copies when their holders die (leak #1 closed — the hard
    # regression proving the closure is test_cache_holder_leak_closed)
    assert hits["within_combined_ci"], hits
    # residual traffic delta: the engine re-caches a decoded chunk at ONE
    # holder where protocol coordinators accumulate copies over repeated
    # misses, so the engine stays mildly optimistic — bounded, directional
    assert traffic["protocol_mean"] >= traffic["engine_mean"], traffic
    assert traffic["protocol_mean"] <= 1.25 * traffic["engine_mean"], traffic
    # ...and caching still has to cut protocol traffic well below cold pulls
    assert traffic["protocol_mean"] < 0.75 * plain["protocol_mean"]


def test_served_traffic_matches(rows):
    """Served traffic: combined CI, except the two documented deltas.

    * cache config — the protocol charges actual cached-chunk bytes
      (``len(chunk)``, not ``k_inner · frag_len``), so padding
      quantization puts each warm read ≈1% under the engine's idealized
      1.0 object unit: gate at 2% of the issued load instead;
    * eclipse config — one-sided (see test_eclipse_serving_one_sided)."""
    for name in _configs(rows):
        if "eclipse" in name:
            continue
        r = _get(rows, name, "served_traffic_units")
        if "cache" in name:
            assert r["abs_diff"] <= 0.02 * r["engine_mean"], r
        else:
            assert r["within_combined_ci"], r


def test_failed_reads_match(rows):
    for name in _configs(rows):
        if "eclipse" in name:
            continue  # one-sided, tested below
        r = _get(rows, name, "reads_failed")
        assert r["within_combined_ci"], r


def test_hit_rate_matches(rows):
    """Cache-hit rate of the served load: combined CI plus a small
    documented slack — the protocol's cache probe also loses warm reads
    to candidate-walk order and probe-time holder state (second-order
    effects the closed-form model folds into its expectation)."""
    for name in _configs(rows):
        r = _get(rows, name, "hit_rate")
        combined = float(np.hypot(r["engine_ci95"], r["protocol_ci95"]))
        assert r["abs_diff"] <= combined + 0.01, r


def test_eclipse_serving_one_sided(rows):
    """The engine eclipses whole groups, so every read of an eclipsed
    object fails; the protocol cuts 30% of holders and k-of-n decoding
    rides it out. Like the loss metric, the engine is the conservative
    bound: the protocol may fail fewer reads (serve more), never more."""
    name = next(n for n in _configs(rows) if "eclipse" in n)
    failed = _get(rows, name, "reads_failed")
    served = _get(rows, name, "served_traffic_units")
    f_comb = float(np.hypot(failed["engine_ci95"], failed["protocol_ci95"]))
    s_comb = float(np.hypot(served["engine_ci95"], served["protocol_ci95"]))
    assert (failed["protocol_mean"]
            <= failed["engine_mean"] + f_comb), failed
    assert (served["protocol_mean"]
            >= served["engine_mean"] - s_comb), served


def test_cache_holder_leak_closed():
    """Leak #1 of the original abstraction-leak table, retired.

    The pre-serving engine cache model kept a cached copy warm for the
    whole TTL regardless of what happened to the node holding it. On a
    leak-amplifying config — TTL longer than the run horizon (warmth can
    only be lost to holder death) and churn high enough to kill holders
    often — that model credits warm hits the protocol's dying holders
    can't serve, under-counting repair traffic beyond any CI. The fix
    (``cache_churn=True``, the default) retires cached copies at the
    holder death rate and lands within CI of the protocol.

    Asserts three things, all deterministic (seeded both layers):
    * the optimistic model's traffic gap exceeds the combined 95% CI —
      the leak is real and measurable;
    * the fixed model agrees within 1.25× the combined CI (slack for the
      holder-accumulation residual documented in
      test_cache_config_repair_metrics);
    * the fix closes more than half of the optimistic gap.
    """
    from repro.core import protocol_sim as PS
    from repro.core import scenarios as SC

    p = PS.ProtocolParams(
        n_nodes=200, n_objects=3, k_outer=2, n_chunks=5, k_inner=6,
        r_inner=14, byz_fraction=0.1, churn_per_year=150.0,
        step_hours=12.0, steps=30, claim_every=2, cache_ttl_hours=400.0,
        read_rate=40.0, zipf_alpha=1.1)
    cell = p.to_scenario_kwargs()
    eng = SC.run_grid([cell, dict(cell, cache_churn=False)],
                      seeds=ENGINE_SEEDS)
    proto = PS.run_protocol_seeds(p, seeds=QUICK_PROTO_SEEDS)

    fixed_m, fixed_c = map(float, SC.mean_ci(
        np.asarray(eng.repair_traffic_units[0], np.float64)))
    optim_m, optim_c = map(float, SC.mean_ci(
        np.asarray(eng.repair_traffic_units[1], np.float64)))
    proto_m, proto_c = map(float, SC.mean_ci(
        np.array([r.repair_traffic_units for r in proto], np.float64)))

    gap_optim = proto_m - optim_m
    gap_fixed = abs(proto_m - fixed_m)
    # the old model over-credits warm hits => under-counts repair traffic
    assert gap_optim > float(np.hypot(optim_c, proto_c)), (
        optim_m, optim_c, proto_m, proto_c)
    # the churn-aware model agrees with the protocol
    assert gap_fixed <= 1.25 * float(np.hypot(fixed_c, proto_c)), (
        fixed_m, fixed_c, proto_m, proto_c)
    assert gap_fixed < 0.5 * gap_optim, (gap_fixed, gap_optim)
    # holder death can only lose warm hits, never add them
    fixed_h = float(np.mean(np.asarray(eng.cache_hits[0], np.float64)))
    optim_h = float(np.mean(np.asarray(eng.cache_hits[1], np.float64)))
    assert fixed_h <= optim_h, (fixed_h, optim_h)


# --------------------------------------------- ISSUE-10 one-sided zoo rows
def _combined(r) -> float:
    return float(np.hypot(r["engine_ci95"], r["protocol_ci95"]))


def test_pareto_one_sided_bound(one_sided_rows):
    """Abstraction leak #5: the engine's Pareto mean-field keeps every
    session *protected* for its full x_m scale (policies.pareto_p_fail),
    so the engine's per-step churn — and with it repair activity — is a
    strict LOWER bound on the protocol's real heavy-tailed sessions,
    where short-lived nodes die and respawn into fresh protected cohorts
    faster than the mean-field credits. Gate exactly that direction plus
    a deterministic sanity ceiling."""
    for metric in ("repairs", "repair_traffic_units"):
        r = _get(one_sided_rows, "pareto_static", metric)
        assert (r["protocol_mean"]
                >= r["engine_mean"] - _combined(r)), r
        assert r["protocol_mean"] <= 2.0 * r["engine_mean"], r
    # the understated churn never binds on durability at this config
    lost = _get(one_sided_rows, "pareto_static", "lost_objects")
    assert lost["protocol_mean"] <= lost["engine_mean"] + 1.0, lost
    # membership statistics still agree within the combined CI
    hon = _get(one_sided_rows, "pareto_static", "final_honest_mean")
    assert hon["abs_diff"] <= _combined(hon), hon


def test_collude_differential_and_one_sided_traffic(one_sided_rows):
    """Withholding changes ONLY the traffic bill, in both layers.

    Corrupt-only candidates never join the pull fan-out set and corrupt
    rows never reach a decode, so a collude run is RNG-identical to its
    matched static run in every field except repair traffic — the
    protocol invariant (vault.gather_available) mirrored by the engine's
    additive-zero wasted-pulls term. Assert exact equality on the
    RNG-dependent metrics, strict traffic increase, and the one-sided
    traffic gate (the engine charges every deficit repair the full
    Byzantine count, the conservative reading of parallel pulls)."""
    for metric in ("repairs", "lost_objects", "final_honest_mean",
                   "cache_hits", "reads_failed", "hit_rate"):
        co = _get(one_sided_rows, "iid_collude", metric)
        st = _get(one_sided_rows, "iid_static", metric)
        assert co["protocol_mean"] == st["protocol_mean"], (metric, co, st)
        assert co["engine_mean"] == st["engine_mean"], (metric, co, st)
    co = _get(one_sided_rows, "iid_collude", "repair_traffic_units")
    st = _get(one_sided_rows, "iid_static", "repair_traffic_units")
    assert co["protocol_mean"] > st["protocol_mean"], (co, st)
    assert co["engine_mean"] > st["engine_mean"], (co, st)
    assert co["protocol_mean"] <= co["engine_mean"] + _combined(co), co


def test_eclipse_targeted_inverted_one_sided_bound(one_sided_rows):
    """The composed adversary INVERTS the eclipse leak direction.

    Each component leak is conservative on its own (engine over-predicts
    eclipse loss, leak #4), but composed, the targeted kill lands while
    the partition blocks recovery: at protocol level the killed groups
    inside the cut cannot be repaired around for the whole window, a
    compounding the engine's independent mean-field product cannot see.
    Measured at the QUICK config the protocol therefore loses MORE
    objects than the engine's upper band — so the one-sided gate points
    the other way (engine as the optimistic floor), with a deterministic
    ceiling; repairs are one-sided low (the engine keeps repairing
    groups the protocol already lost), and the serving metrics still
    agree within the combined CI."""
    lost = _get(one_sided_rows, "iid_eclipse_targeted", "lost_objects")
    assert (lost["protocol_mean"]
            >= lost["engine_mean"] - _combined(lost)), lost
    assert lost["protocol_mean"] <= (
        lost["engine_mean"] + 3.0 * max(_combined(lost), 0.1)), lost
    rep = _get(one_sided_rows, "iid_eclipse_targeted", "repairs")
    assert rep["protocol_mean"] <= rep["engine_mean"] + _combined(rep), rep
    alive = _get(one_sided_rows, "iid_eclipse_targeted", "alive_frac_final")
    assert (alive["protocol_mean"]
            <= alive["engine_mean"] + _combined(alive)), alive
    for metric in ("served_traffic_units", "reads_failed"):
        r = _get(one_sided_rows, "iid_eclipse_targeted", metric)
        assert r["within_combined_ci"], r
