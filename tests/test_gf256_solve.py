"""Bit-pins for ``kernels/gf256_solve`` against the scalar reference.

``rateless.gf256_gaussian_solve_ref`` (the pre-kernel implementation) is
the oracle: the batched numpy mirror and the Pallas kernel must reproduce
its solutions byte-for-byte on full-rank systems, and must flag exactly
the column at which it raises on rank-deficient ones — the simulator's
decode results (and therefore the protocol goldens) ride on this.
"""
import numpy as np
import pytest

from repro.core.rateless import (RLNC, InsufficientFragments,
                                 gf256_gaussian_solve,
                                 gf256_gaussian_solve_ref)
from repro.kernels.gf256_solve import (gf256_rank_prefix, gf256_solve_batch,
                                       gf256_solve_np)


def _ref_outcome(a, y, k):
    """(solution, fail_col) from the scalar reference."""
    try:
        return gf256_gaussian_solve_ref(a, y, k), -1
    except InsufficientFragments as e:
        return None, int(str(e).rsplit(" ", 1)[-1])


def _random_systems(rng, B, m, k, L):
    a = rng.integers(0, 256, (B, m, k), dtype=np.uint8)
    y = rng.integers(0, 256, (B, m, L), dtype=np.uint8)
    return a, y


def _check_against_ref(a, y, backend):
    B, _, k = a.shape
    x, ok, fail = gf256_solve_batch(a, y, backend=backend)
    for b in range(B):
        want, want_fail = _ref_outcome(a[b], y[b], k)
        if want is None:
            assert not ok[b], b
            assert fail[b] == want_fail, (b, fail[b], want_fail)
        else:
            assert ok[b] and fail[b] == -1, b
            np.testing.assert_array_equal(x[b], want, err_msg=str(b))


@pytest.mark.parametrize("backend", ["numpy", "kernel"])
def test_random_systems_bit_identical(backend):
    rng = np.random.default_rng(0)
    for m, k, L in [(4, 4, 1), (6, 4, 37), (16, 16, 130), (21, 16, 257),
                    (9, 8, 64)]:
        a, y = _random_systems(rng, 8, m, k, L)
        _check_against_ref(a, y, backend)


@pytest.mark.parametrize("backend", ["numpy", "kernel"])
def test_permuted_pivot_row_swaps(backend):
    """Zero diagonals force the pivot search below the diagonal — the
    row-swap path (masked-select in the kernel) must match the scalar
    swap exactly."""
    rng = np.random.default_rng(1)
    k, L = 8, 33
    systems_a, systems_y = [], []
    for perm_seed in range(12):
        prm = np.random.default_rng(perm_seed).permutation(k + 3)
        a = rng.integers(0, 256, (k + 3, k), dtype=np.uint8)
        # zero the diagonal so column j never pivots in place
        a[np.arange(k), np.arange(k)] = 0
        systems_a.append(a[prm])
        systems_y.append(rng.integers(0, 256, (k + 3, L), dtype=np.uint8))
    _check_against_ref(np.stack(systems_a), np.stack(systems_y), backend)


@pytest.mark.parametrize("backend", ["numpy", "kernel"])
def test_singular_systems_flag_reference_column(backend):
    rng = np.random.default_rng(2)
    k, L = 6, 16
    mats, syms = [], []
    # zero column 3 -> fails at column 3
    a = rng.integers(0, 256, (k + 1, k), dtype=np.uint8)
    a[:, 3] = 0
    mats.append(a)
    # duplicate rows with m == k -> rank k-1 (column of first divergence
    # is whatever the reference reports; we only require agreement)
    a = rng.integers(0, 256, (k, k), dtype=np.uint8)
    a[k - 1] = a[0]
    mats.append(a)
    # all-zero matrix -> fails at column 0
    mats.append(np.zeros((k, k), np.uint8))
    # linear combination: row2 = row0 ^ row1 (GF(2) subset of GF(256))
    a = rng.integers(0, 256, (k, k), dtype=np.uint8)
    a[2] = a[0] ^ a[1]
    mats.append(a)
    for a in mats:
        syms.append(rng.integers(0, 256, (a.shape[0], L), dtype=np.uint8))
    m = max(a.shape[0] for a in mats)
    batch_a = np.zeros((len(mats), m, k), np.uint8)
    batch_y = np.zeros((len(mats), m, L), np.uint8)
    for i, (a, y) in enumerate(zip(mats, syms)):
        batch_a[i, :a.shape[0]] = a
        batch_y[i, :a.shape[0]] = y
    _check_against_ref(batch_a, batch_y, backend)


@pytest.mark.parametrize("backend", ["numpy", "kernel"])
def test_square_random_matches_ref_including_rank_deficient(backend):
    """m == k random batches: ~1/255-ish of systems are singular; the
    batch must agree with the reference on every element either way."""
    rng = np.random.default_rng(3)
    k, L = 4, 8  # small k raises the singular fraction enough to hit some
    a, y = _random_systems(rng, 300, k, k, L)
    x, ok, fail = gf256_solve_batch(a, y, backend=backend)
    n_singular = 0
    for b in range(a.shape[0]):
        want, want_fail = _ref_outcome(a[b], y[b], k)
        if want is None:
            n_singular += 1
            assert not ok[b] and fail[b] == want_fail
        else:
            assert ok[b]
            np.testing.assert_array_equal(x[b], want)
    assert n_singular >= 1  # the sweep actually exercised the fail path


def test_rlnc_decode_round_trip_unchanged():
    """End-to-end: RLNC.decode (now through the dispatcher) still inverts
    encode, and the raised message on insufficient rank is unchanged."""
    rng = np.random.default_rng(4)
    code = RLNC(k=6, seed=b"solve-pin")
    blocks = rng.integers(0, 256, (6, 97), dtype=np.uint8)
    idx = [2, 5, 7, 11, 12, 19]
    out = code.decode(idx, code.encode(blocks, idx))
    np.testing.assert_array_equal(out, blocks)
    with pytest.raises(InsufficientFragments, match="need >= 6 symbols"):
        code.decode(idx[:4], code.encode(blocks, idx[:4]))


def test_scalar_delegate_message_is_exact():
    a = np.zeros((5, 5), np.uint8)
    a[np.arange(4), np.arange(4)] = 1  # rank 4: fails at column 4
    y = np.ones((5, 9), np.uint8)
    with pytest.raises(InsufficientFragments,
                       match=r"rank-deficient at column 4$"):
        gf256_gaussian_solve(a, y, 5)
    with pytest.raises(InsufficientFragments,
                       match=r"rank-deficient at column 4$"):
        gf256_gaussian_solve_ref(a, y, 5)


@pytest.mark.parametrize("backend", ["numpy", "kernel"])
def test_mixed_systems_one_padded_dispatch(backend):
    """Full-rank, rank-deficient, and permuted-pivot systems of different
    row counts, stacked into ONE padded ``gf256_solve_batch`` dispatch —
    each lane must reproduce the *unpadded* scalar-reference outcome
    exactly (the SolvePool flush rides on this padding contract)."""
    rng = np.random.default_rng(11)
    k, L = 8, 53
    systems = []
    # full-rank rectangular (random uint8 k x k is ~97% full rank; build
    # until one verifiably solves)
    while True:
        a = rng.integers(0, 256, (k + 2, k), dtype=np.uint8)
        if _ref_outcome(a, np.zeros((k + 2, L), np.uint8), k)[0] is not None:
            break
    systems.append(a)
    # rank-deficient: an all-zero column can never pivot
    a = rng.integers(0, 256, (k + 1, k), dtype=np.uint8)
    a[:, 5] = 0
    systems.append(a)
    # rank-deficient square: duplicated row
    a = rng.integers(0, 256, (k, k), dtype=np.uint8)
    a[k - 1] = a[2]
    systems.append(a)
    # permuted pivot: zero diagonal forces below-diagonal row swaps
    a = rng.integers(0, 256, (k + 3, k), dtype=np.uint8)
    a[np.arange(k), np.arange(k)] = 0
    systems.append(a[np.random.default_rng(7).permutation(k + 3)])
    ys = [rng.integers(0, 256, (a.shape[0], L), dtype=np.uint8)
          for a in systems]
    mmax = max(a.shape[0] for a in systems)
    batch_a = np.zeros((len(systems), mmax, k), np.uint8)
    batch_y = np.zeros((len(systems), mmax, L), np.uint8)
    for i, (a, y) in enumerate(zip(systems, ys)):
        batch_a[i, :a.shape[0]] = a
        batch_y[i, :a.shape[0]] = y
    x, ok, fail = gf256_solve_batch(batch_a, batch_y, backend=backend)
    for i, (a, y) in enumerate(zip(systems, ys)):
        want, want_fail = _ref_outcome(a, y, k)  # UNPADDED reference
        if want is None:
            assert not ok[i], i
            assert fail[i] == want_fail, (i, fail[i], want_fail)
        else:
            assert ok[i] and fail[i] == -1, i
            np.testing.assert_array_equal(x[i], want, err_msg=str(i))
    assert ok.tolist() == [True, False, False, True]


def _retry_prefix_ref(a, k):
    """PR 4's incremental one-more-fragment retry, run literally: the
    smallest row prefix >= k the scalar reference solves, or failure once
    rows run out."""
    y = np.zeros((a.shape[0], 1), np.uint8)
    for m in range(k, a.shape[0] + 1):
        try:
            gf256_gaussian_solve_ref(a[:m], y[:m], k)
            return True, m
        except InsufficientFragments:
            continue
    return False, a.shape[0]


def test_rank_prefix_matches_incremental_retry_loop():
    """``gf256_rank_prefix`` must decide, in one elimination pass, exactly
    the prefix the incremental retry loop reaches — the inline repair
    rank decision (and hence the RNG stream) rides on this equality."""
    rng = np.random.default_rng(12)
    k = 8
    cases = []
    for _ in range(40):  # random rectangular, mostly clean prefixes
        cases.append(rng.integers(0, 256, (k + 4, k), dtype=np.uint8))
    for _ in range(10):  # singular k-prefix, cured by a later row
        a = rng.integers(0, 256, (k + 4, k), dtype=np.uint8)
        a[k - 1] = a[0] ^ a[1]  # prefix a[:k] has rank k-1
        cases.append(a)
    for _ in range(10):  # permuted pivots inside the prefix
        a = rng.integers(0, 256, (k + 4, k), dtype=np.uint8)
        a[np.arange(k), np.arange(k)] = 0
        cases.append(a)
    a = rng.integers(0, 256, (k + 4, k), dtype=np.uint8)
    a[:, 3] = 0  # never solvable: dead column
    cases.append(a)
    a = rng.integers(0, 256, (k - 2, k), dtype=np.uint8)
    cases.append(a)  # fewer rows than k: immediate failure
    n_deep, n_fail = 0, 0
    for i, a in enumerate(cases):
        ok, n = gf256_rank_prefix(a)
        want_ok, want_n = _retry_prefix_ref(a, k)
        assert (ok, n) == (want_ok, want_n), (i, ok, n, want_ok, want_n)
        n_deep += ok and n > k
        n_fail += not ok
    assert n_deep >= 10 and n_fail >= 2  # both hard paths were exercised


def test_kernel_and_numpy_backends_agree_on_large_batch():
    """Above SOLVE_KERNEL_MIN the auto dispatcher takes the kernel path;
    force both and compare whole batches directly."""
    rng = np.random.default_rng(5)
    a, y = _random_systems(rng, 24, 18, 16, 192)
    xn, okn, fn = gf256_solve_batch(a, y, backend="numpy")
    xk, okk, fk = gf256_solve_batch(a, y, backend="kernel")
    np.testing.assert_array_equal(okn, okk)
    np.testing.assert_array_equal(fn, fk)
    np.testing.assert_array_equal(xn[okn], xk[okk])
