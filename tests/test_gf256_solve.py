"""Bit-pins for ``kernels/gf256_solve`` against the scalar reference.

``rateless.gf256_gaussian_solve_ref`` (the pre-kernel implementation) is
the oracle: the batched numpy mirror and the Pallas kernel must reproduce
its solutions byte-for-byte on full-rank systems, and must flag exactly
the column at which it raises on rank-deficient ones — the simulator's
decode results (and therefore the protocol goldens) ride on this.
"""
import numpy as np
import pytest

from repro.core.rateless import (RLNC, InsufficientFragments,
                                 gf256_gaussian_solve,
                                 gf256_gaussian_solve_ref)
from repro.kernels.gf256_solve import gf256_solve_batch, gf256_solve_np


def _ref_outcome(a, y, k):
    """(solution, fail_col) from the scalar reference."""
    try:
        return gf256_gaussian_solve_ref(a, y, k), -1
    except InsufficientFragments as e:
        return None, int(str(e).rsplit(" ", 1)[-1])


def _random_systems(rng, B, m, k, L):
    a = rng.integers(0, 256, (B, m, k), dtype=np.uint8)
    y = rng.integers(0, 256, (B, m, L), dtype=np.uint8)
    return a, y


def _check_against_ref(a, y, backend):
    B, _, k = a.shape
    x, ok, fail = gf256_solve_batch(a, y, backend=backend)
    for b in range(B):
        want, want_fail = _ref_outcome(a[b], y[b], k)
        if want is None:
            assert not ok[b], b
            assert fail[b] == want_fail, (b, fail[b], want_fail)
        else:
            assert ok[b] and fail[b] == -1, b
            np.testing.assert_array_equal(x[b], want, err_msg=str(b))


@pytest.mark.parametrize("backend", ["numpy", "kernel"])
def test_random_systems_bit_identical(backend):
    rng = np.random.default_rng(0)
    for m, k, L in [(4, 4, 1), (6, 4, 37), (16, 16, 130), (21, 16, 257),
                    (9, 8, 64)]:
        a, y = _random_systems(rng, 8, m, k, L)
        _check_against_ref(a, y, backend)


@pytest.mark.parametrize("backend", ["numpy", "kernel"])
def test_permuted_pivot_row_swaps(backend):
    """Zero diagonals force the pivot search below the diagonal — the
    row-swap path (masked-select in the kernel) must match the scalar
    swap exactly."""
    rng = np.random.default_rng(1)
    k, L = 8, 33
    systems_a, systems_y = [], []
    for perm_seed in range(12):
        prm = np.random.default_rng(perm_seed).permutation(k + 3)
        a = rng.integers(0, 256, (k + 3, k), dtype=np.uint8)
        # zero the diagonal so column j never pivots in place
        a[np.arange(k), np.arange(k)] = 0
        systems_a.append(a[prm])
        systems_y.append(rng.integers(0, 256, (k + 3, L), dtype=np.uint8))
    _check_against_ref(np.stack(systems_a), np.stack(systems_y), backend)


@pytest.mark.parametrize("backend", ["numpy", "kernel"])
def test_singular_systems_flag_reference_column(backend):
    rng = np.random.default_rng(2)
    k, L = 6, 16
    mats, syms = [], []
    # zero column 3 -> fails at column 3
    a = rng.integers(0, 256, (k + 1, k), dtype=np.uint8)
    a[:, 3] = 0
    mats.append(a)
    # duplicate rows with m == k -> rank k-1 (column of first divergence
    # is whatever the reference reports; we only require agreement)
    a = rng.integers(0, 256, (k, k), dtype=np.uint8)
    a[k - 1] = a[0]
    mats.append(a)
    # all-zero matrix -> fails at column 0
    mats.append(np.zeros((k, k), np.uint8))
    # linear combination: row2 = row0 ^ row1 (GF(2) subset of GF(256))
    a = rng.integers(0, 256, (k, k), dtype=np.uint8)
    a[2] = a[0] ^ a[1]
    mats.append(a)
    for a in mats:
        syms.append(rng.integers(0, 256, (a.shape[0], L), dtype=np.uint8))
    m = max(a.shape[0] for a in mats)
    batch_a = np.zeros((len(mats), m, k), np.uint8)
    batch_y = np.zeros((len(mats), m, L), np.uint8)
    for i, (a, y) in enumerate(zip(mats, syms)):
        batch_a[i, :a.shape[0]] = a
        batch_y[i, :a.shape[0]] = y
    _check_against_ref(batch_a, batch_y, backend)


@pytest.mark.parametrize("backend", ["numpy", "kernel"])
def test_square_random_matches_ref_including_rank_deficient(backend):
    """m == k random batches: ~1/255-ish of systems are singular; the
    batch must agree with the reference on every element either way."""
    rng = np.random.default_rng(3)
    k, L = 4, 8  # small k raises the singular fraction enough to hit some
    a, y = _random_systems(rng, 300, k, k, L)
    x, ok, fail = gf256_solve_batch(a, y, backend=backend)
    n_singular = 0
    for b in range(a.shape[0]):
        want, want_fail = _ref_outcome(a[b], y[b], k)
        if want is None:
            n_singular += 1
            assert not ok[b] and fail[b] == want_fail
        else:
            assert ok[b]
            np.testing.assert_array_equal(x[b], want)
    assert n_singular >= 1  # the sweep actually exercised the fail path


def test_rlnc_decode_round_trip_unchanged():
    """End-to-end: RLNC.decode (now through the dispatcher) still inverts
    encode, and the raised message on insufficient rank is unchanged."""
    rng = np.random.default_rng(4)
    code = RLNC(k=6, seed=b"solve-pin")
    blocks = rng.integers(0, 256, (6, 97), dtype=np.uint8)
    idx = [2, 5, 7, 11, 12, 19]
    out = code.decode(idx, code.encode(blocks, idx))
    np.testing.assert_array_equal(out, blocks)
    with pytest.raises(InsufficientFragments, match="need >= 6 symbols"):
        code.decode(idx[:4], code.encode(blocks, idx[:4]))


def test_scalar_delegate_message_is_exact():
    a = np.zeros((5, 5), np.uint8)
    a[np.arange(4), np.arange(4)] = 1  # rank 4: fails at column 4
    y = np.ones((5, 9), np.uint8)
    with pytest.raises(InsufficientFragments,
                       match=r"rank-deficient at column 4$"):
        gf256_gaussian_solve(a, y, 5)
    with pytest.raises(InsufficientFragments,
                       match=r"rank-deficient at column 4$"):
        gf256_gaussian_solve_ref(a, y, 5)


def test_kernel_and_numpy_backends_agree_on_large_batch():
    """Above SOLVE_KERNEL_MIN the auto dispatcher takes the kernel path;
    force both and compare whole batches directly."""
    rng = np.random.default_rng(5)
    a, y = _random_systems(rng, 24, 18, 16, 192)
    xn, okn, fn = gf256_solve_batch(a, y, backend="numpy")
    xk, okk, fk = gf256_solve_batch(a, y, backend="kernel")
    np.testing.assert_array_equal(okn, okk)
    np.testing.assert_array_equal(fn, fk)
    np.testing.assert_array_equal(xn[okn], xk[okk])
