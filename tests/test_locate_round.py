"""Bit-pins for ``selection.LocateRound`` — the resident batched Locate().

The batched engine's repair/store paths now run every Locate() slot
through ``LocateRound.responders`` instead of
``selection.verified_responders``. These tests pin that the responder
lists — content, proof bytes, and order — are identical on both VRF
backends, that exclusion filtering matches the eligibility prefilter of
the old path, and that the ``SimNetwork.locate_round`` cache invalidates
on membership and partition changes. (End-to-end equivalence of the
whole engine rides on ``tests/test_protocol_golden.py``.)
"""
import random

import numpy as np
import pytest

from repro.core import chunks as C
from repro.core import selection as sel
from repro.core.network import SimNetwork
from repro.core.vrf import RING


def _net(n: int, vrf: str, seed: int = 0) -> SimNetwork:
    net = SimNetwork(seed=seed, vrf=vrf)
    for i in range(n):
        net.add_node(seed=(seed * 997 + i).to_bytes(8, "little"))
    return net


def _assert_same_responders(got, want):
    assert len(got) == len(want)
    for (d_g, n_g, p_g), (d_w, n_w, p_w) in zip(got, want):
        assert d_g == d_w
        assert n_g is n_w
        assert p_g == p_w  # frozen dataclass: full (pk, r, proof, fh) match


@pytest.mark.parametrize("vrf", ["hash", "arx"])
def test_responders_match_verified_responders(vrf):
    net = _net(48, vrf, seed=1)
    r_target = 12
    for obj in range(4):
        chash = C.chunk_hash(b"locate-pin-%d" % obj)
        anchor = C.hash_point(chash)
        cands = net.candidates(anchor, min(4 * r_target, net.n_nodes))
        lr = net.locate_round(anchor, min(4 * r_target, net.n_nodes),
                              r_target)
        for i in range(24):
            fhash = C.fragment_hash(chash, i)
            want = sel.verified_responders(
                net.registry, cands, fhash, anchor, r_target, net.n_nodes)
            _assert_same_responders(lr.responders(fhash), want)


@pytest.mark.parametrize("vrf", ["hash", "arx"])
def test_exclusion_matches_eligibility_prefilter(vrf):
    net = _net(40, vrf, seed=2)
    r_target = 10
    chash = C.chunk_hash(b"locate-excl")
    anchor = C.hash_point(chash)
    cands = net.candidates(anchor, net.n_nodes)
    lr = net.locate_round(anchor, net.n_nodes, r_target)
    rnd = random.Random(7)
    for i in range(12):
        exclude = set(rnd.sample([c.nid for c in cands], k=rnd.randrange(20)))
        fhash = C.fragment_hash(chash, i)
        elig = [c for c in cands if c.nid not in exclude and c.alive]
        want = sel.verified_responders(
            net.registry, elig, fhash, anchor, r_target, net.n_nodes)
        _assert_same_responders(lr.responders(fhash, exclude), want)


@pytest.mark.parametrize("vrf", ["hash", "arx"])
def test_responder_proofs_verify_scalar(vrf):
    """Elided verification is sound: every returned proof passes the
    scalar public VerifySelection exactly as the old path required."""
    net = _net(32, vrf, seed=3)
    r_target = 8
    chash = C.chunk_hash(b"locate-verify")
    anchor = C.hash_point(chash)
    lr = net.locate_round(anchor, net.n_nodes, r_target)
    n_checked = 0
    for i in range(16):
        for _, _, proof in lr.responders(C.fragment_hash(chash, i)):
            assert sel.verify_selection(net.registry, proof, anchor,
                                        r_target, net.n_nodes)
            n_checked += 1
    assert n_checked > 0


@pytest.mark.parametrize("vrf", ["hash", "arx"])
def test_nearest_matches_min_over_responders(vrf):
    net = _net(44, vrf, seed=6)
    r_target = 10
    chash = C.chunk_hash(b"locate-nearest")
    anchor = C.hash_point(chash)
    lr = net.locate_round(anchor, net.n_nodes, r_target)
    rnd = random.Random(9)
    hits = misses = 0
    for i in range(32):
        exclude = set(rnd.sample([c.nid for c in lr.candidates],
                                 k=rnd.randrange(30)))
        fhash = C.fragment_hash(chash, i)
        responders = lr.responders(fhash, exclude)
        got = lr.nearest(fhash, exclude)
        if not responders:
            assert got is None
            misses += 1
        else:
            want = min(responders, key=lambda t: t[0])
            assert got[0] is want[1] and got[1] == want[2]
            hits += 1
    assert hits > 0  # both outcomes exercised
    assert misses >= 0


def test_locate_round_cache_invalidates_on_ring_and_eclipse():
    net = _net(20, "hash", seed=4)
    chash = C.chunk_hash(b"locate-cache")
    anchor = C.hash_point(chash)
    lr1 = net.locate_round(anchor, 20, 6)
    assert net.locate_round(anchor, 20, 6) is lr1          # stable: hit
    net.eclipse = (anchor % RING, (anchor + RING // 4) % RING)
    lr2 = net.locate_round(anchor, 20, 6)
    assert lr2 is not lr1                                  # cut: rebuilt
    reachable = {c.nid for c in lr2.candidates}
    assert all(not net.is_eclipsed(nid) for nid in reachable)
    net.eclipse = None
    lr3 = net.locate_round(anchor, 20, 6)
    assert lr3 is not lr2
    net.fail_node(lr3.candidates[0].nid)                   # churn: rebuilt
    lr4 = net.locate_round(anchor, 20, 6)
    assert lr4 is not lr3
    assert lr3.candidates[0].nid not in {c.nid for c in lr4.candidates}


@pytest.mark.parametrize("vrf", ["hash", "arx"])
def test_selected_count_tracks_r_target(vrf):
    """Sanity on the resident thresholds: expected responders per slot is
    ~R (§4.3.2) — a transcription slip in the uint64 ceiling or the lane
    compare would show up as a gross deviation."""
    net = _net(200, vrf, seed=5)
    r_target = 16
    chash = C.chunk_hash(b"locate-rate")
    anchor = C.hash_point(chash)
    lr = net.locate_round(anchor, net.n_nodes, r_target)
    counts = [len(lr.responders(C.fragment_hash(chash, i)))
              for i in range(64)]
    mean = float(np.mean(counts))
    assert 0.5 * r_target < mean < 1.7 * r_target, mean
