"""Attention core (chunked/GQA/MLA) and MoE dispatch correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig
from repro.models import attention as A
from repro.models import ffn as F


def ref_attention(q, k, v, causal_offset=0):
    """Naive grouped causal attention oracle (numpy)."""
    b, s, h, d = q.shape
    n = k.shape[2]
    g = h // n
    t = k.shape[1]
    out = np.zeros((b, s, h, v.shape[-1]))
    for bi in range(b):
        for hi in range(h):
            ki = hi // g
            sc = q[bi, :, hi] @ k[bi, :, ki].T / 1.0
            mask = np.tril(np.ones((s, t)), k=causal_offset)
            sc = np.where(mask > 0, sc, -1e30)
            w = np.exp(sc - sc.max(-1, keepdims=True))
            w = w / w.sum(-1, keepdims=True)
            out[bi, :, hi] = w @ v[bi, :, ki]
    return out


def test_attend_matches_reference_gqa():
    rng = np.random.default_rng(0)
    b, s, h, n, d = 2, 10, 6, 2, 4
    q = rng.standard_normal((b, s, h, d)).astype(np.float32)
    k = rng.standard_normal((b, s, n, d)).astype(np.float32)
    v = rng.standard_normal((b, s, n, d)).astype(np.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    out = A.attend(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), pos,
                   jnp.arange(s), scale=1.0)
    expect = ref_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), expect, rtol=2e-4, atol=2e-4)


def test_attend_chunked_and_unrolled_match_full():
    rng = np.random.default_rng(1)
    b, s, h, n, d = 1, 29, 4, 4, 8
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, n, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, n, d)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    kpos = jnp.arange(s)
    full = A.attend(q, k, v, pos, kpos, scale=0.5)
    chk = A.attend(q, k, v, pos, kpos, scale=0.5, chunk=8)
    unr = A.attend(q, k, v, pos, kpos, scale=0.5, chunk=8, unroll=True)
    np.testing.assert_allclose(np.asarray(chk), np.asarray(full),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(unr), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def _mla_cfg(**kw):
    return ModelConfig(
        d_model=48, n_heads=4, n_kv_heads=4, q_lora_rank=24, kv_lora_rank=16,
        qk_nope_dim=8, qk_rope_dim=4, v_head_dim=8, max_cache_len=24, **kw
    )


def test_mla_decode_matches_forward():
    cfg = _mla_cfg()
    p = A.mla_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((2, 9, 48)), jnp.float32) * 0.3
    pos = jnp.broadcast_to(jnp.arange(9)[None], (2, 9))
    y_full, _ = A.mla_forward(p, cfg, x, pos)
    cache = A.mla_cache_init(cfg, 2, jnp.float32)
    _, cache = A.mla_forward(p, cfg, x[:, :8], pos[:, :8], cache, 0)
    y_dec, _ = A.mla_decode(p, cfg, x[:, 8:9], pos[:, 8:9], cache, 8)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full[:, 8:9]),
                               rtol=2e-3, atol=2e-3)


def test_rope_relative_property():
    """RoPE: <rot(q,i), rot(k,j)> depends only on i-j."""
    from repro.models.common import apply_rope
    rng = np.random.default_rng(3)
    d = 16
    q = jnp.asarray(rng.standard_normal((1, 1, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, d)), jnp.float32)
    def dot_at(i, j):
        qi = apply_rope(q, jnp.array([[i]]), 10_000.0)
        kj = apply_rope(k, jnp.array([[j]]), 10_000.0)
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(5, 3) - dot_at(102, 100)) < 1e-3
    assert abs(dot_at(7, 7) - dot_at(0, 0)) < 1e-3


def test_moe_single_expert_equals_dense():
    """E=1, top-1, ample capacity ⇒ MoE == that expert's SwiGLU."""
    cfg = ModelConfig(d_model=16, n_experts=1, n_experts_per_tok=1,
                      moe_d_ff=32, capacity_factor=4.0)
    p = F.moe_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((2, 8, 16)), jnp.float32)
    y, aux = F.moe_forward(p, cfg, x)
    dense = F.swiglu(x, p["wg"][0], p["wi"][0], p["wo"][0])
    np.testing.assert_allclose(np.asarray(y), np.asarray(dense),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_are_bounded():
    cfg = ModelConfig(d_model=8, n_experts=4, n_experts_per_tok=2,
                      moe_d_ff=16, capacity_factor=1.0)
    p = F.moe_init(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(np.random.default_rng(5).standard_normal((1, 32, 8)),
                    jnp.float32)
    y, aux = F.moe_forward(p, cfg, x)
    assert np.isfinite(np.asarray(y)).all()
    # ≥1 by Cauchy-Schwarz; 3e-3 slack for float32 softmax/mean accumulation
    assert float(aux["load_balance"]) >= 1.0 - 3e-3


def test_moe_router_gradients_flow():
    cfg = ModelConfig(d_model=8, n_experts=4, n_experts_per_tok=2,
                      moe_d_ff=16, capacity_factor=2.0)
    p = F.moe_init(jax.random.PRNGKey(2), cfg)
    x = jnp.asarray(np.random.default_rng(6).standard_normal((1, 16, 8)),
                    jnp.float32)

    def loss(pp):
        y, aux = F.moe_forward(pp, cfg, x)
        return jnp.sum(y**2) + aux["load_balance"]

    g = jax.grad(loss)(p)
    router_g = np.abs(np.asarray(g["router"])).sum()
    assert router_g > 0
