"""GF(256)/GF(2) arithmetic: field axioms (property-based) + path equality."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # CI installs hypothesis; local runs may lack it
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import gf

bytes_arr = st.lists(st.integers(0, 255), min_size=1, max_size=64).map(
    lambda xs: np.array(xs, dtype=np.uint8)
)


@given(bytes_arr, bytes_arr)
@settings(max_examples=50, deadline=None)
def test_mul_commutative(a, b):
    n = min(len(a), len(b))
    a, b = a[:n], b[:n]
    assert np.array_equal(gf.gf_mul_np(a, b), gf.gf_mul_np(b, a))


@given(st.integers(0, 255), st.integers(0, 255), st.integers(0, 255))
@settings(max_examples=100, deadline=None)
def test_mul_associative_distributive(a, b, c):
    a, b, c = (np.uint8(x) for x in (a, b, c))
    assert gf.gf_mul_np(gf.gf_mul_np(a, b), c) == gf.gf_mul_np(
        a, gf.gf_mul_np(b, c)
    )
    left = gf.gf_mul_np(a, b ^ c)
    right = gf.gf_mul_np(a, b) ^ gf.gf_mul_np(a, c)
    assert left == right


def test_inverse():
    a = np.arange(1, 256, dtype=np.uint8)
    inv = gf.gf_inv_np(a)
    assert np.all(gf.gf_mul_np(a, inv) == 1)
    with pytest.raises(ZeroDivisionError):
        gf.gf_inv_np(np.uint8(0))


@given(bytes_arr, bytes_arr)
@settings(max_examples=30, deadline=None)
def test_bitsliced_matches_tables(a, b):
    n = min(len(a), len(b))
    a, b = a[:n], b[:n]
    bs = np.asarray(gf.gf_mul_bitsliced(a, b)).astype(np.uint8)
    tb = gf.gf_mul_np(a, b)
    assert np.array_equal(bs, tb)
    jt = np.asarray(gf.gf_mul_jnp_tables(a, b)).astype(np.uint8)
    assert np.array_equal(jt, tb)


def test_matmul_identity_and_linearity():
    rng = np.random.default_rng(0)
    m = rng.integers(0, 256, (8, 8), dtype=np.uint8)
    eye = np.eye(8, dtype=np.uint8)
    assert np.array_equal(gf.gf_matmul_np(eye, m), m)
    x = rng.integers(0, 256, (8, 32), dtype=np.uint8)
    y = rng.integers(0, 256, (8, 32), dtype=np.uint8)
    assert np.array_equal(
        gf.gf_matmul_np(m, x ^ y),
        gf.gf_matmul_np(m, x) ^ gf.gf_matmul_np(m, y),
    )


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(1)
    for length in (1, 3, 4, 17, 128):
        data = rng.integers(0, 256, (5, length), dtype=np.uint8)
        words = gf.pack_bits_to_words(data)
        back = gf.unpack_words_to_bytes(words, length)
        assert np.array_equal(back, data)
