"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
shape + finiteness assertions; prefill/decode consistency per family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import (
    forward,
    init_cache,
    init_params,
    train_loss,
)
from repro.optim import AdamWConfig
from repro.training import init_train_state, make_train_step


def make_batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {}
    if cfg.embed_inputs:
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model)).astype(np.float32) * 0.02
        )
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (b, s)), jnp.int32
        )
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (b, s)), jnp.int32
        )
    if cfg.extra_embed_len:
        batch["patches"] = jnp.asarray(
            rng.standard_normal(
                (b, cfg.extra_embed_len, cfg.d_model)
            ).astype(np.float32) * 0.02
        )
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = configs.smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = make_batch(cfg, b, s)
    logits, aux, _ = forward(params, cfg, batch, mode="train")
    total_s = s + cfg.extra_embed_len
    assert logits.shape == (b, total_s, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    if cfg.n_experts:
        assert float(aux["load_balance"]) > 0


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_train_step_improves_nothing_breaks(arch):
    cfg = configs.smoke_config(arch)
    state = init_train_state(cfg, jax.random.PRNGKey(1))
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=1,
                                                    total_steps=4)))
    batch = make_batch(cfg, 2, 16, seed=2)
    losses = []
    for _ in range(3):
        state, metrics = step(state, batch)
        loss = float(metrics["loss"])
        assert np.isfinite(loss)
        losses.append(loss)
    # same batch re-fed: loss must drop (learns) and state stays finite
    assert losses[-1] < losses[0]
    assert int(state["opt"]["step"]) == 3


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_prefill_decode_matches_full_forward(arch):
    cfg = configs.smoke_config(arch)
    if cfg.n_experts:  # avoid MoE token-drop divergence in the check
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = init_params(cfg, jax.random.PRNGKey(3))
    b, s = 2, 12
    batch = make_batch(cfg, b, s + 1, seed=4)

    def trim(d, n):
        out = dict(d)
        for k in ("tokens", "embeds", "labels"):
            if k in out:
                out[k] = out[k][:, :n]
        return out

    full_logits, _, _ = forward(params, cfg, batch, mode="train")
    cache = init_cache(cfg, b)
    _, _, cache = forward(params, cfg, trim(batch, s), mode="prefill",
                          cache=cache, cur_len=0)
    step_batch = {}
    if cfg.embed_inputs:
        step_batch["embeds"] = batch["embeds"][:, s : s + 1]
    else:
        step_batch["tokens"] = batch["tokens"][:, s : s + 1]
    dec_logits, _, _ = forward(
        params, cfg, step_batch, mode="decode", cache=cache,
        cur_len=s + cfg.extra_embed_len,
    )
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0]), np.asarray(full_logits[:, -1]),
        rtol=6e-3, atol=6e-3,
    )


def test_gradient_accumulation_matches_large_batch():
    cfg = configs.smoke_config("internlm2-20b")
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=4)
    batch = make_batch(cfg, 4, 16, seed=5)
    s0 = init_train_state(cfg, jax.random.PRNGKey(6))
    s1 = jax.tree_util.tree_map(jnp.copy, s0)
    stepA = jax.jit(make_train_step(cfg, opt, accum=1))
    stepB = jax.jit(make_train_step(cfg, opt, accum=2))
    outA, mA = stepA(s0, batch)
    outB, mB = stepB(s1, batch)
    np.testing.assert_allclose(
        float(mA["loss"]), float(mB["loss"]), rtol=2e-5
    )
    for a, b in zip(
        jax.tree_util.tree_leaves(outA["params"]),
        jax.tree_util.tree_leaves(outB["params"]),
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-5)
