"""Eclipse/partition adversary: determinism, partition-window invariants,
engine equivalence, and the cross-validation row against the engine's
documented mean-field approximation.

Timestamp-sensitive invariants run on ``engine="reference"`` (the
vectorized engine virtualizes view timestamps; its *behavior* is pinned
bit-identical separately below and by the golden suite).
"""
import dataclasses

import numpy as np

from repro.core import policies as P
from repro.core import protocol_sim as PS
from repro.core import scenarios as SC
from repro.core.vrf import RING

ECL = dict(n_nodes=80, n_objects=2, object_bytes=1200, k_outer=2,
           n_chunks=3, k_inner=5, r_inner=10, byz_fraction=0.1,
           churn_per_year=60.0, step_hours=24.0, steps=12,
           adv_policy="eclipse", attack_frac=0.3, attack_step=3,
           eclipse_steps=5, claim_every=1)


def _window(p):
    return range(p.attack_step, p.attack_step + p.eclipse_steps)


def test_eclipse_deterministic_and_engines_agree():
    """Same seed => identical traces; vectorized == reference bit-for-bit
    (the eclipse policy is new in this PR, so the PR 3 golden cannot pin
    it — this equivalence is its golden)."""
    for seed in (0, 1):
        p = PS.ProtocolParams(**ECL, seed=seed)
        a = PS.run_protocol(p, engine="reference")
        b = PS.run_protocol(p, engine="vectorized")
        c = PS.run_protocol(p, engine="vectorized")
        for x, y in ((a, b), (b, c)):
            np.testing.assert_array_equal(x.honest_trace, y.honest_trace)
            np.testing.assert_array_equal(x.byz_trace, y.byz_trace)
            np.testing.assert_array_equal(x.alive_frac_trace,
                                          y.alive_frac_trace)
            assert x.loss_events == y.loss_events
            assert x.repair_traffic_units == y.repair_traffic_units
            assert x.repairs == y.repairs


def test_partition_window_invariants():
    """During the cut: no claims or repairs cross it — eclipsed nodes gain
    no fragments and no view updates, unaffected nodes never record a
    fresh claim from the silent segment — and eclipsed nodes return with
    their views (and fragments) intact."""
    p = PS.ProtocolParams(**ECL, seed=2)
    lo, hi = P.ring_segment(p.attack_frac, RING)
    snaps = {}
    violations = []

    def probe(t, net):
        in_win = t in _window(p)
        for node in net.nodes.values():
            if not node.alive:
                continue
            ecl = net.is_eclipsed(node.nid)
            if in_win and ecl:
                snap = (tuple(node.fragments),
                        {ch: tuple(v.members) for ch, v in
                         node.groups.items()})
                prev = snaps.get(node.nid)
                if prev is not None and prev != snap:
                    violations.append(("frozen", t, node.nid))
                snaps[node.nid] = snap
            if in_win and not ecl:
                # no fresh claim/timer timestamp from an eclipsed peer may
                # appear in an unaffected node's views during the window
                win_start = (p.attack_step + 1) * p.step_hours
                for ch, view in node.groups.items():
                    for nid, last in view.members.items():
                        if net.is_eclipsed(nid) and last >= win_start \
                                and nid != node.nid:
                            violations.append(("crossed", t, node.nid))
        if not in_win:
            snaps.clear()

    r = PS.run_protocol(p, engine="reference", probe=probe)
    assert not violations, violations[:5]
    assert r.n_groups == p.n_objects * p.n_chunks


def test_eclipse_suppresses_repair_and_recovers():
    """The cut hurts while open (honest membership decays unrepaired in
    eclipsed groups) and repair resumes once it heals."""
    base = dict(ECL, steps=14, attack_frac=0.4, eclipse_steps=6)
    seeds = range(5)
    ecl = [PS.run_protocol(PS.ProtocolParams(**base, seed=s))
           for s in seeds]
    static = [PS.run_protocol(PS.ProtocolParams(
        **{**base, "adv_policy": "static", "eclipse_steps": 0}, seed=s))
        for s in seeds]
    w_end = base["attack_step"] + base["eclipse_steps"]
    # during the window the eclipsed runs fall behind the static runs
    e_mid = np.mean([r.honest_trace[w_end - 1].mean() for r in ecl])
    s_mid = np.mean([r.honest_trace[w_end - 1].mean() for r in static])
    assert e_mid < s_mid
    # post-window repair pulls the eclipse runs' live groups back up
    e_end = np.mean([r.honest_trace[-1][r.honest_trace[-1]
                                        >= base["k_inner"]].mean()
                     for r in ecl
                     if (r.honest_trace[-1] >= base["k_inner"]).any()])
    assert e_end > e_mid


def test_engine_eclipse_policy():
    """Engine mean-field: repairs are suppressed for the eclipsed share of
    groups during the window — and eclipse_steps=0 degenerates exactly to
    the static policy."""
    cell = dict(n_objects=10, n_chunks=4, k_outer=2, k_inner=8, r_inner=20,
                n_nodes=2000, byz_fraction=0.0, churn_per_year=120.0,
                step_hours=12.0, steps=30, adv_policy="eclipse",
                attack_frac=0.5, attack_step=8, eclipse_steps=12)
    ecl = SC.run_grid([cell], seeds=range(4), sampler="fast")
    noop = SC.run_grid([dict(cell, eclipse_steps=0)], seeds=range(4),
                       sampler="fast")
    static = SC.run_grid([dict(cell, adv_policy="static")], seeds=range(4),
                         sampler="fast")
    # a zero-length window is exactly the static adversary, bit for bit
    for f in ("repairs", "lost_objects", "alive_frac_trace",
              "repair_traffic_units"):
        np.testing.assert_array_equal(np.asarray(getattr(noop, f)),
                                      np.asarray(getattr(static, f)))
    # an open window suppresses repairs and costs durability
    assert float(np.mean(ecl.repairs)) < float(np.mean(static.repairs))
    assert (float(np.mean(np.asarray(ecl.alive_frac_trace)[..., -1]))
            <= float(np.mean(np.asarray(static.alive_frac_trace)[..., -1])))


def test_cross_validation_row_against_engine_approximation():
    """Small-scale cross-validation of the new protocol-only scenario: the
    engine's mean-field eclipse must (a) agree with the protocol on the
    end state within the two-sample 95% band, and (b) err on the
    conservative side (it suppresses whole groups where the protocol's
    segment-boundary groups keep partial repair)."""
    proto_p = PS.ProtocolParams(
        n_nodes=200, n_objects=3, object_bytes=1500, k_outer=2, n_chunks=5,
        k_inner=6, r_inner=14, byz_fraction=0.1, churn_per_year=80.0,
        step_hours=12.0, steps=30, claim_every=2, adv_policy="eclipse",
        attack_frac=0.3, attack_step=8, eclipse_steps=10)
    proto = PS.run_protocol_seeds(proto_p, seeds=range(5))
    eng = SC.run_grid([proto_p.to_scenario_kwargs()], seeds=range(8),
                      sampler="fast")
    pa = np.array([r.alive_frac_trace[-1] for r in proto])
    ea = np.asarray(eng.alive_frac_trace)[0, :, proto_p.steps - 1]
    pm, pc = SC.mean_ci(pa)
    em, ec = SC.mean_ci(ea)
    # conservative direction, with two-sample noise allowance
    assert float(em) <= float(pm) + float(np.hypot(ec, pc))
    # and not wildly off: the layers describe the same experiment
    assert abs(float(em) - float(pm)) <= max(
        2.5 * float(np.hypot(ec, pc)), 0.25)
    pl, _ = SC.mean_ci(np.array([r.lost_objects for r in proto],
                                np.float64))
    el, elc = SC.mean_ci(np.asarray(eng.lost_objects)[0].astype(np.float64))
    assert float(el) >= float(pl) - float(np.hypot(elc, pc)) - 1.0
