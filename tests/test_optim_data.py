"""Optimizer vs numpy reference; schedule; data pipeline determinism."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import SyntheticStream
from repro.models import ModelConfig
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
)


def test_adamw_matches_numpy_reference():
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.01,
                      clip_norm=1e9, warmup_steps=0, total_steps=10,
                      min_lr_frac=1.0)
    rng = np.random.default_rng(0)
    p0 = {"w": jnp.asarray(rng.standard_normal((4, 3)), jnp.float32)}
    g = {"w": jnp.asarray(rng.standard_normal((4, 3)), jnp.float32)}
    opt = adamw_init(p0)
    p1, opt1, _ = adamw_update(p0, g, opt, cfg)
    # numpy reference
    w = np.asarray(p0["w"], np.float64)
    gg = np.asarray(g["w"], np.float64)
    m = 0.1 * gg
    v = 0.01 * gg * gg
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.99)
    w1 = w - cfg.lr * (mh / (np.sqrt(vh) + cfg.eps) + cfg.weight_decay * w)
    np.testing.assert_allclose(np.asarray(p1["w"]), w1, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(opt1["mu"]["w"]), m, rtol=1e-5)
    assert int(opt1["step"]) == 1


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      min_lr_frac=0.1)
    lr0 = float(cosine_schedule(cfg, jnp.asarray(0)))
    lr_w = float(cosine_schedule(cfg, jnp.asarray(10)))
    lr_end = float(cosine_schedule(cfg, jnp.asarray(110)))
    assert lr0 < 0.05
    assert abs(lr_w - 1.0) < 1e-6
    assert abs(lr_end - 0.1) < 1e-3
    # monotone decay after warmup
    vals = [float(cosine_schedule(cfg, jnp.asarray(t))) for t in
            range(10, 111, 10)]
    assert all(a >= b - 1e-9 for a, b in zip(vals, vals[1:]))


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    gn = float(norm)
    assert abs(gn - np.sqrt(10 * 9 + 10 * 16)) < 1e-4
    total = np.sqrt(sum(float(jnp.sum(x**2)) for x in
                        jax.tree_util.tree_leaves(clipped)))
    assert abs(total - 1.0) < 1e-5


def test_stream_determinism_and_shards():
    cfg = ModelConfig(vocab=512, d_model=32)
    a = SyntheticStream(cfg, batch=8, seq=32, seed=1)
    b = SyntheticStream(cfg, batch=8, seq=32, seed=1)
    assert np.array_equal(a.batch_at(7)["tokens"], b.batch_at(7)["tokens"])
    assert not np.array_equal(a.batch_at(7)["tokens"],
                              a.batch_at(8)["tokens"])
    # shards partition the global batch deterministically and differ
    s0 = SyntheticStream(cfg, batch=8, seq=32, seed=1, n_shards=2, shard=0)
    s1 = SyntheticStream(cfg, batch=8, seq=32, seed=1, n_shards=2, shard=1)
    t0, t1 = s0.batch_at(3)["tokens"], s1.batch_at(3)["tokens"]
    assert t0.shape == (4, 32)
    assert not np.array_equal(t0, t1)


def test_stream_modality_stubs():
    cfg = ModelConfig(vocab=64, d_model=16, embed_inputs=True)
    b = SyntheticStream(cfg, batch=2, seq=8, seed=0).batch_at(0)
    assert b["embeds"].shape == (2, 8, 16)
    assert b["labels"].shape == (2, 8)
    cfg2 = ModelConfig(vocab=64, d_model=16, extra_embed_len=4)
    b2 = SyntheticStream(cfg2, batch=2, seq=8, seed=0).batch_at(0)
    assert b2["patches"].shape == (2, 4, 16)
