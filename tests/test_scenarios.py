"""Batched JAX scenario engine: equivalence against the numpy reference,
state invariants, theory (CTMC bound) consistency, and determinism."""
import numpy as np

from repro.core import durability as D
from repro.core import scenarios as SC
from repro.core import simulation as S

# one shared cell geometry so every run_grid call below reuses the same
# compiled executable (static dims: 240 groups, 60 objects, 182 steps)
SMALL = dict(n_objects=60, n_chunks=4, k_outer=2, k_inner=8, r_inner=20,
             n_nodes=2000, byz_fraction=0.2, churn_per_year=26.0,
             step_hours=12.0, years=0.25)
SMALL_P = S.SimParams(**{k: v for k, v in SMALL.items()})
N_SEEDS = 12


def _numpy_ref(fn, p, seeds=range(N_SEEDS)):
    return [fn(dataclass_replace(p, seed=s)) for s in seeds]


def dataclass_replace(p, **kw):
    import dataclasses
    return dataclasses.replace(p, **kw)


def _close(a, b, rel=0.1, abs_tol=0.02):
    return abs(a - b) <= rel * max(abs(a), abs(b)) + abs_tol


# ------------------------------------------------------------- equivalence
def test_vault_statistical_equivalence_vs_numpy():
    res = SC.run_grid([SMALL], seeds=range(N_SEEDS))
    ref = _numpy_ref(S.simulate_vault, SMALL_P)
    eng_traffic = res.repair_traffic_units[0]
    ref_traffic = np.array([r.repair_traffic_units for r in ref])
    # same expectation: means agree within a few combined standard errors
    se = np.sqrt(eng_traffic.var() / N_SEEDS + ref_traffic.var() / N_SEEDS)
    assert abs(eng_traffic.mean() - ref_traffic.mean()) < 5 * se + \
        0.02 * ref_traffic.mean()
    assert _close(float(res.lost_fraction[0].mean()),
                  np.mean([r.lost_fraction for r in ref]))
    assert _close(float(res.final_honest_mean[0].mean()),
                  np.mean([r.final_honest_mean for r in ref]), rel=0.05,
                  abs_tol=0.5)


def test_fast_sampler_matches_exact():
    exact = SC.run_grid([SMALL], seeds=range(N_SEEDS))
    fast = SC.run_grid([SMALL], seeds=range(N_SEEDS), sampler="fast")
    a = float(exact.repair_traffic_units[0].mean())
    b = float(fast.repair_traffic_units[0].mean())
    assert _close(a, b, rel=0.03)
    assert _close(float(exact.lost_fraction[0].mean()),
                  float(fast.lost_fraction[0].mean()))


def test_cache_reduces_traffic_batched():
    cells = [SMALL, dict(SMALL, cache_ttl_hours=48.0)]
    res = SC.run_grid(cells, seeds=range(8))
    no_cache = float(res.repair_traffic_units[0].mean())
    cached = float(res.repair_traffic_units[1].mean())
    assert cached < no_cache / 2
    assert float(res.cache_hits[1].mean()) > 0


def test_replicated_statistical_equivalence():
    p = dataclass_replace(SMALL_P, byz_fraction=0.05)
    res = SC.run_replicated_grid([dict(SMALL, byz_fraction=0.05)],
                                 seeds=range(N_SEEDS))
    ref = _numpy_ref(S.simulate_replicated, p)
    assert _close(float(res.lost_fraction[0].mean()),
                  np.mean([r.lost_fraction for r in ref]), abs_tol=0.08)
    assert _close(float(res.repair_traffic_units[0].mean()),
                  np.mean([r.repair_traffic_units for r in ref]), rel=0.15)


def test_fragment_trace_statistical_equivalence():
    tr = SC.trace_grid([dict(k_inner=32, r_inner=80, byz_fraction=1 / 3,
                             churn_per_year=26.0, step_hours=6.0,
                             years=1.0)], seeds=range(8))
    ref = np.stack([S.fragment_trace(32, 80, 1 / 3, 26.0, years=1.0, seed=s)
                    for s in range(8)])
    assert tr.shape == (1, 8, ref.shape[1])
    assert _close(float(tr[0].mean()), float(ref.mean()), rel=0.05,
                  abs_tol=1.0)
    # recoverable at default parameters in every seed (Fig. 5)
    assert tr[0].min() >= 32


def test_targeted_attack_matches_numpy_and_ordering():
    cells = [dict(n_objects=300, n_chunks=c, k_outer=8, byz_fraction=1 / 3,
                  attack_frac=0.2, n_nodes=100_000) for c in (10, 12, 14)]
    tg = SC.targeted_grid(cells, seeds=range(8))
    means = tg.mean(axis=1)
    for i, c in enumerate((10, 12, 14)):
        p = S.SimParams(n_objects=300, n_chunks=c, byz_fraction=1 / 3)
        ref = np.mean([S.targeted_attack_vault(p, 0.2, seed=s)
                       for s in range(8)])
        assert _close(float(means[i]), float(ref), abs_tol=0.05)
    # Fig. 6 bottom: more outer redundancy tolerates more attacked nodes
    assert means[2] <= means[1] <= means[0]


# --------------------------------------------------------------- invariants
def test_invariants_across_policies():
    cells = [
        dict(SMALL),
        dict(SMALL, churn_policy="regional", burst_prob=0.3, burst_mult=10.0),
        dict(SMALL, adv_policy="adaptive", adapt_boost=2.0),
        dict(SMALL, adv_policy="targeted", attack_frac=0.3, attack_step=60),
    ]
    res = SC.run_grid(cells, seeds=range(4), sampler="fast")
    # 0 <= honest and honest + byz <= R at all times, in every scenario
    assert (np.asarray(res.honest_min) >= 0).all()
    assert (np.asarray(res.members_max) <= SMALL["r_inner"] + 1e-6).all()
    # alive fraction is monotone non-increasing (absorbing states)
    trace = np.asarray(res.alive_frac_trace)
    assert (np.diff(trace, axis=-1) <= 1e-6).all()
    # traffic and repair counts are non-negative
    assert (np.asarray(res.repair_traffic_units) >= 0).all()
    assert (np.asarray(res.repairs) >= 0).all()
    assert (np.asarray(res.lost_fraction) >= 0).all()
    assert (np.asarray(res.lost_fraction) <= 1.0).all()


def test_zero_churn_is_silent():
    res = SC.run_grid([dict(SMALL, churn_per_year=0.0)], seeds=range(4))
    assert float(np.asarray(res.repair_traffic_units).max()) == 0.0
    assert float(np.asarray(res.repairs).max()) == 0.0
    assert float(np.asarray(res.lost_fraction).max()) == 0.0


def test_policy_effects_ordering():
    cells = [
        dict(SMALL, byz_fraction=0.25),
        dict(SMALL, byz_fraction=0.25, adv_policy="adaptive",
             adapt_boost=2.5),
        dict(SMALL, byz_fraction=0.25, churn_policy="regional",
             burst_prob=0.3, burst_mult=20.0),
    ]
    res = SC.run_grid(cells, seeds=range(8), sampler="fast")
    lost = np.asarray(res.lost_fraction).mean(axis=1)
    # an adaptive re-join adversary strictly dominates the static one
    assert lost[1] > lost[0] + 0.1
    # correlated regional bursts break groups i.i.d. churn keeps alive
    assert lost[2] > lost[0] + 0.1


# ------------------------------------------------------ theory consistency
def test_engine_loss_bounded_by_ctmc_theory():
    """Short-horizon lossy point: the CTMC object bound (pessimistic —
    Poisson churn at the full group size, no Byzantine churn-out) must
    upper-bound the engine's empirical loss within Monte-Carlo tolerance."""
    HOURS = 24 * 365.0
    N, F, n, k = 10_000, 3_333, 16, 8
    step_h, churn, steps, n_obj = 6.0, 237.0, 8, 150
    p_fail = -np.expm1(-churn / HOURS * step_h)
    I = D.initial_state_vector(N, F, n, k)
    theta = D.transition_matrix(N, F, n, k, churn_mu=n * p_fail, evict=0)
    p_group = D.absorb_probability(I, theta, steps)[-1]
    bound = D.object_loss_bound(p_group, 2)
    res = SC.run_grid([dict(n_objects=n_obj, n_chunks=2, k_outer=2,
                            k_inner=k, r_inner=n, byz_fraction=1 / 3,
                            churn_per_year=churn, step_hours=step_h,
                            steps=steps, n_nodes=N)], seeds=range(8))
    emp = float(res.lost_fraction[0].mean())
    mc_tol = 4 * np.sqrt(bound * (1 - bound) / (n_obj * 8))
    assert emp <= bound + mc_tol + 1e-6, (emp, bound)
    # the point is genuinely lossy, so the check is not vacuous
    assert emp > 0.3


def test_paper_point_engine_agrees_with_durability_margin():
    """At default code parameters the theory says losses are (near) zero
    over a short horizon; the engine must agree over every seed."""
    res = SC.run_grid([dict(n_objects=100, byz_fraction=1 / 3,
                            churn_per_year=26.0, step_hours=12.0,
                            years=0.25)], seeds=range(8), sampler="fast")
    assert float(np.asarray(res.lost_fraction).max()) == 0.0


# ------------------------------------------------------------- determinism
def test_seed_determinism():
    a = SC.run_grid([SMALL], seeds=(3, 7))
    b = SC.run_grid([SMALL], seeds=(3, 7))
    for x, y in zip(a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    # distinct seeds genuinely vary
    assert a.repair_traffic_units[0, 0] != a.repair_traffic_units[0, 1]


def test_grid_shapes_and_compat_wrappers():
    res = SC.run_grid([SMALL, dict(SMALL, byz_fraction=0.0)], seeds=range(3))
    assert res.lost_fraction.shape == (2, 3)
    assert res.alive_frac_trace.shape[:2] == (2, 3)
    r = S.simulate_vault_batched(SMALL_P, seeds=range(3))
    assert isinstance(r, S.SimResult)
    assert r.repair_traffic_units > 0
    rb = S.simulate_replicated_batched(SMALL_P, seeds=range(3))
    assert isinstance(rb, S.SimResult)


# ------------------------------------------------- sharded (devices=) axis
# devices=N compiles the SAME traced run into one jitted executable whose
# batch axis is split over a shard_map mesh (scenarios._compile_runner).
# The samplers are counter-based and per-element, so the sharded results
# must be bit-identical — any drift is a sharding bug. Subprocess-driven:
# the device count is an XLA pre-init flag (tests/conftest.py run_py).
def test_sharded_dispatch_all_runners_bitexact(subproc):
    out = subproc("""
import numpy as np
from repro.core import scenarios as SC
cells = [dict(n_objects=8, n_chunks=2, k_outer=2, k_inner=8, r_inner=20,
              n_nodes=2000, byz_fraction=0.25, churn_per_year=52.0,
              step_hours=12.0, years=0.05, cache_ttl_hours=24.0)]
def diff(tag, a, b):
    fields = getattr(a, "_fields", None)
    pairs = zip(fields, a, b) if fields else [(tag, a, b)]
    for name, x, y in pairs:
        assert np.array_equal(np.asarray(x), np.asarray(y)), (tag, name)
diff("vault", SC.run_grid(cells, seeds=range(4), sampler="arx"),
     SC.run_grid(cells, seeds=range(4), sampler="arx", devices=2))
diff("repl", SC.run_replicated_grid(cells, seeds=range(4), sampler="arx"),
     SC.run_replicated_grid(cells, seeds=range(4), sampler="arx", devices=2))
tc = [dict(k_inner=8, r_inner=20, byz_fraction=0.2, churn_per_year=52.0,
           step_hours=12.0, years=0.05)]
diff("trace", SC.trace_grid(tc, seeds=range(4), sampler="arx"),
     SC.trace_grid(tc, seeds=range(4), sampler="arx", devices=2))
gc = [dict(n_objects=30, n_chunks=4, k_outer=2, byz_fraction=1 / 3,
           attack_frac=0.1, n_nodes=1000)]
diff("targeted", SC.targeted_grid(gc, seeds=range(4), sampler="arx"),
     SC.targeted_grid(gc, seeds=range(4), sampler="arx", devices=2))
print("ALL_RUNNERS_SHARD_OK")
""", devices=2)
    assert "ALL_RUNNERS_SHARD_OK" in out


def test_sharded_dispatch_uneven_batch_padding(subproc):
    """B % devices != 0 exercises the chunker's padding path (replicas of
    the last element, sliced off) — including chunk_size rounding."""
    out = subproc("""
import numpy as np
from repro.core import scenarios as SC
cells = [dict(n_objects=8, n_chunks=2, k_outer=2, k_inner=8, r_inner=20,
              n_nodes=2000, byz_fraction=0.25, churn_per_year=52.0,
              step_hours=12.0, years=0.05)]
a = SC.run_grid(cells, seeds=range(3), sampler="arx")
b = SC.run_grid(cells, seeds=range(3), sampler="arx", devices=2)
c = SC.run_grid(cells, seeds=range(3), sampler="arx", devices=2,
                chunk_size=3)  # rounds up to 4 -> padded chunks
for name, x, y, z in zip(a._fields, a, b, c):
    assert np.array_equal(np.asarray(x), np.asarray(y)), name
    assert np.array_equal(np.asarray(x), np.asarray(z)), name
print("UNEVEN_SHARD_OK")
""", devices=2)
    assert "UNEVEN_SHARD_OK" in out


def test_devices_exceed_available_error_message():
    import pytest

    with pytest.raises(ValueError, match=r"local JAX device"):
        SC.run_grid([SMALL], seeds=range(2), sampler="fast", devices=97)
    with pytest.raises(ValueError, match=r"devices=97"):
        SC.trace_grid([dict(k_inner=8, r_inner=20, years=0.05)],
                      seeds=range(2), devices=97)


def test_warm_cache_two_runners_bitexact(subproc, tmp_path):
    """Persistent-cache replay regression: results must survive a warm
    compilation cache with a second executable running in the process.

    With ``donate_argnums`` on the runners this corrupted the FIRST
    dispatch's outputs: a fresh CPU compile refuses the int32→float
    aliasing ("donated buffers were not usable") and is correct, but the
    deserialized cache entry honors the requested aliases, frees the
    donated input while live outputs still point into it, and the second
    executable's allocations scribble over them (random fields each run).
    Donation is therefore banned in ``scenarios._compile_runner``; this
    test runs the same two-runner snippet cold (writes the cache) and
    warm (replays it) against an isolated cache dir and demands identical
    bytes.
    """
    snippet = """
import hashlib
import numpy as np
from repro.core import scenarios as SC
cells = [dict(n_objects=8, n_chunks=2, k_outer=2, k_inner=8, r_inner=20,
              n_nodes=2000, byz_fraction=0.25, churn_per_year=52.0,
              step_hours=12.0, years=0.05)]
a = SC.run_grid(cells, seeds=range(4), sampler="arx")
b = SC.run_grid(cells, seeds=range(4), sampler="arx", devices=2)
h = hashlib.sha256()
for r in (a, b):
    for name, x in zip(r._fields, r):
        h.update(name.encode())
        h.update(np.ascontiguousarray(np.asarray(x)).tobytes())
print("DIGEST", h.hexdigest())
"""
    cache = str(tmp_path / "jax-cache-d2")
    cold = subproc(snippet, devices=2, cache_dir=cache)
    warm = subproc(snippet, devices=2, cache_dir=cache)
    d_cold = [l for l in cold.splitlines() if l.startswith("DIGEST")]
    d_warm = [l for l in warm.splitlines() if l.startswith("DIGEST")]
    assert d_cold and d_cold == d_warm, (
        f"warm-cache replay diverged from cold run:\n{cold}\n{warm}")
