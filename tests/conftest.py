import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
SRC = REPO / "src"


def run_py(code: str, devices: int = 1, timeout: int = 300) -> str:
    """Run a python snippet in a subprocess with N host devices.

    Used by tests that need >1 device: the main pytest process must keep
    the default single-device jax (smoke tests measure that world), so
    multi-device checks fork with XLA_FLAGS set pre-init.
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    # never share a persistent compilation cache across device counts:
    # the cache key does not cover the host topology flag, and replaying
    # a foreign-topology entry yields corrupted outputs
    cache = env.get("JAX_COMPILATION_CACHE_DIR")
    if cache:
        env["JAX_COMPILATION_CACHE_DIR"] = f"{cache}-sub-d{devices}"
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=timeout,
    )
    if out.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
        )
    return out.stdout


@pytest.fixture
def subproc():
    return run_py
