import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
SRC = REPO / "src"
sys.path.insert(0, str(SRC))

from repro import config as CFG  # noqa: E402


def run_py(code: str, devices: int = 1, timeout: int = 300,
           cache_dir: str | None = None) -> str:
    """Run a python snippet in a subprocess with N host devices.

    Used by tests that need >1 device: the main pytest process must keep
    the default single-device jax (smoke tests measure that world), so
    multi-device checks fork with XLA_FLAGS set pre-init. The environment
    (device-count flag + topology-keyed compilation-cache dir — entries
    are not portable across host topologies) comes from repro.config;
    ``cache_dir`` overrides the compilation-cache location for tests that
    need a controlled cold/warm cache (e.g. the donation-replay
    regression).
    """
    env = CFG.subprocess_env(devices)
    env["PYTHONPATH"] = str(SRC)
    if cache_dir is not None:
        env["JAX_COMPILATION_CACHE_DIR"] = cache_dir
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=timeout,
    )
    if out.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
        )
    return out.stdout


@pytest.fixture
def subproc():
    return run_py
