"""``hypothesis`` facade: the real library when installed, a seeded
stand-in otherwise.

Property tests import it unconditionally:

    from _hypothesis_compat import given, settings, strategies as st

When ``hypothesis`` is importable (the CI property shard installs it via
requirements-ci.txt) this module re-exports the real ``given`` /
``settings`` / ``strategies`` — full shrinking, example database, the
works — so the stub can never shadow it. ``HAVE_HYPOTHESIS`` tells tests
which implementation they got. (The older per-site ``try: from hypothesis
import ...`` pattern still works and short-circuits this module entirely.)

Without it, the stand-in below covers exactly the API surface the tests
use — ``given`` (positional and keyword strategies),
``settings(max_examples=..., deadline=..., derandomize=...)``, and
``strategies.integers / lists / sampled_from / booleans / floats`` with
``.map``. Examples are drawn from a ``numpy.random`` generator seeded from
the test's qualified name, so runs are deterministic; example 0 is each
strategy's minimal value to keep edge cases covered without shrinking.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

try:
    import hypothesis as _hypothesis
except ModuleNotFoundError:
    _hypothesis = None

HAVE_HYPOTHESIS = _hypothesis is not None

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies  # noqa: F401
    st = strategies

DEFAULT_MAX_EXAMPLES = 20


class Strategy:
    def __init__(self, sample, minimal):
        self._sample = sample
        self._minimal = minimal

    def sample(self, rng):
        return self._sample(rng)

    def minimal(self):
        return self._minimal()

    def map(self, f):
        return Strategy(lambda rng: f(self._sample(rng)),
                        lambda: f(self._minimal()))


class _strategies:
    """Namespace mirroring ``hypothesis.strategies``."""

    @staticmethod
    def integers(min_value=0, max_value=2 ** 31 - 1):
        return Strategy(
            lambda rng: int(rng.integers(min_value, max_value,
                                         endpoint=True, dtype=np.int64)),
            lambda: int(min_value))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0):
        return Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)),
            lambda: float(min_value))

    @staticmethod
    def booleans():
        return Strategy(lambda rng: bool(rng.integers(0, 2)), lambda: False)

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def sample(rng):
            size = int(rng.integers(min_size, max_size, endpoint=True))
            return [elements.sample(rng) for _ in range(size)]

        return Strategy(
            sample, lambda: [elements.minimal()] * max(min_size, 0))

    @staticmethod
    def sampled_from(seq):
        seq = list(seq)
        return Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))],
                        lambda: seq[0])


def _settings(max_examples=DEFAULT_MAX_EXAMPLES, **_ignored):
    """Decorator recording the example budget (deadline etc. ignored)."""

    def deco(fn):
        fn._compat_max_examples = max_examples
        return fn

    return deco


def _given(*pos_strategies, **kw_strategies):
    """Run the test once per generated example (seeded, deterministic)."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            max_examples = getattr(wrapper, "_compat_max_examples",
                                   DEFAULT_MAX_EXAMPLES)
            seed = zlib.adler32(
                f"{fn.__module__}.{fn.__qualname__}".encode())
            for i in range(max_examples):
                if i == 0:
                    pos = [s.minimal() for s in pos_strategies]
                    kw = {n: s.minimal() for n, s in kw_strategies.items()}
                else:
                    rng = np.random.default_rng((seed, i))
                    pos = [s.sample(rng) for s in pos_strategies]
                    kw = {n: s.sample(rng) for n, s in kw_strategies.items()}
                try:
                    fn(*args, *pos, **{**kwargs, **kw})
                except Exception as e:  # noqa: BLE001 - annotate + re-raise
                    raise AssertionError(
                        f"falsifying example (#{i}): args={pos} "
                        f"kwargs={kw}: {e}") from e

        # hide the strategy-supplied parameters from pytest's fixture
        # resolution: like hypothesis, positional strategies fill the
        # RIGHTMOST parameters (leading ones stay available for fixtures,
        # matching the fn(*fixtures, *examples) call above)
        params = list(inspect.signature(fn).parameters.values())
        if pos_strategies:
            params = params[:-len(pos_strategies)]
        remaining = [p for p in params if p.name not in kw_strategies]
        wrapper.__signature__ = inspect.Signature(remaining)
        del wrapper.__wrapped__
        return wrapper

    return deco


if not HAVE_HYPOTHESIS:
    strategies = _strategies
    st = _strategies
    settings = _settings
    given = _given
