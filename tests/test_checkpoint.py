"""Checkpointing: pack/unpack properties, Vault save/restore under failures,
baseline checkpointers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # CI installs hypothesis; local runs may lack it
    from _hypothesis_compat import given, settings, strategies as st

from repro.checkpoint import (
    LocalCheckpointer,
    ReplicatedCheckpointer,
    VaultCheckpointer,
    pack_objects,
    unpack_objects,
)
from repro.core import chunks as C
from repro.core.network import SimNetwork
from repro.core.rateless import InsufficientFragments

SMALL = C.CodeParams(k_outer=4, n_chunks=6, k_inner=8, r_inner=20)


def make_net(n=120, byz=0, seed=0):
    net = SimNetwork(seed=seed)
    for i in range(n):
        net.add_node(byzantine=i < byz, seed=i.to_bytes(4, "little"))
    return net


def rand_state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {
            "w": jnp.asarray(rng.standard_normal((64, 32)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((32,)), jnp.bfloat16),
        },
        "opt": {
            "mu": jnp.asarray(rng.standard_normal((64, 32)), jnp.float32),
            "step": jnp.asarray(17, jnp.int32),
        },
    }


def assert_state_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert np.asarray(x).dtype == np.asarray(y).dtype
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@given(
    sizes=st.lists(st.integers(0, 5000), min_size=1, max_size=8),
    object_bytes=st.sampled_from([256, 1024, 4096]),
)
@settings(max_examples=20, deadline=None)
def test_pack_unpack_property(sizes, object_bytes):
    rng = np.random.default_rng(sum(sizes) + object_bytes)
    leaves = [
        (f"leaf{i}", rng.integers(0, 256, s, dtype=np.uint8))
        for i, s in enumerate(sizes)
    ]
    objects, entries = pack_objects(leaves, object_bytes)
    assert all(len(o) <= object_bytes for o in objects)
    back = unpack_objects(objects, entries)
    for (key, arr), out in zip(leaves, back):
        assert np.array_equal(arr, out)


def test_vault_checkpoint_roundtrip():
    net = make_net()
    ck = VaultCheckpointer(net, params=SMALL, object_bytes=4096)
    state = rand_state()
    rep = ck.save(state, step=5)
    assert rep.n_objects >= 2
    restored = ck.restore(5)
    assert_state_equal(state, restored)


def test_vault_checkpoint_survives_failures_and_byzantine():
    net = make_net(n=150, byz=45)  # 30% byzantine claimers
    ck = VaultCheckpointer(net, params=SMALL, object_bytes=4096)
    state = rand_state(1)
    ck.save(state, step=1)
    rng = np.random.default_rng(0)
    honest_alive = [n for n in net.alive_nodes() if not n.byzantine]
    for node in rng.choice(honest_alive[1:], size=25, replace=False):
        net.fail_node(node.nid)  # ~17% churn on top
    restored = ck.restore(1)
    assert_state_equal(state, restored)


def test_vault_checkpoint_fails_loudly_past_threshold():
    net = make_net(n=60)
    ck = VaultCheckpointer(net, params=SMALL, object_bytes=4096)
    ck.save(rand_state(2), step=2)
    for node in list(net.alive_nodes())[1:]:
        net.fail_node(node.nid)
    with pytest.raises(InsufficientFragments):
        ck.restore(2)


def test_replicated_and_local_checkpointers():
    net = make_net()
    rck = ReplicatedCheckpointer(net, object_bytes=4096)
    state = rand_state(3)
    rck.save(state, step=9)
    assert_state_equal(state, rck.restore(9))

    import tempfile
    with tempfile.TemporaryDirectory() as d:
        lck = LocalCheckpointer(d)
        lck.save(state, step=4)
        assert lck.latest_step() == 4
        assert_state_equal(state, lck.restore(4))


def test_vault_redundancy_vs_replication_bytes():
    """Vault ships ~3.1× the payload; replication r=3 ships 3× — comparable
    wire cost, far stronger guarantees (the paper's core trade)."""
    net = make_net()
    data_bytes = 200_000
    state = {"w": jnp.asarray(
        np.random.default_rng(4).standard_normal(data_bytes // 4),
        jnp.float32)}
    vck = VaultCheckpointer(net, params=C.CodeParams(), object_bytes=1 << 20)
    rep = vck.save(state, 0)
    # stored fragment bytes across the network ≈ redundancy × payload
    frag_bytes = sum(
        len(f) for n in net.alive_nodes() for f in n.fragments.values()
    )
    ratio = frag_bytes / rep.bytes
    assert 2.5 < ratio < 4.0  # ≈3.125 plus per-fragment padding
