"""Sampler accuracy (chi-square / KS / moment budgets) and chunked /
sharded dispatch determinism for the scenario engine.

The fast samplers are validated against the *analytic* binomial
distribution in the ``(n, p)`` regimes the engine actually hits: small
means (``n*p <~ 2``, the churn path — where the truncated inverse-CDF must
be statistically exact) and large repair-burst / init means (the Gaussian
branch — held to the documented moment + CDF error budget of
``repro/core/samplers.py``).  No scipy: PMFs come from ``math.comb`` and
chi-square critical values from the Wilson-Hilferty approximation.
"""
import math

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import samplers as SM
from repro.core import scenarios as SC

N_DRAWS = 200_000

# engine regimes: churn thinning at paper-ish rates (small mean, incl. the
# largest group size the sampler domain admits), then refill bursts and
# worst-case init draws (Gaussian branch)
SMALL_MEAN = [(53, 0.0356), (80, 0.02), (5, 0.3), (200, 0.005)]
LARGE_MEAN = [(27, 1 / 3), (80, 0.33), (112, 0.5)]
FAST_SAMPLERS = ("fast", "arx")


def _draw(sampler: str, n: int, p: float, seed: int = 7) -> np.ndarray:
    smp = SM.SAMPLERS[sampler]
    key = smp.streams(smp.fold(smp.base(jnp.int32(seed)), 1), 3)[1]
    out = smp.binom(key, jnp.full((N_DRAWS,), float(n), jnp.float32),
                    jnp.float32(p))
    return np.asarray(out)


def _binom_pmf(n: int, p: float) -> np.ndarray:
    k = np.arange(n + 1)
    return np.array([math.comb(n, int(i)) * p ** i * (1 - p) ** (n - i)
                     for i in k])


def _chi2_crit(dof: int, z: float = 3.09) -> float:
    """Wilson-Hilferty upper-tail critical value (z=3.09 ~ p=0.001)."""
    h = 2.0 / (9.0 * dof)
    return dof * (1.0 - h + z * math.sqrt(h)) ** 3


# ------------------------------------------------------------------ accuracy
@pytest.mark.parametrize("sampler", FAST_SAMPLERS)
@pytest.mark.parametrize("n,p", SMALL_MEAN)
def test_small_mean_chi_square_exact(sampler, n, p):
    """In the churn regime the truncated inverse-CDF must match the exact
    binomial distribution (not just its moments)."""
    x = _draw(sampler, n, p).astype(int)
    pmf = _binom_pmf(n, p)
    exp = pmf * N_DRAWS
    obs = np.bincount(x, minlength=n + 1).astype(float)
    keep = exp >= 10.0
    chi2 = ((obs[keep] - exp[keep]) ** 2 / exp[keep]).sum()
    tail_o, tail_e = obs[~keep].sum(), exp[~keep].sum()
    if tail_e > 0:
        chi2 += (tail_o - tail_e) ** 2 / tail_e
    dof = int(keep.sum())  # merged tail adds ~1, keep conservative
    assert chi2 < _chi2_crit(dof), (sampler, n, p, chi2, dof)


@pytest.mark.parametrize("sampler", FAST_SAMPLERS)
@pytest.mark.parametrize("n,p", LARGE_MEAN)
def test_gauss_branch_moments_and_cdf(sampler, n, p):
    """Above the cutover the rounded-Gaussian branch must hit the
    documented budget: near-exact mean/variance, <= ~3% sup-CDF error
    (the logistic-probit's classical max CDF deviation)."""
    x = _draw(sampler, n, p)
    m, v = n * p, n * p * (1 - p)
    mean_tol = 4.0 * math.sqrt(v / N_DRAWS) + 0.005 * m
    assert abs(x.mean() - m) < mean_tol, (sampler, n, p, x.mean())
    assert 0.9 < x.var() / v < 1.1, (sampler, n, p, x.var(), v)
    # KS-style sup distance against the analytic CDF
    cdf = np.cumsum(_binom_pmf(n, p))
    emp = np.cumsum(np.bincount(x.astype(int), minlength=n + 1)) / N_DRAWS
    assert np.abs(emp - cdf).max() < 0.035, (sampler, n, p)
    # support respected
    assert x.min() >= 0 and x.max() <= n


@pytest.mark.parametrize("sampler", ("exact",) + FAST_SAMPLERS)
def test_edge_cases(sampler):
    smp = SM.SAMPLERS[sampler]
    key = smp.streams(smp.fold(smp.base(jnp.int32(3)), 1), 1)[0]
    n = jnp.full((64,), 10.0, jnp.float32)
    assert np.all(np.asarray(smp.binom(key, jnp.zeros(64), 0.5)) == 0)
    assert np.all(np.asarray(smp.binom(key, n, 0.0)) == 0)
    assert np.all(np.asarray(smp.binom(key, n, 1.0)) == 10.0)


def test_arx_uniform_uniformity_and_streams():
    """256-bin chi-square on the raw ARX uniforms + decorrelation between
    consecutive stream keys of one step key."""
    smp = SM.SAMPLERS["arx"]
    k0, k1 = smp.streams(smp.fold(smp.base(jnp.int32(11)), 5), 2)
    u0 = np.asarray(smp.uniform(k0, (N_DRAWS,)))
    u1 = np.asarray(smp.uniform(k1, (N_DRAWS,)))
    assert 0.0 < u0.min() and u0.max() < 1.0
    hist = np.bincount((u0 * 256).astype(int), minlength=256)
    exp = N_DRAWS / 256.0
    chi2 = ((hist - exp) ** 2 / exp).sum()
    assert chi2 < _chi2_crit(255), chi2
    # across streams and across adjacent lanes
    assert abs(np.corrcoef(u0, u1)[0, 1]) < 0.01
    assert abs(np.corrcoef(u0[:-1], u0[1:])[0, 1]) < 0.01


def test_fast_logit_budget():
    u = jnp.linspace(1e-6, 1.0 - 1e-6, 100_001, dtype=jnp.float32)
    ref = np.log(np.asarray(u, np.float64) / (1.0 - np.asarray(u, np.float64)))
    got = np.asarray(SM.fast_logit(u), np.float64) / 0.5513
    assert np.abs(got - ref).max() < 0.01


# ------------------------------------------------- chunking / device axis
CELLS = [dict(n_objects=12, n_chunks=2, k_outer=2, k_inner=8, r_inner=20,
              n_nodes=2000, byz_fraction=f, churn_per_year=52.0,
              step_hours=12.0, years=0.05, cache_ttl_hours=ttl)
         for f in (0.0, 0.25) for ttl in (0.0, 24.0)]


def test_run_grid_chunking_bitexact():
    a = SC.run_grid(CELLS, seeds=range(3), sampler="arx")
    b = SC.run_grid(CELLS, seeds=range(3), sampler="arx", chunk_size=5)
    for name, x, y in zip(a._fields, a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y)), name


def test_other_runners_chunking_bitexact():
    ra = SC.run_replicated_grid(CELLS[:2], seeds=range(3), sampler="arx")
    rb = SC.run_replicated_grid(CELLS[:2], seeds=range(3), sampler="arx",
                                chunk_size=4)
    for name, x, y in zip(ra._fields, ra, rb):
        assert np.array_equal(np.asarray(x), np.asarray(y)), name
    tc = [dict(k_inner=8, r_inner=20, byz_fraction=0.2, churn_per_year=52.0,
               step_hours=12.0, years=0.05)]
    ta = SC.trace_grid(tc, seeds=range(4), sampler="arx")
    tb = SC.trace_grid(tc, seeds=range(4), sampler="arx", chunk_size=3)
    assert np.array_equal(ta, tb)
    gc = [dict(n_objects=30, n_chunks=4, k_outer=2, byz_fraction=1 / 3,
               attack_frac=0.1, n_nodes=1000)]
    ga = SC.targeted_grid(gc, seeds=range(4))
    gb = SC.targeted_grid(gc, seeds=range(4), chunk_size=3)
    assert np.array_equal(ga, gb)


def test_device_axis_bitexact(subproc):
    """shard_map-sharded dispatch must be bit-identical to single-device."""
    out = subproc("""
import numpy as np
from repro.core import scenarios as SC
cells = [dict(n_objects=12, n_chunks=2, k_outer=2, k_inner=8, r_inner=20,
              n_nodes=2000, byz_fraction=0.25, churn_per_year=52.0,
              step_hours=12.0, years=0.05)]
a = SC.run_grid(cells, seeds=range(4), sampler="arx")
b = SC.run_grid(cells, seeds=range(4), sampler="arx", devices=2)
for name, x, y in zip(a._fields, a, b):
    assert np.array_equal(np.asarray(x), np.asarray(y)), name
print("SHARD_OK")
""", devices=2)
    assert "SHARD_OK" in out


def test_devices_validation():
    with pytest.raises(ValueError):
        SC.run_grid(CELLS[:1], seeds=range(2), sampler="arx",
                    devices=99)


def test_sampler_domain_guard():
    """Group sizes beyond pow_int's 8-bit exponent domain must be rejected
    at scenario construction, not silently mis-sampled."""
    with pytest.raises(ValueError):
        SC.make_scenario(r_inner=256)
    with pytest.raises(ValueError):
        SC.make_scenario(replication=300)
    SC.make_scenario(r_inner=255)  # max admissible
