"""The CI shard partition stays sound and its drift guard actually guards.

``scripts/check_shards.py`` re-derives both tier-1 shards from
``.github/workflows/ci.yml`` and the test files on disk. These tests pin
the two properties that make it a gate rather than a lint: the committed
workflow passes, and each drift mode — a file collected by *neither*
shard, by *both* shards, or a stale ``ENGINE_SHARD`` entry — fails with
a message naming the offending file. The doctored workflows below are
edited copies of the real one, so the parser is exercised on the exact
YAML shapes CI uses (folded ``>-`` block, ``--ignore=$t`` loop).
"""
from __future__ import annotations

import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
WORKFLOW = ROOT / ".github" / "workflows" / "ci.yml"
LOOP = 'for t in $ENGINE_SHARD; do ignores="$ignores --ignore=$t"; done'


def _run(workflow: pathlib.Path) -> subprocess.CompletedProcess:
    return subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "check_shards.py"),
         "--workflow", str(workflow)],
        capture_output=True, text=True)


def _engine_files() -> list[str]:
    sys.path.insert(0, str(ROOT / "scripts"))
    try:
        import check_shards
        return check_shards.parse_engine_shard(WORKFLOW.read_text())
    finally:
        sys.path.pop(0)


def test_committed_workflow_partition_is_sound():
    proc = _run(WORKFLOW)
    assert proc.returncode == 0, proc.stderr
    assert "each collected exactly once" in proc.stdout


def test_engine_shard_parser_matches_workflow():
    files = _engine_files()
    assert "tests/test_scenarios.py" in files
    assert len(files) == len(set(files))
    assert all(f.startswith("tests/test_") for f in files)


def test_file_dropped_from_both_shards_fails(tmp_path):
    # replace the loop with explicit ignores that ALSO ignore a core file:
    # that file is then run by neither shard — the drift this guard exists
    # to catch
    explicit = " ".join(f"--ignore={f}" for f in _engine_files())
    text = WORKFLOW.read_text()
    assert LOOP in text, "core-shard loop changed; update this test"
    doctored = tmp_path / "ci.yml"
    doctored.write_text(text.replace(
        LOOP, f'ignores="{explicit} --ignore=tests/test_gf.py"'))
    proc = _run(doctored)
    assert proc.returncode == 1
    assert "tests/test_gf.py" in proc.stderr
    assert "NEITHER" in proc.stderr


def test_file_collected_by_both_shards_fails(tmp_path):
    engine = _engine_files()
    explicit = " ".join(f"--ignore={f}" for f in engine[:-1])
    doctored = tmp_path / "ci.yml"
    doctored.write_text(WORKFLOW.read_text().replace(
        LOOP, f'ignores="{explicit}"'))
    proc = _run(doctored)
    assert proc.returncode == 1
    assert engine[-1] in proc.stderr
    assert "BOTH" in proc.stderr


def test_stale_engine_shard_entry_fails(tmp_path):
    doctored = tmp_path / "ci.yml"
    doctored.write_text(WORKFLOW.read_text().replace(
        "tests/test_scenarios.py",
        "tests/test_scenarios.py tests/test_gone.py", 1))
    proc = _run(doctored)
    assert proc.returncode == 1
    assert "tests/test_gone.py" in proc.stderr
    assert "stale" in proc.stderr
