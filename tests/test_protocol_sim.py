"""Protocol-level simulator: determinism, invariants, policy effects."""
import dataclasses

import numpy as np

from repro.core import protocol_sim as PS

SMALL = dict(n_nodes=80, n_objects=2, object_bytes=1200, k_outer=2,
             n_chunks=3, k_inner=5, r_inner=10, byz_fraction=0.15,
             churn_per_year=40.0, step_hours=24.0, steps=10)


def test_same_seed_identical_trace():
    """Determinism: identical params (incl. seed) => identical traces and
    stats, across every policy knob at once."""
    p = PS.ProtocolParams(**SMALL, churn_policy="regional", burst_prob=0.3,
                          burst_mult=6.0, adv_policy="adaptive",
                          adapt_boost=3.0, cache_ttl_hours=48.0, seed=7)
    a, b = PS.run_protocol(p), PS.run_protocol(p)
    np.testing.assert_array_equal(a.honest_trace, b.honest_trace)
    np.testing.assert_array_equal(a.byz_trace, b.byz_trace)
    np.testing.assert_array_equal(a.alive_frac_trace, b.alive_frac_trace)
    assert a.loss_events == b.loss_events
    for field in ("repair_traffic_units", "repairs", "cache_hits",
                  "lost_objects", "final_honest_mean", "honest_min",
                  "members_max"):
        assert getattr(a, field) == getattr(b, field), field


def test_seed_changes_trace():
    pa = PS.ProtocolParams(**SMALL, seed=0)
    pb = dataclasses.replace(pa, seed=1)
    a, b = PS.run_protocol(pa), PS.run_protocol(pb)
    assert not np.array_equal(a.honest_trace, b.honest_trace)


def test_invariants_and_schema():
    p = PS.ProtocolParams(**SMALL, seed=3)
    r = PS.run_protocol(p)
    assert r.n_groups == p.n_objects * p.n_chunks
    assert r.honest_trace.shape == (p.steps, r.n_groups)
    assert r.alive_frac_trace.shape == (p.steps,)
    assert (r.honest_trace >= 0).all() and (r.byz_trace >= 0).all()
    # groups are repaired to R, never past it (no over-repair in a tick:
    # stale views converge via MembershipTimer before adding members)
    assert r.members_max <= p.r_inner
    # without caches, group death is absorbing => alive fraction monotone
    assert (np.diff(r.alive_frac_trace) <= 1e-12).all()
    assert 0.0 <= r.lost_fraction <= 1.0
    assert r.lost_objects == len(r.loss_events) or not r.loss_events


def test_heavy_churn_loses_objects():
    """Brutal churn on a thin code must produce recorded loss events that
    agree with the final census."""
    p = PS.ProtocolParams(
        n_nodes=60, n_objects=2, object_bytes=800, k_outer=2, n_chunks=2,
        k_inner=8, r_inner=10, churn_per_year=2000.0, step_hours=24.0,
        steps=8, seed=0)
    r = PS.run_protocol(p)
    assert r.lost_objects > 0
    assert r.loss_events and len(r.loss_events) == r.lost_objects
    steps = [t for t, _ in r.loss_events]
    assert all(0 <= t < p.steps for t in steps)
    assert r.alive_frac_trace[-1] < 1.0


def test_adaptive_rush_biases_refills():
    """The adaptive adversary's Locate()-rush must raise the Byzantine
    share of groups above the static policy's, all else equal."""
    base = dict(SMALL, byz_fraction=0.25, steps=16)
    stat = PS.run_protocol(PS.ProtocolParams(**base, seed=11))
    adpt = PS.run_protocol(PS.ProtocolParams(
        **base, adv_policy="adaptive", adapt_boost=6.0, seed=11))
    # compare late-run byzantine occupancy (refills have turned over seats)
    assert adpt.byz_trace[-5:].mean() > stat.byz_trace[-5:].mean()


def test_matched_cell_roundtrip():
    """to_scenario_kwargs builds a valid engine cell with matching knobs."""
    from repro.core import scenarios as SC

    p = PS.ProtocolParams(**SMALL, churn_policy="regional",
                          adv_policy="adaptive")
    sc = SC.make_scenario(**p.to_scenario_kwargs())
    assert int(sc.steps) == p.steps
    assert float(sc.r_inner) == p.r_inner
    assert int(sc.churn_policy) == SC.CHURN_REGIONAL
    assert int(sc.adv_policy) == SC.ADV_ADAPTIVE


def test_summarize_ci():
    p = PS.ProtocolParams(**SMALL)
    res = PS.run_protocol_seeds(p, seeds=range(3))
    s = PS.summarize(res)
    m, ci = s["repairs"]
    vals = [r.repairs for r in res]
    assert m == float(np.mean(vals))
    assert ci >= 0.0
