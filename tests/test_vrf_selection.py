"""VRF + Algorithm 2 selection: determinism, verifiability, distribution."""
import numpy as np

from repro.core import chunks as C
from repro.core import selection as sel
from repro.core.vrf import RING, KeyPair, VRFRegistry, node_id


def test_vrf_deterministic_and_verifiable():
    reg = VRFRegistry()
    kp = KeyPair.generate(b"a")
    reg.register(kp)
    r1, p1 = reg.prove(kp.sk, b"input")
    r2, p2 = reg.prove(kp.sk, b"input")
    assert (r1, p1) == (r2, p2)
    assert reg.verify(kp.pk, b"input", r1, p1)
    assert not reg.verify(kp.pk, b"other", r1, p1)
    assert not reg.verify(kp.pk, b"input", r1 ^ 1, p1)


def test_vrf_forgery_rejected():
    reg = VRFRegistry()
    kp_a = KeyPair.generate(b"a")
    kp_b = KeyPair.generate(b"b")
    reg.register(kp_a)
    reg.register(kp_b)
    r, p = reg.prove(kp_b.sk, b"x")  # b's proof presented under a's pk
    assert not reg.verify(kp_a.pk, b"x", r, p)
    assert not reg.verify(KeyPair.generate(b"c").pk, b"x", r, p)


def test_vrf_uniformity():
    reg = VRFRegistry()
    kp = KeyPair.generate(b"u")
    reg.register(kp)
    vals = [
        reg.prove(kp.sk, i.to_bytes(4, "little"))[0] / RING
        for i in range(2000)
    ]
    vals = np.array(vals)
    assert abs(vals.mean() - 0.5) < 0.02
    assert abs(np.quantile(vals, 0.25) - 0.25) < 0.03


def test_node_ids_spread_on_ring():
    ids = [node_id(KeyPair.generate(bytes([i, j])).pk)
           for i in range(16) for j in range(16)]
    norm = np.sort(np.array(ids, dtype=np.float64) / RING)
    gaps = np.diff(np.concatenate([norm, [norm[0] + 1.0]]))
    assert gaps.max() < 0.08  # 256 nodes: no giant hole


def test_selection_proof_verifies_and_rejects_wrong_anchor():
    reg = VRFRegistry()
    kp = KeyPair.generate(b"s")
    reg.register(kp)
    anchor = 123456789
    sp, selected = sel.make_selection_proof(
        reg, kp.sk, kp.pk, anchor, anchor, r_target=80, n_nodes=100
    )
    ok = sel.verify_selection(reg, sp, anchor, 80, 100)
    # selection outcome and verification agree
    assert ok == selected


def test_expected_selection_count_near_r():
    """§4.3.2: expected number of selected candidates ≈ R."""
    reg = VRFRegistry()
    n_nodes, r_target = 600, 40
    kps = [KeyPair.generate(bytes([i % 256, i // 256])) for i in range(n_nodes)]
    for kp in kps:
        reg.register(kp)
    counts = []
    for trial in range(12):
        chash = C.chunk_hash(trial.to_bytes(4, "little"))
        anchor = C.hash_point(chash)
        fhash = C.fragment_hash(chash, 0)
        n_sel = 0
        for kp in kps:
            _sp, s = sel.make_selection_proof(
                reg, kp.sk, kp.pk, fhash, anchor, r_target, n_nodes
            )
            n_sel += int(s)
        counts.append(n_sel)
    mean = np.mean(counts)
    assert 0.6 * r_target < mean < 1.6 * r_target, counts


def test_distance_metric_units():
    # distance is measured in expected-node-spacings (+1)
    n = 128
    spacing = RING // n
    assert abs(sel.distance_metric(0, spacing, n) - 2.0) < 0.01
    assert abs(sel.distance_metric(0, 0, n) - 1.0) < 1e-9
    # wraps around the ring
    assert abs(
        sel.distance_metric(RING - spacing // 2, spacing // 2, n) - 2.0
    ) < 0.01
