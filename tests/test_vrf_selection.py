"""VRF + Algorithm 2 selection: determinism, verifiability, distribution,
and the batched paths pinned element-for-element against the scalar ones."""
import numpy as np
import pytest

from repro.core import chunks as C
from repro.core import selection as sel
from repro.core.vrf import (RING, KeyPair, VRFRegistry, make_registry,
                            node_id)


def test_vrf_deterministic_and_verifiable():
    reg = VRFRegistry()
    kp = KeyPair.generate(b"a")
    reg.register(kp)
    r1, p1 = reg.prove(kp.sk, b"input")
    r2, p2 = reg.prove(kp.sk, b"input")
    assert (r1, p1) == (r2, p2)
    assert reg.verify(kp.pk, b"input", r1, p1)
    assert not reg.verify(kp.pk, b"other", r1, p1)
    assert not reg.verify(kp.pk, b"input", r1 ^ 1, p1)


def test_vrf_forgery_rejected():
    reg = VRFRegistry()
    kp_a = KeyPair.generate(b"a")
    kp_b = KeyPair.generate(b"b")
    reg.register(kp_a)
    reg.register(kp_b)
    r, p = reg.prove(kp_b.sk, b"x")  # b's proof presented under a's pk
    assert not reg.verify(kp_a.pk, b"x", r, p)
    assert not reg.verify(KeyPair.generate(b"c").pk, b"x", r, p)


def test_vrf_uniformity():
    reg = VRFRegistry()
    kp = KeyPair.generate(b"u")
    reg.register(kp)
    vals = [
        reg.prove(kp.sk, i.to_bytes(4, "little"))[0] / RING
        for i in range(2000)
    ]
    vals = np.array(vals)
    assert abs(vals.mean() - 0.5) < 0.02
    assert abs(np.quantile(vals, 0.25) - 0.25) < 0.03


def test_node_ids_spread_on_ring():
    ids = [node_id(KeyPair.generate(bytes([i, j])).pk)
           for i in range(16) for j in range(16)]
    norm = np.sort(np.array(ids, dtype=np.float64) / RING)
    gaps = np.diff(np.concatenate([norm, [norm[0] + 1.0]]))
    assert gaps.max() < 0.08  # 256 nodes: no giant hole


def test_selection_proof_verifies_and_rejects_wrong_anchor():
    reg = VRFRegistry()
    kp = KeyPair.generate(b"s")
    reg.register(kp)
    anchor = 123456789
    sp, selected = sel.make_selection_proof(
        reg, kp.sk, kp.pk, anchor, anchor, r_target=80, n_nodes=100
    )
    ok = sel.verify_selection(reg, sp, anchor, 80, 100)
    # selection outcome and verification agree
    assert ok == selected


def test_expected_selection_count_near_r():
    """§4.3.2: expected number of selected candidates ≈ R."""
    reg = VRFRegistry()
    n_nodes, r_target = 600, 40
    kps = [KeyPair.generate(bytes([i % 256, i // 256])) for i in range(n_nodes)]
    for kp in kps:
        reg.register(kp)
    counts = []
    for trial in range(12):
        chash = C.chunk_hash(trial.to_bytes(4, "little"))
        anchor = C.hash_point(chash)
        fhash = C.fragment_hash(chash, 0)
        n_sel = 0
        for kp in kps:
            _sp, s = sel.make_selection_proof(
                reg, kp.sk, kp.pk, fhash, anchor, r_target, n_nodes
            )
            n_sel += int(s)
        counts.append(n_sel)
    mean = np.mean(counts)
    assert 0.6 * r_target < mean < 1.6 * r_target, counts


def test_distance_metric_units():
    # distance is measured in expected-node-spacings (+1)
    n = 128
    spacing = RING // n
    assert abs(sel.distance_metric(0, spacing, n) - 2.0) < 0.01
    assert abs(sel.distance_metric(0, 0, n) - 1.0) < 1e-9
    # wraps around the ring
    assert abs(
        sel.distance_metric(RING - spacing // 2, spacing // 2, n) - 2.0
    ) < 0.01


# ---------------------------------------------------------------- batch paths
def _population(reg, n=24):
    kps = [KeyPair.generate(bytes([i]) * 8) for i in range(n)]
    for kp in kps:
        reg.register(kp)
    return kps


@pytest.mark.parametrize("backend", ["hash", "arx"])
def test_registry_batch_matches_scalar(backend):
    """verify_batch / prove_batch are element-for-element the scalar calls."""
    reg = make_registry(backend)
    kps = _population(reg)
    alphas = [bytes([i]) * 32 for i in range(len(kps))]
    rs, proofs = reg.prove_batch([kp.sk for kp in kps], alphas)
    for kp, a, r, p in zip(kps, alphas, rs, proofs):
        assert (r, p) == reg.prove(kp.sk, a)
        assert reg.verify(kp.pk, a, r, p)
    ok = reg.verify_batch([kp.pk for kp in kps], alphas, rs, proofs)
    assert ok.all()
    # tampered elements fail exactly where the scalar verifier fails
    bad_rs = list(rs)
    bad_rs[3] ^= 1 << 200
    bad_proofs = list(proofs)
    bad_proofs[7] = bytes(len(bad_proofs[7]))
    ok = reg.verify_batch([kp.pk for kp in kps], alphas, bad_rs, bad_proofs)
    want = [reg.verify(kp.pk, a, r, p) for kp, a, r, p in
            zip(kps, alphas, bad_rs, bad_proofs)]
    assert list(ok) == want
    assert not ok[3] and not ok[7] and ok.sum() == len(kps) - 2


@pytest.mark.parametrize("backend", ["hash", "arx"])
def test_selection_batch_pins_scalar_path(backend):
    """The tentpole correctness pin: make_selection_proofs_batch and
    verify_selection_batch agree with the scalar Alg. 2 functions
    element-for-element — selected coins, proof objects, and verdicts."""
    reg = make_registry(backend)
    kps = _population(reg, 32)
    n_nodes, r_target = 32, 8
    anchor = C.hash_point(b"chunk")
    fhash = C.fragment_hash(b"chunk", 5)
    proofs, selected = sel.make_selection_proofs_batch(
        reg, [(kp.sk, kp.pk) for kp in kps], fhash, anchor, r_target,
        n_nodes)
    scalar = [sel.make_selection_proof(reg, kp.sk, kp.pk, fhash, anchor,
                                       r_target, n_nodes) for kp in kps]
    for i, (sp, sel_i) in enumerate(scalar):
        assert bool(selected[i]) == sel_i
        if sel_i:
            assert proofs[i] == sp  # unselected proofs are lazily omitted
    sps = [sp for sp, _ in scalar]
    got = sel.verify_selection_batch(reg, sps, [anchor] * len(sps),
                                     r_target, n_nodes)
    want = [sel.verify_selection(reg, sp, anchor, r_target, n_nodes)
            for sp in sps]
    assert list(got) == want
    # memoized second pass is identical
    again = sel.verify_selection_batch(reg, sps, [anchor] * len(sps),
                                       r_target, n_nodes)
    assert list(again) == want


def test_selection_batch_cache_keyed_on_proof_bits():
    """A forged proof must not hit a genuine proof's cached verdict."""
    reg = make_registry("hash")
    kps = _population(reg, 8)
    anchor = C.hash_point(b"c")
    fhash = C.fragment_hash(b"c", 1)
    sp, sel_ok = sel.make_selection_proof(reg, kps[0].sk, kps[0].pk, fhash,
                                          anchor, 8, 8)
    assert sel.verify_selection_batch(reg, [sp], [anchor], 8, 8)[0]
    forged = sel.SelectionProof(pk=sp.pk, r=sp.r ^ 1, proof=sp.proof,
                                fragment_hash=sp.fragment_hash)
    assert not sel.verify_selection_batch(reg, [forged], [anchor], 8, 8)[0]


def test_arx_registry_uniformity_and_unforgeability():
    reg = make_registry("arx")
    kps = _population(reg, 4)
    rs = []
    for i in range(512):
        r, _ = reg.prove(kps[0].sk, i.to_bytes(32, "big"))
        rs.append(r / RING)
    rs = np.array(rs)
    assert 0.4 < rs.mean() < 0.6 and rs.min() < 0.1 and rs.max() > 0.9
    # proofs from one key don't verify under another, nor unregistered keys
    alpha = (7).to_bytes(32, "big")
    r, p = reg.prove(kps[1].sk, alpha)
    assert reg.verify(kps[1].pk, alpha, r, p)
    assert not reg.verify(kps[2].pk, alpha, r, p)
    assert not reg.verify_batch([KeyPair.generate(b"zz").pk], [alpha], [r],
                                [p])[0]


def test_make_registry_rejects_unknown_backend():
    with pytest.raises(ValueError, match="unknown VRF backend"):
        make_registry("ed25519")
