"""Golden regression: the vectorized protocol tick path is bit-identical
to the PR 3 scalar path at small scale.

``tests/data/golden_protocol_pr3.json`` was captured from the PR 3 commit
(the pure scalar per-claim/per-dict implementation) by running this module
as a script::

    PYTHONPATH=src python -m tests.test_protocol_golden --regen

The test runs every captured config through BOTH engines of
``protocol_sim.run_protocol`` — ``engine="reference"`` (the preserved PR 3
scalar path) and ``engine="vectorized"`` (batched VRF verification +
array-table tick path) — and requires every field of ``ProtocolResult``,
including the full per-step traces and loss-event tuples, to match the
golden values exactly. Any change to RNG consumption order, view-dict
update order, claim acceptance, or repair scheduling shows up here as a
hard failure, not a statistical drift.
"""
from __future__ import annotations

import json
import pathlib
import sys

import numpy as np
import pytest

from repro.core import protocol_sim as PS

GOLDEN = pathlib.Path(__file__).parent / "data" / "golden_protocol_pr3.json"

# Small-scale configs covering every policy axis the PR 3 simulator had.
# (Eclipse is new in this PR, so it is pinned by vectorized==reference
# equivalence in tests/test_eclipse.py, not by this PR 3 golden file.)
_BASE = dict(n_nodes=80, n_objects=2, object_bytes=1200, k_outer=2,
             n_chunks=3, k_inner=5, r_inner=10, byz_fraction=0.15,
             churn_per_year=40.0, step_hours=24.0, steps=8, claim_every=2)

CONFIGS: dict[str, PS.ProtocolParams] = {
    "iid_static": PS.ProtocolParams(**_BASE, seed=0),
    "iid_static_seed1": PS.ProtocolParams(**_BASE, seed=1),
    "regional_burst": PS.ProtocolParams(
        **_BASE, churn_policy="regional", burst_prob=0.4, burst_mult=8.0,
        seed=2),
    "iid_adaptive": PS.ProtocolParams(
        **_BASE, adv_policy="adaptive", adapt_boost=4.0, seed=3),
    "iid_targeted": PS.ProtocolParams(
        **_BASE, adv_policy="targeted", attack_frac=0.3, attack_step=3,
        seed=4),
    "iid_cache": PS.ProtocolParams(**_BASE, cache_ttl_hours=72.0, seed=5),
    # prune-heavy: the claim timeout (3 steps at claim_every=1) is shorter
    # than the run, so stale-member pruning and timer re-admission fire
    # constantly — the pattern that stresses the engine's virtual
    # timestamps. (Captured from engine="reference", which the tests above
    # pin bit-identical to the PR 3 commit.)
    "heavy_prune": PS.ProtocolParams(
        **{**_BASE, "step_hours": 48.0, "claim_every": 1,
           "churn_per_year": 80.0, "steps": 10}, seed=6),
}

_SCALARS = ("repair_traffic_units", "repairs", "cache_hits", "lost_objects",
            "lost_fraction", "final_honest_mean", "honest_min",
            "members_max", "n_groups", "repair_attempts")


def _digest(r: PS.ProtocolResult) -> dict:
    return {
        **{f: getattr(r, f) for f in _SCALARS},
        "alive_frac_trace": np.asarray(r.alive_frac_trace).tolist(),
        "honest_trace": np.asarray(r.honest_trace).tolist(),
        "byz_trace": np.asarray(r.byz_trace).tolist(),
        "loss_events": [list(e) for e in r.loss_events],
    }


def _capture(run_kwargs: dict | None = None) -> dict:
    kw = run_kwargs or {}
    return {name: _digest(PS.run_protocol(p, **kw))
            for name, p in CONFIGS.items()}


def _assert_matches(got: dict, want: dict, label: str) -> None:
    for name, ref in want.items():
        cur = got[name]
        for field, val in ref.items():
            if isinstance(val, float):
                assert cur[field] == pytest.approx(val, rel=0, abs=0), (
                    f"{label}: {name}.{field}")
            else:
                assert cur[field] == val, f"{label}: {name}.{field}"


@pytest.fixture(scope="module")
def golden() -> dict:
    assert GOLDEN.exists(), (
        f"{GOLDEN} missing — regenerate with "
        "`PYTHONPATH=src python -m tests.test_protocol_golden --regen` "
        "from a known-good commit")
    return json.loads(GOLDEN.read_text())


def test_reference_engine_matches_pr3_golden(golden):
    """The preserved scalar path still reproduces PR 3 bit-for-bit."""
    _assert_matches(_capture({"engine": "reference"}), golden, "reference")


def test_vectorized_engine_matches_pr3_golden(golden):
    """The batched/vectorized tick path is bit-identical to PR 3."""
    _assert_matches(_capture({"engine": "vectorized"}), golden, "vectorized")


def test_default_engine_is_vectorized():
    p = CONFIGS["iid_static"]
    a = PS.run_protocol(p)
    b = PS.run_protocol(p, engine="vectorized")
    np.testing.assert_array_equal(a.honest_trace, b.honest_trace)
    assert a.repair_traffic_units == b.repair_traffic_units


def test_view_state_bit_identical():
    """Stronger than the ProtocolResult pin: the raw membership dicts —
    keys AND insertion order, for every view of every node — must match
    between engines at every step. (Timestamp *values* are virtualized by
    the vectorized engine and compared only through behavior: prunes,
    repairs, and the result fields above.)"""
    p = PS.ProtocolParams(
        **{**_BASE, "claim_every": 1, "churn_per_year": 120.0,
           "steps": 8}, seed=9)
    states: dict[tuple, dict] = {}

    def probe(tag):
        def _p(t, net):
            states[(tag, t)] = {
                (n.nid, ch): tuple(v.members)
                for n in net.nodes.values() for ch, v in n.groups.items()}
        return _p

    PS.run_protocol(p, engine="reference", probe=probe("r"))
    PS.run_protocol(p, engine="vectorized", probe=probe("v"))
    for t in range(p.steps):
        assert states[("r", t)] == states[("v", t)], f"views diverge at {t}"



if __name__ == "__main__":
    if "--regen" in sys.argv:
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        # capture from the reference engine (the preserved PR 3 scalar
        # path) — regenerate ONLY from a commit whose reference engine is
        # known-good
        data = _capture({"engine": "reference"})
        GOLDEN.write_text(json.dumps(data, indent=1))
        print(f"wrote {GOLDEN}")
    else:
        print(__doc__)
