"""Sharding rules: resolution+fallback (abstract mesh), ZeRO-1, and a real
multi-device subprocess check that the sharded loss equals single-device."""
import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.distributed import sharding as shd
from repro.models import cache_specs, init_cache, init_params, param_specs

# shd.abstract_mesh handles both the jax>=0.5 (sizes, names) signature and
# the 0.4.x shape_tuple signature
MESH_SINGLE = shd.abstract_mesh((16, 16), ("data", "model"))
MESH_MULTI = shd.abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_resolve_divisibility_fallbacks():
    # 8 kv heads on a 16-way model axis -> replicated kv
    s = shd.resolve_spec(("embed", "kv_heads", "head_dim"), (8192, 8, 128),
                         MESH_SINGLE)
    assert s == P()
    # 64 q heads shard fine
    s = shd.resolve_spec(("embed", "heads", "head_dim"), (8192, 64, 128),
                         MESH_SINGLE)
    assert s == P(None, "model")
    # 60 experts don't divide 16 -> expert_mlp picks up the model axis
    s = shd.resolve_spec(("experts", "embed", "expert_mlp"), (60, 2048, 1408),
                         MESH_SINGLE)
    assert s == P(None, None, "model")
    # 256 experts divide -> expert axis sharded, expert_mlp left replicated
    s = shd.resolve_spec(("experts", "embed", "expert_mlp"), (256, 7168, 2048),
                         MESH_SINGLE)
    assert s == P("model")
    # batch over (pod,data) jointly on the multi-pod mesh
    s = shd.resolve_spec(("batch", "length"), (256, 4096), MESH_MULTI)
    assert s == P(("pod", "data"))
    # batch=1 (long_500k) falls back to replicated; cache_len absorbs axes
    s = shd.resolve_spec(("batch", "cache_len", "kv_heads", "head_dim"),
                         (1, 524288, 8, 128), MESH_SINGLE)
    assert s == P(None, ("data", "model"))


def test_no_axis_used_twice():
    for arch in configs.ARCHS:
        for shape in ("train_4k", "decode_32k"):
            cfg = configs.full_config(arch, shape)
            shapes = jax.eval_shape(
                lambda k: init_params(cfg, k), jax.random.PRNGKey(0)
            )
            specs = shd.tree_specs(param_specs(cfg), shapes, MESH_MULTI)
            for spec, leaf in zip(
                jax.tree_util.tree_leaves(
                    specs, is_leaf=lambda t: isinstance(t, P)
                ),
                jax.tree_util.tree_leaves(shapes),
            ):
                used = []
                for e in spec:
                    if e is None:
                        continue
                    used.extend((e,) if isinstance(e, str) else e)
                assert len(used) == len(set(used)), (arch, spec)
                # divisibility holds
                sizes = dict(zip(MESH_MULTI.axis_names, MESH_MULTI.axis_sizes))
                for e, dim in zip(spec, leaf.shape):
                    if e is None:
                        continue
                    axes = (e,) if isinstance(e, str) else e
                    prod = int(np.prod([sizes[a] for a in axes]))
                    assert dim % prod == 0, (arch, spec, leaf.shape)


def test_cache_specs_resolve_for_all_decode_cells():
    for arch, shape in configs.cells():
        if configs.SHAPES[shape].kind != "decode":
            continue
        cfg = configs.full_config(arch, shape)
        cache_shapes = jax.eval_shape(
            lambda: init_cache(cfg, configs.SHAPES[shape].batch, cfg.cdtype())
        )
        specs = shd.tree_specs(cache_specs(cfg), cache_shapes, MESH_SINGLE)
        assert jax.tree_util.tree_leaves(
            specs, is_leaf=lambda t: isinstance(t, P)
        )


def test_zero1_adds_data_axis():
    spec = shd.zero1_spec(P(None, "model"), (8192, 49152), MESH_SINGLE)
    assert spec == P("data", "model")
    # nothing divisible -> unchanged
    spec = shd.zero1_spec(P(), (7,), MESH_SINGLE)
    assert spec == P()
    # multi-pod uses both pod and data
    spec = shd.zero1_spec(P(None, "model"), (8192, 49152), MESH_MULTI)
    assert spec == P(("pod", "data"), "model")


def test_constrain_noop_without_context():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    y = shd.constrain(x, "batch", None)
    assert y is x


def test_sharded_loss_matches_single_device(subproc):
    out = subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.distributed import sharding as shd
from repro.models import init_params, param_specs
from repro.training import init_train_state, make_train_step
from repro.optim import AdamWConfig

cfg = configs.smoke_config("internlm2-20b")
state = init_train_state(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)}
step = make_train_step(cfg, AdamWConfig())
_, m_single = jax.jit(step)(jax.tree_util.tree_map(jnp.copy, state), batch)

mesh = jax.make_mesh((2, 2), ("data", "model"))
shapes = jax.eval_shape(lambda: state)
resolved = shd.tree_specs(param_specs(cfg), shapes["params"], mesh)
named = jax.tree_util.tree_map(
    lambda s: NamedSharding(mesh, s), resolved,
    is_leaf=lambda t: isinstance(t, P))
state_sh = {"params": named,
            "opt": {"mu": named, "nu": named,
                    "step": NamedSharding(mesh, P())}}
batch_sh = {"tokens": NamedSharding(mesh, P("data"))}
with mesh, shd.logical_axis_rules(None, mesh):
    f = jax.jit(step, in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None))
    new_state, m_sharded = f(state, batch)
a, b = float(m_single["loss"]), float(m_sharded["loss"])
assert abs(a - b) / abs(a) < 2e-4, (a, b)
# params actually sharded
leaf = jax.tree_util.tree_leaves(new_state["params"])[1]
assert len(leaf.sharding.device_set) >= 2
print("OK", a, b)
""",
        devices=4,
    )
    assert "OK" in out
