"""VAULT store/query protocol (Alg. 1) on the simulated peer network."""
import numpy as np
import pytest

from repro.core import chunks as C
from repro.core.network import SimNetwork
from repro.core.rateless import InsufficientFragments
from repro.core.vault import VaultClient

PARAMS = C.CodeParams(k_outer=4, n_chunks=6, k_inner=8, r_inner=20)


def make_net(n=120, byz=0, seed=0):
    net = SimNetwork(seed=seed)
    for i in range(n):
        net.add_node(byzantine=i < byz, seed=i.to_bytes(4, "little"))
    return net


def test_store_query_roundtrip():
    net = make_net()
    client = VaultClient(net, net.alive_nodes()[0])
    data = np.random.default_rng(0).integers(0, 256, 5000, np.uint8).tobytes()
    oid, st = client.store(data, PARAMS)
    assert st.latency_s > 0 and st.bytes_sent > 0
    got, qs = client.query(oid)
    assert got == data
    assert qs.latency_s > 0


def test_store_query_with_byzantine_third():
    net = make_net(n=150, byz=50)  # 1/3 byzantine (claim, store nothing)
    client = VaultClient(net, net.alive_nodes()[60])
    data = b"vault tolerates one third byzantine" * 50
    oid, _ = client.store(data, PARAMS)
    got, _ = client.query(oid)
    assert got == data


def test_query_after_churn_below_threshold():
    net = make_net(n=150, seed=3)
    client = VaultClient(net, net.alive_nodes()[0])
    data = b"churn" * 999
    oid, _ = client.store(data, PARAMS)
    rng = np.random.default_rng(1)
    alive = [n for n in net.alive_nodes() if n.nid != client.node.nid]
    for node in rng.choice(alive, size=45, replace=False):  # ~30% churn
        net.fail_node(node.nid)
    got, _ = client.query(oid)
    assert got == data


def test_query_fails_past_tolerance():
    net = make_net(n=60, seed=5)
    client = VaultClient(net, net.alive_nodes()[0])
    oid, _ = client.store(b"doomed" * 100, PARAMS)
    for node in list(net.alive_nodes()):
        if node.nid != client.node.nid:
            net.fail_node(node.nid)
    with pytest.raises(InsufficientFragments):
        client.query(oid)


def test_object_id_opacity():
    """Chunk hashes are content-addressed but the chunk->object mapping is
    owner-private: two owners storing the SAME object get disjoint chunks
    (different private indices), so observing chunks reveals nothing."""
    net = make_net()
    a = VaultClient(net, net.alive_nodes()[0])
    b = VaultClient(net, net.alive_nodes()[1])
    data = b"same content" * 100
    oid_a, _ = a.store(data, PARAMS)
    oid_b, _ = b.store(data, PARAMS)
    assert oid_a.ohash == oid_b.ohash  # content addressing agrees
    assert set(oid_a.chunk_hashes).isdisjoint(oid_b.chunk_hashes)


def test_content_verification_rejects_corruption():
    net = make_net()
    client = VaultClient(net, net.alive_nodes()[0])
    data = b"integrity" * 64
    oid, _ = client.store(data, PARAMS)
    # corrupt every stored fragment of the first chunk on every holder
    chash = oid.chunk_hashes[0]
    for node in net.alive_nodes():
        for key in list(node.fragments):
            if key[0] == chash:
                frag = bytearray(node.fragments[key])
                frag[0] ^= 0xFF
                node.fragments[key] = bytes(frag)
    # inner_decode must detect the hash mismatch; QUERY still succeeds
    # through the other chunks (k_outer of n_chunks needed)
    got, _ = client.query(oid)
    assert got == data


def test_redundancy_accounting():
    p = C.CodeParams()
    assert abs(p.redundancy - (10 / 8) * (80 / 32)) < 1e-9  # 3.125 (§6)
